"""Auto-planner demo: ONE spec, three engines, chosen by memory budget.

The same declarative ``CoresetSpec`` is compiled against three different
``memory_budget_bytes`` values.  The planner's memory model (calibrated
against the measured yardsticks in BENCH_kernels.json) picks:

  * a LOOSE budget  -> materialized (everything fits on device),
  * a MEDIUM budget -> pipelined   (double-buffered superchunks fit),
  * a TIGHT budget  -> streamed    (one block at a time — minimum footprint).

Every plan prints its full ``describe()`` (engine, resolved knobs, memory
model, exact predicted comm bill), and every build is checked
DRAW-IDENTICAL to its forced-engine plan — the auto-planner changes where
the computation runs, never what it draws.

  PYTHONPATH=src python examples/auto_plan.py
"""

import os
os.environ.setdefault("REPRO_NO_PALLAS", "1")

import jax
import numpy as np

from repro.core import CoresetPipeline, CoresetSpec, VFLDataset


def main() -> None:
    n, d, T, m = 200_000, 30, 3, 512
    rng = np.random.default_rng(0)
    X = rng.standard_normal((n, d), dtype=np.float32)
    y = X @ rng.standard_normal(d).astype(np.float32)
    # numpy-backed parts stay host-resident: the streaming engines only ever
    # put one superchunk on device
    base, rem = divmod(d, T)
    widths = [base + (1 if j < rem else 0) for j in range(T)]
    offs = np.cumsum([0] + widths)
    ds = VFLDataset([X[:, offs[j]:offs[j + 1]] for j in range(T)], y)
    pipeline = CoresetPipeline(ds)
    key = jax.random.PRNGKey(0)

    budgets = {
        "loose (256MB)": 256 << 20,
        "medium (16MB)": 16 << 20,
        "tight (2MB)": 2 << 20,
    }
    draws = {}
    for label, budget in budgets.items():
        spec = CoresetSpec(task="vrlr", budgets=m, block_size=8192,
                           chunk_blocks=4, memory_budget_bytes=budget)
        plan = pipeline.plan(spec)
        print(f"--- {label} ---")
        print(plan.describe())
        cs = pipeline.build(plan, key=key)
        # the same spec FORCED onto the chosen engine draws identically
        forced = pipeline.build(spec.replace(engine=plan.engine,
                                             memory_budget_bytes=None),
                                key=key)
        assert np.array_equal(np.asarray(cs.indices), np.asarray(forced.indices))
        print(f"engine={plan.engine}: {cs.m} draws, comm={cs.comm_units} "
              f"(matches forced plan)\n")
        draws[plan.engine] = np.asarray(cs.indices)

    engines = sorted(draws)
    print(f"engines exercised: {engines}")
    # materialized vs streaming draws differ (flat vs hierarchical key
    # chains) — but every streaming engine draws the same multiset
    if "streamed" in draws and "pipelined" in draws:
        assert np.array_equal(draws["streamed"], draws["pipelined"])
        print("streamed == pipelined draws: identical (pinned)")


if __name__ == "__main__":
    main()
