"""Serve a small model with batched requests through the ServeEngine
(prefill + KV-cached greedy/temperature decode).

  PYTHONPATH=src python examples/serve_lm.py --arch llama3.2-1b --batch 4
"""

import os
os.environ.setdefault("REPRO_NO_PALLAS", "1")

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import all_arch_names, get_arch
from repro.models import init_params
from repro.models.lm_serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=all_arch_names())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()     # CPU-feasible member of the family
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    engine = ServeEngine(cfg, params, cache_len=256)

    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab_size)
    prefix = None
    if cfg.kind == "encdec" or cfg.frontend != "none":
        prefix = jax.random.normal(jax.random.fold_in(key, 2),
                                   (args.batch, cfg.num_prefix, cfg.d_model))

    t0 = time.time()
    out = engine.generate(prompts, max_new_tokens=args.new_tokens,
                          temperature=args.temperature,
                          key=jax.random.fold_in(key, 3), prefix_embeds=prefix)
    dt = time.time() - t0
    toks = args.batch * args.new_tokens
    print(f"arch={args.arch} (reduced)  batch={args.batch}  "
          f"{toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s incl. prefill)")
    for b in range(args.batch):
        print(f"  request {b}: {list(map(int, out[b]))}")


if __name__ == "__main__":
    main()
