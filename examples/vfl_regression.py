"""Vertical federated regression walkthrough: every method of the paper's
Table 1 on one synthetic YearPrediction-profile dataset, with per-round
communication bills printed from the ledger.

All coreset construction goes through ONE declarative surface —
``CoresetSpec`` compiled and dispatched by ``CoresetPipeline`` — and the
downstream ridge solve + full-data relative error come from the
``fit_ridge``/``evaluate`` layer (Theorem 4.1's composition).

  PYTHONPATH=src python examples/vfl_regression.py
"""

import os
os.environ.setdefault("REPRO_NO_PALLAS", "1")

import jax

from repro.core import (
    CommLedger,
    CoresetPipeline,
    CoresetSpec,
    VFLDataset,
    central_comm_cost,
    evaluate,
    fit_ridge,
    ridge_closed_form,
    ridge_cost,
    saga_ridge,
)
from repro.data.synthetic import year_prediction_like


def main() -> None:
    key = jax.random.PRNGKey(0)
    X, y = year_prediction_like(key, n=20000)
    y = y - y.mean()
    ds = VFLDataset.from_dense(X, y, T=3)
    n, lam, m = ds.n, 0.1 * ds.n, 2000
    pipeline = CoresetPipeline(ds)

    def report(name, theta, led):
        c = float(ridge_cost(ds.full(), ds.y, theta, lam)) / n
        print(f"{name:12s} cost/n={c:8.3f}  comm={led.total:>12,}")

    led = CommLedger()
    central_comm_cost(n, ds.dims, led)
    theta_full = ridge_closed_form(ds.full(), ds.y, lam)
    report("CENTRAL", theta_full, led)

    led = CommLedger()
    theta = saga_ridge(jax.random.fold_in(key, 1), ds.full(), ds.y, lam,
                       steps=20000, dims=ds.dims, ledger=led)
    report("SAGA", theta, led)

    for name, task in (("C-CENTRAL", "vrlr"), ("U-CENTRAL", "uniform")):
        led = CommLedger()
        spec = CoresetSpec(task=task, budgets=m)
        cs = pipeline.build(spec, key=jax.random.fold_in(key, 2), ledger=led)
        for j in range(ds.T):
            led.party_to_server("rows", j, m * ds.dims[j])
        fit = fit_ridge(ds, cs, lam)
        report(f"{name}({m})", fit.params, led)
        rel = evaluate(ds, fit, baseline=theta_full).rel_error
        print(f"    full-data relative error: {rel:.4f}")
        if name == "C-CENTRAL":
            print("    DIS round bill:")
            for tag, units in sorted(led.by_tag().items()):
                print(f"      {tag:24s} {units:>10,}")


if __name__ == "__main__":
    main()
