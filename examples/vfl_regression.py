"""Vertical federated regression walkthrough: every method of the paper's
Table 1 on one synthetic YearPrediction-profile dataset, with per-round
communication bills printed from the ledger.

  PYTHONPATH=src python examples/vfl_regression.py
"""

import os
os.environ.setdefault("REPRO_NO_PALLAS", "1")

import jax

from repro.core import (
    CommLedger,
    VFLDataset,
    build_coreset,
    central_comm_cost,
    ridge_closed_form,
    ridge_cost,
    saga_ridge,
)
from repro.data.synthetic import year_prediction_like


def main() -> None:
    key = jax.random.PRNGKey(0)
    X, y = year_prediction_like(key, n=20000)
    y = y - y.mean()
    ds = VFLDataset.from_dense(X, y, T=3)
    n, lam, m = ds.n, 0.1 * ds.n, 2000

    def report(name, theta, led):
        c = float(ridge_cost(ds.full(), ds.y, theta, lam)) / n
        print(f"{name:12s} cost/n={c:8.3f}  comm={led.total:>12,}")

    led = CommLedger()
    central_comm_cost(n, ds.dims, led)
    report("CENTRAL", ridge_closed_form(ds.full(), ds.y, lam), led)

    led = CommLedger()
    theta = saga_ridge(jax.random.fold_in(key, 1), ds.full(), ds.y, lam,
                       steps=20000, dims=ds.dims, ledger=led)
    report("SAGA", theta, led)

    for name, task in (("C-CENTRAL", "vrlr"), ("U-CENTRAL", "uniform")):
        led = CommLedger()
        cs = build_coreset(task, ds, m, key=jax.random.fold_in(key, 2),
                           ledger=led)
        XS, yS, w = cs.materialize(ds)
        for j in range(ds.T):
            led.party_to_server("rows", j, m * ds.dims[j])
        report(f"{name}({m})", ridge_closed_form(XS, yS, lam, w), led)
        if name == "C-CENTRAL":
            print("    DIS round bill:")
            for tag, units in sorted(led.by_tag().items()):
                print(f"      {tag:24s} {units:>10,}")


if __name__ == "__main__":
    main()
