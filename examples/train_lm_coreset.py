"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps with the paper's coreset batch selection, vs dense and uniform
baselines.

This is the first-class-framework integration of the paper (DESIGN.md §3):
each step scores the batch with party-local leverage scores (Algorithm 2 on
the model-axis feature slices), DIS-samples an m-row weighted coreset, and
runs the expensive forward/backward on the coreset only — an unbiased
gradient at ~fraction of the compute/communication.

  PYTHONPATH=src python examples/train_lm_coreset.py --steps 300 --mode coreset
  PYTHONPATH=src python examples/train_lm_coreset.py --compare   # all 3 modes
"""

import os
os.environ.setdefault("REPRO_NO_PALLAS", "1")

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.selector import SelectorConfig
from repro.data.lm import TokenStream
from repro.optim.schedules import cosine_with_warmup
from repro.train import make_train_step, save_checkpoint, train_state_init
from repro.models.api import param_count


def small_llama():
    """~100M-param member of the llama3 family (full code path, CPU-feasible)."""
    return dataclasses.replace(
        get_arch("llama3.2-1b"),
        num_layers=4, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        d_ff=1536, vocab_size=8192, param_dtype=jax.numpy.float32,
        remat=False, attn_chunk=64,
    )


def train(mode: str, steps: int, batch: int, seq: int, seed: int = 0,
          ckpt_dir: str = None, score: str = "leverage"):
    cfg = small_llama()
    key = jax.random.PRNGKey(seed)
    state = train_state_init(key, cfg)
    n_params = param_count(state["params"])
    sel = (SelectorConfig(mode=mode, fraction=0.25, score=score)
           if mode != "none" else None)
    step = jax.jit(make_train_step(cfg, cosine_with_warmup(3e-4, 20, steps), sel))
    stream = iter(TokenStream(vocab=cfg.vocab_size, seq_len=seq,
                              batch_size=batch, seed=seed))
    losses, t0 = [], time.time()
    for i in range(steps):
        state, m = step(state, next(stream), jax.random.fold_in(key, i))
        losses.append(float(m["ce"]))
        if (i + 1) % max(steps // 10, 1) == 0:
            dt = (time.time() - t0) / (i + 1)
            print(f"[{mode:8s}] step {i+1:4d}/{steps} ce={losses[-1]:.4f} "
                  f"avg10={np.mean(losses[-10:]):.4f} {dt*1e3:.0f} ms/step")
    if ckpt_dir:
        save_checkpoint(ckpt_dir, state, steps)
        print(f"[{mode}] checkpoint saved to {ckpt_dir}")
    return np.asarray(losses), n_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mode", default="coreset", choices=["none", "uniform", "coreset"])
    ap.add_argument("--score", default="leverage", choices=["leverage", "norm"],
                    help="coreset score backend (norm = cheap row-norm ablation)")
    ap.add_argument("--compare", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    modes = ["none", "uniform", "coreset"] if args.compare else [args.mode]
    results = {}
    for mode in modes:
        losses, n_params = train(mode, args.steps, args.batch, args.seq,
                                 ckpt_dir=args.ckpt if mode == modes[-1] else None,
                                 score=args.score)
        results[mode] = losses
        print(f"[{mode:8s}] params={n_params/1e6:.1f}M "
              f"final ce={np.mean(losses[-10:]):.4f}")
    if args.compare:
        print("\nmode      final-10-avg   tokens-consumed-ratio")
        for mode, losses in results.items():
            frac = 1.0 if mode == "none" else 0.25
            print(f"{mode:8s}  {np.mean(losses[-10:]):12.4f}   {frac:.2f}")


if __name__ == "__main__":
    main()
