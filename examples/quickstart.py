"""Quickstart: the paper's pipeline end-to-end in ~50 lines.

Builds a vertically-partitioned dataset (3 parties), constructs a VRLR
coreset through the unified ``build_coreset`` API (Algorithm 2 + DIS),
solves ridge regression on the coreset, compares cost + communication
against the full-data CENTRAL baseline — then sweeps seeds x budgets in a
single compiled call with ``build_coresets_batched``.

  PYTHONPATH=src python examples/quickstart.py
"""

import os
os.environ.setdefault("REPRO_NO_PALLAS", "1")   # CPU: use jnp refs for speed

import jax
import jax.numpy as jnp

from repro.core import (
    CommLedger,
    VFLDataset,
    build_coreset,
    build_coresets_batched,
    central_comm_cost,
    ridge_closed_form,
    ridge_cost,
)


def main() -> None:
    key = jax.random.PRNGKey(0)
    n, d, T, m = 20000, 30, 3, 800
    X = jax.random.normal(key, (n, d))
    theta_true = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    y = X @ theta_true + 0.3 * jax.random.normal(jax.random.fold_in(key, 2), (n,))
    ds = VFLDataset.from_dense(X, y, T=T)
    lam = 0.1 * n

    # --- full-data CENTRAL baseline ---------------------------------------
    led_full = CommLedger()
    central_comm_cost(n, ds.dims, led_full)
    theta_full = ridge_closed_form(ds.full(), ds.y, lam)
    cost_full = float(ridge_cost(ds.full(), ds.y, theta_full, lam))

    # --- coreset (Algorithm 2 + DIS, via the task registry) ----------------
    led_cs = CommLedger()
    cs = build_coreset("vrlr", ds, m, key=jax.random.fold_in(key, 3),
                       ledger=led_cs)
    XS, yS, w = cs.materialize(ds)
    for j in range(T):                        # ship the m raw rows centrally
        led_cs.party_to_server("rows", j, m * ds.dims[j])
    theta_cs = ridge_closed_form(XS, yS, lam, w)
    cost_cs = float(ridge_cost(ds.full(), ds.y, theta_cs, lam))

    print(f"n={n}  T={T}  coreset m={m}")
    print(f"CENTRAL   cost={cost_full:12.2f}  comm={led_full.total:>12,} units")
    print(f"C-CENTRAL cost={cost_cs:12.2f}  comm={led_cs.total:>12,} units")
    print(f"cost ratio {cost_cs / cost_full:.4f}  "
          f"comm reduction {led_full.total / led_cs.total:.1f}x")

    # --- batched sweep: 4 seeds x 3 budgets, ONE compiled call -------------
    budgets = (200, 400, 800)
    grid = build_coresets_batched("vrlr", ds, budgets,
                                  key=jax.random.fold_in(key, 4), num_seeds=4)
    print(f"\nbatched sweep ({grid.num_seeds} seeds x {budgets}):")
    for mi, mm in enumerate(budgets):
        ratios = []
        for r in range(grid.num_seeds):
            XSb, ySb, wb = grid.coreset(r, mi).materialize(ds)
            th = ridge_closed_form(XSb, ySb, lam, wb)
            ratios.append(float(ridge_cost(ds.full(), ds.y, th, lam)) / cost_full)
        print(f"  m={mm:4d}  cost ratio mean={jnp.mean(jnp.array(ratios)):.4f}  "
              f"comm={grid.coreset(0, mi).comm_units:>7,} units")


if __name__ == "__main__":
    main()
