"""Quickstart: the paper's pipeline end-to-end in ~50 lines.

Declares ONE :class:`CoresetSpec`, compiles it into an ExecutionPlan
(`pipeline.plan(spec).describe()` shows the engine, memory model, and the
exact predicted communication bill BEFORE anything runs), builds the VRLR
coreset (Algorithm 2 + DIS), then closes the loop with the downstream
solve layer: ``fit_ridge`` on the coreset and ``evaluate`` for the paper's
full-data relative error — and finally sweeps seeds x budgets in a single
compiled call through the batched engine.

  PYTHONPATH=src python examples/quickstart.py
"""

import os
os.environ.setdefault("REPRO_NO_PALLAS", "1")   # CPU: use jnp refs for speed

import jax
import jax.numpy as jnp

from repro.core import (
    CommLedger,
    CoresetPipeline,
    CoresetSpec,
    VFLDataset,
    central_comm_cost,
    evaluate,
    fit_ridge,
    ridge_closed_form,
)


def main() -> None:
    key = jax.random.PRNGKey(0)
    n, d, T, m = 20000, 30, 3, 800
    X = jax.random.normal(key, (n, d))
    theta_true = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    y = X @ theta_true + 0.3 * jax.random.normal(jax.random.fold_in(key, 2), (n,))
    ds = VFLDataset.from_dense(X, y, T=T)
    lam = 0.1 * n

    # --- one declarative spec, compiled into an explicit plan --------------
    pipeline = CoresetPipeline(ds)
    spec = CoresetSpec(task="vrlr", budgets=m)
    print(pipeline.plan(spec).describe(), "\n")

    # --- build (Algorithm 2 + DIS) + downstream solve (Theorem 4.1) --------
    led_cs = CommLedger()
    cs = pipeline.build(spec, key=jax.random.fold_in(key, 3), ledger=led_cs)
    for j in range(T):                        # ship the m raw rows centrally
        led_cs.party_to_server("rows", j, m * ds.dims[j])
    fit = fit_ridge(ds, cs, lam)
    report = evaluate(ds, fit)

    led_full = CommLedger()
    central_comm_cost(n, ds.dims, led_full)
    theta_full = ridge_closed_form(ds.full(), ds.y, lam)

    print(f"n={n}  T={T}  coreset m={m}")
    print(f"CENTRAL   cost={report.cost_opt:12.2f}  comm={led_full.total:>12,} units")
    print(f"C-CENTRAL cost={report.cost_fit:12.2f}  comm={led_cs.total:>12,} units")
    print(f"relative error {report.rel_error:.4f}  "
          f"comm reduction {led_full.total / led_cs.total:.1f}x")

    # --- batched sweep: 4 seeds x 3 budgets, ONE compiled call -------------
    budgets = (200, 400, 800)
    grid_spec = CoresetSpec(task="vrlr", budgets=budgets, num_seeds=4,
                            backend="ref")
    grid = pipeline.build(grid_spec, key=jax.random.fold_in(key, 4))
    print(f"\nbatched sweep ({grid.num_seeds} seeds x {budgets}):")
    for mi, mm in enumerate(budgets):
        rels = []
        for r in range(grid.num_seeds):
            fit_b = fit_ridge(ds, grid.coreset(r, mi), lam)
            rels.append(evaluate(ds, fit_b, baseline=theta_full).rel_error)
        print(f"  m={mm:4d}  rel error mean={jnp.mean(jnp.array(rels)):.4f}  "
              f"comm={grid.coreset(0, mi).comm_units:>7,} units")


if __name__ == "__main__":
    main()
