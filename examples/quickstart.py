"""Quickstart: the paper's pipeline end-to-end in ~40 lines.

Builds a vertically-partitioned dataset (3 parties), constructs a VRLR
coreset with Algorithm 2 + DIS, solves ridge regression on the coreset, and
compares cost + communication against the full-data CENTRAL baseline.

  PYTHONPATH=src python examples/quickstart.py
"""

import os
os.environ.setdefault("REPRO_NO_PALLAS", "1")   # CPU: use jnp refs for speed

import jax
import jax.numpy as jnp

from repro.core import (
    CommLedger,
    VFLDataset,
    build_vrlr_coreset,
    central_comm_cost,
    ridge_closed_form,
    ridge_cost,
)


def main() -> None:
    key = jax.random.PRNGKey(0)
    n, d, T, m = 20000, 30, 3, 800
    X = jax.random.normal(key, (n, d))
    theta_true = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    y = X @ theta_true + 0.3 * jax.random.normal(jax.random.fold_in(key, 2), (n,))
    ds = VFLDataset.from_dense(X, y, T=T)
    lam = 0.1 * n

    # --- full-data CENTRAL baseline ---------------------------------------
    led_full = CommLedger()
    central_comm_cost(n, ds.dims, led_full)
    theta_full = ridge_closed_form(ds.full(), ds.y, lam)
    cost_full = float(ridge_cost(ds.full(), ds.y, theta_full, lam))

    # --- coreset (Algorithm 2 + DIS) ---------------------------------------
    led_cs = CommLedger()
    cs = build_vrlr_coreset(jax.random.fold_in(key, 3), ds, m=m, ledger=led_cs)
    XS, yS, w = cs.materialize(ds)
    for j in range(T):                        # Thm 2.5: ship the m rows
        led_cs.party_to_server("rows", j, m * ds.dims[j])
    theta_cs = ridge_closed_form(XS, yS, lam, w)
    cost_cs = float(ridge_cost(ds.full(), ds.y, theta_cs, lam))

    print(f"n={n}  T={T}  coreset m={m}")
    print(f"CENTRAL   cost={cost_full:12.2f}  comm={led_full.total:>12,} units")
    print(f"C-CENTRAL cost={cost_cs:12.2f}  comm={led_cs.total:>12,} units")
    print(f"cost ratio {cost_cs / cost_full:.4f}  "
          f"comm reduction {led_full.total / led_cs.total:.1f}x")


if __name__ == "__main__":
    main()
