"""Online coreset service walkthrough: two tenants stream superchunks into
one CoresetService, query fresh summaries as they go, and redeem a batched
one-shot build — with the composed merge-and-reduce ledger printed at the
end.

  PYTHONPATH=src python examples/serve_coresets.py
"""

import os
os.environ.setdefault("REPRO_NO_PALLAS", "1")

import jax
import numpy as np

from repro.core import VFLDataset
from repro.core.solve import evaluate, fit_kmeans, fit_ridge, full_data_coreset
from repro.serve import CoresetService

CHUNKS, ROWS, D, T, M = 6, 5000, 16, 3, 384


def make_stream(seed, labels):
    rng = np.random.default_rng(seed)
    centers = 2.0 * rng.standard_normal((6, D)).astype(np.float32)
    theta = rng.standard_normal(D).astype(np.float32)
    widths = [D // T + (1 if j < D % T else 0) for j in range(T)]
    chunks = []
    for _ in range(CHUNKS):
        X = (centers[rng.integers(0, 6, ROWS)]
             + rng.standard_normal((ROWS, D)).astype(np.float32))
        y = (X @ theta + 0.1 * rng.standard_normal(ROWS).astype(np.float32)
             if labels else None)
        parts, start = [], 0
        for w in widths:
            parts.append(X[:, start:start + w])
            start += w
        chunks.append((parts, y))
    return chunks


def main() -> None:
    svc = CoresetService()
    svc.register("ridge-co", task="vrlr", budget=M, seed=0, block_size=2048)
    svc.register("cluster-co", task="vkmc", budget=M, seed=1,
                 block_size=2048, k=6)
    streams = {"ridge-co": make_stream(10, True),
               "cluster-co": make_stream(11, False)}

    for r in range(CHUNKS):
        for name in ("ridge-co", "cluster-co"):
            parts, y = streams[name][r]
            rec = svc.insert(name, parts, y)
            print(f"[{name}] chunk {rec.chunk_idx}: {rec.stats.merges} merge(s), "
                  f"rescored {rec.stats.rescored_rows} rows "
                  f"(stream has {svc.state(name).tree.n_total}), "
                  f"plan {'hit' if rec.plan_hit else 'MISS'}, "
                  f"{rec.latency_s * 1e3:.0f} ms, ledger {rec.ledger_total}")

    # fresh summaries, evaluated against the FULL stream (global row ids)
    for name, labels in (("ridge-co", True), ("cluster-co", False)):
        chunks = streams[name]
        stream = VFLDataset(
            [np.concatenate([c[0][j] for c in chunks]) for j in range(T)],
            np.concatenate([c[1] for c in chunks]) if labels else None)
        q = svc.query(name, reduce_to=M)
        if labels:
            lam = 0.1 * stream.n
            base = fit_ridge(stream, full_data_coreset(stream), lam).params
            rep = evaluate(stream, fit_ridge(stream, q.result.coreset(), lam),
                           baseline=base)
        else:
            base = fit_kmeans(stream, full_data_coreset(stream), 6,
                              key=jax.random.PRNGKey(5), restarts=3,
                              backend="ref").params
            rep = evaluate(stream, fit_kmeans(stream, q.result.coreset(), 6,
                                              key=jax.random.PRNGKey(6),
                                              restarts=3, backend="ref"),
                           baseline=base)
        tree = svc.state(name).tree
        print(f"\n[{name}] m={q.m} summary of n={tree.n_total} "
              f"(height {tree.height}): rel_error={rep.rel_error:.4f}, "
              f"query {q.latency_s * 1e3:.0f} ms")
        print(tree.describe())

    # one-shot builds against a shared reference dataset batch ACROSS tenants
    ref_parts, ref_y = streams["ridge-co"][0]
    svc.attach_dataset("ref", VFLDataset(ref_parts, ref_y))
    t1 = svc.submit("ridge-co", "ref", 128, key=jax.random.PRNGKey(20))
    t2 = svc.submit("cluster-co", "ref", 256, key=jax.random.PRNGKey(21))
    built = svc.flush()                      # ONE batched dispatch
    print(f"\nbatched flush: tickets {sorted(built)} -> "
          f"{[int(built[t].indices.shape[0]) for t in sorted(built)]} rows")
    print(svc.describe())


if __name__ == "__main__":
    main()
