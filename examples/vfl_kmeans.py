"""Vertical federated k-means with coresets (Algorithm 3) vs DistDim.

Plants k Gaussian clusters whose geometry is visible to every party
(Assumption 5.1 regime), then compares:
  KMEANS++ (centralised), DISTDIM (Ding et al., O(nT) comm),
  C-KMEANS++ (coreset), U-KMEANS++ (uniform).

  PYTHONPATH=src python examples/vfl_kmeans.py
"""

import os
os.environ.setdefault("REPRO_NO_PALLAS", "1")

import jax

from repro.core import (
    CommLedger,
    VFLDataset,
    build_coreset,
    distdim,
    kmeans,
    kmeans_cost,
)
from repro.core.vkmc import kmeans_central_comm_cost
from repro.data.synthetic import correlated_vfl_data


def main() -> None:
    key = jax.random.PRNGKey(1)
    n, d, T, k, m = 30000, 24, 3, 8, 1000
    X = correlated_vfl_data(key, n, d, T, cross_correlation=0.8, k_clusters=k)
    ds = VFLDataset.from_dense(X, None, T=T)

    led = CommLedger()
    kmeans_central_comm_cost(n, ds.dims, led)
    cent = kmeans(jax.random.fold_in(key, 1), ds.full(), k)
    print(f"KMEANS++   cost={float(kmeans_cost(ds.full(), cent))/n:9.4f} "
          f"comm={led.total:>12,}")

    led = CommLedger()
    cent_dd = distdim(jax.random.fold_in(key, 2), ds, k, ledger=led)
    print(f"DISTDIM    cost={float(kmeans_cost(ds.full(), cent_dd))/n:9.4f} "
          f"comm={led.total:>12,}")

    led = CommLedger()
    cs = build_coreset("vkmc", ds, m, key=jax.random.fold_in(key, 3), k=k,
                       ledger=led)
    XS, _, w = cs.materialize(ds)
    for j in range(T):
        led.party_to_server("rows", j, m * ds.dims[j])
    cent_cs = kmeans(jax.random.fold_in(key, 4), XS, k, w)
    print(f"C-KMEANS++ cost={float(kmeans_cost(ds.full(), cent_cs))/n:9.4f} "
          f"comm={led.total:>12,}   (m={m})")

    led = CommLedger()
    us = build_coreset("uniform", ds, m, key=jax.random.fold_in(key, 5),
                       ledger=led)
    XU, _, wu = us.materialize(ds)
    for j in range(T):
        led.party_to_server("rows", j, m * ds.dims[j])
    cent_u = kmeans(jax.random.fold_in(key, 6), XU, k, wu)
    print(f"U-KMEANS++ cost={float(kmeans_cost(ds.full(), cent_u))/n:9.4f} "
          f"comm={led.total:>12,}   (m={m})")


if __name__ == "__main__":
    main()
