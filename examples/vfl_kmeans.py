"""Vertical federated k-means with coresets (Algorithm 3) vs DistDim.

Plants k Gaussian clusters whose geometry is visible to every party
(Assumption 5.1 regime), then compares:
  KMEANS++ (centralised), DISTDIM (Ding et al., O(nT) comm),
  C-KMEANS++ (coreset), U-KMEANS++ (uniform).

Coresets are declared as ``CoresetSpec``s and built by ``CoresetPipeline``;
the downstream weighted k-means and the full-data relative error come from
the ``fit_kmeans``/``evaluate`` layer (Theorem 5.2's composition).

  PYTHONPATH=src python examples/vfl_kmeans.py
"""

import os
os.environ.setdefault("REPRO_NO_PALLAS", "1")

import jax

from repro.core import (
    CommLedger,
    CoresetPipeline,
    CoresetSpec,
    VFLDataset,
    distdim,
    evaluate,
    fit_kmeans,
    full_data_coreset,
    kmeans_cost,
)
from repro.core.vkmc import kmeans_central_comm_cost
from repro.data.synthetic import correlated_vfl_data


def main() -> None:
    key = jax.random.PRNGKey(1)
    n, d, T, k, m = 30000, 24, 3, 8, 1000
    X = correlated_vfl_data(key, n, d, T, cross_correlation=0.8, k_clusters=k)
    ds = VFLDataset.from_dense(X, None, T=T)
    pipeline = CoresetPipeline(ds)

    led = CommLedger()
    kmeans_central_comm_cost(n, ds.dims, led)
    # the CENTRAL baseline is the identity coreset through the same solver;
    # best-of-5 restarts keeps the baseline out of bad Lloyd basins
    fit_full = fit_kmeans(ds, full_data_coreset(ds), k,
                          key=jax.random.fold_in(key, 1), restarts=5)
    print(f"KMEANS++   cost={fit_full.objective/n:9.4f} comm={led.total:>12,}")

    led = CommLedger()
    cent_dd = distdim(jax.random.fold_in(key, 2), ds, k, ledger=led)
    print(f"DISTDIM    cost={float(kmeans_cost(ds.full(), cent_dd))/n:9.4f} "
          f"comm={led.total:>12,}")

    for name, task in (("C-KMEANS++", "vkmc"), ("U-KMEANS++", "uniform")):
        led = CommLedger()
        spec = CoresetSpec(task=task, budgets=m,
                           params={"k": k} if task == "vkmc" else {})
        cs = pipeline.build(spec, key=jax.random.fold_in(key, 3), ledger=led)
        for j in range(T):
            led.party_to_server("rows", j, m * ds.dims[j])
        fit = fit_kmeans(ds, cs, k, key=jax.random.fold_in(key, 4),
                         restarts=3)
        rep = evaluate(ds, fit, baseline=fit_full.params)
        print(f"{name} cost={rep.cost_fit/n:9.4f} comm={led.total:>12,}   "
              f"(m={m}, rel err {rep.rel_error:+.4f})")


if __name__ == "__main__":
    main()
