"""rwkv6-3b [ssm] — RWKV-6 "Finch": 32L d_model=2560 (attention-free,
data-dependent decay WKV), channel-mix d_ff=8960, vocab=65536.
[arXiv:2404.05892]
"""

from repro.configs.base import ArchConfig, arch_registry


@arch_registry.register("rwkv6-3b")
def rwkv6_3b() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-3b",
        family="ssm",
        source="arXiv:2404.05892",
        num_layers=32,
        d_model=2560,
        num_heads=40,            # WKV heads, head_dim 64
        num_kv_heads=40,
        head_dim=64,
        d_ff=8960,
        vocab_size=65536,
        mixer="rwkv6",
        attn_type="none",
        tie_embeddings=True,
    )
