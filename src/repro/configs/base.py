"""Architecture + input-shape config system.

Every assigned architecture registers an :class:`ArchConfig` (exact published
dims) via ``@arch_registry.register``; ``reduced()`` derives the CPU smoke
variant of the same family (<=2 layers, d_model<=512, <=4 experts).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.utils.registry import Registry

arch_registry = Registry("arch")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    source: str                    # citation for the dims
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads

    # attention
    attn_type: str = "gqa"         # gqa | mla | none
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0        # 0 = full causal
    learned_pos: int = 0           # >0: learned position table of this size (whisper)

    # MLA (deepseek-v2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0
    shared_d_ff: int = 0           # dense (shared-expert) branch alongside MoE

    # mixer selection
    mixer: str = "attention"       # attention | rwkv6 | hymba
    ssm_state: int = 0
    mamba_d_inner: int = 0

    # structure
    kind: str = "decoder"          # decoder | encdec
    enc_layers: int = 0
    frontend: str = "none"         # none | audio_stub | vision_stub
    num_prefix: int = 0            # precomputed frame/patch embeddings
    tie_embeddings: bool = True

    # numerics / execution
    param_dtype: jnp.dtype = jnp.bfloat16
    remat: bool = True
    fsdp: bool = False             # shard stacked-layer params over `data`
    attn_chunk: int = 1024
    ssm_chunk: int = 32
    capacity_factor: float = 1.25
    scan_unroll: bool = False      # unroll the layer scan (dry-run cost fidelity)
    moe_dispatch: str = "kloop"    # kloop (paper-faithful GSPMD baseline) | einsum (§Perf)
    moe_group: int = 256           # MoE dispatch group size Sg
    pure_fsdp: bool = False        # weight-gathered parallelism: no TP on layer
    #                                weights (embed/unembed stay vocab-TP) —
    #                                wins for non-16-divisible head geometries

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.mixer in ("rwkv6",) and self.attn_type != "none":
            object.__setattr__(self, "attn_type", "none")

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def vocab_pad(self) -> int:
        """Embedding-table rows: vocab padded to a multiple of 256 so the
        vocab axis shards evenly (Megatron-style). Logical vocab stays
        ``vocab_size``; padded logit columns are masked to -inf."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def dec_layers(self) -> int:
        return self.num_layers

    def supports_long_context(self) -> bool:
        """True if decode with a 524k context is sub-quadratic/O(window)."""
        if self.mixer in ("rwkv6", "hymba"):
            return True
        return self.kind == "decoder"   # dense decoders get the sliding-window variant

    def for_shape(self, shape: "InputShape") -> "ArchConfig":
        """Shape-conditioned variant: long-context decode on attention archs
        switches to the sliding-window cache (sub-quadratic requirement)."""
        if shape.name == "long_500k" and self.attn_type in ("gqa", "mla") and self.mixer == "attention":
            return dataclasses.replace(self, sliding_window=8192)
        return self

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/code paths, toy dims."""
        small_heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, small_heads)
        d = min(self.d_model, 256)
        hd = max(d // small_heads, 16)
        return dataclasses.replace(
            self,
            num_layers=2,
            enc_layers=min(self.enc_layers, 2),
            d_model=d,
            num_heads=small_heads,
            num_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4),
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            moe_d_ff=min(self.moe_d_ff, 128) if self.moe_d_ff else 0,
            shared_d_ff=min(self.shared_d_ff, 128) if self.shared_d_ff else 0,
            kv_lora_rank=min(self.kv_lora_rank, 64) if self.kv_lora_rank else 0,
            q_lora_rank=min(self.q_lora_rank, 64) if self.q_lora_rank else 0,
            qk_nope_dim=32 if self.attn_type == "mla" else self.qk_nope_dim,
            qk_rope_dim=16 if self.attn_type == "mla" else self.qk_rope_dim,
            v_head_dim=32 if self.attn_type == "mla" else self.v_head_dim,
            mamba_d_inner=min(self.mamba_d_inner, 256) if self.mamba_d_inner else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            num_prefix=min(self.num_prefix, 8) if self.num_prefix else 0,
            learned_pos=min(self.learned_pos, 4096) if self.learned_pos else 0,
            param_dtype=jnp.float32,
            remat=False,
            fsdp=False,
            attn_chunk=8,
            ssm_chunk=4,
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    phase: str                     # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.phase == "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def get_arch(name: str) -> ArchConfig:
    return arch_registry.get(name)()


def all_arch_names():
    return list(arch_registry.keys())
