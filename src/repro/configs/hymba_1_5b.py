"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504,
parallel attention + mamba heads in every layer, ssm_state=16,
vocab=32001.  [arXiv:2411.13676]

Adaptation (DESIGN.md): Hymba's meta-tokens and per-layer global/local
mix are simplified to sliding-window attention heads (window 1024, as most
Hymba layers use SWA) in parallel with a Mamba branch; outputs are
mean-fused after per-branch normalisation.
"""

from repro.configs.base import ArchConfig, arch_registry


@arch_registry.register("hymba-1.5b")
def hymba_1_5b() -> ArchConfig:
    return ArchConfig(
        name="hymba-1.5b",
        family="hybrid",
        source="arXiv:2411.13676",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        mixer="hymba",
        sliding_window=1024,
        ssm_state=16,
        mamba_d_inner=1600,
        rope_theta=10000.0,
        tie_embeddings=True,
    )
