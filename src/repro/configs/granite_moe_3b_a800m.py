"""granite-moe-3b-a800m [moe] — IBM Granite 3.0 MoE family.

32L d_model=1536 24H (GQA kv=8) per-expert d_ff=512 vocab=49155,
MoE 40 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base]
(Assignment note: the structured field says 40e; the bracket note says 32e —
we follow the structured field, recorded in DESIGN.md.)
"""

from repro.configs.base import ArchConfig, arch_registry


@arch_registry.register("granite-moe-3b-a800m")
def granite_moe_3b_a800m() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
        num_layers=32,
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        head_dim=64,
        d_ff=512,
        vocab_size=49155,
        num_experts=40,
        num_experts_per_tok=8,
        moe_d_ff=512,
        rope_theta=10000.0,
        tie_embeddings=True,
    )
