"""whisper-medium [audio] — encoder-decoder backbone: 24 enc + 24 dec layers,
d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.  [arXiv:2212.04356]

Per the assignment carve-out the mel-spectrogram + conv frontend is a STUB:
``input_specs`` provides precomputed frame embeddings (B, 1500, d_model).
Positions use a learned table (Whisper uses sinusoidal-enc/learned-dec; we
use learned for both — adaptation noted in DESIGN.md).  long_500k is SKIPPED
for this arch (enc-dec, 1500-frame encoder context — see DESIGN.md).
"""

from repro.configs.base import ArchConfig, arch_registry


@arch_registry.register("whisper-medium")
def whisper_medium() -> ArchConfig:
    return ArchConfig(
        name="whisper-medium",
        family="audio",
        source="arXiv:2212.04356",
        num_layers=24,           # decoder layers
        enc_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=51865,
        kind="encdec",
        frontend="audio_stub",
        num_prefix=1500,         # encoder frames
        learned_pos=65536,
        rope_theta=0.0,          # no RoPE
        tie_embeddings=True,
    )
