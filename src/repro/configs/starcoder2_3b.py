"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152; GQA + RoPE.  [arXiv:2402.19173]
"""

from repro.configs.base import ArchConfig, arch_registry


@arch_registry.register("starcoder2-3b")
def starcoder2_3b() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-3b",
        family="dense",
        source="arXiv:2402.19173",
        num_layers=30,
        d_model=3072,
        num_heads=24,
        num_kv_heads=2,
        head_dim=128,
        d_ff=12288,
        vocab_size=49152,
        rope_theta=100000.0,
        tie_embeddings=True,
    )
