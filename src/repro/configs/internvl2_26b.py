"""internvl2-26b [vlm] — InternViT (stub frontend) + InternLM2 backbone:
48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.  [arXiv:2404.16821]

Per the assignment carve-out, the vision encoder + projector are a STUB:
``input_specs`` provides precomputed patch embeddings (B, num_prefix, d_model)
which the language model consumes prepended to the token stream.
"""

from repro.configs.base import ArchConfig, arch_registry


@arch_registry.register("internvl2-26b")
def internvl2_26b() -> ArchConfig:
    return ArchConfig(
        name="internvl2-26b",
        family="vlm",
        source="arXiv:2404.16821",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92553,
        rope_theta=1000000.0,
        frontend="vision_stub",
        num_prefix=256,          # one tile of ViT patch embeddings
        tie_embeddings=False,
        fsdp=True,
    )
