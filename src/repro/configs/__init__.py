"""Architecture configs — one module per assigned architecture.

Importing this package registers every arch in ``arch_registry``; select via
``get_arch("<id>")`` or ``--arch <id>`` on the launchers.
"""

from repro.configs.base import (
    ArchConfig,
    INPUT_SHAPES,
    InputShape,
    all_arch_names,
    arch_registry,
    get_arch,
)

# Register all assigned architectures (import side effects).
from repro.configs import granite_moe_3b_a800m  # noqa: F401
from repro.configs import phi3_medium_14b  # noqa: F401
from repro.configs import qwen3_14b  # noqa: F401
from repro.configs import rwkv6_3b  # noqa: F401
from repro.configs import llama3_2_1b  # noqa: F401
from repro.configs import internvl2_26b  # noqa: F401
from repro.configs import deepseek_v2_236b  # noqa: F401
from repro.configs import whisper_medium  # noqa: F401
from repro.configs import starcoder2_3b  # noqa: F401
from repro.configs import hymba_1_5b  # noqa: F401

__all__ = [
    "ArchConfig",
    "InputShape",
    "INPUT_SHAPES",
    "arch_registry",
    "get_arch",
    "all_arch_names",
]
