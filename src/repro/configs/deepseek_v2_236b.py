"""deepseek-v2-236b [moe] — 60L d_model=5120, MLA with 128 heads
(kv_lora_rank=512, q_lora_rank=1536, nope 128 / rope 64 / v 128),
MoE: 2 shared + 160 routed experts top-6, per-expert d_ff=1536,
vocab=102400.  [arXiv:2405.04434]

Adaptation notes (DESIGN.md): the published model's first layer is dense; we
model it through the always-on shared-expert branch (2 x 1536 = 3072) present
in every layer, keeping the layer stack uniform for lax.scan.
"""

from repro.configs.base import ArchConfig, arch_registry


@arch_registry.register("deepseek-v2-236b")
def deepseek_v2_236b() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-236b",
        family="moe",
        source="arXiv:2405.04434",
        num_layers=60,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,        # MLA: every head has latent-derived K/V
        head_dim=128,
        d_ff=1536,
        vocab_size=102400,
        attn_type="mla",
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        num_experts=160,
        num_experts_per_tok=6,
        moe_d_ff=1536,
        shared_d_ff=3072,        # 2 shared experts
        rope_theta=10000.0,
        tie_embeddings=False,
        fsdp=True,
    )
