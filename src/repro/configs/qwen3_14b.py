"""qwen3-14b [dense] — 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936; qk_norm + GQA.  [hf:Qwen/Qwen3-8B family]
"""

from repro.configs.base import ArchConfig, arch_registry


@arch_registry.register("qwen3-14b")
def qwen3_14b() -> ArchConfig:
    return ArchConfig(
        name="qwen3-14b",
        family="dense",
        source="hf:Qwen/Qwen3-8B",
        num_layers=40,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=17408,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1000000.0,
        tie_embeddings=False,
        fsdp=True,
    )
