"""Integrity layer: checksummed wire envelopes, value-level validators, and
numerical-health guardrails for the VFL coreset protocols.

The paper's (1 +- eps) guarantees (Thm 2.4/2.5) assume every party reports
honest round-1 mass tables and round-2 uploads.  A single silently corrupted
mass table skews the DIS sampling distribution and destroys solution quality
WITHOUT raising any error — the dominant practical failure mode of vertically
partitioned systems.  This module supplies three independent defenses:

* :class:`WireEnvelope` — a payload digest (CRC32 over the raw bytes) plus a
  shape/dtype header, sealed by the sender and verified on delivery by
  :class:`~repro.core.faults.Transport`.  Detected mismatches are
  retransmitted and billed under the exact ``retry/<tag>`` accounting the
  fault seam already uses.  This catches TRANSPORT-level corruption (bit
  flips on the wire); it cannot catch a lying sender who re-seals.  When a
  :mod:`repro.core.wire` codec compresses the payload, the envelope seals
  the ENCODED bytes (:meth:`WireEnvelope.seal_bytes`): the CRC covers the
  compressed payload — per-block scales and quantized words alike — so
  detection is independent of the codec's numeric tolerance.
* Value-level validators (:func:`check_mass_table`, :func:`check_weights`,
  :func:`check_merge_children`) — host-side numpy checks at every
  accumulation seam: mass tables finite and nonnegative, row sums
  cross-checked against the independently communicated round-1 scalar
  totals the schedule already bills, total sensitivity within its task
  bound, realized weights positive and finite.  A violation raises a
  party-attributed :exc:`IntegrityError` under ``fault_policy="fail"`` or
  triggers quarantine (drop the lying party, rescore the survivors) under
  ``fault_policy="quarantine"``.
* :class:`HealthReport` — numerical-health guardrails independent of any
  fault: finite fractions, per-party Gram condition numbers (streaming
  VRLR), and mass-concentration statistics, attached to builds and surfaced
  through ``plan.describe()``, ``CoresetService.stats`` and the tree's
  merge pre-checks.

Everything here is pure host-side numpy: the validators never enter a traced
path, never consume PRNG state, and never touch the ledger when the data is
clean — with integrity checks on but no faults injected, every engine stays
bit-identical to the unchecked build in draws AND ledger entries.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np


class IntegrityError(RuntimeError):
    """A value-level integrity violation, attributed to the offending party.

    ``party`` is the party index the violation is pinned on (or ``None``
    when the violation cannot be attributed to a single party, e.g. a
    server-side merge invariant)."""

    def __init__(self, party: Optional[int], reason: str,
                 tag: Optional[str] = None) -> None:
        who = "server" if party is None else f"party {party}"
        where = f" on {tag!r}" if tag else ""
        super().__init__(f"integrity violation by {who}{where}: {reason}")
        self.party = None if party is None else int(party)
        self.tag = tag
        self.reason = reason


def payload_digest(payload: Any) -> int:
    """CRC32 of the payload's raw bytes — stable across processes (Python's
    ``hash`` is salted per process and would break replayable envelopes)."""
    arr = np.ascontiguousarray(np.asarray(payload))
    return zlib.crc32(arr.tobytes()) & 0xFFFFFFFF


@dataclasses.dataclass(frozen=True)
class WireEnvelope:
    """Sender-sealed integrity header for one wire payload: a byte digest
    plus the declared shape/dtype, verified on delivery."""

    tag: str
    party: int
    shape: Tuple[int, ...]
    dtype: str
    digest: int

    @staticmethod
    def seal(tag: str, party: int, payload: Any) -> "WireEnvelope":
        arr = np.asarray(payload)
        return WireEnvelope(tag, int(party), tuple(arr.shape),
                            str(arr.dtype), payload_digest(arr))

    @staticmethod
    def seal_bytes(tag: str, party: int, blob: bytes) -> "WireEnvelope":
        """Seal a codec's packed byte string (the compressed-wire form:
        the digest covers the ENCODED payload, so verify against the
        received blob's uint8 view)."""
        return WireEnvelope.seal(tag, party, np.frombuffer(blob, np.uint8))

    def mismatch(self, payload: Any) -> Optional[str]:
        """Why the received payload fails verification, or None if it
        passes.  Shape and dtype are checked before the digest so a header
        mismatch names itself instead of reading as random bit damage."""
        arr = np.asarray(payload)
        if tuple(arr.shape) != self.shape:
            return f"shape {tuple(arr.shape)} != sealed {self.shape}"
        if str(arr.dtype) != self.dtype:
            return f"dtype {arr.dtype} != sealed {self.dtype}"
        if payload_digest(arr) != self.digest:
            return "payload digest mismatch"
        return None

    def verify(self, payload: Any) -> bool:
        return self.mismatch(payload) is None


# --------------------------------------------------------------------------
# Value-level validators
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Finding:
    """One validator hit: which party, and why."""

    party: int
    reason: str


def check_mass_table(
    masses: Any,
    totals: Optional[Any] = None,
    *,
    bound: Optional[float] = None,
    rel_tol: float = 1e-4,
    bound_slack: float = 1.05,
) -> List[Finding]:
    """Validate a (T, cells) mass table at the server's accumulation seam.

    Per party: every entry finite, every entry nonnegative, and — when the
    independently communicated round-1 scalar totals are given — the row
    sum must agree with the party's own declared total within ``rel_tol``
    (a lying party cannot keep both stories straight without also faking
    the scalar round the schedule bills separately).  When ``bound`` is the
    task's total-sensitivity bound (Thm 4.2 / Lemma F.2), the grand total
    must stay within ``bound_slack`` of it; an excess is attributed to the
    party with the largest row sum.  Returns findings in party order.
    """
    m = np.asarray(masses, dtype=np.float64)
    findings: List[Finding] = []
    t = None if totals is None else np.asarray(totals, dtype=np.float64)
    for j, row in enumerate(m):
        finite = np.isfinite(row)
        if not finite.all():
            bad = int((~finite).sum())
            findings.append(Finding(j, f"mass table has {bad} non-finite "
                                       f"entr{'y' if bad == 1 else 'ies'}"))
            continue
        if (row < 0.0).any():
            findings.append(Finding(
                j, f"negative mass (min {row.min():.6g}); sensitivities "
                   f"are nonnegative by construction"))
            continue
        if t is not None:
            s = float(row.sum())
            declared = float(t[j])
            if not np.isfinite(declared):
                findings.append(Finding(j, "non-finite round-1 scalar total"))
                continue
            tol = rel_tol * max(abs(s), abs(declared), 1.0)
            if abs(s - declared) > tol:
                findings.append(Finding(
                    j, f"mass row sums to {s:.6g} but the round-1 scalar "
                       f"total was {declared:.6g}"))
    if bound is not None and not findings:
        grand = float(m.sum())
        if np.isfinite(grand) and grand > bound_slack * bound:
            worst = int(np.argmax(m.sum(axis=1)))
            findings.append(Finding(
                worst, f"total sensitivity {grand:.6g} exceeds the task "
                       f"bound {bound:.6g} (x{bound_slack} slack); largest "
                       f"contribution from party {worst}"))
    return findings


def require_valid_masses(
    masses: Any,
    totals: Optional[Any] = None,
    *,
    bound: Optional[float] = None,
    tag: str = "dis/round1/G_j",
    policy: str = "fail",
    rel_tol: float = 1e-4,
) -> Tuple[int, ...]:
    """Run the mass-table validators under a fault policy.

    Under ``"quarantine"`` the sorted offender set is returned for the
    caller's degrade machinery; under any other policy the first finding
    raises a party-attributed :exc:`IntegrityError`.  Clean data returns
    ``()`` either way.  ``rel_tol`` widens the row-sum/scalar cross-check
    for quantized wire tables (the caller knows the codec's tolerance);
    the finiteness/nonnegativity/bound checks are tolerance-independent."""
    findings = check_mass_table(masses, totals, bound=bound, rel_tol=rel_tol)
    if not findings:
        return ()
    if policy == "quarantine":
        return tuple(sorted({f.party for f in findings}))
    f = findings[0]
    raise IntegrityError(f.party, f.reason, tag=tag)


def check_weights(weights: Any) -> Optional[str]:
    """Realized coreset weights must be positive and finite — anything else
    means a corrupted mass total or score leaked into the draw.  Returns
    the violation string, or None."""
    w = np.asarray(weights, dtype=np.float64)
    if w.size == 0:
        return "empty weight vector"
    finite = np.isfinite(w)
    if not finite.all():
        return f"{int((~finite).sum())} non-finite weight(s)"
    if (w <= 0.0).any():
        return f"min weight {w.min():.6g} <= 0"
    return None


def check_merge_children(
    indices: Sequence[Any], weights: Sequence[Any]
) -> None:
    """Tree-merge pre-checks: every child's weights positive/finite, and no
    global id appears in two DIFFERENT children.

    Children of a merge summarize DISJOINT stream segments, so a cross-child
    id collision means a corrupted upload or a broken offset chain.  (Ids
    may legitimately repeat WITHIN a child — DIS samples with replacement.)
    Raises :exc:`IntegrityError` naming the offending child as the party."""
    for c, w in enumerate(weights):
        why = check_weights(w)
        if why is not None:
            raise IntegrityError(c, f"merge child {c}: {why}",
                                 tag="merge/children")
    for a in range(len(indices)):
        ia = np.unique(np.asarray(indices[a]))
        for b in range(a + 1, len(indices)):
            clash = np.intersect1d(ia, np.asarray(indices[b]))
            if clash.size:
                raise IntegrityError(
                    b, f"merge children {a} and {b} share {clash.size} "
                       f"global id(s) (first: {int(clash[0])}); children "
                       f"must summarize disjoint stream segments",
                    tag="merge/children")


# --------------------------------------------------------------------------
# Numerical-health guardrails (fault-independent)
# --------------------------------------------------------------------------

GRAM_COND_WARN = 1e8


@dataclasses.dataclass(frozen=True)
class HealthReport:
    """Numerical health of one build's scoring state — computed host-side
    from the mass table (and, for streaming VRLR, the accumulated Gram
    spectra), independent of any injected fault.

    ``max_cell_share`` is the largest single cell's share of the total
    sensitivity G — the sampling concentration (a share near 1 means the
    coreset draw is dominated by one (party, block) cell)."""

    finite_fraction: float
    mass_total: float
    max_cell_share: float
    party_shares: Tuple[float, ...]
    zero_mass_parties: Tuple[int, ...] = ()
    gram_conds: Optional[Tuple[float, ...]] = None
    notes: Tuple[str, ...] = ()

    @property
    def healthy(self) -> bool:
        return (self.finite_fraction == 1.0 and self.mass_total > 0.0
                and not self.zero_mass_parties and not self.notes)

    def describe(self) -> str:
        lines = [
            f"HealthReport: {'healthy' if self.healthy else 'WARNINGS'}",
            f"  finite fraction: {self.finite_fraction:.6f}",
            f"  total sensitivity G: {self.mass_total:.6g}",
            f"  max cell share: {self.max_cell_share:.4f}",
            "  party shares: "
            + ", ".join(f"{s:.4f}" for s in self.party_shares),
        ]
        if self.gram_conds is not None:
            lines.append("  Gram condition numbers: "
                         + ", ".join(f"{c:.3g}" for c in self.gram_conds))
        if self.zero_mass_parties:
            lines.append(f"  zero-mass parties: "
                         f"{list(self.zero_mass_parties)}")
        for n in self.notes:
            lines.append(f"  note: {n}")
        return "\n".join(lines)


def health_from_masses(
    masses: Any,
    gram_conds: Optional[Any] = None,
    cond_warn: float = GRAM_COND_WARN,
) -> HealthReport:
    """Build a :class:`HealthReport` from any (T, cells) nonnegative mass
    table — per-row scores for the materialized engine (cells = rows), the
    (T, num_blocks) block table for the streaming engines."""
    m = np.asarray(masses, dtype=np.float64)
    if m.ndim != 2:
        m = m.reshape(len(m), -1)
    finite = np.isfinite(m)
    total_cells = max(m.size, 1)
    finite_fraction = float(finite.sum()) / total_cells
    clean = np.where(finite, m, 0.0)
    party_sums = clean.sum(axis=1)
    total = float(party_sums.sum())
    shares = tuple(float(s / total) if total > 0 else 0.0
                   for s in party_sums)
    max_share = float(clean.max() / total) if total > 0 else 0.0
    zero = tuple(int(j) for j, s in enumerate(party_sums) if s <= 0.0)
    notes: List[str] = []
    if finite_fraction < 1.0:
        notes.append(f"{m.size - int(finite.sum())} non-finite mass entries")
    if total <= 0.0:
        notes.append("zero total sensitivity — DIS cannot sample")
    conds: Optional[Tuple[float, ...]] = None
    if gram_conds is not None:
        conds = tuple(float(c) for c in np.asarray(gram_conds, np.float64))
        for j, c in enumerate(conds):
            if not np.isfinite(c):
                notes.append(f"party {j} Gram is singular (constant or "
                             f"all-zero feature slice)")
            elif c > cond_warn:
                notes.append(f"party {j} Gram condition {c:.3g} exceeds "
                             f"{cond_warn:.0e}")
    return HealthReport(
        finite_fraction=finite_fraction, mass_total=total,
        max_cell_share=max_share, party_shares=shares,
        zero_mass_parties=zero, gram_conds=conds, notes=tuple(notes),
    )
