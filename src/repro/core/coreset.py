"""High-level coreset builders: Algorithms 2 and 3 end-to-end.

These glue the party-local scores (:mod:`repro.core.sensitivity`) to the DIS
meta-scheme (:mod:`repro.core.dis`) and return `(S, w)` plus the exact
communication bill.  When the data assumptions (4.1 / 5.1) fail, the SAME
code paths return the (beta, eps)-robust coresets of Remarks 4.3 / 5.3 —
robustness is a property of the guarantee, not of the algorithm.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import sensitivity
from repro.core.comm import CommLedger, null_ledger
from repro.core.dis import dis_sample, uniform_sample
from repro.core.vfl import VFLDataset
from repro.core.vkmc import kmeans


@dataclasses.dataclass
class Coreset:
    """Index coreset: indices into the original rows + importance weights.

    Per Problem 1, the coreset is indices/weights — never raw rows — so the
    construction itself moves no feature data across parties.
    """

    indices: jax.Array   # (m,) int
    weights: jax.Array   # (m,) float
    comm_units: int      # construction cost in paper units

    @property
    def m(self) -> int:
        return int(self.indices.shape[0])

    def materialize(self, ds: VFLDataset) -> Tuple[jax.Array, Optional[jax.Array], jax.Array]:
        """(X_S, y_S, w) on the server — costs 2mT more units when the
        downstream solver needs raw rows (Theorem 2.5's `+2mT` term)."""
        sub = ds.rows(self.indices)
        return sub.full(), sub.y, self.weights


def build_vrlr_coreset(
    key: jax.Array,
    ds: VFLDataset,
    m: int,
    ledger: Optional[CommLedger] = None,
    use_kernel: bool = True,
) -> Coreset:
    """Algorithm 2: per-party ridge-leverage scores + DIS."""
    led = null_ledger(ledger)
    if ds.y is None:
        raise ValueError("VRLR requires labels at party T")
    scores: List[jax.Array] = []
    for j, Xj in enumerate(ds.parts):
        y = ds.y if j == ds.T - 1 else None            # party T appends labels
        scores.append(sensitivity.vrlr_local_scores(Xj, y, use_kernel=use_kernel))
    S, w = dis_sample(key, scores, m, led)
    return Coreset(S, w, led.total)


def build_vkmc_coreset(
    key: jax.Array,
    ds: VFLDataset,
    k: int,
    m: int,
    alpha: float = 2.0,
    local_iters: int = 15,
    ledger: Optional[CommLedger] = None,
    use_kernel: bool = True,
) -> Coreset:
    """Algorithm 3: local alpha-approx k-means -> local sensitivities -> DIS.

    ``alpha`` is the approximation factor credited to the local solver
    (k-means++ + Lloyd is O(log k) in theory, ~2 in practice).
    """
    led = null_ledger(ledger)
    scores: List[jax.Array] = []
    for j, Xj in enumerate(ds.parts):
        key, sub = jax.random.split(key)
        local_c = kmeans(sub, Xj, k, iters=local_iters, use_kernel=use_kernel)
        scores.append(sensitivity.vkmc_local_scores(Xj, local_c, alpha, use_kernel=use_kernel))
    key, sub = jax.random.split(key)
    S, w = dis_sample(sub, scores, m, led)
    return Coreset(S, w, led.total)


def build_uniform_coreset(
    key: jax.Array,
    ds: VFLDataset,
    m: int,
    ledger: Optional[CommLedger] = None,
) -> Coreset:
    """The U-* baseline: uniform indices, weight n/m."""
    led = null_ledger(ledger)
    S, w = uniform_sample(key, ds.n, m, ds.T, led)
    return Coreset(S, w, led.total)


# --------------------------------------------------------------------------
# Offline coreset quality evaluation (used by tests / EXPERIMENTS.md)
# --------------------------------------------------------------------------

def vrlr_coreset_ratio(
    ds: VFLDataset, cs: Coreset, thetas: jax.Array, lam: float
) -> jax.Array:
    """max_theta |cost^R(S,theta)/cost^R(X,theta) - 1| over a probe set of
    thetas (empirical epsilon; Definition 2.3)."""
    X, y = ds.full(), ds.y
    XS, yS, w = cs.materialize(ds)

    def ratio(theta):
        reg = lam * jnp.sum(theta * theta)
        full = jnp.sum((X @ theta - y) ** 2) + reg
        sub = jnp.sum(w * (XS @ theta - yS) ** 2) + reg
        return jnp.abs(sub / full - 1.0)

    return jnp.max(jax.vmap(ratio)(thetas))


def vkmc_coreset_ratio(ds: VFLDataset, cs: Coreset, center_sets: jax.Array) -> jax.Array:
    """max_C |cost^C(S,C)/cost^C(X,C) - 1| over probe center sets
    (empirical epsilon; Definition 2.4)."""
    X = ds.full()
    XS, _, w = cs.materialize(ds)

    def ratio(C):
        d2_full = jnp.min(
            jnp.sum((X[:, None, :] - C[None, :, :]) ** 2, axis=-1), axis=1
        ).sum()
        d2_sub = (
            w * jnp.min(jnp.sum((XS[:, None, :] - C[None, :, :]) ** 2, axis=-1), axis=1)
        ).sum()
        return jnp.abs(d2_sub / d2_full - 1.0)

    return jnp.max(jax.vmap(ratio)(center_sets))
