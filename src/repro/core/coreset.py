"""The :class:`Coreset` container + offline coreset-quality evaluation.

The end-to-end builders for Algorithms 2/3 live in :mod:`repro.core.api`
(``build_coreset`` / ``build_coresets_batched``); the seed-era
``build_vrlr_coreset`` / ``build_vkmc_coreset`` / ``build_uniform_coreset``
entry points survive as deprecation shims in :mod:`repro.core`.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import CommLedger, CommSchedule
from repro.core.vfl import VFLDataset

if TYPE_CHECKING:
    from repro.core.faults import DegradedBuild
    from repro.core.integrity import HealthReport


@dataclasses.dataclass
class Coreset:
    """Index coreset: indices into the original rows + importance weights.

    Per Problem 1, the coreset is indices/weights — never raw rows — so the
    construction itself moves no feature data across parties.

    ``degraded`` (default None: a full-federation build) is the
    :class:`~repro.core.faults.DegradedBuild` receipt when the construction
    continued without every party under ``fault_policy="degrade"`` or
    ``"quarantine"`` — it names the dropped parties/rounds and the widened
    sensitivity bound.  ``health`` (default None: engines that never leave
    the traced path, e.g. jit/batched) is the
    :class:`~repro.core.integrity.HealthReport` of the scoring state the
    draw actually used.
    """

    indices: jax.Array   # (m,) int
    weights: jax.Array   # (m,) float
    comm_units: int      # construction cost in paper units
    #: Construction cost in wire bits — the packed bytes the codec actually
    #: moved (32 bits/unit on the raw path, measured blob sizes under a
    #: compressed codec, retransmissions included).  0 from engines that
    #: predate or bypass the bits column (jit/batched cells extracted
    #: without a ledger).
    comm_bits: int = 0
    degraded: Optional["DegradedBuild"] = None
    health: Optional["HealthReport"] = None

    @property
    def m(self) -> int:
        return int(self.indices.shape[0])

    def materialize(
        self, ds: VFLDataset, ledger: Optional[CommLedger] = None
    ) -> Tuple[jax.Array, Optional[jax.Array], jax.Array]:
        """(X_S, y_S, w) on the server.

        Running the downstream scheme on the coreset costs Theorem 2.5's
        ``+2mT`` extra units (each party: m indices down, m per-row scalar
        shares up); pass ``ledger`` to record them via
        ``CommSchedule.materialize``.  Callers that instead ship the raw
        feature blocks to a central solver should charge ``sum_j m*d_j``
        explicitly, as the benchmarks do — not both.
        """
        CommSchedule.materialize(ds.T, self.m).record(ledger)
        sub = ds.rows(self.indices)
        return sub.full(), sub.y, self.weights


@dataclasses.dataclass
class MaterializedCoreset:
    """A coreset together with its (host-resident) rows — the unit of state
    a long-lived serving layer keeps after the source rows are gone.

    An index :class:`Coreset` only points into a live :class:`VFLDataset`;
    a merge-and-reduce tree (:mod:`repro.serve.tree`) must instead retain
    the m selected rows themselves (per party, numpy, host memory) so later
    merges can re-score them without the original data.  ``indices`` stay
    GLOBAL row ids into the full stream, so the result still evaluates
    against the full dataset; ``comm_units`` is the protocol cost that
    produced this node (Thm 2.5-composed across merges).
    """

    indices: np.ndarray                 # (m,) int — global row ids
    weights: np.ndarray                 # (m,) float
    parts: List[np.ndarray]             # party j's selected rows (m, d_j)
    y: Optional[np.ndarray] = None      # (m,), when the task carries labels
    comm_units: int = 0
    comm_bits: int = 0                  # wire bits behind those units

    @property
    def m(self) -> int:
        return int(self.indices.shape[0])

    @property
    def T(self) -> int:
        return len(self.parts)

    def dataset(self) -> VFLDataset:
        """The rows as a (numpy-backed, host-resident) VFLDataset — what a
        merge re-scores, or a downstream solver fits on."""
        return VFLDataset(list(self.parts), self.y)

    def coreset(self) -> Coreset:
        """The index/weight view (global ids) for ledger-free evaluation
        against the full dataset."""
        return Coreset(jnp.asarray(self.indices), jnp.asarray(self.weights),
                       self.comm_units, comm_bits=self.comm_bits)

    @staticmethod
    def from_coreset(
        cs: Coreset, ds: VFLDataset, offset: int = 0
    ) -> "MaterializedCoreset":
        """Materialize ``cs``'s rows out of ``ds`` host-side.  ``offset``
        shifts the (ds-local) indices into the global row space — the leaf
        case of the merge-and-reduce tree, where ``ds`` is one arriving
        superchunk starting at global row ``offset``."""
        offset = int(offset)
        if offset < 0:
            raise ValueError(f"offset must be >= 0, got {offset}")
        idx = np.asarray(cs.indices).astype(np.int64)
        if idx.size and offset > np.iinfo(np.int64).max - int(idx.max()):
            raise OverflowError(
                f"global id overflow: offset {offset} + max local index "
                f"{int(idx.max())} exceeds int64"
            )
        y = None if ds.y is None else np.asarray(ds.y)[idx]
        return MaterializedCoreset(
            indices=idx + offset,
            weights=np.asarray(cs.weights),
            parts=[np.asarray(p)[idx] for p in ds.parts],
            y=y,
            comm_units=int(cs.comm_units),
            comm_bits=int(cs.comm_bits),
        )

    @staticmethod
    def concat(mats: List["MaterializedCoreset"]) -> "MaterializedCoreset":
        """The weighted union of several materialized coresets (rows and
        weights concatenated; no re-sampling, no protocol cost — union is
        server-side bookkeeping).  ``comm_units`` sums the children's."""
        if not mats:
            raise ValueError("concat needs at least one coreset")
        T = mats[0].T
        if any(m.T != T for m in mats):
            raise ValueError("party counts differ across coresets")
        widths = tuple(p.shape[1] for p in mats[0].parts)
        for i, mt in enumerate(mats[1:], start=1):
            w = tuple(p.shape[1] for p in mt.parts)
            if w != widths:
                raise ValueError(
                    f"party widths differ across coresets: coreset 0 has "
                    f"{widths}, coreset {i} has {w}"
                )
        has_y = mats[0].y is not None
        if any((m.y is not None) != has_y for m in mats):
            raise ValueError("label presence differs across coresets")
        return MaterializedCoreset(
            indices=np.concatenate([m.indices for m in mats]),
            weights=np.concatenate([m.weights for m in mats]),
            parts=[np.concatenate([m.parts[j] for m in mats])
                   for j in range(T)],
            y=np.concatenate([m.y for m in mats]) if has_y else None,
            comm_units=sum(m.comm_units for m in mats),
            comm_bits=sum(m.comm_bits for m in mats),
        )


# --------------------------------------------------------------------------
# Offline coreset quality evaluation (used by tests / EXPERIMENTS.md)
# --------------------------------------------------------------------------

def vrlr_coreset_ratio(
    ds: VFLDataset, cs: Coreset, thetas: jax.Array, lam: float
) -> jax.Array:
    """max_theta |cost^R(S,theta)/cost^R(X,theta) - 1| over a probe set of
    thetas (empirical epsilon; Definition 2.3)."""
    X, y = ds.full(), ds.y
    XS, yS, w = cs.materialize(ds)

    def ratio(theta):
        reg = lam * jnp.sum(theta * theta)
        full = jnp.sum((X @ theta - y) ** 2) + reg
        sub = jnp.sum(w * (XS @ theta - yS) ** 2) + reg
        return jnp.abs(sub / full - 1.0)

    return jnp.max(jax.vmap(ratio)(thetas))


def vkmc_coreset_ratio(ds: VFLDataset, cs: Coreset, center_sets: jax.Array) -> jax.Array:
    """max_C |cost^C(S,C)/cost^C(X,C) - 1| over probe center sets
    (empirical epsilon; Definition 2.4)."""
    X = ds.full()
    XS, _, w = cs.materialize(ds)

    def ratio(C):
        d2_full = jnp.min(
            jnp.sum((X[:, None, :] - C[None, :, :]) ** 2, axis=-1), axis=1
        ).sum()
        d2_sub = (
            w * jnp.min(jnp.sum((XS[:, None, :] - C[None, :, :]) ** 2, axis=-1), axis=1)
        ).sum()
        return jnp.abs(d2_sub / d2_full - 1.0)

    return jnp.max(jax.vmap(ratio)(center_sets))
