"""The :class:`Coreset` container + offline coreset-quality evaluation.

The end-to-end builders for Algorithms 2/3 live in :mod:`repro.core.api`
(``build_coreset`` / ``build_coresets_batched``); the seed-era
``build_vrlr_coreset`` / ``build_vkmc_coreset`` / ``build_uniform_coreset``
entry points survive as deprecation shims in :mod:`repro.core`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.comm import CommLedger, CommSchedule
from repro.core.vfl import VFLDataset


@dataclasses.dataclass
class Coreset:
    """Index coreset: indices into the original rows + importance weights.

    Per Problem 1, the coreset is indices/weights — never raw rows — so the
    construction itself moves no feature data across parties.
    """

    indices: jax.Array   # (m,) int
    weights: jax.Array   # (m,) float
    comm_units: int      # construction cost in paper units

    @property
    def m(self) -> int:
        return int(self.indices.shape[0])

    def materialize(
        self, ds: VFLDataset, ledger: Optional[CommLedger] = None
    ) -> Tuple[jax.Array, Optional[jax.Array], jax.Array]:
        """(X_S, y_S, w) on the server.

        Running the downstream scheme on the coreset costs Theorem 2.5's
        ``+2mT`` extra units (each party: m indices down, m per-row scalar
        shares up); pass ``ledger`` to record them via
        ``CommSchedule.materialize``.  Callers that instead ship the raw
        feature blocks to a central solver should charge ``sum_j m*d_j``
        explicitly, as the benchmarks do — not both.
        """
        CommSchedule.materialize(ds.T, self.m).record(ledger)
        sub = ds.rows(self.indices)
        return sub.full(), sub.y, self.weights


# --------------------------------------------------------------------------
# Offline coreset quality evaluation (used by tests / EXPERIMENTS.md)
# --------------------------------------------------------------------------

def vrlr_coreset_ratio(
    ds: VFLDataset, cs: Coreset, thetas: jax.Array, lam: float
) -> jax.Array:
    """max_theta |cost^R(S,theta)/cost^R(X,theta) - 1| over a probe set of
    thetas (empirical epsilon; Definition 2.3)."""
    X, y = ds.full(), ds.y
    XS, yS, w = cs.materialize(ds)

    def ratio(theta):
        reg = lam * jnp.sum(theta * theta)
        full = jnp.sum((X @ theta - y) ** 2) + reg
        sub = jnp.sum(w * (XS @ theta - yS) ** 2) + reg
        return jnp.abs(sub / full - 1.0)

    return jnp.max(jax.vmap(ratio)(thetas))


def vkmc_coreset_ratio(ds: VFLDataset, cs: Coreset, center_sets: jax.Array) -> jax.Array:
    """max_C |cost^C(S,C)/cost^C(X,C) - 1| over probe center sets
    (empirical epsilon; Definition 2.4)."""
    X = ds.full()
    XS, _, w = cs.materialize(ds)

    def ratio(C):
        d2_full = jnp.min(
            jnp.sum((X[:, None, :] - C[None, :, :]) ** 2, axis=-1), axis=1
        ).sum()
        d2_sub = (
            w * jnp.min(jnp.sum((XS[:, None, :] - C[None, :, :]) ** 2, axis=-1), axis=1)
        ).sum()
        return jnp.abs(d2_sub / d2_full - 1.0)

    return jnp.max(jax.vmap(ratio)(center_sets))
