"""Coreset batch selection for sharded-LLM training — the paper's technique
as a first-class framework feature.

Geometry: under tensor (feature) parallelism each `model`-axis shard holds a
slice of every example's features — exactly the VFL layout (shard = party,
example = data row).  A full forward/backward step pays model-axis
collectives proportional to the batch; selecting an m-row weighted coreset of
the B-row batch *before* the expensive step divides the collective +
compute terms by ~B/m while keeping the loss estimate unbiased (importance
weights in the loss — Theorem 2.5's composition, with the training step as
the downstream scheme `A`).

Scoring is Algorithm 2 verbatim, per shard: each model-shard computes the
ridge-leverage scores of its local (B, d_local) feature slice (a d_local x
d_local Gram inverse + the Pallas ``leverage`` row kernel), i.e.
g_i^(j) = ||u_i^(j)||^2 + 1/B.  Scores are combined with a scalar-psum (the
mesh analogue of DIS rounds 1+3: B scalars over the model axis, vs. B*d for
gathering features), and sampling uses a SHARED PRNG key so every shard
draws the identical multiset S with zero extra communication (the mesh
analogue of round 2's broadcast).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.dis import server_plan, uniform_plan
from repro.core.sensitivity import norm_scores, ridge_leverage_scores


@dataclasses.dataclass(frozen=True)
class SelectorConfig:
    mode: str = "coreset"        # none | uniform | coreset
    fraction: float = 0.25       # m = ceil(fraction * B)
    score: str = "leverage"      # leverage | norm
    ridge: float = 1e-4          # Gram regulariser for the local inverse

    def m_of(self, batch: int) -> int:
        return max(1, int(round(self.fraction * batch)))


def local_scores(feats_local: jax.Array, score: str, ridge: float) -> jax.Array:
    """Party-local sensitivity scores for a (B, d_local) feature slice.

    ``leverage``: Algorithm 2's g_i^(j) (ridge leverage + 1/B floor).
    ``norm``: plain row-norm^2 — the cheap ablation.

    Both delegate to the shared score primitives in
    :mod:`repro.core.sensitivity` (the same ones the ``repro.core.api``
    ScoreBackends use).
    """
    B = feats_local.shape[0]
    if score == "norm":
        return norm_scores(feats_local) + 1.0 / B
    return ridge_leverage_scores(feats_local, ridge) + 1.0 / B


def sample_coreset(
    key: jax.Array, g: jax.Array, m: int
) -> Tuple[jax.Array, jax.Array]:
    """m categorical draws ~ g/G with importance weights G/(m*g_S) — the
    server side of DIS (:func:`repro.core.dis.server_plan`).  `g` must be
    identical on all shards (post-psum), and `key` shared, so this is
    replicated compute with no communication."""
    return server_plan(key, g, m)


def select(
    key: jax.Array,
    feats: jax.Array,
    cfg: SelectorConfig,
    axis_name: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Select (indices, weights) from a (B, d) feature batch.

    Inside ``shard_map`` pass ``axis_name='model'``: `feats` is then the
    local slice and scores are psum-combined.  Outside a mesh (or with the
    feature dim unsharded) pass ``axis_name=None``.
    """
    B = feats.shape[0]
    m = cfg.m_of(B)
    if cfg.mode == "uniform":
        return uniform_plan(key, B, m)
    if cfg.mode != "coreset":
        raise ValueError(f"select() called with mode={cfg.mode!r}")
    g = local_scores(feats, cfg.score, cfg.ridge)
    if axis_name is not None:
        g = jax.lax.psum(g, axis_name)       # DIS rounds 1+3: B scalars
    return sample_coreset(key, g, m)


def make_mesh_selector(mesh, cfg: SelectorConfig, model_axis: str = "model"):
    """shard_map-wrapped selector: features sharded (batch=None, d=model).

    Returns fn(key, feats) -> (indices (m,), weights (m,)) with replicated
    outputs.  This is the production path used by the trainer; it makes the
    communication schedule explicit in the lowered HLO (one f32[B]
    all-reduce over the model axis — parse-able by the roofline tooling).
    """
    from jax.experimental.shard_map import shard_map

    def _inner(key, feats_local):
        return select(key, feats_local, cfg, axis_name=model_axis)

    return shard_map(
        _inner,
        mesh=mesh,
        in_specs=(P(), P(None, model_axis)),
        out_specs=(P(), P()),
        check_rep=False,
    )


def weighted_token_loss(per_example_loss: jax.Array, weights: jax.Array) -> jax.Array:
    """Unbiased batch-loss estimate: (1/B) sum_{i in S} w_i * loss_i.

    E[sum w_i loss_i] = sum_i loss_i because the DIS marginal of each draw is
    g_i/G and w_i = G/(m g_i).
    """
    B_equiv = jnp.sum(weights)                       # E[sum w] = B
    return jnp.sum(weights * per_example_loss) / jnp.maximum(B_equiv, 1e-6)
