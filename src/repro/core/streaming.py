"""Streaming block-scan scoring + hierarchical DIS: million-row coreset
construction on fixed device memory, at pipeline speed.

The materialized pipeline (:mod:`repro.core.api`) holds the full (T, n, s)
stacked design and a (T, n) score matrix on device — its memory scales with
n even though the protocol's *communication* scales with m.  This module
makes n a streaming dimension end to end:

  * **Block-scan scoring** — every score path is restructured into passes
    over (T, bs, s) row blocks (``VFLDataset.blocks``), with only ONE block
    device-resident at a time.  VRLR: pass 1 accumulates the per-party
    (s, s) Gram across blocks (the d x d sufficient statistic — the same
    VMEM-scratch accumulation pattern the Pallas ``weighted_gram`` /
    ``kmeans_assign_update`` kernels use across their sequential grid, here
    lifted to HBM-block granularity), then the eigen-pseudo-inverse is
    computed ONCE and pass 2 emits leverage scores block by block.  VKMC:
    local k-means runs on a bounded uniform row subsample, pass 2
    accumulates global cluster sizes/costs via the fused assign-update
    kernel per block, pass 3 emits sensitivities block by block.
  * **Pipelined superchunks** — the per-block Python dispatch loop is the
    throughput ceiling at large n (one host->device copy + one XLA launch
    per (T, bs, s) block).  With ``chunk_blocks=C > 1`` every scan pass
    instead consumes (C, T, bs, s) superchunks staged by
    ``VFLDataset.blocks_prefetched`` (double-buffered: the async transfer
    of superchunk c+1 is issued while c computes; each chunk's fresh
    staging buffer is aliased by the zero-copy CPU ``device_put``, and
    prompt reference dropping caps live slots at two) and runs the
    per-block step as a
    ``jax.lax.scan`` inside ONE jitted dispatch per superchunk — nb Python
    dispatches become nb/C.  The scan body is the *same* per-block
    computation in the same order, so Gram/stats accumulation and the mass
    table stay draw-identical to the per-block path.
  * **Hierarchical DIS** (:func:`repro.core.dis.dis_plan_blocked`) — round 1
    samples (party, block) cells from the (T, nb) block-mass table, round 2
    samples rows within only the *touched* blocks (scores recomputed on
    demand), so the (T, n) score matrix never exists.  The induced marginal
    telescopes to exactly the flat plan's g_i/G.
    :func:`dis_plan_streamed` recomputes touched blocks one dispatch per
    block; :func:`dis_plan_streamed_batched` gathers touched blocks in
    superchunk-sized groups and scores + draws each group in single
    vmapped dispatches (the one-dispatch redraw), bit-for-bit the same
    draws.
  * **Data-parallel masses** (:func:`vrlr_block_masses_sharded` /
    :func:`vkmc_block_masses_sharded`) — rows sharded over the mesh's
    ``data`` axis via ``shard_map``; each device scores its row shard and
    the block-mass table is combined with one psum (plus one sufficient-
    statistic psum: the (T, s, s) Gram for VRLR, the (T, 2k) cluster
    size/cost table for VKMC — the mesh analogue of DIS round 1's T
    scalars).  Communication stays the DIS bill; compute scales with
    devices.

With a numpy-backed :class:`~repro.core.vfl.VFLDataset` the dataset lives in
host memory and peak *device* memory is O(chunk_blocks * block_size * d) at
any n — measured by ``benchmarks/streaming.py`` and recorded in
BENCH_kernels.json (``streaming`` and ``streaming_pipelined`` sections).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dis import (
    DisPlan,
    _categorical_head,
    _float_dtype,
    _head_draws_ok,
    _key_chain,
)
from repro.core.faults import StreamCheckpoint
from repro.core.sensitivity import batched_gram_pinv, kmeans_update, norm_scores
from repro.core.vfl import VFLDataset
from repro.core.vkmc import kmeans
from repro.kernels import ops as kops
from repro.kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class StreamScorer:
    """Block-granular view of one task's party-local scores.

    ``masses[j, b]`` is the block mass G^(j,b) = sum_{i in block b} g_i^(j)
    (the round-1 table of the hierarchical sampler); ``score_block(b)``
    recomputes the (T, bs) scores of block ``b`` on demand, with padded rows
    exactly 0; ``score_blocks(ids)`` recomputes a whole GROUP of blocks as
    one (len(ids), T, bs) batch in a single vmapped dispatch (the
    one-dispatch redraw path), block i bitwise equal to
    ``score_block(ids[i])``.  ``chunk_blocks`` is the superchunk width the
    scorer was built with (the redraw groups touched blocks at the same
    granularity).  ``data_passes`` counts full passes over the dataset the
    scorer spent building its state + mass table (the streamed analogue of
    ``fused_lloyd``'s passes-over-X census).
    """

    T: int
    n: int
    nb: int
    bs: int
    masses: jax.Array                       # (T, nb) float32
    dis_key: jax.Array
    score_block: Callable[[int], jax.Array]
    data_passes: int
    score_blocks: Optional[Callable[[Sequence[int]], jax.Array]] = None
    chunk_blocks: int = 1
    # (T,) retained condition numbers of the accumulated party Grams (VRLR
    # scorers only; None elsewhere) — feeds the build's HealthReport
    gram_conds: Optional[jax.Array] = None


# (task name) -> factory(key, ds, block_size, backend, probe, **params)
STREAM_SCORERS: Dict[str, Callable[..., StreamScorer]] = {}


def register_stream_scorer(name: str):
    """Decorator: register a :class:`StreamScorer` factory for task ``name``."""

    def deco(fn):
        if name in STREAM_SCORERS:
            raise KeyError(f"stream scorer for {name!r} already registered")
        STREAM_SCORERS[name] = fn
        return fn

    return deco


def make_stream_scorer(
    name: str,
    key: jax.Array,
    ds: VFLDataset,
    block_size: int,
    backend: str,
    probe: Optional[Callable[[], None]] = None,
    chunk_blocks: int = 1,
    prefetch: bool = False,
    masses: Optional[jax.Array] = None,
    ckpt: Optional[StreamCheckpoint] = None,
    **params,
) -> StreamScorer:
    """Build the task's :class:`StreamScorer`.  ``masses`` (a precomputed
    (T, nb) block-mass table, e.g. from :func:`vrlr_block_masses_sharded`)
    skips the factory's own mass pass — the ``sharded_masses`` plan toggle:
    round 1 samples from the supplied table while per-row scores still come
    from the scorer's block recomputation.  ``ckpt`` (a bound
    :class:`~repro.core.faults.StreamCheckpoint`) makes every data pass
    resumable: the accumulator + completed-chunk counter is saved after
    each superchunk (or block), and a restarted build with the same ckpt
    continues the fold where it died, draw-identical to an uninterrupted
    run.  ``ckpt=None`` leaves the scan paths untouched."""
    factory = STREAM_SCORERS.get(name)
    if factory is None:
        raise ValueError(
            f"no streaming scorer registered for task {name!r}; "
            f"available: {sorted(STREAM_SCORERS)}"
        )
    return factory(key, ds, block_size, backend, probe=probe,
                   chunk_blocks=chunk_blocks, prefetch=prefetch,
                   masses=masses, ckpt=ckpt, **params)


def with_masses(scorer: StreamScorer, masses) -> StreamScorer:
    """``scorer`` with its block-mass table swapped for the DELIVERED one.

    The wire seam's hook: when the round-1 table crossed a transport — a
    lossy codec's quantized copy, or a corrupted one an unverifying
    transport let through — the hierarchical sampler must draw from what
    arrived, not the honest host table.  The per-row scores the redraw
    recomputes are untouched; only the block-selection marginals change.
    The table is cast to the scorer's mass dtype so downstream weight
    arithmetic keeps its precision contract."""
    tbl = jnp.asarray(
        np.asarray(masses).astype(np.asarray(scorer.masses).dtype))
    if tbl.shape != scorer.masses.shape:
        raise ValueError(
            f"delivered mass table has shape {tbl.shape}; the scorer's "
            f"is {scorer.masses.shape}"
        )
    return dataclasses.replace(scorer, masses=tbl)


def _noop() -> None:
    return None


def _ckpt_load(ckpt: Optional[StreamCheckpoint], phase: str):
    """(resume chunk counter, restored carry-or-None) for one scan phase.
    A completed phase resumes past the end of the traversal, so its loop
    body never re-runs and the carry is the pass's final accumulator."""
    if ckpt is None:
        return 0, None
    saved = ckpt.load(phase)
    return (0, None) if saved is None else saved


def _ckpt_save(ckpt: Optional[StreamCheckpoint], phase: str, done: int,
               carry) -> None:
    if ckpt is not None:
        ckpt.save(phase, done, carry)


def _row_valid(bs: int, nvalid) -> jax.Array:
    return (jnp.arange(bs) < nvalid).astype(jnp.float32)


# --------------------------------------------------------------------------
# VRLR: Gram block-scan -> one pinv -> blockwise leverage
# --------------------------------------------------------------------------

def _gram_body(G, blk, nvalid, use_kernel: bool):
    """G += blk^T diag(valid) blk, batched over the party axis — the ONE
    per-block Gram step shared verbatim by the per-block jit, the superchunk
    scan, and (einsum form) the sharded mass table, so every granularity
    accumulates bit-identically."""
    T, bs, _ = blk.shape
    f = blk.astype(jnp.float32)
    wv = jnp.broadcast_to(_row_valid(bs, nvalid), (T, bs))
    if use_kernel:
        Gb = kops.weighted_gram(f, wv)
    else:
        Gb = jnp.einsum("tns,tn,tnu->tsu", f, wv, f)
    return G + Gb


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _gram_step(G, blk, nvalid, *, use_kernel: bool):
    """Padded rows are zero so the mask is belt-and-braces; the kernel path
    streams the block through the Pallas ``weighted_gram`` grid
    accumulator."""
    return _gram_body(G, blk, nvalid, use_kernel)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _gram_chunk(G, chunk, nvalids, *, use_kernel: bool):
    """The Gram pass over one (C, T, bs, s) superchunk as a ``lax.scan`` —
    C per-block :func:`_gram_body` steps in block order inside ONE
    dispatch (zero-padded trailing blocks contribute exactly 0)."""

    def body(g, xs):
        blk, nv = xs
        return _gram_body(g, blk, nv, use_kernel), None

    G, _ = jax.lax.scan(body, G, (chunk, nvalids))
    return G


def _vrlr_score_body(blk, M, nvalid, n, use_kernel: bool):
    """clip(x_i^T M x_i, 0, 1) + 1/n per party; 0 on padded rows."""
    f = blk.astype(jnp.float32)
    if use_kernel:
        lev = kops.leverage(f, M)
    else:
        lev = jnp.einsum("tns,tsr,tnr->tn", f, M, f)
    sc = jnp.clip(lev, 0.0, 1.0) + 1.0 / n
    ok = jnp.arange(f.shape[1]) < nvalid
    return jnp.where(ok[None, :], sc, 0.0)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _vrlr_score_block(blk, M, nvalid, n, *, use_kernel: bool):
    return _vrlr_score_body(blk, M, nvalid, n, use_kernel)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _vrlr_mass_chunk(chunk, M, nvalids, n, *, use_kernel: bool):
    """(T, C) block masses of one superchunk: the per-block score + sum in a
    single scanned dispatch."""

    def body(carry, xs):
        blk, nv = xs
        return carry, jnp.sum(_vrlr_score_body(blk, M, nv, n, use_kernel),
                              axis=1)

    _, mm = jax.lax.scan(body, 0, (chunk, nvalids))        # (C, T)
    return mm.T


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _vrlr_score_batch(batch, M, nvalids, n, *, use_kernel: bool):
    """(nt, T, bs) scores of a gathered block batch in ONE vmapped dispatch."""
    return jax.vmap(
        lambda blk, nv: _vrlr_score_body(blk, M, nv, n, use_kernel)
    )(batch, nvalids)


def _norm_score_body(blk, nvalid, n):
    """Row-norm^2 ablation scores, blockwise.  Row-local, so each row's value
    is bitwise identical to the materialized ``norm`` backend's."""
    sc = norm_scores(blk) + 1.0 / n
    ok = jnp.arange(blk.shape[1]) < nvalid
    return jnp.where(ok[None, :], sc, 0.0)


@jax.jit
def _norm_score_block(blk, nvalid, n):
    return _norm_score_body(blk, nvalid, n)


@jax.jit
def _norm_mass_chunk(chunk, nvalids, n):
    def body(carry, xs):
        blk, nv = xs
        return carry, jnp.sum(_norm_score_body(blk, nv, n), axis=1)

    _, mm = jax.lax.scan(body, 0, (chunk, nvalids))
    return mm.T


@jax.jit
def _norm_score_batch(batch, nvalids, n):
    return jax.vmap(lambda blk, nv: _norm_score_body(blk, nv, n))(
        batch, nvalids)


def _mass_table(ds, block_size, score_block, probe, ckpt=None):
    """One pass over the blocks collecting the (T, nb) block-mass table."""
    nb, _ = ds.block_geometry(block_size)
    start, saved = _ckpt_load(ckpt, "mass")
    masses = list(saved) if saved is not None else []
    for b in range(start, nb):
        masses.append(jnp.sum(score_block(b), axis=1))
        _ckpt_save(ckpt, "mass", b + 1, tuple(masses))
        probe()
    return jnp.stack(masses, axis=1)                       # (T, nb)


def _chunked_mass_table(ds, block_size, chunk_blocks, prefetch, probe,
                        with_labels, mass_chunk, ckpt=None):
    """The mass-table pass at superchunk granularity: one jitted scan
    dispatch per (C, T, bs, s) superchunk, blocks prefetched double-buffered.
    Column b is bitwise :func:`_mass_table`'s column b (same per-block score
    + sum, same order); trailing zero-padded blocks are sliced away."""
    nb, _ = ds.block_geometry(block_size)
    start, saved = _ckpt_load(ckpt, "mass")
    cols = list(saved) if saved is not None else []
    for b0, chunk, nvalids in ds.blocks_prefetched(
            block_size, with_labels, chunk_blocks, prefetch,
            start_chunk=start):
        cols.append(mass_chunk(chunk, jnp.asarray(nvalids)))   # (T, C)
        del chunk            # drop the slot before the next one is staged
        _ckpt_save(ckpt, "mass", b0 // chunk_blocks + 1, tuple(cols))
        probe()
    return jnp.concatenate(cols, axis=1)[:, :nb]


@register_stream_scorer("vrlr")
def vrlr_stream_scorer(
    key, ds: VFLDataset, block_size: int, backend: str,
    probe: Optional[Callable[[], None]] = None, rcond: float = 1e-6,
    chunk_blocks: int = 1, prefetch: bool = False,
    masses: Optional[jax.Array] = None,
    ckpt: Optional[StreamCheckpoint] = None,
) -> StreamScorer:
    """Algorithm 2's scores without ever holding (n, d): one block-scan pass
    accumulates each party's (s, s) Gram, the eigen-pseudo-inverse is taken
    once, and scores are re-emitted per block from (block, M) alone.  The
    key passes through untouched, matching the materialized ``vrlr`` task's
    deterministic-score contract.

    ``chunk_blocks=C > 1`` (or ``prefetch=True``) switches both passes to
    the pipelined engine: double-buffered (C, T, bs, s) superchunks, the
    per-block step run as a ``lax.scan`` inside one dispatch per superchunk
    — same accumulation order, same mass table, nb/C dispatches.
    """
    probe = probe or _noop
    use_kernel = backend == "pallas"
    nb, bs = ds.block_geometry(block_size)
    widths, s = ds.stacked_widths(with_labels=True)
    n = ds.n
    C = max(1, min(int(chunk_blocks), nb))
    pipelined = C > 1 or prefetch
    gram_conds = None

    if backend == "norm":
        def score_block(b: int) -> jax.Array:
            blk, nvalid = ds.block(b, block_size, with_labels=True)
            return _norm_score_block(blk, nvalid, float(n))

        def score_blocks(ids) -> jax.Array:
            batch, nvalids = ds.gather_blocks(ids, block_size,
                                              with_labels=True)
            return _norm_score_batch(batch, jnp.asarray(nvalids), float(n))

        if masses is None:
            if pipelined:
                masses = _chunked_mass_table(
                    ds, block_size, C, prefetch, probe, True,
                    lambda chunk, nv: _norm_mass_chunk(chunk, nv, float(n)),
                    ckpt=ckpt)
            else:
                masses = _mass_table(ds, block_size, score_block, probe,
                                     ckpt=ckpt)
            passes = 1
        else:
            passes = 0
    else:
        start, saved = _ckpt_load(ckpt, "gram")
        G = saved if saved is not None else jnp.zeros((ds.T, s, s),
                                                      jnp.float32)
        if pipelined:
            for b0, chunk, nvalids in ds.blocks_prefetched(
                    block_size, True, C, prefetch, start_chunk=start):
                G = _gram_chunk(G, chunk, jnp.asarray(nvalids),
                                use_kernel=use_kernel)
                del chunk    # drop the slot before the next one is staged
                _ckpt_save(ckpt, "gram", b0 // C + 1, G)
                probe()
        else:
            for b, blk, nvalid in ds.blocks(block_size, with_labels=True):
                if b < start:
                    continue
                G = _gram_step(G, blk, nvalid, use_kernel=use_kernel)
                _ckpt_save(ckpt, "gram", b + 1, G)
                probe()
        M, gram_conds = batched_gram_pinv(G, rcond, return_cond=True,
                                          expected_rank=widths)

        def score_block(b: int) -> jax.Array:
            blk, nvalid = ds.block(b, block_size, with_labels=True)
            return _vrlr_score_block(blk, M, nvalid, float(n),
                                     use_kernel=use_kernel)

        def score_blocks(ids) -> jax.Array:
            batch, nvalids = ds.gather_blocks(ids, block_size,
                                              with_labels=True)
            return _vrlr_score_batch(batch, M, jnp.asarray(nvalids), float(n),
                                     use_kernel=use_kernel)

        if masses is None:
            if pipelined:
                masses = _chunked_mass_table(
                    ds, block_size, C, prefetch, probe, True,
                    lambda chunk, nv: _vrlr_mass_chunk(chunk, M, nv, float(n),
                                                       use_kernel=use_kernel),
                    ckpt=ckpt)
            else:
                masses = _mass_table(ds, block_size, score_block, probe,
                                     ckpt=ckpt)
            passes = 2
        else:
            passes = 1           # the Gram pass still ran; the mass pass didn't

    return StreamScorer(T=ds.T, n=n, nb=nb, bs=bs, masses=masses,
                        dis_key=key, score_block=score_block,
                        data_passes=passes, score_blocks=score_blocks,
                        chunk_blocks=C, gram_conds=gram_conds)


# --------------------------------------------------------------------------
# VKMC: subsampled local k-means -> stats block-scan -> blockwise scores
# --------------------------------------------------------------------------

def _vkmc_key_chain(key, T: int):
    """One split per party + one for DIS — the materialized ``vkmc`` task's
    exact key consumption, shared by the scorer and the sharded mass table
    so the same seed drives comparable constructions everywhere."""
    subs = []
    for _ in range(T):
        key, sub = jax.random.split(key)
        subs.append(sub)
    key, dis_key = jax.random.split(key)
    return subs, dis_key


def vkmc_local_centers(
    key, ds: VFLDataset, k: int = 10, local_iters: int = 15,
    center_sample: int = 16384, use_kernel: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Party-local alpha-approximate k-means centers from a bounded uniform
    row subsample, padded to the common stacked width: (T, k, s) centers +
    the downstream DIS key.  O(center_sample * d_j) memory per party; the
    subsample's solution is still an alpha'-approximation absorbed by the
    ``alpha`` knob."""
    widths, s = ds.stacked_widths(with_labels=False)
    subs, dis_key = _vkmc_key_chain(key, ds.T)
    centers = []
    for j, sub in enumerate(subs):
        k_smp, k_km = jax.random.split(sub)
        if ds.n > center_sample:
            idx = np.asarray(jax.random.randint(k_smp, (center_sample,), 0,
                                                ds.n))
            Xj = jnp.asarray(ds.parts[j][idx])
        else:
            Xj = jnp.asarray(ds.parts[j])
        c = kmeans(k_km, Xj, k, iters=local_iters, use_kernel=use_kernel)
        centers.append(jnp.pad(c, ((0, 0), (0, s - widths[j]))))
    return jnp.stack(centers), dis_key                     # (T, k, s)


def _vkmc_stats_body(blk, centers, nvalid, use_kernel: bool):
    """(cluster sizes (T, k), cluster costs (T, k)) of one block — the fused
    assign-update pass with validity weights, batched over parties."""
    T, bs, _ = blk.shape
    wv = jnp.broadcast_to(_row_valid(bs, nvalid), (T, bs))
    _, _, _, wsum, ccost = kmeans_update(blk, centers, wv,
                                         use_kernel=use_kernel)
    return wsum, ccost


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _vkmc_stats_step(blk, centers, nvalid, *, use_kernel: bool):
    return _vkmc_stats_body(blk, centers, nvalid, use_kernel)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _vkmc_stats_chunk(csize, ccost, chunk, centers, nvalids,
                      *, use_kernel: bool):
    """The stats pass over one superchunk as a scan of per-block
    :func:`_vkmc_stats_body` steps — one dispatch, same accumulation order
    as the per-block loop."""

    def body(carry, xs):
        cs, cc = carry
        blk, nv = xs
        ws, c2 = _vkmc_stats_body(blk, centers, nv, use_kernel)
        return (cs + ws, cc + c2), None

    (csize, ccost), _ = jax.lax.scan(body, (csize, ccost), (chunk, nvalids))
    return csize, ccost


def _vkmc_score_body(blk, centers, csize, ccost, nvalid, alpha,
                     use_kernel: bool):
    """Algorithm 3 lines 3-11 for one block, given the GLOBAL per-party
    cluster sizes/costs from the stats pass; 0 on padded rows."""
    # kops/kref directly: both batch over the leading party axis (the
    # inline fallback in sensitivity.kmeans_assignment is 2-D only)
    if use_kernel:
        assign, d2 = kops.kmeans_assign(blk, centers)
    else:
        assign, d2 = kref.kmeans_assign(blk, centers)
    cost = jnp.maximum(ccost.sum(axis=1), 1e-30)[:, None]      # (T, 1)
    cs = jnp.maximum(csize, 1.0)                               # (T, k)
    cc_a = jnp.take_along_axis(ccost, assign, axis=1)          # (T, bs)
    cs_a = jnp.take_along_axis(cs, assign, axis=1)
    sc = alpha * d2 / cost + alpha * cc_a / (cs_a * cost) + 2.0 * alpha / cs_a
    ok = jnp.arange(blk.shape[1]) < nvalid
    return jnp.where(ok[None, :], sc, 0.0)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _vkmc_score_block(blk, centers, csize, ccost, nvalid, alpha,
                      *, use_kernel: bool):
    return _vkmc_score_body(blk, centers, csize, ccost, nvalid, alpha,
                            use_kernel)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _vkmc_mass_chunk(chunk, centers, csize, ccost, nvalids, alpha,
                     *, use_kernel: bool):
    def body(carry, xs):
        blk, nv = xs
        sc = _vkmc_score_body(blk, centers, csize, ccost, nv, alpha,
                              use_kernel)
        return carry, jnp.sum(sc, axis=1)

    _, mm = jax.lax.scan(body, 0, (chunk, nvalids))
    return mm.T


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _vkmc_score_batch(batch, centers, csize, ccost, nvalids, alpha,
                      *, use_kernel: bool):
    return jax.vmap(
        lambda blk, nv: _vkmc_score_body(blk, centers, csize, ccost, nv,
                                         alpha, use_kernel)
    )(batch, nvalids)


@register_stream_scorer("vkmc")
def vkmc_stream_scorer(
    key, ds: VFLDataset, block_size: int, backend: str,
    probe: Optional[Callable[[], None]] = None,
    k: int = 10, alpha: float = 2.0, local_iters: int = 15,
    center_sample: int = 16384,
    chunk_blocks: int = 1, prefetch: bool = False,
    masses: Optional[jax.Array] = None,
    ckpt: Optional[StreamCheckpoint] = None,
) -> StreamScorer:
    """Algorithm 3's sensitivities with only one superchunk resident.

    Party j's local alpha-approximate k-means runs on a uniform row
    subsample (:func:`vkmc_local_centers`), then ONE block-scan pass
    accumulates the global cluster sizes/costs through the fused
    assign-update kernel, and scores are re-emitted per block from (block,
    centers, stats).  The key chain (one split per party, one for DIS)
    matches the materialized ``vkmc`` task, so the same seed drives
    comparable constructions.  ``chunk_blocks``/``prefetch`` select the
    pipelined superchunk engine exactly as in :func:`vrlr_stream_scorer`.
    """
    probe = probe or _noop
    use_kernel = backend == "pallas"
    nb, bs = ds.block_geometry(block_size)
    n, T = ds.n, ds.T
    C = max(1, min(int(chunk_blocks), nb))
    pipelined = C > 1 or prefetch

    if backend == "norm":
        _, dis_key = _vkmc_key_chain(key, T)   # the task's exact key budget

        def score_block(b: int) -> jax.Array:
            blk, nvalid = ds.block(b, block_size, with_labels=False)
            return _norm_score_block(blk, nvalid, float(n))

        def score_blocks(ids) -> jax.Array:
            batch, nvalids = ds.gather_blocks(ids, block_size,
                                              with_labels=False)
            return _norm_score_batch(batch, jnp.asarray(nvalids), float(n))

        if masses is None:
            if pipelined:
                masses = _chunked_mass_table(
                    ds, block_size, C, prefetch, probe, False,
                    lambda chunk, nv: _norm_mass_chunk(chunk, nv, float(n)),
                    ckpt=ckpt)
            else:
                masses = _mass_table(ds, block_size, score_block, probe,
                                     ckpt=ckpt)
            passes = 1
        else:
            passes = 0
        return StreamScorer(T=T, n=n, nb=nb, bs=bs, masses=masses,
                            dis_key=dis_key, score_block=score_block,
                            data_passes=passes, score_blocks=score_blocks,
                            chunk_blocks=C)

    centers, dis_key = vkmc_local_centers(
        key, ds, k=k, local_iters=local_iters, center_sample=center_sample,
        use_kernel=use_kernel)

    start, saved = _ckpt_load(ckpt, "stats")
    if saved is not None:
        csize, ccost = saved
    else:
        csize = jnp.zeros((T, k), jnp.float32)
        ccost = jnp.zeros((T, k), jnp.float32)
    if pipelined:
        for b0, chunk, nvalids in ds.blocks_prefetched(
                block_size, False, C, prefetch, start_chunk=start):
            csize, ccost = _vkmc_stats_chunk(csize, ccost, chunk, centers,
                                             jnp.asarray(nvalids),
                                             use_kernel=use_kernel)
            del chunk        # drop the slot before the next one is staged
            _ckpt_save(ckpt, "stats", b0 // C + 1, (csize, ccost))
            probe()
    else:
        for b, blk, nvalid in ds.blocks(block_size, with_labels=False):
            if b < start:
                continue
            ws, cc = _vkmc_stats_step(blk, centers, nvalid,
                                      use_kernel=use_kernel)
            csize = csize + ws
            ccost = ccost + cc
            _ckpt_save(ckpt, "stats", b + 1, (csize, ccost))
            probe()

    def score_block(b: int) -> jax.Array:
        blk, nvalid = ds.block(b, block_size, with_labels=False)
        return _vkmc_score_block(blk, centers, csize, ccost, nvalid,
                                 float(alpha), use_kernel=use_kernel)

    def score_blocks(ids) -> jax.Array:
        batch, nvalids = ds.gather_blocks(ids, block_size, with_labels=False)
        return _vkmc_score_batch(batch, centers, csize, ccost,
                                 jnp.asarray(nvalids), float(alpha),
                                 use_kernel=use_kernel)

    if masses is None:
        if pipelined:
            masses = _chunked_mass_table(
                ds, block_size, C, prefetch, probe, False,
                lambda chunk, nv: _vkmc_mass_chunk(chunk, centers, csize,
                                                   ccost, nv, float(alpha),
                                                   use_kernel=use_kernel),
                ckpt=ckpt)
        else:
            masses = _mass_table(ds, block_size, score_block, probe,
                                 ckpt=ckpt)
        passes = 3
    else:
        passes = 2               # centers + stats passes ran; masses supplied
    return StreamScorer(T=T, n=n, nb=nb, bs=bs, masses=masses,
                        dis_key=dis_key, score_block=score_block,
                        data_passes=passes, score_blocks=score_blocks,
                        chunk_blocks=C)


# --------------------------------------------------------------------------
# Streamed hierarchical DIS: masses + on-demand block recomputation
# --------------------------------------------------------------------------

def dis_plan_streamed(
    scorer: StreamScorer, m: int,
    probe: Optional[Callable[[], None]] = None,
) -> DisPlan:
    """Run the hierarchical sampler against a :class:`StreamScorer` —
    draw-identical to :func:`repro.core.dis.dis_plan_blocked` on the same
    scores, but only the *touched* blocks' scores are ever materialized.

    Round 1 samples m (party, block) cells from ``scorer.masses``; round 2
    recomputes scores for each touched block once and draws the within-block
    rows (per-cell candidate streams and the cell-ordered union match the
    in-memory plan exactly); round 3 gathers the sampled rows' combined
    scores from the same recomputed blocks, accumulated in party order so
    the weight arithmetic matches the flat plan's scan.

    This is the one-dispatch-per-touched-block reference;
    :func:`dis_plan_streamed_batched` produces the same draws with one
    dispatch per touched-block *group*.
    """
    probe = probe or _noop
    T, nb, bs, n = scorer.T, scorer.nb, scorer.bs, scorer.n
    cap = int(m)
    ncells = T * nb
    subs = _key_chain(scorer.dis_key, ncells + 1)
    masses = scorer.masses.astype(_float_dtype())
    G = masses.sum()

    # ---- round 1: cells ~ Multinomial(m, G_jb/G) ----------------------------
    draws = jax.random.categorical(
        subs[0], jnp.log(jnp.maximum(masses.reshape(-1), 1e-30)), shape=(cap,)
    )
    a_cells = np.bincount(np.asarray(draws), minlength=ncells)

    # ---- rounds 2+3: recompute each touched block ONCE, draw its cells' rows
    # and gather their combined scores, then DISCARD the block's scores — at
    # no point is more than one block's score matrix live, so peak memory is
    # O(bs * T) regardless of how many blocks the m draws touch.
    occupied = np.flatnonzero(a_cells)
    touched = sorted({int(c) % nb for c in occupied})
    per_cell: Dict[int, tuple] = {}
    for b in touched:
        sc_b = scorer.score_block(b).astype(_float_dtype())    # (T, bs)
        # party-ordered combined row scores: gather commutes with the adds,
        # so g_b[cand] is bitwise the flat plan's per-party gather scan
        g_b = jnp.zeros((bs,), sc_b.dtype)
        for j in range(T):
            g_b = g_b + sc_b[j]
        row_ok = (b * bs + jnp.arange(bs)) < n
        for j in range(T):
            c = j * nb + b
            if a_cells[c] == 0:
                continue
            lg = jnp.where(row_ok, jnp.log(jnp.maximum(sc_b[j], 1e-30)),
                           -jnp.inf)
            # full-capacity candidate stream, first a_c taken — the
            # iid-prefix convention keeping draws identical to the
            # in-memory plan
            cand = jax.random.categorical(subs[1 + c], lg, shape=(cap,))
            cand = cand[: int(a_cells[c])]
            per_cell[c] = (b * bs + cand, g_b[cand])
        del sc_b, g_b
        probe()
    # server union in cell order — matches the in-memory plan's stable
    # taken-slots-first selection exactly
    cells = sorted(per_cell)
    S = (jnp.concatenate([per_cell[c][0] for c in cells]) if cells
         else jnp.zeros((0,), jnp.int32))                      # (m,)
    g_sum = (jnp.concatenate([per_cell[c][1] for c in cells]) if cells
             else jnp.zeros((0,), masses.dtype))
    w = G / (m * jnp.maximum(g_sum, 1e-30))

    a = jnp.asarray(a_cells.reshape(T, nb).sum(axis=1), jnp.int32)
    return DisPlan(S, w, a, masses.sum(axis=1))


@functools.partial(jax.jit, static_argnames=("cap", "take", "head"))
def _group_candidates(sc_g, subs, cells, gidx, jidx, bids, n,
                      *, cap: int, take: int, head: bool):
    """Rounds 2+3 for every occupied cell of one touched-block group in ONE
    dispatch.

    ``sc_g`` is the group's (ng, T, bs) scores; ``cells``/``gidx``/``jidx``/
    ``bids`` index the nc occupied cells (global cell id, group-local block
    index, party, global block index).  Returns (rows (nc, take), combined
    scores (nc, take)) — the first ``take`` entries of each cell's
    full-capacity candidate stream and their party-ordered g gathers,
    bitwise the per-block path's (vmapped draws consume the same per-cell
    subkeys; gather commutes with the party-ordered adds).  ``head``
    selects the counter-sliced replay (:func:`_categorical_head`); off, the
    full (cap,)-stream is drawn and its head sliced.
    """
    ng, T, bs = sc_g.shape
    g = jnp.zeros((ng, bs), sc_g.dtype)
    for j in range(T):                     # party order — the flat plan's scan
        g = g + sc_g[:, j]
    sel = sc_g[gidx, jidx]                                     # (nc, bs)
    row_ok = (bids[:, None] * bs + jnp.arange(bs)[None, :]) < n
    lg = jnp.where(row_ok, jnp.log(jnp.maximum(sel, 1e-30)), -jnp.inf)
    keys = subs[1 + cells]                                     # (nc,) subkeys
    if head:
        if jnp.issubdtype(keys.dtype, jax.dtypes.prng_key):
            keys = jax.random.key_data(keys)
        cand = jax.vmap(
            lambda k, l: _categorical_head(k, l, cap, take)
        )(keys, lg)                                            # (nc, take)
    else:
        # full-capacity fallback: draw the cells SEQUENTIALLY (lax.map) so
        # only one (cap, bs) gumbel tensor is transient at a time — the
        # per-block oracle's memory profile, same bits per cell
        cand = jax.lax.map(
            lambda kl: jax.random.categorical(kl[0], kl[1], shape=(cap,)),
            (keys, lg))[:, :take]                              # (nc, take)
    rows = bids[:, None] * bs + cand
    gath = jnp.take_along_axis(g[gidx], cand, axis=1)          # (nc, take)
    return rows, gath


def dis_plan_streamed_batched(
    scorer: StreamScorer, m: int,
    probe: Optional[Callable[[], None]] = None,
) -> DisPlan:
    """:func:`dis_plan_streamed` with the ONE-DISPATCH redraw: touched
    blocks are gathered in ``scorer.chunk_blocks``-sized groups, each group
    scored by a single vmapped dispatch (``scorer.score_blocks``) and all of
    its cells' candidate streams drawn by a single vmapped categorical
    (:func:`_group_candidates`) — 2 dispatches per group instead of
    1 + #cells per block.  Draws, weights, counts, and totals are
    bit-identical to :func:`dis_plan_streamed` for the same scorer and m
    (pinned by ``tests/test_streaming_pipelined.py``); peak score memory is
    one (C, T, bs) group instead of one block.
    """
    probe = probe or _noop
    T, nb, bs, n = scorer.T, scorer.nb, scorer.bs, scorer.n
    if scorer.score_blocks is None:
        return dis_plan_streamed(scorer, m, probe=probe)
    cap = int(m)
    ncells = T * nb
    subs = _key_chain(scorer.dis_key, ncells + 1)
    masses = scorer.masses.astype(_float_dtype())
    G = masses.sum()

    # ---- round 1: cells ~ Multinomial(m, G_jb/G) ----------------------------
    if cap > 0:
        draws = jax.random.categorical(
            subs[0], jnp.log(jnp.maximum(masses.reshape(-1), 1e-30)),
            shape=(cap,))
        a_cells = np.bincount(np.asarray(draws), minlength=ncells)
    else:
        a_cells = np.zeros((ncells,), np.int64)

    # ---- rounds 2+3, grouped: score C touched blocks per dispatch, draw all
    # of the group's cells per dispatch, then host-slice the realised prefixes
    occupied = np.flatnonzero(a_cells)
    touched = sorted({int(c) % nb for c in occupied})
    C = max(1, int(scorer.chunk_blocks))
    per_cell: Dict[int, tuple] = {}
    for g0 in range(0, len(touched), C):
        group = touched[g0:g0 + C]
        # pad the trailing group to the full C blocks (repeats of the last
        # block — same scores, ignored below) so every group shares ONE
        # compiled score/draw shape instead of recompiling per remainder
        padded = group + [group[-1]] * (C - len(group))
        sc_g = scorer.score_blocks(padded).astype(_float_dtype())
        cells: List[int] = []
        gidx: List[int] = []
        jidx: List[int] = []
        bids: List[int] = []
        for gi, b in enumerate(group):
            for j in range(T):
                c = j * nb + b
                if a_cells[c]:
                    cells.append(c)
                    gidx.append(gi)
                    jidx.append(j)
                    bids.append(b)
        nc = len(cells)
        # every cell consumes only the first a_c entries of its cap-capacity
        # stream, so the group draws max(a_c) rows per cell — counter-sliced
        # when the replay is provably exact, full-capacity otherwise.  Both
        # the cell count and the head length are bucketed (multiple of 8 /
        # next power of two, via duplicate cells and extra rows that are
        # sliced away) to bound the number of compiled shape variants.
        take = int(max(a_cells[c] for c in cells))
        pad_nc = -(-nc // 8) * 8
        cells += [cells[0]] * (pad_nc - nc)
        gidx += [gidx[0]] * (pad_nc - nc)
        jidx += [jidx[0]] * (pad_nc - nc)
        bids += [bids[0]] * (pad_nc - nc)
        take_pow2 = 1
        while take_pow2 < take:
            take_pow2 *= 2
        if _head_draws_ok(subs, cap, bs, take_pow2):
            take_eff, head = take_pow2, True
        elif _head_draws_ok(subs, cap, bs, take):
            take_eff, head = take, True
        else:
            take_eff, head = min(take_pow2, cap), False
        rows, gath = _group_candidates(
            sc_g, subs, jnp.asarray(cells), jnp.asarray(gidx),
            jnp.asarray(jidx), jnp.asarray(bids), n,
            cap=cap, take=take_eff, head=head)
        rows = np.asarray(rows)
        gath = np.asarray(gath)
        for i, c in enumerate(cells[:nc]):
            a_c = int(a_cells[c])
            per_cell[c] = (rows[i, :a_c], gath[i, :a_c])
        probe()

    # server union in cell order — identical to the per-block path
    cells_sorted = sorted(per_cell)
    S = (jnp.asarray(np.concatenate([per_cell[c][0] for c in cells_sorted]))
         if cells_sorted else jnp.zeros((0,), jnp.int32))
    g_sum = (jnp.asarray(np.concatenate([per_cell[c][1]
                                         for c in cells_sorted]))
             if cells_sorted else jnp.zeros((0,), masses.dtype))
    w = G / (m * jnp.maximum(g_sum, 1e-30))
    a = jnp.asarray(a_cells.reshape(T, nb).sum(axis=1), jnp.int32)
    return DisPlan(S, w, a, masses.sum(axis=1))


# --------------------------------------------------------------------------
# Data-parallel block masses over the mesh (rows over the `data` axis)
# --------------------------------------------------------------------------

def _stacked_rows(ds: VFLDataset, lo: int, hi: int, widths, s: int,
                  with_labels: bool = True) -> np.ndarray:
    """Host-side (T, hi-lo, s) stacked slice — the layout of
    ``VFLDataset.stacked(with_labels).blocks[:, lo:hi]``, built from the
    host representation of the parts so only this slice is allocated."""
    parts = []
    for j, p in enumerate(ds.parts):
        seg = np.asarray(p[lo:hi], dtype=np.float32)
        if with_labels and j == ds.T - 1:
            yseg = np.asarray(ds.y[lo:hi], dtype=np.float32)
            seg = np.concatenate([seg, yseg[:, None]], axis=1)
        parts.append(np.pad(seg, ((0, 0), (0, s - widths[j]))))
    return np.stack(parts)


def _sharded_stacked(mesh, ds: VFLDataset, widths, s: int, axis: str,
                     with_labels: bool):
    """The (T, n, s) stacked design sharded over ``axis``, each shard built
    straight from the host dataset (``jax.make_array_from_callback``) — the
    full array never lands on one device."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    n = ds.n
    sharding = NamedSharding(mesh, P(None, axis, None))
    return jax.make_array_from_callback(
        (ds.T, n, s), sharding,
        lambda idx: _stacked_rows(ds, idx[1].start or 0,
                                  n if idx[1].stop is None else idx[1].stop,
                                  widths, s, with_labels),
    )


def _check_shard_grid(n: int, D: int, bs: int, axis: str):
    if n % D != 0 or (n // D) % bs != 0:
        raise ValueError(
            f"n={n} must shard evenly over {axis}={D} into bs={bs} blocks"
        )


def vrlr_block_masses_sharded(
    mesh, ds: VFLDataset, block_size: int,
    *, rcond: float = 1e-6, axis: str = "data",
):
    """VRLR block-mass table with rows sharded over ``axis``.

    Each device computes its shard's (T, s, s) partial Gram — combined with
    ONE psum (the mesh analogue of DIS round 1: O(T s^2) scalars, no row
    data moves) — then scores its own rows and emits its slice of the
    (T, nb) mass table; a second psum unions the disjoint slices.  This is
    the selector's psum idiom (:mod:`repro.core.selector`) applied to the
    streaming sampler's round-1 table: compute scales with the ``data``
    axis, communication stays the DIS bill.  Per-device memory is
    O(n/D * d).

    Requires n divisible by the axis size and the per-device shard
    divisible by ``bs`` (block grid aligned to shards).  Returns the same
    (T, nb) table as ``vrlr_stream_scorer(...).masses`` up to fp reduction
    order.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    nb, bs = ds.block_geometry(block_size)
    T, n = ds.T, ds.n
    if ds.y is None:
        raise ValueError("vrlr requires labels at party T")
    D = mesh.shape[axis]
    _check_shard_grid(n, D, bs, axis)
    nb_local = (n // D) // bs
    widths, s = ds.stacked_widths(with_labels=True)
    blocks = _sharded_stacked(mesh, ds, widths, s, axis, with_labels=True)

    def _inner(blk):                                           # (T, n/D, s)
        f = blk.astype(jnp.float32)
        Gm = jax.lax.psum(jnp.einsum("tns,tnu->tsu", f, f), axis)
        M = batched_gram_pinv(Gm, rcond)
        sc = jnp.clip(jnp.einsum("tns,tsr,tnr->tn", f, M, f), 0.0, 1.0) \
            + 1.0 / n
        masses_loc = sc.reshape(T, nb_local, bs).sum(axis=2)
        i = jax.lax.axis_index(axis)
        full = jnp.zeros((T, nb), masses_loc.dtype)
        full = jax.lax.dynamic_update_slice(full, masses_loc, (0, i * nb_local))
        return jax.lax.psum(full, axis)

    fn = shard_map(_inner, mesh=mesh, in_specs=P(None, axis, None),
                   out_specs=P(), check_rep=False)
    return fn(blocks)


def vkmc_block_masses_sharded(
    mesh, ds: VFLDataset, block_size: int,
    *, key, k: int = 10, alpha: float = 2.0, local_iters: int = 15,
    center_sample: int = 16384, axis: str = "data",
    use_kernel: bool = False,
):
    """VKMC block-mass table with rows sharded over ``axis`` — the mirror of
    :func:`vrlr_block_masses_sharded` for Algorithm 3.

    The party-local centers come from the same bounded-subsample k-means
    (and the same key chain) as :func:`vkmc_stream_scorer`, computed once at
    the server side of the simulation.  Each device then assigns its row
    shard, and the GLOBAL per-party cluster size/cost table — VKMC's
    sufficient statistic, O(T k) scalars — is combined with ONE psum (the
    (T, 2k) stack of sizes and costs); scores follow locally and a second
    psum unions the disjoint (T, nb) mass-table slices.  ``use_kernel``
    MUST match the consuming scorer's backend: the centers come from an
    iterated Lloyd solve whose fp accumulation order differs between the
    Pallas kernels and the jnp refs, so a mismatch yields a mass table
    built from *different centers* than the per-row scores the sampler
    recomputes — not an fp-tolerance drift.  With it matched, the table
    equals ``vkmc_stream_scorer(key, ...).masses`` up to fp reduction
    order.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    nb, bs = ds.block_geometry(block_size)
    T, n = ds.T, ds.n
    D = mesh.shape[axis]
    _check_shard_grid(n, D, bs, axis)
    nb_local = (n // D) // bs
    widths, s = ds.stacked_widths(with_labels=False)
    centers, _ = vkmc_local_centers(
        key, ds, k=k, local_iters=local_iters, center_sample=center_sample,
        use_kernel=use_kernel)
    blocks = _sharded_stacked(mesh, ds, widths, s, axis, with_labels=False)
    assign_fn = kops.kmeans_assign if use_kernel else kref.kmeans_assign

    def _inner(blk):                                           # (T, n/D, s)
        f = blk.astype(jnp.float32)
        assign, d2 = assign_fn(f, centers)                     # (T, n/D)
        onehot = (assign[..., None] ==
                  jnp.arange(k)[None, None, :]).astype(jnp.float32)
        stats_loc = jnp.concatenate(
            [onehot.sum(axis=1), (onehot * d2[..., None]).sum(axis=1)],
            axis=1)                                            # (T, 2k)
        stats = jax.lax.psum(stats_loc, axis)                  # ONE stats psum
        csize, ccost = stats[:, :k], stats[:, k:]
        cost = jnp.maximum(ccost.sum(axis=1), 1e-30)[:, None]
        cs = jnp.maximum(csize, 1.0)
        cc_a = jnp.take_along_axis(ccost, assign, axis=1)
        cs_a = jnp.take_along_axis(cs, assign, axis=1)
        sc = (alpha * d2 / cost + alpha * cc_a / (cs_a * cost)
              + 2.0 * alpha / cs_a)
        masses_loc = sc.reshape(T, nb_local, bs).sum(axis=2)
        i = jax.lax.axis_index(axis)
        full = jnp.zeros((T, nb), masses_loc.dtype)
        full = jax.lax.dynamic_update_slice(full, masses_loc, (0, i * nb_local))
        return jax.lax.psum(full, axis)                        # ONE mass psum

    fn = shard_map(_inner, mesh=mesh, in_specs=P(None, axis, None),
                   out_specs=P(), check_rep=False)
    return fn(blocks)
