"""Streaming block-scan scoring + hierarchical DIS: million-row coreset
construction on fixed device memory.

The materialized pipeline (:mod:`repro.core.api`) holds the full (T, n, s)
stacked design and a (T, n) score matrix on device — its memory scales with
n even though the protocol's *communication* scales with m.  This module
makes n a streaming dimension end to end:

  * **Block-scan scoring** — every score path is restructured into passes
    over (T, bs, s) row blocks (``VFLDataset.blocks``), with only ONE block
    device-resident at a time.  VRLR: pass 1 accumulates the per-party
    (s, s) Gram across blocks (the d x d sufficient statistic — the same
    VMEM-scratch accumulation pattern the Pallas ``weighted_gram`` /
    ``kmeans_assign_update`` kernels use across their sequential grid, here
    lifted to HBM-block granularity), then the eigen-pseudo-inverse is
    computed ONCE and pass 2 emits leverage scores block by block.  VKMC:
    local k-means runs on a bounded uniform row subsample, pass 2
    accumulates global cluster sizes/costs via the fused assign-update
    kernel per block, pass 3 emits sensitivities block by block.
  * **Hierarchical DIS** (:func:`repro.core.dis.dis_plan_blocked`) — round 1
    samples (party, block) cells from the (T, nb) block-mass table, round 2
    samples rows within only the *touched* blocks (scores recomputed on
    demand), so the (T, n) score matrix never exists.  The induced marginal
    telescopes to exactly the flat plan's g_i/G.
  * **Data-parallel masses** (:func:`vrlr_block_masses_sharded`) — rows
    sharded over the mesh's ``data`` axis via ``shard_map``; each device
    scores its row shard and the block-mass table is combined with one psum
    (plus one (T, s, s) Gram psum — the mesh analogue of DIS round 1's T
    scalars).  Communication stays the DIS bill; compute scales with
    devices.

With a numpy-backed :class:`~repro.core.vfl.VFLDataset` the dataset lives in
host memory and peak *device* memory is O(block_size * d) at any n —
measured by ``benchmarks/streaming.py`` and recorded in BENCH_kernels.json.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dis import DisPlan, _float_dtype, _key_chain
from repro.core.sensitivity import batched_gram_pinv, kmeans_update, norm_scores
from repro.core.vfl import VFLDataset
from repro.core.vkmc import kmeans
from repro.kernels import ops as kops
from repro.kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class StreamScorer:
    """Block-granular view of one task's party-local scores.

    ``masses[j, b]`` is the block mass G^(j,b) = sum_{i in block b} g_i^(j)
    (the round-1 table of the hierarchical sampler); ``score_block(b)``
    recomputes the (T, bs) scores of block ``b`` on demand, with padded rows
    exactly 0.  ``data_passes`` counts full passes over the dataset the
    scorer spent building its state + mass table (the streamed analogue of
    ``fused_lloyd``'s passes-over-X census).
    """

    T: int
    n: int
    nb: int
    bs: int
    masses: jax.Array                       # (T, nb) float32
    dis_key: jax.Array
    score_block: Callable[[int], jax.Array]
    data_passes: int


# (task name) -> factory(key, ds, block_size, backend, probe, **params)
STREAM_SCORERS: Dict[str, Callable[..., StreamScorer]] = {}


def register_stream_scorer(name: str):
    """Decorator: register a :class:`StreamScorer` factory for task ``name``."""

    def deco(fn):
        if name in STREAM_SCORERS:
            raise KeyError(f"stream scorer for {name!r} already registered")
        STREAM_SCORERS[name] = fn
        return fn

    return deco


def make_stream_scorer(
    name: str,
    key: jax.Array,
    ds: VFLDataset,
    block_size: int,
    backend: str,
    probe: Optional[Callable[[], None]] = None,
    **params,
) -> StreamScorer:
    factory = STREAM_SCORERS.get(name)
    if factory is None:
        raise ValueError(
            f"no streaming scorer registered for task {name!r}; "
            f"available: {sorted(STREAM_SCORERS)}"
        )
    return factory(key, ds, block_size, backend, probe=probe, **params)


def _noop() -> None:
    return None


def _row_valid(bs: int, nvalid) -> jax.Array:
    return (jnp.arange(bs) < nvalid).astype(jnp.float32)


# --------------------------------------------------------------------------
# VRLR: Gram block-scan -> one pinv -> blockwise leverage
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _gram_step(G, blk, nvalid, *, use_kernel: bool):
    """G += blk^T diag(valid) blk, batched over the party axis.  Padded rows
    are zero so the mask is belt-and-braces; the kernel path streams the
    block through the Pallas ``weighted_gram`` grid accumulator."""
    T, bs, _ = blk.shape
    f = blk.astype(jnp.float32)
    wv = jnp.broadcast_to(_row_valid(bs, nvalid), (T, bs))
    if use_kernel:
        Gb = kops.weighted_gram(f, wv)
    else:
        Gb = jnp.einsum("tns,tn,tnu->tsu", f, wv, f)
    return G + Gb


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _vrlr_score_block(blk, M, nvalid, n, *, use_kernel: bool):
    """clip(x_i^T M x_i, 0, 1) + 1/n per party; 0 on padded rows."""
    f = blk.astype(jnp.float32)
    if use_kernel:
        lev = kops.leverage(f, M)
    else:
        lev = jnp.einsum("tns,tsr,tnr->tn", f, M, f)
    sc = jnp.clip(lev, 0.0, 1.0) + 1.0 / n
    ok = jnp.arange(f.shape[1]) < nvalid
    return jnp.where(ok[None, :], sc, 0.0)


@jax.jit
def _norm_score_block(blk, nvalid, n):
    """Row-norm^2 ablation scores, blockwise.  Row-local, so each row's value
    is bitwise identical to the materialized ``norm`` backend's."""
    sc = norm_scores(blk) + 1.0 / n
    ok = jnp.arange(blk.shape[1]) < nvalid
    return jnp.where(ok[None, :], sc, 0.0)


def _mass_table(ds, block_size, score_block, probe):
    """One pass over the blocks collecting the (T, nb) block-mass table."""
    nb, _ = ds.block_geometry(block_size)
    masses = []
    for b in range(nb):
        masses.append(jnp.sum(score_block(b), axis=1))
        probe()
    return jnp.stack(masses, axis=1)                       # (T, nb)


@register_stream_scorer("vrlr")
def vrlr_stream_scorer(
    key, ds: VFLDataset, block_size: int, backend: str,
    probe: Optional[Callable[[], None]] = None, rcond: float = 1e-6,
) -> StreamScorer:
    """Algorithm 2's scores without ever holding (n, d): one block-scan pass
    accumulates each party's (s, s) Gram, the eigen-pseudo-inverse is taken
    once, and scores are re-emitted per block from (block, M) alone.  The
    key passes through untouched, matching the materialized ``vrlr`` task's
    deterministic-score contract.
    """
    probe = probe or _noop
    use_kernel = backend == "pallas"
    nb, bs = ds.block_geometry(block_size)
    _, s = ds.stacked_widths(with_labels=True)
    n = ds.n

    if backend == "norm":
        def score_block(b: int) -> jax.Array:
            blk, nvalid = ds.block(b, block_size, with_labels=True)
            return _norm_score_block(blk, nvalid, float(n))
        passes = 1
    else:
        G = jnp.zeros((ds.T, s, s), jnp.float32)
        for _, blk, nvalid in ds.blocks(block_size, with_labels=True):
            G = _gram_step(G, blk, nvalid, use_kernel=use_kernel)
            probe()
        M = batched_gram_pinv(G, rcond)

        def score_block(b: int) -> jax.Array:
            blk, nvalid = ds.block(b, block_size, with_labels=True)
            return _vrlr_score_block(blk, M, nvalid, float(n),
                                     use_kernel=use_kernel)
        passes = 2

    masses = _mass_table(ds, block_size, score_block, probe)
    return StreamScorer(T=ds.T, n=n, nb=nb, bs=bs, masses=masses,
                        dis_key=key, score_block=score_block,
                        data_passes=passes)


# --------------------------------------------------------------------------
# VKMC: subsampled local k-means -> stats block-scan -> blockwise scores
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _vkmc_stats_step(blk, centers, nvalid, *, use_kernel: bool):
    """(cluster sizes (T, k), cluster costs (T, k)) of one block — the fused
    assign-update pass with validity weights, batched over parties."""
    T, bs, _ = blk.shape
    wv = jnp.broadcast_to(_row_valid(bs, nvalid), (T, bs))
    _, _, _, wsum, ccost = kmeans_update(blk, centers, wv, use_kernel=use_kernel)
    return wsum, ccost


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def _vkmc_score_block(blk, centers, csize, ccost, nvalid, alpha,
                      *, use_kernel: bool):
    """Algorithm 3 lines 3-11 for one block, given the GLOBAL per-party
    cluster sizes/costs from the stats pass; 0 on padded rows."""
    # kops/kref directly: both batch over the leading party axis (the
    # inline fallback in sensitivity.kmeans_assignment is 2-D only)
    if use_kernel:
        assign, d2 = kops.kmeans_assign(blk, centers)
    else:
        assign, d2 = kref.kmeans_assign(blk, centers)
    cost = jnp.maximum(ccost.sum(axis=1), 1e-30)[:, None]      # (T, 1)
    cs = jnp.maximum(csize, 1.0)                               # (T, k)
    cc_a = jnp.take_along_axis(ccost, assign, axis=1)          # (T, bs)
    cs_a = jnp.take_along_axis(cs, assign, axis=1)
    sc = alpha * d2 / cost + alpha * cc_a / (cs_a * cost) + 2.0 * alpha / cs_a
    ok = jnp.arange(blk.shape[1]) < nvalid
    return jnp.where(ok[None, :], sc, 0.0)


@register_stream_scorer("vkmc")
def vkmc_stream_scorer(
    key, ds: VFLDataset, block_size: int, backend: str,
    probe: Optional[Callable[[], None]] = None,
    k: int = 10, alpha: float = 2.0, local_iters: int = 15,
    center_sample: int = 16384,
) -> StreamScorer:
    """Algorithm 3's sensitivities with only one block resident.

    Party j's local alpha-approximate k-means runs on a uniform row
    subsample of at most ``center_sample`` rows (O(center_sample * d_j)
    memory; the subsample's solution is still an alpha'-approximation
    absorbed by the ``alpha`` knob), then ONE block-scan pass accumulates
    the global cluster sizes/costs through the fused assign-update kernel,
    and scores are re-emitted per block from (block, centers, stats).  The
    key chain (one split per party, one for DIS) matches the materialized
    ``vkmc`` task, so the same seed drives comparable constructions.
    """
    probe = probe or _noop
    use_kernel = backend == "pallas"
    nb, bs = ds.block_geometry(block_size)
    widths, s = ds.stacked_widths(with_labels=False)
    n, T = ds.n, ds.T

    subs = []
    for _ in range(T):                     # the materialized task's key chain
        key, sub = jax.random.split(key)
        subs.append(sub)
    key, dis_key = jax.random.split(key)

    if backend == "norm":
        def score_block(b: int) -> jax.Array:
            blk, nvalid = ds.block(b, block_size, with_labels=False)
            return _norm_score_block(blk, nvalid, float(n))
        masses = _mass_table(ds, block_size, score_block, probe)
        return StreamScorer(T=T, n=n, nb=nb, bs=bs, masses=masses,
                            dis_key=dis_key, score_block=score_block,
                            data_passes=1)

    # local centers from a bounded uniform subsample, padded to width s
    centers = []
    for j, sub in enumerate(subs):
        k_smp, k_km = jax.random.split(sub)
        if n > center_sample:
            idx = np.asarray(jax.random.randint(k_smp, (center_sample,), 0, n))
            Xj = jnp.asarray(ds.parts[j][idx])
        else:
            Xj = jnp.asarray(ds.parts[j])
        c = kmeans(k_km, Xj, k, iters=local_iters, use_kernel=use_kernel)
        centers.append(jnp.pad(c, ((0, 0), (0, s - widths[j]))))
    centers = jnp.stack(centers)                               # (T, k, s)

    csize = jnp.zeros((T, k), jnp.float32)
    ccost = jnp.zeros((T, k), jnp.float32)
    for _, blk, nvalid in ds.blocks(block_size, with_labels=False):
        ws, cc = _vkmc_stats_step(blk, centers, nvalid, use_kernel=use_kernel)
        csize = csize + ws
        ccost = ccost + cc
        probe()

    def score_block(b: int) -> jax.Array:
        blk, nvalid = ds.block(b, block_size, with_labels=False)
        return _vkmc_score_block(blk, centers, csize, ccost, nvalid,
                                 float(alpha), use_kernel=use_kernel)

    masses = _mass_table(ds, block_size, score_block, probe)
    return StreamScorer(T=T, n=n, nb=nb, bs=bs, masses=masses,
                        dis_key=dis_key, score_block=score_block,
                        data_passes=3)


# --------------------------------------------------------------------------
# Streamed hierarchical DIS: masses + on-demand block recomputation
# --------------------------------------------------------------------------

def dis_plan_streamed(
    scorer: StreamScorer, m: int,
    probe: Optional[Callable[[], None]] = None,
) -> DisPlan:
    """Run the hierarchical sampler against a :class:`StreamScorer` —
    draw-identical to :func:`repro.core.dis.dis_plan_blocked` on the same
    scores, but only the *touched* blocks' scores are ever materialized.

    Round 1 samples m (party, block) cells from ``scorer.masses``; round 2
    recomputes scores for each touched block once and draws the within-block
    rows (per-cell candidate streams and the cell-ordered union match the
    in-memory plan exactly); round 3 gathers the sampled rows' combined
    scores from the same recomputed blocks, accumulated in party order so
    the weight arithmetic matches the flat plan's scan.
    """
    probe = probe or _noop
    T, nb, bs, n = scorer.T, scorer.nb, scorer.bs, scorer.n
    cap = int(m)
    ncells = T * nb
    subs = _key_chain(scorer.dis_key, ncells + 1)
    masses = scorer.masses.astype(_float_dtype())
    G = masses.sum()

    # ---- round 1: cells ~ Multinomial(m, G_jb/G) ----------------------------
    draws = jax.random.categorical(
        subs[0], jnp.log(jnp.maximum(masses.reshape(-1), 1e-30)), shape=(cap,)
    )
    a_cells = np.bincount(np.asarray(draws), minlength=ncells)

    # ---- rounds 2+3: recompute each touched block ONCE, draw its cells' rows
    # and gather their combined scores, then DISCARD the block's scores — at
    # no point is more than one block's score matrix live, so peak memory is
    # O(bs * T) regardless of how many blocks the m draws touch.
    occupied = np.flatnonzero(a_cells)
    touched = sorted({int(c) % nb for c in occupied})
    per_cell: Dict[int, tuple] = {}
    for b in touched:
        sc_b = scorer.score_block(b).astype(_float_dtype())    # (T, bs)
        # party-ordered combined row scores: gather commutes with the adds,
        # so g_b[cand] is bitwise the flat plan's per-party gather scan
        g_b = jnp.zeros((bs,), sc_b.dtype)
        for j in range(T):
            g_b = g_b + sc_b[j]
        row_ok = (b * bs + jnp.arange(bs)) < n
        for j in range(T):
            c = j * nb + b
            if a_cells[c] == 0:
                continue
            lg = jnp.where(row_ok, jnp.log(jnp.maximum(sc_b[j], 1e-30)),
                           -jnp.inf)
            # full-capacity candidate stream, first a_c taken — the
            # iid-prefix convention keeping draws identical to the
            # in-memory plan
            cand = jax.random.categorical(subs[1 + c], lg, shape=(cap,))
            cand = cand[: int(a_cells[c])]
            per_cell[c] = (b * bs + cand, g_b[cand])
        del sc_b, g_b
        probe()
    # server union in cell order — matches the in-memory plan's stable
    # taken-slots-first selection exactly
    cells = sorted(per_cell)
    S = (jnp.concatenate([per_cell[c][0] for c in cells]) if cells
         else jnp.zeros((0,), jnp.int32))                      # (m,)
    g_sum = (jnp.concatenate([per_cell[c][1] for c in cells]) if cells
             else jnp.zeros((0,), masses.dtype))
    w = G / (m * jnp.maximum(g_sum, 1e-30))

    a = jnp.asarray(a_cells.reshape(T, nb).sum(axis=1), jnp.int32)
    return DisPlan(S, w, a, masses.sum(axis=1))


# --------------------------------------------------------------------------
# Data-parallel block masses over the mesh (rows over the `data` axis)
# --------------------------------------------------------------------------

def _stacked_rows(ds: VFLDataset, lo: int, hi: int, widths, s: int) -> np.ndarray:
    """Host-side (T, hi-lo, s) labeled stacked slice — the layout of
    ``VFLDataset.stacked(with_labels=True).blocks[:, lo:hi]``, built from
    the host representation of the parts so only this slice is allocated."""
    parts = []
    for j, p in enumerate(ds.parts):
        seg = np.asarray(p[lo:hi], dtype=np.float32)
        if j == ds.T - 1:
            yseg = np.asarray(ds.y[lo:hi], dtype=np.float32)
            seg = np.concatenate([seg, yseg[:, None]], axis=1)
        parts.append(np.pad(seg, ((0, 0), (0, s - widths[j]))))
    return np.stack(parts)


def vrlr_block_masses_sharded(
    mesh, ds: VFLDataset, block_size: int,
    *, rcond: float = 1e-6, axis: str = "data",
):
    """VRLR block-mass table with rows sharded over ``axis``.

    Each device computes its shard's (T, s, s) partial Gram — combined with
    ONE psum (the mesh analogue of DIS round 1: O(T s^2) scalars, no row
    data moves) — then scores its own rows and emits its slice of the
    (T, nb) mass table; a second psum unions the disjoint slices.  This is
    the selector's psum idiom (:mod:`repro.core.selector`) applied to the
    streaming sampler's round-1 table: compute scales with the ``data``
    axis, communication stays the DIS bill.  The sharded design is built
    per shard straight from the host dataset
    (``jax.make_array_from_callback``), so per-device memory is
    O(n/D * d) — the full (T, n, s) array never lands on one device.

    Requires n divisible by the axis size and the per-device shard
    divisible by ``bs`` (block grid aligned to shards).  Returns the same
    (T, nb) table as ``vrlr_stream_scorer(...).masses`` up to fp reduction
    order.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P

    nb, bs = ds.block_geometry(block_size)
    T, n = ds.T, ds.n
    if ds.y is None:
        raise ValueError("vrlr requires labels at party T")
    D = mesh.shape[axis]
    if n % D != 0 or (n // D) % bs != 0:
        raise ValueError(
            f"n={n} must shard evenly over {axis}={D} into bs={bs} blocks"
        )
    nb_local = (n // D) // bs
    widths, s = ds.stacked_widths(with_labels=True)
    sharding = NamedSharding(mesh, P(None, axis, None))
    blocks = jax.make_array_from_callback(
        (T, n, s), sharding,
        lambda idx: _stacked_rows(ds, idx[1].start or 0,
                                  n if idx[1].stop is None else idx[1].stop,
                                  widths, s),
    )

    def _inner(blk):                                           # (T, n/D, s)
        f = blk.astype(jnp.float32)
        Gm = jax.lax.psum(jnp.einsum("tns,tnu->tsu", f, f), axis)
        M = batched_gram_pinv(Gm, rcond)
        sc = jnp.clip(jnp.einsum("tns,tsr,tnr->tn", f, M, f), 0.0, 1.0) \
            + 1.0 / n
        masses_loc = sc.reshape(T, nb_local, bs).sum(axis=2)
        i = jax.lax.axis_index(axis)
        full = jnp.zeros((T, nb), masses_loc.dtype)
        full = jax.lax.dynamic_update_slice(full, masses_loc, (0, i * nb_local))
        return jax.lax.psum(full, axis)

    fn = shard_map(_inner, mesh=mesh, in_specs=P(None, axis, None),
                   out_specs=P(), check_rep=False)
    return fn(blocks)
