"""The downstream solve + evaluation layer: the end of the paper's pipeline.

Theorems 4.1 / 5.2 bound what happens AFTER the coreset exists: run the
downstream scheme on the weighted sample and the objective on the FULL data
is within (1 +- eps) of optimal.  This module closes that loop:

  * :func:`fit_ridge`   — closed-form weighted ridge on the coreset rows
    (the Pallas ``weighted_gram`` path of
    :func:`repro.core.vrlr.ridge_closed_form`), Theorem 4.1's scheme A.
  * :func:`fit_kmeans`  — weighted k-means++ + Lloyd on the coreset rows
    (each Lloyd iteration is ONE fused ``kmeans_assign_update`` kernel
    pass), Theorem 5.2's scheme A, with optional restarts picked by the
    weighted coreset objective.
  * :func:`evaluate`    — the paper's relative-error ratio: the FULL-data
    objective at the coreset-fit parameters vs at the full-data-fit
    parameters (the quantity Figures 2-3 plot).  ``rel_error = cost_fit /
    cost_opt - 1``; an identity coreset (:func:`full_data_coreset`)
    reproduces the full-data solve to fp tolerance, which
    ``tests/test_solve.py`` pins.
  * :func:`end_to_end`  — spec in, (Coreset, FitResult, EvalReport) out:
    ``CoresetPipeline.build`` -> ``fit_*`` -> ``evaluate`` in one call,
    used by ``benchmarks/e2e.py``, the CI smoke, and the examples.

Communication composition: ``fit_*`` materializes the coreset rows, so pass
``ledger`` to account Theorem 2.5's ``+2mT`` (in-protocol solve) — or charge
``sum_j m*d_j`` explicitly when shipping raw rows centrally, as the
benchmarks do; never both on one ledger.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import CoresetPipeline, get_task, resolve_backend
from repro.core.comm import CommLedger
from repro.core.coreset import Coreset
from repro.core.plan import CoresetSpec
from repro.core.vfl import VFLDataset
from repro.core.vkmc import kmeans, kmeans_cost
from repro.core.vrlr import ridge_closed_form, ridge_cost


def full_data_coreset(ds: VFLDataset) -> Coreset:
    """The identity coreset: every row once, weight 1, zero protocol cost.

    ``fit_*`` on it IS the full-data solve (to fp tolerance) — the
    baseline ``evaluate`` compares against, and the budget=n sanity anchor
    of the solve layer."""
    n = ds.n
    return Coreset(jnp.arange(n), jnp.ones((n,), jnp.float32), 0)


@dataclasses.dataclass(frozen=True)
class FitResult:
    """One downstream solve on one coreset.

    ``params`` is theta (d,) for ridge, centers (k, d) for k-means;
    ``objective`` is the WEIGHTED objective on the coreset itself (what the
    solver minimized — compare with :func:`evaluate` for the full-data
    view).  ``lam``/``k`` carry the hyperparameter so ``evaluate`` can
    recompute objectives without re-asking."""

    task: str                     # "ridge" | "kmeans"
    params: jax.Array
    coreset: Coreset
    objective: float
    lam: Optional[float] = None
    k: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class EvalReport:
    """The paper's relative-error ratio on the FULL data.

    ``cost_fit`` — full-data objective at the coreset-fit parameters;
    ``cost_opt`` — full-data objective at the baseline (full-data-fit)
    parameters; ``rel_error = cost_fit / cost_opt - 1`` (>= 0 for the
    closed-form ridge optimum up to fp; can be mildly negative for k-means,
    where both solves are heuristic)."""

    task: str
    cost_fit: float
    cost_opt: float
    rel_error: float
    m: int
    n: int
    comm_units: int


def fit_ridge(
    ds: VFLDataset,
    cs: Coreset,
    lam: float,
    *,
    ledger: Optional[CommLedger] = None,
) -> FitResult:
    """Closed-form weighted ridge on the coreset rows (Theorem 4.1's
    downstream scheme): argmin_theta sum_{i in S} w_i (x_i^T theta - y_i)^2
    + lam ||theta||^2."""
    if ds.y is None:
        raise ValueError("fit_ridge requires labels at party T")
    XS, yS, w = cs.materialize(ds, ledger)
    theta = ridge_closed_form(XS, yS, lam, w)
    obj = float(ridge_cost(XS, yS, theta, lam, w))
    return FitResult("ridge", theta, cs, obj, lam=float(lam))


def fit_kmeans(
    ds: VFLDataset,
    cs: Coreset,
    k: int,
    *,
    key: jax.Array,
    iters: int = 25,
    restarts: int = 1,
    backend: str = "auto",
    ledger: Optional[CommLedger] = None,
) -> FitResult:
    """Weighted k-means++ + Lloyd on the coreset rows (Theorem 5.2's
    downstream scheme).  Each Lloyd iteration is ONE fused
    ``kmeans_assign_update`` pass over the m coreset rows.  ``restarts``
    re-seeds ``kmeans`` with ``fold_in(key, r)`` and keeps the centers with
    the lowest WEIGHTED coreset objective — the only objective the server
    can evaluate without touching the full data."""
    if restarts < 1:
        raise ValueError(f"restarts must be >= 1, got {restarts}")
    use_kernel = resolve_backend(backend) == "pallas"
    XS, _, w = cs.materialize(ds, ledger)
    best, best_obj = None, float("inf")
    for r in range(restarts):
        centers = kmeans(jax.random.fold_in(key, r), XS, k, w, iters=iters,
                         use_kernel=use_kernel)
        obj = float(kmeans_cost(XS, centers, w, use_kernel=use_kernel))
        if best is None or obj < best_obj:
            best, best_obj = centers, obj
    if not np.isfinite(best_obj):
        raise ValueError(
            f"every k-means restart produced a non-finite objective "
            f"({best_obj}); the coreset rows or weights are degenerate"
        )
    return FitResult("kmeans", best, cs, best_obj, k=int(k))


def evaluate(
    ds: VFLDataset,
    fit: FitResult,
    *,
    key: Optional[jax.Array] = None,
    baseline: Optional[jax.Array] = None,
    iters: int = 25,
    restarts: int = 1,
    backend: str = "auto",
) -> EvalReport:
    """Full-data relative error of a coreset fit (the paper's y-axis).

    ``baseline`` (precomputed full-data parameters) short-circuits the
    full-data solve — pass it when evaluating many coresets against one
    baseline.  For k-means the baseline solve needs ``key`` (same restarts
    policy as :func:`fit_kmeans`, on the identity coreset)."""
    use_kernel = resolve_backend(backend) == "pallas"
    X, y = ds.full(), ds.y
    if fit.task == "ridge":
        cost_fit = float(ridge_cost(X, y, fit.params, fit.lam))
        if baseline is None:
            baseline = ridge_closed_form(X, y, fit.lam)
        cost_opt = float(ridge_cost(X, y, baseline, fit.lam))
    elif fit.task == "kmeans":
        cost_fit = float(kmeans_cost(X, fit.params, use_kernel=use_kernel))
        if baseline is None:
            if key is None:
                raise ValueError(
                    "evaluate needs `key` (or a precomputed `baseline`) for "
                    "the full-data k-means baseline"
                )
            baseline = fit_kmeans(ds, full_data_coreset(ds), fit.k, key=key,
                                  iters=iters, restarts=restarts,
                                  backend=backend).params
        cost_opt = float(kmeans_cost(X, baseline, use_kernel=use_kernel))
    else:
        raise ValueError(f"unknown fit task {fit.task!r}")
    rel = cost_fit / max(cost_opt, 1e-30) - 1.0
    return EvalReport(fit.task, cost_fit, cost_opt, rel,
                      m=fit.coreset.m, n=ds.n,
                      comm_units=fit.coreset.comm_units)


def end_to_end(
    spec: Union[CoresetSpec, str],
    ds: VFLDataset,
    *,
    key: jax.Array,
    lam: Optional[float] = None,
    k: Optional[int] = None,
    solve_key: Optional[jax.Array] = None,
    baseline: Optional[jax.Array] = None,
    iters: int = 25,
    restarts: int = 1,
    ledger: Optional[CommLedger] = None,
):
    """Spec -> coreset -> fit -> full-data evaluation, in one call.

    ``spec`` may be a task name (compiled with spec defaults).  The solver
    is chosen by the hyperparameter: pass ``lam`` for the ridge leg, ``k``
    for the k-means leg (exactly one).  ``solve_key`` seeds the k-means
    solve (defaults to ``fold_in(key, 1)``; the build consumes ``key``
    itself, matching the examples' choreography).

    Returns ``(coreset, FitResult, EvalReport)``.
    """
    if isinstance(spec, str):
        spec = CoresetSpec(task=spec)
    if spec.is_grid:
        raise ValueError(
            "end_to_end runs one construction; build grids with "
            "CoresetPipeline.build and fit cells individually"
        )
    if (lam is None) == (k is None):
        raise ValueError("pass exactly one of `lam` (ridge) or `k` (k-means)")
    cs = CoresetPipeline(ds).build(spec, key=key, ledger=ledger)
    if lam is not None:
        fit = fit_ridge(ds, cs, lam, ledger=ledger)
        rep = evaluate(ds, fit, baseline=baseline)
    else:
        sk = jax.random.fold_in(key, 1) if solve_key is None else solve_key
        fit = fit_kmeans(ds, cs, k, key=sk, iters=iters, restarts=restarts,
                         ledger=ledger)
        rep = evaluate(ds, fit, key=sk, baseline=baseline, iters=iters,
                       restarts=restarts)
    return cs, fit, rep


# Task-name -> default solver mapping used by examples/benchmarks: the
# paper's pairing of construction (Alg 2/3) with downstream scheme A.
DEFAULT_SOLVER = {"vrlr": "ridge", "vkmc": "kmeans", "uniform": None}


def solver_for(task) -> Optional[str]:
    """The canonical downstream solver for a task name (None = caller's
    choice, e.g. the uniform baseline works with either)."""
    name = get_task(task).name
    return DEFAULT_SOLVER.get(name)
