"""Party-local sensitivity scores — the per-problem halves of Algorithms 2
(VRLR) and 3 (VKMC).

Everything here is computed from ONE party's block `X^(j)` only; the
cross-party combination happens inside DIS (Algorithm 1).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import ref as kref


# --------------------------------------------------------------------------
# Algorithm 2: VRLR leverage scores
# --------------------------------------------------------------------------

def leverage_scores(Xj: jax.Array, rcond: float = 1e-6, use_kernel: bool = True) -> jax.Array:
    """Row leverage scores ||u_i^(j)||^2 of the orthonormal basis U^(j) of
    col(X^(j)).

    Computed Gram-side: lev_i = x_i^T (X^T X)^+ x_i, which equals the QR-row
    norm but costs O(n d^2 + d^3) instead of an n x d QR, and whose O(n d^2)
    inner loop is the Pallas ``leverage`` kernel (row-wise quadratic form).
    Handles rank deficiency via eigen-pseudo-inverse.
    """
    Xj = jnp.asarray(Xj)
    n, dj = Xj.shape
    G = Xj.T @ Xj                                   # (d_j, d_j)
    evals, evecs = jnp.linalg.eigh(G)
    cutoff = rcond * jnp.maximum(evals.max(), 0.0)
    inv = jnp.where(evals > cutoff, 1.0 / jnp.maximum(evals, 1e-30), 0.0)
    M = (evecs * inv[None, :]) @ evecs.T            # pseudo-inverse of Gram
    if use_kernel:
        lev = kops.leverage(Xj, M)                  # row-wise x_i^T M x_i
    else:
        lev = jnp.einsum("nd,de,ne->n", Xj, M, Xj)
    # numerical clamp: true leverage lies in [0, 1]
    return jnp.clip(lev, 0.0, 1.0)


def ridge_leverage_scores(
    X: jax.Array, ridge: float = 1e-4, use_kernel: bool = False
) -> jax.Array:
    """Regularised leverage x_i^T (X^T X + ridge*I)^{-1} x_i, clipped to [0,1].

    The well-conditioned variant used on mesh feature slices (the selector's
    per-shard scores); :func:`leverage_scores` is the exact pseudo-inverse
    form for the paper-fidelity path.
    """
    f32 = X.astype(jnp.float32)
    dl = f32.shape[-1]
    G = f32.T @ f32 + ridge * jnp.eye(dl, dtype=jnp.float32)
    M = jnp.linalg.inv(G)
    if use_kernel:
        lev = kops.leverage(f32, M)
    else:
        lev = jnp.einsum("nd,de,ne->n", f32, M, f32)
    return jnp.clip(lev, 0.0, 1.0)


def norm_scores(X: jax.Array) -> jax.Array:
    """Plain row-norm^2 — the cheap ablation backend shared by the selector
    and the ``norm`` ScoreBackend of :mod:`repro.core.api`."""
    f32 = X.astype(jnp.float32)
    return jnp.sum(f32 * f32, axis=-1)


def vrlr_local_scores(
    Xj: jax.Array, y: Optional[jax.Array] = None, use_kernel: bool = True
) -> jax.Array:
    """Algorithm 2 lines 2-3: g_i^(j) = ||u_i^(j)||^2 + 1/n.

    Party T passes its labels: the basis is taken over [X^(T), y].
    """
    if y is not None:
        Xj = jnp.concatenate([Xj, y[:, None]], axis=1)
    n = Xj.shape[0]
    return leverage_scores(Xj, use_kernel=use_kernel) + 1.0 / n


def batched_gram_pinv(G: jax.Array, rcond: float = 1e-6,
                      return_cond: bool = False, expected_rank=None):
    """Eigen-pseudo-inverse of a (T, s, s) stack of party Grams.

    The shared core of :func:`vrlr_scores_stacked` (one-shot Gram) and the
    streaming block-scan path (:mod:`repro.core.streaming`, Gram accumulated
    over row blocks): zero padding contributes zero eigenvalues that fall
    below the rcond cutoff, so the batched pinv equals the per-party one
    embedded.  The rcond cutoff is itself the conditioning guardrail — the
    retained spectrum's condition number never exceeds 1/rcond, and a fully
    degenerate Gram (constant-zero feature slice) inverts to the zero
    matrix instead of exploding.

    ``return_cond=True`` additionally returns the (T,) retained condition
    numbers (top eigenvalue over the smallest eigenvalue clearing the
    cutoff; +inf when nothing clears it) for the build's
    :class:`~repro.core.integrity.HealthReport`.  Zero-padded columns
    contribute legitimate below-cutoff eigenvalues, so real rank
    deficiency is detected against ``expected_rank`` (the per-party valid
    widths): a party whose RETAINED rank falls short — a constant or
    duplicated feature slice — reports +inf.  The pinv itself is
    bit-identical either way.
    """
    evals, evecs = jnp.linalg.eigh(G)
    top = jnp.maximum(evals.max(axis=1), 0.0)              # (T,)
    cutoff = rcond * top
    keep = evals > cutoff[:, None]
    inv = jnp.where(keep, 1.0 / jnp.maximum(evals, 1e-30), 0.0)
    M = jnp.einsum("tsu,tu,tru->tsr", evecs, inv, evecs)
    if not return_cond:
        return M
    small = jnp.min(jnp.where(keep, evals, jnp.inf), axis=1)
    cond = jnp.where(jnp.isfinite(small) & (small > 0.0),
                     top / jnp.maximum(small, 1e-30), jnp.inf)
    if expected_rank is not None:
        rank = keep.sum(axis=1)
        cond = jnp.where(rank < jnp.asarray(expected_rank), jnp.inf, cond)
    return M, cond


def vrlr_scores_stacked(
    blocks: jax.Array, rcond: float = 1e-6, use_kernel: bool = True
) -> jax.Array:
    """Algorithm 2 lines 2-3 for ALL parties in one dispatch.

    ``blocks`` is the (T, n, s) zero-padded stack from
    :meth:`repro.core.vfl.VFLDataset.stacked` (labels already appended to
    party T's block).  Zero padding is transparent: the padded Gram gains
    zero rows/columns whose eigenvalues fall below the rcond cutoff, so the
    batched eigen-pseudo-inverse equals the per-party one embedded, and the
    rows' quadratic forms are untouched (x is 0 on padded coordinates).
    Returns (T, n) scores.  The O(T n s^2) row sweep is ONE batched
    ``leverage`` kernel call (party axis folded into the grid).
    """
    f = blocks.astype(jnp.float32)
    T, n, s = f.shape
    G = jnp.einsum("tns,tnu->tsu", f, f)                   # (T, s, s)
    M = batched_gram_pinv(G, rcond)                        # batched pinv(Gram)
    if use_kernel:
        lev = kops.leverage(f, M)                          # (T, n), one dispatch
    else:
        lev = jnp.einsum("tns,tsr,tnr->tn", f, M, f)
    return jnp.clip(lev, 0.0, 1.0) + 1.0 / n


# --------------------------------------------------------------------------
# Algorithm 3: VKMC local sensitivities
# --------------------------------------------------------------------------

def kmeans_assignment(
    Xj: jax.Array, centers: jax.Array, use_kernel: bool = True
) -> Tuple[jax.Array, jax.Array]:
    """(argmin_l d(x_i, c_l), min_l d(x_i, c_l)^2) — the O(nkd) hot loop,
    served by the Pallas ``kmeans_assign`` kernel."""
    if use_kernel:
        return kops.kmeans_assign(Xj, centers)
    d2 = (
        jnp.sum(Xj * Xj, axis=1, keepdims=True)
        - 2.0 * Xj @ centers.T
        + jnp.sum(centers * centers, axis=1)[None, :]
    )
    d2 = jnp.maximum(d2, 0.0)
    return jnp.argmin(d2, axis=1), jnp.min(d2, axis=1)


def kmeans_update(
    Xj: jax.Array,
    centers: jax.Array,
    w: Optional[jax.Array] = None,
    use_kernel: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """One fused Lloyd read: (assign, d2, csum, wsum, ccost).

    ``use_kernel=True`` is the single-pass Pallas ``kmeans_assign_update``
    kernel (one HBM read of X per Lloyd iteration, no segment_sum);
    ``use_kernel=False`` is the pure-jnp assignment + segment-sum
    composition — the seed's 3-pass data flow, kept as the semantic oracle.
    """
    if use_kernel:
        return kops.kmeans_assign_update(Xj, centers, w)
    return kref.kmeans_assign_update(Xj, centers, w)


def vkmc_local_scores(
    Xj: jax.Array,
    centers: jax.Array,
    alpha: float,
    use_kernel: bool = True,
) -> jax.Array:
    """Algorithm 3 lines 3-11 for one party.

    g_i^(j) = alpha*d(x_i, c_pi(i))^2 / cost
            + alpha * (sum_{i' in B_pi(i)} d(x_i', c_pi(i'))^2) / (|B_pi(i)| * cost)
            + 2*alpha / |B_pi(i)|

    ``cluster_cost``/``cluster_size`` fall out of the same fused pass that
    computes the assignment (unit weights: wsum = |B_l|, ccost = cost_l) —
    the scoring pass reads X exactly once.
    """
    assign, d2, _, cluster_size, cluster_cost = kmeans_update(
        Xj, centers, use_kernel=use_kernel)
    cost = jnp.maximum(d2.sum(), 1e-30)
    cluster_size = jnp.maximum(cluster_size, 1.0)
    term1 = alpha * d2 / cost
    term2 = alpha * cluster_cost[assign] / (cluster_size[assign] * cost)
    term3 = 2.0 * alpha / cluster_size[assign]
    return term1 + term2 + term3


def total_sensitivity_bound_vrlr(dims, T: int) -> float:
    """Thm 4.2: G = sum_j d'_j + T <= d + T + 1 (used by tests)."""
    return float(sum(dims) + T)


def total_sensitivity_bound_vkmc(k: int, T: int, alpha: float) -> float:
    """Lemma F.2: G = 2(k+1) * alpha * T exactly (used by tests)."""
    return 2.0 * (k + 1) * alpha * T
