"""Unified CoresetPipeline API: one entry point for every coreset task.

The paper's Algorithms 1-3 share a single shape — party-local scores ->
DIS sampling -> importance weights — which this module makes explicit:

  * :class:`CoresetTask` + :func:`register_task` — a declarative task spec in
    a string registry (``CORESET_TASKS``, built on ``repro.utils.registry``).
    Shipped tasks: ``vrlr`` (Algorithm 2), ``vkmc`` (Algorithm 3), ``uniform``
    (the U-* baseline).  New tasks (e.g. communication-compressed or DP
    score variants) plug in with one decorator and inherit the DIS core,
    accounting, and batched construction for free.
  * ScoreBackend — how party-local scores are computed: ``pallas`` (the
    Pallas kernels; interpret-mode on CPU), ``ref`` (pure-jnp references,
    vmap-safe), ``norm`` (row-norm^2 ablation, as in the mesh selector).
  * :func:`build_coreset` — the single sequential entry point.  Communication
    is derived *after* sampling from the plan's realised round-2 counts via
    :class:`repro.core.comm.CommSchedule`; nothing imperative happens in the
    traced path.
  * :func:`build_coreset_jit` — the one-dispatch fast path: scoring (stacked
    party axis, fused kernels) + DIS compiled into ONE jitted function per
    ``(task, shapes, backend, params)`` cache key.
  * :func:`build_coresets_batched` — seeds x budget-grid construction as ONE
    jit-compiled ``vmap(vmap(...))`` call over the pure
    :func:`repro.core.dis.dis_plan_full` core, using the ``m_cap`` prefix
    convention for the budget grid.
  * :func:`build_coreset_streaming` — n as a streaming dimension: block-scan
    scoring (:mod:`repro.core.streaming`) + the hierarchical (party, block)
    DIS sampler, peak device memory O(block_size * d) at any n.

Key-consumption choreography matches the seed builders exactly, so the
deprecated ``build_vrlr_coreset`` / ``build_vkmc_coreset`` shims in
:mod:`repro.core` return bit-identical ``(S, w)`` for the same PRNG key.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import CommLedger, CommSchedule
from repro.core.coreset import Coreset
from repro.core.dis import _float_dtype, dis_plan_full, uniform_plan
from repro.core.sensitivity import (
    norm_scores,
    vkmc_local_scores,
    vrlr_scores_stacked,
)
from repro.core.vfl import VFLDataset
from repro.core.vkmc import kmeans
from repro.utils.registry import Registry

SCORE_BACKENDS = ("pallas", "ref", "norm")

CORESET_TASKS = Registry("coreset_task")


def resolve_backend(backend: str) -> str:
    """Resolve ``"auto"`` to a concrete ScoreBackend for this process.

    ``auto`` picks ``pallas`` on TPU/GPU (compiled kernels) and ``ref`` on
    CPU — interpret-mode Pallas is 25-60x slower than the compiled jnp
    references there (BENCH_kernels.json), so a silent ``pallas`` default
    was a CPU footgun.  Explicit names pass through (and are validated).
    """
    if backend == "auto":
        return "pallas" if jax.default_backend() in ("tpu", "gpu") else "ref"
    if backend not in SCORE_BACKENDS:
        raise ValueError(
            f"unknown score backend {backend!r}; expected 'auto' or one of "
            f"{SCORE_BACKENDS}"
        )
    return backend


def _key_data(k: jax.Array) -> np.ndarray:
    """Raw uint32 view of a PRNG key — works for both legacy uint32 keys and
    new-style typed keys (which np.asarray refuses to convert)."""
    if jnp.issubdtype(k.dtype, jax.dtypes.prng_key):
        k = jax.random.key_data(k)
    return np.asarray(k)


def _use_kernel(backend: str) -> bool:
    if backend not in SCORE_BACKENDS:
        raise ValueError(
            f"unknown score backend {backend!r}; expected one of {SCORE_BACKENDS}"
        )
    return backend == "pallas"


# ScoreFn(key, ds, backend=..., **params) -> (scores (T, n), dis_key).
# Returning the key for the DIS stage lets tasks that consume PRNG state
# while scoring (vkmc's local k-means seeding) keep the seed's exact
# split chain.
ScoreFn = Callable[..., Tuple[jax.Array, jax.Array]]


@dataclasses.dataclass(frozen=True)
class CoresetTask:
    """Declarative spec of one coreset-construction task.

    ``score_fn is None`` marks the uniform baseline: no scores travel, the
    schedule is broadcast-only.  ``deterministic_scores`` asserts the
    score_fn neither consumes nor transforms the PRNG key (it returns the
    key it was given, as ``vrlr`` does), letting the batched builder hoist
    scoring out of the vmapped hot path and share scores across all seeds;
    the builder verifies the contract and falls back to per-seed scoring if
    the returned dis_key differs.
    """

    name: str
    score_fn: Optional[ScoreFn]
    needs_labels: bool = False
    deterministic_scores: bool = True
    description: str = ""


def register_task(name: str, **spec_kwargs):
    """Decorator: register a score function as task ``name``.

    The decorated callable keeps its identity (so it stays directly
    importable/testable); the registry stores the wrapping
    :class:`CoresetTask`.
    """

    def deco(score_fn: ScoreFn) -> ScoreFn:
        CORESET_TASKS.register(name)(
            CoresetTask(name=name, score_fn=score_fn, **spec_kwargs)
        )
        return score_fn

    return deco


def get_task(task: Union[str, CoresetTask]) -> CoresetTask:
    if isinstance(task, CoresetTask):
        return task
    return CORESET_TASKS.get(task)


# --------------------------------------------------------------------------
# Shipped tasks
# --------------------------------------------------------------------------

@register_task("vrlr", needs_labels=True,
               description="Algorithm 2: per-party ridge-leverage scores + DIS")
def vrlr_scores(key, ds: VFLDataset, backend: str = "pallas"):
    """Algorithm 2 lines 2-3: g_i^(j) = ||u_i^(j)||^2 + 1/n per party, with
    party T scoring [X^(T), y].  Deterministic — the key passes through to
    DIS untouched (the seed's choreography).

    All T parties are scored by ONE dispatch over the padded stacked view
    ((T, n, s) blocks, labels pre-appended): batched Gram + eigh, then a
    single party-batched ``leverage`` kernel call — no Python party loop.
    """
    st = ds.stacked(with_labels=True)
    if backend == "norm":
        return norm_scores(st.blocks) + 1.0 / ds.n, key
    return vrlr_scores_stacked(st.blocks, use_kernel=_use_kernel(backend)), key


@register_task("vkmc", deterministic_scores=False,
               description="Algorithm 3: local alpha-approx k-means sensitivities + DIS")
def vkmc_scores(key, ds: VFLDataset, backend: str = "pallas",
                k: int = 10, alpha: float = 2.0, local_iters: int = 15):
    """Algorithm 3: party j runs local k-means (alpha-approximate) and scores
    its block; the key is split once per party and once more for DIS —
    exactly the seed's chain (subkeys are pre-split host-side, then the
    compute runs as ONE vmap over the party axis of the stacked view).

    Zero column padding is distance-transparent (every point shares the
    same zeros), so local k-means and sensitivities on the padded blocks
    equal their per-party values.  ``alpha`` is the approximation factor
    credited to the local solver (k-means++ + Lloyd is O(log k) in theory,
    ~2 in practice).
    """
    subs = []
    for _ in range(ds.T):                     # the seed's per-party key chain
        key, sub = jax.random.split(key)
        subs.append(sub)
    key, dis_key = jax.random.split(key)
    st = ds.stacked()
    if backend == "norm":
        return norm_scores(st.blocks) + 1.0 / ds.n, dis_key

    use_kernel = _use_kernel(backend)

    def party(sub, Xb):
        local_c = kmeans(sub, Xb, k, iters=local_iters, use_kernel=use_kernel)
        return vkmc_local_scores(Xb, local_c, alpha, use_kernel=use_kernel)

    return jax.vmap(party)(jnp.stack(subs), st.blocks), dis_key


CORESET_TASKS.register("uniform")(
    CoresetTask(name="uniform", score_fn=None,
                description="U-* baseline: uniform indices, weight n/m")
)


# --------------------------------------------------------------------------
# Sequential entry point
# --------------------------------------------------------------------------

def build_coreset(
    task: Union[str, CoresetTask],
    ds: VFLDataset,
    budget: int,
    *,
    key: jax.Array,
    backend: str = "auto",
    ledger: Optional[CommLedger] = None,
    **params,
) -> Coreset:
    """Build one coreset of ``budget`` rows for ``task`` on ``ds``.

    Task-specific knobs (vkmc's ``k``/``alpha``/``local_iters``) pass through
    ``**params`` to the task's score function.  ``backend`` defaults to
    ``"auto"`` (:func:`resolve_backend`: kernels on TPU/GPU, jnp refs on
    CPU).  The exact per-round communication bill is derived from the
    realised plan and recorded on ``ledger`` (when given);
    ``Coreset.comm_units`` is always this construction's own total.
    """
    spec = get_task(task)
    backend = resolve_backend(backend)
    m = int(budget)
    if spec.needs_labels and ds.y is None:
        raise ValueError(f"{spec.name} requires labels at party T")
    if spec.score_fn is None:
        S, w = uniform_plan(key, ds.n, m)
        schedule = CommSchedule.uniform(ds.T, m)
    else:
        scores, dis_key = spec.score_fn(key, ds, backend=backend, **params)
        plan = dis_plan_full(dis_key, scores, m)
        if not bool(plan.totals.sum() > 0):
            raise ValueError("DIS requires a positive total score")
        S, w = plan.indices, plan.weights
        schedule = CommSchedule.dis(ds.T, m, counts=np.asarray(plan.counts))
    schedule.record(ledger)
    return Coreset(S, w, schedule.total)


# --------------------------------------------------------------------------
# Fused scoring+DIS fast path: ONE compiled dispatch per construction
# --------------------------------------------------------------------------

# (task spec, dims, labeled?, n, m, backend, params) -> jitted builder.
_JIT_BUILDERS: dict = {}


def build_coreset_jit(
    task: Union[str, CoresetTask],
    ds: VFLDataset,
    budget: int,
    *,
    key: jax.Array,
    backend: str = "auto",
    ledger: Optional[CommLedger] = None,
    **params,
) -> Coreset:
    """One-dispatch :func:`build_coreset`: scoring + :func:`dis_plan_full`
    fused into a single jitted function, cached per ``(task, shapes,
    backend, params)``.  ``backend="auto"`` resolves per
    :func:`resolve_backend` before the cache key is formed.

    The sequential :func:`build_coreset` stays the fidelity reference — it
    runs scoring eagerly and is the bit-identity anchor against the seed;
    this fast path traces the exact same score function and DIS core into
    one XLA program (a T-party build is ONE launch instead of T+1) and
    amortises compilation across repeated builds of the same geometry.
    Whole-program fusion may reorder fp reductions vs the eager reference,
    so weights agree to fp tolerance (not bitwise) and a draw landing
    exactly on a categorical boundary could in principle differ — use the
    sequential path where cross-version draw stability matters.
    """
    spec = get_task(task)
    backend = resolve_backend(backend)
    m = int(budget)
    if spec.needs_labels and ds.y is None:
        raise ValueError(f"{spec.name} requires labels at party T")

    if spec.score_fn is None:
        cache_key = (spec, ds.n, m)
        fn = _JIT_BUILDERS.get(cache_key)
        if fn is None:
            n = ds.n   # bind the scalars only — the cached closure must not
            fn = jax.jit(lambda k: uniform_plan(k, n, m))  # pin ds's arrays
            _JIT_BUILDERS[cache_key] = fn
        S, w = fn(key)
        schedule = CommSchedule.uniform(ds.T, m)
        schedule.record(ledger)
        return Coreset(S, w, schedule.total)

    cache_key = (spec, ds.dims, ds.y is not None, ds.n, m, backend,
                 tuple(sorted(params.items())))
    fn = _JIT_BUILDERS.get(cache_key)
    if fn is None:
        def _build(k, parts, y):
            ds_t = VFLDataset(list(parts), y)
            scores, dis_key = spec.score_fn(k, ds_t, backend=backend, **params)
            return dis_plan_full(dis_key, scores, m)

        fn = jax.jit(_build)
        _JIT_BUILDERS[cache_key] = fn
    plan = fn(key, tuple(ds.parts), ds.y)
    if not bool(plan.totals.sum() > 0):
        raise ValueError("DIS requires a positive total score")
    schedule = CommSchedule.dis(ds.T, m, counts=np.asarray(plan.counts))
    schedule.record(ledger)
    return Coreset(plan.indices, plan.weights, schedule.total)


# --------------------------------------------------------------------------
# Streaming construction: block-scan scoring + hierarchical DIS
# --------------------------------------------------------------------------

# superchunk width when chunk_blocks is not given: deep enough to amortise
# the per-dispatch overhead, shallow enough that two prefetch slots + one
# resident superchunk stay a small multiple of the single-block footprint
DEFAULT_CHUNK_BLOCKS = 8


def build_coreset_streaming(
    task: Union[str, CoresetTask],
    ds: VFLDataset,
    budget: int,
    *,
    key: jax.Array,
    block_size: int = 65536,
    chunk_blocks: Optional[int] = None,
    prefetch: Optional[bool] = None,
    backend: str = "auto",
    ledger: Optional[CommLedger] = None,
    probe: Optional[Callable[[], None]] = None,
    **params,
) -> Coreset:
    """Build one coreset with n as a STREAMING dimension: block-scan scoring
    plus the hierarchical (party, block)-cell DIS sampler, so peak device
    memory is O(chunk_blocks * block_size * d) — the (T, n) score matrix and
    the (n, d) design are never materialized (pass a numpy-backed
    ``VFLDataset`` to keep the raw data off-device too).

    ``chunk_blocks`` (default :data:`DEFAULT_CHUNK_BLOCKS`, clamped to the
    number of blocks) sets the PIPELINED dispatch granularity: scoring
    passes consume double-buffered (chunk_blocks, T, bs, s) superchunks and
    run the per-block step as a ``lax.scan`` in one dispatch per superchunk,
    and the touched-block redraw scores + draws one superchunk-sized group
    per dispatch; ``prefetch`` issues the async staging of the next
    superchunk while the current one computes.  Its default is
    backend-aware: on CPU the zero-copy staging already overlaps with the
    async dispatch of the current chunk's compute, so eager prefetch only
    adds a live slot (the BENCH ablation measures it strictly slower) and
    the default is off; on TPU/GPU the extra in-flight H2D transfer is the
    point and the default is on.  ``chunk_blocks=1`` with
    ``prefetch=False`` selects the strictly block-at-a-time engine — the
    same draws, one dispatch per block (the draw-identity oracle pinned by
    ``tests/test_streaming_pipelined.py``).  Both knobs are validated
    host-side: a non-positive (or non-integral) value raises ``ValueError``
    before any work happens; values above the block count are clamped, so
    ``chunk_blocks >= nb`` means one superchunk spanning the whole dataset.

    The sampled marginal is exactly the flat plan's g_i/G (the two-level
    sampling telescopes — see :func:`repro.core.dis.dis_plan_blocked`), and
    with ``block_size >= ds.n`` the draws coincide with
    :func:`build_coreset` bit for bit when the blockwise scores do (e.g.
    the row-local ``norm`` backend).  ``probe`` (if given) is invoked once
    per superchunk step — instrumentation hook for the memory benchmark.
    The communication bill is unchanged: blocking is server-side
    bookkeeping; parties still ship one scalar mass per round-1 row
    (aggregated per party), m indices, and m score shares.
    """
    from repro.core.streaming import (
        dis_plan_streamed,
        dis_plan_streamed_batched,
        make_stream_scorer,
    )
    from repro.core.vfl import block_geometry

    spec = get_task(task)
    backend = resolve_backend(backend)
    m = int(budget)
    # host-side knob validation (the budget-validation pattern of
    # build_coresets_batched): fail loudly before any pass is dispatched
    if not isinstance(block_size, (int, np.integer)) or block_size < 1:
        raise ValueError(
            f"block_size must be a positive int, got {block_size!r}"
        )
    nb, _ = block_geometry(ds.n, int(block_size))
    if chunk_blocks is None:
        chunk_blocks = DEFAULT_CHUNK_BLOCKS
    if not isinstance(chunk_blocks, (int, np.integer)) or chunk_blocks < 1:
        raise ValueError(
            f"chunk_blocks must be a positive int, got {chunk_blocks!r}"
        )
    chunk_blocks = min(int(chunk_blocks), nb)      # > nb: one full-span chunk
    if prefetch is None:
        prefetch = jax.default_backend() in ("tpu", "gpu")
    if spec.needs_labels and ds.y is None:
        raise ValueError(f"{spec.name} requires labels at party T")
    if spec.score_fn is None:
        S, w = uniform_plan(key, ds.n, m)
        schedule = CommSchedule.uniform(ds.T, m)
        schedule.record(ledger)
        return Coreset(S, w, schedule.total)

    scorer = make_stream_scorer(spec.name, key, ds, int(block_size), backend,
                                probe=probe, chunk_blocks=chunk_blocks,
                                prefetch=prefetch, **params)
    if not bool(scorer.masses.sum() > 0):
        raise ValueError("DIS requires a positive total score")
    if chunk_blocks == 1 and not prefetch:
        plan = dis_plan_streamed(scorer, m, probe=probe)
    else:
        plan = dis_plan_streamed_batched(scorer, m, probe=probe)
    schedule = CommSchedule.dis(ds.T, m, counts=np.asarray(plan.counts))
    schedule.record(ledger)
    return Coreset(plan.indices, plan.weights, schedule.total)


# --------------------------------------------------------------------------
# Batched multi-seed / multi-budget construction (one compilation)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BatchedCoresets:
    """A (num_seeds, num_budgets) grid of coresets from ONE compiled call.

    ``indices``/``weights`` are ``(R, M, m_cap)`` with the valid-prefix
    convention: cell (r, i) holds ``ms[i]`` real samples; the padded tail has
    weight 0.  ``counts`` carries the realised round-2 a_j per cell so the
    exact CommSchedule can be derived lazily, after the fact — accounting
    never touched the compiled path.
    """

    indices: jax.Array            # (R, M, m_cap) int
    weights: jax.Array            # (R, M, m_cap) float
    counts: Optional[jax.Array]   # (R, M, T) int; None for the uniform task
    ms: Tuple[int, ...]
    T: int

    @property
    def num_seeds(self) -> int:
        return int(self.indices.shape[0])

    def schedule(self, seed_idx: int, m_idx: int) -> CommSchedule:
        m = self.ms[m_idx]
        if self.counts is None:
            return CommSchedule.uniform(self.T, m)
        return CommSchedule.dis(
            self.T, m, counts=np.asarray(self.counts[seed_idx, m_idx])
        )

    def coreset(
        self, seed_idx: int, m_idx: int = 0,
        ledger: Optional[CommLedger] = None,
    ) -> Coreset:
        """Extract cell (seed_idx, m_idx) as a plain :class:`Coreset`."""
        m = self.ms[m_idx]
        schedule = self.schedule(seed_idx, m_idx).record(ledger)
        return Coreset(
            self.indices[seed_idx, m_idx, :m],
            self.weights[seed_idx, m_idx, :m],
            schedule.total,
        )


def build_coresets_batched(
    task: Union[str, CoresetTask],
    ds: VFLDataset,
    ms,
    *,
    key: Optional[jax.Array] = None,
    num_seeds: int = 1,
    keys: Optional[jax.Array] = None,
    backend: str = "ref",
    m_cap: Optional[int] = None,
    **params,
) -> BatchedCoresets:
    """Construct coresets for every (seed, budget) pair in one compiled call.

    ``ms`` is the budget grid (any iterable of ints); seeds come either from
    ``keys`` (a stacked ``(R, ...)`` key array) or ``jax.random.split(key,
    num_seeds)``.  The whole grid is ``jit(vmap(vmap(dis_plan_full)))`` over
    the pure DIS core: budgets below ``max(ms)`` use the prefix-masking
    convention (draws are iid, so a prefix of the capacity draw is a valid
    m-sample), and for ``m == max(ms)`` each cell is exactly the sequential
    :func:`build_coreset` result for that key.

    ``backend`` defaults to ``"ref"`` (the pure-jnp scores are cheapest on
    a CPU container); ``"pallas"`` also vmaps — the kernels fold the seed
    batch into their grid via the native pallas batching rule, so the whole
    grid is still one dispatch (interpret-mode on CPU, compiled on TPU) —
    and ``"auto"`` resolves per :func:`resolve_backend`.  ``m_cap``
    overrides the draw capacity (defaults to ``max(ms)``); every budget
    must lie in [1, m_cap] or the builder raises before tracing.
    """
    spec = get_task(task)
    backend = resolve_backend(backend)
    ms = tuple(int(m) for m in ms)
    if not ms:
        raise ValueError("empty budget grid")
    m_cap = max(ms) if m_cap is None else int(m_cap)
    # host-side validation: a budget outside [1, m_cap] would silently
    # produce a garbage masked prefix (negative-length or truncated draws)
    # inside the traced core — fail loudly here instead.
    bad = [m for m in ms if m < 1 or m > m_cap]
    if bad:
        raise ValueError(
            f"budgets {bad} outside [1, m_cap={m_cap}]; every budget in the "
            f"grid must be >= 1 and <= the draw capacity"
        )
    if keys is None:
        if key is None:
            raise ValueError("pass either `key` (+ num_seeds) or `keys`")
        keys = jax.random.split(key, num_seeds)
    if spec.needs_labels and ds.y is None:
        raise ValueError(f"{spec.name} requires labels at party T")
    ms_arr = jnp.asarray(ms, jnp.int32)

    def _cells(dis_key, sc, totals=None):
        """All budget cells for one seed (scores computed once per seed)."""
        def cell(m):
            plan = dis_plan_full(dis_key, sc, m, m_cap=m_cap, totals=totals)
            return plan.indices, plan.weights, plan.counts
        return jax.vmap(cell)(ms_arr)

    if spec.score_fn is None:
        def per_seed(k):
            def cell(m):
                S, w = uniform_plan(k, ds.n, m, m_cap=m_cap)
                return S, w, jnp.zeros((ds.T,), jnp.int32)
            return jax.vmap(cell)(ms_arr)
    else:
        hoisted = None
        if spec.deterministic_scores:
            # scores are seed-independent: compute once on the host and
            # share across the whole grid — but only if the score_fn honours
            # the deterministic contract (key passed through unchanged);
            # otherwise fall back to per-seed scoring so sequential and
            # batched builds keep sampling with the same dis_key.
            sc0, dk0 = spec.score_fn(keys[0], ds, backend=backend, **params)
            if np.array_equal(_key_data(dk0), _key_data(keys[0])):
                hoisted = sc0
        if hoisted is not None:
            if not bool(hoisted.sum() > 0):
                raise ValueError("DIS requires a positive total score")
            # eager per-party totals: same reduction kernel as the sequential
            # path, so w = G/(m g) matches sequential builds bit for bit.
            hoisted_totals = jnp.sum(hoisted.astype(_float_dtype()), axis=1)

            def per_seed(k):
                return _cells(k, hoisted, totals=hoisted_totals)
        else:
            def per_seed(k):
                sc, dis_key = spec.score_fn(k, ds, backend=backend, **params)
                return _cells(dis_key, sc)

    S, w, counts = jax.jit(jax.vmap(per_seed))(keys)
    if spec.score_fn is not None and not bool(jnp.all(w[..., 0] > 0)):
        # w[r, i, 0] = G / (m * g) is positive iff the realised total score
        # G was — the traced core can't raise, so validate post hoc.
        raise ValueError("DIS requires a positive total score")
    return BatchedCoresets(
        indices=S, weights=w,
        counts=None if spec.score_fn is None else counts,
        ms=ms, T=ds.T,
    )
