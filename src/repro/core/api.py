"""Unified CoresetPipeline API: one declarative entry point for every engine.

The paper's Algorithms 1-3 share a single shape — party-local scores ->
DIS sampling -> importance weights — which this module makes explicit:

  * :class:`CoresetTask` + :func:`register_task` — a declarative task spec in
    a string registry (``CORESET_TASKS``, built on ``repro.utils.registry``).
    Shipped tasks: ``vrlr`` (Algorithm 2), ``vkmc`` (Algorithm 3), ``uniform``
    (the U-* baseline).  New tasks (e.g. communication-compressed or DP
    score variants) plug in with one decorator and inherit the DIS core,
    accounting, and every engine for free.
  * :class:`CoresetPipeline` — the spec-compiled entry point.  A frozen
    :class:`repro.core.plan.CoresetSpec` is compiled by
    :func:`repro.core.plan.compile_plan` into an
    :class:`~repro.core.plan.ExecutionPlan` naming ONE concrete engine —
    ``materialized | batched | streamed | pipelined`` — with auto-selection
    driven by the memory model when the spec carries a
    ``memory_budget_bytes``; ``CoresetPipeline.build`` dispatches on the
    plan.  ``pipeline.plan(spec).describe()`` shows every planner decision
    (engine, clamps, predicted peak bytes, predicted comm units) before
    anything runs.
  * The four legacy entry points — :func:`build_coreset` (materialized),
    :func:`build_coreset_jit` (materialized, fused one-dispatch),
    :func:`build_coreset_streaming` (streamed/pipelined), and
    :func:`build_coresets_batched` (batched) — are thin shims constructing
    forced-engine specs; each is DRAW-IDENTICAL to the same spec through
    ``CoresetPipeline.build`` (same code path, pinned by
    ``tests/test_plan.py``).

Key-consumption choreography matches the seed builders exactly, so the
deprecated ``build_vrlr_coreset`` / ``build_vkmc_coreset`` shims in
:mod:`repro.core` return bit-identical ``(S, w)`` for the same PRNG key.
The downstream solve layer (closed-form weighted ridge, weighted Lloyd,
relative-error evaluation) lives in :mod:`repro.core.solve`.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import CommLedger, CommSchedule
from repro.core.coreset import Coreset
from repro.core.dis import _float_dtype, dis_plan_full, split_uploads, uniform_plan
from repro.core.faults import (
    DeadlineExceeded,
    DegradedBuild,
    DroppedParty,
    PartyUnavailable,
    StreamCheckpoint,
    Transport,
)
from repro.core.integrity import (
    HealthReport,
    IntegrityError,
    check_weights,
    health_from_masses,
    require_valid_masses,
)
from repro.core.plan import (
    DEFAULT_CHUNK_BLOCKS,
    ENGINES,
    SCORE_BACKENDS,
    CoresetSpec,
    ExecutionPlan,
    MemoryBudgetExceeded,
    MemoryWatchdog,
    PlanCache,
    compile_plan,
)
from repro.core.sensitivity import (
    norm_scores,
    total_sensitivity_bound_vkmc,
    total_sensitivity_bound_vrlr,
    vkmc_local_scores,
    vrlr_scores_stacked,
)
from repro.core.vfl import VFLDataset
from repro.core.vkmc import kmeans
from repro.core.wire import WirePayload, get_codec
from repro.utils.registry import Registry

CORESET_TASKS = Registry("coreset_task")


def resolve_backend(backend: str) -> str:
    """Resolve ``"auto"`` to a concrete ScoreBackend for this process.

    ``auto`` picks ``pallas`` on TPU/GPU (compiled kernels) and ``ref`` on
    CPU — interpret-mode Pallas is 25-60x slower than the compiled jnp
    references there (BENCH_kernels.json), so a silent ``pallas`` default
    was a CPU footgun.  Explicit names pass through (and are validated).
    """
    if backend == "auto":
        return "pallas" if jax.default_backend() in ("tpu", "gpu") else "ref"
    if backend not in SCORE_BACKENDS:
        raise ValueError(
            f"unknown score backend {backend!r}; expected 'auto' or one of "
            f"{SCORE_BACKENDS}"
        )
    return backend


def _key_data(k: jax.Array) -> np.ndarray:
    """Raw uint32 view of a PRNG key — works for both legacy uint32 keys and
    new-style typed keys (which np.asarray refuses to convert)."""
    if jnp.issubdtype(k.dtype, jax.dtypes.prng_key):
        k = jax.random.key_data(k)
    return np.asarray(k)


def _use_kernel(backend: str) -> bool:
    if backend not in SCORE_BACKENDS:
        raise ValueError(
            f"unknown score backend {backend!r}; expected one of {SCORE_BACKENDS}"
        )
    return backend == "pallas"


# ScoreFn(key, ds, backend=..., **params) -> (scores (T, n), dis_key).
# Returning the key for the DIS stage lets tasks that consume PRNG state
# while scoring (vkmc's local k-means seeding) keep the seed's exact
# split chain.
ScoreFn = Callable[..., Tuple[jax.Array, jax.Array]]


@dataclasses.dataclass(frozen=True)
class CoresetTask:
    """Declarative spec of one coreset-construction task.

    ``score_fn is None`` marks the uniform baseline: no scores travel, the
    schedule is broadcast-only.  ``deterministic_scores`` asserts the
    score_fn neither consumes nor transforms the PRNG key (it returns the
    key it was given, as ``vrlr`` does), letting the batched builder hoist
    scoring out of the vmapped hot path and share scores across all seeds;
    the builder verifies the contract and falls back to per-seed scoring if
    the returned dis_key differs.
    """

    name: str
    score_fn: Optional[ScoreFn]
    needs_labels: bool = False
    deterministic_scores: bool = True
    description: str = ""


def register_task(name: str, **spec_kwargs):
    """Decorator: register a score function as task ``name``.

    The decorated callable keeps its identity (so it stays directly
    importable/testable); the registry stores the wrapping
    :class:`CoresetTask`.
    """

    def deco(score_fn: ScoreFn) -> ScoreFn:
        CORESET_TASKS.register(name)(
            CoresetTask(name=name, score_fn=score_fn, **spec_kwargs)
        )
        return score_fn

    return deco


def get_task(task: Union[str, CoresetTask]) -> CoresetTask:
    if isinstance(task, CoresetTask):
        return task
    return CORESET_TASKS.get(task)


# --------------------------------------------------------------------------
# Shipped tasks
# --------------------------------------------------------------------------

@register_task("vrlr", needs_labels=True,
               description="Algorithm 2: per-party ridge-leverage scores + DIS")
def vrlr_scores(key, ds: VFLDataset, backend: str = "pallas"):
    """Algorithm 2 lines 2-3: g_i^(j) = ||u_i^(j)||^2 + 1/n per party, with
    party T scoring [X^(T), y].  Deterministic — the key passes through to
    DIS untouched (the seed's choreography).

    All T parties are scored by ONE dispatch over the padded stacked view
    ((T, n, s) blocks, labels pre-appended): batched Gram + eigh, then a
    single party-batched ``leverage`` kernel call — no Python party loop.
    """
    st = ds.stacked(with_labels=True)
    if backend == "norm":
        return norm_scores(st.blocks) + 1.0 / ds.n, key
    return vrlr_scores_stacked(st.blocks, use_kernel=_use_kernel(backend)), key


@register_task("vkmc", deterministic_scores=False,
               description="Algorithm 3: local alpha-approx k-means sensitivities + DIS")
def vkmc_scores(key, ds: VFLDataset, backend: str = "pallas",
                k: int = 10, alpha: float = 2.0, local_iters: int = 15):
    """Algorithm 3: party j runs local k-means (alpha-approximate) and scores
    its block; the key is split once per party and once more for DIS —
    exactly the seed's chain (subkeys are pre-split host-side, then the
    compute runs as ONE vmap over the party axis of the stacked view).

    Zero column padding is distance-transparent (every point shares the
    same zeros), so local k-means and sensitivities on the padded blocks
    equal their per-party values.  ``alpha`` is the approximation factor
    credited to the local solver (k-means++ + Lloyd is O(log k) in theory,
    ~2 in practice).
    """
    subs = []
    for _ in range(ds.T):                     # the seed's per-party key chain
        key, sub = jax.random.split(key)
        subs.append(sub)
    key, dis_key = jax.random.split(key)
    st = ds.stacked()
    if backend == "norm":
        return norm_scores(st.blocks) + 1.0 / ds.n, dis_key

    use_kernel = _use_kernel(backend)

    def party(sub, Xb):
        local_c = kmeans(sub, Xb, k, iters=local_iters, use_kernel=use_kernel)
        return vkmc_local_scores(Xb, local_c, alpha, use_kernel=use_kernel)

    return jax.vmap(party)(jnp.stack(subs), st.blocks), dis_key


CORESET_TASKS.register("uniform")(
    CoresetTask(name="uniform", score_fn=None,
                description="U-* baseline: uniform indices, weight n/m")
)


# --------------------------------------------------------------------------
# Engine executors — one per ExecutionPlan.engine.  These are the exact
# legacy builder bodies, factored so the shims and the pipeline share ONE
# code path (draw identity by construction, pinned by tests/test_plan.py).
# --------------------------------------------------------------------------

def _policy_retries(fault_policy: str) -> Optional[int]:
    """``fail`` is fail-fast (one attempt per message); ``retry``/``degrade``
    use the transport plan's own ``max_retries``."""
    return 0 if fault_policy == "fail" else None


def _faulted_round1(
    spec: CoresetTask, ds: VFLDataset, transport: Transport,
    ledger: Optional[CommLedger], fault_policy: str,
    payload: Optional[WirePayload] = None,
) -> Tuple[VFLDataset, Optional[list], Optional[DegradedBuild], int, int]:
    """Deliver DIS round 1 through the transport; under ``degrade`` a party
    exhausting its retries here — BEFORE any score travels — is dropped and
    the build continues over the survivors.

    ``payload`` is the wire descriptor for the mass-table row each party's
    G_j upload physically carries — it drives the bits column only.
    Returns ``(effective dataset, surviving original party ids or None,
    DegradedBuild receipt or None, round-1 units billed, round-1 bits
    billed)``.  The label party (T-1) is irreplaceable for a labels-bearing
    task, and losing every party is unrecoverable — both re-raise
    :exc:`PartyUnavailable`.
    """
    rep = transport.deliver(
        CommSchedule.dis_round1(ds.T, payload=payload), ledger,
        max_retries=_policy_retries(fault_policy),
        drop_on_exhaust=(fault_policy == "degrade"),
    )
    if not rep.failed:
        return ds, None, None, rep.units, rep.bits
    alive = sorted(set(range(ds.T)) - set(rep.failed))
    dropped = tuple(sorted(rep.failed.values(), key=lambda d: d.party))
    if not alive:
        d = dropped[0]
        raise PartyUnavailable(d.party, d.tag, d.attempts)
    if spec.needs_labels and (ds.T - 1) in rep.failed:
        # labels live ONLY at party T-1; no surviving subset can score vrlr
        d = rep.failed[ds.T - 1]
        raise PartyUnavailable(d.party, d.tag, d.attempts)
    degraded = DegradedBuild(dropped=dropped, surviving=tuple(alive),
                             total_parties=ds.T)
    return ds.select_parties(alive), alive, degraded, rep.units, rep.bits


def _validators_on(fault_policy: str) -> bool:
    """The policy matrix's defense column: ``fail`` and ``quarantine`` run
    the value-level validators on delivered payloads; ``retry``/``degrade``
    trust party values (they defend availability, not honesty — the
    undefended baseline the integrity benchmark measures against)."""
    return fault_policy in ("fail", "quarantine")


def _task_bound(spec: CoresetTask, eff_ds: VFLDataset, backend: str,
                params: dict) -> Optional[float]:
    """The task's total-sensitivity bound for the value-level validators —
    Thm 4.2 for VRLR (sum of effective widths + T, labels widening party
    T's block), Lemma F.2 for VKMC (2(k+1)*alpha*T exactly).  The ``norm``
    ablation backend scores row norms, which respect no such bound."""
    if backend == "norm":
        return None
    if spec.name == "vrlr":
        dims = list(eff_ds.dims)
        if eff_ds.y is not None:
            dims[-1] += 1
        return total_sensitivity_bound_vrlr(dims, eff_ds.T)
    if spec.name == "vkmc":
        return total_sensitivity_bound_vkmc(
            int(params.get("k", 10)), eff_ds.T,
            float(params.get("alpha", 2.0)))
    return None


def _integrity_round1(
    spec: CoresetTask, eff_ds: VFLDataset, transport: Transport,
    ledger: Optional[CommLedger], fault_policy: str, masses,
    backend: str, params: dict, codec: str = "raw_fp32",
):
    """The round-1 integrity seam: ship each party's mass row under a
    checksummed :class:`~repro.core.integrity.WireEnvelope`, then run the
    value-level validators on what was DELIVERED.

    ``masses`` is the host (T_eff, cells) table — per-row scores for the
    materialized engine, the (T, nb) block table for the streamed ones.
    The cross-check totals are the honest per-party scalars (the round-1
    ``G_j`` message the schedule already billed); a lying or corrupted row
    cannot match them.  Returns ``(delivered_table_or_None, offenders)``:
    the table is None when nothing changed (the clean path touches no
    bytes), ``offenders`` — local party indices — is nonempty only
    under ``quarantine`` (validator hits under ``fail`` raise a
    party-attributed :exc:`IntegrityError`; transport-level detections
    were already retried and billed inside ``ship``), and
    ``retry_units``/``retry_bits`` are the retransmission traffic ship
    billed, so the returned coreset's ``comm_units``/``comm_bits`` stay
    the composed ledger truth.

    ``codec`` packs each row through :mod:`repro.core.wire`: the envelope
    CRC covers the ENCODED bytes and a lossy codec delivers the quantized
    table (the draw consumes what crossed the wire).  A lossy codec also
    skips the row-sum/scalar cross-check — the quantized row cannot match
    the honest fp32 scalar by construction; the finiteness/nonnegativity/
    bound validators still run on the delivered values."""
    c = get_codec(codec)
    tbl = np.asarray(masses)
    totals = tbl.sum(axis=1)
    rows = {j: tbl[j] for j in range(tbl.shape[0])}
    r0 = transport.stats.units_retried
    b0 = transport.stats.bits_retried
    delivered, failed = transport.ship(
        "dis/round1/G_j", rows, ledger, units=1,
        max_retries=_policy_retries(fault_policy),
        drop_on_exhaust=(fault_policy == "quarantine"), codec=codec)
    retry_units = transport.stats.units_retried - r0
    retry_bits = transport.stats.bits_retried - b0
    changed = any(delivered.get(j) is not rows[j] for j in rows)
    out = (np.stack([np.asarray(delivered.get(j, rows[j]))
                     for j in range(len(rows))])
           if changed else None)
    offenders = set(failed)
    if _validators_on(fault_policy):
        offenders |= set(require_valid_masses(
            tbl if out is None else out,
            totals if c.lossless else None,
            bound=_task_bound(spec, eff_ds, backend, params),
            policy=fault_policy))
    return out, tuple(sorted(offenders)), retry_units, retry_bits


def _quarantine(
    spec: CoresetTask, ds: VFLDataset, alive: Optional[list],
    degraded: Optional[DegradedBuild], offenders: Tuple[int, ...],
    tag: str = "dis/round1/G_j",
) -> Tuple[VFLDataset, list, DegradedBuild]:
    """Fold integrity offenders into the degrade machinery: map local
    offender indices back to original party ids, drop them, and extend the
    :class:`DegradedBuild` receipt with the quarantine reason.  The label
    party is irreplaceable and losing every party is unrecoverable — both
    raise instead of degrading, mirroring :func:`_faulted_round1`."""
    orig = list(alive) if alive is not None else list(range(ds.T))
    bad = sorted(orig[j] for j in offenders)
    survivors = [p for p in orig if p not in set(bad)]
    if not survivors:
        raise IntegrityError(bad[0], "every party quarantined; no feature "
                                     "slices left to build from", tag=tag)
    if spec.needs_labels and (ds.T - 1) in bad:
        raise IntegrityError(
            ds.T - 1, "label party failed integrity validation; labels "
                      "live only at party T-1, the build cannot continue",
            tag=tag)
    dropped = tuple(degraded.dropped if degraded is not None else ()) + tuple(
        DroppedParty(p, f"quarantine/{tag}", 1) for p in bad)
    reason = (f"part{'y' if len(bad) == 1 else 'ies'} {bad} quarantined "
              f"for integrity violations at {tag!r}")
    receipt = DegradedBuild(
        dropped=tuple(sorted(dropped, key=lambda d: d.party)),
        surviving=tuple(survivors), total_parties=ds.T, reason=reason)
    return ds.select_parties(survivors), survivors, receipt


def _round2_wire(plan, alive: Optional[list], T: int, codec: str):
    """Pre-encode the round-2 index uploads ONCE: the returned payload
    descriptors (aligned with ``plan.counts``) carry the measured packed
    bits for :meth:`CommSchedule.dis_rounds23`, and the returned blobs are
    handed to :meth:`Transport.ship` via ``encoded=`` — bits billed equal
    bytes sealed by construction (delta-varint uploads are value-dependent,
    so the bound-only descriptor would over-bill)."""
    counts = np.asarray(plan.counts)
    ups = split_uploads(np.asarray(plan.indices), counts)
    orig = list(alive) if alive is not None else list(range(T))
    c = get_codec(codec)
    payloads: list = [None] * len(ups)
    blobs: dict = {}
    for j in range(len(ups)):
        if counts[j] <= 0:
            continue
        arr = np.asarray(ups[j])
        blob = c.encode(arr)
        blobs[orig[j]] = blob
        payloads[j] = WirePayload.measured(
            arr.shape, str(arr.dtype), codec, 8 * len(blob))
    return payloads, blobs


def _ship_round2(
    transport: Transport, ledger: Optional[CommLedger], fault_policy: str,
    plan, alive: Optional[list], T: int, codec: str = "raw_fp32",
    blobs: Optional[dict] = None,
):
    """Ship the round-2 index uploads under envelopes.  Units per party are
    the realized a_j — the exact sizes ``CommSchedule.dis_rounds23`` billed,
    so envelope-detected retransmissions land under ``retry/dis/round2/S_up``
    at the message's true cost (measured packed bits in the bits column).
    ``blobs`` are the pre-encoded uploads from :func:`_round2_wire`, sealed
    as-is.  Returns the (possibly corrupted, if the transport does not
    verify) realized index vector plus the retry units and bits billed, and
    raises through the weight validator when the policy defends."""
    counts = np.asarray(plan.counts)
    ups = split_uploads(np.asarray(plan.indices), counts)
    orig = list(alive) if alive is not None else list(range(T))
    payloads = {orig[j]: ups[j] for j in range(len(ups)) if counts[j] > 0}
    units = {orig[j]: int(counts[j]) for j in range(len(ups)) if counts[j] > 0}
    r0 = transport.stats.units_retried
    b0 = transport.stats.bits_retried
    delivered, _ = transport.ship(
        "dis/round2/S_up", payloads, ledger, units=units,
        max_retries=_policy_retries(fault_policy), drop_on_exhaust=False,
        codec=codec, encoded=blobs)
    retry_units = transport.stats.units_retried - r0
    retry_bits = transport.stats.bits_retried - b0
    if _validators_on(fault_policy):
        why = check_weights(plan.weights)
        if why is not None:
            raise IntegrityError(None, f"realized coreset weights: {why}",
                                 tag="dis/round3/g_scores")
    changed = any(delivered[p] is not payloads[p] for p in payloads)
    if not changed:
        return plan.indices, retry_units, retry_bits
    parts = [np.asarray(delivered.get(orig[j], ups[j]))
             for j in range(len(ups))]
    out = jnp.asarray(np.concatenate(parts)) if parts else plan.indices
    return out, retry_units, retry_bits


def _exec_materialized(
    spec: CoresetTask, ds: VFLDataset, m: int, key, backend: str,
    ledger: Optional[CommLedger], params: dict,
    transport: Optional[Transport] = None, fault_policy: str = "fail",
    codec: str = "raw_fp32",
) -> Coreset:
    """The eager sequential engine — the fidelity reference against the
    seed's builders (scores computed eagerly, DIS on the full matrix).

    With a ``transport`` the DIS rounds are DELIVERED instead of recorded:
    round 1 before scoring (where ``degrade`` can still drop a party —
    sensitivities are then recomputed over the surviving feature slices),
    rounds 2-3 after the draw.  Without one (or with a null fault plan) the
    ledger entries and draws are bit-identical to the pre-transport path.
    """
    if spec.needs_labels and ds.y is None:
        raise ValueError(f"{spec.name} requires labels at party T")
    retries = _policy_retries(fault_policy)
    if spec.score_fn is None:
        S, w = uniform_plan(key, ds.n, m)
        schedule = CommSchedule.uniform(ds.T, m)
        if transport is None:
            schedule.record(ledger)
            return Coreset(S, w, schedule.total,
                           comm_bits=schedule.total_bits)
        rep = transport.deliver(schedule, ledger, max_retries=retries,
                                drop_on_exhaust=(fault_policy == "degrade"))
        degraded = None
        if rep.failed:
            dropped = tuple(sorted(rep.failed.values(), key=lambda d: d.party))
            alive = sorted(set(range(ds.T)) - set(rep.failed))
            degraded = DegradedBuild(dropped=dropped, surviving=tuple(alive),
                                     total_parties=ds.T)
        return Coreset(S, w, rep.units, comm_bits=rep.bits,
                       degraded=degraded)

    # the round-1 G_j upload physically carries the per-row mass table —
    # one float32 entry per row on this engine, descriptor shared by the
    # recorded and the delivered path so their bits columns agree
    r1_payload = WirePayload.of((ds.n,), "float32", codec)
    if transport is None:
        if codec != "raw_fp32":
            raise ValueError(
                f"codec={codec!r} quantizes what crosses the wire; without "
                f"a transport nothing crosses it — the recorded path "
                f"supports codec='raw_fp32' only"
            )
        scores, dis_key = spec.score_fn(key, ds, backend=backend, **params)
        plan = dis_plan_full(dis_key, scores, m)
        if not bool(plan.totals.sum() > 0):
            raise ValueError("DIS requires a positive total score")
        schedule = CommSchedule.dis(ds.T, m, counts=np.asarray(plan.counts),
                                    round1_payload=r1_payload)
        schedule.record(ledger)
        return Coreset(plan.indices, plan.weights, schedule.total,
                       comm_bits=schedule.total_bits,
                       health=health_from_masses(np.asarray(scores)))

    eff_ds, alive, degraded, units1, bits1 = _faulted_round1(
        spec, ds, transport, ledger, fault_policy, payload=r1_payload)
    scores, dis_key = spec.score_fn(key, eff_ds, backend=backend, **params)
    # integrity seam: the per-row score table IS this engine's round-1 mass
    # payload — ship it under envelopes, validate what arrived
    delivered, offenders, ship_units, ship_bits = _integrity_round1(
        spec, eff_ds, transport, ledger, fault_policy,
        np.asarray(scores), backend, params, codec=codec)
    if offenders:
        eff_ds, alive, degraded = _quarantine(spec, ds, alive, degraded,
                                              offenders)
        # rescore the survivors; their tables already validated clean
        scores, dis_key = spec.score_fn(key, eff_ds, backend=backend,
                                        **params)
    elif delivered is not None:
        # what crossed the wire drives the draw: a lossy codec's quantized
        # table on the clean path, or — with verification off — corrupted
        # masses, exactly the undefended blow-up the integrity benchmark
        # measures
        scores = jnp.asarray(delivered)
    health = health_from_masses(np.asarray(scores))
    plan = dis_plan_full(dis_key, scores, m)
    if not bool(plan.totals.sum() > 0):
        raise ValueError("DIS requires a positive total score")
    # rounds 2-3 exhaust hard even under degrade: by now the scores exist
    # and dropping a party would orphan its drawn rows (documented)
    up_payloads, up_blobs = _round2_wire(plan, alive, ds.T, codec)
    rep23 = transport.deliver(
        CommSchedule.dis_rounds23(ds.T, m, counts=np.asarray(plan.counts),
                                  parties=alive,
                                  upload_payloads=up_payloads),
        ledger, max_retries=retries, drop_on_exhaust=False,
    )
    indices, r2_units, r2_bits = _ship_round2(
        transport, ledger, fault_policy, plan, alive, ds.T,
        codec=codec, blobs=up_blobs)
    return Coreset(indices, plan.weights,
                   units1 + rep23.units + ship_units + r2_units,
                   comm_bits=bits1 + rep23.bits + ship_bits + r2_bits,
                   degraded=degraded, health=health)


# (task spec, dims, labeled?, n, m, backend, params) -> jitted builder.
_JIT_BUILDERS: dict = {}


def _exec_fused(
    spec: CoresetTask, ds: VFLDataset, m: int, key, backend: str,
    ledger: Optional[CommLedger], params: dict,
) -> Coreset:
    """The materialized engine's fused fast path: scoring +
    :func:`dis_plan_full` in ONE jitted dispatch, cached per ``(task,
    shapes, backend, params)``.

    The eager :func:`_exec_materialized` stays the bit-identity anchor;
    whole-program fusion may reorder fp reductions, so weights agree to fp
    tolerance (not bitwise) and a draw landing exactly on a categorical
    boundary could in principle differ — use the eager path where
    cross-version draw stability matters.
    """
    if spec.needs_labels and ds.y is None:
        raise ValueError(f"{spec.name} requires labels at party T")

    if spec.score_fn is None:
        cache_key = (spec, ds.n, m)
        fn = _JIT_BUILDERS.get(cache_key)
        if fn is None:
            n = ds.n   # bind the scalars only — the cached closure must not
            fn = jax.jit(lambda k: uniform_plan(k, n, m))  # pin ds's arrays
            _JIT_BUILDERS[cache_key] = fn
        S, w = fn(key)
        schedule = CommSchedule.uniform(ds.T, m)
        schedule.record(ledger)
        return Coreset(S, w, schedule.total, comm_bits=schedule.total_bits)

    cache_key = (spec, ds.dims, ds.y is not None, ds.n, m, backend,
                 tuple(sorted(params.items())))
    fn = _JIT_BUILDERS.get(cache_key)
    if fn is None:
        def _build(k, parts, y):
            ds_t = VFLDataset(list(parts), y)
            scores, dis_key = spec.score_fn(k, ds_t, backend=backend, **params)
            return dis_plan_full(dis_key, scores, m)

        fn = jax.jit(_build)
        _JIT_BUILDERS[cache_key] = fn
    plan = fn(key, tuple(ds.parts), ds.y)
    if not bool(plan.totals.sum() > 0):
        raise ValueError("DIS requires a positive total score")
    schedule = CommSchedule.dis(
        ds.T, m, counts=np.asarray(plan.counts),
        round1_payload=WirePayload.of((ds.n,), "float32", "raw_fp32"))
    schedule.record(ledger)
    return Coreset(plan.indices, plan.weights, schedule.total,
                   comm_bits=schedule.total_bits)


# sharded block-mass helpers per task (the `sharded_masses` plan toggle)
_SHARDED_MASSES: dict = {}


def _sharded_mass_table(task_name: str, key, ds: VFLDataset,
                        block_size: int, backend: str, params: dict):
    """Compute the (T, nb) block-mass table data-parallel over a one-axis
    mesh spanning every local device (shard_map + two psums — see
    :mod:`repro.core.streaming`).  The per-row scores the sampler later
    recomputes come from the scorer's own block path; ``backend`` is
    forwarded so vkmc's iterated center solve runs the SAME kernels as the
    scorer (a mismatch would build the table from different centers), and
    the table matches the scorer's up to fp reduction order."""
    from repro.core.streaming import (
        vkmc_block_masses_sharded,
        vrlr_block_masses_sharded,
    )

    mesh = jax.sharding.Mesh(np.array(jax.devices()), ("data",))
    if task_name == "vrlr":
        kw = {k: v for k, v in params.items() if k == "rcond"}
        return vrlr_block_masses_sharded(mesh, ds, block_size, **kw)
    if task_name == "vkmc":
        kw = {k: v for k, v in params.items()
              if k in ("k", "alpha", "local_iters", "center_sample")}
        return vkmc_block_masses_sharded(mesh, ds, block_size, key=key,
                                         use_kernel=_use_kernel(backend),
                                         **kw)
    raise ValueError(
        f"sharded_masses supports tasks ('vrlr', 'vkmc'), got {task_name!r}"
    )


def _exec_streaming(
    spec: CoresetTask, ds: VFLDataset, m: int, key, backend: str,
    ledger: Optional[CommLedger], probe, block_size: int, chunk_blocks: int,
    prefetch: bool, pipelined: bool, sharded_masses: bool, params: dict,
    transport: Optional[Transport] = None, fault_policy: str = "fail",
    checkpoint: Optional[StreamCheckpoint] = None,
    codec: str = "raw_fp32",
) -> Coreset:
    """The streamed / pipelined engines: block-scan scoring + hierarchical
    (party, block) DIS.  ``pipelined`` selects the superchunk-grouped
    redraw (:func:`repro.core.streaming.dis_plan_streamed_batched`) — the
    same draws as the block-at-a-time reference, fewer dispatches.  All
    knobs arrive RESOLVED (validated by :class:`CoresetSpec`, clamped by
    the planner) — nothing is coerced here.

    ``transport`` delivers the DIS rounds through the fault seam exactly as
    in :func:`_exec_materialized` (round 1 before the scorer is built, so
    ``degrade`` drops a party before any pass over the data).
    ``checkpoint`` (a :class:`~repro.core.faults.StreamCheckpoint`) makes
    the scorer's scan passes resumable per superchunk: a crashed build
    rerun with the same arguments restores the last completed superchunk's
    accumulators and finishes draw-identically.  ``None`` for either keeps
    today's exact code path.
    """
    from repro.core.streaming import (
        dis_plan_streamed,
        dis_plan_streamed_batched,
        make_stream_scorer,
        with_masses,
    )

    if spec.needs_labels and ds.y is None:
        raise ValueError(f"{spec.name} requires labels at party T")
    retries = _policy_retries(fault_policy)
    if spec.score_fn is None:
        S, w = uniform_plan(key, ds.n, m)
        schedule = CommSchedule.uniform(ds.T, m)
        if transport is None:
            schedule.record(ledger)
            return Coreset(S, w, schedule.total,
                           comm_bits=schedule.total_bits)
        rep = transport.deliver(schedule, ledger, max_retries=retries,
                                drop_on_exhaust=(fault_policy == "degrade"))
        degraded = None
        if rep.failed:
            dropped = tuple(sorted(rep.failed.values(), key=lambda d: d.party))
            alive = sorted(set(range(ds.T)) - set(rep.failed))
            degraded = DegradedBuild(dropped=dropped, surviving=tuple(alive),
                                     total_parties=ds.T)
        return Coreset(S, w, rep.units, comm_bits=rep.bits,
                       degraded=degraded)

    # the streamed round-1 payload is the (T, nb) block-mass table — one
    # float32 entry per BLOCK per party, not per row
    nb = ds.block_geometry(int(block_size))[0]
    r1_payload = WirePayload.of((nb,), "float32", codec)
    if transport is None and codec != "raw_fp32":
        raise ValueError(
            f"codec={codec!r} quantizes what crosses the wire; without a "
            f"transport nothing crosses it — the recorded path supports "
            f"codec='raw_fp32' only"
        )
    alive = degraded = None
    units1 = bits1 = 0
    eff_ds = ds
    if transport is not None:
        eff_ds, alive, degraded, units1, bits1 = _faulted_round1(
            spec, ds, transport, ledger, fault_policy, payload=r1_payload)

    def _build_scorer(eff):
        masses = None
        if sharded_masses:
            # task/backend compatibility was validated by compile_plan —
            # every path into this executor goes through the planner
            masses = _sharded_mass_table(spec.name, key, eff, block_size,
                                         backend, params)
        if checkpoint is not None:
            checkpoint.bind((
                spec.name, eff.n, eff.dims, eff.y is not None,
                int(block_size), int(chunk_blocks), bool(prefetch), backend,
                tuple(sorted(params.items())), int(m),
                tuple(np.asarray(_key_data(key)).ravel().tolist()),
            ))
        return make_stream_scorer(spec.name, key, eff, int(block_size),
                                  backend, probe=probe,
                                  chunk_blocks=chunk_blocks,
                                  prefetch=prefetch, masses=masses,
                                  ckpt=checkpoint, **params)

    scorer = _build_scorer(eff_ds)
    ship_units = ship_bits = 0
    if transport is not None:
        # integrity seam: the (T, nb) block-mass table is the streamed
        # round-1 payload — ship it under envelopes, validate what arrived
        delivered, offenders, ship_units, ship_bits = _integrity_round1(
            spec, eff_ds, transport, ledger, fault_policy,
            np.asarray(scorer.masses), backend, params, codec=codec)
        if offenders:
            eff_ds, alive, degraded = _quarantine(spec, ds, alive, degraded,
                                                  offenders)
            scorer = _build_scorer(eff_ds)  # rescore the survivors
        elif delivered is not None:
            # what crossed the wire drives the draw: the lossy codec's
            # quantized table, or — unverified — a corrupted one
            scorer = with_masses(scorer, delivered)
    health = health_from_masses(np.asarray(scorer.masses),
                                gram_conds=scorer.gram_conds)
    if not bool(scorer.masses.sum() > 0):
        raise ValueError("DIS requires a positive total score")
    if pipelined:
        plan = dis_plan_streamed_batched(scorer, m, probe=probe)
    else:
        plan = dis_plan_streamed(scorer, m, probe=probe)
    if checkpoint is not None:
        checkpoint.clear()            # the build completed; state is stale
    if transport is None:
        schedule = CommSchedule.dis(ds.T, m, counts=np.asarray(plan.counts),
                                    round1_payload=r1_payload)
        schedule.record(ledger)
        return Coreset(plan.indices, plan.weights, schedule.total,
                       comm_bits=schedule.total_bits, health=health)
    up_payloads, up_blobs = _round2_wire(plan, alive, ds.T, codec)
    rep23 = transport.deliver(
        CommSchedule.dis_rounds23(ds.T, m, counts=np.asarray(plan.counts),
                                  parties=alive,
                                  upload_payloads=up_payloads),
        ledger, max_retries=retries, drop_on_exhaust=False,
    )
    indices, r2_units, r2_bits = _ship_round2(
        transport, ledger, fault_policy, plan, alive, ds.T,
        codec=codec, blobs=up_blobs)
    return Coreset(indices, plan.weights,
                   units1 + rep23.units + ship_units + r2_units,
                   comm_bits=bits1 + rep23.bits + ship_bits + r2_bits,
                   degraded=degraded, health=health)


# --------------------------------------------------------------------------
# Batched multi-seed / multi-budget engine (one compilation)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BatchedCoresets:
    """A (num_seeds, num_budgets) grid of coresets from ONE compiled call.

    ``indices``/``weights`` are ``(R, M, m_cap)`` with the valid-prefix
    convention: cell (r, i) holds ``ms[i]`` real samples; the padded tail has
    weight 0.  ``counts`` carries the realised round-2 a_j per cell so the
    exact CommSchedule can be derived lazily, after the fact — accounting
    never touched the compiled path.
    """

    indices: jax.Array            # (R, M, m_cap) int
    weights: jax.Array            # (R, M, m_cap) float
    counts: Optional[jax.Array]   # (R, M, T) int; None for the uniform task
    ms: Tuple[int, ...]
    T: int
    #: Round-1 mass-table cells per party (n on this engine); 0 on legacy
    #: grids predating the bits column — their schedules then bill the
    #: scalar-only convention.  The batched engine is raw_fp32-only (it
    #: never transports), so no codec field is needed.
    cells: int = 0

    @property
    def num_seeds(self) -> int:
        return int(self.indices.shape[0])

    def schedule(self, seed_idx: int, m_idx: int) -> CommSchedule:
        m = self.ms[m_idx]
        if self.counts is None:
            return CommSchedule.uniform(self.T, m)
        r1 = (WirePayload.of((self.cells,), "float32", "raw_fp32")
              if self.cells else None)
        return CommSchedule.dis(
            self.T, m, counts=np.asarray(self.counts[seed_idx, m_idx]),
            round1_payload=r1,
        )

    def coreset(
        self, seed_idx: int, m_idx: int = 0,
        ledger: Optional[CommLedger] = None,
    ) -> Coreset:
        """Extract cell (seed_idx, m_idx) as a plain :class:`Coreset`."""
        m = self.ms[m_idx]
        schedule = self.schedule(seed_idx, m_idx).record(ledger)
        return Coreset(
            self.indices[seed_idx, m_idx, :m],
            self.weights[seed_idx, m_idx, :m],
            schedule.total,
            comm_bits=schedule.total_bits,
        )


def _exec_batched(
    spec: CoresetTask, ds: VFLDataset, ms: Tuple[int, ...], keys,
    backend: str, m_cap: int, params: dict,
) -> BatchedCoresets:
    """The batched engine: every (seed, budget) cell in one compiled
    ``jit(vmap(vmap(dis_plan_full)))`` call over the pure DIS core, using
    the ``m_cap`` prefix-masking convention for the budget grid.  For ``m
    == m_cap`` each cell is exactly the eager :func:`_exec_materialized`
    result for that key (eager hoisted totals keep the weight arithmetic
    bit-identical for deterministic-score tasks).
    """
    if spec.needs_labels and ds.y is None:
        raise ValueError(f"{spec.name} requires labels at party T")
    ms_arr = jnp.asarray(ms, jnp.int32)

    def _cells(dis_key, sc, totals=None):
        """All budget cells for one seed (scores computed once per seed)."""
        def cell(m):
            plan = dis_plan_full(dis_key, sc, m, m_cap=m_cap, totals=totals)
            return plan.indices, plan.weights, plan.counts
        return jax.vmap(cell)(ms_arr)

    if spec.score_fn is None:
        def per_seed(k):
            def cell(m):
                S, w = uniform_plan(k, ds.n, m, m_cap=m_cap)
                return S, w, jnp.zeros((ds.T,), jnp.int32)
            return jax.vmap(cell)(ms_arr)
    else:
        hoisted = None
        if spec.deterministic_scores:
            # scores are seed-independent: compute once on the host and
            # share across the whole grid — but only if the score_fn honours
            # the deterministic contract (key passed through unchanged);
            # otherwise fall back to per-seed scoring so sequential and
            # batched builds keep sampling with the same dis_key.
            sc0, dk0 = spec.score_fn(keys[0], ds, backend=backend, **params)
            if np.array_equal(_key_data(dk0), _key_data(keys[0])):
                hoisted = sc0
        if hoisted is not None:
            if not bool(hoisted.sum() > 0):
                raise ValueError("DIS requires a positive total score")
            # eager per-party totals: same reduction kernel as the sequential
            # path, so w = G/(m g) matches sequential builds bit for bit.
            hoisted_totals = jnp.sum(hoisted.astype(_float_dtype()), axis=1)

            def per_seed(k):
                return _cells(k, hoisted, totals=hoisted_totals)
        else:
            def per_seed(k):
                sc, dis_key = spec.score_fn(k, ds, backend=backend, **params)
                return _cells(dis_key, sc)

    S, w, counts = jax.jit(jax.vmap(per_seed))(keys)
    if spec.score_fn is not None and not bool(jnp.all(w[..., 0] > 0)):
        # w[r, i, 0] = G / (m * g) is positive iff the realised total score
        # G was — the traced core can't raise, so validate post hoc.
        raise ValueError("DIS requires a positive total score")
    return BatchedCoresets(
        indices=S, weights=w,
        counts=None if spec.score_fn is None else counts,
        ms=ms, T=ds.T, cells=ds.n,
    )


# --------------------------------------------------------------------------
# CoresetPipeline: spec in, plan-dispatched build out
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CoresetPipeline:
    """The declarative entry point: ``build(spec)`` compiles the spec into
    an :class:`~repro.core.plan.ExecutionPlan` and dispatches to the named
    engine.

    ``plan(spec)`` exposes the compiled plan without running anything
    (``plan.describe()`` prints engine, resolved knobs, the full memory
    model, and the exact predicted communication bill); ``build`` also
    accepts a pre-compiled plan so introspect-then-run costs one
    compilation.  A forced-engine spec reproduces the corresponding legacy
    entry point draw for draw — the legacy functions ARE such specs.

    ``plan_cache`` (a :class:`~repro.core.plan.PlanCache`) memoizes
    ``plan(spec)`` by ``(task, geometry, knobs)`` — the serving layer's
    seam: one cache shared across tenants makes repeat shapes skip
    compilation (the same signature also keys the executors' jit caches,
    so a hit implies the engine's compiled programs are warm too).
    """

    ds: VFLDataset
    plan_cache: Optional[PlanCache] = None

    def plan(self, spec: CoresetSpec) -> ExecutionPlan:
        if self.plan_cache is not None:
            return self.plan_cache.get(spec, self.ds)
        return compile_plan(spec, self.ds)

    def build(
        self,
        spec: Union[CoresetSpec, ExecutionPlan],
        *,
        key: Optional[jax.Array] = None,
        keys: Optional[jax.Array] = None,
        ledger: Optional[CommLedger] = None,
        probe: Optional[Callable[[], None]] = None,
        transport: Optional[Transport] = None,
        checkpoint: Optional[StreamCheckpoint] = None,
    ) -> Union[Coreset, BatchedCoresets]:
        """Build per the (compiled) spec.

        Returns a :class:`Coreset` for single-cell plans and a
        :class:`BatchedCoresets` grid for the batched engine.  ``keys``
        (a stacked key array) overrides ``key`` + ``spec.num_seeds`` for
        the batched engine; ``probe`` is the streaming engines'
        per-superchunk instrumentation hook.  The batched engine derives
        its bills lazily per cell (``grid.coreset(..., ledger=...)``), so
        ``ledger`` applies to single-cell engines only.

        ``transport`` (a :class:`~repro.core.faults.Transport`) delivers
        the protocol rounds through the party fault seam, honouring
        ``spec.fault_policy``; with no transport — or a null fault plan —
        every engine's draws AND ledger entries are bit-identical to a
        transportless build (pinned in ``tests/test_faults.py``).
        ``checkpoint`` (a :class:`~repro.core.faults.StreamCheckpoint`)
        makes the streamed/pipelined engines' passes resumable per
        superchunk.
        """
        if isinstance(spec, ExecutionPlan):
            ep = spec
            if (ep.n, ep.dims) != (self.ds.n, self.ds.dims):
                raise ValueError(
                    f"plan was compiled for a dataset with n={ep.n}, "
                    f"dims={ep.dims}; this pipeline's dataset has "
                    f"n={self.ds.n}, dims={self.ds.dims} — recompile with "
                    f"plan(spec)"
                )
        else:
            ep = self.plan(spec)
        cspec = ep.spec
        task = get_task(cspec.task)

        if ep.engine == "batched":
            if transport is not None or checkpoint is not None:
                raise ValueError(
                    "the batched engine bills its cells lazily; transport "
                    "delivery and checkpointed resume apply to single-cell "
                    "engines only"
                )
            if keys is None:
                if key is None:
                    raise ValueError("pass either `key` (+ num_seeds) or `keys`")
                keys = jax.random.split(key, cspec.num_seeds)
            return _exec_batched(task, self.ds, cspec.budgets, keys,
                                 ep.backend, ep.m_cap, cspec.params)

        if key is None:
            raise ValueError(f"the {ep.engine} engine requires `key`")
        m = cspec.budget
        if ep.engine == "materialized":
            if checkpoint is not None:
                raise ValueError(
                    "checkpointed resume is a streamed/pipelined-engine "
                    "feature; the materialized engine has no superchunk "
                    "passes to checkpoint"
                )
            if cspec.jit:
                if transport is not None:
                    raise ValueError(
                        "the fused jit path cannot deliver per-round "
                        "schedules through a transport; use the eager "
                        "materialized engine (jit=False)"
                    )
                return _exec_fused(task, self.ds, m, key, ep.backend, ledger,
                                   cspec.params)
            return _exec_materialized(task, self.ds, m, key, ep.backend,
                                      ledger, cspec.params,
                                      transport=transport,
                                      fault_policy=cspec.fault_policy,
                                      codec=ep.codec)
        return _exec_streaming(
            task, self.ds, m, key, ep.backend, ledger, probe,
            cspec.block_size, ep.chunk_blocks, ep.prefetch,
            pipelined=(ep.engine == "pipelined"),
            sharded_masses=cspec.sharded_masses, params=cspec.params,
            transport=transport, fault_policy=cspec.fault_policy,
            checkpoint=checkpoint, codec=ep.codec,
        )

    def build_failover(
        self,
        spec: CoresetSpec,
        *,
        key: jax.Array,
        ledger: Optional[CommLedger] = None,
        probe: Optional[Callable[[], None]] = None,
        transport: Optional[Transport] = None,
        checkpoint: Optional[StreamCheckpoint] = None,
        memory_budget_bytes: Optional[int] = None,
    ) -> "FailoverOutcome":
        """:meth:`build` with the plan's engine failover ladder armed.

        Runs the compiled plan's engine under a live-bytes
        :class:`~repro.core.plan.MemoryWatchdog` (when
        ``memory_budget_bytes`` is given, checked at every superchunk probe
        and once after the build); a watchdog breach or an engine crash
        retries once per remaining rung of ``plan.fallback_chain``
        (materialized -> pipelined -> streamed).  The LAST rung runs
        without the watchdog — streamed is the minimum-footprint engine,
        there is nothing left to fall back to.

        Errors that are engine-INDEPENDENT propagate instead of burning
        ladder rungs: :class:`DeadlineExceeded` (caller's time budget),
        :class:`PartyUnavailable` / :class:`IntegrityError` (party-side —
        the circuit breaker's domain, a cheaper engine talks to the same
        parties), and ``ValueError`` (spec/geometry validation).

        The billing contract the acceptance test pins: each failed attempt
        is rolled back to a ``ledger.mark()``, then a zero-unit
        ``fallback/<from>-><to>`` entry attributes the switch — the final
        total equals the successful engine's bill exactly, plus the tagged
        zero-cost marker.  The winning plan is returned with the decision
        appended to ``plan.notes``.
        """
        first = self.plan(spec)
        chain = (first.engine,) + first.fallback_chain
        watchdog = (None if memory_budget_bytes is None
                    else MemoryWatchdog(memory_budget_bytes))
        attempts = []
        tried = set()
        ep = first
        for rung, engine in enumerate(chain):
            if engine in tried:
                continue
            if rung > 0:
                # recompile on the fallback engine; jit is a
                # materialized/batched-only flag, never valid on the rungs
                fb_spec = dataclasses.replace(spec, engine=engine, jit=False)
                ep = self.plan(fb_spec)
                if ep.engine in tried:   # pipelined may lower to streamed
                    continue
            tried.add(ep.engine)
            last_rung = (rung == len(chain) - 1) or all(
                e in tried for e in chain[rung + 1:]
            )
            wd = None if (watchdog is None or last_rung) else watchdog
            eff_probe = _compose_probes(probe, wd)
            mark = None if ledger is None else ledger.mark()
            # checkpoints only exist on the streaming engines; the bind
            # signature changes with the engine's knobs, so reusing one
            # store across rungs auto-discards the failed rung's state
            ckpt = (checkpoint if ep.engine in ("streamed", "pipelined")
                    else None)
            try:
                cs = self.build(ep, key=key, ledger=ledger, probe=eff_probe,
                                transport=transport, checkpoint=ckpt)
                if wd is not None:
                    wd.check()   # materialized has no probes; final census
            except (DeadlineExceeded, PartyUnavailable, IntegrityError,
                    ValueError):
                if ledger is not None:
                    ledger.rollback(mark)
                raise
            except Exception as e:
                if ledger is not None:
                    ledger.rollback(mark)
                attempts.append(FailoverAttempt(
                    engine=ep.engine,
                    error=f"{type(e).__name__}: {e}",
                ))
                if last_rung:
                    raise
                continue
            if attempts:
                trail = " -> ".join([a.engine for a in attempts]
                                    + [ep.engine])
                ep = dataclasses.replace(
                    ep, notes=ep.notes + (
                        f"failover: {trail} "
                        f"({attempts[-1].error})",
                    ))
                if ledger is not None:
                    ledger.send(
                        f"fallback/{attempts[-1].engine}->{ep.engine}",
                        "server", "server", 0)
            return FailoverOutcome(coreset=cs, plan=ep,
                                   attempts=tuple(attempts))
        raise RuntimeError("unreachable: failover chain exhausted silently")


def _compose_probes(*fns) -> Optional[Callable[[], None]]:
    """Chain per-superchunk probes (caller's deadline check, the memory
    watchdog) into one hook; None entries drop out."""
    live = [f for f in fns if f is not None]
    if not live:
        return None
    if len(live) == 1:
        return live[0]

    def probe() -> None:
        for f in live:
            f()
    return probe


@dataclasses.dataclass(frozen=True)
class FailoverAttempt:
    """One failed rung of the ladder: which engine, what killed it."""

    engine: str
    error: str


@dataclasses.dataclass(frozen=True)
class FailoverOutcome:
    """Result of :meth:`CoresetPipeline.build_failover`: the coreset, the
    plan that produced it (with any failover note appended), and the failed
    attempts in ladder order (empty when the first engine succeeded)."""

    coreset: Coreset
    plan: ExecutionPlan
    attempts: Tuple[FailoverAttempt, ...] = ()

    @property
    def engine(self) -> str:
        return self.plan.engine

    @property
    def fallback(self) -> Optional[str]:
        """``"<first-failed>-><winner>"`` when the ladder fired, else None."""
        if not self.attempts:
            return None
        return f"{self.attempts[0].engine}->{self.plan.engine}"


# --------------------------------------------------------------------------
# Legacy entry points — thin shims over forced-engine specs.
# --------------------------------------------------------------------------

def build_coreset(
    task: Union[str, CoresetTask],
    ds: VFLDataset,
    budget: int,
    *,
    key: jax.Array,
    backend: str = "auto",
    ledger: Optional[CommLedger] = None,
    **params,
) -> Coreset:
    """Build one coreset of ``budget`` rows for ``task`` on ``ds`` — the
    MATERIALIZED engine (shim over ``CoresetSpec(engine="materialized")``).

    Task-specific knobs (vkmc's ``k``/``alpha``/``local_iters``) pass through
    ``**params`` to the task's score function.  ``backend`` defaults to
    ``"auto"`` (:func:`resolve_backend`: kernels on TPU/GPU, jnp refs on
    CPU).  The exact per-round communication bill is derived from the
    realised plan and recorded on ``ledger`` (when given);
    ``Coreset.comm_units`` is always this construction's own total.
    """
    spec = CoresetSpec(task=task, budgets=int(budget),
                       engine="materialized", backend=backend, params=params)
    return CoresetPipeline(ds).build(spec, key=key, ledger=ledger)


def build_coreset_jit(
    task: Union[str, CoresetTask],
    ds: VFLDataset,
    budget: int,
    *,
    key: jax.Array,
    backend: str = "auto",
    ledger: Optional[CommLedger] = None,
    **params,
) -> Coreset:
    """One-dispatch :func:`build_coreset` — the materialized engine's fused
    fast path (shim over ``CoresetSpec(engine="materialized", jit=True)``):
    scoring + DIS compiled into a single jitted function, cached per
    ``(task, shapes, backend, params)``.  Weights agree with the eager
    reference to fp tolerance (whole-program fusion reorders reductions);
    use :func:`build_coreset` where cross-version draw stability matters.
    """
    spec = CoresetSpec(task=task, budgets=int(budget),
                       engine="materialized", jit=True, backend=backend,
                       params=params)
    return CoresetPipeline(ds).build(spec, key=key, ledger=ledger)


def build_coreset_streaming(
    task: Union[str, CoresetTask],
    ds: VFLDataset,
    budget: int,
    *,
    key: jax.Array,
    block_size: int = 65536,
    chunk_blocks: Optional[int] = None,
    prefetch: Optional[bool] = None,
    backend: str = "auto",
    ledger: Optional[CommLedger] = None,
    probe: Optional[Callable[[], None]] = None,
    **params,
) -> Coreset:
    """Build one coreset with n as a STREAMING dimension — the streamed /
    pipelined engines (shim over ``CoresetSpec(engine="pipelined")``; the
    planner lowers ``chunk_blocks=1, prefetch=False`` to the strictly
    block-at-a-time streamed engine, same draws either way).

    Block-scan scoring plus the hierarchical (party, block)-cell DIS
    sampler keep peak device memory O(chunk_blocks * block_size * d) — the
    (T, n) score matrix and the (n, d) design are never materialized (pass
    a numpy-backed ``VFLDataset`` to keep the raw data off-device too).

    ``chunk_blocks`` (default :data:`repro.core.plan.DEFAULT_CHUNK_BLOCKS`)
    sets the pipelined dispatch granularity; ``prefetch`` (default
    :data:`repro.core.plan.PREFETCH_DEFAULT` — the measured winner per
    backend: off on CPU, where the staging thread competes with compute
    for the same cores and costs ~25% throughput, on for TPU/GPU, where
    the transfer engine overlaps for free) double-buffers the superchunk
    staging.  Knob validation is centralized in
    :class:`~repro.core.plan.CoresetSpec` (non-positive / non-integral
    values raise ``ValueError`` before any work); ``chunk_blocks`` above
    the block count is clamped by the PLANNER — an explicit decision
    surfaced in ``CoresetPipeline(ds).plan(spec).describe()``.

    The sampled marginal is exactly the flat plan's g_i/G (the two-level
    sampling telescopes — :func:`repro.core.dis.dis_plan_blocked`), and
    with ``block_size >= ds.n`` the draws coincide with
    :func:`build_coreset` bit for bit when the blockwise scores do (e.g.
    the row-local ``norm`` backend).  ``probe`` (if given) is invoked once
    per superchunk step — instrumentation hook for the memory benchmark.
    The communication bill is unchanged: blocking is server-side
    bookkeeping.
    """
    spec = CoresetSpec(task=task, budgets=int(budget),
                       engine="pipelined", backend=backend,
                       block_size=block_size, chunk_blocks=chunk_blocks,
                       prefetch=prefetch, params=params)
    return CoresetPipeline(ds).build(spec, key=key, ledger=ledger,
                                     probe=probe)


def build_coresets_batched(
    task: Union[str, CoresetTask],
    ds: VFLDataset,
    ms,
    *,
    key: Optional[jax.Array] = None,
    num_seeds: int = 1,
    keys: Optional[jax.Array] = None,
    backend: str = "ref",
    m_cap: Optional[int] = None,
    **params,
) -> BatchedCoresets:
    """Construct coresets for every (seed, budget) pair in one compiled call
    — the BATCHED engine (shim over ``CoresetSpec(engine="batched")``).

    ``ms`` is the budget grid (any iterable of ints); seeds come either from
    ``keys`` (a stacked ``(R, ...)`` key array) or ``jax.random.split(key,
    num_seeds)``.  Budgets below ``max(ms)`` use the prefix-masking
    convention (draws are iid, so a prefix of the capacity draw is a valid
    m-sample); for ``m == max(ms)`` each cell is exactly the sequential
    :func:`build_coreset` result for that key.

    ``backend`` defaults to ``"ref"`` (the pure-jnp scores are cheapest on
    a CPU container); ``"pallas"`` also vmaps — the kernels fold the seed
    batch into their grid via the native pallas batching rule — and
    ``"auto"`` resolves per :func:`resolve_backend`.  ``m_cap`` overrides
    the draw capacity (defaults to ``max(ms)``); every budget must lie in
    [1, m_cap] or the spec raises before tracing.
    """
    ms = tuple(int(m) for m in ms)       # the legacy coercion, pre-validation
    if keys is not None:
        num_seeds = int(keys.shape[0])
    spec = CoresetSpec(task=task, budgets=ms, num_seeds=num_seeds,
                       engine="batched", backend=backend, m_cap=m_cap,
                       params=params)
    return CoresetPipeline(ds).build(spec, key=key, keys=keys)
