"""repro.core.wire — the bit-level compressed wire subsystem.

Three modules under one namespace:

* :mod:`~repro.core.wire.codecs` — the codec registry: named packed-byte
  formats (``raw_fp32`` / ``fp16`` / ``int8_blockscale`` mass tables,
  ``delta_varint`` index uploads) with measured ``wire_bits``;
* :mod:`~repro.core.wire.payload` — :class:`WirePayload` descriptors that
  ride on ``CommSchedule`` ops so the ledger's bits column bills the
  bytes :meth:`Transport.ship` actually seals;
* :mod:`~repro.core.wire.budget` — plan-time bit prediction and the
  ``comm_budget_bits`` codec walk.

Everything here is numpy-only and imports nothing from the rest of
``repro.core`` — it is the layer below the ledger.
"""

from repro.core.wire.budget import (
    choose_codec,
    predict_dis_bits,
    predict_uniform_bits,
)
from repro.core.wire.codecs import (
    CODEC_LADDER,
    INT8_BLOCK,
    SPEC_CODECS,
    UNIT_BITS,
    WIRE_CODECS,
    Codec,
    get_codec,
)
from repro.core.wire.payload import WirePayload, encode_payloads, fmt_bits

__all__ = [
    "CODEC_LADDER",
    "Codec",
    "INT8_BLOCK",
    "SPEC_CODECS",
    "UNIT_BITS",
    "WIRE_CODECS",
    "WirePayload",
    "choose_codec",
    "encode_payloads",
    "fmt_bits",
    "get_codec",
    "predict_dis_bits",
    "predict_uniform_bits",
]
