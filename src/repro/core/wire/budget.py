"""Plan-time wire-bit prediction and the comm-budget codec walk.

Mirrors :meth:`CommSchedule.dis_total` one level down: where the unit
prediction is exact because the total is split-invariant, the bit
prediction is exact for every shape-determined message and a certified
upper bound for the value-dependent varint uploads — so a plan's
``predicted_wire_bits`` is a number the realized bill can never exceed,
which is what makes ``comm_budget_bits`` a real admission criterion
rather than a hope.

Numpy-free and comm-free on purpose: :mod:`repro.core.plan` calls in
here before any executor exists.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.core.wire.codecs import (
    CODEC_LADDER,
    UNIT_BITS,
    get_codec,
)


def predict_dis_bits(T: int, m: int, cells: int, codec: str) -> int:
    """Exact-or-upper-bound wire bits for one DIS cell (Algorithm 1).

    Round 1: each party uploads its mass-table row — ``cells`` float32
    entries through ``codec`` (the real payload behind the paper's G_j
    scalar) — and receives its a_j scalar.  Round 2: the m realized
    index uploads (int32 words raw; varint bound compressed — the total
    is split-invariant because the bound is per index) plus the m-index
    broadcast to every party.  Round 3: m score scalars up per party.
    """
    c = get_codec(codec)
    row = c.wire_bits((cells,), "float32")
    round1 = T * (row + UNIT_BITS)
    round2_up = c.wire_bits((m,), "int32")
    round23 = round2_up + 2 * T * m * UNIT_BITS
    return round1 + round23


def predict_uniform_bits(T: int, m: int) -> int:
    """U-* baseline: the m-index broadcast only (no tables, no uploads)."""
    return T * m * UNIT_BITS


def choose_codec(
    spec_codec: str,
    budget_bits: Optional[int],
    bits_by_codec: Dict[str, int],
) -> Tuple[str, bool, str]:
    """Resolve the spec's codec axis against a bit budget.

    Returns ``(codec, budget_exceeded, note)``.  ``codec="auto"`` walks
    :data:`CODEC_LADDER` in fidelity order and picks the FIRST codec whose
    predicted bits fit the budget — the best tolerance money can buy; if
    none fits, the smallest codec is chosen and the plan is flagged.  An
    explicit codec is honoured as-is and only checked against the budget.
    """
    if spec_codec != "auto":
        bits = bits_by_codec[spec_codec]
        if budget_bits is not None and bits > budget_bits:
            return spec_codec, True, (
                f"codec {spec_codec} predicted {bits} bits exceeds "
                f"comm_budget_bits={budget_bits}"
            )
        return spec_codec, False, ""
    if budget_bits is None:
        return CODEC_LADDER[0], False, ""
    for name in CODEC_LADDER:
        if bits_by_codec[name] <= budget_bits:
            others = ", ".join(
                f"{n}={bits_by_codec[n]}b" for n in CODEC_LADDER if n != name
            )
            return name, False, (
                f"comm budget {budget_bits}b -> {name} "
                f"({bits_by_codec[name]}b predicted; {others}; "
                f"tolerance {get_codec(name).tolerance:.3g})"
            )
    name = min(CODEC_LADDER, key=lambda n: bits_by_codec[n])
    return name, True, (
        f"comm budget {budget_bits}b unmeetable; smallest codec {name} "
        f"still predicts {bits_by_codec[name]}b"
    )
