"""Payload descriptors: the bridge between schedules and packed bytes.

A :class:`WirePayload` rides on a :class:`~repro.core.comm.CommOp` and
states what the op's message physically is on the wire — shape, dtype,
codec, and the packed bit count the ledger should bill.  Scalar control
messages carry no descriptor and default to one 32-bit word per unit
(:data:`~repro.core.wire.codecs.UNIT_BITS`), which keeps the bits column
consistent with the paper's unit convention everywhere a real payload
does not travel.

``WirePayload.of`` computes the bits from the codec contract (exact for
shape-determined codecs); ``WirePayload.measured`` records an
already-encoded payload's actual packed length (the varint round-2
uploads), so the schedule bills precisely what
:meth:`~repro.core.faults.Transport.ship` later puts on the wire —
that is what lets the benchmark reconcile bills against receipts to
the bit.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Tuple

import numpy as np

from repro.core.wire.codecs import get_codec


@dataclasses.dataclass(frozen=True)
class WirePayload:
    """What one scheduled message physically carries."""

    shape: Tuple[int, ...]
    dtype: str
    codec: str
    bits: int

    def __post_init__(self) -> None:
        if self.bits < 0:
            raise ValueError(f"negative wire bits: {self.bits}")

    @staticmethod
    def of(shape, dtype, codec: str) -> "WirePayload":
        """Descriptor with bits from the codec contract (shape-determined
        codecs: exact; varint integer payloads: certified upper bound)."""
        shape = tuple(int(s) for s in shape)
        dt = np.dtype(dtype).name
        return WirePayload(shape, dt, codec,
                           get_codec(codec).wire_bits(shape, dt))

    @staticmethod
    def measured(shape, dtype, codec: str, bits: int) -> "WirePayload":
        """Descriptor for a payload that was actually encoded: ``bits`` is
        the measured packed length (``8 * len(blob)``)."""
        return WirePayload(tuple(int(s) for s in shape),
                           np.dtype(dtype).name, codec, int(bits))


def fmt_bits(bits: int) -> str:
    """Human-readable wire size: raw bits below 1 KiB, then KiB/MiB."""
    nbytes = bits / 8.0
    if nbytes >= (1 << 20):
        return f"{nbytes / (1 << 20):.2f}MiB"
    if nbytes >= (1 << 10):
        return f"{nbytes / (1 << 10):.2f}KiB"
    return f"{int(bits)}b"


def encode_payloads(
    codec: str, payloads: Mapping[int, np.ndarray],
) -> Tuple[Dict[int, bytes], Dict[int, int]]:
    """Encode a per-party payload map once, up front.

    Returns ``(blobs, bits)`` keyed like ``payloads``.  The executor
    builds the round-2 schedule from ``bits`` (measured, not modeled) and
    hands ``blobs`` to :meth:`Transport.ship` so the bytes billed are the
    bytes sealed — encode exactly once per payload."""
    c = get_codec(codec)
    blobs = {j: c.encode(arr) for j, arr in payloads.items()}
    return blobs, {j: 8 * len(b) for j, b in blobs.items()}
