"""Wire codecs: real packed-byte encodings for the protocol's payloads.

The paper's ledger counts abstract units (one per transported float/int);
this module is what turns those units into an honest bytes-on-the-wire
number.  Every codec round-trips through REAL packed bytes — ``encode``
returns the byte string that would cross the wire, ``decode`` reconstructs
the array the receiver would see, and ``wire_bits(shape, dtype)`` states
the packed size up front so the planner can bill a message before it is
ever built.  ``wire_bits`` is a contract, not an estimate: for the
shape-determined codecs it equals ``8 * len(encode(x))`` exactly for every
``x`` of that shape/dtype (property-tested in ``tests/test_wire.py``);
for the value-dependent varint path it is a guaranteed upper bound and the
ledger bills the measured packed length instead.

Two payload families cross the wire (Compressed-VFL, Castiglia et al.,
motivates quantizing both):

* round-1 mass tables — float32 rows, one per party: per-row sensitivity
  scores (materialized engine) or per-block masses (streamed/pipelined);
* round-2 index uploads — int32 row indices, one vector per party.

Float payloads go through the named quantizer; integer payloads are
always LOSSLESS (a wrong index is a different coreset, not a noisier
one): ``raw_fp32`` ships them as packed int32 words, every compressed
codec ships them zigzag-delta varint encoded.

Tolerance contract (float payloads, per entry, relative to the payload's
absmax):  ``|decode(encode(x)) - x| <= tolerance * max|x|``.

============== ========== ===================== =======================
codec          tolerance  float payload          int payload
============== ========== ===================== =======================
raw_fp32       0 (exact)  4 B/entry             4 B/entry (int32 words)
fp16           2**-10     4 B + 2 B/entry       varint (<= 5 B/entry)
int8_blockscale1/127      4 B/64-block + 1 B/e  varint (<= 5 B/entry)
delta_varint   2**-10     fp16 scheme           varint (<= 5 B/entry)
============== ========== ===================== =======================

This module is numpy-only by design — it sits below ``repro.core.comm``
and must import nothing from the rest of the package.
"""

from __future__ import annotations

import math
from typing import Dict, Sequence, Tuple

import numpy as np

#: One ledger unit (a transported float/int, paper Section 2) is one
#: 32-bit word on the raw wire — the conversion the bits column defaults
#: to for scalar control messages that carry no payload descriptor.
UNIT_BITS = 32

#: Per-block quantization group for the int8 codec (absmax scale / block).
INT8_BLOCK = 64

#: Worst-case varint bytes for one zigzag-delta-encoded int32 index.
VARINT_MAX_BYTES_I32 = 5


def _is_int(dtype) -> bool:
    kind = np.dtype(dtype).kind
    if kind in "iu":
        return True
    if kind == "f":
        return False
    raise ValueError(f"wire codecs carry float/int payloads only, got {dtype}")


# --------------------------------------------------------------------------
# shared integer paths
# --------------------------------------------------------------------------

def _raw_i32_encode(arr: np.ndarray) -> bytes:
    v = np.ascontiguousarray(arr)
    if v.size and (v.min() < np.iinfo(np.int32).min
                   or v.max() > np.iinfo(np.int32).max):
        raise ValueError(
            "raw wire ships indices as int32 words; payload exceeds int32 "
            f"range (min={v.min()}, max={v.max()})"
        )
    return v.astype("<i4").tobytes()


def _raw_i32_decode(blob: bytes, shape: Tuple[int, ...], dtype) -> np.ndarray:
    return np.frombuffer(blob, "<i4").reshape(shape).astype(dtype)


def _varint_encode(arr: np.ndarray) -> bytes:
    """Zigzag delta varint: lossless, order-preserving, value-dependent size."""
    out = bytearray()
    prev = 0
    for v in np.asarray(arr, np.int64).ravel().tolist():
        d = v - prev
        prev = v
        u = d * 2 if d >= 0 else -d * 2 - 1
        while True:
            b = u & 0x7F
            u >>= 7
            if u:
                out.append(b | 0x80)
            else:
                out.append(b)
                break
    return bytes(out)


def _varint_decode(blob: bytes, shape: Tuple[int, ...], dtype) -> np.ndarray:
    vals = []
    acc = 0
    cur = 0
    shift = 0
    for b in blob:
        cur |= (b & 0x7F) << shift
        if b & 0x80:
            shift += 7
        else:
            d = cur // 2 if cur % 2 == 0 else -((cur + 1) // 2)
            acc += d
            vals.append(acc)
            cur = 0
            shift = 0
    if cur or shift:
        raise ValueError("truncated varint payload")
    out = np.asarray(vals, np.int64).reshape(shape)
    return out.astype(dtype)


def _varint_max_bits(size: int) -> int:
    return size * VARINT_MAX_BYTES_I32 * 8


# --------------------------------------------------------------------------
# codec protocol + concrete codecs
# --------------------------------------------------------------------------

class Codec:
    """One wire format: named, tolerance-documented, byte-measured.

    Subclasses implement the float payload path; the integer path is the
    shared lossless machinery above (raw int32 words or zigzag-delta
    varint, per ``int_varint``)."""

    name: str = ""
    #: per-entry round-trip error bound relative to the payload absmax
    #: (float payloads; integer payloads are always exact)
    tolerance: float = 0.0
    #: True when decode(encode(x)) reproduces x bit-for-bit (float32 domain)
    lossless: bool = True
    #: compressed codecs varint their integer payloads; raw ships i32 words
    int_varint: bool = False

    # -- float payload path (subclass responsibility) ----------------------
    def _encode_f(self, x: np.ndarray) -> bytes:
        raise NotImplementedError

    def _decode_f(self, blob: bytes, shape: Tuple[int, ...]) -> np.ndarray:
        raise NotImplementedError

    def _float_bits(self, size: int) -> int:
        raise NotImplementedError

    # -- public protocol ---------------------------------------------------
    def encode(self, arr) -> bytes:
        a = np.asarray(arr)
        if _is_int(a.dtype):
            return (_varint_encode(a) if self.int_varint
                    else _raw_i32_encode(a))
        return self._encode_f(np.ascontiguousarray(a, np.float32))

    def decode(self, blob: bytes, shape: Sequence[int], dtype) -> np.ndarray:
        shape = tuple(int(s) for s in shape)
        if _is_int(dtype):
            return (_varint_decode(blob, shape, dtype) if self.int_varint
                    else _raw_i32_decode(blob, shape, dtype))
        return self._decode_f(blob, shape)

    def wire_bits(self, shape: Sequence[int], dtype) -> int:
        """Packed size of any payload of ``(shape, dtype)``: exact where
        :meth:`bits_exact`, else a guaranteed upper bound (varint ints)."""
        size = int(np.prod([int(s) for s in shape], dtype=np.int64)) \
            if len(tuple(shape)) else 1
        if _is_int(dtype):
            return _varint_max_bits(size) if self.int_varint else 32 * size
        return self._float_bits(size)

    def bits_exact(self, dtype) -> bool:
        """True when ``wire_bits`` equals the packed length for EVERY value
        of that dtype (the property the ledger reconciliation relies on)."""
        return not (self.int_varint and _is_int(dtype))

    def exact_for(self, dtype) -> bool:
        """True when decode(encode(x)) reproduces x's VALUES exactly for
        this dtype — integer payloads are exact under every codec (indices
        are never quantized), floats only under the lossless ones."""
        return self.lossless or _is_int(dtype)


class RawFP32(Codec):
    """The unit convention made literal: one 32-bit word per float/int.

    Lossless for the float32 wire domain — the default codec, pinned
    draw- and ledger-identical to the uncompressed protocol."""

    name = "raw_fp32"
    tolerance = 0.0
    lossless = True
    int_varint = False

    def _encode_f(self, x: np.ndarray) -> bytes:
        return x.astype("<f4").tobytes()

    def _decode_f(self, blob: bytes, shape: Tuple[int, ...]) -> np.ndarray:
        return np.frombuffer(blob, "<f4").reshape(shape).astype(np.float32)

    def _float_bits(self, size: int) -> int:
        return 32 * size


class FP16(Codec):
    """Scaled half precision: one float32 scale (absmax / 32768) + fp16
    mantissas.  The scale keeps every entry inside fp16's exactly-normal
    range, so the per-entry error is <= 2**-11 of the entry's magnitude;
    tolerance documents 2**-10 (a 2x margin covering subnormal dust)."""

    name = "fp16"
    tolerance = 2.0 ** -10
    lossless = False
    int_varint = True

    _SPAN = np.float32(32768.0)

    def _scale(self, x: np.ndarray) -> np.float32:
        a = float(np.max(np.abs(x))) if x.size else 0.0
        if not math.isfinite(a) or a == 0.0:
            return np.float32(1.0)
        return np.float32(a) / self._SPAN

    def _encode_f(self, x: np.ndarray) -> bytes:
        s = self._scale(x)
        q = (x.ravel() / s).astype("<f2")
        return s.tobytes() + q.tobytes()

    def _decode_f(self, blob: bytes, shape: Tuple[int, ...]) -> np.ndarray:
        s = np.frombuffer(blob[:4], "<f4")[0]
        q = np.frombuffer(blob[4:], "<f2").astype(np.float32)
        return (q * s).reshape(shape)

    def _float_bits(self, size: int) -> int:
        return 32 + 16 * size


class Int8BlockScale(Codec):
    """Per-block absmax int8: one float32 scale per 64-entry block + one
    signed byte per entry.  Round-trip error is <= scale/2 = absmax_block
    / 254 per entry; tolerance documents 1/127 (2x margin) relative to
    the payload absmax.  ~3.8x smaller than raw_fp32 for long rows."""

    name = "int8_blockscale"
    tolerance = 1.0 / 127.0
    lossless = False
    int_varint = True

    def _encode_f(self, x: np.ndarray) -> bytes:
        v = x.ravel()
        size = v.size
        nb = -(-size // INT8_BLOCK) if size else 0
        pad = nb * INT8_BLOCK - size
        xb = np.pad(v, (0, pad)).reshape(nb, INT8_BLOCK) if nb \
            else v.reshape(0, INT8_BLOCK)
        a = np.max(np.abs(xb), axis=1) if nb else np.zeros((0,), np.float32)
        s = np.where((a > 0) & np.isfinite(a), a / 127.0, 1.0).astype("<f4")
        qf = np.round(xb / s[:, None].astype(np.float32)) if nb else xb
        qf = np.where(np.isfinite(qf), qf, 0.0)
        q = np.clip(qf, -127, 127).astype("<i1").ravel()[:size]
        return s.tobytes() + q.tobytes()

    def _decode_f(self, blob: bytes, shape: Tuple[int, ...]) -> np.ndarray:
        size = int(np.prod(shape, dtype=np.int64)) if shape else 1
        nb = -(-size // INT8_BLOCK) if size else 0
        s = np.frombuffer(blob[:4 * nb], "<f4").astype(np.float32)
        q = np.frombuffer(blob[4 * nb:], "<i1").astype(np.float32)
        pad = nb * INT8_BLOCK - size
        qb = np.pad(q, (0, pad)).reshape(nb, INT8_BLOCK) if nb \
            else q.reshape(0, INT8_BLOCK)
        return (qb * s[:, None]).ravel()[:size].reshape(shape)

    def _float_bits(self, size: int) -> int:
        nb = -(-size // INT8_BLOCK) if size else 0
        return 32 * nb + 8 * size


class DeltaVarint(Codec):
    """Round-2 upload format: zigzag-delta varint indices (lossless —
    a flipped index is a different coreset, never acceptable) plus
    fp16-quantized float payloads ("quantized weights") should a float
    array travel under it.  Used internally by every compressed codec's
    integer path; selectable by name for tests and benchmarks."""

    name = "delta_varint"
    tolerance = FP16.tolerance
    lossless = False
    int_varint = True

    _fp16 = FP16()

    def _encode_f(self, x: np.ndarray) -> bytes:
        return self._fp16._encode_f(x)

    def _decode_f(self, blob: bytes, shape: Tuple[int, ...]) -> np.ndarray:
        return self._fp16._decode_f(blob, shape)

    def _float_bits(self, size: int) -> int:
        return self._fp16._float_bits(size)


#: name -> codec instance (codecs are stateless; one shared instance each)
WIRE_CODECS: Dict[str, Codec] = {
    c.name: c for c in (RawFP32(), FP16(), Int8BlockScale(), DeltaVarint())
}

#: fidelity order for the planner's comm-budget walk: the first codec
#: whose predicted bits fit ``comm_budget_bits`` wins (best tolerance
#: that fits the budget)
CODEC_LADDER: Tuple[str, ...] = ("raw_fp32", "fp16", "int8_blockscale")

#: valid values for ``CoresetSpec.codec`` — the spec names the round-1
#: mass-table format; compressed codecs varint the round-2 uploads
#: automatically (``delta_varint`` is their shared integer path, not a
#: table format, so it is not spec-selectable)
SPEC_CODECS: Tuple[str, ...] = ("auto",) + CODEC_LADDER


def get_codec(name: str) -> Codec:
    try:
        return WIRE_CODECS[name]
    except KeyError:
        raise ValueError(
            f"unknown wire codec {name!r}; known: {sorted(WIRE_CODECS)}"
        ) from None
