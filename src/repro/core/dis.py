"""Algorithm 1 of the paper: the unified Distributed Importance Sampling
(DIS) scheme for coreset construction in the VFL model.

Three communication rounds (star topology, unit accounting per
:mod:`repro.core.comm`):

  round 1:  party j -> server: scalar G^(j) = sum_i g_i^(j)            (T units)
            server samples multiset A ~ Multinomial(m, G^(j)/G)
            server -> party j: a_j = #{j in A}                          (T units)
  round 2:  party j -> server: multiset S^(j) of a_j indices,
            i sampled w.p. g_i^(j)/G^(j)                               (m units)
            server -> all parties: S = union_j S^(j)                 (mT units)
  round 3:  party j -> server: {g_i^(j) : i in S}                     (mT units)
            server: w(i) = G / (|S| * sum_j g_i^(j))

The induced marginal of every sample is exactly g_i/G with
g_i = sum_j g_i^(j) (proof of Thm 3.1), i.e. DIS *simulates* the
Feldman-Langberg importance-sampling framework without any party ever
revealing a raw feature.  Tests verify both the marginal and the ledger
against ``theoretical_dis_cost``.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.comm import CommLedger, null_ledger


def _categorical_counts(key: jax.Array, logits: jax.Array, m: int) -> jax.Array:
    """m iid categorical draws, returned as per-class counts."""
    draws = jax.random.categorical(key, logits, shape=(m,))
    return jnp.bincount(draws, length=logits.shape[0])


def dis_sample(
    key: jax.Array,
    local_scores: List[jax.Array],
    m: int,
    ledger: Optional[CommLedger] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Run Algorithm 1 (DIS).

    Args:
      key: PRNG key.
      local_scores: list over parties; party j's vector g^(j) of shape (n,),
        entries >= 0 with a positive total.
      m: number of samples (with replacement — a multiset, as in the paper).
      ledger: optional CommLedger to account the protocol's traffic.

    Returns:
      (indices, weights): both shape (m,).  ``weights[i] = G/(m * g_{S_i})``.
    """
    led = null_ledger(ledger)
    T = len(local_scores)
    n = int(local_scores[0].shape[0])
    scores = [jnp.asarray(g, jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)
              for g in local_scores]

    # ---- round 1: local totals up, per-party sample counts down -------------
    G_j = jnp.stack([g.sum() for g in scores])                # (T,)
    for j in range(T):
        led.party_to_server("dis/round1/G_j", j, 1)
    G = G_j.sum()
    if not bool(G > 0):
        raise ValueError("DIS requires a positive total score")
    key, sub = jax.random.split(key)
    a = _categorical_counts(sub, jnp.log(jnp.maximum(G_j, 1e-30)), m)  # (T,)
    for j in range(T):
        led.server_to_party("dis/round1/a_j", j, 1)

    # ---- round 2: party-local index sampling, then server union -------------
    # Party j draws a_j iid indices ~ g_i^(j)/G^(j).  To keep everything
    # static-shape/jit-friendly we draw m candidates per party and select the
    # first a_j of each via a mask when concatenating — statistically
    # identical because draws are iid.
    per_party_idx = []
    for j in range(T):
        key, sub = jax.random.split(key)
        logits = jnp.log(jnp.maximum(scores[j], 1e-30))
        per_party_idx.append(jax.random.categorical(sub, logits, shape=(m,)))
    cand = jnp.stack(per_party_idx)                            # (T, m)
    # position p of the flat sample belongs to the party owning that slot:
    owner = jnp.repeat(jnp.arange(T), m).reshape(T, m)
    # build the multiset S by taking a_j entries from party j
    slot = jnp.arange(m)
    take = slot[None, :] < a[:, None]                          # (T, m) bool
    flat_idx = cand.reshape(-1)
    flat_take = take.reshape(-1)
    # stable selection of exactly m entries (sum(a)=m by construction)
    order = jnp.argsort(~flat_take, stable=True)               # taken slots first
    S = flat_idx[order][:m]                                    # (m,)
    # parties collectively send exactly m indices up (sum_j a_j = m)
    led.party_to_server("dis/round2/S_up", 0, m)
    led.broadcast("dis/round2/S_bcast", T, m)                  # S to every party

    # ---- round 3: per-sample local scores up, weights at server ------------
    g_sum_S = jnp.zeros((m,), scores[0].dtype)
    for j in range(T):
        g_sum_S = g_sum_S + scores[j][S]
        led.party_to_server("dis/round3/g_scores", j, m)
    w = G / (m * jnp.maximum(g_sum_S, 1e-30))
    return S, w


def dis_marginals(local_scores: List[jax.Array]) -> jax.Array:
    """The exact per-index sampling marginal g_i/G (used by tests)."""
    g = jnp.sum(jnp.stack(local_scores), axis=0)
    return g / g.sum()


def uniform_sample(
    key: jax.Array, n: int, m: int, T: int, ledger: Optional[CommLedger] = None
) -> Tuple[jax.Array, jax.Array]:
    """Uniform-sampling baseline (the paper's U-*): the server draws m indices
    itself and broadcasts them; weight n/m each.  Cost: mT (broadcast only —
    no scores ever travel, which is why U-* is slightly cheaper)."""
    led = null_ledger(ledger)
    S = jax.random.randint(key, (m,), 0, n)
    led.broadcast("uniform/S_bcast", T, m)
    w = jnp.full((m,), n / m)
    return S, w
