"""Algorithm 1 of the paper: the unified Distributed Importance Sampling
(DIS) scheme for coreset construction in the VFL model.

Three communication rounds (star topology, unit accounting per
:mod:`repro.core.comm`):

  round 1:  party j -> server: scalar G^(j) = sum_i g_i^(j)            (T units)
            server samples multiset A ~ Multinomial(m, G^(j)/G)
            server -> party j: a_j = #{j in A}                          (T units)
  round 2:  party j -> server: multiset S^(j) of a_j indices,
            i sampled w.p. g_i^(j)/G^(j)                               (m units)
            server -> all parties: S = union_j S^(j)                 (mT units)
  round 3:  party j -> server: {g_i^(j) : i in S}                     (mT units)
            server: w(i) = G / (|S| * sum_j g_i^(j))

The induced marginal of every sample is exactly g_i/G with
g_i = sum_j g_i^(j) (proof of Thm 3.1), i.e. DIS *simulates* the
Feldman-Langberg importance-sampling framework without any party ever
revealing a raw feature.  Tests verify both the marginal and the ledger
against ``theoretical_dis_cost``.

Layering (post api_redesign):

  * :func:`dis_plan` / :func:`dis_plan_full` — the PURE protocol core.  The
    party scores enter stacked as one ``(T, n)`` array, there are no Python
    party loops and no ledger mutation, so the function jit-compiles and
    vmaps (over seeds and over a budget grid via the ``m_cap`` masking
    convention).  Accounting is derived afterwards by
    :class:`repro.core.comm.CommSchedule` from ``(T, m)`` and the realised
    round-2 counts ``a_j`` the plan returns.
  * :func:`server_plan` — the one-round server-side variant used when the
    combined scores already live on every shard (the mesh selector's psum
    path: :mod:`repro.core.selector`).
  * :func:`dis_sample` / :func:`uniform_sample` — back-compat wrappers with
    the seed API (list-of-scores in, ledger recorded in place); they produce
    bit-identical ``(S, w)`` for the same PRNG key.
"""

from __future__ import annotations

from typing import List, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.comm import CommLedger, CommSchedule

try:  # the head-draw replay reaches for the threefry primitive directly
    from jax._src.prng import threefry2x32_p as _threefry2x32_p
except ImportError:  # pragma: no cover - jax moved the internal; fall back
    _threefry2x32_p = None


def _float_dtype() -> jnp.dtype:
    return jnp.float64 if jax.config.jax_enable_x64 else jnp.float32


def _key_chain(key: jax.Array, num: int) -> jax.Array:
    """``num`` subkeys from the sequential ``key, sub = split(key)`` chain.

    Matches the seed's per-party key consumption exactly (sub_0 for the
    round-1 counts, sub_1..sub_T for the party draws) while staying a single
    scan — no Python loop, traceable, vmap-able.
    """

    def body(k, _):
        nxt, sub = jax.random.split(k)
        return nxt, sub

    _, subs = jax.lax.scan(body, key, None, length=num)
    return subs


def _categorical_head(key_data, lg, cap: int, take: int):
    """The first ``take`` entries of ``jax.random.categorical(key, lg,
    shape=(cap,))`` WITHOUT materializing the (cap, bs) gumbel tensor.

    Every DIS round-2 sampler in this codebase follows the full-capacity
    candidate-stream convention — draw ``cap`` iid candidates per cell, use
    the first a_c — because static shapes demand it inside jit/vmap.  The
    full draw's uniform bits come from ``threefry_2x32(key, iota(cap*bs))``,
    which pairs counter p with counter p + cap*bs/2 and keeps lane 1 for
    flat positions below the midpoint — so rows [0, take) (flat positions
    [0, take*bs), all below the midpoint when take <= cap//2) are
    reproducible bit for bit from exactly those counter pairs.  The float
    conversion replays ``jax.random._uniform``'s mantissa trick and
    ``gumbel``'s double-log verbatim.  This is what makes the convention
    affordable at streaming scale: a cell that uses a_c of its cap
    candidates only ever *computes* max(a_c) rows
    (:func:`repro.core.streaming.dis_plan_streamed_batched`).
    """
    bs = lg.shape[-1]
    half = (cap * bs) // 2
    x1 = jax.lax.iota(jnp.uint32, take * bs)
    x2 = x1 + jnp.uint32(half)
    bits, _ = _threefry2x32_p.bind(key_data[0], key_data[1], x1, x2)
    float_bits = jax.lax.bitwise_or(
        jax.lax.shift_right_logical(bits, np.uint32(9)),
        np.array(1.0, np.float32).view(np.uint32))
    floats = (jax.lax.bitcast_convert_type(float_bits, jnp.float32)
              - np.float32(1.0))
    tiny = np.float32(np.finfo(np.float32).tiny)
    u = jax.lax.max(tiny, floats * (np.float32(1.0) - tiny) + tiny)
    g = -jnp.log(-jnp.log(u)).reshape(take, bs)
    return jnp.argmax(g + lg[None, :], axis=-1)


def _head_draws_ok(subs, cap: int, bs: int, take: int) -> bool:
    """True when :func:`_categorical_head` provably replays the full draw:
    float32 sampling dtype, even counter stream, head strictly inside the
    first threefry lane, and non-partitionable threefry keys (the layouts
    the replay assumes).  Anything else falls back to the full-capacity
    draw — still one dispatch per group, just cap rows instead of take."""
    if _threefry2x32_p is None or _float_dtype() != jnp.float32:
        return False
    if cap <= 0 or take > cap // 2 or (cap * bs) % 2:
        return False
    if getattr(jax.config, "jax_threefry_partitionable", False):
        return False
    if jnp.issubdtype(subs.dtype, jax.dtypes.prng_key):
        return "threefry" in str(jax.random.key_impl(subs)).lower()
    return getattr(jax.config, "jax_default_prng_impl",
                   "threefry2x32") == "threefry2x32"


class DisPlan(NamedTuple):
    """The result of one DIS execution, accounting-free.

    With ``m_cap`` masking (``m`` traced < ``m_cap``), ``indices``/``weights``
    hold the m real samples as a prefix; the padded tail has weight 0 and
    index 0.
    """

    indices: jax.Array    # (m_cap,) int   — the sampled multiset S
    weights: jax.Array    # (m_cap,) float — w(i) = G / (m * g_i)
    counts: jax.Array     # (T,) int       — realised round-1 a_j (sums to m)
    totals: jax.Array     # (T,) float     — per-party score mass G^(j)


def dis_plan_full(
    key: jax.Array,
    scores: jax.Array,
    m: Union[int, jax.Array],
    m_cap: Optional[int] = None,
    totals: Optional[jax.Array] = None,
) -> DisPlan:
    """Run Algorithm 1 purely: scores ``(T, n)`` in, :class:`DisPlan` out.

    Args:
      key: PRNG key.
      scores: stacked party-local scores g^(j), shape (T, n), entries >= 0
        with a positive total (NOT checked here — the core stays trace-safe;
        wrappers validate host-side).
      m: number of samples (with replacement).  May be a traced int32 scalar
        when ``m_cap`` is given.
      m_cap: static draw capacity for the masked/batched path.  When None
        (or equal to a static ``m``) the plan is bit-identical to the seed's
        ``dis_sample`` for the same key.
      totals: optional precomputed per-party mass ``sum_i g_i^(j)`` (T,).
        The batched builder passes the eagerly-reduced totals of hoisted
        scores here: XLA lowers the (T, n) -> (T,) reduction with a
        different accumulation order inside a vmapped program than in the
        standalone eager kernel, and since every weight carries G = sum_j
        G^(j), reusing the eager reduction keeps batched cells bit-identical
        to sequential builds.

    Returns:
      DisPlan — no ledger is touched; derive the bill afterwards with
      ``CommSchedule.dis(T, m, counts=plan.counts)``.
    """
    T, _ = scores.shape
    scores = scores.astype(_float_dtype())
    static_m = m_cap is None or (isinstance(m, int) and int(m) == int(m_cap))
    cap = int(m) if m_cap is None else int(m_cap)
    valid = jnp.arange(cap) < m                                # all True if static

    subs = _key_chain(key, T + 1)
    G_j = (jnp.sum(scores, axis=1) if totals is None
           else totals.astype(_float_dtype()))                 # (T,)
    G = G_j.sum()

    # ---- round 1: a ~ Multinomial(m, G_j/G), realised as m iid draws --------
    draws = jax.random.categorical(
        subs[0], jnp.log(jnp.maximum(G_j, 1e-30)), shape=(cap,)
    )
    a = jnp.zeros((T,), jnp.int32).at[draws].add(valid.astype(jnp.int32))

    # ---- round 2: party-local index sampling, then server union -------------
    # Party j draws a_j iid indices ~ g_i^(j)/G^(j).  To keep everything
    # static-shape we draw `cap` candidates per party and select the first
    # a_j of each via a mask when concatenating — statistically identical
    # because draws are iid.
    logits = jnp.log(jnp.maximum(scores, 1e-30))               # (T, n)
    cand = jax.vmap(
        lambda k, lg: jax.random.categorical(k, lg, shape=(cap,))
    )(subs[1:], logits)                                        # (T, cap)
    take = jnp.arange(cap)[None, :] < a[:, None]               # (T, cap) bool
    # stable selection of exactly m entries (sum(a) = m by construction)
    order = jnp.argsort(~take.reshape(-1), stable=True)        # taken slots first
    S = cand.reshape(-1)[order][:cap]                          # (cap,)

    # ---- round 3: per-sample local scores up, weights at server -------------
    # Sequential per-party accumulation (scan) keeps the float addition order
    # identical to the seed's Python loop.
    def add_party(acc, g_row):
        return acc + g_row[S], None

    g_sum_S, _ = jax.lax.scan(add_party, jnp.zeros((cap,), scores.dtype), scores)
    w = G / (m * jnp.maximum(g_sum_S, 1e-30))
    if not static_m:
        S = jnp.where(valid, S, 0)
        w = jnp.where(valid, w, 0.0)
    return DisPlan(S, w, a, G_j)


def split_uploads(indices, counts):
    """Recover the round-2 per-party uploads from a realized plan.

    The realized sample ``S`` is party-major (round 2 concatenates party
    j's a_j draws in party order — in :func:`dis_plan_full` the stable
    argsort keeps taken slots in row-major (party, slot) order), so party
    j's upload is the j-th contiguous slice of length ``counts[j]``.  These
    are exactly the payloads the integrity envelopes seal on the
    ``dis/round2/S_up`` message.  Host-side numpy; returns a list of
    (a_j,) arrays whose concatenation is ``indices``."""
    idx = np.asarray(indices)
    c = np.asarray(counts, dtype=np.int64)
    if int(c.sum()) != idx.shape[0]:
        raise ValueError(
            f"counts sum to {int(c.sum())} but the plan realized "
            f"{idx.shape[0]} indices; uploads cannot be attributed")
    return np.split(idx, np.cumsum(c)[:-1])


def blocked_geometry(n: int, block_size: int) -> Tuple[int, int]:
    """(num_blocks nb, rows-per-block bs) for a ``block_size`` row chunking —
    delegates to the canonical :func:`repro.core.vfl.block_geometry`, so the
    sampler's cell grid and ``VFLDataset.block``'s chunking can never drift.
    ``block_size >= n`` degenerates to ONE unpadded block — the regime where
    :func:`dis_plan_blocked` is bit-identical to :func:`dis_plan_full`.
    """
    from repro.core.vfl import block_geometry

    return block_geometry(n, block_size)


def dis_plan_blocked(
    key: jax.Array,
    scores: jax.Array,
    m: Union[int, jax.Array],
    block_size: int,
    m_cap: Optional[int] = None,
) -> DisPlan:
    """Hierarchical (two-level) DIS: Algorithm 1 applied recursively to
    (party, row-block) cells.

    Round 1 samples *cells* (j, b) from the block masses
    G^(j,b) = sum_{i in block b} g_i^(j); round 2 samples a row within the
    chosen cell ~ g_i^(j)/G^(j,b).  The induced marginal telescopes,

        P(i via j) = (G^(j,b(i))/G) * (g_i^(j)/G^(j,b(i))) = g_i^(j)/G,

    i.e. EXACTLY the flat plan's marginal (:func:`dis_blocked_marginals`
    verifies this cancellation numerically) — the blocking is invisible to
    Theorem 3.1.  What it buys: the sampler only ever needs block masses
    (T, nb) plus the scores of *touched* blocks, so the streaming builder
    (:mod:`repro.core.streaming`) never materializes the (T, n) score
    matrix.  This in-memory variant takes the full scores (it is the
    semantic oracle the streamed path is tested against) and consumes a
    ``T*nb + 1``-subkey chain; with ``block_size >= n`` that chain, the cell
    masses, and every draw coincide with :func:`dis_plan_full` bit for bit.
    """
    T, n = scores.shape
    scores = scores.astype(_float_dtype())
    nb, bs = blocked_geometry(n, block_size)
    static_m = m_cap is None or (isinstance(m, int) and int(m) == int(m_cap))
    cap = int(m) if m_cap is None else int(m_cap)
    valid = jnp.arange(cap) < m

    npad = nb * bs
    sp = jnp.pad(scores, ((0, 0), (0, npad - n))).reshape(T, nb, bs)
    row_ok = (jnp.arange(npad) < n).reshape(nb, bs)            # (nb, bs)

    ncells = T * nb
    subs = _key_chain(key, ncells + 1)
    masses = jnp.sum(sp, axis=2)                               # (T, nb)
    G = masses.sum()

    # ---- round 1: cells ~ Multinomial(m, G_jb/G) ----------------------------
    draws = jax.random.categorical(
        subs[0], jnp.log(jnp.maximum(masses.reshape(-1), 1e-30)), shape=(cap,)
    )
    a_cells = jnp.zeros((ncells,), jnp.int32).at[draws].add(valid.astype(jnp.int32))

    # ---- round 2: within-cell row sampling, then server union ---------------
    # Padded rows get -inf logits (probability exactly 0); valid rows keep the
    # flat plan's 1e-30 floor.  Cells are ordered party-major (j*nb + b), so
    # nb == 1 reproduces dis_plan_full's per-party candidate streams.
    cell_logits = jnp.where(
        row_ok[None, :, :], jnp.log(jnp.maximum(sp, 1e-30)), -jnp.inf
    ).reshape(ncells, bs)
    cand_local = jax.vmap(
        lambda k, lg: jax.random.categorical(k, lg, shape=(cap,))
    )(subs[1:], cell_logits)                                   # (ncells, cap)
    offsets = jnp.tile(jnp.arange(nb) * bs, T)                 # cell -> row base
    cand = cand_local + offsets[:, None]
    take = jnp.arange(cap)[None, :] < a_cells[:, None]
    order = jnp.argsort(~take.reshape(-1), stable=True)        # taken slots first
    S = cand.reshape(-1)[order][:cap]

    # ---- round 3: per-sample combined scores, weights at server -------------
    def add_party(acc, g_row):
        return acc + g_row[S], None

    g_sum_S, _ = jax.lax.scan(add_party, jnp.zeros((cap,), scores.dtype), scores)
    w = G / (m * jnp.maximum(g_sum_S, 1e-30))
    if not static_m:
        S = jnp.where(valid, S, 0)
        w = jnp.where(valid, w, 0.0)
    a = a_cells.reshape(T, nb).sum(axis=1)                     # per-party a_j
    return DisPlan(S, w, a, masses.sum(axis=1))


def dis_blocked_marginals(
    local_scores: List[jax.Array], block_size: int
) -> np.ndarray:
    """The exact per-index marginal induced by :func:`dis_plan_blocked`,
    computed WITHOUT algebraic simplification (float64): sum over cells of
    P(cell) * P(i | cell).  Tests assert this telescopes back to the flat
    :func:`dis_marginals` — the hierarchical sampler's correctness claim."""
    g = np.stack([np.asarray(x, np.float64) for x in local_scores])  # (T, n)
    T, n = g.shape
    nb, bs = blocked_geometry(n, block_size)
    gp = np.pad(g, ((0, 0), (0, nb * bs - n))).reshape(T, nb, bs)
    masses = gp.sum(axis=2)                                    # (T, nb)
    G = masses.sum()
    within = gp / np.maximum(masses[:, :, None], np.finfo(np.float64).tiny)
    per_cell = (masses[:, :, None] / G) * within               # (T, nb, bs)
    return per_cell.reshape(T, -1)[:, :n].sum(axis=0)


def dis_plan(
    key: jax.Array,
    scores: jax.Array,
    m: Union[int, jax.Array],
    m_cap: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Pure DIS core: ``(key, scores (T, n), m) -> (S, w)``.

    jit with ``static_argnums=2`` (or pass a traced ``m`` plus static
    ``m_cap``), vmap over keys and/or budgets freely.
    """
    plan = dis_plan_full(key, scores, m, m_cap=m_cap)
    return plan.indices, plan.weights


def server_plan(
    key: jax.Array, g: jax.Array, m: int
) -> Tuple[jax.Array, jax.Array]:
    """One-round server-side DIS: m categorical draws ~ g/G with importance
    weights G/(m*g_S).

    This is the degenerate T=1 view of Algorithm 1, used when the combined
    scores g already live at the sampler — the mesh selector after its psum
    (rounds 1+3 collapse into the all-reduce, round 2's broadcast into the
    shared key).
    """
    G = jnp.sum(g)
    S = jax.random.categorical(key, jnp.log(jnp.maximum(g, 1e-30)), shape=(m,))
    w = G / (m * jnp.maximum(g[S], 1e-30))
    return S, w


def uniform_plan(
    key: jax.Array,
    n: int,
    m: Union[int, jax.Array],
    m_cap: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Pure uniform baseline: m server-side uniform indices, weight n/m."""
    static_m = m_cap is None or (isinstance(m, int) and int(m) == int(m_cap))
    cap = int(m) if m_cap is None else int(m_cap)
    S = jax.random.randint(key, (cap,), 0, n)
    if static_m:
        return S, jnp.full((cap,), n / m)
    valid = jnp.arange(cap) < m
    return jnp.where(valid, S, 0), jnp.where(valid, n / m, 0.0)


# --------------------------------------------------------------------------
# Back-compat wrappers (seed API): list-of-scores in, ledger recorded here
# --------------------------------------------------------------------------

def dis_sample(
    key: jax.Array,
    local_scores: List[jax.Array],
    m: int,
    ledger: Optional[CommLedger] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Run Algorithm 1 (DIS) — seed-compatible wrapper over :func:`dis_plan`.

    Args:
      key: PRNG key.
      local_scores: list over parties; party j's vector g^(j) of shape (n,),
        entries >= 0 with a positive total.
      m: number of samples (with replacement — a multiset, as in the paper).
      ledger: optional CommLedger to account the protocol's traffic.

    Returns:
      (indices, weights): both shape (m,).  ``weights[i] = G/(m * g_{S_i})``.
    """
    T = len(local_scores)
    scores = jnp.stack([jnp.asarray(g) for g in local_scores])
    plan = dis_plan_full(key, scores, int(m))
    if not bool(plan.totals.sum() > 0):
        raise ValueError("DIS requires a positive total score")
    CommSchedule.dis(T, int(m), counts=np.asarray(plan.counts)).record(ledger)
    return plan.indices, plan.weights


def dis_marginals(local_scores: List[jax.Array]) -> jax.Array:
    """The exact per-index sampling marginal g_i/G (used by tests)."""
    g = jnp.sum(jnp.stack(local_scores), axis=0)
    return g / g.sum()


def uniform_sample(
    key: jax.Array, n: int, m: int, T: int, ledger: Optional[CommLedger] = None
) -> Tuple[jax.Array, jax.Array]:
    """Uniform-sampling baseline (the paper's U-*): the server draws m indices
    itself and broadcasts them; weight n/m each.  Cost: mT (broadcast only —
    no scores ever travel, which is why U-* is slightly cheaper)."""
    S, w = uniform_plan(key, n, int(m))
    CommSchedule.uniform(T, int(m)).record(ledger)
    return S, w
