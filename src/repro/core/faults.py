"""Party fault model + the transport seam: fault-tolerant VFL rounds.

Every protocol in this repo assumed the paper's idealized network: all T
parties answer every round instantly and correctly.  Real VFL deployments
see dropped messages, stragglers, and parties that disappear mid-round
(the first-order applicability gap the VFL survey calls out; Compressed-VFL
shows the statistical machinery tolerates imperfect messages).  This module
is the seam every layer injects faults through:

  * :class:`FaultPlan` — a deterministic, seeded chaos specification.  Each
    logical message's fate is a pure function of ``(fault_seed, round_tag,
    party, attempt)`` via the threefry PRNG (``jax.random.fold_in`` on a
    stable CRC of the tag), so a chaos run is exactly replayable: the same
    plan yields the same drops, the same retry counts, the same ledger — on
    every run, on every machine.  Per-party rate overrides model asymmetric
    links (one flaky party, the rest healthy).
  * :class:`Transport` — delivers a :class:`~repro.core.comm.CommSchedule`
    op by op.  A failed attempt (drop, detected corruption, or a simulated
    delay exceeding the per-attempt timeout) is RETRANSMITTED up to
    ``max_retries`` times with capped exponential backoff; every
    retransmission-causing attempt is billed on the ledger under a
    ``retry/<tag>`` entry with the message's full unit cost, so the
    composed bill stays exact under faults (base tags bill exactly the
    fault-free schedule; ``ledger.by_prefix("retry/")`` is exactly the
    retransmission overhead).  With a null plan the delivery is
    bit-identical to ``schedule.record(ledger)`` — same entries, same
    order — which the fault-free pinning tests assert.
  * :exc:`PartyUnavailable` / :class:`DegradedBuild` — what happens when a
    party exhausts its retries.  Under ``fault_policy="fail"`` or
    ``"retry"`` the build raises; under ``"degrade"`` the scoring round
    drops the party, the build continues over the surviving feature slices
    (sensitivities recomputed over the present parties), and the returned
    coreset carries a :class:`DegradedBuild` receipt naming the dropped
    parties/rounds and the widened sensitivity bound.
  * :class:`StreamCheckpoint` — per-superchunk checkpoint of a streaming /
    pipelined build's accumulator state (Gram / cluster stats / mass-table
    columns + the completed-chunk counter), so a crashed build resumes at
    the last completed superchunk and finishes DRAW-IDENTICALLY to an
    uninterrupted run (the accumulators are restored bitwise; the threefry
    key chain is untouched by the scan, so the DIS draw cannot drift).

Simulated time: the transport never sleeps by default — delays, timeouts
and backoff accumulate in ``TransportStats.sim_time_s`` so chaos tests run
at full speed while latency accounting stays exact.
"""

from __future__ import annotations

import dataclasses
import functools
import zlib
from typing import Any, Dict, Mapping, Optional, Tuple, Union

import numpy as np

from repro.core.comm import CommLedger, CommSchedule
from repro.core.integrity import WireEnvelope
from repro.core.wire import UNIT_BITS, get_codec

FAULT_POLICIES = ("fail", "retry", "degrade", "quarantine")


# --------------------------------------------------------------------------
# The time seam: one Clock shared by deadlines and the fault plan's
# simulated delays, so "a slow party eats the request's time budget" is a
# single consistent statement in both real and simulated time.
# --------------------------------------------------------------------------

class Clock:
    """Abstract monotonic time source.

    :class:`WallClock` reads the process monotonic clock (``advance`` is a
    no-op: real time passes on its own; simulated fault delays are *never*
    slept, only accounted).  :class:`SimClock` is fully simulated — a
    :class:`Transport` bound to it pushes its fault delays and backoffs
    into the same timeline deadline checks read, so chaos tests exercise
    deadline pressure deterministically at full speed.
    """

    def now(self) -> float:
        raise NotImplementedError

    def advance(self, dt: float) -> None:
        raise NotImplementedError


class WallClock(Clock):
    """Real monotonic time.  ``advance`` is deliberately a no-op — wall
    time cannot be pushed forward, and simulated transport delays must not
    turn into real sleeps."""

    def now(self) -> float:
        import time

        return time.monotonic()

    def advance(self, dt: float) -> None:
        return None


class SimClock(Clock):
    """Deterministic simulated time.

    ``tick`` (default 0) is the auto-advance per :meth:`now` read — each
    observation of the clock models one unit of elapsed work, which is what
    makes deadline-at-a-superchunk-boundary tests exact: the k-th boundary
    check happens at precisely ``start + k * tick``.  ``advance`` adds
    simulated delay explicitly (the :class:`Transport` seam calls it for
    fault delays and retry backoffs when bound to this clock).
    """

    def __init__(self, start: float = 0.0, tick: float = 0.0) -> None:
        if not tick >= 0:
            raise ValueError(f"tick must be >= 0, got {tick!r}")
        self._t = float(start)
        self.tick = float(tick)

    def now(self) -> float:
        t = self._t
        self._t += self.tick
        return t

    def advance(self, dt: float) -> None:
        if not dt >= 0:
            raise ValueError(f"cannot advance time backwards (dt={dt!r})")
        self._t += float(dt)

    def peek(self) -> float:
        """The current time WITHOUT consuming an auto-tick."""
        return self._t


class DeadlineExceeded(RuntimeError):
    """An operation ran past its deadline.  Raised at a checkpoint
    boundary (superchunk probes, service admission) — never mid-kernel —
    so the state it interrupts is always rollback-safe."""

    def __init__(self, op: str, at: float, now: float) -> None:
        super().__init__(
            f"{op}: deadline {at:.6g} exceeded at t={now:.6g} "
            f"(over by {now - at:.6g}s)"
        )
        self.op = op
        self.at = float(at)
        self.now = float(now)


@dataclasses.dataclass(frozen=True)
class Deadline:
    """An absolute point on a :class:`Clock` by which an operation must
    finish.  ``expired`` uses >= — a deadline landing EXACTLY on a check
    boundary counts as missed (pinned by the edge-case tests), so budget 0
    always sheds at admission.
    """

    at: float
    budget_s: float = 0.0        # the original relative budget, for receipts

    @staticmethod
    def after(clock: Clock, budget_s: float) -> "Deadline":
        if not budget_s >= 0:
            raise ValueError(f"deadline budget must be >= 0, got {budget_s!r}")
        return Deadline(at=clock.now() + float(budget_s),
                        budget_s=float(budget_s))

    def expired(self, clock: Clock) -> bool:
        return clock.now() >= self.at

    def remaining(self, clock: Clock) -> float:
        return self.at - clock.now()

    def check(self, clock: Clock, op: str) -> None:
        """Raise :exc:`DeadlineExceeded` if the deadline has passed."""
        now = clock.now()
        if now >= self.at:
            raise DeadlineExceeded(op, self.at, now)

#: Silent-corruption flavors: whole-payload sign flip, whole-payload scale
#: inflation, and a single seeded NaN injection.
SILENT_KINDS = ("sign", "scale", "nan")

_Rate = Union[float, Mapping[int, float], Tuple[Tuple[int, float], ...]]


class PartyUnavailable(RuntimeError):
    """A party exhausted its delivery attempts for one protocol message."""

    def __init__(self, party: int, tag: str, attempts: int) -> None:
        super().__init__(
            f"party {party} unavailable: {attempts} attempt(s) at "
            f"{tag!r} all failed"
        )
        self.party = int(party)
        self.tag = tag
        self.attempts = int(attempts)


@dataclasses.dataclass(frozen=True)
class DroppedParty:
    """One party lost during a build: which round's message exhausted its
    retries, and after how many attempts."""

    party: int
    tag: str
    attempts: int


@dataclasses.dataclass(frozen=True)
class DegradedBuild:
    """Receipt of a build that continued without every party.

    ``bound_factor`` is the widened sensitivity bound: the paper's total
    sensitivity sums per-party contributions, so a coreset built from
    ``len(surviving)`` of ``total_parties`` slices guarantees the epsilon
    bound only for the SURVIVING projection — the factor
    ``total_parties / len(surviving)`` is the honest multiplier on the
    guarantee a consumer should assume for the full feature space."""

    dropped: Tuple[DroppedParty, ...]
    surviving: Tuple[int, ...]
    total_parties: int
    reason: str = ""

    @property
    def bound_factor(self) -> float:
        return self.total_parties / max(len(self.surviving), 1)

    def describe(self) -> str:
        drops = ", ".join(
            f"party {d.party} at {d.tag} ({d.attempts} attempts)"
            for d in self.dropped
        )
        base = (
            f"DegradedBuild: {len(self.surviving)}/{self.total_parties} "
            f"parties survived (dropped: {drops}); sensitivity bound "
            f"widened x{self.bound_factor:.2f}"
        )
        return f"{base}; {self.reason}" if self.reason else base


@functools.lru_cache(maxsize=4096)
def _tag_code(tag: str) -> int:
    """Stable 31-bit code of a round tag (CRC32 — Python's ``hash`` is
    salted per process and would break cross-run replay)."""
    return zlib.crc32(tag.encode()) & 0x7FFFFFFF


@functools.lru_cache(maxsize=256)
def _seed_key(seed: int):
    import jax

    return jax.random.PRNGKey(seed)


@functools.lru_cache(maxsize=65536)
def _fault_draw(seed: int, tag: str, party: int, attempt: int) -> Tuple[float, float, float]:
    """The threefry uniforms deciding one attempt's fate — a pure function
    of ``(seed, tag, party, attempt)``, cached so repeated replays (and the
    determinism property tests) never re-dispatch."""
    import jax

    sub = jax.random.fold_in(
        jax.random.fold_in(jax.random.fold_in(_seed_key(seed), _tag_code(tag)),
                           party),
        attempt)
    u = np.asarray(jax.random.uniform(sub, (3,)), np.float64)
    return float(u[0]), float(u[1]), float(u[2])


def _normalize_rate(rate: _Rate, what: str) -> Tuple[float, Tuple[Tuple[int, float], ...]]:
    """(default rate, sorted per-party overrides) with [0, 1] validation."""
    if isinstance(rate, Mapping):
        overrides = tuple(sorted((int(j), float(p)) for j, p in rate.items()))
        default = 0.0
    elif isinstance(rate, tuple):
        overrides = tuple(sorted((int(j), float(p)) for j, p in rate))
        default = 0.0
    else:
        overrides = ()
        default = float(rate)
    for p in (default,) + tuple(p for _, p in overrides):
        if not 0.0 <= p <= 1.0:
            raise ValueError(f"{what} probability must be in [0, 1], got {p}")
    return default, overrides


def perturb_payload(payload: Any, kind: str, u: float) -> np.ndarray:
    """Apply one silent corruption to a payload copy (the original is never
    touched — the honest sender can retransmit it).

    ``sign`` negates every entry; ``scale`` inflates every entry by a
    seeded factor in [10, 1000]; ``nan`` plants a single NaN at the seeded
    position ``int(u * size)``.  Integer payloads (round-2 index uploads)
    cannot hold NaN, so ``nan`` degrades to ``sign`` and ``scale`` uses an
    integer factor.  Every kind changes the payload bytes for any nonzero
    payload, so the envelope digest catches all of them."""
    arr = np.asarray(payload)
    out = arr.copy()
    flat = out.reshape(-1)
    if flat.size == 0:
        return out
    is_float = np.issubdtype(arr.dtype, np.floating)
    if kind == "nan" and not is_float:
        kind = "sign"
    if kind == "sign":
        np.negative(flat, out=flat)
    elif kind == "scale":
        if is_float:
            flat *= np.asarray(10.0 ** (1.0 + 2.0 * u), arr.dtype)
        else:
            flat *= 2 + int(u * 8)
    elif kind == "nan":
        flat[min(int(u * flat.size), flat.size - 1)] = np.nan
    else:
        raise ValueError(f"unknown corruption kind {kind!r}; "
                         f"expected one of {SILENT_KINDS}")
    return out


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Deterministic, seeded per-party fault specification.

    ``drop`` / ``corrupt`` / ``delay`` are probabilities — a scalar applies
    to every party; a ``{party: p}`` mapping overrides per party (parties
    not named get 0).  A delayed message whose simulated delay (uniform in
    ``(0, delay_s]``) exceeds ``timeout_s`` counts as a failed attempt
    exactly like a drop; a shorter delay just accrues simulated latency.
    Corrupt messages are assumed checksum-detected at the receiver, so they
    cost a retransmission like a drop (billed under the same ``retry/``
    tag, counted separately in :class:`TransportStats`).

    ``silent_corrupt`` is the adversarial rate: a silently corrupted
    transmission actually PERTURBS the payload (seeded sign-flip / scale /
    NaN injection via :func:`perturb_payload`) instead of being
    pre-detected.  Whether it is caught depends on the receiver: a
    verifying :class:`Transport` checks the :class:`WireEnvelope` digest
    and retransmits (billed like any retry); an unverifying one delivers
    the damaged bytes — the scenario the value-level validators exist to
    catch.  ``silent_kind`` pins the corruption flavor (one of
    :data:`SILENT_KINDS`); by default the fate draw picks one.  Silent
    fates live in their own ``silent!<tag>`` namespace of the threefry
    chain, so enabling them never perturbs drop/corrupt/delay replay.

    ``max_retries`` bounds retransmissions per message; backoff between
    attempts is capped exponential: ``min(backoff_cap_s, backoff_base_s *
    2**k)`` after the k-th failure (simulated — accrued, never slept).
    """

    seed: int = 0
    drop: _Rate = 0.0
    corrupt: _Rate = 0.0
    delay: _Rate = 0.0
    delay_s: float = 0.05
    timeout_s: float = 0.02
    max_retries: int = 3
    backoff_base_s: float = 0.01
    backoff_cap_s: float = 0.16
    silent_corrupt: _Rate = 0.0
    silent_kind: Optional[str] = None

    def __post_init__(self) -> None:
        d, do = _normalize_rate(self.drop, "drop")
        c, co = _normalize_rate(self.corrupt, "corrupt")
        l, lo = _normalize_rate(self.delay, "delay")
        s, so = _normalize_rate(self.silent_corrupt, "silent_corrupt")
        object.__setattr__(self, "drop", do if do else d)
        object.__setattr__(self, "corrupt", co if co else c)
        object.__setattr__(self, "delay", lo if lo else l)
        object.__setattr__(self, "silent_corrupt", so if so else s)
        if self.silent_kind is not None and self.silent_kind not in SILENT_KINDS:
            raise ValueError(
                f"silent_kind must be one of {SILENT_KINDS} or None, "
                f"got {self.silent_kind!r}")
        if not isinstance(self.seed, int) or isinstance(self.seed, bool):
            raise ValueError(f"seed must be an int, got {self.seed!r}")
        if not isinstance(self.max_retries, int) or self.max_retries < 0:
            raise ValueError(
                f"max_retries must be an int >= 0, got {self.max_retries!r}"
            )
        for name in ("delay_s", "timeout_s", "backoff_base_s", "backoff_cap_s"):
            v = getattr(self, name)
            if not v >= 0:
                raise ValueError(f"{name} must be >= 0, got {v!r}")

    @staticmethod
    def none() -> "FaultPlan":
        """The null plan: every message delivered first try — transport
        delivery through it is bit-identical to ``schedule.record``."""
        return FaultPlan()

    def rate(self, kind: str, party: int) -> float:
        r = getattr(self, kind)
        if isinstance(r, tuple):
            for j, p in r:
                if j == party:
                    return p
            return 0.0
        return float(r)

    @property
    def is_null(self) -> bool:
        def _any(r) -> bool:
            if isinstance(r, tuple):
                return any(p > 0 for _, p in r)
            return r > 0
        return not (_any(self.drop) or _any(self.corrupt) or _any(self.delay)
                    or _any(self.silent_corrupt))

    def silent_fate(self, tag: str, party: int, attempt: int
                    ) -> Optional[Tuple[str, float]]:
        """None, or ``(kind, u)`` for a silently corrupted transmission.

        Drawn from a SEPARATE fate namespace (``silent!<tag>``) so enabling
        silent corruption never shifts the drop/corrupt/delay chain (the
        chaos replay pins), and a zero rate consumes no draws at all."""
        p = self.rate("silent_corrupt", party)
        if p == 0.0:
            return None
        u_hit, u_kind, u_mag = _fault_draw(self.seed, "silent!" + tag,
                                           party, attempt)
        if u_hit >= p:
            return None
        kind = self.silent_kind
        if kind is None:
            kind = SILENT_KINDS[min(int(u_kind * len(SILENT_KINDS)),
                                    len(SILENT_KINDS) - 1)]
        return kind, float(u_mag)

    def decide(self, tag: str, party: int, attempt: int) -> "FaultEvent":
        """The fate of delivery attempt ``attempt`` of message ``tag`` to/from
        ``party`` — deterministic (threefry on the plan's seed), replayable."""
        p_drop = self.rate("drop", party)
        p_corrupt = self.rate("corrupt", party)
        p_delay = self.rate("delay", party)
        if p_drop == p_corrupt == p_delay == 0.0:
            return FaultEvent("ok", 0.0)
        u_drop, u_corrupt, u_delay = _fault_draw(self.seed, tag, party, attempt)
        if u_drop < p_drop:
            return FaultEvent("drop", 0.0)
        if u_corrupt < p_corrupt:
            return FaultEvent("corrupt", 0.0)
        if p_delay > 0.0 and u_delay < p_delay:
            # deterministic magnitude: the sub-uniform position within the
            # delay event, scaled to (0, delay_s]
            d = (u_delay / p_delay) * self.delay_s
            if d > self.timeout_s:
                return FaultEvent("timeout", self.timeout_s)
            return FaultEvent("ok", d)
        return FaultEvent("ok", 0.0)

    def backoff_s(self, failures: int) -> float:
        """Capped exponential backoff after the ``failures``-th failed
        attempt (1-indexed)."""
        return min(self.backoff_cap_s,
                   self.backoff_base_s * (2.0 ** max(failures - 1, 0)))


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One attempt's outcome: ``status`` in ok|drop|corrupt|timeout plus the
    simulated latency the attempt accrued."""

    status: str
    delay_s: float

    @property
    def ok(self) -> bool:
        return self.status == "ok"


@dataclasses.dataclass
class TransportStats:
    """Cumulative census of everything a :class:`Transport` delivered."""

    attempts: int = 0
    delivered: int = 0
    retries: int = 0
    drops: int = 0
    corrupts: int = 0
    timeouts: int = 0
    exhausted: int = 0
    units_base: int = 0
    units_retried: int = 0
    bits_base: int = 0
    bits_retried: int = 0
    sim_time_s: float = 0.0
    silent_corrupts: int = 0
    silent_detected: int = 0

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class DeliveryReport:
    """The outcome of delivering one :class:`~repro.core.comm.CommSchedule`.

    ``failed`` maps party -> :class:`DroppedParty` for parties that
    exhausted their retries (only possible with ``drop_on_exhaust=True``;
    otherwise delivery raises).  ``units`` is the total billed — base
    schedule plus every retransmission."""

    units_base: int
    units_retried: int
    retries: int
    failed: Mapping[int, DroppedParty]
    sim_time_s: float
    bits_base: int = 0
    bits_retried: int = 0

    @property
    def units(self) -> int:
        return self.units_base + self.units_retried

    @property
    def bits(self) -> int:
        """Packed wire bits billed — base schedule plus retransmissions."""
        return self.bits_base + self.bits_retried


class Transport:
    """The delivery seam between a :class:`CommSchedule` and its ledger.

    ``deliver`` walks the schedule's ops in order.  Each op is attempted up
    to ``1 + max_retries`` times (``max_retries=0`` under
    ``fault_policy="fail"``): the successful transmission bills the op
    under its own tag (so base-tag totals are EXACTLY the fault-free
    bill), and every failed transmission bills the op's full units under
    ``retry/<tag>`` — retransmissions are real traffic and the composed
    bill stays exact.  Ledger entry order is chronological (failures
    before the success), which degenerates to exactly
    ``schedule.record(ledger)`` when no fault fires.

    One transport instance accumulates :class:`TransportStats` across every
    schedule it delivers (a build, a tree's lifetime, a whole service), so
    the chaos benchmark reads retry counts and simulated latency off the
    same object it injected.
    """

    def __init__(self, plan: Optional[FaultPlan] = None, *,
                 verify: bool = True, clock: Optional[Clock] = None) -> None:
        self.plan = plan if plan is not None else FaultPlan.none()
        self.stats = TransportStats()
        # verify=False models an undefended receiver: silently corrupted
        # payloads shipped through this transport are DELIVERED as-is
        self.verify = bool(verify)
        # clock binding: simulated delays/backoffs ADVANCE this clock in
        # addition to accruing in stats.sim_time_s, so deadline checks and
        # fault latency share one timeline (a no-op on WallClock)
        self.clock = clock

    def _accrue(self, dt: float) -> None:
        self.stats.sim_time_s += dt
        if self.clock is not None and dt:
            self.clock.advance(dt)

    def deliver(
        self,
        schedule: CommSchedule,
        ledger: Optional[CommLedger] = None,
        *,
        max_retries: Optional[int] = None,
        drop_on_exhaust: bool = False,
    ) -> DeliveryReport:
        """Deliver every op; returns the report.  ``max_retries`` overrides
        the plan's (``0`` = fail-fast, the ``fault_policy="fail"`` mode).
        ``drop_on_exhaust=True`` (the ``degrade`` scoring round) records an
        exhausted party in ``report.failed`` and SKIPS its remaining ops in
        this schedule instead of raising :exc:`PartyUnavailable`."""
        plan = self.plan
        retries_cap = plan.max_retries if max_retries is None else int(max_retries)
        stats = self.stats
        failed: Dict[int, DroppedParty] = {}
        units_base = 0
        units_retried = 0
        bits_base = 0
        bits_retried = 0
        retries = 0
        sim0 = stats.sim_time_s
        for op in schedule.ops:
            if op.party in failed:
                continue                     # the party is gone for this round
            attempts = 0
            while True:
                ev = plan.decide(op.tag, op.party, attempts)
                attempts += 1
                stats.attempts += 1
                self._accrue(ev.delay_s)
                if ev.ok:
                    if ledger is not None:
                        if op.down:
                            ledger.server_to_party(op.tag, op.party, op.units,
                                                   op.bits)
                        else:
                            ledger.party_to_server(op.tag, op.party, op.units,
                                                   op.bits)
                    stats.delivered += 1
                    stats.units_base += op.units
                    stats.bits_base += op.bits
                    units_base += op.units
                    bits_base += op.bits
                    break
                # failed transmission: the bytes still crossed the link
                if ledger is not None:
                    rtag = f"retry/{op.tag}"
                    if op.down:
                        ledger.server_to_party(rtag, op.party, op.units,
                                               op.bits)
                    else:
                        ledger.party_to_server(rtag, op.party, op.units,
                                               op.bits)
                stats.units_retried += op.units
                stats.bits_retried += op.bits
                units_retried += op.units
                bits_retried += op.bits
                setattr(stats, {"drop": "drops", "corrupt": "corrupts",
                                "timeout": "timeouts"}[ev.status],
                        getattr(stats, {"drop": "drops", "corrupt": "corrupts",
                                        "timeout": "timeouts"}[ev.status]) + 1)
                if attempts > retries_cap:
                    stats.exhausted += 1
                    if drop_on_exhaust:
                        failed[op.party] = DroppedParty(op.party, op.tag,
                                                       attempts)
                        break
                    raise PartyUnavailable(op.party, op.tag, attempts)
                retries += 1
                stats.retries += 1
                self._accrue(plan.backoff_s(attempts))
        return DeliveryReport(
            units_base=units_base, units_retried=units_retried,
            retries=retries, failed=failed,
            sim_time_s=stats.sim_time_s - sim0,
            bits_base=bits_base, bits_retried=bits_retried,
        )

    def ship(
        self,
        tag: str,
        payloads: Mapping[int, Any],
        ledger: Optional[CommLedger] = None,
        *,
        units: Union[int, Mapping[int, int], None] = None,
        down: bool = False,
        max_retries: Optional[int] = None,
        drop_on_exhaust: bool = False,
        codec: Optional[str] = None,
        encoded: Optional[Mapping[int, bytes]] = None,
    ) -> Tuple[Dict[int, Any], Dict[int, DroppedParty]]:
        """Deliver VALUE payloads under checksummed :class:`WireEnvelope`\\ s.

        The schedule already billed the base message — ``ship`` never bills
        base tags.  What it adds is the integrity seam: each party's payload
        is sealed, silently corrupted per the plan's ``silent_corrupt`` fate
        chain, and — when the transport verifies — every detected mismatch
        is retransmitted and billed under ``retry/<tag>`` with the message's
        full units AND packed bits, the exact :meth:`deliver` convention.
        With verification off the corrupted payload is DELIVERED, the
        attack the value-level validators exist to catch.

        ``codec`` names a :mod:`repro.core.wire` format: the payload is
        packed through it and the envelope seals the ENCODED bytes (the
        CRC covers the compressed payload — corrupting either the scales
        or the quantized words trips it), retries bill the measured packed
        size, and a lossy codec delivers ``decode(encode(payload))`` so
        downstream draws consume exactly what crossed the wire.  ``encoded``
        supplies pre-packed blobs (the round-2 uploads, encoded once when
        the schedule was built) so bits billed == bytes sealed by
        construction.  With ``codec=None`` the envelope seals the raw
        array, the pre-compression behavior.

        ``units`` is the per-party message size (scalar for all, or a
        mapping; default 1 — the round-1 scalar convention).  Returns
        ``(delivered, failed)``: ``delivered`` maps party -> payload, and is
        the ORIGINAL object whenever no corruption fired and the codec is
        value-exact for the payload's dtype (so the clean raw path stays
        bit-identical and free of host/device round-trips); ``failed``
        maps party -> :class:`DroppedParty` for parties whose every
        transmission was corrupted (only with ``drop_on_exhaust=True``;
        otherwise :exc:`PartyUnavailable` raises)."""
        plan = self.plan
        retries_cap = (plan.max_retries if max_retries is None
                       else int(max_retries))
        stats = self.stats
        delivered: Dict[int, Any] = {}
        failed: Dict[int, DroppedParty] = {}
        c = None if codec is None else get_codec(codec)

        def _units(j: int) -> int:
            if units is None:
                return 1
            if isinstance(units, Mapping):
                return int(units.get(j, 1))
            return int(units)

        for j, payload in payloads.items():
            if c is None:
                env = WireEnvelope.seal(tag, j, payload)
                blob = None
                bits_j = UNIT_BITS * _units(j)
            else:
                arr = np.asarray(payload)
                blob = (encoded[j] if encoded is not None and j in encoded
                        else c.encode(arr))
                env = WireEnvelope.seal_bytes(tag, j, blob)
                bits_j = 8 * len(blob)
            attempts = 0
            while True:
                fate = plan.silent_fate(tag, j, attempts)
                attempts += 1
                if fate is not None:
                    stats.silent_corrupts += 1
                if c is None:
                    out = (payload if fate is None
                           else perturb_payload(payload, *fate))
                    ok = not self.verify or env.verify(out)
                else:
                    if fate is None:
                        wire_blob = blob
                        out = (payload if c.exact_for(arr.dtype)
                               else c.decode(blob, arr.shape, arr.dtype))
                    else:
                        p = perturb_payload(arr, *fate)
                        wire_blob = c.encode(p)
                        out = c.decode(wire_blob, p.shape, p.dtype)
                    ok = (not self.verify
                          or env.verify(np.frombuffer(wire_blob, np.uint8)))
                if ok:
                    delivered[j] = out
                    break
                stats.silent_detected += 1
                # detected corruption: the bytes still crossed the link
                u = _units(j)
                if ledger is not None:
                    rtag = f"retry/{tag}"
                    if down:
                        ledger.server_to_party(rtag, j, u, bits_j)
                    else:
                        ledger.party_to_server(rtag, j, u, bits_j)
                stats.units_retried += u
                stats.bits_retried += bits_j
                if attempts > retries_cap:
                    stats.exhausted += 1
                    if drop_on_exhaust:
                        failed[j] = DroppedParty(j, tag, attempts)
                        break
                    raise PartyUnavailable(j, tag, attempts)
                stats.retries += 1
                self._accrue(plan.backoff_s(attempts))
        return delivered, failed


def deliver_or_record(
    schedule: CommSchedule,
    ledger: Optional[CommLedger],
    transport: Optional[Transport],
    *,
    max_retries: Optional[int] = None,
    drop_on_exhaust: bool = False,
) -> DeliveryReport:
    """The one helper every executor bills through: with no transport this
    IS ``schedule.record(ledger)`` (bit-identical entries, zero overhead);
    with one, delivery goes through the fault plan."""
    if transport is None:
        schedule.record(ledger)
        return DeliveryReport(units_base=schedule.total, units_retried=0,
                              retries=0, failed={}, sim_time_s=0.0,
                              bits_base=schedule.total_bits)
    return transport.deliver(schedule, ledger, max_retries=max_retries,
                             drop_on_exhaust=drop_on_exhaust)


# --------------------------------------------------------------------------
# StreamCheckpoint: per-superchunk resume state for the streaming engines
# --------------------------------------------------------------------------

def _to_host(tree):
    import jax

    return jax.tree_util.tree_map(lambda x: np.asarray(x), tree)


def _to_device(tree):
    import jax
    import jax.numpy as jnp

    return jax.tree_util.tree_map(lambda x: jnp.asarray(x), tree)


class StreamCheckpoint:
    """Per-superchunk checkpoint of one streaming/pipelined build.

    The streaming scorers' scan passes are pure folds over superchunks:
    checkpointing ``(chunks_done, accumulator)`` after every superchunk
    step makes the whole build resumable — restore the accumulator bitwise,
    continue the fold at ``chunks_done``, and every downstream value (mass
    table, scores, DIS draws) is IDENTICAL to an uninterrupted run, because
    the scan never consumes PRNG state (the threefry chain is a pure
    function of the input key, untouched by how many times the data pass
    restarted).

    ``bind(signature)`` ties the checkpoint to one build's identity (task,
    geometry, knobs, key bytes) — a signature change discards stale state,
    so one long-lived store per tenant is safe.  Carries are host-ified
    (numpy) on save so the state survives device loss; phases are the
    scorer passes (``gram`` / ``centers`` / ``stats`` / ``mass``).
    """

    def __init__(self) -> None:
        self.signature: Optional[tuple] = None
        self._phases: Dict[str, Tuple[int, Any]] = {}
        self.saves = 0
        self.resumes = 0

    def bind(self, signature: tuple) -> None:
        if self.signature != signature:
            self.signature = signature
            self._phases.clear()

    def save(self, phase: str, chunks_done: int, carry: Any) -> None:
        self._phases[phase] = (int(chunks_done), _to_host(carry))
        self.saves += 1

    def load(self, phase: str) -> Optional[Tuple[int, Any]]:
        saved = self._phases.get(phase)
        if saved is None:
            return None
        self.resumes += 1
        return saved[0], _to_device(saved[1])

    def clear(self) -> None:
        self.signature = None
        self._phases.clear()

    def __contains__(self, phase: str) -> bool:
        return phase in self._phases
