"""Vertical-federated dataset model: one dataset, feature columns split
across T parties; labels (if any) live at party T-1 (0-indexed; paper's
"party T").

This is the faithful, protocol-level simulation substrate used by the
paper-reproduction benchmarks.  The mesh/shard_map execution of the same
geometry (model axis = party axis) lives in :mod:`repro.core.selector`.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


def split_columns(d: int, T: int, sizes: Optional[Sequence[int]] = None) -> List[slice]:
    """Column slices for T parties. ``sizes`` overrides the near-even split."""
    if sizes is None:
        base, rem = divmod(d, T)
        sizes = [base + (1 if j < rem else 0) for j in range(T)]
    if len(sizes) != T or sum(sizes) != d:
        raise ValueError(f"bad sizes {sizes} for d={d}, T={T}")
    out, start = [], 0
    for s in sizes:
        out.append(slice(start, start + s))
        start += s
    return out


@dataclasses.dataclass
class VFLDataset:
    """X (n, d) vertically partitioned; y optional, held by the last party."""

    parts: List[jnp.ndarray]            # party j's local block (n, d_j)
    y: Optional[jnp.ndarray] = None     # (n,), stored at party T-1

    def __post_init__(self) -> None:
        n = self.parts[0].shape[0]
        for j, p in enumerate(self.parts):
            if p.ndim != 2 or p.shape[0] != n:
                raise ValueError(f"party {j}: bad shape {p.shape}")
        if self.y is not None and self.y.shape[0] != n:
            raise ValueError("label length mismatch")

    @property
    def n(self) -> int:
        return int(self.parts[0].shape[0])

    @property
    def T(self) -> int:
        return len(self.parts)

    @property
    def d(self) -> int:
        return int(sum(p.shape[1] for p in self.parts))

    @property
    def dims(self) -> Tuple[int, ...]:
        return tuple(int(p.shape[1]) for p in self.parts)

    def full(self) -> jnp.ndarray:
        """Server-side concatenation — ONLY for evaluation/tests, never used
        inside communication-accounted protocols."""
        return jnp.concatenate(self.parts, axis=1)

    def rows(self, idx: jnp.ndarray) -> "VFLDataset":
        y = None if self.y is None else self.y[idx]
        return VFLDataset([p[idx] for p in self.parts], y)

    @staticmethod
    def from_dense(X, y=None, T: int = 3, sizes: Optional[Sequence[int]] = None) -> "VFLDataset":
        X = jnp.asarray(X)
        slices = split_columns(X.shape[1], T, sizes)
        return VFLDataset([X[:, s] for s in slices], None if y is None else jnp.asarray(y))


def standardize(ds: VFLDataset, eps: float = 1e-8) -> VFLDataset:
    """Per-feature mean-0 / std-1 normalisation, computed party-locally
    (no cross-party stats needed — matches the paper's preprocessing)."""
    parts = []
    for p in ds.parts:
        mu = p.mean(axis=0, keepdims=True)
        sd = p.std(axis=0, keepdims=True)
        parts.append((p - mu) / jnp.maximum(sd, eps))
    return VFLDataset(parts, ds.y)


def as_numpy(ds: VFLDataset) -> Tuple[List[np.ndarray], Optional[np.ndarray]]:
    return [np.asarray(p) for p in ds.parts], (None if ds.y is None else np.asarray(ds.y))
