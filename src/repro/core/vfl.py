"""Vertical-federated dataset model: one dataset, feature columns split
across T parties; labels (if any) live at party T-1 (0-indexed; paper's
"party T").

This is the faithful, protocol-level simulation substrate used by the
paper-reproduction benchmarks.  The mesh/shard_map execution of the same
geometry (model axis = party axis) lives in :mod:`repro.core.selector`.
"""

from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


class StackedParts(NamedTuple):
    """Padded party-major view of a :class:`VFLDataset`.

    ``blocks`` is (T, n, s) with party j's block left-aligned and
    zero-padded to the common width s = max_j d_j (+1 when labels are
    stacked in); ``mask`` is (T, s) bool marking the valid columns.  Zero
    padding is score-transparent: distances, Grams, row norms and
    quadratic forms over the padded axis all equal their unpadded values,
    so one vmap over axis 0 scores every party in a single dispatch.
    """

    blocks: jnp.ndarray            # (T, n, s) float
    mask: jnp.ndarray              # (T, s) bool
    dims: Tuple[int, ...]          # valid width per party (incl. label col)

    @property
    def T(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def n(self) -> int:
        return int(self.blocks.shape[1])


def split_columns(d: int, T: int, sizes: Optional[Sequence[int]] = None) -> List[slice]:
    """Column slices for T parties. ``sizes`` overrides the near-even split."""
    if sizes is None:
        base, rem = divmod(d, T)
        sizes = [base + (1 if j < rem else 0) for j in range(T)]
    if len(sizes) != T or sum(sizes) != d:
        raise ValueError(f"bad sizes {sizes} for d={d}, T={T}")
    out, start = [], 0
    for s in sizes:
        out.append(slice(start, start + s))
        start += s
    return out


@dataclasses.dataclass
class VFLDataset:
    """X (n, d) vertically partitioned; y optional, held by the last party."""

    parts: List[jnp.ndarray]            # party j's local block (n, d_j)
    y: Optional[jnp.ndarray] = None     # (n,), stored at party T-1

    def __post_init__(self) -> None:
        n = self.parts[0].shape[0]
        for j, p in enumerate(self.parts):
            if p.ndim != 2 or p.shape[0] != n:
                raise ValueError(f"party {j}: bad shape {p.shape}")
        if self.y is not None and self.y.shape[0] != n:
            raise ValueError("label length mismatch")

    @property
    def n(self) -> int:
        return int(self.parts[0].shape[0])

    @property
    def T(self) -> int:
        return len(self.parts)

    @property
    def d(self) -> int:
        return int(sum(p.shape[1] for p in self.parts))

    @property
    def dims(self) -> Tuple[int, ...]:
        return tuple(int(p.shape[1]) for p in self.parts)

    def full(self) -> jnp.ndarray:
        """Server-side concatenation — ONLY for evaluation/tests, never used
        inside communication-accounted protocols."""
        return jnp.concatenate(self.parts, axis=1)

    def stacked(self, with_labels: bool = False) -> StackedParts:
        """Padded (T, n, s) stacking of the party blocks for single-dispatch
        scoring (one vmap over the party axis instead of a Python loop).

        With ``with_labels=True`` party T's labels are appended as one extra
        column of its block (the [X^(T), y] basis of Algorithm 2); the
        common width s grows accordingly.  Each party only ever touches its
        own slice, so the view is a layout change, not a protocol change.
        """
        if with_labels and self.y is None:
            raise ValueError("with_labels requires labels at party T")
        widths = list(self.dims)
        if with_labels:
            widths[-1] += 1
        s = max(widths)
        blocks, mask = [], []
        for j, p in enumerate(self.parts):
            b = p
            if with_labels and j == self.T - 1:
                b = jnp.concatenate([b, self.y[:, None].astype(b.dtype)], axis=1)
            pad = s - widths[j]
            if pad:
                b = jnp.pad(b, ((0, 0), (0, pad)))
            blocks.append(b)
            mask.append(np.arange(s) < widths[j])
        return StackedParts(jnp.stack(blocks), jnp.asarray(np.stack(mask)),
                            tuple(widths))

    def rows(self, idx: jnp.ndarray) -> "VFLDataset":
        y = None if self.y is None else self.y[idx]
        return VFLDataset([p[idx] for p in self.parts], y)

    @staticmethod
    def from_dense(X, y=None, T: int = 3, sizes: Optional[Sequence[int]] = None) -> "VFLDataset":
        X = jnp.asarray(X)
        slices = split_columns(X.shape[1], T, sizes)
        return VFLDataset([X[:, s] for s in slices], None if y is None else jnp.asarray(y))


def standardize(ds: VFLDataset, eps: float = 1e-8) -> VFLDataset:
    """Per-feature mean-0 / std-1 normalisation, computed party-locally
    (no cross-party stats needed — matches the paper's preprocessing)."""
    parts = []
    for p in ds.parts:
        mu = p.mean(axis=0, keepdims=True)
        sd = p.std(axis=0, keepdims=True)
        parts.append((p - mu) / jnp.maximum(sd, eps))
    return VFLDataset(parts, ds.y)


def as_numpy(ds: VFLDataset) -> Tuple[List[np.ndarray], Optional[np.ndarray]]:
    return [np.asarray(p) for p in ds.parts], (None if ds.y is None else np.asarray(ds.y))
