"""Vertical-federated dataset model: one dataset, feature columns split
across T parties; labels (if any) live at party T-1 (0-indexed; paper's
"party T").

This is the faithful, protocol-level simulation substrate used by the
paper-reproduction benchmarks.  The mesh/shard_map execution of the same
geometry (model axis = party axis) lives in :mod:`repro.core.selector`.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class StackedParts(NamedTuple):
    """Padded party-major view of a :class:`VFLDataset`.

    ``blocks`` is (T, n, s) with party j's block left-aligned and
    zero-padded to the common width s = max_j d_j (+1 when labels are
    stacked in); ``mask`` is (T, s) bool marking the valid columns.  Zero
    padding is score-transparent: distances, Grams, row norms and
    quadratic forms over the padded axis all equal their unpadded values,
    so one vmap over axis 0 scores every party in a single dispatch.
    """

    blocks: jnp.ndarray            # (T, n, s) float
    mask: jnp.ndarray              # (T, s) bool
    dims: Tuple[int, ...]          # valid width per party (incl. label col)

    @property
    def T(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def n(self) -> int:
        return int(self.blocks.shape[1])


def block_geometry(n: int, block_size: int) -> Tuple[int, int]:
    """(num_blocks nb, rows-per-block bs) for a ``block_size`` row chunking
    of n rows — the canonical geometry shared by ``VFLDataset.block`` and
    the hierarchical DIS sampler (``repro.core.dis.blocked_geometry``
    delegates here, so the two can never drift apart).

    bs clamps to n, so ``block_size >= n`` is exactly one unpadded block —
    the flat-plan degeneration the bit-identity tests rely on; the last
    block is zero-padded up to bs.
    """
    if block_size < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size}")
    bs = min(int(block_size), int(n))
    return -(-int(n) // bs), bs


def split_columns(d: int, T: int, sizes: Optional[Sequence[int]] = None) -> List[slice]:
    """Column slices for T parties. ``sizes`` overrides the near-even split."""
    if sizes is None:
        base, rem = divmod(d, T)
        sizes = [base + (1 if j < rem else 0) for j in range(T)]
    if len(sizes) != T or sum(sizes) != d:
        raise ValueError(f"bad sizes {sizes} for d={d}, T={T}")
    out, start = [], 0
    for s in sizes:
        out.append(slice(start, start + s))
        start += s
    return out


@dataclasses.dataclass
class VFLDataset:
    """X (n, d) vertically partitioned; y optional, held by the last party.

    ``parts`` may be jnp arrays (device-resident) or plain numpy arrays.
    Numpy-backed datasets are the host-resident substrate of the streaming
    path (:mod:`repro.core.streaming`): :meth:`block` slices on the host and
    only the requested (T, bs, s) chunk ever becomes a device array, so
    device memory stays O(block_size * d) at any n.
    """

    parts: List[jnp.ndarray]            # party j's local block (n, d_j)
    y: Optional[jnp.ndarray] = None     # (n,), stored at party T-1
    validate: bool = True               # NaN/Inf screen at construction

    def __post_init__(self) -> None:
        if not self.parts:
            raise ValueError(
                "VFLDataset needs at least one party (parts is empty)"
            )
        n = self.parts[0].shape[0]
        if n == 0:
            raise ValueError(
                "VFLDataset needs at least one row (n=0); every protocol "
                "downstream scores and samples rows"
            )
        for j, p in enumerate(self.parts):
            if p.ndim != 2 or p.shape[0] != n:
                raise ValueError(f"party {j}: bad shape {p.shape}")
        if self.y is not None and self.y.shape[0] != n:
            raise ValueError("label length mismatch")
        if self.validate:
            self._validate_values()

    def _validate_values(self) -> None:
        """NaN/Inf screen: a single non-finite cell poisons every Gram /
        distance it touches downstream, so fail loudly at ingest and name
        the offender.  Skipped for traced arrays (``_exec_fused`` constructs
        datasets inside jit) and via ``validate=False`` when non-finite
        values are intentional (e.g. corruption-injection tests)."""
        named = [(f"party {j}", p) for j, p in enumerate(self.parts)]
        if self.y is not None:
            named.append((f"labels (party {self.T - 1})", self.y))
        for name, a in named:
            if isinstance(a, jax.core.Tracer):
                continue
            vals = np.asarray(a)
            if not np.issubdtype(vals.dtype, np.inexact):
                continue
            finite = np.isfinite(vals)
            if finite.all():
                continue
            loc = np.argwhere(~finite)[0]
            where = (f"row {loc[0]}, column {loc[1]}" if loc.size == 2
                     else f"row {loc[0]}")
            bad = vals[tuple(loc)]
            kind = "NaN" if np.isnan(bad) else "Inf"
            raise ValueError(
                f"non-finite value ({kind}) in {name} at {where}; "
                f"clean the feed or construct with validate=False to "
                f"bypass the ingest screen"
            )

    @property
    def n(self) -> int:
        return int(self.parts[0].shape[0])

    @property
    def T(self) -> int:
        return len(self.parts)

    @property
    def d(self) -> int:
        return int(sum(p.shape[1] for p in self.parts))

    @property
    def dims(self) -> Tuple[int, ...]:
        return tuple(int(p.shape[1]) for p in self.parts)

    def full(self) -> jnp.ndarray:
        """Server-side concatenation — ONLY for evaluation/tests, never used
        inside communication-accounted protocols."""
        return jnp.concatenate(self.parts, axis=1)

    def stacked_widths(self, with_labels: bool = False) -> Tuple[Tuple[int, ...], int]:
        """(per-party valid widths, common padded width s) of the stacked
        view — the geometry shared by :meth:`stacked` and :meth:`block`."""
        if with_labels and self.y is None:
            raise ValueError("with_labels requires labels at party T")
        widths = list(self.dims)
        if with_labels:
            widths[-1] += 1
        return tuple(widths), max(widths)

    def stacked(self, with_labels: bool = False) -> StackedParts:
        """Padded (T, n, s) stacking of the party blocks for single-dispatch
        scoring (one vmap over the party axis instead of a Python loop).

        With ``with_labels=True`` party T's labels are appended as one extra
        column of its block (the [X^(T), y] basis of Algorithm 2); the
        common width s grows accordingly.  Each party only ever touches its
        own slice, so the view is a layout change, not a protocol change.
        """
        widths, s = self.stacked_widths(with_labels)
        blocks, mask = [], []
        for j, p in enumerate(self.parts):
            b = jnp.asarray(p)
            if with_labels and j == self.T - 1:
                b = jnp.concatenate([b, jnp.asarray(self.y)[:, None].astype(b.dtype)],
                                    axis=1)
            pad = s - widths[j]
            if pad:
                b = jnp.pad(b, ((0, 0), (0, pad)))
            blocks.append(b)
            mask.append(np.arange(s) < widths[j])
        return StackedParts(jnp.stack(blocks), jnp.asarray(np.stack(mask)),
                            tuple(widths))

    # -- chunked row-block view (the streaming substrate) ---------------------

    def block_geometry(self, block_size: int) -> Tuple[int, int]:
        """:func:`block_geometry` of this dataset's n rows."""
        return block_geometry(self.n, block_size)

    def block(
        self, b: int, block_size: int, with_labels: bool = False
    ) -> Tuple[jnp.ndarray, int]:
        """Padded (T, bs, s) stacked view of row block ``b`` + its valid-row
        count.

        Rows [b*bs, b*bs + bs) of every party, laid out exactly as the
        corresponding slice of :meth:`stacked` (labels appended to party T,
        columns zero-padded to the common width); rows past n are zero.
        Slicing happens on the host representation of ``parts`` (numpy or
        jnp), so with numpy-backed parts only this one block is ever
        transferred to the device.
        """
        widths, s = self.stacked_widths(with_labels)
        nb, bs = self.block_geometry(block_size)
        if not 0 <= b < nb:
            raise IndexError(f"block {b} out of range [0, {nb})")
        lo = b * bs
        hi = min(lo + bs, self.n)
        nvalid = hi - lo
        blocks = []
        for j, p in enumerate(self.parts):
            seg = jnp.asarray(p[lo:hi])
            if with_labels and j == self.T - 1:
                seg = jnp.concatenate(
                    [seg, jnp.asarray(self.y[lo:hi])[:, None].astype(seg.dtype)],
                    axis=1)
            seg = jnp.pad(seg, ((0, bs - nvalid), (0, s - widths[j])))
            blocks.append(seg)
        return jnp.stack(blocks), nvalid

    def blocks(self, block_size: int, with_labels: bool = False):
        """Iterate ``(b, block (T, bs, s), nvalid)`` over the row chunking —
        the one-block-resident traversal the streaming scorers consume."""
        nb, _ = self.block_geometry(block_size)
        for b in range(nb):
            blk, nvalid = self.block(b, block_size, with_labels)
            yield b, blk, nvalid

    # -- pipelined superchunk view (the prefetched streaming substrate) -------

    def _staging_dtype(self, with_labels: bool) -> np.dtype:
        """Canonical dtype of the stacked device blocks (what :meth:`block`
        yields after jnp's dtype canonicalization) — the staging buffers must
        match it so the superchunk path sees the exact same values."""
        arrs = [p[0:0] for p in self.parts]
        if with_labels:
            arrs.append(self.y[0:0])
        dt = np.result_type(*[np.asarray(a).dtype for a in arrs])
        return np.dtype(jax.dtypes.canonicalize_dtype(dt))

    def _fill_superchunk(
        self, out: np.ndarray, b0: int, block_size: int, with_labels: bool,
        widths: Tuple[int, ...], bs: int, nb: int,
    ) -> np.ndarray:
        """Host-side assembly of blocks [b0, b0 + C) into the (C, T, bs, s)
        numpy staging buffer ``out`` (zeroed first; blocks past nb stay
        all-zero with 0 valid rows).  One contiguous host slice per party per
        superchunk — no device dispatches happen here at all; the single
        ``device_put`` of ``out`` is the only transfer.  Returns the (C,)
        per-block valid-row counts."""
        C = out.shape[0]
        out[...] = 0.0
        count = max(0, min(C, nb - b0))
        lo = b0 * bs
        hi = min(lo + count * bs, self.n)
        nvalids = np.clip(self.n - (b0 + np.arange(C)) * bs, 0, bs)
        nvalids[count:] = 0
        for j, p in enumerate(self.parts):
            seg = np.asarray(p[lo:hi])
            if with_labels and j == self.T - 1:
                yseg = np.asarray(self.y[lo:hi])
                seg = np.concatenate([seg, yseg[:, None].astype(seg.dtype)],
                                     axis=1)
            w = widths[j]
            for i in range(count):
                r0 = i * bs
                nv = int(nvalids[i])
                out[i, j, :nv, :w] = seg[r0:r0 + nv]
        return nvalids

    def blocks_prefetched(
        self, block_size: int, with_labels: bool = False,
        chunk_blocks: int = 1, prefetch: bool = True,
        start_chunk: int = 0,
    ) -> Iterator[Tuple[int, jnp.ndarray, np.ndarray]]:
        """Iterate ``(b0, chunk (C, T, bs, s) device array, nvalids (C,))``
        over superchunks of ``chunk_blocks`` row blocks — the double-buffered
        staging layer of the pipelined streaming engine.

        With ``prefetch=True`` the async ``jax.device_put`` of superchunk
        c+1 is issued BEFORE superchunk c is yielded, so the staging of the
        next chunk overlaps with whatever the consumer computes on the
        current one.  Each superchunk gets a FRESH staging buffer that the
        device array aliases (CPU ``device_put`` is zero-copy: the staging
        buffer IS the device buffer, so assembly writes double as the
        transfer and nothing is ever copied twice; on an accelerator it
        becomes a real async H2D copy of an immutable source — safe either
        way because a staged buffer is never written again).  The consumed
        chunk's reference is dropped as soon as the next one is yielded, so
        at most two slots are live regardless of n.  Block contents and
        ordering are identical to :meth:`blocks`; only the transfer
        granularity and overlap change.

        ``start_chunk`` skips the first superchunks entirely (no staging, no
        transfer) — the checkpointed-resume entry point: a restored scan
        continues at the first unprocessed superchunk and sees exactly the
        buffers a full traversal would have yielded from there.
        """
        widths, s = self.stacked_widths(with_labels)
        nb, bs = self.block_geometry(block_size)
        if chunk_blocks < 1:
            raise ValueError(f"chunk_blocks must be >= 1, got {chunk_blocks}")
        nchunks = -(-nb // chunk_blocks)
        if not 0 <= start_chunk <= nchunks:
            raise ValueError(
                f"start_chunk {start_chunk} out of range [0, {nchunks}]"
            )
        dt = self._staging_dtype(with_labels)

        def stage(c: int):
            buf = np.empty((chunk_blocks, self.T, bs, s), dt)
            nvalids = self._fill_superchunk(buf, c * chunk_blocks, block_size,
                                            with_labels, widths, bs, nb)
            return jax.device_put(buf), nvalids          # async: returns now

        if not prefetch:
            for c in range(start_chunk, nchunks):
                dev, nvalids = stage(c)
                yield c * chunk_blocks, dev, nvalids
                del dev                       # drop the slot before restaging
            return
        if start_chunk >= nchunks:
            return
        nxt = stage(start_chunk)
        for c in range(start_chunk, nchunks):
            cur = nxt
            # issue the NEXT transfer before handing the current chunk to the
            # consumer — the copy proceeds while the consumer's dispatch runs
            nxt = stage(c + 1) if c + 1 < nchunks else None
            yield c * chunk_blocks, cur[0], cur[1]
            del cur

    def gather_blocks(
        self, block_ids, block_size: int, with_labels: bool = False,
    ) -> Tuple[jnp.ndarray, np.ndarray]:
        """One (len(ids), T, bs, s) device batch of arbitrary row blocks plus
        their valid-row counts — the gather feeding the one-dispatch
        touched-block redraw (scores for ALL touched cells from a single
        vmapped dispatch instead of one per block)."""
        widths, s = self.stacked_widths(with_labels)
        nb, bs = self.block_geometry(block_size)
        ids = [int(b) for b in block_ids]
        for b in ids:
            if not 0 <= b < nb:
                raise IndexError(f"block {b} out of range [0, {nb})")
        out = np.empty((len(ids), self.T, bs, s),
                       self._staging_dtype(with_labels))
        nvalids = np.zeros((len(ids),), np.int64)
        for i, b in enumerate(ids):
            nvalids[i:i + 1] = self._fill_superchunk(
                out[i:i + 1], b, block_size, with_labels, widths, bs, nb)
        return jax.device_put(out), nvalids

    def rows(self, idx: jnp.ndarray) -> "VFLDataset":
        y = None if self.y is None else self.y[idx]
        return VFLDataset([p[idx] for p in self.parts], y)

    def select_parties(self, parties: Sequence[int]) -> "VFLDataset":
        """The SAME rows restricted to a party subset — the surviving
        federation of a degraded build (:mod:`repro.core.faults`).  Labels
        survive only if the label holder (party T-1) is among ``parties``;
        order follows ``parties`` (keep it sorted to preserve the paper's
        party numbering)."""
        ids = [int(j) for j in parties]
        if not ids:
            raise ValueError("select_parties needs at least one party")
        bad = [j for j in ids if not 0 <= j < self.T]
        if bad:
            raise ValueError(f"parties {bad} out of range [0, {self.T})")
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate parties in {ids}")
        y = self.y if (self.T - 1) in ids else None
        return VFLDataset([self.parts[j] for j in ids], y)

    @staticmethod
    def from_dense(X, y=None, T: int = 3, sizes: Optional[Sequence[int]] = None) -> "VFLDataset":
        X = jnp.asarray(X)
        slices = split_columns(X.shape[1], T, sizes)
        return VFLDataset([X[:, s] for s in slices], None if y is None else jnp.asarray(y))


def standardize(ds: VFLDataset, eps: float = 1e-8) -> VFLDataset:
    """Per-feature mean-0 / std-1 normalisation, computed party-locally
    (no cross-party stats needed — matches the paper's preprocessing)."""
    parts = []
    for p in ds.parts:
        mu = p.mean(axis=0, keepdims=True)
        sd = p.std(axis=0, keepdims=True)
        parts.append((p - mu) / jnp.maximum(sd, eps))
    return VFLDataset(parts, ds.y)


def as_numpy(ds: VFLDataset) -> Tuple[List[np.ndarray], Optional[np.ndarray]]:
    return [np.asarray(p) for p in ds.parts], (None if ds.y is None else np.asarray(ds.y))
