"""CoresetSpec -> ExecutionPlan: the declarative layer over every engine.

After the perf PRs the repo had four divergent build entry points
(``build_coreset``, ``build_coreset_jit``, ``build_coreset_streaming``,
``build_coresets_batched``) with inconsistent knobs and validation.  This
module makes the pipeline spec-compiled, in the declarative-launcher idiom:

  * :class:`CoresetSpec` — ONE frozen description of a construction: task,
    budgets, seeds, backend, engine preference, streaming knobs
    (``block_size``/``chunk_blocks``/``prefetch``), ``memory_budget_bytes``
    and the ``sharded_masses`` toggle.  ALL knob validation lives in its
    ``__post_init__`` with uniform ``ValueError`` messages — no entry point
    validates anything on its own anymore.
  * :class:`ExecutionPlan` — the compiled plan: ONE concrete engine
    (``materialized | batched | streamed | pipelined``), resolved backend
    and knobs (the ``chunk_blocks > nb`` clamp is an explicit, recorded
    planner decision, not a silent coercion), the full memory model, the
    predicted communication bill (via :class:`repro.core.comm.CommSchedule`
    — the total is count-independent, so it is exact before any draw), and
    ``describe()`` introspection.
  * :func:`compile_plan` — the auto-planner.  Engine selection is driven by
    a MEMORY MODEL calibrated against the measured yardsticks in
    BENCH_kernels.json: the materialized path holds the (T, n, s) stacked
    design plus the (T, n) score matrix; the streamed path holds one
    (T, bs, s) block (measured peak within ~2% of ``block_bytes``); the
    pipelined path holds up to 2.5x one (C, T, bs, s) superchunk (two
    double-buffered staging slots + the live compute residency — measured
    peaks are <= 2.01x ``chunk_bytes``).  Given ``memory_budget_bytes`` the
    planner picks the FASTEST engine whose predicted peak fits:
    materialized when everything fits, pipelined when a superchunk pipeline
    fits, streamed otherwise (the minimum-footprint engine; if even that
    exceeds the budget the plan is still streamed, flagged
    ``budget_exceeded``).  Grids (num_seeds > 1 or multiple budgets) always
    compile to the batched engine.

The executors themselves live in :mod:`repro.core.api`
(:class:`~repro.core.api.CoresetPipeline` dispatches on the plan); this
module stays import-light so the spec can be constructed anywhere.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Mapping, Optional, Tuple, Union

import numpy as np

from repro.core.comm import CommSchedule
from repro.core.vfl import VFLDataset, block_geometry
from repro.core.wire import (
    CODEC_LADDER,
    SPEC_CODECS,
    choose_codec,
    fmt_bits,
    predict_dis_bits,
    predict_uniform_bits,
)

SCORE_BACKENDS = ("pallas", "ref", "norm")

ENGINES = ("materialized", "batched", "streamed", "pipelined")

#: Failover order, most capable to minimum footprint.  A build that crashes
#: or breaches its runtime memory budget retries on the next engine in this
#: ladder (pipelined -> streamed is bit-identical by the PR 4 contract).
FAILOVER_LADDER = ("materialized", "pipelined", "streamed")

FAULT_POLICIES = ("fail", "retry", "degrade", "quarantine")

# superchunk width when chunk_blocks is not given: deep enough to amortise
# the per-dispatch overhead, shallow enough that two prefetch slots + one
# resident superchunk stay a small multiple of the single-block footprint
DEFAULT_CHUNK_BLOCKS = 8

#: Measured-winner prefetch default per backend.  BENCH_kernels.json's
#: streaming sweep: on CPU the host thread that feeds the prefetch slot
#: competes with the compute it overlaps — noprefetch wins (918,245 rows/s
#: vs 690,124 with prefetch on).  On accelerators the staging copy runs on
#: the transfer engine while compute owns the cores, so prefetch wins.
#: Backends outside the table default to prefetching (accelerator-like).
PREFETCH_DEFAULT = {"cpu": False, "tpu": True, "gpu": True}

# pipelined peak model: two double-buffered staging slots + the live compute
# residency of one superchunk.  BENCH_kernels.json's streaming_pipelined
# sweep measures every peak <= 2.01x chunk_bytes; 2.5x is the documented
# bound the benchmark asserts against.
PIPELINED_PEAK_FACTOR = 2.5

_FLOAT_BYTES = 4        # every engine scores in float32


def _is_int(x) -> bool:
    return isinstance(x, (int, np.integer)) and not isinstance(x, bool)


@dataclasses.dataclass(frozen=True)
class CoresetSpec:
    """Frozen declarative description of one coreset construction.

    ``budgets`` accepts a single int or any iterable of ints; a grid
    (``num_seeds > 1`` or multiple budgets) compiles to the batched engine.
    ``engine="auto"`` lets the planner choose from the memory model;
    forcing an engine pins the exact legacy code path (the thin shims
    ``build_coreset`` / ``build_coreset_jit`` / ``build_coreset_streaming``
    / ``build_coresets_batched`` are precisely such forced specs, and stay
    draw-identical).  ``params`` carries task-specific score knobs (vkmc's
    ``k``/``alpha``/``local_iters``, vrlr's ``rcond``) verbatim.

    All validation is centralized HERE — uniform ``ValueError`` messages,
    raised at spec construction before any work happens.  The one knob
    that is *coerced* rather than rejected, ``chunk_blocks`` above the
    block count, is clamped by the PLANNER (an explicit decision recorded
    in ``ExecutionPlan.notes`` and ``describe()``), never silently here.
    """

    task: Union[str, Any] = "vrlr"
    budgets: Union[int, Tuple[int, ...]] = (512,)
    num_seeds: int = 1
    engine: str = "auto"
    backend: str = "auto"
    jit: bool = False                     # materialized fast path: one fused dispatch
    block_size: int = 65536
    chunk_blocks: Optional[int] = None    # None -> DEFAULT_CHUNK_BLOCKS (planner)
    prefetch: Optional[bool] = None       # None -> backend-aware (planner)
    memory_budget_bytes: Optional[int] = None
    sharded_masses: bool = False          # mass table via shard_map over `data`
    m_cap: Optional[int] = None           # batched draw capacity override
    fault_policy: str = "fail"            # fail | retry | degrade | quarantine
    codec: str = "raw_fp32"               # wire codec, or "auto" (planner)
    comm_budget_bits: Optional[int] = None
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if not (isinstance(self.task, str) or hasattr(self.task, "score_fn")):
            raise ValueError(
                f"task must be a registry name or CoresetTask, got {self.task!r}"
            )
        budgets = self.budgets
        if _is_int(budgets):
            budgets = (int(budgets),)
        else:
            budgets = tuple(budgets)
        if not budgets:
            raise ValueError("budgets must be a non-empty tuple of positive ints")
        bad = [b for b in budgets if not _is_int(b) or b < 1]
        if bad:
            raise ValueError(
                f"budgets must be positive ints, got {bad} in {budgets}"
            )
        budgets = tuple(int(b) for b in budgets)
        object.__setattr__(self, "budgets", budgets)
        if not _is_int(self.num_seeds) or self.num_seeds < 1:
            raise ValueError(
                f"num_seeds must be a positive int, got {self.num_seeds!r}"
            )
        if self.engine not in ("auto",) + ENGINES:
            raise ValueError(
                f"engine must be 'auto' or one of {ENGINES}, got {self.engine!r}"
            )
        if self.backend not in ("auto",) + SCORE_BACKENDS:
            raise ValueError(
                f"backend must be 'auto' or one of {SCORE_BACKENDS}, "
                f"got {self.backend!r}"
            )
        if not isinstance(self.jit, bool):
            raise ValueError(f"jit must be a bool, got {self.jit!r}")
        if self.jit and self.engine not in ("auto", "materialized", "batched"):
            raise ValueError(
                f"jit=True is the materialized/batched fused path; it cannot "
                f"combine with engine={self.engine!r}"
            )
        if not _is_int(self.block_size) or self.block_size < 1:
            raise ValueError(
                f"block_size must be a positive int, got {self.block_size!r}"
            )
        if self.chunk_blocks is not None and (
                not _is_int(self.chunk_blocks) or self.chunk_blocks < 1):
            raise ValueError(
                f"chunk_blocks must be a positive int, got {self.chunk_blocks!r}"
            )
        if self.prefetch is not None and not isinstance(self.prefetch, bool):
            raise ValueError(f"prefetch must be a bool, got {self.prefetch!r}")
        if self.memory_budget_bytes is not None and (
                not _is_int(self.memory_budget_bytes)
                or self.memory_budget_bytes < 1):
            raise ValueError(
                f"memory_budget_bytes must be a positive int, "
                f"got {self.memory_budget_bytes!r}"
            )
        if not isinstance(self.sharded_masses, bool):
            raise ValueError(
                f"sharded_masses must be a bool, got {self.sharded_masses!r}"
            )
        if self.sharded_masses and self.engine in ("materialized", "batched"):
            raise ValueError(
                f"sharded_masses computes the streaming block-mass table; it "
                f"cannot combine with engine={self.engine!r}"
            )
        if self.m_cap is not None:
            if not _is_int(self.m_cap) or self.m_cap < 1:
                raise ValueError(
                    f"m_cap must be a positive int, got {self.m_cap!r}"
                )
            over = [b for b in budgets if b > self.m_cap]
            if over:
                raise ValueError(
                    f"budgets {over} outside [1, m_cap={self.m_cap}]; every "
                    f"budget must be >= 1 and <= the draw capacity"
                )
        if self.fault_policy not in FAULT_POLICIES:
            raise ValueError(
                f"fault_policy must be one of {FAULT_POLICIES}, "
                f"got {self.fault_policy!r}"
            )
        if self.fault_policy != "fail" and self.engine == "batched":
            raise ValueError(
                f"fault_policy={self.fault_policy!r} delivers per-round "
                f"schedules through a transport; the batched engine bills "
                f"its cells lazily and cannot combine with it"
            )
        if self.codec not in SPEC_CODECS:
            raise ValueError(
                f"codec must be one of {SPEC_CODECS}, got {self.codec!r}"
            )
        lossy = self.codec not in ("auto", "raw_fp32")
        if lossy and self.jit:
            raise ValueError(
                f"codec={self.codec!r} quantizes the wire; the jit fused "
                f"path never leaves the device and cannot combine with it"
            )
        if lossy and self.engine == "batched":
            raise ValueError(
                f"codec={self.codec!r} quantizes per-round payloads; the "
                f"batched engine bills its cells lazily and cannot combine "
                f"with it"
            )
        if self.comm_budget_bits is not None and (
                not _is_int(self.comm_budget_bits)
                or self.comm_budget_bits < 1):
            raise ValueError(
                f"comm_budget_bits must be a positive int, "
                f"got {self.comm_budget_bits!r}"
            )
        object.__setattr__(self, "params", dict(self.params))

    # -- conveniences --------------------------------------------------------

    @property
    def is_grid(self) -> bool:
        return self.num_seeds > 1 or len(self.budgets) > 1

    @property
    def budget(self) -> int:
        """The single budget of a non-grid spec."""
        if self.is_grid:
            raise ValueError(
                f"spec is a {self.num_seeds}x{len(self.budgets)} grid; "
                f"use .budgets"
            )
        return self.budgets[0]

    def replace(self, **kw) -> "CoresetSpec":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------
# Memory model (bytes) — calibrated against BENCH_kernels.json yardsticks
# --------------------------------------------------------------------------

def block_bytes(T: int, bs: int, s: int) -> int:
    """One (T, bs, s) stacked row block — the streaming yardstick (measured
    streamed peaks sit within ~2% of this)."""
    return T * bs * s * _FLOAT_BYTES


def memory_model(
    T: int, n: int, s: int, bs: int, chunk_blocks: int,
    num_seeds: int = 1, num_budgets: int = 1, m_cap: int = 512,
    scored: bool = True,
) -> dict:
    """Predicted peak live device bytes per engine.

    materialized: the (T, n, s) stacked design + the (T, n) score matrix.
    batched:      materialized + the (R, M, m_cap) result grid.
    streamed:     one (T, bs, s) block + its transient (T, bs) scores.
    pipelined:    PIPELINED_PEAK_FACTOR x one (C, T, bs, s) superchunk
                  (two double-buffered staging slots + compute residency).

    ``scored=False`` (the uniform task — no scores, no design on device)
    collapses every engine to the tiny sample buffers.
    """
    if not scored:
        tiny = num_seeds * num_budgets * m_cap * 2 * _FLOAT_BYTES
        return {e: tiny for e in ENGINES}
    design = T * n * s * _FLOAT_BYTES
    scores = T * n * _FLOAT_BYTES
    blk = block_bytes(T, bs, s)
    grid = num_seeds * num_budgets * m_cap * 3 * _FLOAT_BYTES
    return {
        "materialized": design + scores,
        "batched": design + scores + grid,
        "streamed": blk + T * bs * _FLOAT_BYTES,
        "pipelined": int(PIPELINED_PEAK_FACTOR * chunk_blocks * blk),
    }


def _fmt_bytes(b: int) -> str:
    if b >= 1 << 20:
        return f"{b / (1 << 20):.1f}MB"
    if b >= 1 << 10:
        return f"{b / (1 << 10):.1f}KB"
    return f"{b}B"


# --------------------------------------------------------------------------
# Runtime memory watchdog — the benchmarks/streaming.py dedup census,
# productionized: the planner PREDICTS peaks from the calibrated model, the
# watchdog MEASURES them, and the failover ladder reacts when the model was
# wrong (ROADMAP item 2 shows it already is on CPU).
# --------------------------------------------------------------------------

def live_bytes() -> int:
    """Total bytes of live device arrays right now, deduped by underlying
    buffer so donated/aliased views (e.g. the pipelined engine's staging
    slots) count once, not per ``jax.Array`` object.  Process-wide: in a
    multi-tenant service this measures the whole device residency, which is
    exactly the number an OOM cares about."""
    import jax

    seen, total = set(), 0
    for a in jax.live_arrays():
        try:
            key = a.unsafe_buffer_pointer()
        except Exception:
            key = id(a)
        if key in seen:
            continue
        seen.add(key)
        total += int(np.prod(a.shape)) * a.dtype.itemsize
    return total


class MemoryBudgetExceeded(RuntimeError):
    """The live-bytes census breached the build's ``memory_budget_bytes``.

    Raised by :class:`MemoryWatchdog` at a probe boundary (between
    superchunk dispatches / after a build) — the failover ladder catches it
    and retries on the next-cheaper engine."""

    def __init__(self, observed: int, budget: int) -> None:
        super().__init__(
            f"live device bytes {observed} exceed memory_budget_bytes="
            f"{budget} ({_fmt_bytes(observed)} > {_fmt_bytes(budget)})"
        )
        self.observed = int(observed)
        self.budget = int(budget)


class MemoryWatchdog:
    """Runtime guard: compare the live-bytes census against a budget at
    every check.  Callable, so it plugs directly into the streaming
    engines' per-superchunk ``probe`` hook; ``peak``/``checks`` are the
    census the receipts and benchmarks read back."""

    def __init__(self, budget_bytes: int) -> None:
        if not _is_int(budget_bytes) or budget_bytes < 1:
            raise ValueError(
                f"budget_bytes must be a positive int, got {budget_bytes!r}"
            )
        self.budget_bytes = int(budget_bytes)
        self.checks = 0
        self.peak = 0

    def check(self) -> int:
        b = live_bytes()
        self.checks += 1
        if b > self.peak:
            self.peak = b
        if b > self.budget_bytes:
            raise MemoryBudgetExceeded(b, self.budget_bytes)
        return b

    __call__ = check


# --------------------------------------------------------------------------
# ExecutionPlan
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """The compiled execution of a :class:`CoresetSpec` on one dataset.

    ``engine`` is concrete (one of :data:`ENGINES`); every knob is resolved
    (``backend`` never ``"auto"``, ``chunk_blocks`` clamped to the block
    count with the clamp recorded in ``notes``).  ``predicted_comm_units``
    is exact, not an estimate: Algorithm 1's total is independent of the
    realised round-2 counts (2T + m + 2mT per DIS cell, mT per uniform
    cell), so the bill is known before any draw.  ``memory_model`` keeps
    every engine's predicted peak so tests can pin the selection
    thresholds; ``predicted_peak_bytes`` is the chosen engine's entry.
    """

    spec: CoresetSpec
    engine: str
    backend: str
    task_name: str
    n: int
    T: int
    dims: Tuple[int, ...]          # per-party feature widths (sans label col)
    stacked_width: int
    nb: int
    bs: int
    chunk_blocks: int
    prefetch: bool
    grid: Tuple[int, int]                  # (num_seeds, num_budgets)
    m_cap: int
    memory_model: Mapping[str, int]
    predicted_peak_bytes: int
    predicted_comm_units: int
    #: Resolved wire codec (never ``"auto"``) and its predicted bill in
    #: bits.  Exact for ``raw_fp32`` (32 bits/unit); a certified upper
    #: bound for codecs with varint index uploads.  ``comm_budget_exceeded``
    #: flags a plan whose cheapest admissible codec still overshoots
    #: ``spec.comm_budget_bits`` — recorded, never silently dropped.
    codec: str = "raw_fp32"
    predicted_wire_bits: int = 0
    comm_budget_exceeded: bool = False
    budget_exceeded: bool = False
    notes: Tuple[str, ...] = ()
    #: Ordered engines to retry on if this plan's engine crashes or breaches
    #: its runtime memory budget — the cheaper tail of the failover ladder
    #: materialized -> pipelined -> streamed.  Empty for batched (grid
    #: semantics don't failover) and for streamed (already the
    #: minimum-footprint engine).  PR 5's executor contract makes
    #: pipelined -> streamed draw-identical; materialized -> pipelined
    #: switches to the streaming draw path (each engine's own canonical
    #: draw, same Thm 2.5 guarantee).
    fallback_chain: Tuple[str, ...] = ()

    @property
    def is_grid(self) -> bool:
        return self.grid[0] > 1 or self.grid[1] > 1

    def describe(self) -> str:
        """Human-readable plan: engine, geometry, memory table, comm bill,
        and every planner decision (clamps, lowerings, budget verdict)."""
        spec = self.spec
        lines = [
            f"ExecutionPlan: engine={self.engine}"
            + (" (jit)" if spec.jit and self.engine == "materialized" else "")
            + (" +sharded_masses" if spec.sharded_masses else ""),
            f"  task={self.task_name} backend={self.backend} "
            f"grid={self.grid[0]}x{self.grid[1]} budgets={spec.budgets} "
            f"m_cap={self.m_cap} fault_policy={spec.fault_policy}",
            f"  data: n={self.n} T={self.T} s={self.stacked_width} "
            f"blocks: {self.nb} x {self.bs} rows "
            f"(block_size={spec.block_size})",
        ]
        if self.engine in ("streamed", "pipelined"):
            lines.append(
                f"  streaming knobs: chunk_blocks={self.chunk_blocks} "
                f"prefetch={'on' if self.prefetch else 'off'}"
            )
        validators = ("on" if spec.fault_policy in ("fail", "quarantine")
                      else "off")
        lines.append(
            f"  integrity: wire envelopes on transported rounds 1-2; "
            f"value validators {validators} "
            f"(policy={spec.fault_policy})"
        )
        mm = ", ".join(f"{e}={_fmt_bytes(self.memory_model[e])}"
                       for e in ENGINES)
        lines.append(f"  memory model: {mm}")
        if spec.memory_budget_bytes is None:
            lines.append(
                f"  budget: none -> {self.engine} "
                f"(predicted peak {_fmt_bytes(self.predicted_peak_bytes)})"
            )
        else:
            verdict = ("EXCEEDS budget — streamed is the minimum-footprint "
                       "engine" if self.budget_exceeded else "fits")
            lines.append(
                f"  budget: {_fmt_bytes(spec.memory_budget_bytes)} -> "
                f"{self.engine} (predicted peak "
                f"{_fmt_bytes(self.predicted_peak_bytes)}, {verdict})"
            )
        lines.append(
            f"  predicted comm: {self.predicted_comm_units} units "
            f"({fmt_bits(self.predicted_wire_bits)} on the wire, "
            f"codec={self.codec})"
        )
        if spec.comm_budget_bits is not None:
            verdict = ("EXCEEDS budget — no admissible codec fits"
                       if self.comm_budget_exceeded else "fits")
            lines.append(
                f"  comm budget: {fmt_bits(spec.comm_budget_bits)} -> "
                f"{self.codec} ({fmt_bits(self.predicted_wire_bits)}, "
                f"{verdict})"
            )
        for note in self.notes:
            lines.append(f"  note: {note}")
        return "\n".join(lines)


# --------------------------------------------------------------------------
# Plan cache — the serving layer's compile-once seam
# --------------------------------------------------------------------------

#: CoresetSpec fields folded verbatim into the plan-cache key, in key
#: order.  ``task`` and ``params`` are encoded specially (registry name;
#: sorted item tuple).  The key-audit test asserts every CoresetSpec field
#: appears here, in the special pair, or on PLAN_KEY_EXEMPT — so a new
#: knob (fault_policy in PR 7, the integrity policy now) can never
#: silently alias cached plans.
PLAN_KEY_FIELDS = (
    "engine", "backend", "jit", "budgets", "num_seeds", "block_size",
    "chunk_blocks", "prefetch", "memory_budget_bytes", "sharded_masses",
    "m_cap", "fault_policy", "codec", "comm_budget_bits",
)

#: Spec fields deliberately excluded from the cache key, each with the
#: reason it cannot alias a cached plan.  Currently empty: every knob
#: influences planning or execution.
PLAN_KEY_EXEMPT: Tuple[str, ...] = ()


class PlanCache:
    """Memoized :func:`compile_plan`, keyed by ``(task, dataset geometry,
    resolved knobs)``.

    A long-lived service compiles the SAME plan over and over: every tenant
    streaming fixed-size superchunks presents the same ``(task, shapes,
    knobs)`` signature, and — because the executors' jit caches key on the
    same shapes — a plan-cache hit also means every jitted program the
    engine dispatches is already compiled.  That is the warm/cold latency
    story BENCH_kernels.json measures (warm 690k rows/s vs cold 240k on the
    pipelined engine): the plan itself is cheap, the warmup it signals is
    not.  ``hits``/``misses`` are exposed so the serving benchmark can
    report the ratio.

    A cached plan is geometry-checked at dispatch time
    (:meth:`CoresetPipeline.build` rejects a plan whose ``(n, dims)`` do
    not match the dataset), so sharing one cache across tenants/datasets is
    safe: different shapes occupy different keys.  ``spec.params`` values
    must be hashable (the shipped task knobs — ints/floats — are).

    ``max_entries`` bounds the cache LRU-style: a long-lived service seeing
    an unbounded variety of shapes (many tenants, many chunk sizes) evicts
    the least-recently-USED plan instead of growing forever.  Evicting a
    plan only costs a recompile on the next miss — correctness is
    unaffected.  ``evictions`` counts them; :meth:`stats` is the
    one-call census the serving layer surfaces.
    """

    DEFAULT_MAX_ENTRIES = 256

    def __init__(self, max_entries: Optional[int] = None, *,
                 time_fn=None) -> None:
        from collections import OrderedDict

        if max_entries is None:
            max_entries = self.DEFAULT_MAX_ENTRIES
        if not _is_int(max_entries) or max_entries < 1:
            raise ValueError(
                f"max_entries must be a positive int, got {max_entries!r}"
            )
        self.max_entries = int(max_entries)
        self._plans: "OrderedDict[tuple, ExecutionPlan]" = OrderedDict()
        # last_used ages entries so a long-lived service can shed stale
        # shape signatures (prune) instead of waiting for LRU pressure;
        # time_fn is injectable for deterministic aging tests.
        self._time_fn = time.monotonic if time_fn is None else time_fn
        self._last_used: dict = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @staticmethod
    def key(spec: CoresetSpec, ds: VFLDataset) -> tuple:
        task = spec.task if isinstance(spec.task, str) else spec.task.name
        return (
            (task, ds.n, ds.dims, ds.y is not None)
            + tuple(getattr(spec, f) for f in PLAN_KEY_FIELDS)
            + (tuple(sorted(spec.params.items())),)
        )

    def get(self, spec: CoresetSpec, ds: VFLDataset) -> "ExecutionPlan":
        k = self.key(spec, ds)
        plan = self._plans.get(k)
        if plan is None:
            self.misses += 1
            plan = compile_plan(spec, ds)
            self._plans[k] = plan
            if len(self._plans) > self.max_entries:
                old, _ = self._plans.popitem(last=False)  # least recently used
                self._last_used.pop(old, None)
                self.evictions += 1
        else:
            self.hits += 1
            self._plans.move_to_end(k)
        self._last_used[k] = self._time_fn()
        return plan

    def __len__(self) -> int:
        return len(self._plans)

    def clear(self) -> None:
        self._plans.clear()
        self._last_used.clear()

    def prune(self, max_idle_s: float) -> int:
        """Evict every entry unused for more than ``max_idle_s`` seconds.
        Returns the number evicted (also added to ``evictions``).  Cheap to
        call periodically — correctness is unaffected; a pruned plan just
        recompiles on its next miss."""
        if not (isinstance(max_idle_s, (int, float)) and max_idle_s >= 0):
            raise ValueError(
                f"max_idle_s must be a non-negative number, got {max_idle_s!r}"
            )
        now = self._time_fn()
        stale = [k for k, t in self._last_used.items()
                 if now - t > max_idle_s]
        for k in stale:
            self._plans.pop(k, None)
            self._last_used.pop(k, None)
        self.evictions += len(stale)
        return len(stale)

    def stats(self) -> dict:
        now = self._time_fn()
        ages = [now - t for t in self._last_used.values()]
        return {
            "size": len(self._plans),
            "max_entries": self.max_entries,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "oldest_idle_s": max(ages) if ages else 0.0,
            "newest_idle_s": min(ages) if ages else 0.0,
        }


# --------------------------------------------------------------------------
# The planner
# --------------------------------------------------------------------------

def _cell_comm(T: int, m: int, uniform: bool) -> int:
    """Exact per-cell bill via CommSchedule — the DIS total is independent
    of the realised a_j split (:meth:`CommSchedule.dis_total`)."""
    if uniform:
        return CommSchedule.uniform(T, m).total
    return CommSchedule.dis_total(T, m)


def compile_plan(spec: CoresetSpec, ds: VFLDataset) -> ExecutionPlan:
    """Compile ``spec`` against ``ds``'s geometry into an ExecutionPlan.

    Pure planning — no scoring work, no draws; the only jax state consulted
    is the default backend (for ``backend="auto"`` and the prefetch
    default).  Raises the task's label requirement eagerly so a bad spec
    fails before any engine runs.
    """
    import jax

    from repro.core.api import get_task, resolve_backend

    task = get_task(spec.task)
    backend = resolve_backend(spec.backend)
    if task.needs_labels and ds.y is None:
        raise ValueError(f"{task.name} requires labels at party T")

    uniform = task.score_fn is None
    with_labels = task.needs_labels and ds.y is not None
    if uniform:
        s = 0
    else:
        _, s = ds.stacked_widths(with_labels=with_labels)
    n, T = ds.n, ds.T
    nb, bs = block_geometry(n, spec.block_size)
    R, M = spec.num_seeds, len(spec.budgets)
    m_cap = max(spec.budgets) if spec.m_cap is None else spec.m_cap

    notes = []

    # -- streaming knob resolution (explicit planner decisions) --------------
    chunk_req = (DEFAULT_CHUNK_BLOCKS if spec.chunk_blocks is None
                 else int(spec.chunk_blocks))
    chunk = min(chunk_req, nb)
    # prefetch default = the measured winner per backend, NOT "accelerator
    # => on" folklore.  BENCH_kernels.json streaming sweep on CPU:
    # 918,245 rows/s without prefetch vs 690,124 with it — the host thread
    # feeding the staging slot steals the cores the compute needs.
    if spec.prefetch is None:
        prefetch = PREFETCH_DEFAULT.get(jax.default_backend(), True)
    else:
        prefetch = bool(spec.prefetch)

    mm = memory_model(T, n, s, bs, chunk, R, M, m_cap, scored=not uniform)

    # -- engine selection ----------------------------------------------------
    budget_exceeded = False
    if spec.is_grid:
        if spec.engine not in ("auto", "batched"):
            raise ValueError(
                f"engine={spec.engine!r} builds one coreset per call; a "
                f"{R}x{M} grid requires engine='batched' (or 'auto')"
            )
        if spec.fault_policy != "fail":
            raise ValueError(
                f"fault_policy={spec.fault_policy!r} delivers per-round "
                f"schedules through a transport; the batched engine bills "
                f"its cells lazily and cannot combine with it"
            )
        engine = "batched"
        if spec.engine == "auto":
            notes.append(f"{R}x{M} grid -> batched (one compiled call)")
    elif spec.engine != "auto":
        engine = spec.engine
    elif spec.memory_budget_bytes is None:
        engine = "materialized"
    else:
        B = spec.memory_budget_bytes
        if mm["materialized"] <= B:
            engine = "materialized"
        elif mm["pipelined"] <= B:
            engine = "pipelined"
        else:
            engine = "streamed"
            budget_exceeded = mm["streamed"] > B
        notes.append(
            f"auto-selected {engine} for memory_budget_bytes={B} "
            f"(materialized needs {mm['materialized']}, pipelined "
            f"{mm['pipelined']}, streamed {mm['streamed']})"
        )

    # the streamed engine IS the pipelined engine at C=1 without prefetch —
    # normalize both directions so dispatch is unambiguous
    lowered_from_pipelined = False
    if engine == "streamed":
        chunk, prefetch = 1, False
    elif engine == "pipelined" and chunk == 1 and not prefetch:
        engine = "streamed"
        lowered_from_pipelined = True
        notes.append(
            "pipelined at chunk_blocks=1 without prefetch IS the "
            "block-at-a-time engine -> lowered to streamed"
        )
    if chunk_req > nb and (engine == "pipelined" or lowered_from_pipelined):
        # the documented planner clamp (NOT silent coercion: recorded here,
        # printed by describe()) — a superchunk cannot span more than nb
        # blocks, so chunk_blocks >= nb means one full-span superchunk.
        # Forced-streamed plans ignore chunk_blocks entirely (chunk = 1), so
        # no clamp note there.
        notes.append(
            f"chunk_blocks clamped {chunk_req} -> {nb}: n={n} at "
            f"block_size={spec.block_size} has only {nb} blocks "
            f"(one full-span superchunk)"
        )

    # spec flags that only make sense on SOME engines must not be dropped
    # silently when the auto-planner picks another one — mirror the forced
    # combinations CoresetSpec.__post_init__ already rejects
    if spec.jit and engine not in ("materialized", "batched"):
        raise ValueError(
            f"jit=True is the materialized/batched fused path, but the "
            f"auto-planner selected engine {engine!r} — drop jit or force "
            f"a compatible engine"
        )
    if spec.sharded_masses:
        if engine not in ("streamed", "pipelined"):
            raise ValueError(
                f"sharded_masses computes the streaming block-mass table, "
                f"but the planner selected engine {engine!r} — force a "
                f"streaming engine or drop the toggle"
            )
        if backend == "norm":
            raise ValueError(
                "sharded_masses computes the task's real score masses; it "
                "cannot combine with backend='norm'"
            )
        if task.name not in ("vrlr", "vkmc"):
            raise ValueError(
                f"sharded_masses supports tasks ('vrlr', 'vkmc'), got "
                f"{task.name!r}"
            )
        D = jax.device_count()
        if not uniform and (n % D != 0 or (n // D) % bs != 0):
            # the shard-grid requirement _check_shard_grid enforces at run
            # time, surfaced at PLAN time so a bad spec fails before work
            raise ValueError(
                f"sharded_masses needs n divisible by the device count and "
                f"the per-device shard divisible by the block size: n={n}, "
                f"devices={D}, bs={bs}"
            )

    comm = R * sum(_cell_comm(T, m, uniform) for m in spec.budgets)

    # -- wire codec resolution (the comm-budget axis) ------------------------
    # The round-1 mass table a party uploads has one entry per scoring cell:
    # the full n-row table on the materialized/batched paths, the nb
    # block-mass table on the streaming engines.  Bits are exact for every
    # shape-determined message; varint uploads contribute their certified
    # upper bound, so the prediction is a ceiling the realized bill never
    # crosses.
    cells = n if engine in ("materialized", "batched") else nb
    lossless_only = spec.jit or engine == "batched"
    if spec.codec not in ("auto", "raw_fp32") and lossless_only:
        raise ValueError(
            f"codec={spec.codec!r} quantizes per-round payloads, but the "
            f"planner selected the "
            f"{'jit fused' if spec.jit else 'batched'} path — use "
            f"codec='raw_fp32' or a transported engine"
        )

    def _predict(name: str) -> int:
        if uniform:
            return R * sum(predict_uniform_bits(T, m) for m in spec.budgets)
        return R * sum(predict_dis_bits(T, m, cells, name)
                       for m in spec.budgets)

    if spec.codec == "auto" and lossless_only:
        # the only admissible codec on a never-leaves-device path
        codec, wire_bits = "raw_fp32", _predict("raw_fp32")
        comm_budget_exceeded = (
            spec.comm_budget_bits is not None
            and wire_bits > spec.comm_budget_bits
        )
        if comm_budget_exceeded:
            notes.append(
                f"comm budget {spec.comm_budget_bits}b unmeetable: the "
                f"{'jit' if spec.jit else 'batched'} path admits only "
                f"raw_fp32 ({wire_bits}b predicted)"
            )
    else:
        bits_by_codec = {name: _predict(name) for name in CODEC_LADDER}
        codec, comm_budget_exceeded, codec_note = choose_codec(
            spec.codec, spec.comm_budget_bits, bits_by_codec
        )
        wire_bits = bits_by_codec[codec]
        if codec_note:
            notes.append(codec_note)

    # failover ladder: the cheaper engines after the chosen one.  jit and
    # sharded_masses bind the spec to specific engines (validated above), so
    # those plans pin their engine and never failover.
    if engine in FAILOVER_LADDER and not spec.jit and not spec.sharded_masses:
        fallback = FAILOVER_LADDER[FAILOVER_LADDER.index(engine) + 1:]
    else:
        fallback = ()

    return ExecutionPlan(
        spec=spec,
        engine=engine,
        backend=backend,
        task_name=task.name,
        n=n, T=T, dims=ds.dims, stacked_width=s, nb=nb, bs=bs,
        chunk_blocks=chunk, prefetch=prefetch,
        grid=(R, M), m_cap=m_cap,
        memory_model=mm,
        predicted_peak_bytes=mm[engine],
        predicted_comm_units=comm,
        codec=codec,
        predicted_wire_bits=wire_bits,
        comm_budget_exceeded=comm_budget_exceeded,
        budget_exceeded=budget_exceeded,
        notes=tuple(notes),
        fallback_chain=fallback,
    )
