"""Communication accounting for the VFL model of the paper.

The paper (Section 2) counts one unit per transported integer/float, so a
d-dimensional vector costs d units.  Every protocol in ``repro.core`` takes an
optional :class:`CommLedger` and records each message with its direction and
round, so benchmarks can reproduce the paper's communication-complexity
columns exactly (Table 1 "Com. compl.").

Alongside units, every message carries a ``bits`` column: the packed size
of the bytes that physically cross the wire.  Scalar control messages
default to one 32-bit word per unit (the paper's float/int is a raw
float32 on the wire); ops that carry a real payload — the round-1 mass
tables, the round-2 index uploads — bill their codec's packed size via a
:class:`~repro.core.wire.WirePayload` descriptor instead.  The units
column is untouched by compression: it stays the paper's abstract count,
while bits answer "how many bytes did that actually cost".
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.wire import UNIT_BITS, WirePayload, fmt_bits


@dataclasses.dataclass
class Message:
    """One logical message in the star topology (server <-> party)."""

    tag: str          # e.g. "dis/round1/G_j"
    src: str          # "server" or "party:<j>"
    dst: str
    units: int        # floats/ints transported (paper Section 2 count)
    bits: int = 0     # packed bits on the wire (codec-measured)


class CommLedger:
    """Unit-accounting ledger for server<->party communication.

    Only server<->party links exist (paper Section 2 / Figure 1a); any
    party<->party exchange must be relayed and is recorded as two messages.
    """

    def __init__(self) -> None:
        self.messages: List[Message] = []
        self._by_tag: Dict[str, int] = defaultdict(int)
        self._bits_by_tag: Dict[str, int] = defaultdict(int)

    def send(self, tag: str, src: str, dst: str, units: int,
             bits: Optional[int] = None) -> None:
        if units < 0:
            raise ValueError(f"negative units for {tag}: {units}")
        if bits is None:
            bits = UNIT_BITS * int(units)
        if bits < 0:
            raise ValueError(f"negative bits for {tag}: {bits}")
        self.messages.append(Message(tag, src, dst, int(units), int(bits)))
        self._by_tag[tag] += int(units)
        self._bits_by_tag[tag] += int(bits)

    # -- convenience wrappers ------------------------------------------------
    def party_to_server(self, tag: str, party: int, units: int,
                        bits: Optional[int] = None) -> None:
        self.send(tag, f"party:{party}", "server", units, bits)

    def server_to_party(self, tag: str, party: int, units: int,
                        bits: Optional[int] = None) -> None:
        self.send(tag, "server", f"party:{party}", units, bits)

    def broadcast(self, tag: str, n_parties: int, units_each: int,
                  bits_each: Optional[int] = None) -> None:
        for j in range(n_parties):
            self.server_to_party(tag, j, units_each, bits_each)

    # -- queries ---------------------------------------------------------------
    @property
    def total(self) -> int:
        return sum(m.units for m in self.messages)

    @property
    def total_bits(self) -> int:
        """Packed bits across every message — the honest wire total the
        unit column abstracts away."""
        return sum(m.bits for m in self.messages)

    def by_tag(self, *, bits: bool = False) -> Dict[str, int]:
        """Per-tag units (default) or, with ``bits=True``, per-tag packed
        wire bits — same keys, the byte-billed view of the same traffic."""
        return dict(self._bits_by_tag if bits else self._by_tag)

    def by_prefix(self, prefix: str, *, bits: bool = False) -> int:
        src = self._bits_by_tag if bits else self._by_tag
        return sum(u for t, u in src.items() if t.startswith(prefix))

    def fork(self) -> "CommLedger":
        """Fresh ledger (used to isolate a sub-protocol's cost)."""
        return CommLedger()

    # -- crash-safe snapshots ------------------------------------------------
    def mark(self) -> int:
        """A rollback point: the current message count.  Pair with
        :meth:`rollback` to undo a failed multi-schedule operation (e.g. a
        tree insert that died mid-merge) so the composed bill never counts
        work that was rolled back."""
        return len(self.messages)

    def rollback(self, mark: int) -> None:
        """Truncate to the state :meth:`mark` captured (``_by_tag`` is
        rebuilt from the surviving messages)."""
        if not 0 <= mark <= len(self.messages):
            raise ValueError(
                f"bad mark {mark}: ledger has {len(self.messages)} messages"
            )
        del self.messages[mark:]
        self._by_tag = defaultdict(int)
        self._bits_by_tag = defaultdict(int)
        for m in self.messages:
            self._by_tag[m.tag] += m.units
            self._bits_by_tag[m.tag] += m.bits

    def since(self, mark: int, *, bits: bool = False) -> int:
        """Units (or packed bits, with ``bits=True``) recorded after a
        :meth:`mark` — the cost delta of the bracketed operation (e.g. the
        integrity benchmark reads one build's retransmission overhead off
        this without forking ledgers)."""
        if not 0 <= mark <= len(self.messages):
            raise ValueError(
                f"bad mark {mark}: ledger has {len(self.messages)} messages"
            )
        if bits:
            return sum(m.bits for m in self.messages[mark:])
        return sum(m.units for m in self.messages[mark:])

    def merge(self, other: "CommLedger") -> None:
        for m in other.messages:
            self.send(m.tag, m.src, m.dst, m.units, m.bits)

    def summary(self) -> str:
        lines = [f"total={self.total} units "
                 f"({fmt_bits(self.total_bits)} on the wire)"]
        for tag in sorted(self._by_tag):
            lines.append(f"  {tag}: {self._by_tag[tag]} "
                         f"({fmt_bits(self._bits_by_tag[tag])})")
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class CommOp:
    """One planned message: party j's uplink (or downlink when ``down``).

    ``payload`` states what the message physically carries on the wire
    (shape/dtype/codec + packed bits); ops without one are scalar control
    messages billed at one 32-bit word per unit."""

    tag: str
    party: int
    units: int
    down: bool = False    # True: server -> party, False: party -> server
    payload: Optional[WirePayload] = None

    @property
    def bits(self) -> int:
        """Packed wire bits this op bills — the descriptor's measured
        size, or the raw-word default for scalar messages."""
        return self.payload.bits if self.payload is not None \
            else UNIT_BITS * self.units


@dataclasses.dataclass(frozen=True)
class CommSchedule:
    """Declarative per-round ledger entries for a protocol execution.

    The jittable protocol cores (:func:`repro.core.dis.dis_plan`) carry no
    ledger side effects; instead the exact entries are *derived after the
    fact* from the protocol parameters — ``(T, m)`` plus, for DIS round 2,
    the realised per-party sample counts ``a_j``.  ``record`` replays the
    schedule onto a :class:`CommLedger`, producing the same bill the seed's
    in-line accounting did, without ever entering the traced hot path.
    """

    ops: Tuple[CommOp, ...]

    @property
    def total(self) -> int:
        return sum(op.units for op in self.ops)

    @property
    def total_bits(self) -> int:
        """Packed wire bits for the whole schedule (payload-descriptor
        bits where present, one raw word per unit otherwise)."""
        return sum(op.bits for op in self.ops)

    def record(self, ledger: Optional["CommLedger"]) -> "CommSchedule":
        """Replay onto ``ledger`` (no-op when None); returns self for chaining."""
        if ledger is not None:
            for op in self.ops:
                if op.down:
                    ledger.server_to_party(op.tag, op.party, op.units,
                                           op.bits)
                else:
                    ledger.party_to_server(op.tag, op.party, op.units,
                                           op.bits)
        return self

    def __add__(self, other: "CommSchedule") -> "CommSchedule":
        return CommSchedule(self.ops + other.ops)

    @staticmethod
    def dis(
        T: int, m: int, counts: Sequence[int],
        round1_payload: Optional[WirePayload] = None,
        upload_payloads: Optional[Sequence[Optional[WirePayload]]] = None,
    ) -> "CommSchedule":
        """Algorithm 1's three rounds.  ``counts`` is the realised a_j vector
        (sum = m): round 2's m index uploads are attributed to the party that
        actually sent them, not lumped onto party 0.

        Composed from :meth:`dis_round1` + :meth:`dis_rounds23` with
        identical op order — the split exists so a fault-aware executor can
        deliver round 1 BEFORE scoring (the point where a party can still
        drop under ``fault_policy="degrade"``) and rounds 2-3 after the
        draw, while fault-free delivery of the two halves back to back is
        bit-identical to this one-shot schedule.

        ``round1_payload`` / ``upload_payloads`` are the wire descriptors
        for the two messages that carry real payloads (the per-party mass
        table row, the per-party index upload) — they change the bits
        column only, never units."""
        return (CommSchedule.dis_round1(T, payload=round1_payload)
                + CommSchedule.dis_rounds23(
                    T, m, counts, upload_payloads=upload_payloads))

    @staticmethod
    def dis_round1(
        T: int, parties: Optional[Sequence[int]] = None,
        payload: Optional[WirePayload] = None,
    ) -> "CommSchedule":
        """DIS round 1 only: each party's total-score scalar up, its a_j
        scalar down.  ``parties`` restricts (and re-labels) the ops to a
        surviving subset — ids stay the ORIGINAL party numbers so degraded
        builds bill against the parties that actually spoke.  ``payload``
        describes the mass-table row each party's G_j upload physically
        carries (the scalar is the paper's unit count; the row is what
        crosses the wire)."""
        ids = list(range(T)) if parties is None else [int(j) for j in parties]
        ops: List[CommOp] = []
        ops += [CommOp("dis/round1/G_j", j, 1, payload=payload) for j in ids]
        ops += [CommOp("dis/round1/a_j", j, 1, down=True) for j in ids]
        return CommSchedule(tuple(ops))

    @staticmethod
    def dis_rounds23(
        T: int, m: int, counts: Sequence[int],
        parties: Optional[Sequence[int]] = None,
        upload_payloads: Optional[Sequence[Optional[WirePayload]]] = None,
    ) -> "CommSchedule":
        """DIS rounds 2-3: per-party index uploads (the realised a_j),
        the m-index broadcast, and the m score uploads.  ``parties`` maps
        position i of ``counts`` to original party id ``parties[i]`` for
        degraded builds over a surviving subset; ``upload_payloads``
        (aligned with ``counts``) carries each S_up op's measured wire
        descriptor for the bits column."""
        counts = [int(c) for c in counts]
        ids = (list(range(T)) if parties is None
               else [int(j) for j in parties])
        if len(counts) != len(ids) or sum(counts) != m:
            raise ValueError(
                f"bad round-2 counts {counts} for parties={ids}, m={m}"
            )
        if upload_payloads is None:
            upload_payloads = [None] * len(ids)
        if len(upload_payloads) != len(ids):
            raise ValueError(
                f"{len(upload_payloads)} upload payloads for "
                f"{len(ids)} parties"
            )
        ops: List[CommOp] = []
        ops += [CommOp("dis/round2/S_up", j, c, payload=p)
                for j, c, p in zip(ids, counts, upload_payloads)]
        ops += [CommOp("dis/round2/S_bcast", j, m, down=True) for j in ids]
        ops += [CommOp("dis/round3/g_scores", j, m) for j in ids]
        return CommSchedule(tuple(ops))

    @staticmethod
    def dis_total(T: int, m: int) -> int:
        """Algorithm 1's exact total bill, BEFORE any draw happens.

        The total is independent of the realised round-2 split (the a_j
        only re-attribute the m index uploads between parties):
        2T (round 1) + m (round 2 up) + mT (round 2 broadcast) + mT
        (round 3).  This is what lets the planner
        (:mod:`repro.core.plan`) predict the bill exactly at compile time.
        """
        return CommSchedule.dis(T, m, counts=[m] + [0] * (T - 1)).total

    @staticmethod
    def uniform(T: int, m: int) -> "CommSchedule":
        """U-* baseline: the server broadcasts its m uniform indices (mT)."""
        return CommSchedule(
            tuple(CommOp("uniform/S_bcast", j, m, down=True) for j in range(T))
        )

    @staticmethod
    def merge(T: int, m_left: int, m_right: int) -> "CommSchedule":
        """Theorem 2.5's composition bill for one merge-and-reduce node:
        the downstream scheme (here: DIS re-sampling over the union)
        consumes TWO materialized coresets, so each party receives the
        ``m_left + m_right`` selected indices and contributes its per-row
        scalar shares — ``+2mT`` per consumed child, under ``merge/`` tags.

        This is :meth:`materialize`'s accounting promoted to a named
        schedule so every level of a merge-and-reduce tree
        (:mod:`repro.serve.tree`) bills uniformly; per-party units are
        identical to ``materialize(T, m_left) + materialize(T, m_right)``.
        The re-sampling DIS run over the union is billed separately (its
        :meth:`dis` schedule), exactly as a leaf build would be.
        """
        if m_left < 0 or m_right < 0:
            raise ValueError(
                f"merge sizes must be >= 0, got ({m_left}, {m_right})"
            )
        m_u = int(m_left) + int(m_right)
        ops = [CommOp("merge/S_down", j, m_u, down=True) for j in range(T)]
        ops += [CommOp("merge/rows_up", j, m_u) for j in range(T)]
        return CommSchedule(tuple(ops))

    @staticmethod
    def materialize(T: int, m: int) -> "CommSchedule":
        """Theorem 2.5's ``+2mT`` term: when the downstream scheme A runs
        in-protocol on the coreset, each party receives the m selected
        indices (m down) and contributes its m per-row scalar shares (m up).

        This is the paper's composition bill (see :meth:`merge` for the
        two-coreset form a merge-and-reduce node pays).  Shipping the raw
        feature blocks of the m rows to a central solver instead costs
        ``sum_j m*d_j`` — the benchmarks account that convention explicitly
        (their ``materialize/rows`` entries); don't mix the two on one
        ledger."""
        ops = [CommOp("materialize/S_down", j, m, down=True) for j in range(T)]
        ops += [CommOp("materialize/rows_up", j, m) for j in range(T)]
        return CommSchedule(tuple(ops))


def theoretical_dis_cost(m: int, T: int) -> Tuple[int, int]:
    """(lower, upper) unit bounds for Algorithm 1 given m samples, T parties.

    Round 1: T (G_j up) + T (a_j down); round 2: <=m (indices up) + m*T
    (S broadcast); round 3: m*T (scores up).  Total in [2T + 2m, 2T + m + 2mT].
    """
    return 2 * T + 2 * m, 2 * T + m + 2 * m * T


def null_ledger(ledger: Optional[CommLedger]) -> CommLedger:
    """Allow ``ledger=None`` call sites without branching everywhere."""
    return ledger if ledger is not None else CommLedger()
