"""Communication accounting for the VFL model of the paper.

The paper (Section 2) counts one unit per transported integer/float, so a
d-dimensional vector costs d units.  Every protocol in ``repro.core`` takes an
optional :class:`CommLedger` and records each message with its direction and
round, so benchmarks can reproduce the paper's communication-complexity
columns exactly (Table 1 "Com. compl.").
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class Message:
    """One logical message in the star topology (server <-> party)."""

    tag: str          # e.g. "dis/round1/G_j"
    src: str          # "server" or "party:<j>"
    dst: str
    units: int        # floats/ints transported


class CommLedger:
    """Unit-accounting ledger for server<->party communication.

    Only server<->party links exist (paper Section 2 / Figure 1a); any
    party<->party exchange must be relayed and is recorded as two messages.
    """

    def __init__(self) -> None:
        self.messages: List[Message] = []
        self._by_tag: Dict[str, int] = defaultdict(int)

    def send(self, tag: str, src: str, dst: str, units: int) -> None:
        if units < 0:
            raise ValueError(f"negative units for {tag}: {units}")
        self.messages.append(Message(tag, src, dst, int(units)))
        self._by_tag[tag] += int(units)

    # -- convenience wrappers ------------------------------------------------
    def party_to_server(self, tag: str, party: int, units: int) -> None:
        self.send(tag, f"party:{party}", "server", units)

    def server_to_party(self, tag: str, party: int, units: int) -> None:
        self.send(tag, "server", f"party:{party}", units)

    def broadcast(self, tag: str, n_parties: int, units_each: int) -> None:
        for j in range(n_parties):
            self.server_to_party(tag, j, units_each)

    # -- queries ---------------------------------------------------------------
    @property
    def total(self) -> int:
        return sum(m.units for m in self.messages)

    def by_tag(self) -> Dict[str, int]:
        return dict(self._by_tag)

    def by_prefix(self, prefix: str) -> int:
        return sum(u for t, u in self._by_tag.items() if t.startswith(prefix))

    def fork(self) -> "CommLedger":
        """Fresh ledger (used to isolate a sub-protocol's cost)."""
        return CommLedger()

    # -- crash-safe snapshots ------------------------------------------------
    def mark(self) -> int:
        """A rollback point: the current message count.  Pair with
        :meth:`rollback` to undo a failed multi-schedule operation (e.g. a
        tree insert that died mid-merge) so the composed bill never counts
        work that was rolled back."""
        return len(self.messages)

    def rollback(self, mark: int) -> None:
        """Truncate to the state :meth:`mark` captured (``_by_tag`` is
        rebuilt from the surviving messages)."""
        if not 0 <= mark <= len(self.messages):
            raise ValueError(
                f"bad mark {mark}: ledger has {len(self.messages)} messages"
            )
        del self.messages[mark:]
        self._by_tag = defaultdict(int)
        for m in self.messages:
            self._by_tag[m.tag] += m.units

    def since(self, mark: int) -> int:
        """Units recorded after a :meth:`mark` — the cost delta of the
        bracketed operation (e.g. the integrity benchmark reads one build's
        retransmission overhead off this without forking ledgers)."""
        if not 0 <= mark <= len(self.messages):
            raise ValueError(
                f"bad mark {mark}: ledger has {len(self.messages)} messages"
            )
        return sum(m.units for m in self.messages[mark:])

    def merge(self, other: "CommLedger") -> None:
        for m in other.messages:
            self.send(m.tag, m.src, m.dst, m.units)

    def summary(self) -> str:
        lines = [f"total={self.total}"]
        for tag in sorted(self._by_tag):
            lines.append(f"  {tag}: {self._by_tag[tag]}")
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class CommOp:
    """One planned message: party j's uplink (or downlink when ``down``)."""

    tag: str
    party: int
    units: int
    down: bool = False    # True: server -> party, False: party -> server


@dataclasses.dataclass(frozen=True)
class CommSchedule:
    """Declarative per-round ledger entries for a protocol execution.

    The jittable protocol cores (:func:`repro.core.dis.dis_plan`) carry no
    ledger side effects; instead the exact entries are *derived after the
    fact* from the protocol parameters — ``(T, m)`` plus, for DIS round 2,
    the realised per-party sample counts ``a_j``.  ``record`` replays the
    schedule onto a :class:`CommLedger`, producing the same bill the seed's
    in-line accounting did, without ever entering the traced hot path.
    """

    ops: Tuple[CommOp, ...]

    @property
    def total(self) -> int:
        return sum(op.units for op in self.ops)

    def record(self, ledger: Optional["CommLedger"]) -> "CommSchedule":
        """Replay onto ``ledger`` (no-op when None); returns self for chaining."""
        if ledger is not None:
            for op in self.ops:
                if op.down:
                    ledger.server_to_party(op.tag, op.party, op.units)
                else:
                    ledger.party_to_server(op.tag, op.party, op.units)
        return self

    def __add__(self, other: "CommSchedule") -> "CommSchedule":
        return CommSchedule(self.ops + other.ops)

    @staticmethod
    def dis(T: int, m: int, counts: Sequence[int]) -> "CommSchedule":
        """Algorithm 1's three rounds.  ``counts`` is the realised a_j vector
        (sum = m): round 2's m index uploads are attributed to the party that
        actually sent them, not lumped onto party 0.

        Composed from :meth:`dis_round1` + :meth:`dis_rounds23` with
        identical op order — the split exists so a fault-aware executor can
        deliver round 1 BEFORE scoring (the point where a party can still
        drop under ``fault_policy="degrade"``) and rounds 2-3 after the
        draw, while fault-free delivery of the two halves back to back is
        bit-identical to this one-shot schedule."""
        return (CommSchedule.dis_round1(T)
                + CommSchedule.dis_rounds23(T, m, counts))

    @staticmethod
    def dis_round1(T: int, parties: Optional[Sequence[int]] = None) -> "CommSchedule":
        """DIS round 1 only: each party's total-score scalar up, its a_j
        scalar down.  ``parties`` restricts (and re-labels) the ops to a
        surviving subset — ids stay the ORIGINAL party numbers so degraded
        builds bill against the parties that actually spoke."""
        ids = list(range(T)) if parties is None else [int(j) for j in parties]
        ops: List[CommOp] = []
        ops += [CommOp("dis/round1/G_j", j, 1) for j in ids]
        ops += [CommOp("dis/round1/a_j", j, 1, down=True) for j in ids]
        return CommSchedule(tuple(ops))

    @staticmethod
    def dis_rounds23(
        T: int, m: int, counts: Sequence[int],
        parties: Optional[Sequence[int]] = None,
    ) -> "CommSchedule":
        """DIS rounds 2-3: per-party index uploads (the realised a_j),
        the m-index broadcast, and the m score uploads.  ``parties`` maps
        position i of ``counts`` to original party id ``parties[i]`` for
        degraded builds over a surviving subset."""
        counts = [int(c) for c in counts]
        ids = (list(range(T)) if parties is None
               else [int(j) for j in parties])
        if len(counts) != len(ids) or sum(counts) != m:
            raise ValueError(
                f"bad round-2 counts {counts} for parties={ids}, m={m}"
            )
        ops: List[CommOp] = []
        ops += [CommOp("dis/round2/S_up", j, c) for j, c in zip(ids, counts)]
        ops += [CommOp("dis/round2/S_bcast", j, m, down=True) for j in ids]
        ops += [CommOp("dis/round3/g_scores", j, m) for j in ids]
        return CommSchedule(tuple(ops))

    @staticmethod
    def dis_total(T: int, m: int) -> int:
        """Algorithm 1's exact total bill, BEFORE any draw happens.

        The total is independent of the realised round-2 split (the a_j
        only re-attribute the m index uploads between parties):
        2T (round 1) + m (round 2 up) + mT (round 2 broadcast) + mT
        (round 3).  This is what lets the planner
        (:mod:`repro.core.plan`) predict the bill exactly at compile time.
        """
        return CommSchedule.dis(T, m, counts=[m] + [0] * (T - 1)).total

    @staticmethod
    def uniform(T: int, m: int) -> "CommSchedule":
        """U-* baseline: the server broadcasts its m uniform indices (mT)."""
        return CommSchedule(
            tuple(CommOp("uniform/S_bcast", j, m, down=True) for j in range(T))
        )

    @staticmethod
    def merge(T: int, m_left: int, m_right: int) -> "CommSchedule":
        """Theorem 2.5's composition bill for one merge-and-reduce node:
        the downstream scheme (here: DIS re-sampling over the union)
        consumes TWO materialized coresets, so each party receives the
        ``m_left + m_right`` selected indices and contributes its per-row
        scalar shares — ``+2mT`` per consumed child, under ``merge/`` tags.

        This is :meth:`materialize`'s accounting promoted to a named
        schedule so every level of a merge-and-reduce tree
        (:mod:`repro.serve.tree`) bills uniformly; per-party units are
        identical to ``materialize(T, m_left) + materialize(T, m_right)``.
        The re-sampling DIS run over the union is billed separately (its
        :meth:`dis` schedule), exactly as a leaf build would be.
        """
        if m_left < 0 or m_right < 0:
            raise ValueError(
                f"merge sizes must be >= 0, got ({m_left}, {m_right})"
            )
        m_u = int(m_left) + int(m_right)
        ops = [CommOp("merge/S_down", j, m_u, down=True) for j in range(T)]
        ops += [CommOp("merge/rows_up", j, m_u) for j in range(T)]
        return CommSchedule(tuple(ops))

    @staticmethod
    def materialize(T: int, m: int) -> "CommSchedule":
        """Theorem 2.5's ``+2mT`` term: when the downstream scheme A runs
        in-protocol on the coreset, each party receives the m selected
        indices (m down) and contributes its m per-row scalar shares (m up).

        This is the paper's composition bill (see :meth:`merge` for the
        two-coreset form a merge-and-reduce node pays).  Shipping the raw
        feature blocks of the m rows to a central solver instead costs
        ``sum_j m*d_j`` — the benchmarks account that convention explicitly
        (their ``materialize/rows`` entries); don't mix the two on one
        ledger."""
        ops = [CommOp("materialize/S_down", j, m, down=True) for j in range(T)]
        ops += [CommOp("materialize/rows_up", j, m) for j in range(T)]
        return CommSchedule(tuple(ops))


def theoretical_dis_cost(m: int, T: int) -> Tuple[int, int]:
    """(lower, upper) unit bounds for Algorithm 1 given m samples, T parties.

    Round 1: T (G_j up) + T (a_j down); round 2: <=m (indices up) + m*T
    (S broadcast); round 3: m*T (scores up).  Total in [2T + 2m, 2T + m + 2mT].
    """
    return 2 * T + 2 * m, 2 * T + m + 2 * m * T


def null_ledger(ledger: Optional[CommLedger]) -> CommLedger:
    """Allow ``ledger=None`` call sites without branching everywhere."""
    return ledger if ledger is not None else CommLedger()
