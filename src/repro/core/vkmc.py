"""Vertical k-means clustering (Definition 2.2): solvers and baselines.

  * ``kmeans_plusplus``  — D^2 seeding (Arthur & Vassilvitskii), weighted;
  * ``lloyd``            — weighted Lloyd iterations; the assignment step is
    the Pallas ``kmeans_assign`` kernel (the O(nkd) hot loop);
  * ``kmeans``           — seeding + Lloyd, the paper's KMEANS++ baseline;
  * ``distdim``          — Ding et al. [19] "k-means with distributed
    dimensions": the O(nT)-communication VFL baseline the paper compares
    against (each party clusters locally and ships *assignments*, the server
    clusters the concatenated local-center surrogates);
  * ``kmeans_cost``      — cost^C evaluation.

All solvers take optional per-point weights so they run unchanged on (S, w)
coresets (Theorem 2.5 composition).
"""

from __future__ import annotations

import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.comm import CommLedger, null_ledger
from repro.core.sensitivity import kmeans_assignment, kmeans_update
from repro.core.vfl import VFLDataset


def kmeans_cost(
    X: jax.Array, centers: jax.Array, w: Optional[jax.Array] = None, use_kernel: bool = True
) -> jax.Array:
    _, d2 = kmeans_assignment(X, centers, use_kernel=use_kernel)
    return jnp.sum(d2 if w is None else w * d2)


def kmeans_plusplus(
    key: jax.Array,
    X: jax.Array,
    k: int,
    w: Optional[jax.Array] = None,
) -> jax.Array:
    """Weighted D^2 seeding.  O(nkd) total, via incremental min-distances.

    Distances to each new center use the cached-norm expansion
    ``||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2``: the per-step cost is one
    (n, d) matvec instead of materialising the full (n, d) difference —
    one fewer (n, d) array per seeding step, and the row norms ``||x||^2``
    are computed once for the whole sweep.
    """
    n, d = X.shape
    ww = jnp.ones((n,)) if w is None else jnp.maximum(w, 0.0)
    x2 = jnp.sum(X * X, axis=1)                                    # cached once

    def d2_to(c):
        # clamp: the expanded form can go slightly negative under fp
        return jnp.maximum(x2 - 2.0 * (X @ c) + jnp.sum(c * c), 0.0)

    k0, key = jax.random.split(key)
    first = jax.random.categorical(k0, jnp.log(jnp.maximum(ww, 1e-30)))
    centers0 = jnp.zeros((k, d), X.dtype).at[0].set(X[first])
    d2_0 = d2_to(X[first])

    def body(carry, key_l):
        centers, d2, l = carry
        probs = jnp.maximum(ww * d2, 1e-30)
        idx = jax.random.categorical(key_l, jnp.log(probs))
        c_new = X[idx]
        centers = centers.at[l].set(c_new)
        d2 = jnp.minimum(d2, d2_to(c_new))
        return (centers, d2, l + 1), None

    keys = jax.random.split(key, k - 1)
    (centers, _, _), _ = jax.lax.scan(body, (centers0, d2_0, 1), keys)
    return centers


@functools.partial(jax.jit, static_argnames=("iters", "use_kernel"))
def lloyd(
    X: jax.Array,
    init_centers: jax.Array,
    w: Optional[jax.Array] = None,
    iters: int = 25,
    use_kernel: bool = True,
) -> jax.Array:
    """Weighted Lloyd. Empty clusters keep their previous center.

    With ``use_kernel=True`` each iteration is ONE fused
    ``kmeans_assign_update`` dispatch (one HBM read of X: assignment,
    weighted cluster sums and counts come out of the same pass — the seed
    path's assign kernel + two segment_sums collapsed).  ``use_kernel=False``
    keeps the 3-pass pure-jnp composition.
    """
    n, d = X.shape
    ww = jnp.ones((n,)) if w is None else w

    def body(centers, _):
        _, _, csum, wsum, _ = kmeans_update(X, centers, ww, use_kernel=use_kernel)
        new = jnp.where(wsum[:, None] > 0, csum / jnp.maximum(wsum, 1e-30)[:, None], centers)
        return new, None

    centers, _ = jax.lax.scan(body, init_centers, None, length=iters)
    return centers


def kmeans(
    key: jax.Array,
    X: jax.Array,
    k: int,
    w: Optional[jax.Array] = None,
    iters: int = 25,
    use_kernel: bool = True,
) -> jax.Array:
    """k-means++ seeding + Lloyd — the paper's KMEANS++ central baseline."""
    init = kmeans_plusplus(key, X, k, w)
    return lloyd(X, init, w, iters=iters, use_kernel=use_kernel)


def kmeans_central_comm_cost(n: int, dims, ledger: Optional[CommLedger] = None) -> int:
    """Central baseline ships all raw blocks: sum_j n*d_j units."""
    led = null_ledger(ledger)
    for j, dj in enumerate(dims):
        led.party_to_server("kmeans_central/raw_block", j, n * int(dj))
    return led.total


# --------------------------------------------------------------------------
# DistDim (Ding et al. 2016): the O(nT) VFL baseline
# --------------------------------------------------------------------------

def distdim(
    key: jax.Array,
    ds: VFLDataset,
    k: int,
    w: Optional[jax.Array] = None,
    local_iters: int = 15,
    global_iters: int = 25,
    ledger: Optional[CommLedger] = None,
    use_kernel: bool = True,
) -> jax.Array:
    """K-means with distributed dimensions.

    Party j clusters its block into k local centers and sends (i) the n-vector
    of local assignments and (ii) its k local centers to the server
    (communication n + k*d_j each -> O(nT) total, the cost the paper
    improves on).  The server replaces each point by the concatenation of its
    local centers (the product-partition surrogate) and runs weighted k-means
    over the surrogate points; the returned global centers live in R^d.
    """
    led = null_ledger(ledger)
    T = ds.T
    n = ds.n
    surrogate_parts: List[jax.Array] = []
    for j, Xj in enumerate(ds.parts):
        key, sub = jax.random.split(key)
        local_c = kmeans(sub, Xj, k, w, iters=local_iters, use_kernel=use_kernel)
        assign, _ = kmeans_assignment(Xj, local_c, use_kernel=use_kernel)
        surrogate_parts.append(local_c[assign])                     # (n, d_j)
        led.party_to_server("distdim/assignments", j, n)
        led.party_to_server("distdim/local_centers", j, k * Xj.shape[1])
    surrogate = jnp.concatenate(surrogate_parts, axis=1)            # (n, d)
    key, sub = jax.random.split(key)
    return kmeans(sub, surrogate, k, w, iters=global_iters, use_kernel=use_kernel)
