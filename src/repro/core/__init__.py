"""The paper's primary contribution: communication-efficient coreset
construction for vertical federated learning.

Public API:
  VFLDataset, split_columns, standardize          (vfl)
  CommLedger, theoretical_dis_cost                (comm)
  dis_sample, uniform_sample, dis_marginals       (dis — Algorithm 1)
  vrlr_local_scores, vkmc_local_scores, ...       (sensitivity — Alg 2/3 local)
  build_vrlr_coreset, build_vkmc_coreset, Coreset (coreset — Alg 2/3 e2e)
  ridge_closed_form, fista, saga_ridge, solve     (vrlr solvers)
  kmeans, kmeans_plusplus, lloyd, distdim, ...    (vkmc solvers)
  CoresetBatchSelector                            (selector — LLM integration)
"""

from repro.core.comm import CommLedger, theoretical_dis_cost
from repro.core.coreset import (
    Coreset,
    build_uniform_coreset,
    build_vkmc_coreset,
    build_vrlr_coreset,
    vkmc_coreset_ratio,
    vrlr_coreset_ratio,
)
from repro.core.dis import dis_marginals, dis_sample, uniform_sample
from repro.core.sensitivity import (
    kmeans_assignment,
    leverage_scores,
    total_sensitivity_bound_vkmc,
    total_sensitivity_bound_vrlr,
    vkmc_local_scores,
    vrlr_local_scores,
)
from repro.core.vfl import VFLDataset, split_columns, standardize
from repro.core.vkmc import distdim, kmeans, kmeans_cost, kmeans_plusplus, lloyd
from repro.core.vrlr import (
    central_comm_cost,
    elastic_cost,
    fista,
    lasso_cost,
    ridge_closed_form,
    ridge_cost,
    saga_ridge,
    solve,
    sq_loss,
)

__all__ = [n for n in dir() if not n.startswith("_")]
