"""The paper's primary contribution: communication-efficient coreset
construction for vertical federated learning.

Public API:
  CoresetSpec, ExecutionPlan, compile_plan, ENGINES,
  PlanCache                                               (plan — declarative spec
                                                           + auto-planner)
  CoresetPipeline, build_coreset, build_coreset_jit,
  build_coresets_batched, build_coreset_streaming,
  CoresetTask, register_task, get_task,
  CORESET_TASKS, SCORE_BACKENDS, resolve_backend          (api — spec-compiled engines)
  fit_ridge, fit_kmeans, evaluate, end_to_end,
  FitResult, EvalReport, full_data_coreset                (solve — downstream layer)
  VFLDataset, split_columns, standardize                  (vfl)
  CommLedger, CommSchedule, theoretical_dis_cost          (comm)
  FaultPlan, Transport, PartyUnavailable, DegradedBuild,
  DroppedParty, TransportStats, StreamCheckpoint,
  deliver_or_record, FAULT_POLICIES,
  SILENT_KINDS, perturb_payload                           (faults — party fault model)
  IntegrityError, WireEnvelope, Finding, HealthReport,
  payload_digest, check_mass_table, check_weights,
  check_merge_children, health_from_masses,
  require_valid_masses                                    (integrity — verified wire)
  Codec, get_codec, WIRE_CODECS, CODEC_LADDER,
  WirePayload, fmt_bits, UNIT_BITS,
  predict_dis_bits, predict_uniform_bits                  (wire — compressed codecs)
  dis_plan, dis_plan_full, dis_plan_blocked, server_plan, uniform_plan,
  dis_sample, uniform_sample, dis_marginals,
  dis_blocked_marginals, blocked_geometry                 (dis — Algorithm 1)
  StreamScorer, make_stream_scorer, dis_plan_streamed,
  dis_plan_streamed_batched, vkmc_local_centers,
  vrlr_block_masses_sharded, vkmc_block_masses_sharded    (streaming — block-scan n)
  vrlr_local_scores, vkmc_local_scores, ...               (sensitivity — Alg 2/3 local)
  Coreset, MaterializedCoreset,
  vrlr_coreset_ratio, vkmc_coreset_ratio                  (coreset)
  ridge_closed_form, fista, saga_ridge, solve             (vrlr solvers)
  kmeans, kmeans_plusplus, lloyd, distdim, ...            (vkmc solvers)
  SelectorConfig, make_mesh_selector                      (selector — LLM integration)

Deprecated (seed API, kept as bit-identical shims):
  build_vrlr_coreset, build_vkmc_coreset, build_uniform_coreset
"""

import warnings
from typing import Optional

import jax

from repro.core.api import (
    CORESET_TASKS,
    SCORE_BACKENDS,
    BatchedCoresets,
    CoresetPipeline,
    CoresetTask,
    FailoverAttempt,
    FailoverOutcome,
    build_coreset,
    build_coreset_jit,
    build_coreset_streaming,
    build_coresets_batched,
    get_task,
    register_task,
    resolve_backend,
)
from repro.core.plan import (
    DEFAULT_CHUNK_BLOCKS,
    ENGINES,
    FAILOVER_LADDER,
    CoresetSpec,
    ExecutionPlan,
    MemoryBudgetExceeded,
    MemoryWatchdog,
    PlanCache,
    compile_plan,
    live_bytes,
    memory_model,
)
from repro.core.solve import (
    EvalReport,
    FitResult,
    end_to_end,
    evaluate,
    fit_kmeans,
    fit_ridge,
    full_data_coreset,
    solver_for,
)
from repro.core.comm import CommLedger, CommSchedule, theoretical_dis_cost
from repro.core.faults import (
    FAULT_POLICIES,
    SILENT_KINDS,
    Clock,
    Deadline,
    DeadlineExceeded,
    DegradedBuild,
    DroppedParty,
    FaultPlan,
    PartyUnavailable,
    SimClock,
    StreamCheckpoint,
    Transport,
    TransportStats,
    WallClock,
    deliver_or_record,
    perturb_payload,
)
from repro.core.integrity import (
    GRAM_COND_WARN,
    Finding,
    HealthReport,
    IntegrityError,
    WireEnvelope,
    check_mass_table,
    check_merge_children,
    check_weights,
    health_from_masses,
    payload_digest,
    require_valid_masses,
)
from repro.core.coreset import (
    Coreset,
    MaterializedCoreset,
    vkmc_coreset_ratio,
    vrlr_coreset_ratio,
)
from repro.core.dis import (
    blocked_geometry,
    dis_blocked_marginals,
    dis_marginals,
    dis_plan,
    dis_plan_blocked,
    dis_plan_full,
    dis_sample,
    server_plan,
    split_uploads,
    uniform_plan,
    uniform_sample,
)
from repro.core.streaming import (
    StreamScorer,
    dis_plan_streamed,
    dis_plan_streamed_batched,
    make_stream_scorer,
    register_stream_scorer,
    vkmc_block_masses_sharded,
    vkmc_local_centers,
    vrlr_block_masses_sharded,
)
from repro.core.sensitivity import (
    kmeans_assignment,
    leverage_scores,
    norm_scores,
    ridge_leverage_scores,
    total_sensitivity_bound_vkmc,
    total_sensitivity_bound_vrlr,
    vkmc_local_scores,
    vrlr_local_scores,
)
from repro.core.vfl import VFLDataset, split_columns, standardize
from repro.core.wire import (
    CODEC_LADDER,
    UNIT_BITS,
    WIRE_CODECS,
    Codec,
    WirePayload,
    fmt_bits,
    get_codec,
    predict_dis_bits,
    predict_uniform_bits,
)
from repro.core.vkmc import distdim, kmeans, kmeans_cost, kmeans_plusplus, lloyd
from repro.core.vrlr import (
    central_comm_cost,
    elastic_cost,
    fista,
    lasso_cost,
    ridge_closed_form,
    ridge_cost,
    saga_ridge,
    solve,
    sq_loss,
)


# --------------------------------------------------------------------------
# Deprecated seed-era builders — thin shims over build_coreset.
# Same PRNG key => bit-identical (S, w) and identical ledger totals.
# --------------------------------------------------------------------------

def _deprecated(old: str, new: str) -> None:
    warnings.warn(f"{old} is deprecated; use {new}",
                  DeprecationWarning, stacklevel=3)


def build_vrlr_coreset(
    key: jax.Array,
    ds: VFLDataset,
    m: int,
    ledger: Optional[CommLedger] = None,
    use_kernel: bool = True,
) -> Coreset:
    """Deprecated: use ``build_coreset("vrlr", ds, m, key=key, ...)``."""
    _deprecated("build_vrlr_coreset", 'build_coreset("vrlr", ...)')
    # use_kernel=True maps to "auto" (kernels where they profit — TPU/GPU),
    # so the shim keeps resolving to the same backend as build_coreset's
    # default and stays draw-identical to it on every platform.
    return build_coreset("vrlr", ds, m, key=key,
                         backend="auto" if use_kernel else "ref",
                         ledger=ledger)


def build_vkmc_coreset(
    key: jax.Array,
    ds: VFLDataset,
    k: int,
    m: int,
    alpha: float = 2.0,
    local_iters: int = 15,
    ledger: Optional[CommLedger] = None,
    use_kernel: bool = True,
) -> Coreset:
    """Deprecated: use ``build_coreset("vkmc", ds, m, key=key, k=k, ...)``."""
    _deprecated("build_vkmc_coreset", 'build_coreset("vkmc", ...)')
    return build_coreset("vkmc", ds, m, key=key,
                         backend="auto" if use_kernel else "ref",
                         ledger=ledger, k=k, alpha=alpha,
                         local_iters=local_iters)


def build_uniform_coreset(
    key: jax.Array,
    ds: VFLDataset,
    m: int,
    ledger: Optional[CommLedger] = None,
) -> Coreset:
    """Deprecated: use ``build_coreset("uniform", ds, m, key=key, ...)``."""
    _deprecated("build_uniform_coreset", 'build_coreset("uniform", ...)')
    return build_coreset("uniform", ds, m, key=key, ledger=ledger)


import inspect as _inspect

__all__ = [
    n for n, v in list(globals().items())
    if not n.startswith("_") and not _inspect.ismodule(v) and n != "Optional"
]
