"""Vertical regularized linear regression (Definition 2.1): objectives and
solvers.

Solvers implemented from scratch in JAX (no sklearn in the image):
  * ``ridge_closed_form``  — weighted normal equations (the paper's CENTRAL
    baseline for R(theta)=lambda*||theta||^2), Gram built by the Pallas
    ``weighted_gram`` kernel;
  * ``fista``              — proximal gradient for lasso / elastic net
    (appendix A.2 regularizers);
  * ``saga``               — Defazio et al. incremental gradient, run "in a
    VFL fashion": each step touches one row, whose inner products require a
    scalar from every party, accounted per-step on the CommLedger (this is
    why full-data SAGA costs ~1e8 units in Table 1).

All solvers accept per-row weights so they run unchanged on (S, w) coresets —
exactly the composition of Theorem 2.5.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.comm import CommLedger, null_ledger
from repro.kernels import ops as kops


# --------------------------------------------------------------------------
# Objectives (Definitions 2.1 / 2.3)
# --------------------------------------------------------------------------

def sq_loss(X: jax.Array, y: jax.Array, theta: jax.Array, w: Optional[jax.Array] = None) -> jax.Array:
    r = X @ theta - y
    if w is None:
        return jnp.sum(r * r)
    return jnp.sum(w * r * r)


def ridge_cost(X, y, theta, lam: float, w=None) -> jax.Array:
    """cost^R with R(theta) = lam * ||theta||^2."""
    return sq_loss(X, y, theta, w) + lam * jnp.sum(theta * theta)


def lasso_cost(X, y, theta, lam: float, w=None) -> jax.Array:
    return sq_loss(X, y, theta, w) + lam * jnp.sum(jnp.abs(theta))


def elastic_cost(X, y, theta, lam1: float, lam2: float, w=None) -> jax.Array:
    return sq_loss(X, y, theta, w) + lam1 * jnp.sum(jnp.abs(theta)) + lam2 * jnp.sum(theta * theta)


# --------------------------------------------------------------------------
# Closed-form weighted ridge (CENTRAL)
# --------------------------------------------------------------------------

def ridge_closed_form(
    X: jax.Array, y: jax.Array, lam: float, w: Optional[jax.Array] = None
) -> jax.Array:
    """argmin_theta sum_i w_i (x_i^T theta - y_i)^2 + lam ||theta||^2."""
    n, d = X.shape
    ww = jnp.ones((n,)) if w is None else w
    G = kops.weighted_gram(X, ww) + lam * jnp.eye(d, dtype=jnp.float32)
    b = X.T @ (ww * y)
    return jnp.linalg.solve(G, b.astype(jnp.float32))


def central_comm_cost(n: int, dims, ledger: Optional[CommLedger] = None) -> int:
    """CENTRAL transfers every party's raw block to the server: n * d_j each
    (plus labels already at the server's side party).  Matches Table 1's
    4.2e7 for (n=463715, d=90)."""
    led = null_ledger(ledger)
    for j, dj in enumerate(dims):
        led.party_to_server("central/raw_block", j, n * int(dj))
    return led.total


# --------------------------------------------------------------------------
# FISTA for lasso / elastic net
# --------------------------------------------------------------------------

def _soft(x: jax.Array, t) -> jax.Array:
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)


@functools.partial(jax.jit, static_argnames=("iters",))
def fista(
    X: jax.Array,
    y: jax.Array,
    lam1: float,
    lam2: float = 0.0,
    w: Optional[jax.Array] = None,
    iters: int = 500,
) -> jax.Array:
    """Proximal-gradient solve of weighted lasso/elastic net.

    min_theta sum w_i (x_i^T theta - y_i)^2 + lam1 |theta|_1 + lam2 |theta|_2^2
    """
    n, d = X.shape
    ww = jnp.ones((n,)) if w is None else w
    Xw = X * ww[:, None]
    # Lipschitz constant of the smooth part: 2*(sigma_max(X^T W X) + lam2)
    G = Xw.T @ X
    L = 2.0 * (jnp.linalg.norm(G, ord=2) + lam2) + 1e-6
    b = Xw.T @ y

    def smooth_grad(theta):
        return 2.0 * (G @ theta - b + lam2 * theta)

    def body(_, carry):
        theta, z, t = carry
        theta_new = _soft(z - smooth_grad(z) / L, lam1 / L)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        z_new = theta_new + (t - 1.0) / t_new * (theta_new - theta)
        return theta_new, z_new, t_new

    theta0 = jnp.zeros((d,), jnp.float32)
    theta, _, _ = jax.lax.fori_loop(0, iters, body, (theta0, theta0, jnp.float32(1.0)))
    return theta


# --------------------------------------------------------------------------
# SAGA in the VFL fashion
# --------------------------------------------------------------------------

def saga_ridge(
    key: jax.Array,
    X: jax.Array,
    y: jax.Array,
    lam: float,
    w: Optional[jax.Array] = None,
    steps: int = 20000,
    lr: Optional[float] = None,
    dims: Optional[Tuple[int, ...]] = None,
    ledger: Optional[CommLedger] = None,
) -> jax.Array:
    """SAGA on the (weighted) ridge objective, with VFL comm accounting.

    Per step on row i: every party j sends the scalar partial inner product
    x_i^(j).theta^(j) to the server (T units), the server returns the shared
    residual scalar to every party (T units) -> 2T units/step.  Parameter
    updates stay party-local.  (This per-step 2T is what makes full-data
    SAGA's communication blow up to O(steps*T) ~ 1e8 in Table 1.)
    """
    n, d = X.shape
    ww = jnp.ones((n,)) if w is None else w
    lam_n = lam / n
    if lr is None:
        # 1/(3 * max_i L_i): per-sample smoothness of f_i = w_i(x'th-y)^2 + lam/n |th|^2
        L = 2.0 * jnp.max(ww * jnp.sum(X * X, axis=1)) + 2.0 * lam_n
        lr = float(1.0 / (3.0 * jnp.maximum(L, 1e-9)))

    def grad_i(theta, i):
        r = X[i] @ theta - y[i]
        return 2.0 * ww[i] * r * X[i] + 2.0 * lam_n * theta

    @jax.jit
    def run(key, theta0):
        table0 = jnp.zeros((n, d), jnp.float32)  # stored per-row gradients
        avg0 = jnp.zeros((d,), jnp.float32)

        def body(carry, k):
            theta, table, avg = carry
            i = jax.random.randint(k, (), 0, n)
            g_new = grad_i(theta, i)
            g_old = table[i]
            theta = theta - lr * (g_new - g_old + avg)
            avg = avg + (g_new - g_old) / n
            table = table.at[i].set(g_new)
            return (theta, table, avg), None

        keys = jax.random.split(key, steps)
        (theta, _, _), _ = jax.lax.scan(body, (theta0, table0, avg0), keys)
        return theta

    theta = run(key, jnp.zeros((d,), jnp.float32))
    if ledger is not None:
        T = len(dims) if dims is not None else 1
        ledger.party_to_server("saga/partials", 0, steps * T)
        ledger.server_to_party("saga/residuals", 0, steps * T)
    return theta


def solve(
    kind: str,
    X: jax.Array,
    y: jax.Array,
    w: Optional[jax.Array] = None,
    *,
    lam: float = 0.0,
    lam1: float = 0.0,
    lam2: float = 0.0,
    key: Optional[jax.Array] = None,
    saga_steps: int = 20000,
    saga_lr: float = 1e-3,
) -> jax.Array:
    """Uniform solver entry point used by benchmarks."""
    if kind == "ridge":
        return ridge_closed_form(X, y, lam, w)
    if kind == "linear":
        return ridge_closed_form(X, y, 1e-6, w)  # tiny jitter for conditioning
    if kind == "lasso":
        return fista(X, y, lam1, 0.0, w)
    if kind == "elastic":
        return fista(X, y, lam1, lam2, w)
    if kind == "saga":
        assert key is not None
        return saga_ridge(key, X, y, lam, w, steps=saga_steps, lr=saga_lr)
    raise ValueError(f"unknown solver {kind!r}")
