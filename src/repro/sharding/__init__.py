from repro.sharding.ctx import (
    ShardingCtx,
    current_ctx,
    set_ctx,
    shard_batch_seq,
    shard_expert,
    shard_logits,
)
from repro.sharding.specs import param_shardings, cache_shardings

__all__ = [
    "ShardingCtx",
    "current_ctx",
    "set_ctx",
    "shard_batch_seq",
    "shard_expert",
    "shard_logits",
    "param_shardings",
    "cache_shardings",
]
