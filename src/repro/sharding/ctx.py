"""Activation-sharding context.

Model code calls the ``shard_*`` helpers; outside a mesh (CPU smoke tests)
they are no-ops, under the dry-run/production launchers ``set_ctx`` installs
the axis names and they become ``with_sharding_constraint`` anchors that pin
GSPMD's propagation at the layer boundaries.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    dp_axes: Tuple[str, ...] = ("data",)   # batch axes, e.g. ("pod", "data")
    tp_axis: str = "model"
    seq_axis: Optional[str] = None          # set for sequence-parallel decode


_current: Optional[ShardingCtx] = None


@contextlib.contextmanager
def set_ctx(ctx: Optional[ShardingCtx]):
    global _current
    prev = _current
    _current = ctx
    try:
        yield
    finally:
        _current = prev


def current_ctx() -> Optional[ShardingCtx]:
    return _current


def _constrain(x, spec: P):
    return jax.lax.with_sharding_constraint(x, spec)


def _divisible(dim: int, ax) -> bool:
    from repro.sharding.specs import MESH_SIZES

    if ax is None:
        return True
    axes = ax if isinstance(ax, (tuple, list)) else (ax,)
    n = 1
    for a in axes:
        n *= MESH_SIZES[a]
    return dim % n == 0


def shard_batch_seq(x):
    """(B, S, ...) activations: batch over dp axes, seq over seq_axis
    (Megatron-style sequence parallelism — the residual stream and its
    per-layer remat checkpoints are model-axis sharded between blocks)."""
    c = _current
    if c is None:
        return x
    dp = c.dp_axes if (c.dp_axes and _divisible(x.shape[0], c.dp_axes)) else None
    seq = c.seq_axis if _divisible(x.shape[1], c.seq_axis) else None
    rest = (None,) * (x.ndim - 2)
    return _constrain(x, P(dp, seq, *rest))


def shard_heads(x, head_axis: int = 2):
    """(B, S, H, ...) per-head tensors: heads over tp when divisible (MLA's
    H=128 materialised K/V; replicated otherwise by the divisibility check)."""
    c = _current
    if c is None or not _divisible(x.shape[head_axis], c.tp_axis):
        return x
    dp = c.dp_axes if (c.dp_axes and _divisible(x.shape[0], c.dp_axes)) else None
    spec = [None] * x.ndim
    spec[0] = dp
    spec[head_axis] = c.tp_axis
    return _constrain(x, P(*spec))


def shard_logits(x):
    """(B, S, V) logits: batch over dp, vocab over tp (vocab wins the model
    axis over sequence — CE is vocab-reduction-heavy)."""
    c = _current
    if c is None:
        return x
    dp = c.dp_axes if (c.dp_axes and _divisible(x.shape[0], c.dp_axes)) else None
    return _constrain(x, P(dp, None, c.tp_axis))


def shard_expert(x):
    """(E, C, d) MoE buffers: experts over tp."""
    c = _current
    if c is None:
        return x
    rest = (None,) * (x.ndim - 1)
    return _constrain(x, P(c.tp_axis, *rest))
