"""PartitionSpec rules for parameters, optimizer state, and serve caches.

Policy (Megatron-style TP over `model` + DP over ('pod','data'), optional
FSDP over `data` for the >=14B archs):
  * attention/FFN projections: contracting d_model dim replicated, the
    head/ffn output dim sharded over `model`; the out-projection shards its
    input dim (so the pair produces one all-reduce per block);
  * MoE expert tensors: expert axis over `model` (expert parallelism) when E
    divides the axis, else the per-expert ffn dim (granite's E=40 vs 16);
  * embeddings/unembedding: padded vocab (ArchConfig.vocab_pad) over `model`;
  * FSDP (cfg.fsdp): `data` is added to the first still-unsharded divisible
    dim of each weight (ZeRO-3-ish; gathered layer-by-layer inside the scan);
  * KV caches: batch over dp axes, head_dim over `model` (the per-arch KV
    head counts 2/5/8/10/16 do not divide a 16-way axis; head_dim 64/128
    always does); the batch=1 long-context shape shards the cache SEQUENCE
    over `data` instead (sequence-parallel decode).

Every spec passes a divisibility sanitizer (pjit rejects uneven *input*
shardings): any axis that does not divide its dim is dropped to replication.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape

MESH_SIZES = {"pod": 2, "data": 16, "model": 16}


def _axis_size(ax, sizes: Dict[str, int]) -> int:
    if ax is None:
        return 1
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= sizes[a]
        return n
    return sizes[ax]


def sanitize(spec: P, shape: Tuple[int, ...], sizes: Dict[str, int] = MESH_SIZES) -> P:
    """Drop any spec axis whose size does not divide the dim."""
    axes = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, ax in zip(shape, axes):
        out.append(ax if (ax is not None and dim % _axis_size(ax, sizes) == 0) else None)
    return P(*out)


def _add_fsdp(spec: P, shape: Tuple[int, ...], sizes: Dict[str, int],
              multi_pod: bool = False) -> P:
    """Add the dp axes to the largest unsharded divisible dim (ZeRO-3-ish)."""
    candidates = (("pod", "data"), ("data",)) if multi_pod else (("data",),)
    axes = list(spec) + [None] * (len(shape) - len(spec))
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for cand in candidates:
        n = 1
        for a in cand:
            n *= sizes[a]
        for i in order:
            if axes[i] is None and shape[i] % n == 0 and shape[i] >= n:
                axes[i] = cand if len(cand) > 1 else cand[0]
                return P(*axes)
    return P(*axes)


def _add_axis(spec: P, shape: Tuple[int, ...], sizes: Dict[str, int], axis: str) -> P:
    """Add one named axis to the largest unsharded divisible dim."""
    axes = list(spec) + [None] * (len(shape) - len(spec))
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if axes[i] is None and shape[i] % sizes[axis] == 0 and shape[i] >= sizes[axis]:
            axes[i] = axis
            return P(*axes)
    return P(*axes)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _param_rule(path: str, shape: Tuple[int, ...], cfg: ArchConfig, tp: str):
    """Base (pre-sanitize, pre-FSDP) spec for one parameter leaf."""
    stacked = path.startswith("layers") or path.startswith("enc_layers")
    lead: Tuple = (None,) if stacked else ()
    body = len(shape) - len(lead)
    name = path.split("/")[-1]

    def spec(*axes):
        return P(*lead, *axes)

    # embeddings / head / positions ---------------------------------------
    if name == "embed":
        return P(tp, None)
    if name == "head":
        return P(None, tp)
    if name in ("pos_embed", "enc_pos_embed"):
        return P(None, None)
    # MoE ------------------------------------------------------------------
    if "moe" in path and name in ("w_gate", "w_up", "w_down"):
        E = shape[len(lead)]
        if E % MESH_SIZES["model"] == 0:
            return spec(tp, None, None)        # expert parallelism
        # fallback: shard the per-expert ffn dim
        if name == "w_down":
            return spec(None, tp, None)        # (E, f, d)
        return spec(None, None, tp)            # (E, d, f)
    if name == "router":
        return spec(None, None)                # E often non-divisible; tiny
    # attention ------------------------------------------------------------
    if name in ("wq", "wk", "wv", "w_uq", "w_uk", "w_uv", "w_in"):
        return spec(None, tp)
    if name in ("wo", "w_out"):
        return spec(tp, None)
    if name in ("w_dq", "w_dkv", "w_kpe"):
        return spec(None, None)                # small latent projections
    if name == "bonus_u":
        return spec(None, None)                # (H, hd): H rarely divides
    # rwkv -----------------------------------------------------------------
    if name in ("w_r", "w_k", "w_v", "w_g"):
        return spec(None, tp)
    if name == "w_o":
        return spec(tp, None)
    if name == "decay_lora_a":
        return spec(None, None)
    if name == "decay_lora_b":
        return spec(None, tp)
    # mamba ----------------------------------------------------------------
    if name in ("w_bcdt", "A_log"):
        return spec(tp, None)                  # (di, ...)
    if name == "D":
        return spec(tp)
    if name == "ln_out" and "mamba" in path:
        return spec(tp)                        # over di
    # dense mlp ------------------------------------------------------------
    if name in ("w_gate", "w_up"):
        return spec(None, tp)                  # (D, F)
    if name == "w_down":
        return spec(tp, None)                  # (F, D)
    # norms / vectors --------------------------------------------------------
    return spec(*([None] * body))


def param_shardings(
    params_shape: Any, cfg: ArchConfig, multi_pod: bool,
    sizes: Dict[str, int] = MESH_SIZES,
) -> Any:
    """Pytree of PartitionSpec matching a params(-shaped) pytree."""
    tp = "model"

    def rule(path, leaf):
        pstr = _path_str(path)
        spec = _param_rule(pstr, tuple(leaf.shape), cfg, tp)
        spec = sanitize(spec, tuple(leaf.shape), sizes)
        if getattr(cfg, "pure_fsdp", False) and (
            pstr.startswith("layers") or pstr.startswith("enc_layers")
        ):
            # weight-gathered parallelism: strip TP from layer weights; the
            # (small) weights are all-gathered per layer instead of the
            # (large) activations — wins when head counts don't divide the
            # model axis (rwkv6's 40 heads; §Perf pair B)
            spec = P(*(None if a == tp else a for a in spec))
            spec = _add_fsdp(spec, tuple(leaf.shape), sizes, multi_pod)
            # also spread over the model axis for memory when possible
            spec = _add_axis(spec, tuple(leaf.shape), sizes, "model")
        elif cfg.fsdp:
            spec = _add_fsdp(spec, tuple(leaf.shape), sizes, multi_pod)
        return spec

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def opt_shardings(params_specs: Any) -> Any:
    """Adam m/v follow the parameter shardings."""
    return params_specs


def batch_shardings(cfg: ArchConfig, shape: InputShape, multi_pod: bool) -> Any:
    dp = ("pod", "data") if multi_pod else ("data",)
    if shape.global_batch == 1 or (shape.global_batch % (32 if multi_pod else 16)) != 0:
        # batch must divide the dp axes; fall back to 'data' only, else replicate
        if shape.global_batch % 16 == 0:
            dp = ("data",)
        else:
            dp = ()
    tok = P(dp if dp else None)
    if shape.is_decode:
        return {"tokens": tok}
    out = {"tokens": tok, "labels": tok}
    if cfg.frontend != "none" or cfg.kind == "encdec":
        out["prefix_embeds"] = P(dp if dp else None, None, None)
    return out


def cache_shardings(cache_shape: Any, cfg: ArchConfig, shape: InputShape, multi_pod: bool) -> Any:
    """Specs for the serve cache pytree (see models.lm.init_cache layouts)."""
    dp: Tuple = ("pod", "data") if multi_pod else ("data",)
    if shape.global_batch % (32 if multi_pod else 16) != 0:
        dp = ("data",) if shape.global_batch % 16 == 0 else ()
    seq_parallel = shape.global_batch == 1
    b_ax = None if (seq_parallel or not dp) else dp
    s_ax = "data" if seq_parallel else None
    tp = "model"

    def rule(path, leaf):
        name = _path_str(path)
        nd = len(leaf.shape)
        shp = tuple(leaf.shape)
        if name.endswith("kpos"):
            return sanitize(P(s_ax), shp)
        if name.endswith("pos"):
            return P()
        if name.endswith("/k") or name.endswith("/v") or "cross_" in name:
            # (L, B, Sc, KV, hd): head_dim over model (KV counts rarely divide)
            return sanitize(P(None, b_ax, s_ax, None, tp), shp)
        if name.endswith("c_kv"):                            # (L, B, Sc, r_kv)
            return sanitize(P(None, b_ax, s_ax, tp), shp)
        if name.endswith("k_pe"):                            # (L, B, Sc, dr)
            return sanitize(P(None, b_ax, s_ax, None), shp)
        if name.endswith("wkv"):                             # (L, B, H, hd, hd)
            return sanitize(P(None, b_ax, None, tp, None), shp)
        if name.endswith("shift"):                           # (L, B, D)
            return sanitize(P(None, b_ax, tp), shp)
        if name.endswith("mamba_h"):                         # (L, B, di, N)
            return sanitize(P(None, b_ax, tp, None), shp)
        if name.endswith("enc_out"):                         # (B, P, D)
            return sanitize(P(b_ax, None, None), shp)
        if nd >= 2:
            return sanitize(P(None, b_ax, *([None] * (nd - 2))), shp)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, cache_shape)
