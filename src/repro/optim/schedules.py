"""LR schedules as step -> lr functions (jit-safe)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def fn(step):
        return jnp.asarray(lr, jnp.float32)

    return fn


def cosine_with_warmup(peak: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(s < warmup, warm, cos)

    return fn
