"""AdamW, hand-rolled (no optax in the image), pytree-native.

State: {"m": f32 tree, "v": f32 tree, "step": scalar}.  m/v inherit the
parameter shardings (see sharding.specs.opt_shardings).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def adamw_init(params: Any) -> Dict[str, Any]:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(
    params: Any,
    grads: Any,
    state: Dict[str, Any],
    lr: jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Tuple[Any, Dict[str, Any]]:
    step = state["step"] + 1

    # global-norm clip in f32
    g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree_util.tree_leaves(g32)))
    scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
    g32 = jax.tree_util.tree_map(lambda g: g * scale, g32)

    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], g32)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], g32)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}
