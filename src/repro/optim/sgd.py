"""SGD with momentum (pytree-native)."""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def sgd_init(params: Any) -> Dict[str, Any]:
    return {"mom": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}


def sgd_update(
    params: Any, grads: Any, state: Dict[str, Any], lr: jax.Array, *, momentum: float = 0.9
) -> Tuple[Any, Dict[str, Any]]:
    mom = jax.tree_util.tree_map(
        lambda m, g: momentum * m + g.astype(jnp.float32), state["mom"], grads
    )
    new_params = jax.tree_util.tree_map(
        lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype), params, mom
    )
    return new_params, {"mom": mom}
