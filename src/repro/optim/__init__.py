from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.sgd import sgd_init, sgd_update
from repro.optim.schedules import constant, cosine_with_warmup

__all__ = [
    "adamw_init",
    "adamw_update",
    "sgd_init",
    "sgd_update",
    "constant",
    "cosine_with_warmup",
]
