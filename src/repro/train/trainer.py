"""Training step factory: loss + grad + AdamW, with the paper's coreset
batch selection as a first-class option.

With ``SelectorConfig.mode == "coreset"`` the step is two-phase:
  1. SCORE (cheap, communication-light): per-example features are the
     mean-pooled token embeddings — party-local in the VFL geometry (each
     model-axis shard scores its d_model slice; combining scores is one
     f32[B] all-reduce, the mesh form of DIS rounds 1+3);
  2. STEP (expensive): the full forward/backward runs only on the m-row
     weighted coreset; the loss uses the DIS importance weights so the
     gradient stays an unbiased estimate of the full-batch gradient
     (Theorem 2.5 with the optimizer step as the downstream scheme A).

``mode == "uniform"`` is the U-* baseline (same m, weight B/m);
``mode == "none"`` is the dense step.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.dis import uniform_plan
from repro.core.selector import SelectorConfig, local_scores, sample_coreset
from repro.models import api as model_api
from repro.models.layers import embed
from repro.optim.adamw import adamw_init, adamw_update

TrainState = Dict[str, Any]   # {"params", "opt", "step"}


def train_state_init(key: jax.Array, cfg: ArchConfig) -> TrainState:
    params = model_api.init_params(key, cfg)
    return {"params": params, "opt": adamw_init(params), "step": jnp.zeros((), jnp.int32)}


def _select_rows(batch: Dict[str, jax.Array], idx: jax.Array) -> Dict[str, jax.Array]:
    return {k: v[idx] for k, v in batch.items()}


def _score_features(params, cfg: ArchConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    """(B, D) mean-pooled embedding features — the cheap, party-local score
    input (O(B*S*D) lookups; no layer compute, no cross-shard traffic)."""
    x = embed(batch["tokens"], params["embed"])          # (B, S, D)
    feats = jnp.mean(x.astype(jnp.float32), axis=1)
    if "prefix_embeds" in batch:
        feats = feats + jnp.mean(batch["prefix_embeds"].astype(jnp.float32), axis=1)
    return feats


def make_train_step(
    cfg: ArchConfig,
    lr_schedule: Callable[[jax.Array], jax.Array],
    selector: Optional[SelectorConfig] = None,
    weight_decay: float = 0.1,
) -> Callable[[TrainState, Dict[str, jax.Array], jax.Array], Tuple[TrainState, Dict]]:
    """Returns train_step(state, batch, key) -> (state, metrics). jit/pjit-able."""
    sel = selector or SelectorConfig(mode="none")

    def step_fn(state: TrainState, batch: Dict[str, jax.Array], key: jax.Array):
        params = state["params"]
        weights = None
        if sel.mode == "uniform":
            B = batch["tokens"].shape[0]
            idx, weights = uniform_plan(key, B, sel.m_of(B))
            batch = _select_rows(batch, idx)
        elif sel.mode == "coreset":
            feats = _score_features(params, cfg, batch)
            g = local_scores(feats, sel.score, sel.ridge)
            idx, weights = sample_coreset(key, g, sel.m_of(feats.shape[0]))
            batch = _select_rows(batch, idx)

        def loss(p):
            return model_api.loss_fn(p, cfg, batch, example_weights=weights)

        (total, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        lr = lr_schedule(state["step"])
        new_params, new_opt = adamw_update(
            params, grads, state["opt"], lr, weight_decay=weight_decay
        )
        out_metrics = {
            "loss": total,
            "ce": metrics["ce"],
            "aux": metrics["aux"],
            "lr": lr,
        }
        return (
            {"params": new_params, "opt": new_opt, "step": state["step"] + 1},
            out_metrics,
        )

    return step_fn


def make_eval_step(cfg: ArchConfig):
    def eval_step(params, batch):
        loss, metrics = model_api.loss_fn(params, cfg, batch)
        return metrics["ce"]

    return eval_step
