from repro.train.trainer import TrainState, make_train_step, train_state_init
from repro.train.checkpoint import load_checkpoint, save_checkpoint

__all__ = [
    "TrainState",
    "train_state_init",
    "make_train_step",
    "save_checkpoint",
    "load_checkpoint",
]
