"""Checkpointing: flatten the state pytree to path-keyed arrays in an .npz.

Pure numpy (no orbax in the image); good enough for single-host restarts and
the examples.  Multi-host note: each host saves its addressable shards under
``<dir>/shard<k>.npz``; on this container there is one host/one file.
"""

from __future__ import annotations

import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "|"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, state: Any, step: int) -> str:
    os.makedirs(path, exist_ok=True)
    fname = os.path.join(path, f"step{step:08d}.npz")
    np.savez(fname, **_flatten(state))
    with open(os.path.join(path, "LATEST"), "w") as f:
        f.write(os.path.basename(fname))
    return fname


def load_checkpoint(path: str, like: Any) -> Tuple[Any, int]:
    """Restore into the structure of `like` (a state pytree or eval_shape)."""
    with open(os.path.join(path, "LATEST")) as f:
        fname = os.path.join(path, f.read().strip())
    data = np.load(fname)
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    restored = []
    for path_keys, leaf in leaves_like:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_keys)
        arr = data[key]
        restored.append(jnp.asarray(arr, dtype=leaf.dtype).reshape(leaf.shape))
    step = int(fname.rsplit("step", 1)[1].split(".")[0])
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), restored
    ), step
