"""Logging helpers (single place so launchers can reconfigure)."""

from __future__ import annotations

import logging
import os
import sys

_FORMAT = "%(asctime)s %(levelname).1s %(name)s: %(message)s"
_configured = False


def get_logger(name: str) -> logging.Logger:
    global _configured
    if not _configured:
        level = os.environ.get("REPRO_LOGLEVEL", "INFO").upper()
        logging.basicConfig(stream=sys.stderr, level=level, format=_FORMAT, datefmt="%H:%M:%S")
        _configured = True
    return logging.getLogger(name)
