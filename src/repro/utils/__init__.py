from repro.utils.registry import Registry
from repro.utils.logging import get_logger

__all__ = ["Registry", "get_logger"]
