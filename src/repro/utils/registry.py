"""Tiny string -> factory registry used for archs, optimizers, selectors."""

from __future__ import annotations

from typing import Any, Callable, Dict, Iterator


class Registry:
    """A named registry mapping string keys to factories.

    Used so that ``--arch granite-moe-3b-a800m`` style CLI flags resolve to
    config/model factories without import cycles.
    """

    def __init__(self, name: str):
        self.name = name
        self._entries: Dict[str, Callable[..., Any]] = {}

    def register(self, key: str) -> Callable[[Callable[..., Any]], Callable[..., Any]]:
        def deco(fn: Callable[..., Any]) -> Callable[..., Any]:
            if key in self._entries:
                raise KeyError(f"{self.name}: duplicate key {key!r}")
            self._entries[key] = fn
            return fn

        return deco

    def get(self, key: str) -> Callable[..., Any]:
        try:
            return self._entries[key]
        except KeyError:
            known = ", ".join(sorted(self._entries))
            raise KeyError(f"{self.name}: unknown key {key!r}. Known: {known}") from None

    def __contains__(self, key: str) -> bool:
        return key in self._entries

    def keys(self):
        return sorted(self._entries)

    def __iter__(self) -> Iterator[str]:
        return iter(sorted(self._entries))

    def __len__(self) -> int:
        return len(self._entries)
