"""Pytree helpers shared by trainer / checkpointing / dry-run."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def tree_bytes(tree: Any) -> int:
    """Total bytes of all array leaves (works for ShapeDtypeStruct too)."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for leaf in leaves:
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total


def tree_params(tree: Any) -> int:
    """Total element count of all array leaves."""
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(int(np.prod(leaf.shape)) for leaf in leaves if hasattr(leaf, "shape"))


def tree_zeros_like(tree: Any) -> Any:
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a: Any, b: Any) -> Any:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(tree: Any, s) -> Any:
    return jax.tree_util.tree_map(lambda x: x * s, tree)


def tree_finite(tree: Any) -> jax.Array:
    """Scalar bool: every leaf is finite."""
    leaves = [jnp.all(jnp.isfinite(x)) for x in jax.tree_util.tree_leaves(tree)]
    out = jnp.array(True)
    for l in leaves:
        out = jnp.logical_and(out, l)
    return out
