"""Mixture-of-Experts FFN: top-k softmax router + grouped einsum dispatch.

TPU/GSPMD-native design (recorded in DESIGN.md): the CUDA-style
sort-and-scatter grouped GEMM is pathological under the SPMD partitioner
(data-dependent scatters into an expert-major buffer replicate the full
(E*C, d) tensor on every device and all-reduce it — measured 60 GiB/device
on granite).  We instead use the classic Switch/GLaM formulation: tokens are
split into groups of ``group_size``, each group builds a (Sg, E, C) one-hot
dispatch/combine tensor (position-in-expert via per-slot cumsum), and
pack/unpack are einsums that map straight onto the MXU:

    dispatched = einsum('gsec,gsd->gecd', dispatch, x)
    ...expert FFN over (g,e,c,:) with E (or C) sharded on `model`...
    out        = einsum('gsec,gecd->gsd', combine, y)

The dispatch einsums cost ~Sg/(3*d_ff) of the expert FLOPs per direction
(group_size=256 -> 6-17% overhead depending on arch) — the documented price
of static-shape, scatter-free MoE under GSPMD.  Capacity C =
ceil(Sg*K/E * capacity_factor); overflowing tokens drop (standard).

Sharding: groups over ('pod','data'); the expert axis over `model` when E
divides it (deepseek 160/16), else the capacity axis (granite E=40, C
divisible); constraints are divisibility-sanitized so CPU smoke tests (no
mesh ctx) run the identical code path unconstrained.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init
from repro.sharding import ctx as shctx


def init_moe(key: jax.Array, cfg) -> dict:
    d, E, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    return {
        "router": dense_init(ks[0], (d, E), jnp.float32),       # router in f32
        "w_gate": dense_init(ks[1], (E, d, f), cfg.param_dtype),
        "w_up": dense_init(ks[2], (E, d, f), cfg.param_dtype),
        "w_down": dense_init(ks[3], (E, f, d), cfg.param_dtype),
    }


def _constrain(x, spec: P):
    """Sharding constraint, divisibility-sanitized; no-op without a mesh ctx."""
    if shctx.current_ctx() is None:
        return x
    from repro.sharding.specs import sanitize

    return jax.lax.with_sharding_constraint(x, sanitize(spec, tuple(x.shape)))


def _pick_group(N: int, group_size: int) -> int:
    """Largest group <= group_size dividing N (N is a power-of-two times a
    small factor for every assigned shape)."""
    g = min(group_size, N)
    while N % g != 0:
        g -= 1
    return g


def moe_ffn(
    params: dict,
    cfg,
    x: jax.Array,                    # (B, S, D)
    capacity_factor: float = 1.25,
    group_size: int = 256,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (B,S,D), aux_loss scalar)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    N = B * S
    Sg = _pick_group(N, getattr(cfg, "moe_group", group_size))
    G = N // Sg
    ctx = shctx.current_ctx()
    dp = ctx.dp_axes if (ctx and ctx.dp_axes) else None

    xg = _constrain(x.reshape(G, Sg, D), P(dp, None, None))
    logits = xg.astype(jnp.float32) @ params["router"]            # (G, Sg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)               # (G, Sg, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- switch-style load-balance aux loss -------------------------------
    me = probs.reshape(N, E).mean(axis=0)                         # (E,)
    ce = jnp.zeros((E,)).at[expert_ids.reshape(-1)].add(1.0) / (N * K)
    aux = E * jnp.sum(me * ce)

    # ---- grouped one-hot dispatch -----------------------------------------
    # Position bookkeeping runs on (G,Sg,E)/(G,Sg) tensors only; the big
    # (G,Sg,E,C) dispatch/combine masks are built by ONE einsum over stacked
    # per-slot one-hots (MXU work, bf16) instead of K accumulation passes —
    # this is the §Perf "einsum-of-one-hots" optimisation: the HBM traffic of
    # the mask build drops ~K-fold and the masks are half-width.
    C = int(math.ceil(Sg * K / E * capacity_factor))
    mask_spec = P(dp, None, "model", None) if E % 16 == 0 else P(dp, None, None, "model")
    tok_spec = P(dp, "model", None, None) if E % 16 == 0 else P(dp, None, "model", None)

    if getattr(cfg, "moe_dispatch", "einsum") == "einsum":
        fill = jnp.zeros((G, E), jnp.float32)
        pos_slots, keep_slots = [], []
        for k in range(K):
            mk = jax.nn.one_hot(expert_ids[..., k], E, dtype=jnp.float32)   # (G,Sg,E)
            pos = jnp.cumsum(mk, axis=1) - mk + fill[:, None, :]
            pos_tok = jnp.sum(pos * mk, axis=-1)                            # (G,Sg)
            keep_slots.append(pos_tok < C)
            pos_slots.append(pos_tok)
            fill = fill + mk.sum(axis=1)
        pos_all = jnp.stack(pos_slots, axis=2).astype(jnp.int32)            # (G,Sg,K)
        keep_all = jnp.stack(keep_slots, axis=2)                            # (G,Sg,K)
        oh_e = jax.nn.one_hot(expert_ids, E, dtype=x.dtype) * keep_all[..., None].astype(x.dtype)
        oh_c = jax.nn.one_hot(pos_all, C, dtype=x.dtype)                    # (G,Sg,K,C)
        dispatch = jnp.einsum("gske,gskc->gsec", oh_e, oh_c)
        combine = jnp.einsum("gske,gskc->gsec",
                             oh_e * gate_vals[..., None].astype(x.dtype), oh_c)
    else:
        # baseline Switch-style K-pass accumulation (paper-faithful GSPMD MoE;
        # kept selectable for the §Perf before/after)
        fill = jnp.zeros((G, E), jnp.float32)
        dispatch = jnp.zeros((G, Sg, E, C), jnp.float32)
        combine = jnp.zeros((G, Sg, E, C), jnp.float32)
        for k in range(K):
            mk = jax.nn.one_hot(expert_ids[..., k], E, dtype=jnp.float32)
            pos = jnp.cumsum(mk, axis=1) - mk + fill[:, None, :]
            keep = mk * (pos < C)
            slot = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)
            dk = keep[..., None] * slot
            dispatch = dispatch + dk
            combine = combine + dk * gate_vals[..., k][:, :, None, None]
            fill = fill + mk.sum(axis=1)
        dispatch = dispatch.astype(x.dtype)
        combine = combine.astype(x.dtype)
    dispatch = _constrain(dispatch, mask_spec)
    combine = _constrain(combine, mask_spec)

    # ---- pack -> expert FFN -> unpack (all einsums, MXU-friendly) ---------
    disp = jnp.einsum("gsec,gsd->gecd", dispatch, xg)
    disp = _constrain(disp, tok_spec)
    g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", disp, params["w_gate"]))
    u = jnp.einsum("gecd,edf->gecf", disp, params["w_up"])
    y = jnp.einsum("gecf,efd->gecd", g * u, params["w_down"])
    y = _constrain(y, tok_spec)
    out = jnp.einsum("gsec,gecd->gsd", combine, y)
    out = _constrain(out, P(dp, None, None))
    return out.reshape(B, S, D), aux
