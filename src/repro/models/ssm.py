"""Attention-free mixers: RWKV-6 (Finch) and a Mamba-style selective SSM
branch (for Hymba's parallel heads).

TPU adaptation (recorded in DESIGN.md): the reference CUDA kernels for both
models are per-timestep recurrences in SRAM.  On TPU we use the CHUNKED
linear-attention form instead — an outer ``lax.scan`` carries the recurrent
state across chunks while all within-chunk work is (C x C)/(C x d) matmuls
that feed the MXU.  This keeps the materialised state O(B*H*hd^2) per chunk
instead of O(B*S*...) (which would be terabytes at 32k x 1M tokens) and gives
the compiler a short static loop (S/C trips) rather than an S-trip scalar
recurrence.

Numerics: per-token log-decays are clamped to [-DECAY_CLAMP, 0] so the
within-chunk exp() of cumulative decays stays in f32 range (documented
deviation; training from scratch is insensitive to the clamp).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm

DECAY_CLAMP = 2.0   # max |log w| per token; chunk 32 -> exponent <= 64 (f32-safe)


# ==========================================================================
# RWKV-6 (Finch): data-dependent decay WKV, chunked
# ==========================================================================

def init_rwkv6(key: jax.Array, cfg) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    lora = max(32, hd // 2)
    ks = jax.random.split(key, 12)
    return {
        # token-shift mixing coefficients (static lerp; data-dep part via lora)
        "mix_r": jnp.full((d,), 0.5, cfg.param_dtype),
        "mix_k": jnp.full((d,), 0.5, cfg.param_dtype),
        "mix_v": jnp.full((d,), 0.5, cfg.param_dtype),
        "mix_w": jnp.full((d,), 0.5, cfg.param_dtype),
        "w_r": dense_init(ks[0], (d, d), cfg.param_dtype),
        "w_k": dense_init(ks[1], (d, d), cfg.param_dtype),
        "w_v": dense_init(ks[2], (d, d), cfg.param_dtype),
        "w_g": dense_init(ks[3], (d, d), cfg.param_dtype),
        "w_o": dense_init(ks[4], (d, d), cfg.param_dtype),
        # data-dependent decay: w_t = -softplus(base + lora(x)) (log-space)
        "decay_base": jnp.full((d,), -1.0, jnp.float32),
        "decay_lora_a": dense_init(ks[5], (d, lora), cfg.param_dtype),
        "decay_lora_b": dense_init(ks[6], (lora, d), cfg.param_dtype, scale=1e-2),
        "bonus_u": jnp.zeros((H, hd), jnp.float32),                 # per-head u
        "ln_out": jnp.ones((d,), cfg.param_dtype),                  # group-ish norm
    }


def _chunked_wkv(
    r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array, u: jax.Array,
    state0: jax.Array, chunk: int,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked WKV6.

    r,k,v,logw: (B, S, H, hd); u: (H, hd); state0: (B, H, hd, hd).
    Recurrence: S_t = diag(w_t) S_{t-1} + k_t v_t^T
                y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    Returns (y (B,S,H,hd), state (B,H,hd,hd)).
    """
    B, S, H, hd = r.shape
    nc = max(S // chunk, 1)
    c = S // nc

    def resh(x):
        return x.reshape(B, nc, c, H, hd).transpose(1, 0, 3, 2, 4)   # (nc,B,H,c,hd)

    # §Perf B2/B3: keep the chunk stacks in the model dtype (halves the
    # gather/HBM bytes vs the f32 baseline; decay math stays f32) and shard
    # their head_dim over the model axis so the chunk scan's dynamic slices
    # are device-local instead of all-gathering the full (nc,B,H,c,hd) stack.
    from repro.sharding import ctx as shctx

    def stack(x):
        x = resh(x)                                  # (nc, B, H, c, hd)
        cc = shctx.current_ctx()
        if cc is None:
            return x
        from jax.sharding import PartitionSpec as P
        from repro.sharding.specs import sanitize
        dp = cc.dp_axes if cc.dp_axes else None
        spec = sanitize(P(None, dp, None, None, cc.tp_axis), tuple(x.shape))
        return jax.lax.with_sharding_constraint(x, spec)

    rc, kc, vc = stack(r), stack(k), stack(v)
    wc = stack(logw)

    def body(state, args):
        ri, ki, vi, lwi = args                       # (B,H,c,hd)
        lwi = lwi.astype(jnp.float32)
        L = jnp.cumsum(lwi, axis=2)                  # inclusive cumulative log decay
        Lprev = L - lwi                              # exclusive (decay before t)
        # inter-chunk: y_inter_t = (r_t * exp(Lprev_t))^T S0
        r_dec = ri.astype(jnp.float32) * jnp.exp(Lprev)
        y_inter = jnp.einsum("bhck,bhkv->bhcv", r_dec, state,
                             preferred_element_type=jnp.float32)
        # intra-chunk: A_{tj} = sum_d r_td k_jd exp(Lprev_t - L_j), j < t
        k_dec = ki.astype(jnp.float32) * jnp.exp(-L)
        A = jnp.einsum("bhtk,bhjk->bhtj", r_dec, k_dec,
                       preferred_element_type=jnp.float32)
        tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
        A = jnp.where(tri[None, None], A, 0.0)
        diag = jnp.einsum("bhtk,bhtk->bht", ri.astype(jnp.float32),
                          ki.astype(jnp.float32) * u[None, :, None, :])
        vf = vi.astype(jnp.float32)
        y = y_inter + jnp.einsum("bhtj,bhjv->bhtv", A, vf,
                                 preferred_element_type=jnp.float32) \
            + diag[..., None] * vf
        # state update: S_C = diag(exp(L_C)) S0 + sum_j diag(exp(L_C - L_j)) k_j v_j^T
        Lc = L[:, :, -1:, :]                          # (B,H,1,hd)
        k_carry = ki.astype(jnp.float32) * jnp.exp(Lc - L)
        state = jnp.exp(Lc[:, :, 0, :])[..., None] * state + \
            jnp.einsum("bhjk,bhjv->bhkv", k_carry, vf,
                       preferred_element_type=jnp.float32)
        return state, y

    # remat per chunk: the (B,H,c,c) decay matrices are recomputed in the
    # backward instead of being stacked across all S/c chunks
    body = jax.checkpoint(body, prevent_cse=False)
    state, ys = jax.lax.scan(body, state0.astype(jnp.float32), (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, S, H, hd)
    return y, state


def rwkv6_mixer(
    params: dict,
    cfg,
    x: jax.Array,                        # (B, S, D)
    state: Optional[dict] = None,        # {"wkv": (B,H,hd,hd), "shift": (B,D)}
    chunk: int = 32,
):
    """Returns (out (B,S,D), new_state or None)."""
    B, S, D = x.shape
    H = cfg.num_heads
    hd = D // H
    prev = (
        jnp.concatenate([jnp.zeros((B, 1, D), x.dtype), x[:, :-1]], axis=1)
        if state is None
        else jnp.concatenate([state["shift"][:, None], x[:, :-1]], axis=1)
    )

    def mixed(name):
        m = params[f"mix_{name}"]
        return x * m + prev * (1 - m)

    r = (mixed("r") @ params["w_r"]).reshape(B, S, H, hd)
    k = (mixed("k") @ params["w_k"]).reshape(B, S, H, hd)
    v = (mixed("v") @ params["w_v"]).reshape(B, S, H, hd)
    g = jax.nn.silu(x @ params["w_g"])
    lw = params["decay_base"] + (mixed("w") @ params["decay_lora_a"]) @ params["decay_lora_b"]
    logw = -jnp.clip(jax.nn.softplus(lw.astype(jnp.float32)), 0.0, DECAY_CLAMP)
    logw = logw.reshape(B, S, H, hd)

    s0 = (
        jnp.zeros((B, H, hd, hd), jnp.float32) if state is None else state["wkv"]
    )
    y, s_new = _chunked_wkv(r, k, v, logw, params["bonus_u"], s0, chunk)
    y = rms_norm(y.reshape(B, S, D).astype(x.dtype), params["ln_out"])
    out = (y * g) @ params["w_o"]
    new_state = {"wkv": s_new, "shift": x[:, -1]}
    return out, new_state


# ==========================================================================
# Mamba-style selective SSM branch (Hymba)
# ==========================================================================

def init_mamba(key: jax.Array, cfg) -> dict:
    d = cfg.d_model
    di = cfg.mamba_d_inner
    N = cfg.ssm_state
    ks = jax.random.split(key, 8)
    return {
        "w_in": dense_init(ks[0], (d, 2 * di), cfg.param_dtype),      # x & gate
        "w_bcdt": dense_init(ks[1], (di, 2 * N + 1), cfg.param_dtype),  # B, C, dt
        "dt_bias": jnp.zeros((1,), jnp.float32),
        "A_log": jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32))[None, :]
        * jnp.ones((di, 1), jnp.float32),                             # (di, N)
        "D": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[2], (di, d), cfg.param_dtype),
        "ln_out": jnp.ones((di,), cfg.param_dtype),
    }


def mamba_mixer(
    params: dict,
    cfg,
    x: jax.Array,                      # (B, S, D)
    state: Optional[jax.Array] = None,  # (B, di, N)
    chunk: int = 64,
):
    """Selective SSM: h_t = exp(A*dt_t) h_{t-1} + dt_t B_t x_t; y = C_t.h_t + D x.

    Outer scan over chunks; within-chunk via associative_scan (parallel
    prefix over the diagonal recurrence) so the (B, c, di, N) tensor stays
    chunk-bounded.
    """
    B, S, D = x.shape
    di, N = cfg.mamba_d_inner, cfg.ssm_state
    xz = x @ params["w_in"]
    u, z = jnp.split(xz, 2, axis=-1)                   # (B,S,di) each
    u = jax.nn.silu(u)
    bcdt = u @ params["w_bcdt"]                        # (B,S,2N+1)
    Bm, Cm, dt = bcdt[..., :N], bcdt[..., N : 2 * N], bcdt[..., 2 * N :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    dt = jnp.clip(dt, 1e-4, 10.0)                      # (B,S,1): scalar dt per token
    A = -jnp.exp(params["A_log"])                      # (di, N), negative

    nc = max(S // chunk, 1)
    c = S // nc
    uc = u.astype(jnp.float32).reshape(B, nc, c, di).transpose(1, 0, 2, 3)
    Bc = Bm.astype(jnp.float32).reshape(B, nc, c, N).transpose(1, 0, 2, 3)
    Cc = Cm.astype(jnp.float32).reshape(B, nc, c, N).transpose(1, 0, 2, 3)
    dtc = dt.reshape(B, nc, c, 1).transpose(1, 0, 2, 3)

    def body(h, args):
        ui, Bi, Ci, dti = args                         # (B,c,di) (B,c,N) (B,c,N) (B,c,1)
        a = jnp.exp(dti[..., None] * A[None, None])    # (B,c,di,N)
        b = (dti * Bi)[:, :, None, :] * ui[..., None]  # (B,c,di,N)

        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, br + ar * bl

        a_sc, b_sc = jax.lax.associative_scan(comb, (a, b), axis=1)
        hs = a_sc * h[:, None] + b_sc                  # (B,c,di,N)
        y = jnp.einsum("bcdn,bcn->bcd", hs, Ci)
        return hs[:, -1], y

    h0 = jnp.zeros((B, di, N), jnp.float32) if state is None else state
    body = jax.checkpoint(body, prevent_cse=False)
    h_final, ys = jax.lax.scan(body, h0, (uc, Bc, Cc, dtc))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, di)
    y = y + params["D"][None, None] * u.astype(jnp.float32)
    y = rms_norm(y.astype(x.dtype), params["ln_out"]) * jax.nn.silu(z)
    return y @ params["w_out"], h_final
