from repro.models.api import (
    active_param_count,
    decode_step,
    forward_hidden,
    init_cache,
    init_params,
    loss_fn,
    param_count,
)

__all__ = [
    "init_params",
    "loss_fn",
    "forward_hidden",
    "init_cache",
    "decode_step",
    "param_count",
    "active_param_count",
]
