"""Attention mixers: GQA (full / sliding-window, optional qk-norm), and
DeepSeek-V2 MLA (multi-head latent attention) with the absorbed decode path
that attends directly over the compressed kv-lora cache.

Training/prefill attention is query-chunked (lax.map over query blocks) so the
(B, H, Sq, Sk) score tensor never materialises beyond one chunk — this bounds
the per-device transient to chunk*Sk scores, which is what lets the 32k
prefill shapes fit HBM in the dry-run.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, rms_norm

NEG_INF = -1e30


# ==========================================================================
# GQA
# ==========================================================================

def init_gqa(key: jax.Array, cfg) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), cfg.param_dtype),
        "wk": dense_init(ks[1], (d, KV * hd), cfg.param_dtype),
        "wv": dense_init(ks[2], (d, KV * hd), cfg.param_dtype),
        "wo": dense_init(ks[3], (H * hd, d), cfg.param_dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.param_dtype)
        p["k_norm"] = jnp.ones((hd,), cfg.param_dtype)
    return p


def _sdpa_chunked(
    q: jax.Array,            # (B, Sq, KV, G, hd)
    k: jax.Array,            # (B, Sk, KV, hd)
    v: jax.Array,            # (B, Sk, KV, hd)
    q_positions: jax.Array,  # (Sq,) global positions of queries
    k_positions: jax.Array,  # (Sk,) global positions of keys
    window: int,             # 0 = full causal
    chunk: int,
) -> jax.Array:
    """Exact causal attention, sequential over query chunks."""
    B, Sq, KV, G, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    nc = max(Sq // chunk, 1)
    chunk = Sq // nc
    qc = q.reshape(B, nc, chunk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)  # (nc, B, c, KV, G, hd)
    qpos = q_positions.reshape(nc, chunk)

    def one(args):
        qi, qp = args                                    # (B, c, KV, G, hd), (c,)
        # mixed precision (§Perf A2): bf16 operands, f32 MXU accumulation —
        # no materialised f32 upcasts of Q/K/V (the baseline .astype(f32)
        # dominated HBM traffic with convert/copy ops)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qi, k,
                       preferred_element_type=jnp.float32) * scale
        causal = k_positions[None, :] <= qp[:, None]     # (c, Sk)
        if window > 0:
            causal &= (qp[:, None] - k_positions[None, :]) < window
        s = jnp.where(causal[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)                   # f32
        return jnp.einsum("bkgqs,bskh->bqkgh", p.astype(q.dtype), v,
                          preferred_element_type=jnp.float32).astype(q.dtype)

    # remat per chunk: the (B, H, c, Sk) f32 probs are recomputed in the
    # backward chunk-by-chunk instead of all chunks being stored at once
    one = jax.checkpoint(one, prevent_cse=False)
    out = jax.lax.map(one, (qc, qpos))                   # (nc, B, c, KV, G, hd_v)
    hd_v = v.shape[-1]
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KV * G * hd_v)


def gqa_attention(
    params: dict,
    cfg,
    x: jax.Array,                       # (B, S, D)
    positions: jax.Array,               # (S,)
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,   # ((B,Sc,KV,hd) k, v)
    cache_positions: Optional[jax.Array] = None,               # (Sc,)
    window: Optional[int] = None,
    chunk: int = 1024,
):
    """Returns (out (B,S,D), new_kv or None).

    Training/prefill: kv_cache is None -> keys are this segment.
    Decode: kv_cache given, S==1 -> append then attend over the cache ring.
    """
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KV
    win = cfg.sliding_window if window is None else window

    q = (x @ params["wq"]).reshape(B, S, H, hd)
    k = (x @ params["wk"]).reshape(B, S, KV, hd)
    v = (x @ params["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if cfg.rope_theta > 0:
        q = apply_rope(q, positions[None, :], cfg.rope_theta)
        k = apply_rope(k, positions[None, :], cfg.rope_theta)
    q = q.reshape(B, S, KV, G, hd)

    if kv_cache is None:
        out = _sdpa_chunked(q, k, v, positions, positions, win, chunk)
        new_kv = (k, v)
    else:
        # decode: caller manages the ring buffer slot + updated kpos
        ck, cv = kv_cache
        slot = slot_of(positions, ck.shape[1])
        ck = jax.lax.dynamic_update_slice(ck, k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, slot, 0, 0))
        out = _sdpa_chunked(q, ck, cv, positions, cache_positions, win, chunk=1)
        new_kv = (ck, cv)
    return out @ params["wo"], new_kv


def slot_of(positions: jax.Array, cache_len: int) -> jax.Array:
    """Ring-buffer slot for a single decode token."""
    return positions[0] % cache_len


def update_kpos(cache_positions: jax.Array, positions: jax.Array) -> jax.Array:
    slot = slot_of(positions, cache_positions.shape[0])
    return jax.lax.dynamic_update_slice(cache_positions, positions, (slot,))


# ==========================================================================
# MLA (DeepSeek-V2 multi-head latent attention)
# ==========================================================================

def init_mla(key: jax.Array, cfg) -> dict:
    d, H = cfg.d_model, cfg.num_heads
    r_kv, r_q = cfg.kv_lora_rank, cfg.q_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return {
        "w_dq": dense_init(ks[0], (d, r_q), cfg.param_dtype),          # q down
        "q_ln": jnp.ones((r_q,), cfg.param_dtype),
        "w_uq": dense_init(ks[1], (r_q, H * (dn + dr)), cfg.param_dtype),
        "w_dkv": dense_init(ks[2], (d, r_kv), cfg.param_dtype),        # kv down
        "kv_ln": jnp.ones((r_kv,), cfg.param_dtype),
        "w_kpe": dense_init(ks[3], (d, dr), cfg.param_dtype),          # shared rope key
        "w_uk": dense_init(ks[4], (r_kv, H * dn), cfg.param_dtype),
        "w_uv": dense_init(ks[5], (r_kv, H * dv), cfg.param_dtype),
        "wo": dense_init(ks[6], (H * dv, d), cfg.param_dtype),
    }


def _mla_qk(params, cfg, x, positions):
    """Shared q/compressed-kv projections. Returns q_nope, q_pe, c_kv, k_pe."""
    B, S, _ = x.shape
    H = cfg.num_heads
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    q_lat = rms_norm(x @ params["w_dq"], params["q_ln"])
    q = (q_lat @ params["w_uq"]).reshape(B, S, H, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = apply_rope(q_pe, positions[None, :], cfg.rope_theta)
    c_kv = rms_norm(x @ params["w_dkv"], params["kv_ln"])               # (B, S, r_kv)
    k_pe = (x @ params["w_kpe"]).reshape(B, S, 1, dr)
    k_pe = apply_rope(k_pe, positions[None, :], cfg.rope_theta)[:, :, 0]  # (B, S, dr)
    return q_nope, q_pe, c_kv, k_pe


def mla_attention(
    params: dict,
    cfg,
    x: jax.Array,
    positions: jax.Array,
    kv_cache=None,                    # (c_kv (B,Sc,r_kv), k_pe (B,Sc,dr), kpos)
    cache_positions=None,
    chunk: int = 1024,
):
    """MLA. Prefill materialises per-head K/V from the latent (matmul-heavy,
    MXU-friendly); decode uses the ABSORBED form — queries are mapped into
    latent space (q~ = W_uk^T q_nope) and attention runs directly over the
    (B, Sc, r_kv) compressed cache, which is the paper-relevant feature:
    the KV cache is r_kv+dr=576 floats/token instead of 2*H*hd=32768."""
    B, S, D = x.shape
    H = cfg.num_heads
    dn, dr, dv, r_kv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim, cfg.kv_lora_rank
    scale = 1.0 / math.sqrt(dn + dr)
    q_nope, q_pe, c_kv, k_pe = _mla_qk(params, cfg, x, positions)

    if kv_cache is None:
        # non-absorbed prefill: materialise K/V per head (head-sharded)
        from repro.sharding.ctx import shard_heads

        k_nope = shard_heads((c_kv @ params["w_uk"]).reshape(B, S, H, dn))
        v = shard_heads((c_kv @ params["w_uv"]).reshape(B, S, H, dv))
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (B, S, H, dr))], -1)
        k = shard_heads(k)
        q = jnp.concatenate([q_nope, q_pe], -1).reshape(B, S, H, 1, dn + dr)
        q = shard_heads(q)
        out = _sdpa_chunked(q, k, v, positions, positions, 0, chunk)   # KV=H, G=1
        out = out.reshape(B, S, H * dv)
        new_cache = (c_kv, k_pe)
    else:
        cc, cpe = kv_cache
        kpos = cache_positions
        slot = slot_of(positions, cc.shape[1])
        cc = jax.lax.dynamic_update_slice(cc, c_kv, (0, slot, 0))
        cpe = jax.lax.dynamic_update_slice(cpe, k_pe, (0, slot, 0))
        # absorbed: q~ (B,1,H,r_kv) = q_nope @ W_uk (viewed (r_kv, H, dn))
        w_uk = params["w_uk"].reshape(r_kv, H, dn)
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))
        s = jnp.einsum("bqhr,bsr->bhqs", q_lat, cc.astype(jnp.float32))
        s = s + jnp.einsum("bqhd,bsd->bhqs", q_pe.astype(jnp.float32),
                           cpe.astype(jnp.float32))
        s = s * scale
        mask = kpos[None, :] <= positions[:, None]                     # (1, Sc)
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        lat = jnp.einsum("bhqs,bsr->bqhr", p, cc.astype(jnp.float32))  # (B,1,H,r_kv)
        w_uv = params["w_uv"].reshape(r_kv, H, dv)
        out = jnp.einsum("bqhr,rhv->bqhv", lat, w_uv.astype(jnp.float32))
        out = out.reshape(B, S, H * dv).astype(x.dtype)
        new_cache = (cc, cpe)
    return out @ params["wo"], new_cache
