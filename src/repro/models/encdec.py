"""Encoder-decoder backbone (Whisper-medium shape).

Frontend carve-out: the mel-spectrogram + conv feature extractor is a STUB —
the model consumes precomputed frame embeddings (B, num_prefix, d_model).
The encoder is bidirectional self-attention + MLP; the decoder adds causal
self-attention (KV-cached for decode) and cross-attention over the encoder
output (whose K/V are computed once and cached for decode).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.layers import (
    cross_entropy,
    dense_init,
    embed,
    init_embed,
    init_mlp,
    mlp,
    rms_norm,
    unembed,
)
from repro.models.lm import KPOS_EMPTY, mask_pad_logits
from repro.sharding.ctx import shard_batch_seq, shard_logits

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# cross attention
# --------------------------------------------------------------------------

def init_cross(key: jax.Array, cfg: ArchConfig) -> Params:
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, H * hd), cfg.param_dtype),
        "wk": dense_init(ks[1], (d, H * hd), cfg.param_dtype),
        "wv": dense_init(ks[2], (d, H * hd), cfg.param_dtype),
        "wo": dense_init(ks[3], (H * hd, d), cfg.param_dtype),
    }


def cross_kv(params: Params, cfg: ArchConfig, memory: jax.Array) -> Tuple[jax.Array, jax.Array]:
    B, P, _ = memory.shape
    H, hd = cfg.num_heads, cfg.head_dim
    k = (memory @ params["wk"]).reshape(B, P, H, hd)
    v = (memory @ params["wv"]).reshape(B, P, H, hd)
    return k, v


def cross_attention(params: Params, cfg: ArchConfig, x: jax.Array,
                    k: jax.Array, v: jax.Array) -> jax.Array:
    B, S, _ = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, S, H, hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(x.dtype)
    return out.reshape(B, S, H * hd) @ params["wo"]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_enc_layer(key: jax.Array, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "ffn_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "attn": attn.init_gqa(k1, cfg),
        "ffn": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.param_dtype),
    }


def _init_dec_layer(key: jax.Array, cfg: ArchConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "attn_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "cross_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "ffn_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "attn": attn.init_gqa(k1, cfg),
        "cross": init_cross(k2, cfg),
        "ffn": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.param_dtype),
    }


def init_params(key: jax.Array, cfg: ArchConfig) -> Params:
    ke, kd, kt, kp, kpe = jax.random.split(key, 5)
    enc = jax.vmap(lambda k: _init_enc_layer(k, cfg))(jax.random.split(ke, cfg.enc_layers))
    dec = jax.vmap(lambda k: _init_dec_layer(k, cfg))(jax.random.split(kd, cfg.num_layers))
    return {
        "embed": init_embed(kt, cfg.vocab_pad, cfg.d_model, cfg.param_dtype),
        "pos_embed": dense_init(kp, (cfg.learned_pos, cfg.d_model), cfg.param_dtype, scale=0.02),
        "enc_pos_embed": dense_init(kpe, (cfg.num_prefix, cfg.d_model), cfg.param_dtype, scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "enc_final_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "enc_layers": enc,
        "layers": dec,
    }


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def encode(params: Params, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, P, D) stub embeddings -> encoder output (B, P, D)."""
    B, P, D = frames.shape
    x = frames.astype(cfg.param_dtype) + params["enc_pos_embed"][None, :P]
    positions = jnp.arange(P)
    x = shard_batch_seq(x)

    def body(carry, p):
        h = rms_norm(carry, p["attn_norm"])
        # bidirectional: window=0 and no causal mask -> implement by giving
        # every query position the max position so all keys pass the mask
        out, _ = attn.gqa_attention(
            p["attn"], cfg, h, positions * 0 + (P - 1), window=0, chunk=cfg.attn_chunk
        )
        carry = carry + out
        h = rms_norm(carry, p["ffn_norm"])
        return carry + mlp(p["ffn"], h), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"],
                        unroll=cfg.enc_layers if cfg.scan_unroll else 1)
    return rms_norm(x, params["enc_final_norm"])


def forward(
    params: Params,
    cfg: ArchConfig,
    tokens: jax.Array,                # (B, S)
    prefix_embeds: jax.Array,         # (B, P, D) frame embeddings (stub)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (decoder hidden (B, S, D), aux=0)."""
    enc_out = encode(params, cfg, prefix_embeds)
    B, S = tokens.shape
    positions = jnp.arange(S)
    x = embed(tokens, params["embed"]) + params["pos_embed"][positions][None]
    x = shard_batch_seq(x)

    def body(carry, p):
        h = rms_norm(carry, p["attn_norm"])
        out, _ = attn.gqa_attention(p["attn"], cfg, h, positions, chunk=cfg.attn_chunk)
        carry = carry + out
        h = rms_norm(carry, p["cross_norm"])
        k, v = cross_kv(p["cross"], cfg, enc_out)
        carry = carry + cross_attention(p["cross"], cfg, h, k, v)
        h = rms_norm(carry, p["ffn_norm"])
        return carry + mlp(p["ffn"], h), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"],
                        unroll=cfg.num_layers if cfg.scan_unroll else 1)
    return rms_norm(x, params["final_norm"]), jnp.zeros((), jnp.float32)


def loss_fn(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            aux_weight: float = 0.0,
            example_weights: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    hidden, _ = forward(params, cfg, batch["tokens"], batch["prefix_embeds"])
    logits = mask_pad_logits(
        shard_logits(unembed(hidden, params["embed"], tied=True)), cfg.vocab_size)
    ce = cross_entropy(logits, batch["labels"]).mean(axis=-1)
    if example_weights is not None:
        denom = jnp.maximum(jnp.sum(example_weights), 1e-6)
        loss = jnp.sum(example_weights * ce) / denom
    else:
        loss = ce.mean()
    return loss, {"ce": loss, "aux": jnp.zeros(())}


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype=None) -> Dict[str, Any]:
    dt = dtype or cfg.param_dtype
    L, H, KV, hd = cfg.num_layers, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    P = cfg.num_prefix
    return {
        "layers": {
            "k": jnp.zeros((L, batch, cache_len, KV, hd), dt),
            "v": jnp.zeros((L, batch, cache_len, KV, hd), dt),
            "cross_k": jnp.zeros((L, batch, P, H, hd), dt),
            "cross_v": jnp.zeros((L, batch, P, H, hd), dt),
        },
        "kpos": jnp.full((cache_len,), KPOS_EMPTY, jnp.int32),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill_cross(params: Params, cfg: ArchConfig, cache: Dict[str, Any],
                  frames: jax.Array) -> Dict[str, Any]:
    """Run the encoder once and stash per-layer cross K/V (real serving path;
    the dry-run decode shape assumes this already happened)."""
    enc_out = encode(params, cfg, frames)

    def per_layer(p):
        return cross_kv(p["cross"], cfg, enc_out)

    ck, cv = jax.vmap(per_layer)(params["layers"])
    layers = dict(cache["layers"])
    layers["cross_k"], layers["cross_v"] = ck, cv
    return {**cache, "layers": layers}


def decode_step(params: Params, cfg: ArchConfig, cache: Dict[str, Any],
                tokens: jax.Array) -> Tuple[jax.Array, Dict[str, Any]]:
    pos = cache["pos"]
    positions = pos[None]
    x = embed(tokens, params["embed"]) + params["pos_embed"][positions][None]
    kpos = attn.update_kpos(cache["kpos"], positions)

    def body(carry, xs):
        p, lc = xs
        new_lc = dict(lc)
        h = rms_norm(carry, p["attn_norm"])
        out, (ck, cv) = attn.gqa_attention(
            p["attn"], cfg, h, positions, kv_cache=(lc["k"], lc["v"]),
            cache_positions=kpos)
        new_lc["k"], new_lc["v"] = ck, cv
        carry = carry + out
        h = rms_norm(carry, p["cross_norm"])
        carry = carry + cross_attention(p["cross"], cfg, h, lc["cross_k"], lc["cross_v"])
        h = rms_norm(carry, p["ffn_norm"])
        return carry + mlp(p["ffn"], h), new_lc

    x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]),
                                 unroll=cfg.num_layers if cfg.scan_unroll else 1)
    x = rms_norm(x, params["final_norm"])
    logits = mask_pad_logits(
        shard_logits(unembed(x, params["embed"], tied=True)), cfg.vocab_size)
    return logits, {"layers": new_layers, "kpos": kpos, "pos": pos + 1}
