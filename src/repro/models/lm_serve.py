"""Batched LM serving engine: prefill + greedy/temperature decode over the
family-dispatched ``decode_step``.

``make_serve_step`` is the jit/pjit unit the dry-run lowers for the decode
shapes: ONE token against a standing cache of ``cache_len``.

(Relocated from ``repro.serve.engine``: this is the language-model decode
stub of the seed, unrelated to coresets — ``repro.serve`` is the coreset
service namespace.  ``repro.serve.engine`` keeps a deprecation re-export.)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import api as model_api


def make_serve_step(cfg: ArchConfig):
    """serve_step(params, cache, tokens (B,1)) -> (logits (B,1,V), cache)."""

    def serve_step(params, cache, tokens):
        return model_api.decode_step(params, cfg, cache, tokens)

    return serve_step


@dataclasses.dataclass
class ServeEngine:
    """Minimal batched engine for the examples: greedy/temperature sampling.

    Prefill runs token-by-token through ``decode_step`` (exact; fine at
    example scale — production prefill would lower the chunked forward).
    """

    cfg: ArchConfig
    params: Any
    cache_len: int = 4096

    def __post_init__(self) -> None:
        self._step = jax.jit(make_serve_step(self.cfg))

    def generate(
        self,
        prompts: jax.Array,                # (B, P) int32
        max_new_tokens: int = 32,
        temperature: float = 0.0,
        key: Optional[jax.Array] = None,
        prefix_embeds: Optional[jax.Array] = None,   # encdec/vlm stub inputs
    ) -> jax.Array:
        B, P = prompts.shape
        cache = model_api.init_cache(self.cfg, B, self.cache_len)
        if self.cfg.kind == "encdec":
            from repro.models import encdec
            assert prefix_embeds is not None, "encdec needs frame embeddings"
            cache = encdec.prefill_cross(self.params, self.cfg, cache, prefix_embeds)
        # prefill
        logits = None
        for t in range(P):
            logits, cache = self._step(self.params, cache, prompts[:, t : t + 1])
        # decode
        out = []
        tok = self._sample(logits, temperature, key, 0)
        for i in range(max_new_tokens):
            out.append(tok)
            logits, cache = self._step(self.params, cache, tok)
            key = None if key is None else jax.random.fold_in(key, i)
            tok = self._sample(logits, temperature, key, i + 1)
        return jnp.concatenate(out, axis=1)            # (B, max_new_tokens)

    @staticmethod
    def _sample(logits, temperature, key, i):
        last = logits[:, -1, :]
        if temperature <= 0.0 or key is None:
            return jnp.argmax(last, axis=-1, keepdims=True).astype(jnp.int32)
        return jax.random.categorical(
            jax.random.fold_in(key, 7919 + i), last / temperature, axis=-1
        )[:, None].astype(jnp.int32)
