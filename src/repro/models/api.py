"""Family-dispatching model API: init / loss / decode for any ArchConfig."""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, lm


def init_params(key: jax.Array, cfg: ArchConfig):
    if cfg.kind == "encdec":
        return encdec.init_params(key, cfg)
    return lm.init_params(key, cfg)


def loss_fn(params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            example_weights: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict]:
    if cfg.kind == "encdec":
        return encdec.loss_fn(params, cfg, batch, example_weights=example_weights)
    return lm.loss_fn(params, cfg, batch, example_weights=example_weights)


def forward_hidden(params, cfg: ArchConfig, batch: Dict[str, jax.Array]):
    """Hidden states (B, S_text, D) — used by the coreset batch selector."""
    if cfg.kind == "encdec":
        h, _ = encdec.forward(params, cfg, batch["tokens"], batch["prefix_embeds"])
        return h
    h, _ = lm.forward(params, cfg, batch["tokens"], batch.get("prefix_embeds"))
    if batch.get("prefix_embeds") is not None:
        h = h[:, batch["prefix_embeds"].shape[1] :]
    return h


def init_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype=None):
    if cfg.kind == "encdec":
        return encdec.init_cache(cfg, batch, cache_len, dtype)
    return lm.init_cache(cfg, batch, cache_len, dtype)


def decode_step(params, cfg: ArchConfig, cache, tokens):
    if cfg.kind == "encdec":
        return encdec.decode_step(params, cfg, cache, tokens)
    return lm.decode_step(params, cfg, cache, tokens)


def param_count(params) -> int:
    return sum(int(jnp.size(p)) for p in jax.tree_util.tree_leaves(params))


def active_param_count(cfg: ArchConfig, params) -> int:
    """Active params per token (MoE: top-k of routed experts + the rest)."""
    total = param_count(params)
    if not cfg.is_moe:
        return total

    def expert_leaves(tree):
        return sum(
            int(jnp.size(p))
            for path, p in jax.tree_util.tree_flatten_with_path(tree)[0]
            if any(getattr(k, "key", None) == "moe" for k in path)
            and not any(getattr(k, "key", None) == "router" for k in path)
        )

    e_total = expert_leaves(params)
    active_frac = cfg.num_experts_per_tok / max(cfg.num_experts, 1)
    return int(total - e_total + e_total * active_frac)
