"""Common transformer building blocks (functional, params-as-pytrees)."""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def dense_init(key: jax.Array, shape: Tuple[int, ...], dtype, scale: Optional[float] = None) -> jax.Array:
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32) * std).astype(dtype)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate (..., S, H, hd) by per-position angles. positions: (..., S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                                  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv         # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                             # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------

def init_mlp(key: jax.Array, d: int, f: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, f), dtype),
        "w_up": dense_init(k2, (d, f), dtype),
        "w_down": dense_init(k3, (f, d), dtype),
    }


def mlp(params: dict, x: jax.Array) -> jax.Array:
    g = jax.nn.silu(x @ params["w_gate"])
    return (g * (x @ params["w_up"])) @ params["w_down"]


# --------------------------------------------------------------------------
# Embeddings / head
# --------------------------------------------------------------------------

def init_embed(key: jax.Array, vocab: int, d: int, dtype) -> jax.Array:
    # 1/sqrt(d): unit-RMS hidden states and O(1) tied logits at init
    return dense_init(key, (vocab, d), dtype, scale=1.0 / math.sqrt(d))


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table_or_head: jax.Array, tied: bool) -> jax.Array:
    """Logits in f32 (softmax stability)."""
    w = table_or_head.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    if tied:
        return xf @ w.T        # table (V, D)
    return xf @ w              # head (D, V)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-position CE. logits (..., V) f32, labels (...) int."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return logz - gold
