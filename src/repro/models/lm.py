"""Decoder-only language model, assembled from an ArchConfig.

All per-layer parameters are stacked on a leading L axis and the layer stack
runs as a single ``jax.lax.scan`` (optionally rematerialised), which keeps
the lowered HLO size O(1) in depth — essential for compiling 60-layer models
against a 512-device mesh on this host.

Covers the dense / moe / ssm / hybrid / vlm families; the enc-dec (whisper)
family builds on these pieces in :mod:`repro.models.encdec`.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.layers import (
    cross_entropy,
    dense_init,
    embed,
    init_embed,
    init_mlp,
    mlp,
    rms_norm,
    unembed,
)
from repro.sharding.ctx import shard_batch_seq, shard_logits

Params = Dict[str, Any]

KPOS_EMPTY = jnp.iinfo(jnp.int32).max // 2   # "slot never written" marker


# ==========================================================================
# init
# ==========================================================================

def init_layer(key: jax.Array, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {
        "attn_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "ffn_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
    }
    if cfg.mixer == "attention":
        if cfg.attn_type == "mla":
            p["mla"] = attn.init_mla(ks[0], cfg)
        else:
            p["attn"] = attn.init_gqa(ks[0], cfg)
    elif cfg.mixer == "rwkv6":
        p["rwkv"] = ssm.init_rwkv6(ks[0], cfg)
    elif cfg.mixer == "hymba":
        p["attn"] = attn.init_gqa(ks[0], cfg)
        p["mamba"] = ssm.init_mamba(ks[1], cfg)
    else:
        raise ValueError(cfg.mixer)
    if cfg.is_moe:
        p["moe"] = moe_mod.init_moe(ks[2], cfg)
        if cfg.shared_d_ff:
            p["ffn"] = init_mlp(ks[3], cfg.d_model, cfg.shared_d_ff, cfg.param_dtype)
    else:
        p["ffn"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.param_dtype)
    return p


def init_params(key: jax.Array, cfg: ArchConfig) -> Params:
    k_embed, k_head, k_layers, k_pos = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    params: Params = {
        "embed": init_embed(k_embed, cfg.vocab_pad, cfg.d_model, cfg.param_dtype),
        "final_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(k_head, (cfg.d_model, cfg.vocab_pad), cfg.param_dtype)
    if cfg.learned_pos:
        params["pos_embed"] = dense_init(k_pos, (cfg.learned_pos, cfg.d_model), cfg.param_dtype, scale=0.02)
    return params


# ==========================================================================
# training / prefill forward
# ==========================================================================

def _layer_fwd(cfg: ArchConfig, x: jax.Array, p: Params, positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One block. Returns (x, aux_loss)."""
    h = rms_norm(x, p["attn_norm"])
    if cfg.mixer == "attention":
        if cfg.attn_type == "mla":
            out, _ = attn.mla_attention(p["mla"], cfg, h, positions, chunk=cfg.attn_chunk)
        else:
            out, _ = attn.gqa_attention(p["attn"], cfg, h, positions, chunk=cfg.attn_chunk)
    elif cfg.mixer == "rwkv6":
        out, _ = ssm.rwkv6_mixer(p["rwkv"], cfg, h, chunk=cfg.ssm_chunk)
    else:  # hymba: parallel attention + mamba heads
        a, _ = attn.gqa_attention(p["attn"], cfg, h, positions, chunk=cfg.attn_chunk)
        m, _ = ssm.mamba_mixer(p["mamba"], cfg, h, chunk=max(cfg.ssm_chunk, 4))
        out = 0.5 * (a + m)
    x = x + shard_batch_seq(out)

    h = rms_norm(x, p["ffn_norm"])
    aux = jnp.zeros((), jnp.float32)
    if cfg.is_moe:
        out, aux = moe_mod.moe_ffn(p["moe"], cfg, h, cfg.capacity_factor)
        if cfg.shared_d_ff:
            out = out + mlp(p["ffn"], h)
    else:
        out = mlp(p["ffn"], h)
    x = x + shard_batch_seq(out)
    return x, aux


def forward(
    params: Params,
    cfg: ArchConfig,
    tokens: jax.Array,                       # (B, S_text)
    prefix_embeds: Optional[jax.Array] = None,   # (B, P, D) for vlm/audio stubs
) -> Tuple[jax.Array, jax.Array]:
    """Returns (hidden (B, S, D), total_aux_loss)."""
    x = embed(tokens, params["embed"])
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    B, S, D = x.shape
    positions = jnp.arange(S)
    if cfg.learned_pos:
        x = x + params["pos_embed"][positions][None]
    x = shard_batch_seq(x)

    def body(carry, layer_p):
        y, aux = _layer_fwd(cfg, carry, layer_p, positions)
        return y, aux

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, auxes = jax.lax.scan(body, x, params["layers"],
                           unroll=cfg.num_layers if cfg.scan_unroll else 1)
    x = rms_norm(x, params["final_norm"])
    return x, jnp.sum(auxes)


def mask_pad_logits(logits: jax.Array, vocab: int) -> jax.Array:
    """-inf on the padded vocab columns (see ArchConfig.vocab_pad)."""
    if logits.shape[-1] == vocab:
        return logits
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(col < vocab, logits, -1e30)


def logits_of(params: Params, cfg: ArchConfig, hidden: jax.Array) -> jax.Array:
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = shard_logits(unembed(hidden, head, cfg.tie_embeddings))
    return mask_pad_logits(logits, cfg.vocab_size)


def loss_fn(
    params: Params,
    cfg: ArchConfig,
    batch: Dict[str, jax.Array],
    aux_weight: float = 0.01,
    example_weights: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token CE (+ MoE aux). For prefix archs (vlm/audio) the loss is
    computed on the text positions only."""
    prefix = batch.get("prefix_embeds")
    hidden, aux = forward(params, cfg, batch["tokens"], prefix)
    if prefix is not None:
        hidden = hidden[:, prefix.shape[1] :]
    logits = logits_of(params, cfg, hidden)
    ce = cross_entropy(logits, batch["labels"])              # (B, S_text)
    per_example = ce.mean(axis=-1)                           # (B,)
    if example_weights is not None:
        denom = jnp.maximum(jnp.sum(example_weights), 1e-6)
        loss = jnp.sum(example_weights * per_example) / denom
    else:
        loss = per_example.mean()
    total = loss + aux_weight * aux
    return total, {"ce": loss, "aux": aux}


# ==========================================================================
# decode (serve_step)
# ==========================================================================

def init_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype=None) -> Dict[str, Any]:
    """Decode-state pytree. ``cache_len`` is the ring size: full seq_len for
    exact attention, the window for sliding-window, ignored by pure SSM."""
    dt = dtype or cfg.param_dtype
    L = cfg.num_layers
    layers: Dict[str, Any] = {}
    if cfg.mixer in ("attention", "hymba") and cfg.attn_type != "mla":
        KV, hd = cfg.num_kv_heads, cfg.head_dim
        eff = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        layers["k"] = jnp.zeros((L, batch, eff, KV, hd), dt)
        layers["v"] = jnp.zeros((L, batch, eff, KV, hd), dt)
    if cfg.attn_type == "mla":
        layers["c_kv"] = jnp.zeros((L, batch, cache_len, cfg.kv_lora_rank), dt)
        layers["k_pe"] = jnp.zeros((L, batch, cache_len, cfg.qk_rope_dim), dt)
    if cfg.mixer == "rwkv6":
        H, hd = cfg.num_heads, cfg.d_model // cfg.num_heads
        layers["wkv"] = jnp.zeros((L, batch, H, hd, hd), jnp.float32)
        layers["shift"] = jnp.zeros((L, batch, cfg.d_model), dt)
    if cfg.mixer == "hymba":
        layers["mamba_h"] = jnp.zeros((L, batch, cfg.mamba_d_inner, cfg.ssm_state), jnp.float32)
    cache: Dict[str, Any] = {"layers": layers, "pos": jnp.zeros((), jnp.int32)}
    if ("k" in layers) or ("c_kv" in layers):
        eff = layers.get("k", layers.get("c_kv")).shape[2]
        cache["kpos"] = jnp.full((eff,), KPOS_EMPTY, jnp.int32)
    return cache


def _layer_decode(
    cfg: ArchConfig,
    x: jax.Array,
    p: Params,
    lc: Dict[str, jax.Array],
    positions: jax.Array,
    kpos: Optional[jax.Array],
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    new_lc = dict(lc)
    h = rms_norm(x, p["attn_norm"])
    if cfg.mixer == "attention":
        if cfg.attn_type == "mla":
            out, (cc, cpe) = attn.mla_attention(
                p["mla"], cfg, h, positions, kv_cache=(lc["c_kv"], lc["k_pe"]),
                cache_positions=kpos)
            new_lc["c_kv"], new_lc["k_pe"] = cc, cpe
        else:
            out, (ck, cv) = attn.gqa_attention(
                p["attn"], cfg, h, positions, kv_cache=(lc["k"], lc["v"]),
                cache_positions=kpos)
            new_lc["k"], new_lc["v"] = ck, cv
    elif cfg.mixer == "rwkv6":
        out, st = ssm.rwkv6_mixer(p["rwkv"], cfg, h,
                                  state={"wkv": lc["wkv"], "shift": lc["shift"]},
                                  chunk=1)
        new_lc["wkv"], new_lc["shift"] = st["wkv"], st["shift"]
    else:  # hymba
        a, (ck, cv) = attn.gqa_attention(
            p["attn"], cfg, h, positions, kv_cache=(lc["k"], lc["v"]),
            cache_positions=kpos)
        m, hm = ssm.mamba_mixer(p["mamba"], cfg, h, state=lc["mamba_h"], chunk=1)
        new_lc["k"], new_lc["v"], new_lc["mamba_h"] = ck, cv, hm
        out = 0.5 * (a + m)
    x = x + out

    h = rms_norm(x, p["ffn_norm"])
    if cfg.is_moe:
        out, _ = moe_mod.moe_ffn(p["moe"], cfg, h, cfg.capacity_factor)
        if cfg.shared_d_ff:
            out = out + mlp(p["ffn"], h)
    else:
        out = mlp(p["ffn"], h)
    return x + out, new_lc


def decode_step(
    params: Params,
    cfg: ArchConfig,
    cache: Dict[str, Any],
    tokens: jax.Array,                 # (B, 1)
) -> Tuple[jax.Array, Dict[str, Any]]:
    """serve_step: ONE new token against the standing cache."""
    pos = cache["pos"]
    positions = pos[None]                                    # (1,)
    x = embed(tokens, params["embed"])
    if cfg.learned_pos:
        x = x + params["pos_embed"][positions][None]

    kpos = cache.get("kpos")
    if kpos is not None:
        kpos = attn.update_kpos(kpos, positions)

    def body(carry, xs):
        layer_p, lc = xs
        y, new_lc = _layer_decode(cfg, carry, layer_p, lc, positions, kpos)
        return y, new_lc

    x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]),
                                 unroll=cfg.num_layers if cfg.scan_unroll else 1)
    x = rms_norm(x, params["final_norm"])
    logits = logits_of(params, cfg, x)                       # (B, 1, V)
    new_cache = {"layers": new_layers, "pos": pos + 1}
    if kpos is not None:
        new_cache["kpos"] = kpos
    return logits, new_cache
