"""Multi-tenant coreset service: long-lived trees, a shared plan cache, and
cross-tenant request batching.

One :class:`CoresetService` process serves many tenants (one VFL federation
each).  Three things make it a SERVICE rather than a loop over
:class:`~repro.serve.tree.CoresetTree`:

  * **Plan cache** — every tenant's leaf builds plan through one shared
    :class:`~repro.core.plan.PlanCache` keyed on
    ``(task, shapes, resolved knobs)``.  Since jit caches key on the same
    shapes, a plan hit means the compiled scan programs are already warm:
    the FIRST tenant at a given (chunk shape, task, knobs) pays
    compilation, every later tenant streams at steady-state throughput
    (the warm/cold gap is what ``benchmarks/serve.py`` measures).
  * **Per-tenant state** — each tenant owns a tree, a ledger, and a
    deterministic key chain seeded at registration; the same registration +
    insert sequence replays the same draws regardless of what other
    tenants do (pinned in ``tests/test_serve_service.py``).
  * **Cross-tenant batching** — one-shot build requests against shared
    reference datasets (``attach_dataset`` / ``submit`` / ``flush``) are
    grouped by ``(dataset, task, backend, params)`` and executed as ONE
    ``build_coresets_batched`` grid per group — R tenants' requests cost
    one compiled dispatch instead of R.

All receipts carry wall latency and the tenant's ledger total so the
harness can report p50/p99 and verify composed accounting externally.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.core.api import CoresetTask, build_coresets_batched, get_task
from repro.core.comm import CommLedger
from repro.core.coreset import Coreset, MaterializedCoreset
from repro.core.faults import StreamCheckpoint, Transport
from repro.core.plan import PlanCache
from repro.core.vfl import VFLDataset
from repro.serve.tree import CoresetTree, InsertStats


@dataclasses.dataclass(frozen=True)
class InsertReceipt:
    tenant: str
    chunk_idx: int              # 0-based index of this chunk in the stream
    stats: InsertStats
    ledger_total: int           # tenant's composed comm bill after the insert
    plan_hit: bool              # leaf build reused a cached ExecutionPlan
    latency_s: float


@dataclasses.dataclass(frozen=True)
class QueryReceipt:
    tenant: str
    result: MaterializedCoreset
    m: int
    ledger_total: int
    latency_s: float


@dataclasses.dataclass(frozen=True)
class EvictReceipt:
    tenant: str
    chunks: int
    rows: int
    ledger_total: int           # final composed bill at eviction


@dataclasses.dataclass
class TenantState:
    """Everything the service holds for one federation."""

    name: str
    tree: CoresetTree
    inserts: int = 0
    queries: int = 0

    @property
    def ledger(self) -> CommLedger:
        return self.tree.ledger


@dataclasses.dataclass(frozen=True)
class _BuildRequest:
    ticket: int
    tenant: str
    dataset: str
    task: str
    m: int
    key: jax.Array
    params: Tuple[Tuple[str, Any], ...]


class CoresetService:
    """The long-lived serving layer.

    Streaming path: ``register`` a tenant (task, budget, seed), ``insert``
    superchunks as they arrive, ``query`` the current summary, ``evict``
    when the federation leaves.  Batch path: ``attach_dataset`` shared
    reference data, ``submit`` one-shot build requests from any tenants,
    ``flush`` to execute each compatible group as a single batched-engine
    dispatch.
    """

    def __init__(self, *, backend: str = "auto",
                 plan_cache: Optional[PlanCache] = None) -> None:
        self.backend = backend
        self.plan_cache = plan_cache if plan_cache is not None else PlanCache()
        self._tenants: Dict[str, TenantState] = {}
        self._datasets: Dict[str, VFLDataset] = {}
        self._pending: List[_BuildRequest] = []
        self._next_ticket = 0
        self.batched_flushes = 0
        self.batched_cells = 0

    # -- tenant lifecycle ----------------------------------------------------

    def register(
        self,
        tenant: str,
        *,
        task: Union[str, CoresetTask] = "vrlr",
        budget: int = 512,
        seed: int = 0,
        key: Optional[jax.Array] = None,
        block_size: int = 65536,
        chunk_blocks: Optional[int] = None,
        prefetch: Optional[bool] = None,
        headroom: int = 2,
        fault_policy: str = "fail",
        transport: Optional[Transport] = None,
        checkpoint: bool = False,
        **params: Any,
    ) -> TenantState:
        """Create a tenant: its tree, ledger, and key chain.  Deterministic —
        the same (seed/key, insert sequence) replays the same coresets.

        ``fault_policy``/``transport`` route the tenant's leaf builds and
        merges through the party fault seam (see :mod:`repro.core.faults`);
        ``checkpoint=True`` gives the tenant a persistent
        :class:`~repro.core.faults.StreamCheckpoint`, so an insert that
        crashes mid-build (and is rolled back by the tree) RESUMES its scan
        passes at the last completed superchunk when the chunk is retried —
        draw-identical to a never-failed insert.
        """
        if tenant in self._tenants:
            raise ValueError(f"tenant {tenant!r} already registered")
        if key is None:
            key = jax.random.PRNGKey(seed)
        tree = CoresetTree(
            task, budget, key=key, backend=self.backend,
            block_size=block_size, chunk_blocks=chunk_blocks,
            prefetch=prefetch, params=params, plan_cache=self.plan_cache,
            headroom=headroom, fault_policy=fault_policy,
            transport=transport,
            checkpoint=StreamCheckpoint() if checkpoint else None,
        )
        state = TenantState(name=tenant, tree=tree)
        self._tenants[tenant] = state
        return state

    def tenants(self) -> List[str]:
        return sorted(self._tenants)

    def state(self, tenant: str) -> TenantState:
        try:
            return self._tenants[tenant]
        except KeyError:
            raise KeyError(f"unknown tenant {tenant!r}; "
                           f"registered: {self.tenants()}") from None

    def evict(self, tenant: str) -> EvictReceipt:
        st = self.state(tenant)
        del self._tenants[tenant]
        return EvictReceipt(tenant=tenant, chunks=st.tree.num_chunks,
                            rows=st.tree.n_total,
                            ledger_total=st.ledger.total)

    # -- streaming path ------------------------------------------------------

    def insert(self, tenant: str, parts: Sequence[Any],
               y: Optional[Any] = None) -> InsertReceipt:
        """Absorb one superchunk into the tenant's tree.

        Validates the chunk at the service edge — a malformed request fails
        with a clear error BEFORE any tree state is touched (the tree's own
        insert is additionally crash-safe: a failure mid-build rolls back).
        """
        st = self.state(tenant)
        parts = list(parts)
        if not parts:
            raise ValueError(
                f"insert for tenant {tenant!r} got an empty parts list; "
                f"a superchunk needs one feature slice per party"
            )
        rows = [int(np.asarray(p).shape[0]) for p in parts]
        if rows[0] == 0:
            raise ValueError(
                f"insert for tenant {tenant!r} got a zero-row superchunk; "
                f"send at least one row per chunk"
            )
        if len(set(rows)) != 1:
            raise ValueError(
                f"insert for tenant {tenant!r}: parties disagree on the "
                f"chunk's row count ({rows}); every party must slice the "
                f"same rows"
            )
        hits0 = self.plan_cache.hits
        t0 = time.perf_counter()
        stats = st.tree.insert(parts, y)
        dt = time.perf_counter() - t0
        st.inserts += 1
        return InsertReceipt(
            tenant=tenant, chunk_idx=st.tree.num_chunks - 1, stats=stats,
            ledger_total=st.ledger.total,
            plan_hit=self.plan_cache.hits > hits0, latency_s=dt,
        )

    def query(self, tenant: str, *, reduce_to: Optional[int] = None,
              key: Optional[jax.Array] = None) -> QueryReceipt:
        st = self.state(tenant)
        t0 = time.perf_counter()
        result = st.tree.query(reduce_to=reduce_to, key=key)
        dt = time.perf_counter() - t0
        st.queries += 1
        return QueryReceipt(tenant=tenant, result=result, m=result.m,
                            ledger_total=st.ledger.total, latency_s=dt)

    # -- cross-tenant batched builds -----------------------------------------

    def attach_dataset(self, name: str, ds: VFLDataset) -> None:
        """Register shared reference data one-shot builds can target."""
        if name in self._datasets:
            raise ValueError(f"dataset {name!r} already attached")
        self._datasets[name] = ds

    def submit(
        self,
        tenant: str,
        dataset: str,
        m: int,
        *,
        key: jax.Array,
        task: Union[str, CoresetTask] = "vrlr",
        **params: Any,
    ) -> int:
        """Queue a one-shot build; returns a ticket redeemed by ``flush``.

        The draw is a pure function of (dataset, task, params, m, key) —
        batching with other tenants' requests cannot change it (the batched
        engine vmaps over the key axis; pinned in the tests).
        """
        if dataset not in self._datasets:
            raise KeyError(f"dataset {dataset!r} not attached; "
                           f"have: {sorted(self._datasets)}")
        ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append(_BuildRequest(
            ticket=ticket, tenant=tenant, dataset=dataset,
            task=get_task(task).name, m=int(m), key=key,
            params=tuple(sorted(params.items())),
        ))
        return ticket

    @property
    def pending(self) -> int:
        return len(self._pending)

    def flush(self) -> Dict[int, Coreset]:
        """Execute all pending requests; ONE batched-engine dispatch per
        compatible ``(dataset, task, params)`` group.

        Each group stacks its requests' keys as the seed axis and takes the
        union of requested budgets as the grid; request r's result is cell
        ``(r, ms.index(m_r))``.  Every cell still pays its own exact comm
        schedule on the submitting tenant's ledger (if that tenant has one).
        """
        pending, self._pending = self._pending, []
        groups: Dict[Tuple[str, str, Tuple], List[_BuildRequest]] = {}
        for req in pending:
            groups.setdefault((req.dataset, req.task, req.params),
                              []).append(req)

        out: Dict[int, Coreset] = {}
        for (ds_name, task, params), reqs in groups.items():
            ds = self._datasets[ds_name]
            ms = tuple(sorted({r.m for r in reqs}))
            keys = jax.numpy.stack([r.key for r in reqs])
            grid = build_coresets_batched(
                task, ds, ms, keys=keys, backend="ref", **dict(params))
            self.batched_flushes += 1
            self.batched_cells += len(reqs)
            for i, req in enumerate(reqs):
                ledger = (self._tenants[req.tenant].ledger
                          if req.tenant in self._tenants else None)
                out[req.ticket] = grid.coreset(i, ms.index(req.m),
                                               ledger=ledger)
        return out

    # -- introspection -------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        pc = self.plan_cache.stats()
        return {
            "tenants": len(self._tenants),
            "plan_cache_size": pc["size"],
            "plan_cache_max": pc["max_entries"],
            "plan_hits": pc["hits"],
            "plan_misses": pc["misses"],
            "plan_evictions": pc["evictions"],
            "batched_flushes": self.batched_flushes,
            "batched_cells": self.batched_cells,
            "pending": len(self._pending),
            "health_checks": sum(st.tree.health_checks
                                 for st in self._tenants.values()),
            "health_warnings": sum(st.tree.health_warnings
                                   for st in self._tenants.values()),
        }

    def describe(self) -> str:
        s = self.stats()
        lines = [
            f"CoresetService: {s['tenants']} tenant(s), plan cache "
            f"{s['plan_cache_size']} plan(s) ({s['plan_hits']} hit(s) / "
            f"{s['plan_misses']} miss(es)), "
            f"{s['batched_cells']} batched cell(s) in "
            f"{s['batched_flushes']} flush(es)",
        ]
        for name in self.tenants():
            st = self._tenants[name]
            t = st.tree
            lines.append(
                f"  {name}: task={t.task.name} budget={t.budget} "
                f"chunks={t.num_chunks} rows={t.n_total} height={t.height} "
                f"comm={st.ledger.total}"
            )
        return "\n".join(lines)
