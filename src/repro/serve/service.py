"""Multi-tenant coreset service: long-lived trees, a shared plan cache, and
cross-tenant request batching.

One :class:`CoresetService` process serves many tenants (one VFL federation
each).  Three things make it a SERVICE rather than a loop over
:class:`~repro.serve.tree.CoresetTree`:

  * **Plan cache** — every tenant's leaf builds plan through one shared
    :class:`~repro.core.plan.PlanCache` keyed on
    ``(task, shapes, resolved knobs)``.  Since jit caches key on the same
    shapes, a plan hit means the compiled scan programs are already warm:
    the FIRST tenant at a given (chunk shape, task, knobs) pays
    compilation, every later tenant streams at steady-state throughput
    (the warm/cold gap is what ``benchmarks/serve.py`` measures).
  * **Per-tenant state** — each tenant owns a tree, a ledger, and a
    deterministic key chain seeded at registration; the same registration +
    insert sequence replays the same draws regardless of what other
    tenants do (pinned in ``tests/test_serve_service.py``).
  * **Cross-tenant batching** — one-shot build requests against shared
    reference datasets (``attach_dataset`` / ``submit`` / ``flush``) are
    grouped by ``(dataset, task, backend, params)`` and executed as ONE
    ``build_coresets_batched`` grid per group — R tenants' requests cost
    one compiled dispatch instead of R.

All receipts carry wall latency and the tenant's ledger total so the
harness can report p50/p99 and verify composed accounting externally.

The service is additionally OVERLOAD-SAFE (PR 9): every operation passes
an admission gate (deadline, global in-flight cap, per-tenant token
bucket, per-tenant circuit breaker — see :mod:`repro.serve.resilience`)
and refusals return :class:`~repro.serve.resilience.ShedReceipt` instead
of raising or silently dropping.  Deadline-pressed queries degrade to the
un-reduced tree union; leaf builds can arm the engine failover ladder
(``failover=True`` + ``memory_budget_bytes``).  The invariant the overload
benchmark asserts: no request is ever lost without a receipt.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.core.api import CoresetTask, build_coresets_batched, get_task
from repro.core.comm import CommLedger
from repro.core.coreset import Coreset, MaterializedCoreset
from repro.core.faults import (
    Clock,
    Deadline,
    DeadlineExceeded,
    PartyUnavailable,
    StreamCheckpoint,
    Transport,
    WallClock,
)
from repro.core.integrity import IntegrityError
from repro.core.plan import PlanCache
from repro.core.wire import fmt_bits
from repro.core.vfl import VFLDataset
from repro.serve.resilience import CircuitBreaker, ShedReceipt, TokenBucket
from repro.serve.tree import CoresetTree, InsertStats


@dataclasses.dataclass(frozen=True)
class InsertReceipt:
    tenant: str
    chunk_idx: int              # 0-based index of this chunk in the stream
    stats: InsertStats
    ledger_total: int           # tenant's composed comm bill after the insert
    plan_hit: bool              # leaf build reused a cached ExecutionPlan
    latency_s: float
    #: engine failover trail of the leaf build ("pipelined->streamed"), or
    #: None when the planned engine succeeded
    fallback: Optional[str] = None
    #: tenant's composed wire bill in bits after the insert (the bytes the
    #: codecs actually moved behind ``ledger_total``'s paper units)
    ledger_bits: int = 0


@dataclasses.dataclass(frozen=True)
class QueryReceipt:
    tenant: str
    result: MaterializedCoreset
    m: int
    ledger_total: int
    latency_s: float
    #: True when a deadline-pressed query returned the current tree union
    #: WITHOUT the requested final reduce_to pass (still a valid coreset —
    #: just larger than asked)
    degraded: bool = False
    #: comm units this query added to the tenant's ledger (the reduce's
    #: bill; 0 for union/degraded queries)
    comm_delta: int = 0
    #: tenant's composed wire bill in bits, and this query's bit delta
    ledger_bits: int = 0
    comm_delta_bits: int = 0


@dataclasses.dataclass(frozen=True)
class EvictReceipt:
    tenant: str
    chunks: int
    rows: int
    ledger_total: int           # final composed bill at eviction
    #: the tenant's not-yet-flushed submit requests dropped at evict time
    dropped_pending: int = 0
    ledger_bits: int = 0        # final composed wire bill at eviction


@dataclasses.dataclass
class TenantState:
    """Everything the service holds for one federation."""

    name: str
    tree: CoresetTree
    inserts: int = 0
    queries: int = 0
    bucket: Optional[TokenBucket] = None
    breaker: Optional[CircuitBreaker] = None
    max_pending: Optional[int] = None
    sheds: int = 0

    @property
    def ledger(self) -> CommLedger:
        return self.tree.ledger


@dataclasses.dataclass(frozen=True)
class _BuildRequest:
    ticket: int
    tenant: str
    dataset: str
    task: str
    m: int
    key: jax.Array
    params: Tuple[Tuple[str, Any], ...]


class CoresetService:
    """The long-lived serving layer.

    Streaming path: ``register`` a tenant (task, budget, seed), ``insert``
    superchunks as they arrive, ``query`` the current summary, ``evict``
    when the federation leaves.  Batch path: ``attach_dataset`` shared
    reference data, ``submit`` one-shot build requests from any tenants,
    ``flush`` to execute each compatible group as a single batched-engine
    dispatch.
    """

    def __init__(self, *, backend: str = "auto",
                 plan_cache: Optional[PlanCache] = None,
                 clock: Optional[Clock] = None,
                 max_inflight: Optional[int] = None) -> None:
        self.backend = backend
        self.plan_cache = plan_cache if plan_cache is not None else PlanCache()
        # the service's time seam: deadlines, token buckets, and breaker
        # cooldowns all read THIS clock — hand it a SimClock (ideally the
        # same one the tenants' Transports advance) and the whole resilience
        # layer becomes deterministic
        self.clock = clock if clock is not None else WallClock()
        if max_inflight is not None and (not isinstance(max_inflight, int)
                                         or max_inflight < 1):
            raise ValueError(
                f"max_inflight must be a positive int, got {max_inflight!r}"
            )
        self.max_inflight = max_inflight
        self._inflight = 0
        self._tenants: Dict[str, TenantState] = {}
        self._datasets: Dict[str, VFLDataset] = {}
        self._pending: List[_BuildRequest] = []
        self._next_ticket = 0
        self.batched_flushes = 0
        self.batched_cells = 0

    # -- tenant lifecycle ----------------------------------------------------

    def register(
        self,
        tenant: str,
        *,
        task: Union[str, CoresetTask] = "vrlr",
        budget: int = 512,
        seed: int = 0,
        key: Optional[jax.Array] = None,
        block_size: int = 65536,
        chunk_blocks: Optional[int] = None,
        prefetch: Optional[bool] = None,
        headroom: int = 2,
        fault_policy: str = "fail",
        transport: Optional[Transport] = None,
        checkpoint: bool = False,
        rate_limit: Optional[Tuple[float, float]] = None,
        max_pending: Optional[int] = None,
        breaker_threshold: int = 3,
        breaker_cooldown_s: float = 30.0,
        memory_budget_bytes: Optional[int] = None,
        failover: bool = False,
        **params: Any,
    ) -> TenantState:
        """Create a tenant: its tree, ledger, and key chain.  Deterministic —
        the same (seed/key, insert sequence) replays the same coresets.

        ``fault_policy``/``transport`` route the tenant's leaf builds and
        merges through the party fault seam (see :mod:`repro.core.faults`);
        ``checkpoint=True`` gives the tenant a persistent
        :class:`~repro.core.faults.StreamCheckpoint`, so an insert that
        crashes mid-build (and is rolled back by the tree) RESUMES its scan
        passes at the last completed superchunk when the chunk is retried —
        draw-identical to a never-failed insert.

        Resilience knobs (all default permissive, so a tenant without them
        behaves exactly as before): ``rate_limit=(rate_per_s, burst)`` arms
        a token bucket on the service clock; ``max_pending`` bounds the
        tenant's un-flushed ``submit`` queue; ``breaker_threshold`` /
        ``breaker_cooldown_s`` tune the circuit breaker (consecutive
        party-side failures open it); ``memory_budget_bytes`` +
        ``failover=True`` arm the leaf builds' engine failover ladder with
        the live-bytes watchdog.
        """
        if tenant in self._tenants:
            raise ValueError(f"tenant {tenant!r} already registered")
        if key is None:
            key = jax.random.PRNGKey(seed)
        tree = CoresetTree(
            task, budget, key=key, backend=self.backend,
            block_size=block_size, chunk_blocks=chunk_blocks,
            prefetch=prefetch, params=params, plan_cache=self.plan_cache,
            headroom=headroom, fault_policy=fault_policy,
            transport=transport,
            checkpoint=StreamCheckpoint() if checkpoint else None,
            memory_budget_bytes=memory_budget_bytes, failover=failover,
        )
        state = TenantState(
            name=tenant, tree=tree,
            bucket=None if rate_limit is None else TokenBucket(*rate_limit),
            breaker=CircuitBreaker(threshold=breaker_threshold,
                                   cooldown_s=breaker_cooldown_s),
            max_pending=max_pending,
        )
        self._tenants[tenant] = state
        return state

    # -- admission control ---------------------------------------------------

    def _admit(self, st: TenantState, op: str,
               deadline: Optional[Deadline]) -> Optional[ShedReceipt]:
        """The admission gate, cheapest check first: an already-expired
        deadline sheds before ANY state is touched (not even a token is
        spent); then the global in-flight cap, the tenant's token bucket,
        and LAST the circuit breaker — last because an open->half-open
        transition admits a probe, so nothing may shed the request after
        the breaker says yes."""
        if deadline is not None and deadline.expired(self.clock):
            st.sheds += 1
            return ShedReceipt(tenant=st.name, op=op, reason="deadline")
        if (self.max_inflight is not None
                and self._inflight >= self.max_inflight):
            st.sheds += 1
            return ShedReceipt(tenant=st.name, op=op, reason="overloaded")
        if st.bucket is not None:
            ok, retry = st.bucket.try_take(self.clock.now())
            if not ok:
                st.sheds += 1
                return ShedReceipt(tenant=st.name, op=op,
                                   reason="rate_limit", retry_after_s=retry)
        ok, retry = st.breaker.allow(self.clock.now())
        if not ok:
            st.sheds += 1
            return ShedReceipt(tenant=st.name, op=op,
                               reason="breaker_open", retry_after_s=retry)
        return None

    def tenants(self) -> List[str]:
        return sorted(self._tenants)

    def state(self, tenant: str) -> TenantState:
        try:
            return self._tenants[tenant]
        except KeyError:
            raise KeyError(f"unknown tenant {tenant!r}; "
                           f"registered: {self.tenants()}") from None

    def evict(self, tenant: str) -> EvictReceipt:
        st = self.state(tenant)
        del self._tenants[tenant]
        # drop the tenant's not-yet-flushed submits: flushing work for an
        # evicted tenant would burn a batched-grid slot nobody redeems
        dropped = sum(1 for r in self._pending if r.tenant == tenant)
        if dropped:
            self._pending = [r for r in self._pending if r.tenant != tenant]
        return EvictReceipt(tenant=tenant, chunks=st.tree.num_chunks,
                            rows=st.tree.n_total,
                            ledger_total=st.ledger.total,
                            dropped_pending=dropped,
                            ledger_bits=st.ledger.total_bits)

    # -- streaming path ------------------------------------------------------

    def insert(self, tenant: str, parts: Sequence[Any],
               y: Optional[Any] = None, *,
               deadline: Optional[Deadline] = None,
               ) -> Union[InsertReceipt, ShedReceipt]:
        """Absorb one superchunk into the tenant's tree.

        Validates the chunk at the service edge — a malformed request fails
        with a clear error BEFORE any tree state is touched (the tree's own
        insert is additionally crash-safe: a failure mid-build rolls back).

        ``deadline`` (a :class:`~repro.core.faults.Deadline` on the service
        clock) is checked at admission — already expired sheds with zero
        work — and at every superchunk boundary of the leaf build; a
        mid-build breach rolls the tree back and returns a
        :class:`ShedReceipt` (reason ``"deadline"``), never a half-applied
        insert.  Party-side failures feed the tenant's circuit breaker and
        re-raise.
        """
        st = self.state(tenant)
        t0 = time.perf_counter()
        # pure request validation first — a malformed request costs the
        # tenant nothing (no token, no breaker probe)
        parts = list(parts)
        if not parts:
            raise ValueError(
                f"insert for tenant {tenant!r} got an empty parts list; "
                f"a superchunk needs one feature slice per party"
            )
        rows = [int(np.asarray(p).shape[0]) for p in parts]
        if rows[0] == 0:
            raise ValueError(
                f"insert for tenant {tenant!r} got a zero-row superchunk; "
                f"send at least one row per chunk"
            )
        if len(set(rows)) != 1:
            raise ValueError(
                f"insert for tenant {tenant!r}: parties disagree on the "
                f"chunk's row count ({rows}); every party must slice the "
                f"same rows"
            )
        shed = self._admit(st, "insert", deadline)
        if shed is not None:
            return shed
        probe = (None if deadline is None
                 else lambda: deadline.check(self.clock, f"insert/{tenant}"))
        hits0 = self.plan_cache.hits
        self._inflight += 1
        try:
            stats = st.tree.insert(parts, y, probe=probe)
        except DeadlineExceeded:
            # the tree rolled itself back; the breaker learns nothing about
            # party health from a time-budget abort
            st.breaker.record_neutral(self.clock.now())
            st.sheds += 1
            return ShedReceipt(tenant=tenant, op="insert", reason="deadline",
                               latency_s=time.perf_counter() - t0)
        except (PartyUnavailable, IntegrityError) as e:
            st.breaker.record_failure(self.clock.now(),
                                      f"{type(e).__name__}: {e}")
            raise
        except BaseException:
            # not a party-side failure: a half-open probe must not stay
            # dangling, but this says nothing about party health either
            st.breaker.record_neutral(self.clock.now())
            raise
        finally:
            self._inflight -= 1
        st.breaker.record_success()
        st.inserts += 1
        return InsertReceipt(
            tenant=tenant, chunk_idx=st.tree.num_chunks - 1, stats=stats,
            ledger_total=st.ledger.total,
            plan_hit=self.plan_cache.hits > hits0,
            latency_s=time.perf_counter() - t0,
            fallback=stats.fallback,
            ledger_bits=st.ledger.total_bits,
        )

    def query(self, tenant: str, *, reduce_to: Optional[int] = None,
              key: Optional[jax.Array] = None,
              deadline: Optional[Deadline] = None,
              ) -> Union[QueryReceipt, ShedReceipt]:
        """The tenant's current stream summary.

        With a ``deadline``: already expired at admission sheds; expired by
        the time the final ``reduce_to`` pass would run DEGRADES instead —
        the receipt carries the current tree union (a valid coreset, just
        larger than requested) with ``degraded=True`` and no reduce bill.
        """
        st = self.state(tenant)
        t0 = time.perf_counter()
        shed = self._admit(st, "query", deadline)
        if shed is not None:
            return shed
        led0 = st.ledger.total
        bits0 = st.ledger.total_bits
        mark = st.ledger.mark()
        degraded = False
        self._inflight += 1
        try:
            if (reduce_to is not None and deadline is not None
                    and deadline.expired(self.clock)):
                # no time left for the reduce pass: serve what we have
                result = st.tree.query(reduce_to=None)
                degraded = True
            else:
                result = st.tree.query(reduce_to=reduce_to, key=key)
        except (PartyUnavailable, IntegrityError) as e:
            st.ledger.rollback(mark)
            st.breaker.record_failure(self.clock.now(),
                                      f"{type(e).__name__}: {e}")
            raise
        except BaseException:
            st.ledger.rollback(mark)
            st.breaker.record_neutral(self.clock.now())
            raise
        finally:
            self._inflight -= 1
        st.breaker.record_success()
        st.queries += 1
        return QueryReceipt(tenant=tenant, result=result, m=result.m,
                            ledger_total=st.ledger.total,
                            latency_s=time.perf_counter() - t0,
                            degraded=degraded,
                            comm_delta=st.ledger.total - led0,
                            ledger_bits=st.ledger.total_bits,
                            comm_delta_bits=st.ledger.total_bits - bits0)

    # -- cross-tenant batched builds -----------------------------------------

    def attach_dataset(self, name: str, ds: VFLDataset) -> None:
        """Register shared reference data one-shot builds can target."""
        if name in self._datasets:
            raise ValueError(f"dataset {name!r} already attached")
        self._datasets[name] = ds

    def submit(
        self,
        tenant: str,
        dataset: str,
        m: int,
        *,
        key: jax.Array,
        task: Union[str, CoresetTask] = "vrlr",
        **params: Any,
    ) -> Union[int, ShedReceipt]:
        """Queue a one-shot build; returns a ticket redeemed by ``flush``.

        The draw is a pure function of (dataset, task, params, m, key) —
        batching with other tenants' requests cannot change it (the batched
        engine vmaps over the key axis; pinned in the tests).

        A registered tenant with ``max_pending`` set is bounded: submits
        past the cap return a :class:`ShedReceipt` (reason
        ``"queue_full"``) instead of a ticket, so one tenant cannot grow
        the flush queue without limit.
        """
        if dataset not in self._datasets:
            raise KeyError(f"dataset {dataset!r} not attached; "
                           f"have: {sorted(self._datasets)}")
        st = self._tenants.get(tenant)
        if st is not None and st.max_pending is not None:
            depth = sum(1 for r in self._pending if r.tenant == tenant)
            if depth >= st.max_pending:
                st.sheds += 1
                return ShedReceipt(tenant=tenant, op="submit",
                                   reason="queue_full")
        ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append(_BuildRequest(
            ticket=ticket, tenant=tenant, dataset=dataset,
            task=get_task(task).name, m=int(m), key=key,
            params=tuple(sorted(params.items())),
        ))
        return ticket

    @property
    def pending(self) -> int:
        return len(self._pending)

    def flush(self, *, deadline: Optional[Deadline] = None) -> Dict[int, Coreset]:
        """Execute all pending requests; ONE batched-engine dispatch per
        compatible ``(dataset, task, params)`` group.

        Each group stacks its requests' keys as the seed axis and takes the
        union of requested budgets as the grid; request r's result is cell
        ``(r, ms.index(m_r))``.  Every cell still pays its own exact comm
        schedule on the submitting tenant's ledger (if that tenant has one).

        ``deadline`` is checked between group dispatches: groups there was
        no time to start go BACK to the pending queue (tickets intact, no
        partial groups) and are executed by the next flush.
        """
        pending, self._pending = self._pending, []
        groups: Dict[Tuple[str, str, Tuple], List[_BuildRequest]] = {}
        for req in pending:
            groups.setdefault((req.dataset, req.task, req.params),
                              []).append(req)

        out: Dict[int, Coreset] = {}
        for gi, ((ds_name, task, params), reqs) in enumerate(groups.items()):
            if deadline is not None and deadline.expired(self.clock):
                # out of budget: requeue every unstarted group atomically
                deferred = [r for (_, rs) in list(groups.items())[gi:]
                            for r in rs]
                self._pending = deferred + self._pending
                break
            ds = self._datasets[ds_name]
            ms = tuple(sorted({r.m for r in reqs}))
            keys = jax.numpy.stack([r.key for r in reqs])
            grid = build_coresets_batched(
                task, ds, ms, keys=keys, backend="ref", **dict(params))
            self.batched_flushes += 1
            self.batched_cells += len(reqs)
            for i, req in enumerate(reqs):
                ledger = (self._tenants[req.tenant].ledger
                          if req.tenant in self._tenants else None)
                out[req.ticket] = grid.coreset(i, ms.index(req.m),
                                               ledger=ledger)
        return out

    # -- plan-cache maintenance ----------------------------------------------

    def prune_plans(self, max_idle_s: float) -> int:
        """Evict plans unused for ``max_idle_s`` seconds (see
        :meth:`PlanCache.prune`); returns the count evicted."""
        return self.plan_cache.prune(max_idle_s)

    def clear_plans(self) -> None:
        self.plan_cache.clear()

    # -- introspection -------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        pc = self.plan_cache.stats()
        return {
            "tenants": len(self._tenants),
            "plan_cache_size": pc["size"],
            "plan_cache_max": pc["max_entries"],
            "plan_hits": pc["hits"],
            "plan_misses": pc["misses"],
            "plan_evictions": pc["evictions"],
            "plan_oldest_idle_s": pc["oldest_idle_s"],
            "batched_flushes": self.batched_flushes,
            "batched_cells": self.batched_cells,
            "pending": len(self._pending),
            "inflight": self._inflight,
            "health_checks": sum(st.tree.health_checks
                                 for st in self._tenants.values()),
            "health_warnings": sum(st.tree.health_warnings
                                   for st in self._tenants.values()),
            "sheds": sum(st.sheds for st in self._tenants.values()),
            "wire_bits": sum(st.ledger.total_bits
                             for st in self._tenants.values()),
            "wire_bits_by_tenant": {name: st.ledger.total_bits
                                    for name, st
                                    in sorted(self._tenants.items())},
            "fallbacks": sum(st.tree.fallbacks
                             for st in self._tenants.values()),
            "breakers": {name: st.breaker.stats()
                         for name, st in sorted(self._tenants.items())},
        }

    def describe(self) -> str:
        s = self.stats()
        lines = [
            f"CoresetService: {s['tenants']} tenant(s), plan cache "
            f"{s['plan_cache_size']} plan(s) ({s['plan_hits']} hit(s) / "
            f"{s['plan_misses']} miss(es)), "
            f"{s['batched_cells']} batched cell(s) in "
            f"{s['batched_flushes']} flush(es)",
        ]
        for name in self.tenants():
            st = self._tenants[name]
            t = st.tree
            extra = ""
            if st.breaker.state != "closed" or st.breaker.trips:
                extra += (f" breaker={st.breaker.state}"
                          f"({st.breaker.trips} trip(s))")
            if st.sheds:
                extra += f" sheds={st.sheds}"
            if t.fallbacks:
                extra += f" fallbacks={t.fallbacks}({t.last_fallback})"
            lines.append(
                f"  {name}: task={t.task.name} budget={t.budget} "
                f"chunks={t.num_chunks} rows={t.n_total} height={t.height} "
                f"comm={st.ledger.total} "
                f"({fmt_bits(st.ledger.total_bits)} on the wire){extra}"
            )
        return "\n".join(lines)
