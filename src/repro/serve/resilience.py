"""Service-level resilience primitives: admission control and failure
isolation for :class:`~repro.serve.service.CoresetService`.

PRs 7-8 made individual *builds* survive faults (retry-billed transport,
checkpointed resume, integrity quarantine); this module protects the
SERVICE from its tenants.  Three small, clock-driven state machines:

- :class:`TokenBucket` — per-tenant rate limiting.  A greedy tenant runs
  its bucket dry and gets shed; everyone else's buckets are untouched.
- :class:`CircuitBreaker` — per-tenant failure isolation.  Consecutive
  party-side failures (``PartyUnavailable`` exhaustion, ``IntegrityError``)
  open the breaker: subsequent requests shed instantly instead of burning
  a full retry ladder per call, and a half-open probe admits one trial
  request after a cooldown to detect recovery.
- :class:`ShedReceipt` — the refusal artifact.  The overload benchmark's
  invariant is *zero requests lost without a receipt*: every admitted
  request returns an Insert/Query receipt, every refused one returns a
  ShedReceipt naming the reason.

All time comes from the caller's :class:`~repro.core.faults.Clock` seam
(the same seam ``Transport`` accrues simulated delay through), so every
state machine here is deterministic under ``SimClock`` — the tests drive
whole breaker lifecycles without sleeping.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.faults import Clock

#: The closed set of refusal reasons a ShedReceipt may carry.
SHED_REASONS = (
    "deadline",       # expired at admission, or breached mid-op (rolled back)
    "rate_limit",     # tenant token bucket empty
    "queue_full",     # tenant pending-submit queue at max_pending
    "overloaded",     # global in-flight cap reached
    "breaker_open",   # tenant circuit breaker open
)


@dataclasses.dataclass(frozen=True)
class ShedReceipt:
    """A refused request.  ``reason`` is one of :data:`SHED_REASONS`;
    ``retry_after_s`` is the earliest useful retry (bucket refill time,
    breaker cooldown remainder) or 0.0 when unknowable."""

    tenant: str
    op: str                      # "insert" | "query" | "submit" | "flush"
    reason: str
    retry_after_s: float = 0.0
    latency_s: float = 0.0

    def __post_init__(self) -> None:
        if self.reason not in SHED_REASONS:
            raise ValueError(
                f"reason must be one of {SHED_REASONS}, got {self.reason!r}"
            )


class TokenBucket:
    """Standard token bucket on an injected clock: ``burst`` capacity,
    ``rate_per_s`` refill.  ``try_take`` is the admission check; on refusal
    it reports how long until a token exists."""

    def __init__(self, rate_per_s: float, burst: float) -> None:
        if not (isinstance(rate_per_s, (int, float)) and rate_per_s > 0):
            raise ValueError(
                f"rate_per_s must be a positive number, got {rate_per_s!r}"
            )
        if not (isinstance(burst, (int, float)) and burst >= 1):
            raise ValueError(f"burst must be >= 1, got {burst!r}")
        self.rate_per_s = float(rate_per_s)
        self.burst = float(burst)
        self._tokens = float(burst)
        self._last: Optional[float] = None

    def _refill(self, now: float) -> None:
        if self._last is not None and now > self._last:
            self._tokens = min(
                self.burst, self._tokens + (now - self._last) * self.rate_per_s
            )
        self._last = now

    def try_take(self, now: float) -> tuple[bool, float]:
        """``(admitted, retry_after_s)`` — consumes one token on success."""
        self._refill(now)
        if self._tokens >= 1.0:
            self._tokens -= 1.0
            return True, 0.0
        return False, (1.0 - self._tokens) / self.rate_per_s

    @property
    def tokens(self) -> float:
        return self._tokens


class CircuitBreaker:
    """closed -> open -> half-open, per tenant.

    ``record_failure`` counts CONSECUTIVE party-side failures; at
    ``threshold`` the breaker opens for ``cooldown_s`` (on the injected
    clock).  After cooldown, ``allow`` admits exactly one half-open probe:
    its success closes the breaker, its failure reopens it (and bumps
    ``trips`` again).  ``record_success`` in the closed state resets the
    consecutive count — intermittent failures never open a healthy tenant's
    breaker.
    """

    def __init__(self, threshold: int = 3, cooldown_s: float = 30.0) -> None:
        if not (isinstance(threshold, int) and threshold >= 1):
            raise ValueError(f"threshold must be an int >= 1, got {threshold!r}")
        if not (isinstance(cooldown_s, (int, float)) and cooldown_s > 0):
            raise ValueError(
                f"cooldown_s must be a positive number, got {cooldown_s!r}"
            )
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self.state = "closed"            # "closed" | "open" | "half_open"
        self.failures = 0                # consecutive, in the closed state
        self.trips = 0
        self.last_error: Optional[str] = None
        self._opened_at: Optional[float] = None

    def allow(self, now: float) -> tuple[bool, float]:
        """``(admitted, retry_after_s)``.  Transitions open -> half_open
        when the cooldown has elapsed (the admitted request IS the probe)."""
        if self.state == "closed":
            return True, 0.0
        if self.state == "half_open":
            # one probe is already in flight; hold the line until it reports
            return False, self.cooldown_s
        elapsed = now - self._opened_at
        if elapsed >= self.cooldown_s:
            self.state = "half_open"
            return True, 0.0
        return False, self.cooldown_s - elapsed

    def record_success(self) -> None:
        if self.state == "half_open":
            self.state = "closed"
            self._opened_at = None
        self.failures = 0

    def record_neutral(self, now: float) -> None:
        """The admitted request aborted for a reason unrelated to party
        health (a deadline shed): a half-open probe returns to open —
        restarting the cooldown, but NOT counting a trip — so the next
        probe still fires.  No-op in other states."""
        if self.state == "half_open":
            self.state = "open"
            self._opened_at = now

    def record_failure(self, now: float, error: str) -> None:
        self.last_error = error
        if self.state == "half_open":
            # the probe failed: reopen immediately, restart the cooldown
            self.state = "open"
            self._opened_at = now
            self.trips += 1
            return
        self.failures += 1
        if self.state == "closed" and self.failures >= self.threshold:
            self.state = "open"
            self._opened_at = now
            self.trips += 1

    def stats(self) -> dict:
        return {
            "state": self.state,
            "failures": self.failures,
            "trips": self.trips,
            "last_error": self.last_error,
        }
