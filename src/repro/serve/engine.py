"""Deprecated location: the LM decode engine moved to
:mod:`repro.models.lm_serve`.

``repro.serve`` is the coreset service namespace (merge-and-reduce tree +
multi-tenant serving layer); the seed's language-model ``ServeEngine`` was
never about coresets.  This module stays as a re-export so existing imports
(``tests/test_serve.py``, old scripts) keep working.
"""

from repro.models.lm_serve import ServeEngine, make_serve_step

__all__ = ["ServeEngine", "make_serve_step"]
