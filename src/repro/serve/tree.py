"""Merge-and-reduce coreset tree: online maintenance under arriving rows.

Every engine in :mod:`repro.core` is a batch job over a fixed
:class:`~repro.core.vfl.VFLDataset`; the paper's setting — parties
continuously accumulating feature slices of a shared user population —
means rows arrive over time.  This module maintains a coreset of the
ever-growing stream with the classic merge-and-reduce scheme, built
entirely out of the existing machinery:

  * **Leaves** — each arriving superchunk (one (rows, d_j)-per-party batch)
    is summarized by a PIPELINED-engine build
    (:class:`~repro.core.api.CoresetPipeline` with a forced
    ``engine="pipelined"`` spec): draw-identical to calling
    ``build_coreset_streaming`` on the chunk directly with
    :meth:`CoresetTree.leaf_key`.
  * **Merges** — a binary counter over levels: level l summarizes 2^l
    chunks, and two occupied level-l nodes combine into one level-(l+1)
    node by RE-RUNNING DIS over the union of the two materialized coresets
    with the children's weights folded into the sensitivities
    (:func:`merge_reduce`): the sampling mass of union row i is
    ``w_i * g_i^(j)``, and the drawn row keeps
    ``w_i * G~/(m * w_i g_i) = G~/(m g_i)`` — the weighted
    Feldman-Langberg draw, so reduction never re-touches raw stream rows.
  * **Cost** — inserting a superchunk builds ONE leaf plus at most
    ``ceil(log2(chunks))`` merge nodes, each over a 2m-row union: O(m log n)
    work, never a full-data rescore (:class:`InsertStats` is the census the
    tests assert against).
  * **Accounting** — every leaf pays Algorithm 1's DIS bill; every merge
    pays :meth:`CommSchedule.merge` (Theorem 2.5's ``+2mT`` composition for
    BOTH consumed children) plus the union re-sample's DIS bill, all
    recorded on one ledger per tree.  The composed total depends only on
    the number of chunks and the budget — insert ORDER never changes it
    (pinned by a hypothesis property in ``tests/test_serve_tree.py``).

Key chain (all draws deterministic given the root ``key``):
leaf i consumes ``fold_in(fold_in(key, 1), i)``; merge op t consumes
``fold_in(fold_in(key, 2), t)``; a query after i inserts defaults to
``fold_in(fold_in(key, 3), i)`` — so repeated queries between inserts are
draw-identical, and the whole tree replays exactly from (key, insert
sequence).
"""

from __future__ import annotations

import dataclasses
import inspect
import time
from typing import Any, List, Mapping, Optional, Sequence, Union

import jax
import numpy as np

from repro.core.api import CoresetPipeline, CoresetTask, get_task, resolve_backend
from repro.core.comm import CommLedger, CommSchedule
from repro.core.coreset import MaterializedCoreset
from repro.core.dis import dis_plan_full, uniform_plan
from repro.core.faults import StreamCheckpoint, Transport, deliver_or_record
from repro.core.integrity import HealthReport, check_merge_children
from repro.core.plan import CoresetSpec, PlanCache
from repro.core.vfl import VFLDataset
from repro.core.wire import WirePayload, fmt_bits


def merge_reduce(
    task: Union[str, CoresetTask],
    mats: Sequence[MaterializedCoreset],
    m: int,
    *,
    key: jax.Array,
    backend: str = "auto",
    params: Optional[Mapping[str, Any]] = None,
    ledger: Optional[CommLedger] = None,
    bill_consume: bool = True,
    transport: Optional[Transport] = None,
    fault_policy: str = "fail",
) -> MaterializedCoreset:
    """One merge-and-reduce step: re-run DIS over the weighted union of
    ``mats``, weights folded into the sensitivities.

    Sampling mass of union row i at party j is ``w_i * g_i^(j)`` (the
    task's score on the union rows times the row's carried weight), so the
    induced marginal is ``w_i g_i / sum w g`` and the drawn row's new
    weight ``w_i * G~/(m * w_i g_i)`` telescopes to ``G~/(m g_i)`` — an
    unbiased estimator over the weighted point set, which is exactly what
    merge-and-reduce needs at every level.  The uniform baseline
    degenerates to m uniform union draws with weights scaled by
    ``m_union/m``.

    Billing: ``bill_consume`` records :meth:`CommSchedule.merge` — Theorem
    2.5's composition term for consuming every child coreset (each party
    receives the union's indices and returns its per-row shares) — then the
    union re-sample's own DIS (or uniform) schedule.  The returned node's
    ``comm_units`` composes: children's totals + this op's bill.

    ``transport`` delivers the schedule through the party fault seam
    (retries billed under ``retry/`` tags, composed into ``comm_units``).
    A merge NEVER degrades — every child row already carries all T
    parties' feature slices, so dropping a party here would orphan the
    materialized columns; under ``fault_policy="degrade"`` a merge behaves
    like ``"retry"`` and raises on exhaustion.
    """
    task = get_task(task)
    params = dict(params or {})
    mats = list(mats)
    # integrity pre-checks: child weights positive/finite, and no global id
    # in two different children (children summarize disjoint stream
    # segments; a collision means a corrupted upload or broken offsets)
    check_merge_children([mt.indices for mt in mats],
                         [mt.weights for mt in mats])
    union = MaterializedCoreset.concat(mats)
    ds_u = union.dataset()
    T = ds_u.T
    m = int(m)
    if m < 1:
        raise ValueError(f"reduce budget must be >= 1, got {m}")

    if task.score_fn is None:
        S, w0 = uniform_plan(key, ds_u.n, m)
        S = np.asarray(S)
        weights = np.asarray(w0) * union.weights[S]
        schedule = CommSchedule.uniform(T, m)
    else:
        if task.needs_labels and ds_u.y is None:
            raise ValueError(f"{task.name} requires labels at party T")
        # The tree's params may carry stream-scorer-only knobs (rcond,
        # center_sample, ...); the union re-score runs the full score_fn,
        # so keep only what its signature accepts.
        sig = inspect.signature(task.score_fn).parameters
        if not any(p.kind is inspect.Parameter.VAR_KEYWORD
                   for p in sig.values()):
            params = {k: v for k, v in params.items() if k in sig}
        scores, dis_key = task.score_fn(key, ds_u,
                                        backend=resolve_backend(backend),
                                        **params)
        folded = scores * np.asarray(union.weights,
                                     np.float32)[None, :]      # (T, m_union)
        plan = dis_plan_full(dis_key, folded, m)
        if not bool(plan.totals.sum() > 0):
            raise ValueError("DIS requires a positive total score")
        S = np.asarray(plan.indices)
        weights = np.asarray(plan.weights) * union.weights[S]
        # the merge re-score's round-1 G_j physically carries one float32
        # mass per union row — bill those bits, not just the paper scalar
        schedule = CommSchedule.dis(
            T, m, counts=np.asarray(plan.counts),
            round1_payload=WirePayload.of((ds_u.n,), "float32", "raw_fp32"))

    if bill_consume:
        sizes = [mt.m for mt in mats]
        # merge(T, a, b) bills per consumed row, so folding k children into
        # (sum of first k-1, last) charges exactly sum_i 2*m_i*T
        schedule = CommSchedule.merge(T, sum(sizes[:-1]), sizes[-1]) + schedule
    rep = deliver_or_record(
        schedule, ledger, transport,
        max_retries=0 if fault_policy == "fail" else None,
        drop_on_exhaust=False,
    )
    return MaterializedCoreset(
        indices=union.indices[S],
        weights=weights.astype(union.weights.dtype),
        parts=[p[S] for p in union.parts],
        y=None if union.y is None else union.y[S],
        comm_units=union.comm_units + rep.units,
        comm_bits=union.comm_bits + rep.bits,
    )


@dataclasses.dataclass(frozen=True)
class InsertStats:
    """The census of ONE insert — what the no-full-rescore tests assert.

    ``rescored_rows`` counts every row any score function touched during
    the insert: the chunk itself (the leaf build) plus each merge's 2m-row
    union — NEVER the n_total rows already absorbed.  ``merges`` is bounded
    by the binary-counter carry chain: at most ``log2(chunks)+1``.
    """

    chunk_rows: int
    leaf_builds: int
    merges: int
    rescored_rows: int
    comm_delta: int
    height_after: int
    latency_s: float
    #: ``"<failed-engine>-><winner>"`` when the leaf build's failover
    #: ladder fired (tree constructed with ``failover=True``), else None.
    fallback: Optional[str] = None


@dataclasses.dataclass
class TreeNode:
    """One merge-and-reduce node: a materialized coreset summarizing
    ``chunks`` superchunks (``rows`` raw rows) at binary-counter ``level``."""

    level: int
    chunks: int
    rows: int
    cs: MaterializedCoreset


class CoresetTree:
    """Merge-and-reduce maintenance of one task's coreset over a row stream.

    ``insert(parts, y)`` absorbs one superchunk (per-party feature slices of
    the same new rows, labels at party T when the task needs them) in
    O(m log n); ``query()`` returns the current summary — the weighted
    union of the O(log n) occupied levels, or, with ``reduce_to=m``, one
    more :func:`merge_reduce` down to exactly m rows.  All indices are
    GLOBAL row ids (offset by the stream position at insert time), so query
    results evaluate directly against the full stream.

    ``headroom`` (default 2) is the classic merge-and-reduce variance
    control: every NODE stores ``headroom * budget`` rows
    (``node_budget``), and only the final query reduce comes down to the
    requested m — each level's re-sample then draws from a richer union,
    and the measured rel_error of a height-h tree lands within ~2x of the
    flat equal-budget build instead of compounding per level
    (``benchmarks/serve.py``'s gate).  ``headroom=1`` gives the textbook
    equal-size scheme.  Insert cost stays O(m log n); the ledger bills the
    node_budget-sized schedules exactly.

    The tree owns a :class:`CommLedger` (or records on a supplied one) —
    after any sequence of inserts its total is exactly the composed
    merge-and-reduce bill, invariant to insert order.
    """

    def __init__(
        self,
        task: Union[str, CoresetTask],
        budget: int,
        *,
        key: jax.Array,
        backend: str = "auto",
        block_size: int = 65536,
        chunk_blocks: Optional[int] = None,
        prefetch: Optional[bool] = None,
        params: Optional[Mapping[str, Any]] = None,
        plan_cache: Optional[PlanCache] = None,
        ledger: Optional[CommLedger] = None,
        headroom: int = 2,
        fault_policy: str = "fail",
        transport: Optional[Transport] = None,
        checkpoint: Optional[StreamCheckpoint] = None,
        memory_budget_bytes: Optional[int] = None,
        failover: bool = False,
    ) -> None:
        self.task = get_task(task)
        self.budget = int(budget)
        if self.budget < 1:
            raise ValueError(f"budget must be >= 1, got {budget}")
        self.headroom = int(headroom)
        if self.headroom < 1:
            raise ValueError(f"headroom must be >= 1, got {headroom}")
        self.node_budget = self.headroom * self.budget
        self.key = key
        self.backend = backend
        self.block_size = int(block_size)
        self.chunk_blocks = chunk_blocks
        self.prefetch = prefetch
        self.params = dict(params or {})
        self.plan_cache = plan_cache
        self.fault_policy = str(fault_policy)
        self.transport = transport
        self.checkpoint = checkpoint
        # engine failover for LEAF builds: a leaf that crashes or breaches
        # memory_budget_bytes retries down the plan's fallback chain
        # (pipelined -> streamed, draw-identical).  Merges never failover —
        # they run dis_plan_full over tiny materialized unions, not an
        # engine.
        self.memory_budget_bytes = memory_budget_bytes
        self.failover = bool(failover)
        self.fallbacks = 0
        self.last_fallback: Optional[str] = None
        self.ledger = ledger if ledger is not None else CommLedger()
        self.levels: List[Optional[TreeNode]] = []
        self.num_chunks = 0
        self.n_total = 0
        self._merge_ops = 0
        self.last_insert: Optional[InsertStats] = None
        # numerical-health census over leaf builds (merge unions re-score
        # already-validated rows, so leaves are where health is measured)
        self.health_checks = 0
        self.health_warnings = 0
        self.last_health: Optional[HealthReport] = None

    # -- the deterministic key chain ----------------------------------------

    def leaf_key(self, i: int) -> jax.Array:
        """The PRNG key leaf ``i`` consumes — the SAME key a direct
        ``build_coreset_streaming`` of that chunk (at ``node_budget``)
        would need to reproduce the leaf draw bit for bit."""
        return jax.random.fold_in(jax.random.fold_in(self.key, 1), i)

    def merge_key(self, t: int) -> jax.Array:
        return jax.random.fold_in(jax.random.fold_in(self.key, 2), t)

    def query_key(self) -> jax.Array:
        """Stable between inserts (keyed by the insert count), so repeated
        queries of an unchanged tree are draw-identical."""
        return jax.random.fold_in(jax.random.fold_in(self.key, 3),
                                  self.num_chunks)

    # -- geometry ------------------------------------------------------------

    @property
    def height(self) -> int:
        occ = [i for i, nd in enumerate(self.levels) if nd is not None]
        return (max(occ) + 1) if occ else 0

    @property
    def num_nodes(self) -> int:
        return sum(1 for nd in self.levels if nd is not None)

    @property
    def m_active(self) -> int:
        """Rows held across all occupied levels (the un-reduced query size)."""
        return sum(nd.cs.m for nd in self.levels if nd is not None)

    # -- crash-safe snapshots ------------------------------------------------

    def _snapshot(self):
        """Everything one insert mutates: a shallow copy of the level slots
        (nodes themselves are immutable once placed), the key-chain
        counters, and a ledger rollback mark."""
        return (list(self.levels), self.num_chunks, self.n_total,
                self._merge_ops, self.health_checks, self.health_warnings,
                self.last_health, self.fallbacks, self.last_fallback,
                self.ledger.mark())

    def _restore(self, snap) -> None:
        (levels, num_chunks, n_total, merge_ops,
         health_checks, health_warnings, last_health,
         fallbacks, last_fallback, mark) = snap
        self.levels = levels
        self.num_chunks = num_chunks
        self.n_total = n_total
        self._merge_ops = merge_ops
        self.health_checks = health_checks
        self.health_warnings = health_warnings
        self.last_health = last_health
        self.fallbacks = fallbacks
        self.last_fallback = last_fallback
        self.ledger.rollback(mark)

    # -- the operations ------------------------------------------------------

    def insert(self, parts: Sequence[Any], y: Optional[Any] = None, *,
               probe: Optional[Any] = None) -> InsertStats:
        """Absorb one superchunk: ONE pipelined leaf build over the chunk +
        the binary-counter carry chain of merges.  Returns the census.

        ``probe`` (a no-arg callable) fires at every superchunk boundary of
        the leaf build — the serving layer's deadline-check injection point;
        a probe that raises aborts the insert and the rollback below makes
        the abort free.

        Crash-safe: any failure mid-insert (a party exhausting its retries,
        a killed process probe, OOM, a deadline breach) rolls the tree back
        to its pre-insert state — levels, key-chain counters, AND the
        ledger — so retrying the same chunk replays the SAME leaf/merge
        keys and lands draw-identically to a never-failed insert.  With a
        ``checkpoint`` bound, the retried leaf build additionally resumes
        its scan passes at the last completed superchunk instead of
        restarting from row 0.
        """
        snap = self._snapshot()
        try:
            return self._insert(parts, y, probe)
        except BaseException:
            self._restore(snap)
            raise

    def _insert(self, parts: Sequence[Any], y: Optional[Any],
                probe: Optional[Any] = None) -> InsertStats:
        t0 = time.perf_counter()
        led0 = self.ledger.total
        parts = [np.asarray(p) for p in parts]
        chunk_rows = int(parts[0].shape[0])
        if chunk_rows < 1:
            raise ValueError("superchunk must contain at least one row")
        ds = VFLDataset(parts, None if y is None else np.asarray(y))

        spec = CoresetSpec(
            task=self.task, budgets=self.node_budget, engine="pipelined",
            backend=self.backend, block_size=self.block_size,
            chunk_blocks=self.chunk_blocks, prefetch=self.prefetch,
            fault_policy=self.fault_policy, params=self.params,
        )
        pipe = CoresetPipeline(ds, plan_cache=self.plan_cache)
        fallback = None
        if self.failover:
            out = pipe.build_failover(
                spec, key=self.leaf_key(self.num_chunks),
                ledger=self.ledger, probe=probe, transport=self.transport,
                checkpoint=self.checkpoint,
                memory_budget_bytes=self.memory_budget_bytes,
            )
            cs, fallback = out.coreset, out.fallback
            if fallback is not None:
                self.fallbacks += 1
                self.last_fallback = fallback
        else:
            cs = pipe.build(spec, key=self.leaf_key(self.num_chunks),
                            ledger=self.ledger, probe=probe,
                            transport=self.transport,
                            checkpoint=self.checkpoint)
        if cs.health is not None:
            self.health_checks += 1
            if not cs.health.healthy:
                self.health_warnings += 1
            self.last_health = cs.health
        node = TreeNode(
            level=0, chunks=1, rows=chunk_rows,
            cs=MaterializedCoreset.from_coreset(cs, ds, offset=self.n_total),
        )
        self.num_chunks += 1
        self.n_total += chunk_rows

        merges = 0
        rescored = chunk_rows
        lvl = 0
        while lvl < len(self.levels) and self.levels[lvl] is not None:
            other = self.levels[lvl]
            self.levels[lvl] = None
            rescored += other.cs.m + node.cs.m     # the 2m-row merge union
            node = self._merge(other, node)
            merges += 1
            lvl += 1
        if lvl == len(self.levels):
            self.levels.append(None)
        self.levels[lvl] = node

        self.last_insert = InsertStats(
            chunk_rows=chunk_rows, leaf_builds=1, merges=merges,
            rescored_rows=rescored, comm_delta=self.ledger.total - led0,
            height_after=self.height,
            latency_s=time.perf_counter() - t0,
            fallback=fallback,
        )
        return self.last_insert

    def _merge(self, left: TreeNode, right: TreeNode) -> TreeNode:
        """Combine two equal-level nodes (older child LEFT, so the union's
        row order is stream order) into one level-(l+1) node."""
        mat = merge_reduce(
            self.task, [left.cs, right.cs], self.node_budget,
            key=self.merge_key(self._merge_ops), backend=self.backend,
            params=self.params, ledger=self.ledger,
            transport=self.transport, fault_policy=self.fault_policy,
        )
        self._merge_ops += 1
        return TreeNode(level=left.level + 1, chunks=left.chunks + right.chunks,
                        rows=left.rows + right.rows, cs=mat)

    def query(
        self,
        *,
        reduce_to: Optional[int] = None,
        key: Optional[jax.Array] = None,
    ) -> MaterializedCoreset:
        """The current stream summary.

        Default: the weighted UNION of the occupied levels (size
        ``m_active`` <= budget * height; union is server-side bookkeeping —
        no protocol cost, ``comm_units`` composes the children's).  With
        ``reduce_to=m``: one more :func:`merge_reduce` down to exactly m
        rows, billed on the tree's ledger like any merge.  Deterministic:
        the default key is stable until the next insert.
        """
        nodes = [nd for nd in reversed(self.levels) if nd is not None]
        if not nodes:
            raise ValueError("query on an empty tree — insert a chunk first")
        if reduce_to is None:
            return MaterializedCoreset.concat([nd.cs for nd in nodes])
        return merge_reduce(
            self.task, [nd.cs for nd in nodes], int(reduce_to),
            key=self.query_key() if key is None else key,
            backend=self.backend, params=self.params, ledger=self.ledger,
            transport=self.transport, fault_policy=self.fault_policy,
        )

    def describe(self) -> str:
        occ = [(nd.level, nd.chunks, nd.cs.m)
               for nd in self.levels if nd is not None]
        lines = [
            f"CoresetTree: task={self.task.name} budget={self.budget} "
            f"(nodes keep {self.node_budget}) "
            f"chunks={self.num_chunks} rows={self.n_total}",
            f"  height={self.height} nodes={self.num_nodes} "
            f"m_active={self.m_active} comm={self.ledger.total} "
            f"({fmt_bits(self.ledger.total_bits)} on the wire)",
        ]
        if self.health_checks:
            status = ("ok" if self.last_health is None
                      or self.last_health.healthy else "WARN")
            lines.append(
                f"  health: {self.health_checks} checked, "
                f"{self.health_warnings} warning(s), last={status}"
            )
        for level, chunks, m in sorted(occ, reverse=True):
            lines.append(f"  level {level}: {chunks} chunk(s), m={m}")
        return "\n".join(lines)
