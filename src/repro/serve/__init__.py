"""Online coreset service: merge-and-reduce tree + multi-tenant serving.

  * :mod:`repro.serve.tree` — :class:`CoresetTree`: merge-and-reduce
    maintenance of one task's coreset over a row stream (pipelined-engine
    leaves, weighted-union DIS merges, exact composed ledger).
  * :mod:`repro.serve.service` — :class:`CoresetService`: many tenants,
    one shared plan cache, cross-tenant batching of one-shot builds.
  * :mod:`repro.serve.resilience` — admission control and failure
    isolation: :class:`TokenBucket`, :class:`CircuitBreaker`, and the
    :class:`ShedReceipt` every refused request returns.

(The seed's language-model ``ServeEngine`` now lives in
:mod:`repro.models.lm_serve`; it is re-exported here — deprecated — so old
imports keep working.)
"""

from repro.models.lm_serve import ServeEngine, make_serve_step   # deprecated
from repro.serve.resilience import CircuitBreaker, ShedReceipt, TokenBucket
from repro.serve.service import (
    CoresetService,
    EvictReceipt,
    InsertReceipt,
    QueryReceipt,
    TenantState,
)
from repro.serve.tree import CoresetTree, InsertStats, TreeNode, merge_reduce

__all__ = [
    "CoresetTree",
    "TreeNode",
    "InsertStats",
    "merge_reduce",
    "CoresetService",
    "TenantState",
    "InsertReceipt",
    "QueryReceipt",
    "EvictReceipt",
    "ShedReceipt",
    "TokenBucket",
    "CircuitBreaker",
    # deprecated LM re-exports
    "ServeEngine",
    "make_serve_step",
]
