from repro.data.synthetic import (
    correlated_vfl_data,
    kc_house_like,
    year_prediction_like,
)
from repro.data.lm import TokenStream, lm_batch

__all__ = [
    "year_prediction_like",
    "kc_house_like",
    "correlated_vfl_data",
    "TokenStream",
    "lm_batch",
]
