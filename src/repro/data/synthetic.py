"""Synthetic datasets matched to the paper's benchmark profiles.

The container is offline, so YearPredictionMSD [4] and KC-House [35] are
replaced by generators with the same (n, d, label) shape and qualitatively
matched structure: correlated feature blocks (audio timbre features /
house attributes are strongly collinear), heavy-tailed leverage-score
profiles (so importance sampling genuinely beats uniform), and labels from a
noisy linear + mild nonlinear response.

``correlated_vfl_data`` exposes the cross-party correlation knob used by the
assumption-sweep tests: high correlation -> Assumption 5.1's tau small;
independent blocks -> Assumption 4.1's gamma large.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _latent_block_features(
    key: jax.Array, n: int, d: int, n_latent: int, noise: float, heavy_tail: float
) -> jax.Array:
    """Features = latent factors x loadings + noise; a few rows are scaled by
    a Pareto-ish factor so leverage scores are heavy-tailed (the regime the
    paper's YearPrediction experiments live in)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    Z = jax.random.normal(k1, (n, n_latent))
    W = jax.random.normal(k2, (n_latent, d)) / jnp.sqrt(n_latent)
    X = Z @ W + noise * jax.random.normal(k3, (n, d))
    if heavy_tail > 0:
        u = jax.random.uniform(k4, (n, 1), minval=1e-3, maxval=1.0)
        scale = u ** (-heavy_tail)          # Pareto tail
        X = X * (1.0 + 0.1 * scale)
    return X


def year_prediction_like(
    key: jax.Array, n: int = 51534, d: int = 90
) -> Tuple[jax.Array, jax.Array]:
    """(X (n, 90), y (n,)) — YearPredictionMSD profile (default n is the
    paper's 515345 scaled 10x down so CPU benchmarks finish; benchmarks can
    pass the full size)."""
    kx, kt, kn = jax.random.split(key, 3)
    X = _latent_block_features(kx, n, d, n_latent=12, noise=0.4, heavy_tail=0.4)
    theta = jax.random.normal(kt, (d,)) / jnp.sqrt(d)
    y = 1998.0 + 8.0 * (X @ theta) + 1.5 * jnp.tanh(X[:, 0]) \
        + 3.0 * jax.random.normal(kn, (n,))
    return X, y


def kc_house_like(key: jax.Array, n: int = 21613, d: int = 18) -> Tuple[jax.Array, jax.Array]:
    """(X (n, 18), y (n,)) — KC-House profile (prices, log-normal-ish)."""
    kx, kt, kn = jax.random.split(key, 3)
    X = _latent_block_features(kx, n, d, n_latent=5, noise=0.3, heavy_tail=0.6)
    theta = jax.random.normal(kt, (d,)) / jnp.sqrt(d)
    log_price = 13.0 + 0.5 * (X @ theta) + 0.1 * jax.random.normal(kn, (n,))
    return X, jnp.exp(jnp.clip(log_price, 11.0, 16.0)) / 1e5


def correlated_vfl_data(
    key: jax.Array,
    n: int,
    d: int,
    T: int,
    cross_correlation: float = 0.7,
    k_clusters: int = 0,
) -> jax.Array:
    """X (n, d) whose T near-even column blocks share a fraction
    ``cross_correlation`` of variance through common latents.

    cross_correlation ~ 1: every party sees the same geometry (tau -> small,
    Assumption 5.1 easy; gamma -> small, Assumption 4.1 hard).
    cross_correlation ~ 0: independent blocks (gamma -> 1, tau unbounded).
    Optionally plants ``k_clusters`` Gaussian clusters (VKMC regime).
    """
    kc, ks, kp, kz = jax.random.split(key, 4)
    rho = jnp.clip(cross_correlation, 0.0, 1.0)
    shared = jax.random.normal(ks, (n, d))
    private = jax.random.normal(kp, (n, d))
    X = jnp.sqrt(rho) * shared + jnp.sqrt(1 - rho) * private
    if k_clusters > 0:
        centers = 4.0 * jax.random.normal(kc, (k_clusters, d))
        assign = jax.random.randint(kz, (n,), 0, k_clusters)
        X = X + centers[assign]
    return X
