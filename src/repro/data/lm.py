"""Token pipeline for the LM examples: a synthetic Zipf-Markov corpus with
enough structure that per-example losses/leverage scores differ (so coreset
batch selection has signal), plus a simple sharded batch iterator.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


def lm_batch(key: jax.Array, batch: int, seq: int, vocab: int) -> Dict[str, jax.Array]:
    """One (tokens, labels) batch from the synthetic corpus distribution."""
    stream = TokenStream(vocab=vocab, seq_len=seq, batch_size=batch,
                         seed=int(jax.random.randint(key, (), 0, 2**31 - 1)))
    return next(iter(stream))


@dataclasses.dataclass
class TokenStream:
    """Zipf unigram + order-1 Markov 'grammar' + per-sequence difficulty tiers.

    A third of sequences are near-deterministic (low loss), a third mixed,
    a third high-entropy — mirroring real-corpus heterogeneity; this is what
    makes importance-weighted batch selection measurably better than uniform
    in examples/train_lm_coreset.py.
    """

    vocab: int
    seq_len: int
    batch_size: int
    seed: int = 0

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        v = self.vocab
        ranks = np.arange(1, v + 1)
        self._unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # sparse deterministic successor table for the "grammar"
        self._succ = rng.integers(0, v, size=v)
        self._rng = rng

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> Dict[str, jnp.ndarray]:
        rng = self._rng
        B, S, v = self.batch_size, self.seq_len, self.vocab
        tier = rng.integers(0, 3, size=B)                   # 0 easy, 2 hard
        p_grammar = np.array([0.95, 0.6, 0.1])[tier]        # (B,)
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.choice(v, size=B, p=self._unigram)
        for t in range(1, S + 1):
            use_g = rng.random(B) < p_grammar
            rand = rng.choice(v, size=B, p=self._unigram)
            toks[:, t] = np.where(use_g, self._succ[toks[:, t - 1]], rand)
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }
