"""Three-term roofline model from a compiled dry-run artifact.

TPU v5e constants (per chip):
  peak bf16 compute: 197 TFLOP/s
  HBM bandwidth:     819 GB/s
  ICI per link:      ~50 GB/s

  compute term    = HLO_FLOPs / (chips * peak)
  memory term     = HLO_bytes / (chips * hbm_bw)
  collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-module,
i.e. already the per-replica program under SPMD); collective_bytes is parsed
from the compiled HLO text (launch.hlo).  MODEL_FLOPS = 6*N*D (dense) or
6*N_active*D (MoE) gives the "useful fraction" check.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float          # per-chip program FLOPs (SPMD module)
    hlo_bytes: float          # per-chip HBM traffic
    collective_bytes: float   # per-chip link traffic
    model_flops: float        # 6*N(active)*tokens, global
    peak_bytes_per_device: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / (chips * HLO_FLOPs): how much compiled compute is
        'useful' model math (catches remat / redundant compute)."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total > 0 else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilisation at the roofline step time."""
        t = self.step_time
        return self.model_flops / (self.chips * PEAK_FLOPS * t) if t > 0 else 0.0

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": round(self.t_compute, 6),
            "t_memory_s": round(self.t_memory, 6),
            "t_collective_s": round(self.t_collective, 6),
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_fraction": round(self.useful_fraction, 4),
            "mfu_at_roofline": round(self.mfu, 4),
            "peak_bytes_per_device": self.peak_bytes_per_device,
        }


def model_flops(
    n_active_params: int, tokens: int, phase: str
) -> float:
    """6ND for training (fwd+bwd), 2ND for inference fwd."""
    mult = 6.0 if phase == "train" else 2.0
    return mult * n_active_params * tokens
