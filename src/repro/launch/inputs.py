"""ShapeDtypeStruct stand-ins for every model input, per (arch x shape).

No device allocation — these are what ``jax.jit(...).lower()`` consumes in
the dry-run.  The frontend carve-out is visible here: audio/vlm archs get a
``prefix_embeds`` spec (precomputed frame/patch embeddings) instead of raw
media.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    """Model-input specs for one step of the shape's phase."""
    B, S = shape.global_batch, shape.seq_len
    if shape.is_decode:
        out = {"tokens": SDS((B, 1), jnp.int32)}
        return out

    if cfg.kind == "encdec":
        # decoder consumes S tokens; encoder consumes the stub frames
        return {
            "tokens": SDS((B, S), jnp.int32),
            "labels": SDS((B, S), jnp.int32),
            "prefix_embeds": SDS((B, cfg.num_prefix, cfg.d_model), jnp.bfloat16),
        }
    if cfg.frontend == "vision_stub":
        s_text = S - cfg.num_prefix
        return {
            "tokens": SDS((B, s_text), jnp.int32),
            "labels": SDS((B, s_text), jnp.int32),
            "prefix_embeds": SDS((B, cfg.num_prefix, cfg.d_model), jnp.bfloat16),
        }
    return {"tokens": SDS((B, S), jnp.int32), "labels": SDS((B, S), jnp.int32)}


def prefill_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    specs = input_specs(cfg, shape)
    specs.pop("labels", None)
    return specs


def cache_specs(cfg: ArchConfig, shape: InputShape) -> Any:
    """Decode-cache specs (eval_shape over init_cache — no allocation)."""
    from repro.models import api as model_api

    return jax.eval_shape(
        lambda: model_api.init_cache(cfg, shape.global_batch, shape.seq_len)
    )


def state_specs(cfg: ArchConfig) -> Any:
    """Train-state specs (params + AdamW m/v) via eval_shape."""
    from repro.models import api as model_api
    from repro.optim.adamw import adamw_init

    def build(key):
        params = model_api.init_params(key, cfg)
        return {"params": params, "opt": adamw_init(params),
                "step": jnp.zeros((), jnp.int32)}

    return jax.eval_shape(build, jax.random.PRNGKey(0))
