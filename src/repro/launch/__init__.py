# Launchers: mesh.py (production mesh builders), dryrun.py (512-device
# lower+compile + roofline extraction), hillclimb.py (§Perf driver),
# train.py (training driver), hlo.py (collective parsing), roofline.py
# (three-term model).  dryrun/hillclimb must be the process entry point
# (they set XLA_FLAGS before importing jax).
