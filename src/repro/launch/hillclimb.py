import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing driver.

Runs the named optimization variants for the three selected (arch x shape)
pairs against the single-pod production mesh and appends layer-slope
roofline records to benchmarks/artifacts/hillclimb.jsonl.  Each variant is a
(cfg_transform, selector) pair — the hypothesis/meaning lives in
EXPERIMENTS.md §Perf.

  PYTHONPATH=src python -m repro.launch.hillclimb --step A1
  PYTHONPATH=src python -m repro.launch.hillclimb --step B1 C1 ...
"""

import argparse
import dataclasses
import json
from typing import Optional

from repro.core.selector import SelectorConfig
from repro.launch.dryrun import roofline_one


def _t(**kw):
    def tr(cfg):
        return dataclasses.replace(cfg, **kw)

    return tr


STEPS = {
    # ---- pair A: deepseek-v2-236b train_4k (worst roofline fraction) ------
    "A1": ("deepseek-v2-236b", "train_4k", _t(moe_dispatch="einsum"), None),
    "A2": ("deepseek-v2-236b", "train_4k",
           _t(moe_dispatch="einsum", capacity_factor=1.0), None),
    "A3": ("deepseek-v2-236b", "train_4k",
           _t(moe_dispatch="einsum", capacity_factor=1.0, moe_group=128), None),
    "A4": ("deepseek-v2-236b", "train_4k",
           _t(moe_dispatch="einsum", capacity_factor=1.0, moe_group=512), None),
    # ---- pair B: rwkv6-3b train_4k (most collective-bound) ----------------
    "B1": ("rwkv6-3b", "train_4k", _t(pure_fsdp=True, fsdp=True), None),
    "B2": ("rwkv6-3b", "train_4k",
           _t(pure_fsdp=True, fsdp=True, ssm_chunk=64), None),
    "B3": ("rwkv6-3b", "train_4k",
           _t(pure_fsdp=True, fsdp=True, ssm_chunk=128), None),
    # ---- pair C: granite train_4k (paper-technique representative) --------
    "C1": ("granite-moe-3b-a800m", "train_4k", None,
           SelectorConfig(mode="coreset", fraction=0.25)),
    "C2": ("granite-moe-3b-a800m", "train_4k", _t(moe_dispatch="einsum"),
           SelectorConfig(mode="coreset", fraction=0.25)),
    "C3": ("granite-moe-3b-a800m", "train_4k", _t(moe_dispatch="einsum"), None),
}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--step", nargs="+", required=True, choices=list(STEPS))
    ap.add_argument("--out", default="benchmarks/artifacts/hillclimb.jsonl")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    fails = 0
    with open(args.out, "a") as out:
        for step in args.step:
            arch, shape, tr, sel = STEPS[step]
            rec = roofline_one(arch, shape, cfg_transform=tr, selector=sel)
            rec["step"] = step
            rec.pop("trace", None)
            out.write(json.dumps(rec) + "\n")
            out.flush()
            if rec["status"] != "ok":
                fails += 1
                print(f"[{step}] ERROR {rec.get('error', '')[:300]}")
            else:
                print(f"[{step}] {arch}/{shape}: t_comp={rec['t_compute_s']:.3f} "
                      f"t_mem={rec['t_memory_s']:.3f} t_coll={rec['t_collective_s']:.3f} "
                      f"bneck={rec['bottleneck']} useful={rec['useful_fraction']:.3f} "
                      f"peakGiB={(rec.get('peak_bytes_per_device') or 0)/2**30:.1f}")
    return 1 if fails else 0


if __name__ == "__main__":
    raise SystemExit(main())
