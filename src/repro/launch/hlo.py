"""HLO-text analysis: collective traffic + op census from a lowered/compiled
module.  This is the dry-run "profiler" — no real hardware, so the roofline's
collective term comes from summing operand bytes of every collective op here.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
)


def shape_bytes(text: str) -> int:
    """Sum bytes over every dtype[dims] occurrence in `text` (handles tuple
    shapes by construction)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _result_bytes_of_line(line: str) -> int:
    """Bytes of the op's RESULT shape (the `lhs = shape op(...)` part)."""
    lhs = line.split(" = ", 1)
    if len(lhs) != 2:
        return 0
    rhs = lhs[1]
    # result shape is everything before the opcode name
    for op in COLLECTIVE_OPS:
        k = rhs.find(op + "(")
        if k < 0:
            k = rhs.find(op + "-start(")
        if k < 0:
            k = rhs.find(op + "-done(")
        if k >= 0:
            return shape_bytes(rhs[:k])
    return shape_bytes(rhs)


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind {count, bytes} over the module.

    Bytes = result-shape bytes of each collective op (for all-reduce this is
    the payload; for all-gather it is the gathered output — a conservative
    upper bound on link traffic).  *-start ops are counted; their *-done
    twins are skipped to avoid double counting.
    """
    out: Dict[str, Dict[str, float]] = defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        for op in COLLECTIVE_OPS:
            if re.search(rf"\b{op}(-start)?\(", s):
                if re.search(rf"\b{op}-done\(", s):
                    break
                out[op]["count"] += 1
                out[op]["bytes"] += _result_bytes_of_line(s)
                break
    return dict(out)


def total_collective_bytes(hlo_text: str) -> int:
    return int(sum(v["bytes"] for v in collective_stats(hlo_text).values()))


def op_census(hlo_text: str, top: int = 15) -> Dict[str, int]:
    """Count of ops by opcode (remat/redundancy smell test)."""
    counts: Dict[str, int] = defaultdict(int)
    opcode_re = re.compile(r" = (?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*) ([a-z][a-z0-9-]*)\(")
    for line in hlo_text.splitlines():
        m = opcode_re.search(line)
        if m:
            counts[m.group(1)] += 1
    return dict(sorted(counts.items(), key=lambda kv: -kv[1])[:top])


_HEAVY_OPS = ("dot", "scatter", "gather", "dynamic-slice", "dynamic-update-slice",
              "convolution")


def fusion_optimistic_bytes(hlo_text: str) -> int:
    """Fusion-optimistic HBM-traffic lower bound: result bytes (x2 for
    read+write) of the ops a TPU pipeline cannot fuse away — matmuls,
    gathers/scatters, cache updates — ignoring elementwise/convert chains
    that fuse on TPU.  The XLA-CPU ``cost_analysis()['bytes accessed']``
    counts every unfused op and over-states traffic by ~10x on deep stacks;
    the truth lies between the two (both are reported in §Roofline)."""
    total = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        rhs = s.split(" = ", 1)[1]
        for op in _HEAVY_OPS:
            k = rhs.find(f" {op}(")
            if k < 0 and rhs.startswith("("):
                continue
            m = re.match(
                rf"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+{op}\(", rhs)
            if m:
                total += 2 * shape_bytes(m.group(1))
                break
    return total


def while_trip_counts(hlo_text: str):
    """Trip counts of while loops when XLA annotates them (scan bodies)."""
    return [int(m) for m in re.findall(r'trip_count[="]+(\d+)', hlo_text)]
