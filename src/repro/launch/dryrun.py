import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) against the
production mesh, prove it fits (memory_analysis), and extract the roofline
terms (cost_analysis + HLO collective parse).

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all                 # 16x16
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod     # 2x16x16

Results are appended as JSON lines to ``--out`` (default
benchmarks/artifacts/dryrun.jsonl) — EXPERIMENTS.md §Dry-run/§Roofline read
from there.
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import INPUT_SHAPES, all_arch_names, get_arch
from repro.configs.base import ArchConfig, InputShape
from repro.launch import hlo as hlo_mod
from repro.launch import roofline as rf
from repro.launch.inputs import cache_specs, input_specs, prefill_specs, state_specs
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import api as model_api
from repro.optim.schedules import constant
from repro.models.lm_serve import make_serve_step
from repro.sharding.ctx import ShardingCtx, set_ctx
from repro.sharding.specs import batch_shardings, cache_shardings, param_shardings
from repro.train.trainer import make_train_step
from repro.utils.logging import get_logger

log = get_logger("dryrun")

SKIPS = {
    # (arch, shape): reason — recorded in DESIGN.md §Arch-applicability
    ("whisper-medium", "long_500k"):
        "enc-dec with 1500-frame encoder context; 524k-token decode is out of scope",
}


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: jax.NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )


def build_lowerable(cfg: ArchConfig, shape: InputShape, mesh, multi_pod: bool,
                    selector=None):
    """Returns (fn, example_args, in_shardings, donate) for the shape's phase."""
    pspecs = param_shardings(
        jax.eval_shape(lambda k: model_api.init_params(k, cfg), jax.random.PRNGKey(0)),
        cfg, multi_pod)

    if shape.phase == "train":
        st_specs = state_specs(cfg)
        st_shard = {
            "params": pspecs,
            "opt": {"m": pspecs, "v": pspecs, "step": jax.sharding.PartitionSpec()},
            "step": jax.sharding.PartitionSpec(),
        }
        b_specs = input_specs(cfg, shape)
        b_shard = {k: v for k, v in batch_shardings(cfg, shape, multi_pod).items()
                   if k in b_specs}
        fn = make_train_step(cfg, constant(1e-4), selector=selector)
        key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
        args = (st_specs, b_specs, key_spec)
        shardings = (_named(mesh, st_shard), _named(mesh, b_shard),
                     jax.NamedSharding(mesh, jax.sharding.PartitionSpec()))
        return fn, args, shardings, (0,)

    if shape.phase == "prefill":
        b_specs = prefill_specs(cfg, shape)
        b_shard = {k: v for k, v in batch_shardings(cfg, shape, multi_pod).items()
                   if k in b_specs}

        def prefill(params, batch):
            hidden = model_api.forward_hidden(params, cfg, batch)
            from repro.models.lm import logits_of, mask_pad_logits
            if cfg.kind == "encdec":
                from repro.models.layers import unembed
                from repro.sharding.ctx import shard_logits
                return mask_pad_logits(
                    shard_logits(unembed(hidden, params["embed"], tied=True)),
                    cfg.vocab_size)
            return logits_of(params, cfg, hidden)

        p_specs = jax.eval_shape(lambda k: model_api.init_params(k, cfg),
                                 jax.random.PRNGKey(0))
        args = (p_specs, b_specs)
        shardings = (_named(mesh, pspecs), _named(mesh, b_shard))
        return prefill, args, shardings, ()

    # decode
    c_specs = cache_specs(cfg, shape)
    c_shard = cache_shardings(c_specs, cfg, shape, multi_pod)
    b_specs = input_specs(cfg, shape)
    b_shard = batch_shardings(cfg, shape, multi_pod)
    p_specs = jax.eval_shape(lambda k: model_api.init_params(k, cfg),
                             jax.random.PRNGKey(0))
    fn = make_serve_step(cfg)
    args = (p_specs, c_specs, b_specs["tokens"])
    shardings = (_named(mesh, pspecs), _named(mesh, c_shard),
                 jax.NamedSharding(mesh, b_shard["tokens"]))
    return fn, args, shardings, (1,)


def run_one(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    save_hlo: Optional[str] = None,
    verbose: bool = True,
    layers_override: Optional[int] = None,
    unroll: bool = False,
    cfg_transform=None,
    selector=None,
) -> Dict[str, Any]:
    """Lower+compile one (arch, shape, mesh) and extract raw costs.

    ``layers_override``/``unroll`` support the layer-slope roofline method
    (see ``roofline_one``): XLA's cost_analysis counts a while-loop body once,
    so in-loop FLOPs/bytes/collectives of the L-layer scan are invisible —
    compiling unrolled L=1 and L=2 variants and extrapolating linearly is
    exact because all layers are identical.
    """
    import dataclasses as _dc

    shape = INPUT_SHAPES[shape_name]
    cfg = get_arch(arch).for_shape(shape)
    if layers_override is not None:
        cfg = _dc.replace(cfg, num_layers=layers_override,
                          enc_layers=min(cfg.enc_layers, layers_override))
    if unroll:
        cfg = _dc.replace(cfg, scan_unroll=True)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    if (arch, shape_name) in SKIPS:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": SKIPS[(arch, shape_name)]}
    if shape_name == "long_500k" and not cfg.supports_long_context():
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": "no sub-quadratic decode variant"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    ctx = ShardingCtx(dp_axes=dp_axes(multi_pod) if shape.global_batch > 1 else (),
                      tp_axis="model",
                      seq_axis=None if shape.is_decode else "model")
    t0 = time.time()
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "phase": shape.phase,
    }
    try:
        with mesh, set_ctx(ctx):
            fn, args, shardings, donate = build_lowerable(cfg, shape, mesh, multi_pod,
                                                          selector=selector)
            jfn = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        hlo_text = compiled.as_text()
        colls = hlo_mod.collective_stats(hlo_text)
        coll_bytes = int(sum(v["bytes"] for v in colls.values()))
        bytes_opt = hlo_mod.fusion_optimistic_bytes(hlo_text)

        mem: Dict[str, float] = {}
        try:
            ma = compiled.memory_analysis()
            for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                         "output_size_in_bytes", "alias_size_in_bytes",
                         "generated_code_size_in_bytes"):
                if hasattr(ma, attr):
                    mem[attr] = float(getattr(ma, attr))
        except Exception as e:  # pragma: no cover - backend-specific
            mem["error"] = str(e)
        peak = None
        if "temp_size_in_bytes" in mem:
            peak = mem["temp_size_in_bytes"] + mem.get("argument_size_in_bytes", 0.0) \
                - mem.get("alias_size_in_bytes", 0.0) + mem.get("output_size_in_bytes", 0.0)

        full_cfg = get_arch(arch).for_shape(shape)
        n_active = model_api.active_param_count(
            full_cfg, jax.eval_shape(lambda k: model_api.init_params(k, full_cfg),
                                     jax.random.PRNGKey(0)))
        tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
        mf = rf.model_flops(n_active, tokens, shape.phase)
        roof = rf.Roofline(
            arch=arch, shape=shape_name, mesh=rec["mesh"], chips=chips,
            hlo_flops=flops, hlo_bytes=bytes_acc, collective_bytes=coll_bytes,
            model_flops=mf, peak_bytes_per_device=peak,
        )
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "n_layers": cfg.num_layers,
            "hlo_flops": flops,
            "hlo_bytes": bytes_acc,
            "hlo_bytes_opt": float(bytes_opt),
            "collective_bytes": coll_bytes,
            "collectives": colls,
            "memory": mem,
            **roof.row(),
        })
        if save_hlo:
            os.makedirs(os.path.dirname(save_hlo), exist_ok=True)
            with open(save_hlo, "w") as f:
                f.write(hlo_text)
        if verbose:
            print(compiled.memory_analysis())
            print({k: v for k, v in cost.items()
                   if k in ("flops", "bytes accessed")})
    except Exception as e:
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "trace": traceback.format_exc()[-2000:]})
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def roofline_one(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    cfg_transform=None,
    full_rec: Optional[Dict[str, Any]] = None,
    selector=None,
) -> Dict[str, Any]:
    """Layer-slope roofline: full compile (lowering proof + memory fit) plus
    unrolled L=1 / L=2 compiles whose cost difference gives the exact
    per-layer FLOPs/bytes/collectives; total = outer + L * per-layer."""
    shape = INPUT_SHAPES[shape_name]
    L = get_arch(arch).num_layers
    full = full_rec or run_one(arch, shape_name, multi_pod, verbose=False,
                               cfg_transform=cfg_transform, selector=selector)
    if full["status"] != "ok":
        return full
    c1 = run_one(arch, shape_name, multi_pod, verbose=False, layers_override=1,
                 unroll=True, cfg_transform=cfg_transform, selector=selector)
    c2 = run_one(arch, shape_name, multi_pod, verbose=False, layers_override=2,
                 unroll=True, cfg_transform=cfg_transform, selector=selector)
    if c1["status"] != "ok" or c2["status"] != "ok":
        bad = c1 if c1["status"] != "ok" else c2
        full["slope_error"] = bad.get("error", "slope compile failed")
        return full

    def extrap(key):
        a, b = c1[key], c2[key] - c1[key]
        return max(a - b, 0.0) + L * b        # outer + L * per-layer

    flops = extrap("hlo_flops")
    bytes_acc = extrap("hlo_bytes")
    bytes_opt = extrap("hlo_bytes_opt")
    coll_bytes = extrap("collective_bytes")
    colls: Dict[str, Dict[str, float]] = {}
    kinds = set(c1["collectives"]) | set(c2["collectives"])
    for k in kinds:
        v1 = c1["collectives"].get(k, {"count": 0, "bytes": 0})
        v2 = c2["collectives"].get(k, {"count": 0, "bytes": 0})
        colls[k] = {
            "count": max(v1["count"] - (v2["count"] - v1["count"]), 0)
            + L * (v2["count"] - v1["count"]),
            "bytes": max(v1["bytes"] - (v2["bytes"] - v1["bytes"]), 0)
            + L * (v2["bytes"] - v1["bytes"]),
        }

    roof = rf.Roofline(
        arch=arch, shape=shape_name, mesh=full["mesh"], chips=full["chips"],
        hlo_flops=flops, hlo_bytes=bytes_acc, collective_bytes=coll_bytes,
        model_flops=full["model_flops"],
        peak_bytes_per_device=full.get("peak_bytes_per_device"),
    )
    rec = dict(full)
    rec.update({
        "method": "layer_slope",
        "hlo_flops": flops,
        "hlo_bytes": bytes_acc,
        "hlo_bytes_opt": bytes_opt,
        "t_memory_opt_s": round(bytes_opt / rf.HBM_BW, 6),
        "collective_bytes": coll_bytes,
        "collectives": colls,
        "raw_loop": {k: full[k] for k in ("hlo_flops", "hlo_bytes", "collective_bytes")},
        "slope_wall_s": c1["wall_s"] + c2["wall_s"],
        **roof.row(),
    })
    return rec


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES),
                    help="input shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="all archs x shapes")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun.jsonl")
    ap.add_argument("--save-hlo-dir", default=None)
    ap.add_argument("--roofline", action="store_true",
                    help="add layer-slope L=1/L=2 compiles for exact roofline terms")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else all_arch_names()
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    failures = 0
    with open(args.out, "a") as out:
        for arch in archs:
            for shape in shapes:
                for mp in meshes:
                    hlo_path = None
                    if args.save_hlo_dir:
                        hlo_path = os.path.join(
                            args.save_hlo_dir,
                            f"{arch}_{shape}_{'mp' if mp else 'sp'}.hlo.txt")
                    if args.roofline:
                        rec = roofline_one(arch, shape, mp)
                    else:
                        rec = run_one(arch, shape, mp, save_hlo=hlo_path, verbose=False)
                    rec.pop("trace", None)
                    out.write(json.dumps(rec) + "\n")
                    out.flush()
                    status = rec["status"]
                    extra = rec.get("bottleneck", rec.get("reason", rec.get("error", "")))
                    print(f"[{status:>7s}] {arch:25s} {shape:12s} "
                          f"{rec['mesh']:7s} {rec.get('wall_s', 0.0):7.1f}s {extra}")
                    if status == "error":
                        failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
