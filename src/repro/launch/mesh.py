"""Production mesh builders (TPU v5e target).

Functions, not module constants: importing this module never touches jax
device state, so smoke tests keep seeing 1 CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over however many devices exist (tests)."""
    import numpy as np

    devs = np.array(jax.devices()[: n_data * n_model]).reshape(n_data, n_model)
    return jax.sharding.Mesh(devs, ("data", "model"))


def dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)
