"""Training driver: ``--arch <id>`` selects any assigned architecture;
``--reduced`` (default, CPU) trains the family's smoke-scale variant on the
synthetic corpus with optional coreset batch selection; ``--production``
prints the pjit plan (shardings + mesh) that the dry-run compiles — on a
real TPU slice the same code path executes it.

  PYTHONPATH=src python -m repro.launch.train --arch granite-moe-3b-a800m \\
      --steps 50 --selector coreset --fraction 0.25
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--selector", default="none", choices=["none", "uniform", "coreset"])
    ap.add_argument("--fraction", type=float, default=0.25)
    ap.add_argument("--reduced", dest="reduced", action="store_true", default=True)
    ap.add_argument("--production", dest="reduced", action="store_false",
                    help="print the production-mesh plan instead of training")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax

    from repro.configs import get_arch
    from repro.core.selector import SelectorConfig
    from repro.data.lm import TokenStream
    from repro.optim.schedules import cosine_with_warmup
    from repro.train import make_train_step, save_checkpoint, train_state_init
    from repro.utils.logging import get_logger

    log = get_logger("train")
    cfg = get_arch(args.arch)

    if not args.reduced:
        # production plan: show the shardings the dry-run compiles
        from repro.launch.inputs import state_specs
        from repro.sharding.specs import param_shardings

        specs = param_shardings(state_specs(cfg)["params"], cfg, multi_pod=False)
        log.info("production mesh: 16x16 ('data','model'); param shardings:")
        for path, spec in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))[0]:
            name = "/".join(str(getattr(p, "key", p)) for p in path)
            log.info("  %-55s %s", name, spec)
        log.info("run `python -m repro.launch.dryrun --arch %s` to compile it",
                 args.arch)
        return 0

    cfg = cfg.reduced()
    sel = None if args.selector == "none" else SelectorConfig(
        mode=args.selector, fraction=args.fraction)
    key = jax.random.PRNGKey(args.seed)
    state = train_state_init(key, cfg)
    step = jax.jit(make_train_step(
        cfg, cosine_with_warmup(args.lr, max(args.steps // 10, 1), args.steps), sel))
    stream = iter(TokenStream(vocab=cfg.vocab_size, seq_len=args.seq,
                              batch_size=args.batch, seed=args.seed))
    losses, t0 = [], time.time()
    for i in range(args.steps):
        state, m = step(state, next(stream), jax.random.fold_in(key, i))
        losses.append(float(m["ce"]))
        if (i + 1) % max(args.steps // 10, 1) == 0:
            log.info("step %4d/%d ce=%.4f avg10=%.4f lr=%.2e %.0f ms/step",
                     i + 1, args.steps, losses[-1], np.mean(losses[-10:]),
                     float(m["lr"]), (time.time() - t0) / (i + 1) * 1e3)
    if args.ckpt:
        path = save_checkpoint(args.ckpt, state, args.steps)
        log.info("checkpoint: %s", path)
    log.info("final ce (last 10 avg): %.4f", np.mean(losses[-10:]))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
