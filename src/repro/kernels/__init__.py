# Pallas TPU kernels for the paper's compute hot-spots:
#   kmeans_assign        — blocked n x k distance + argmin (Algorithm 3 / Lloyd)
#   kmeans_assign_update — fused single-pass assign + cluster sums/counts/cost
#                          (one Lloyd iteration = ONE read of X; VKMC scoring
#                          gets cluster_cost/cluster_size from the same pass)
#   leverage             — row-wise quadratic form x_i^T M x_i (Algorithm 2)
#   weighted_gram        — X^T diag(w) X accumulation (coreset ridge solve)
# Each <name>.py holds the pl.pallas_call + BlockSpec; ops.py is the jit'd
# dispatch layer; ref.py the pure-jnp oracles.  All kernels accept leading
# batch dims (folded into the grid by the native pallas vmap rule).
