# Pallas TPU kernels for the paper's compute hot-spots:
#   kmeans_assign  — blocked n x k distance + argmin (Algorithm 3 / Lloyd)
#   leverage       — row-wise quadratic form x_i^T M x_i (Algorithm 2)
#   weighted_gram  — X^T diag(w) X accumulation (coreset ridge solve)
# Each <name>.py holds the pl.pallas_call + BlockSpec; ops.py is the jit'd
# dispatch layer; ref.py the pure-jnp oracles.
