"""Pallas TPU kernel: row-wise quadratic form lev_i = x_i^T M x_i.

This is the O(n*d^2) hot loop of Algorithm 2 (VRLR leverage scores): after a
party inverts its (d_j x d_j) local Gram matrix once, every row's leverage
score is a quadratic form against that inverse.  On TPU the (bn, d) @ (d, d)
product runs on the MXU; the Hadamard-and-reduce epilogue runs on the VPU in
the same VMEM residency, so X is read from HBM exactly once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, m_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)                       # (bn, d_pad)
    m = m_ref[...].astype(jnp.float32)                       # (d_pad, d_pad)
    xm = jax.lax.dot_general(
        x, m, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                        # (bn, d_pad)
    out_ref[...] = jnp.sum(xm * x, axis=1)


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def leverage(
    X: jax.Array,
    M: jax.Array,
    *,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """X: (n, d); M: (d, d) -> (n,) float32 quadratic forms.

    Leading batch dimensions (X (..., n, d), M (..., d, d)) fold into the
    grid via the native pallas_call batching rule — one dispatch per call,
    stacked-party scoring uses this with both operands batched over T.
    """
    if X.ndim > 2 or M.ndim > 2:
        return jax.vmap(
            lambda x, m: leverage(x, m, block_n=block_n, interpret=interpret),
            in_axes=(0 if X.ndim > 2 else None, 0 if M.ndim > 2 else None),
        )(X, M)
    n, d = X.shape
    d_pad = _round_up(max(d, 1), 128)
    bn = min(block_n, _round_up(n, 8))
    n_pad = _round_up(n, bn)

    Xp = jnp.zeros((n_pad, d_pad), X.dtype).at[:n, :d].set(X)
    Mp = jnp.zeros((d_pad, d_pad), jnp.float32).at[:d, :d].set(M.astype(jnp.float32))

    out = pl.pallas_call(
        _kernel,
        grid=(n_pad // bn,),
        in_specs=[
            pl.BlockSpec((bn, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((d_pad, d_pad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        interpret=interpret,
    )(Xp, Mp)
    return out[:n]
