"""Public jit'd wrappers over the Pallas kernels.

On a TPU backend the kernels compile natively; on CPU (this container) they
execute under ``interpret=True`` — the kernel bodies run in Python with the
exact same tiling/masking logic, which is what the allclose tests validate
against the ``ref.py`` oracles.

Set ``REPRO_NO_PALLAS=1`` to route everything to the jnp references (used to
A/B the kernels and as an escape hatch inside traced code where pallas
interpret mode would be too slow, e.g. hypothesis sweeps with huge n).
"""

from __future__ import annotations

import os
from typing import Optional, Tuple

import jax

from repro.kernels import kmeans_assign as _ka
from repro.kernels import kmeans_assign_update as _kau
from repro.kernels import leverage as _lev
from repro.kernels import ref
from repro.kernels import weighted_gram as _wg


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _disabled() -> bool:
    return os.environ.get("REPRO_NO_PALLAS", "0") == "1"


def kmeans_assign(X: jax.Array, C: jax.Array, *, block_n: int = 256) -> Tuple[jax.Array, jax.Array]:
    if _disabled():
        return ref.kmeans_assign(X, C)
    return _ka.kmeans_assign(X, C, block_n=block_n, interpret=_interpret())


def kmeans_assign_update(
    X: jax.Array, C: jax.Array, w: Optional[jax.Array] = None, *, block_n: int = 256
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused single-pass (assign, d2, csum, wsum, ccost) — ONE read of X.

    The ``REPRO_NO_PALLAS`` escape hatch routes to the assignment +
    segment-sum composition (the seed's 3-pass Lloyd data flow), which is
    also the semantic oracle the fused kernel is tested against.
    """
    if _disabled():
        return ref.kmeans_assign_update(X, C, w)
    return _kau.kmeans_assign_update(X, C, w, block_n=block_n, interpret=_interpret())


def leverage(X: jax.Array, M: jax.Array, *, block_n: int = 512) -> jax.Array:
    if _disabled():
        return ref.leverage(X, M)
    return _lev.leverage(X, M, block_n=block_n, interpret=_interpret())


def weighted_gram(X: jax.Array, w: jax.Array, *, block_n: int = 512) -> jax.Array:
    if _disabled():
        return ref.weighted_gram(X, w)
    return _wg.weighted_gram(X, w, block_n=block_n, interpret=_interpret())
