"""Pallas TPU kernel: weighted Gram accumulation G = X^T diag(w) X.

The coreset-side ridge solve (Theorem 2.5's downstream scheme A) reduces to
normal equations over the *weighted* coreset; at full-data scale the same
primitive builds each party's local Gram for leverage scoring.  The kernel
streams X through VMEM in (bn, d) tiles and accumulates the (d, d) output
block in place across the grid — a classic TPU reduction pattern (the output
BlockSpec maps every grid step to the same block, initialised at step 0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)                     # (bn, d_pad)
    w = w_ref[...].astype(jnp.float32)                     # (bn, 1)
    xw = x * w                                             # VPU broadcast
    out_ref[...] += jax.lax.dot_general(
        xw, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                      # MXU (d, d) update


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def weighted_gram(
    X: jax.Array,
    w: jax.Array,
    *,
    block_n: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """X: (n, d); w: (n,) -> (d, d) float32 = X^T diag(w) X.

    Leading batch dimensions (X (..., n, d), w (..., n)) fold into the grid
    via the native pallas_call batching rule — the streaming Gram block-scan
    uses this with both operands batched over the party axis.
    """
    if X.ndim > 2 or w.ndim > 1:
        return jax.vmap(
            lambda x, ww: weighted_gram(x, ww, block_n=block_n,
                                        interpret=interpret),
            in_axes=(0 if X.ndim > 2 else None, 0 if w.ndim > 1 else None),
        )(X, w)
    n, d = X.shape
    d_pad = _round_up(max(d, 1), 128)
    bn = min(block_n, _round_up(n, 8))
    n_pad = _round_up(n, bn)

    Xp = jnp.zeros((n_pad, d_pad), X.dtype).at[:n, :d].set(X)
    wp = jnp.zeros((n_pad, 1), jnp.float32).at[:n, 0].set(w.astype(jnp.float32))

    out = pl.pallas_call(
        _kernel,
        grid=(n_pad // bn,),
        in_specs=[
            pl.BlockSpec((bn, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((d_pad, d_pad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d_pad, d_pad), jnp.float32),
        interpret=interpret,
    )(Xp, wp)
    return out[:d, :d]
