"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth; kernel tests sweep shapes/dtypes
and ``assert_allclose`` the Pallas output (interpret mode on CPU) against
these.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def kmeans_assign(X: jax.Array, C: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(argmin_l ||x_i - c_l||^2, min_l ||x_i - c_l||^2).

    X: (n, d) float; C: (k, d) float.  Returns (int32 (n,), float32 (n,)).
    """
    x2 = jnp.sum(X.astype(jnp.float32) ** 2, axis=1, keepdims=True)        # (n, 1)
    c2 = jnp.sum(C.astype(jnp.float32) ** 2, axis=1)[None, :]              # (1, k)
    xc = X.astype(jnp.float32) @ C.astype(jnp.float32).T                   # (n, k)
    d2 = jnp.maximum(x2 + c2 - 2.0 * xc, 0.0)
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)


def leverage(X: jax.Array, M: jax.Array) -> jax.Array:
    """Row-wise quadratic form x_i^T M x_i.  X: (n, d); M: (d, d) symmetric."""
    Xf = X.astype(jnp.float32)
    Mf = M.astype(jnp.float32)
    return jnp.einsum("nd,de,ne->n", Xf, Mf, Xf)


def weighted_gram(X: jax.Array, w: jax.Array) -> jax.Array:
    """X^T diag(w) X.  X: (n, d); w: (n,).  Returns (d, d) float32."""
    Xf = X.astype(jnp.float32)
    return (Xf * w.astype(jnp.float32)[:, None]).T @ Xf
