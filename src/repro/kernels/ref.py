"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth; kernel tests sweep shapes/dtypes
and ``assert_allclose`` the Pallas output (interpret mode on CPU) against
these.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _batched(fn, *args, axes):
    """vmap ``fn`` over axis 0 of the args whose entry in ``axes`` is 0 —
    the oracles mirror the kernels' leading-batch-dim support, with the
    2-D path left bit-identical."""
    return jax.vmap(fn, in_axes=axes)(*args)


def kmeans_assign(X: jax.Array, C: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(argmin_l ||x_i - c_l||^2, min_l ||x_i - c_l||^2).

    X: (n, d) float; C: (k, d) float.  Returns (int32 (n,), float32 (n,)).
    Leading batch dims on either operand vmap through.
    """
    if X.ndim > 2 or C.ndim > 2:
        return _batched(kmeans_assign, X, C,
                        axes=(0 if X.ndim > 2 else None,
                              0 if C.ndim > 2 else None))
    x2 = jnp.sum(X.astype(jnp.float32) ** 2, axis=1, keepdims=True)        # (n, 1)
    c2 = jnp.sum(C.astype(jnp.float32) ** 2, axis=1)[None, :]              # (1, k)
    xc = X.astype(jnp.float32) @ C.astype(jnp.float32).T                   # (n, k)
    d2 = jnp.maximum(x2 + c2 - 2.0 * xc, 0.0)
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)


def kmeans_assign_update(
    X: jax.Array, C: jax.Array, w: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """The fused kernel's semantic ground truth: assignment followed by the
    seed path's segment-sum composition.

    Returns (assign (n,) i32, d2 (n,) f32, csum (k, d) f32 = sum_i w_i x_i,
    wsum (k,) f32 = sum_i w_i, ccost (k,) f32 = sum_i w_i d2_i), grouped by
    assigned cluster.  With ``w=None`` weights default to ones, so wsum is
    the cluster size and ccost the cluster cost of Algorithm 3.
    """
    if X.ndim > 2 or C.ndim > 2 or (w is not None and w.ndim > 1):
        if w is None:
            return _batched(lambda x, c: kmeans_assign_update(x, c), X, C,
                            axes=(0 if X.ndim > 2 else None,
                                  0 if C.ndim > 2 else None))
        return _batched(kmeans_assign_update, X, C, w,
                        axes=(0 if X.ndim > 2 else None,
                              0 if C.ndim > 2 else None,
                              0 if w.ndim > 1 else None))
    n = X.shape[0]
    k = C.shape[0]
    assign, d2 = kmeans_assign(X, C)
    ww = jnp.ones((n,), jnp.float32) if w is None else w.astype(jnp.float32)
    wsum = jax.ops.segment_sum(ww, assign, num_segments=k)
    csum = jax.ops.segment_sum(
        ww[:, None] * X.astype(jnp.float32), assign, num_segments=k)
    ccost = jax.ops.segment_sum(ww * d2, assign, num_segments=k)
    return assign, d2, csum, wsum, ccost


def leverage(X: jax.Array, M: jax.Array) -> jax.Array:
    """Row-wise quadratic form x_i^T M x_i.  X: (n, d); M: (d, d) symmetric.
    Leading batch dims on either operand vmap through."""
    if X.ndim > 2 or M.ndim > 2:
        return _batched(leverage, X, M,
                        axes=(0 if X.ndim > 2 else None,
                              0 if M.ndim > 2 else None))
    Xf = X.astype(jnp.float32)
    Mf = M.astype(jnp.float32)
    return jnp.einsum("nd,de,ne->n", Xf, Mf, Xf)


def weighted_gram(X: jax.Array, w: jax.Array) -> jax.Array:
    """X^T diag(w) X.  X: (n, d); w: (n,).  Returns (d, d) float32."""
    if X.ndim > 2 or w.ndim > 1:
        return _batched(weighted_gram, X, w,
                        axes=(0 if X.ndim > 2 else None,
                              0 if w.ndim > 1 else None))
    Xf = X.astype(jnp.float32)
    return (Xf * w.astype(jnp.float32)[:, None]).T @ Xf
