"""Pallas TPU kernel: fused single-pass k-means assign + cluster update.

One Lloyd iteration of the seed path is three separate passes over the
data: the ``kmeans_assign`` kernel (distances + argmin, one X-sized HBM
read) and two ``segment_sum`` scatters — the coordinate-sum scatter
streams X again (a second X-sized read, plus its (n, d) weighted temp),
the weight-sum scatter streams the (n,) weights.  This kernel collapses
all of it to exactly ONE pass over X: in the same VMEM residency that
computes each (bn, d) tile's distances it also accumulates, into VMEM
scratch carried across the sequential grid,

  * ``csum``  (k, d) — per-cluster weighted coordinate sums  sum_i w_i x_i,
  * ``wsum``  (k,)   — per-cluster weight mass               sum_i w_i,
  * ``ccost`` (k,)   — per-cluster weighted cost             sum_i w_i d2_i,

and flushes the accumulators to the outputs on the last grid step.  With
unit weights ``wsum``/``ccost`` are the cluster sizes and costs Algorithm 3
(VKMC sensitivities) needs — so the scoring pass gets them for free from
the assignment read.

The per-tile cluster reduction is a one-hot matmul on the MXU:
``csum += (w * onehot(assign))^T @ x`` — a (bn, k) x (bn, d) contraction,
the transpose-side twin of the distance matmul, so arithmetic intensity
stays ~2k MAC/byte while X-sized HBM reads drop from 2 to 1 (and the
n-sized weight scatter disappears entirely).

Leading batch dimensions (stacked parties, multi-seed grids) fold into the
grid through jax.vmap's native pallas_call batching rule — the batch
becomes a new leading grid axis; unbatched operands are NOT broadcast, and
the scratch accumulators re-initialise per batch step because the i == 0 /
i == nb-1 conditions are evaluated on the original (remapped) grid axis.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    x_ref, c_ref, cn_ref, w_ref,
    assign_ref, d2_ref, csum_ref, wsum_ref, ccost_ref,
    acc_ref, stat_ref,
    *, k: int, nb: int,
):
    """One grid step: assign a (bn, d_pad) tile and fold it into the scratch
    accumulators; flush scratch -> outputs on the last step.

    x_ref:   (bn, d_pad) points tile             (VMEM)
    c_ref:   (k_pad, d_pad) all centers          (VMEM, same block every step)
    cn_ref:  (1, k_pad) precomputed ||c||^2      (VMEM)
    w_ref:   (bn, 1) per-point weights           (VMEM; 0 on padded rows)
    assign_ref: (bn,) int32 out
    d2_ref:  (bn,) float32 out
    csum_ref:  (k_pad, d_pad) out                (written on last step)
    wsum_ref:  (k_pad,) out                      (written on last step)
    ccost_ref: (k_pad,) out                      (written on last step)
    acc_ref:  (k_pad, d_pad) VMEM scratch — csum accumulator
    stat_ref: (2, k_pad) VMEM scratch — [wsum; ccost] accumulators
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        stat_ref[...] = jnp.zeros_like(stat_ref)

    x = x_ref[...].astype(jnp.float32)                         # (bn, d_pad)
    c = c_ref[...].astype(jnp.float32)                         # (k_pad, d_pad)
    w = w_ref[...].astype(jnp.float32)                         # (bn, 1)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)                 # (bn, 1)
    # MXU: (bn, d) @ (d, k_pad) — same distance tile as kmeans_assign
    xc = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                          # (bn, k_pad)
    d2 = x2 + cn_ref[...] - 2.0 * xc
    col = jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    d2 = jnp.where(col < k, d2, jnp.inf)                       # mask padding
    assign = jnp.argmin(d2, axis=1).astype(jnp.int32)
    d2min = jnp.maximum(jnp.min(d2, axis=1), 0.0)
    assign_ref[...] = assign
    d2_ref[...] = d2min

    # weighted one-hot fold: wh[i, l] = w_i * [assign_i == l]
    wh = jnp.where(col == assign[:, None], w, 0.0)             # (bn, k_pad)
    # MXU: (k_pad, bn) @ (bn, d_pad) — per-cluster coordinate sums
    acc_ref[...] += jax.lax.dot_general(
        wh, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    stat_ref[0, :] += jnp.sum(wh, axis=0)
    stat_ref[1, :] += jnp.sum(wh * d2min[:, None], axis=0)

    @pl.when(i == nb - 1)
    def _flush():
        csum_ref[...] = acc_ref[...]
        wsum_ref[...] = stat_ref[0, :]
        ccost_ref[...] = stat_ref[1, :]


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign_update(
    X: jax.Array,
    C: jax.Array,
    w: Optional[jax.Array] = None,
    *,
    block_n: int = 256,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused single-pass assign + cluster update.

    X: (n, d); C: (k, d); w: optional (n,) weights (defaults to ones).
    Returns (assign int32 (n,), d2 f32 (n,), csum f32 (k, d),
    wsum f32 (k,), ccost f32 (k,)).

    Leading batch dimensions on any operand vmap into the grid:
    X (..., n, d) / C (..., k, d) / w (..., n) -> batched outputs.
    """
    if X.ndim > 2 or C.ndim > 2 or (w is not None and w.ndim > 1):
        xa = 0 if X.ndim > 2 else None
        ca = 0 if C.ndim > 2 else None
        wa = 0 if (w is not None and w.ndim > 1) else None
        if w is None:
            return jax.vmap(
                lambda x, c: kmeans_assign_update(
                    x, c, block_n=block_n, interpret=interpret),
                in_axes=(xa, ca),
            )(X, C)
        return jax.vmap(
            lambda x, c, ww: kmeans_assign_update(
                x, c, ww, block_n=block_n, interpret=interpret),
            in_axes=(xa, ca, wa),
        )(X, C, w)

    n, d = X.shape
    k = C.shape[0]
    d_pad = _round_up(max(d, 1), 128)
    k_pad = _round_up(max(k, 1), 128)
    bn = min(block_n, _round_up(n, 8))
    n_pad = _round_up(n, bn)
    nb = n_pad // bn

    Xp = jnp.zeros((n_pad, d_pad), X.dtype).at[:n, :d].set(X)
    Cp = jnp.zeros((k_pad, d_pad), C.dtype).at[:k, :d].set(C)
    cn = jnp.sum(Cp.astype(jnp.float32) ** 2, axis=1)[None, :]   # (1, k_pad)
    # zero weights on padded rows mask them out of every accumulator
    wn = jnp.ones((n,), jnp.float32) if w is None else w.astype(jnp.float32)
    wp = jnp.zeros((n_pad, 1), jnp.float32).at[:n, 0].set(wn)

    assign, d2, csum, wsum, ccost = pl.pallas_call(
        functools.partial(_kernel, k=k, nb=nb),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bn, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((k_pad, d_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, k_pad), lambda i: (0, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((k_pad, d_pad), lambda i: (0, 0)),
            pl.BlockSpec((k_pad,), lambda i: (0,)),
            pl.BlockSpec((k_pad,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((k_pad, d_pad), jnp.float32),
            jax.ShapeDtypeStruct((k_pad,), jnp.float32),
            jax.ShapeDtypeStruct((k_pad,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((k_pad, d_pad), jnp.float32),
            pltpu.VMEM((2, k_pad), jnp.float32),
        ],
        interpret=interpret,
    )(Xp, Cp, cn, wp)
    return assign[:n], d2[:n], csum[:k, :d], wsum[:k], ccost[:k]
