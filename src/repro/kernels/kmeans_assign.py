"""Pallas TPU kernel: blocked k-means assignment (distance + argmin).

This is the O(n*k*d) hot loop of Algorithm 3 (VKMC sensitivities) and of the
Lloyd/k-means++ solvers — by far the dominant FLOP cost of the paper's
clustering pipeline at scale.

TPU-native design (vs. the usual CUDA one-thread-per-point port):
  * the (bn, d) x (d, k) distance cross-term runs on the MXU as a single
    matmul per tile — tiles are chosen as multiples of (8, 128) so the
    systolic array is fully fed;
  * points are tiled over the grid's only axis; the full center block
    (k_pad, d_pad) stays resident in VMEM across the sweep (centers are tiny:
    k <= O(1e3)), so HBM traffic is exactly one read of X — the kernel is
    memory-bound at roofline, arithmetic intensity ~ k MAC/byte;
  * min + argmin are computed in-register on the (bn, k_pad) distance tile;
    padded center columns are masked to +inf.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, c_ref, cn_ref, assign_ref, d2_ref, *, k: int):
    """One grid step: assign a (bn, d_pad) tile of points.

    x_ref:  (bn, d_pad) points tile            (VMEM)
    c_ref:  (k_pad, d_pad) all centers         (VMEM, same block every step)
    cn_ref: (1, k_pad) precomputed ||c||^2     (VMEM)
    assign_ref: (bn,) int32 out
    d2_ref: (bn,) float32 out
    """
    x = x_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)                 # (bn, 1)
    # MXU: (bn, d) @ (d, k_pad)
    xc = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                          # (bn, k_pad)
    d2 = x2 + cn_ref[...] - 2.0 * xc
    k_pad = d2.shape[1]
    col = jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1)
    d2 = jnp.where(col < k, d2, jnp.inf)                       # mask padding
    assign_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)
    d2_ref[...] = jnp.maximum(jnp.min(d2, axis=1), 0.0)


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign(
    X: jax.Array,
    C: jax.Array,
    *,
    block_n: int = 256,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Blocked assignment.  X: (n, d); C: (k, d) -> (assign int32 (n,), d2 f32 (n,)).

    Leading batch dimensions on either operand (X (..., n, d), C (..., k, d))
    fold into the grid via the native pallas_call batching rule — one
    dispatch, no broadcast of the unbatched operand.
    """
    if X.ndim > 2 or C.ndim > 2:
        return jax.vmap(
            lambda x, c: kmeans_assign(x, c, block_n=block_n, interpret=interpret),
            in_axes=(0 if X.ndim > 2 else None, 0 if C.ndim > 2 else None),
        )(X, C)
    n, d = X.shape
    k = C.shape[0]
    # MXU/VPU alignment: lanes = 128, sublanes = 8.
    d_pad = _round_up(max(d, 1), 128)
    k_pad = _round_up(max(k, 1), 128)
    bn = min(block_n, _round_up(n, 8))
    n_pad = _round_up(n, bn)

    Xp = jnp.zeros((n_pad, d_pad), X.dtype).at[:n, :d].set(X)
    Cp = jnp.zeros((k_pad, d_pad), C.dtype).at[:k, :d].set(C)
    cn = jnp.sum(Cp.astype(jnp.float32) ** 2, axis=1)[None, :]  # (1, k_pad)

    grid = (n_pad // bn,)
    assign, d2 = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((k_pad, d_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, k_pad), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        ],
        interpret=interpret,
    )(Xp, Cp, cn)
    return assign[:n], d2[:n]
