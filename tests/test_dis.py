"""Algorithm 1 (DIS): marginal correctness, weights, communication bounds."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.comm import CommLedger, theoretical_dis_cost
from repro.core.dis import dis_marginals, dis_sample, uniform_sample


def _scores(key, n, T):
    keys = jax.random.split(key, T)
    return [jax.random.uniform(k, (n,), minval=0.0, maxval=1.0) for k in keys]


def test_dis_shapes_and_weights():
    n, T, m = 500, 3, 100
    scores = _scores(jax.random.PRNGKey(0), n, T)
    S, w = dis_sample(jax.random.PRNGKey(1), scores, m)
    assert S.shape == (m,) and w.shape == (m,)
    assert bool(jnp.all(S >= 0)) and bool(jnp.all(S < n))
    # w(i) = G / (m * g_i)
    g = jnp.sum(jnp.stack(scores), axis=0)
    G = g.sum()
    np.testing.assert_allclose(np.asarray(w), np.asarray(G / (m * g[S])), rtol=1e-5)


def test_dis_comm_within_theoretical_bounds():
    n, T, m = 300, 4, 64
    led = CommLedger()
    dis_sample(jax.random.PRNGKey(0), _scores(jax.random.PRNGKey(2), n, T), m, led)
    lo, hi = theoretical_dis_cost(m, T)
    assert lo <= led.total <= hi, (led.total, lo, hi)


def test_dis_marginals_match_empirically():
    """The induced sampling marginal equals g_i/G (proof of Thm 3.1)."""
    n, T, m = 20, 3, 20000
    scores = _scores(jax.random.PRNGKey(3), n, T)
    probs = np.asarray(dis_marginals(scores))
    S, _ = dis_sample(jax.random.PRNGKey(4), scores, m)
    emp = np.bincount(np.asarray(S), minlength=n) / m
    # chi-square-ish: each cell within 5 sigma
    sigma = np.sqrt(probs * (1 - probs) / m)
    assert np.all(np.abs(emp - probs) < 5 * sigma + 1e-3)


def test_dis_unbiased_sum_estimator():
    """E[sum_{i in S} w_i f_i] = sum_i f_i — the coreset estimator core."""
    n, T, m = 100, 2, 4000
    scores = _scores(jax.random.PRNGKey(5), n, T)
    f = np.asarray(jax.random.uniform(jax.random.PRNGKey(6), (n,)))
    S, w = dis_sample(jax.random.PRNGKey(7), scores, m)
    est = float(np.sum(np.asarray(w) * f[np.asarray(S)]))
    true = float(f.sum())
    assert abs(est - true) / true < 0.1


def test_uniform_sample_weights():
    led = CommLedger()
    S, w = uniform_sample(jax.random.PRNGKey(0), 1000, 50, 3, led)
    assert np.allclose(np.asarray(w), 1000 / 50)
    assert led.total == 50 * 3        # broadcast only


def test_dis_rejects_zero_scores():
    with pytest.raises(ValueError):
        dis_sample(jax.random.PRNGKey(0), [jnp.zeros((10,))], 5)
