"""Downstream solve layer: fit_ridge / fit_kmeans / evaluate / end_to_end.

Pins the solve-layer acceptance criteria:
  * ``fit_ridge`` / ``fit_kmeans`` on the IDENTITY coreset (budget = n,
    weight 1) match the full-data solve to fp tolerance;
  * ``evaluate`` returns the paper's relative-error ratio and is ~0 for the
    identity coreset, small for a real coreset at a healthy budget;
  * ``end_to_end`` composes spec -> build -> fit -> evaluate, with the
    Theorem 2.5 ledger composition available throughout.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CommLedger,
    CoresetSpec,
    VFLDataset,
    end_to_end,
    evaluate,
    fit_kmeans,
    fit_ridge,
    full_data_coreset,
    ridge_closed_form,
    solver_for,
)
from repro.core.vkmc import kmeans


def _dataset(key, n=2000, d=12, T=3):
    kx, kt, kn = jax.random.split(key, 3)
    X = jax.random.normal(kx, (n, d))
    theta = jax.random.normal(kt, (d,))
    y = X @ theta + 0.1 * jax.random.normal(kn, (n,))
    return VFLDataset.from_dense(X, y, T=T)


def test_fit_ridge_identity_matches_full_solve():
    ds = _dataset(jax.random.PRNGKey(0))
    lam = 0.1 * ds.n
    fit = fit_ridge(ds, full_data_coreset(ds), lam)
    theta_full = ridge_closed_form(ds.full(), ds.y, lam)
    np.testing.assert_allclose(np.asarray(fit.params),
                               np.asarray(theta_full), rtol=1e-5, atol=1e-6)
    rep = evaluate(ds, fit)
    assert abs(rep.rel_error) < 1e-5
    assert rep.m == ds.n and rep.comm_units == 0


def test_fit_kmeans_identity_matches_full_solve():
    ds = _dataset(jax.random.PRNGKey(1), n=800)
    k, key = 4, jax.random.PRNGKey(2)
    fit = fit_kmeans(ds, full_data_coreset(ds), k, key=key)
    # restart r=0 seeds with fold_in(key, 0) on the full rows, unit weights
    direct = kmeans(jax.random.fold_in(key, 0), ds.full(), k,
                    jnp.ones((ds.n,)), use_kernel=False)
    np.testing.assert_allclose(np.asarray(fit.params), np.asarray(direct),
                               rtol=1e-5, atol=1e-5)
    rep = evaluate(ds, fit, key=key)
    assert abs(rep.rel_error) < 1e-6        # same key chain -> same baseline


def test_evaluate_real_coreset_small_error():
    ds = _dataset(jax.random.PRNGKey(3), n=4000)
    lam = 0.1 * ds.n
    cs, fit, rep = end_to_end(CoresetSpec(task="vrlr", budgets=1000), ds,
                              key=jax.random.PRNGKey(4), lam=lam)
    assert cs.m == 1000 and fit.task == "ridge"
    assert -1e-6 <= rep.rel_error < 0.25    # closed form: >= optimum, close
    assert rep.cost_opt > 0 and rep.n == ds.n


def test_end_to_end_kmeans_leg():
    ds = _dataset(jax.random.PRNGKey(5), n=1500)
    cs, fit, rep = end_to_end(
        CoresetSpec(task="vkmc", budgets=500, params={"k": 4}), ds,
        key=jax.random.PRNGKey(6), k=4, restarts=2)
    assert fit.task == "kmeans" and fit.params.shape == (4, ds.d)
    assert rep.rel_error < 0.5              # heuristic; may be mildly < 0


def test_end_to_end_validates_solver_choice():
    ds = _dataset(jax.random.PRNGKey(7), n=300)
    with pytest.raises(ValueError, match="exactly one"):
        end_to_end("vrlr", ds, key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="exactly one"):
        end_to_end("vrlr", ds, key=jax.random.PRNGKey(0), lam=1.0, k=3)
    with pytest.raises(ValueError, match="grid"):
        end_to_end(CoresetSpec(task="vrlr", budgets=(10, 20)), ds,
                   key=jax.random.PRNGKey(0), lam=1.0)


def test_fit_ledger_composition():
    """fit_* records Theorem 2.5's +2mT materialization on the ledger."""
    ds = _dataset(jax.random.PRNGKey(8), n=600)
    led = CommLedger()
    cs, _, _ = end_to_end(CoresetSpec(task="vrlr", budgets=50), ds,
                          key=jax.random.PRNGKey(9), lam=10.0, ledger=led)
    assert led.total == cs.comm_units + 2 * 50 * ds.T
    assert led.by_prefix("materialize/") == 2 * 50 * ds.T


def test_fit_validation_errors():
    ds = _dataset(jax.random.PRNGKey(10), n=300)
    unlabeled = VFLDataset(ds.parts, None)
    with pytest.raises(ValueError, match="labels"):
        fit_ridge(unlabeled, full_data_coreset(unlabeled), 1.0)
    with pytest.raises(ValueError, match="restarts"):
        fit_kmeans(ds, full_data_coreset(ds), 3, key=jax.random.PRNGKey(0),
                   restarts=0)
    fit = fit_kmeans(ds, full_data_coreset(ds), 3, key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="key"):
        evaluate(ds, fit)                   # k-means baseline needs a key


def test_solver_for_mapping():
    assert solver_for("vrlr") == "ridge"
    assert solver_for("vkmc") == "kmeans"
    assert solver_for("uniform") is None


def test_uniform_coreset_through_solve_layer():
    """The U-* baseline composes with both solvers (the paper's U-CENTRAL /
    U-KMEANS++ columns)."""
    ds = _dataset(jax.random.PRNGKey(11), n=2000)
    lam = 0.1 * ds.n
    cs, fit, rep = end_to_end(CoresetSpec(task="uniform", budgets=800), ds,
                              key=jax.random.PRNGKey(12), lam=lam)
    assert cs.comm_units == 800 * ds.T      # broadcast-only bill
    assert rep.rel_error < 0.5
