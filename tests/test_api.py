"""Unified CoresetPipeline API: registry, pure DIS core, shims, batching.

Covers the api_redesign acceptance criteria:
  * task-registry round-trip;
  * `dis_plan` is bit-identical to a verbatim transcription of the seed's
    host-loop `dis_sample` for the same PRNG key;
  * the deprecated builder shims match `build_coreset` exactly, with the
    seed's exact ledger totals (and per-party round-2 attribution);
  * `jax.jit(dis_plan)` traces cleanly (no ledger side effects);
  * `build_coresets_batched` (vmap over seeds x budget grid) matches a
    Python loop of sequential builds;
  * `Coreset.materialize(ds, ledger)` accounts Theorem 2.5's +2mT term.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.core import (
    CORESET_TASKS,
    CommLedger,
    CommSchedule,
    VFLDataset,
    build_coreset,
    build_coresets_batched,
    get_task,
    theoretical_dis_cost,
)
from repro.core.api import CoresetTask, register_task
from repro.core.dis import dis_plan, dis_plan_full, server_plan
from repro.core.selector import sample_coreset


def _dataset(key, n=1200, d=12, T=3):
    kx, kt, kn = jax.random.split(key, 3)
    X = jax.random.normal(kx, (n, d))
    theta = jax.random.normal(kt, (d,))
    y = X @ theta + 0.1 * jax.random.normal(kn, (n,))
    return VFLDataset.from_dense(X, y, T=T)


def _scores(key, n, T):
    keys = jax.random.split(key, T)
    return [jax.random.uniform(k, (n,)) + 1e-3 for k in keys]


def _seed_dis_sample(key, local_scores, m):
    """Verbatim transcription of the seed repo's host-loop dis_sample
    (ledger calls elided) — the bit-identity oracle."""
    scores = [jnp.asarray(g, jnp.float32) for g in local_scores]
    T = len(scores)
    G_j = jnp.stack([g.sum() for g in scores])
    G = G_j.sum()
    key, sub = jax.random.split(key)
    draws = jax.random.categorical(sub, jnp.log(jnp.maximum(G_j, 1e-30)), shape=(m,))
    a = jnp.bincount(draws, length=T)
    per = []
    for j in range(T):
        key, sub = jax.random.split(key)
        per.append(jax.random.categorical(
            sub, jnp.log(jnp.maximum(scores[j], 1e-30)), shape=(m,)))
    cand = jnp.stack(per)
    take = jnp.arange(m)[None, :] < a[:, None]
    order = jnp.argsort(~take.reshape(-1), stable=True)
    S = cand.reshape(-1)[order][:m]
    g_sum = jnp.zeros((m,), scores[0].dtype)
    for j in range(T):
        g_sum = g_sum + scores[j][S]
    w = G / (m * jnp.maximum(g_sum, 1e-30))
    return S, w, a


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

def test_registry_roundtrip():
    assert {"vrlr", "vkmc", "uniform"} <= set(CORESET_TASKS.keys())
    spec = get_task("vrlr")
    assert isinstance(spec, CoresetTask)
    assert spec.name == "vrlr" and spec.needs_labels
    assert get_task(spec) is spec                      # pass-through
    assert get_task("vkmc").deterministic_scores is False
    assert get_task("uniform").score_fn is None
    with pytest.raises(KeyError):
        get_task("no-such-task")


def test_registry_rejects_duplicates():
    with pytest.raises(KeyError):
        register_task("vrlr")(lambda key, ds, backend: None)


def test_unknown_backend_rejected():
    ds = _dataset(jax.random.PRNGKey(0), n=200)
    with pytest.raises(ValueError):
        build_coreset("vrlr", ds, 20, key=jax.random.PRNGKey(1), backend="bogus")


# --------------------------------------------------------------------------
# Pure DIS core: seed bit-identity + jit/vmap compatibility
# --------------------------------------------------------------------------

def test_dis_plan_bit_identical_to_seed_reference():
    for trial in range(5):
        n, T, m = 300 + 17 * trial, trial % 3 + 1, 64 + trial
        scores = _scores(jax.random.PRNGKey(100 + trial), n, T)
        key = jax.random.PRNGKey(trial)
        S0, w0, a0 = _seed_dis_sample(key, scores, m)
        plan = dis_plan_full(key, jnp.stack(scores), m)
        np.testing.assert_array_equal(np.asarray(S0), np.asarray(plan.indices))
        np.testing.assert_array_equal(np.asarray(w0), np.asarray(plan.weights))
        np.testing.assert_array_equal(np.asarray(a0), np.asarray(plan.counts))


def test_dis_plan_jits_cleanly():
    n, T, m = 400, 3, 50
    scores = jnp.stack(_scores(jax.random.PRNGKey(0), n, T))
    key = jax.random.PRNGKey(1)
    S_e, w_e = dis_plan(key, scores, m)
    S_j, w_j = jax.jit(dis_plan, static_argnums=2)(key, scores, m)
    np.testing.assert_array_equal(np.asarray(S_e), np.asarray(S_j))
    np.testing.assert_allclose(np.asarray(w_e), np.asarray(w_j), rtol=1e-6)


def test_dis_plan_vmaps_over_seeds():
    n, T, m = 250, 2, 40
    scores = jnp.stack(_scores(jax.random.PRNGKey(2), n, T))
    keys = jax.random.split(jax.random.PRNGKey(3), 5)
    Sv, wv = jax.vmap(lambda k: dis_plan(k, scores, m))(keys)
    assert Sv.shape == (5, m) and wv.shape == (5, m)
    for i, k in enumerate(keys):
        S_i, w_i = dis_plan(k, scores, m)
        np.testing.assert_array_equal(np.asarray(Sv[i]), np.asarray(S_i))


# --------------------------------------------------------------------------
# Shims: bit-identical (S, w), seed-exact ledger totals, fixed attribution
# --------------------------------------------------------------------------

def test_vrlr_shim_bit_identical_with_seed_ledger_total():
    ds = _dataset(jax.random.PRNGKey(4))
    m, T = 150, ds.T
    led_old, led_new = CommLedger(), CommLedger()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        cs_old = core.build_vrlr_coreset(jax.random.PRNGKey(5), ds, m, ledger=led_old)
    cs_new = build_coreset("vrlr", ds, m, key=jax.random.PRNGKey(5), ledger=led_new)
    np.testing.assert_array_equal(np.asarray(cs_old.indices), np.asarray(cs_new.indices))
    np.testing.assert_array_equal(np.asarray(cs_old.weights), np.asarray(cs_new.weights))
    # the seed's exact bill: 2T (round 1) + m (round 2 up) + 2mT (bcast + round 3)
    assert led_old.total == led_new.total == 2 * T + m + 2 * m * T
    tags = led_new.by_tag()
    assert tags["dis/round1/G_j"] == T and tags["dis/round1/a_j"] == T
    assert tags["dis/round2/S_up"] == m
    assert tags["dis/round2/S_bcast"] == m * T
    assert tags["dis/round3/g_scores"] == m * T


def test_round2_upload_attributed_per_party():
    """The m index uploads are split across parties by the realised a_j —
    not lumped onto party 0 as in the seed."""
    ds = _dataset(jax.random.PRNGKey(6), n=2000)
    led = CommLedger()
    build_coreset("vrlr", ds, 300, key=jax.random.PRNGKey(7), ledger=led)
    ups = {msg.src: msg.units for msg in led.messages
           if msg.tag == "dis/round2/S_up"}
    assert sum(ups.values()) == 300
    # with n=2000 rows and near-even leverage mass, every party sends some
    assert all(u > 0 for u in ups.values()) and len(ups) == ds.T


def test_vkmc_shim_bit_identical():
    ds = _dataset(jax.random.PRNGKey(8))
    m, k = 120, 4
    led_old, led_new = CommLedger(), CommLedger()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        cs_old = core.build_vkmc_coreset(jax.random.PRNGKey(9), ds, k=k, m=m,
                                         ledger=led_old)
    cs_new = build_coreset("vkmc", ds, m, key=jax.random.PRNGKey(9), k=k,
                           ledger=led_new)
    np.testing.assert_array_equal(np.asarray(cs_old.indices), np.asarray(cs_new.indices))
    np.testing.assert_array_equal(np.asarray(cs_old.weights), np.asarray(cs_new.weights))
    assert led_old.total == led_new.total


def test_uniform_shim_bit_identical():
    ds = _dataset(jax.random.PRNGKey(10))
    m = 80
    led = CommLedger()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        cs_old = core.build_uniform_coreset(jax.random.PRNGKey(11), ds, m)
    cs_new = build_coreset("uniform", ds, m, key=jax.random.PRNGKey(11), ledger=led)
    np.testing.assert_array_equal(np.asarray(cs_old.indices), np.asarray(cs_new.indices))
    np.testing.assert_array_equal(np.asarray(cs_old.weights), np.asarray(cs_new.weights))
    assert led.total == m * ds.T                        # broadcast only


def test_build_coreset_requires_labels_for_vrlr():
    ds = _dataset(jax.random.PRNGKey(12), n=100)
    ds_unlabeled = VFLDataset(ds.parts, None)
    with pytest.raises(ValueError):
        build_coreset("vrlr", ds_unlabeled, 10, key=jax.random.PRNGKey(0))


# --------------------------------------------------------------------------
# Batched multi-seed / multi-budget construction
# --------------------------------------------------------------------------

def test_batched_vrlr_matches_python_loop_exactly():
    ds = _dataset(jax.random.PRNGKey(13))
    m = 100
    keys = jax.random.split(jax.random.PRNGKey(14), 4)
    grid = build_coresets_batched("vrlr", ds, [m], keys=keys, backend="ref")
    for r in range(4):
        seq = build_coreset("vrlr", ds, m, key=keys[r], backend="ref")
        cell = grid.coreset(r, 0)
        np.testing.assert_array_equal(np.asarray(cell.indices), np.asarray(seq.indices))
        np.testing.assert_array_equal(np.asarray(cell.weights), np.asarray(seq.weights))
        assert cell.comm_units == seq.comm_units


def test_batched_vkmc_matches_python_loop():
    ds = _dataset(jax.random.PRNGKey(15))
    m, k = 90, 4
    keys = jax.random.split(jax.random.PRNGKey(16), 3)
    grid = build_coresets_batched("vkmc", ds, [m], keys=keys, backend="ref", k=k)
    for r in range(3):
        seq = build_coreset("vkmc", ds, m, key=keys[r], backend="ref", k=k)
        cell = grid.coreset(r, 0)
        # indices exact; weights to float tolerance (vmapped k-means scoring
        # lowers with different reduction order than the sequential trace)
        np.testing.assert_array_equal(np.asarray(cell.indices), np.asarray(seq.indices))
        np.testing.assert_allclose(np.asarray(cell.weights), np.asarray(seq.weights),
                                   rtol=1e-5)


def test_batched_budget_grid_prefix_convention():
    ds = _dataset(jax.random.PRNGKey(17))
    ms = (40, 100)
    grid = build_coresets_batched("vrlr", ds, ms, key=jax.random.PRNGKey(18),
                                  num_seeds=2, backend="ref")
    assert grid.indices.shape == (2, 2, 100)
    # the tail beyond each budget is weight-0 padding
    assert float(jnp.sum(grid.weights[:, 0, 40:])) == 0.0
    for r in range(2):
        for mi, m in enumerate(ms):
            led = CommLedger()
            cs = grid.coreset(r, mi, ledger=led)
            assert cs.m == m
            assert bool(jnp.all(cs.weights > 0))
            assert led.total == 2 * ds.T + m + 2 * m * ds.T
            lo, hi = theoretical_dis_cost(m, ds.T)
            assert lo <= led.total <= hi


def test_batched_falls_back_when_deterministic_contract_broken():
    """A task flagged deterministic whose score_fn transforms the key must
    still produce batched cells identical to sequential builds (the builder
    detects the broken contract and scores per seed)."""
    ds = _dataset(jax.random.PRNGKey(25), n=400)

    def sneaky_scores(key, ds2, backend="ref"):
        key, sub = jax.random.split(key)                # consumes the key
        sc = jnp.stack([jnp.sum(p * p, axis=1) + 1.0 for p in ds2.parts])
        return sc, sub
    task = CoresetTask(name="sneaky", score_fn=sneaky_scores,
                       deterministic_scores=True)
    keys = jax.random.split(jax.random.PRNGKey(26), 3)
    grid = build_coresets_batched(task, ds, [25], keys=keys)
    for r in range(3):
        seq = build_coreset(task, ds, 25, key=keys[r], backend="ref")
        cell = grid.coreset(r, 0)
        # same dis_key => identical draws; weights to float tolerance only
        # (scores computed under vmap lower with a different reduction order)
        np.testing.assert_array_equal(np.asarray(cell.indices), np.asarray(seq.indices))
        np.testing.assert_allclose(np.asarray(cell.weights), np.asarray(seq.weights),
                                   rtol=1e-5)


def test_batched_rejects_zero_scores():
    ds = _dataset(jax.random.PRNGKey(27), n=60)

    def zero_scores(key, ds2, backend="ref"):
        return jnp.zeros((ds2.T, ds2.n)), key
    for deterministic in (True, False):
        task = CoresetTask(name="zero", score_fn=zero_scores,
                           deterministic_scores=deterministic)
        with pytest.raises(ValueError):
            build_coresets_batched(task, ds, [5], key=jax.random.PRNGKey(0),
                                   num_seeds=2)


def test_batched_accepts_typed_prng_keys():
    """New-style jax.random.key() keys work end to end (the deterministic
    contract check must not np.asarray a typed key)."""
    ds = _dataset(jax.random.PRNGKey(28), n=300)
    grid = build_coresets_batched("vrlr", ds, [20], key=jax.random.key(29),
                                  num_seeds=2)
    cs = grid.coreset(0, 0)
    assert cs.m == 20 and bool(jnp.all(cs.weights > 0))


def test_batched_uniform():
    ds = _dataset(jax.random.PRNGKey(19))
    grid = build_coresets_batched("uniform", ds, [30], key=jax.random.PRNGKey(20),
                                  num_seeds=2)
    cs = grid.coreset(0, 0)
    assert cs.m == 30 and cs.comm_units == 30 * ds.T
    np.testing.assert_allclose(np.asarray(cs.weights), ds.n / 30)


# --------------------------------------------------------------------------
# Materialize accounting (Theorem 2.5's +2mT) and schedule composition
# --------------------------------------------------------------------------

def test_materialize_accounts_2mT():
    ds = _dataset(jax.random.PRNGKey(21))
    m, T = 60, ds.T
    led = CommLedger()
    cs = build_coreset("vrlr", ds, m, key=jax.random.PRNGKey(22), ledger=led)
    build_total = led.total
    XS, yS, w = cs.materialize(ds, led)
    assert XS.shape == (m, ds.d) and yS.shape == (m,) and w.shape == (m,)
    assert led.total == build_total + 2 * m * T
    # composition against the paper bounds: construction in [lo, hi], plus 2mT
    lo, hi = theoretical_dis_cost(m, T)
    assert lo + 2 * m * T <= led.total <= hi + 2 * m * T
    # ledger-less call unchanged
    XS2, _, _ = cs.materialize(ds)
    np.testing.assert_array_equal(np.asarray(XS), np.asarray(XS2))


def test_comm_schedule_validates_counts():
    with pytest.raises(ValueError):
        CommSchedule.dis(3, 10, counts=[5, 5, 5])       # sums to 15, not 10
    sched = CommSchedule.dis(3, 10, counts=[7, 3, 0])
    assert sched.total == 2 * 3 + 10 + 2 * 10 * 3
    led = CommLedger()
    sched.record(led)
    assert led.total == sched.total


# --------------------------------------------------------------------------
# Selector shares the DIS server core
# --------------------------------------------------------------------------

def test_selector_sampling_is_server_plan():
    g = jax.random.uniform(jax.random.PRNGKey(23), (64,)) + 1e-3
    key = jax.random.PRNGKey(24)
    S1, w1 = sample_coreset(key, g, 16)
    S2, w2 = server_plan(key, g, 16)
    np.testing.assert_array_equal(np.asarray(S1), np.asarray(S2))
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
