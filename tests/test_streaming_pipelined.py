"""Pipelined streaming engine: draw-identity, superchunk-scan equivalence,
head-draw replay, and the sharded VKMC mass table.

The acceptance chain on top of ``tests/test_streaming.py``:

  1. ``blocks_prefetched`` / ``gather_blocks`` reproduce ``VFLDataset.block``
     contents exactly at every chunking (the staging layer is a layout
     change, not a data change);
  2. the superchunk-scan scorers (chunk_blocks > 1, prefetch on/off) build
     BIT-identical mass tables and per-block scores to the block-at-a-time
     scorers — the scan body is the same per-block computation in the same
     order (hypothesis property included);
  3. ``dis_plan_streamed_batched`` (grouped one-dispatch redraw, head-draw
     candidate replay) is bit-identical to PR 3's ``dis_plan_streamed``
     across odd nb, nb not divisible by chunk size, and the touched-block
     edge regimes (one touched block, all blocks touched, m=0);
  4. therefore ``build_coreset_streaming`` with the pipelined defaults
     matches the strict block-at-a-time engine draw for draw, ledger
     included — the pinned draw-identity acceptance;
  5. ``vkmc_block_masses_sharded`` (one stats psum + one mass psum) agrees
     with the streamed VKMC scorer's mass table.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CommLedger,
    VFLDataset,
    build_coreset,
    build_coreset_streaming,
)
from repro.core.streaming import (
    _categorical_head,
    _head_draws_ok,
    dis_plan_streamed,
    dis_plan_streamed_batched,
    make_stream_scorer,
    vkmc_block_masses_sharded,
    vkmc_local_centers,
)


def _dataset(key, n=1100, d=12, T=3):
    kx, kt, kn = jax.random.split(key, 3)
    X = jax.random.normal(kx, (n, d))
    theta = jax.random.normal(kt, (d,))
    y = X @ theta + 0.1 * jax.random.normal(kn, (n,))
    return VFLDataset.from_dense(X, y, T=T)


def _assert_plans_equal(pa, pb):
    np.testing.assert_array_equal(np.asarray(pa.indices), np.asarray(pb.indices))
    np.testing.assert_array_equal(np.asarray(pa.weights), np.asarray(pb.weights))
    np.testing.assert_array_equal(np.asarray(pa.counts), np.asarray(pb.counts))
    np.testing.assert_array_equal(np.asarray(pa.totals), np.asarray(pb.totals))


# --------------------------------------------------------------------------
# 1: the staging layer is data-transparent
# --------------------------------------------------------------------------

@pytest.mark.parametrize("with_labels", [False, True])
@pytest.mark.parametrize("chunk_blocks,prefetch", [(1, True), (3, False),
                                                   (4, True), (64, True)])
def test_blocks_prefetched_matches_blocks(with_labels, chunk_blocks, prefetch):
    """Every (b, block) pair of the prefetched superchunk traversal equals
    VFLDataset.block(b) bitwise; zero-padded trailing blocks carry 0 valid
    rows and all-zero data."""
    ds = _dataset(jax.random.PRNGKey(0), n=505)
    bsz = 100
    nb, bs = ds.block_geometry(bsz)
    seen = 0
    for b0, chunk, nvalids in ds.blocks_prefetched(bsz, with_labels,
                                                   chunk_blocks, prefetch):
        for i in range(chunk.shape[0]):
            b = b0 + i
            if b >= nb:
                assert int(nvalids[i]) == 0
                assert float(jnp.abs(chunk[i]).sum()) == 0.0
                continue
            blk, nvalid = ds.block(b, bsz, with_labels)
            assert int(nvalids[i]) == nvalid
            np.testing.assert_array_equal(np.asarray(chunk[i]),
                                          np.asarray(blk))
            seen += 1
    assert seen == nb


def test_gather_blocks_matches_block():
    ds = _dataset(jax.random.PRNGKey(1), n=505)
    bsz = 100
    ids = [4, 0, 5, 2]            # out of order, includes the ragged tail
    batch, nvalids = ds.gather_blocks(ids, bsz, with_labels=True)
    for i, b in enumerate(ids):
        blk, nvalid = ds.block(b, bsz, with_labels=True)
        assert int(nvalids[i]) == nvalid
        np.testing.assert_array_equal(np.asarray(batch[i]), np.asarray(blk))
    with pytest.raises(IndexError):
        ds.gather_blocks([99], bsz, with_labels=True)


def test_numpy_backed_staging_matches_jnp():
    """The staging layer gives identical bits for numpy- and jnp-backed
    parts (numpy-backed is the zero-copy hot path)."""
    ds = _dataset(jax.random.PRNGKey(2), n=300)
    ds_np = VFLDataset([np.asarray(p) for p in ds.parts], np.asarray(ds.y))
    for (_, ca, _), (_, cb, _) in zip(
            ds.blocks_prefetched(64, True, 3, True),
            ds_np.blocks_prefetched(64, True, 3, True)):
        np.testing.assert_array_equal(np.asarray(ca), np.asarray(cb))


# --------------------------------------------------------------------------
# 2: superchunk-scan scorers == block-at-a-time scorers, bit for bit
# --------------------------------------------------------------------------

@pytest.mark.parametrize("task,params", [("vrlr", {}), ("vkmc", {"k": 4})])
@pytest.mark.parametrize("backend", ["ref", "norm"])
def test_chunked_scorer_masses_and_scores_bitwise(task, params, backend):
    ds = _dataset(jax.random.PRNGKey(3), n=1100)     # nb=9 at bs=128: odd nb
    key = jax.random.PRNGKey(4)
    legacy = make_stream_scorer(task, key, ds, 128, backend, **params)
    for C in (2, 4, 9, 50):                          # 9 % 2, 9 % 4 != 0
        for pf in (False, True):
            sc = make_stream_scorer(task, key, ds, 128, backend,
                                    chunk_blocks=C, prefetch=pf, **params)
            np.testing.assert_array_equal(np.asarray(legacy.masses),
                                          np.asarray(sc.masses))
    # the batched redraw scorer reproduces per-block scores bitwise
    sc = make_stream_scorer(task, key, ds, 128, backend, chunk_blocks=4,
                            prefetch=True, **params)
    batch = sc.score_blocks([8, 3, 0])               # includes ragged tail
    for i, b in enumerate([8, 3, 0]):
        np.testing.assert_array_equal(np.asarray(batch[i]),
                                      np.asarray(legacy.score_block(b)))


# --------------------------------------------------------------------------
# 3: the grouped one-dispatch redraw == PR 3's per-block redraw
# --------------------------------------------------------------------------

@pytest.mark.parametrize("task,params", [("vrlr", {}), ("vkmc", {"k": 4})])
def test_batched_redraw_draw_identity(task, params):
    """Across odd nb, nb not divisible by the chunk size, and several
    budgets, the grouped redraw reproduces dis_plan_streamed exactly."""
    ds = _dataset(jax.random.PRNGKey(5), n=1100)
    key = jax.random.PRNGKey(6)
    for bsz in (128, 333):
        legacy = make_stream_scorer(task, key, ds, bsz, "ref", **params)
        for m in (1, 17, 90):
            ref_plan = dis_plan_streamed(legacy, m)
            for C in (2, 3, 5):
                sc = make_stream_scorer(task, key, ds, bsz, "ref",
                                        chunk_blocks=C, prefetch=True,
                                        **params)
                _assert_plans_equal(ref_plan, dis_plan_streamed_batched(sc, m))


def test_batched_redraw_touched_block_edges():
    """nt edge cases: m=0 touches nothing, m=1 touches one block, a large
    budget touches every block (nt = nb)."""
    ds = _dataset(jax.random.PRNGKey(7), n=600)
    key = jax.random.PRNGKey(8)
    legacy = make_stream_scorer("vrlr", key, ds, 64, "ref")
    sc = make_stream_scorer("vrlr", key, ds, 64, "ref", chunk_blocks=4,
                            prefetch=True)
    nb = sc.nb
    # m = 0: empty plan, no dispatches
    p0_ref, p0 = dis_plan_streamed(legacy, 0), dis_plan_streamed_batched(sc, 0)
    assert p0.indices.shape == (0,) and p0.weights.shape == (0,)
    _assert_plans_equal(p0_ref, p0)
    # m = 1: exactly one touched block
    _assert_plans_equal(dis_plan_streamed(legacy, 1),
                        dis_plan_streamed_batched(sc, 1))
    # large m: every block is touched (checked, then identity)
    m = 3000
    plan = dis_plan_streamed_batched(sc, m)
    touched = {int(i) // sc.bs for i in np.asarray(plan.indices)}
    assert len(touched) == nb
    _assert_plans_equal(dis_plan_streamed(legacy, m), plan)


def test_head_draw_replay_matches_full_categorical():
    """_categorical_head reproduces the first rows of the full-capacity
    categorical stream bit for bit across shapes, keys, and -inf padding."""
    for trial in range(8):
        k = jax.random.PRNGKey(100 + trial)
        bs = [4096, 128, 500, 64][trial % 4]
        cap = [512, 90, 34, 8][trial % 4]
        take = min(cap // 2, [5, 3, 16, 4][trial % 4])
        lg = jnp.log(jax.random.uniform(jax.random.fold_in(k, 1), (bs,))
                     + 1e-3).astype(jnp.float32)
        if trial % 2:                     # padded-row logits
            lg = jnp.where(jnp.arange(bs) < bs - 7, lg, -jnp.inf)
        assert _head_draws_ok(jnp.stack([k, k]), cap, bs, take)
        full = np.asarray(jax.random.categorical(k, lg, shape=(cap,)))[:take]
        head = np.asarray(_categorical_head(k, lg, cap, take))
        np.testing.assert_array_equal(full, head)


def test_head_draws_gate():
    keys = jnp.stack([jax.random.PRNGKey(0)] * 3)
    assert _head_draws_ok(keys, 512, 4096, 5)
    assert not _head_draws_ok(keys, 512, 4096, 300)    # take > cap // 2
    assert not _head_draws_ok(keys, 0, 4096, 0)        # empty capacity
    assert not _head_draws_ok(keys, 3, 7, 1)           # odd counter stream


# --------------------------------------------------------------------------
# 4: the entry point — pipelined defaults == strict block-at-a-time engine
# --------------------------------------------------------------------------

@pytest.mark.parametrize("task,params", [("vrlr", {}), ("vkmc", {"k": 4})])
def test_build_streaming_pipelined_draw_identity(task, params):
    """THE acceptance pin: build_coreset_streaming with the pipelined
    defaults (chunked + prefetched) is draw-identical to the PR 3 engine
    (chunk_blocks=1, prefetch=False) — indices, weights, and the exact
    ledger bill."""
    ds = _dataset(jax.random.PRNGKey(9), n=1100)
    key = jax.random.PRNGKey(10)
    led_a, led_b = CommLedger(), CommLedger()
    cs_a = build_coreset_streaming(task, ds, 120, key=key, backend="ref",
                                   block_size=128, chunk_blocks=1,
                                   prefetch=False, ledger=led_a, **params)
    cs_b = build_coreset_streaming(task, ds, 120, key=key, backend="ref",
                                   block_size=128, ledger=led_b, **params)
    np.testing.assert_array_equal(np.asarray(cs_a.indices),
                                  np.asarray(cs_b.indices))
    np.testing.assert_array_equal(np.asarray(cs_a.weights),
                                  np.asarray(cs_b.weights))
    assert led_a.total == led_b.total == cs_b.comm_units


def test_build_streaming_pipelined_norm_flat_bit_identity():
    """block_size >= n + row-local scores: the PIPELINED path still matches
    the flat build_coreset bit for bit (the PR 3 contract survives)."""
    ds = _dataset(jax.random.PRNGKey(11))
    key = jax.random.PRNGKey(12)
    cs_f = build_coreset("vrlr", ds, 120, key=key, backend="norm")
    cs_s = build_coreset_streaming("vrlr", ds, 120, key=key, backend="norm",
                                   block_size=ds.n, chunk_blocks=4,
                                   prefetch=True)
    np.testing.assert_array_equal(np.asarray(cs_f.indices),
                                  np.asarray(cs_s.indices))
    np.testing.assert_array_equal(np.asarray(cs_f.weights),
                                  np.asarray(cs_s.weights))


def test_build_streaming_knob_validation():
    """block_size / chunk_blocks are validated HOST-side before any work;
    chunk_blocks above the block count clamps to one full-span superchunk."""
    ds = _dataset(jax.random.PRNGKey(13), n=400)
    key = jax.random.PRNGKey(0)
    for bad in (0, -1, 2.5, "64"):
        with pytest.raises(ValueError, match="block_size"):
            build_coreset_streaming("vrlr", ds, 10, key=key, block_size=bad)
    for bad in (0, -3, 1.5):
        with pytest.raises(ValueError, match="chunk_blocks"):
            build_coreset_streaming("vrlr", ds, 10, key=key, block_size=64,
                                    chunk_blocks=bad)
    # clamp: chunk_blocks > nb behaves as one superchunk over everything
    cs_a = build_coreset_streaming("vrlr", ds, 20, key=key, block_size=64,
                                   chunk_blocks=10_000)
    cs_b = build_coreset_streaming("vrlr", ds, 20, key=key, block_size=64,
                                   chunk_blocks=7)     # nb = ceil(400/64) = 7
    np.testing.assert_array_equal(np.asarray(cs_a.indices),
                                  np.asarray(cs_b.indices))


# --------------------------------------------------------------------------
# 5: sharded VKMC mass table
# --------------------------------------------------------------------------

def test_vkmc_sharded_masses_match_block_scan():
    from repro.launch.mesh import make_debug_mesh

    ds = _dataset(jax.random.PRNGKey(14), n=800)
    key = jax.random.PRNGKey(15)
    mesh = make_debug_mesh(n_data=1, n_model=1)
    ms = vkmc_block_masses_sharded(mesh, ds, 100, key=key, k=4)
    scorer = make_stream_scorer("vkmc", key, ds, 100, "ref", k=4)
    assert ms.shape == (ds.T, 8)
    np.testing.assert_allclose(np.asarray(ms), np.asarray(scorer.masses),
                               rtol=1e-4, atol=1e-6)


def test_vkmc_sharded_masses_rejects_misaligned_grid():
    from repro.launch.mesh import make_debug_mesh

    ds = _dataset(jax.random.PRNGKey(16), n=101)
    with pytest.raises(ValueError):
        vkmc_block_masses_sharded(make_debug_mesh(1, 1), ds, 100,
                                  key=jax.random.PRNGKey(0))


def test_vkmc_local_centers_key_chain_matches_scorer():
    """The centers helper consumes exactly the scorer's key chain, so the
    sharded table and the streamed scorer see the same local solutions and
    the same downstream DIS key."""
    ds = _dataset(jax.random.PRNGKey(17), n=300)
    key = jax.random.PRNGKey(18)
    centers, dis_key = vkmc_local_centers(key, ds, k=4)
    scorer = make_stream_scorer("vkmc", key, ds, 64, "ref", k=4)
    np.testing.assert_array_equal(np.asarray(dis_key),
                                  np.asarray(scorer.dis_key))


# --------------------------------------------------------------------------
# hypothesis: superchunk-scan == per-block composition, any geometry
# --------------------------------------------------------------------------

def test_property_superchunk_scan_equals_per_block():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(st.integers(40, 400), st.integers(7, 64), st.integers(1, 9),
           st.integers(0, 2 ** 31 - 1))
    @settings(max_examples=12, deadline=None)
    def prop(n, block_size, chunk_blocks, seed):
        ds = _dataset(jax.random.PRNGKey(seed), n=n, d=6, T=2)
        key = jax.random.fold_in(jax.random.PRNGKey(seed), 1)
        legacy = make_stream_scorer("vrlr", key, ds, block_size, "ref")
        chunked = make_stream_scorer("vrlr", key, ds, block_size, "ref",
                                     chunk_blocks=chunk_blocks, prefetch=True)
        np.testing.assert_array_equal(np.asarray(legacy.masses),
                                      np.asarray(chunked.masses))
        m = max(1, n // 10)
        _assert_plans_equal(dis_plan_streamed(legacy, m),
                            dis_plan_streamed_batched(chunked, m))

    prop()
