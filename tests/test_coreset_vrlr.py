"""End-to-end coreset quality for VRLR (Algorithm 2 + Theorem 2.5)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CommLedger,
    VFLDataset,
    build_uniform_coreset,
    build_vrlr_coreset,
    ridge_closed_form,
    ridge_cost,
    vrlr_coreset_ratio,
)


def _dataset(key, n=3000, d=12, T=3, noise=0.1, heavy=True):
    kx, kt, kn, kh = jax.random.split(key, 4)
    X = jax.random.normal(kx, (n, d))
    if heavy:
        # heavy-tailed rows -> leverage scores differ, coreset should win
        scale = jax.random.uniform(kh, (n, 1)) ** (-0.5)
        X = X * (1 + 0.2 * scale)
    theta = jax.random.normal(kt, (d,))
    y = X @ theta + noise * jax.random.normal(kn, (n,))
    return VFLDataset.from_dense(X, y, T=T)


def test_coreset_near_optimal_solution():
    ds = _dataset(jax.random.PRNGKey(0))
    lam = 0.1 * ds.n
    cs = build_vrlr_coreset(jax.random.PRNGKey(1), ds, m=400)
    XS, yS, w = cs.materialize(ds)
    th_full = ridge_closed_form(ds.full(), ds.y, lam)
    th_cs = ridge_closed_form(XS, yS, lam, w)
    c_full = float(ridge_cost(ds.full(), ds.y, th_full, lam))
    c_cs = float(ridge_cost(ds.full(), ds.y, th_cs, lam))
    assert c_cs <= 1.10 * c_full, (c_cs, c_full)


def test_coreset_epsilon_over_probe_thetas():
    ds = _dataset(jax.random.PRNGKey(2), n=2000)
    lam = 0.1 * ds.n
    cs = build_vrlr_coreset(jax.random.PRNGKey(3), ds, m=600)
    thetas = jax.random.normal(jax.random.PRNGKey(4), (24, ds.d))
    eps = float(vrlr_coreset_ratio(ds, cs, thetas, lam))
    assert eps < 0.5, eps


def test_coreset_beats_uniform_on_heavy_tails():
    """Paper claim: C-* <= U-* at the same m (averaged over seeds)."""
    ds = _dataset(jax.random.PRNGKey(5), n=4000, heavy=True)
    lam = 0.1 * ds.n
    th_full = ridge_closed_form(ds.full(), ds.y, lam)
    c_full = float(ridge_cost(ds.full(), ds.y, th_full, lam))

    def excess(builder, seed):
        cs = builder(jax.random.PRNGKey(seed), ds, 150)
        XS, yS, w = cs.materialize(ds)
        th = ridge_closed_form(XS, yS, lam, w)
        return float(ridge_cost(ds.full(), ds.y, th, lam)) - c_full

    cs_ex = np.mean([excess(build_vrlr_coreset, s) for s in range(8)])
    un_ex = np.mean([excess(build_uniform_coreset, s + 100) for s in range(8)])
    assert cs_ex <= un_ex * 1.05, (cs_ex, un_ex)


def test_construction_comm_independent_of_n():
    """O(mT) communication — the paper's headline property."""
    led_small, led_big = CommLedger(), CommLedger()
    build_vrlr_coreset(jax.random.PRNGKey(6), _dataset(jax.random.PRNGKey(7), n=1000),
                       m=100, ledger=led_small)
    build_vrlr_coreset(jax.random.PRNGKey(8), _dataset(jax.random.PRNGKey(9), n=4000),
                       m=100, ledger=led_big)
    assert led_small.total == led_big.total
