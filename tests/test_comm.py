"""CommLedger unit tests + Algorithm 1 accounting bounds + Theorem 2.5
composition schedules (materialize pinned, the named merge form)."""

import pytest

from repro.core.comm import CommLedger, CommSchedule, theoretical_dis_cost


def test_ledger_totals():
    led = CommLedger()
    led.party_to_server("x", 0, 10)
    led.server_to_party("y", 1, 5)
    led.broadcast("z", 3, 2)
    assert led.total == 10 + 5 + 6
    assert led.by_tag()["z"] == 6
    assert led.by_prefix("") == led.total


def test_ledger_rejects_negative():
    led = CommLedger()
    with pytest.raises(ValueError):
        led.send("bad", "server", "party:0", -1)


def test_merge_and_fork():
    led = CommLedger()
    sub = led.fork()
    sub.party_to_server("a", 0, 7)
    assert led.total == 0
    led.merge(sub)
    assert led.total == 7


def test_theoretical_bounds_monotone():
    lo1, hi1 = theoretical_dis_cost(100, 3)
    lo2, hi2 = theoretical_dis_cost(200, 3)
    assert lo1 <= hi1 and lo2 <= hi2
    assert lo2 > lo1 and hi2 > hi1


def test_materialize_total_pinned():
    """Theorem 2.5's +2mT consume bill — pinned so the composed ledgers of
    every earlier PR keep their exact totals."""
    for T, m in ((1, 1), (2, 64), (5, 1000)):
        sched = CommSchedule.materialize(T, m)
        assert sched.total == 2 * m * T
        led = CommLedger()
        sched.record(led)
        assert led.by_tag()["materialize/S_down"] == m * T
        assert led.by_tag()["materialize/rows_up"] == m * T


def test_merge_schedule_is_both_children_consume_bill():
    """The named merge-and-reduce form: consuming BOTH children costs
    2*(m_left + m_right)*T — and only depends on the union size, so
    folding k coresets as (sum of first k-1, last) bills sum_i 2*m_i*T."""
    for T, ml, mr in ((1, 1, 1), (2, 64, 64), (3, 10, 500)):
        sched = CommSchedule.merge(T, ml, mr)
        assert sched.total == 2 * (ml + mr) * T
        # the merge of two equal coresets costs exactly two materializes
        assert CommSchedule.merge(T, ml, ml).total \
            == 2 * CommSchedule.materialize(T, ml).total
    assert CommSchedule.merge(2, 0, 7).total == CommSchedule.materialize(2, 7).total
    led = CommLedger()
    CommSchedule.merge(2, 3, 4).record(led)
    assert led.by_prefix("merge/") == led.total == 28
    assert led.by_tag()["merge/S_down"] == 14
    assert led.by_tag()["merge/rows_up"] == 14


def test_merge_schedule_rejects_negative():
    with pytest.raises(ValueError):
        CommSchedule.merge(2, -1, 4)
    with pytest.raises(ValueError):
        CommSchedule.merge(2, 4, -1)
