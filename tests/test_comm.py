"""CommLedger unit tests + Algorithm 1 accounting bounds."""

import pytest

from repro.core.comm import CommLedger, theoretical_dis_cost


def test_ledger_totals():
    led = CommLedger()
    led.party_to_server("x", 0, 10)
    led.server_to_party("y", 1, 5)
    led.broadcast("z", 3, 2)
    assert led.total == 10 + 5 + 6
    assert led.by_tag()["z"] == 6
    assert led.by_prefix("") == led.total


def test_ledger_rejects_negative():
    led = CommLedger()
    with pytest.raises(ValueError):
        led.send("bad", "server", "party:0", -1)


def test_merge_and_fork():
    led = CommLedger()
    sub = led.fork()
    sub.party_to_server("a", 0, 7)
    assert led.total == 0
    led.merge(sub)
    assert led.total == 7


def test_theoretical_bounds_monotone():
    lo1, hi1 = theoretical_dis_cost(100, 3)
    lo2, hi2 = theoretical_dis_cost(200, 3)
    assert lo1 <= hi1 and lo2 <= hi2
    assert lo2 > lo1 and hi2 > hi1
