"""CommLedger unit tests + Algorithm 1 accounting bounds + Theorem 2.5
composition schedules (materialize pinned, the named merge form)."""

import pytest

from repro.core.comm import CommLedger, CommSchedule, theoretical_dis_cost


def test_ledger_totals():
    led = CommLedger()
    led.party_to_server("x", 0, 10)
    led.server_to_party("y", 1, 5)
    led.broadcast("z", 3, 2)
    assert led.total == 10 + 5 + 6
    assert led.by_tag()["z"] == 6
    assert led.by_prefix("") == led.total


def test_ledger_rejects_negative():
    led = CommLedger()
    with pytest.raises(ValueError):
        led.send("bad", "server", "party:0", -1)


def test_merge_and_fork():
    led = CommLedger()
    sub = led.fork()
    sub.party_to_server("a", 0, 7)
    assert led.total == 0
    led.merge(sub)
    assert led.total == 7


def test_theoretical_bounds_monotone():
    lo1, hi1 = theoretical_dis_cost(100, 3)
    lo2, hi2 = theoretical_dis_cost(200, 3)
    assert lo1 <= hi1 and lo2 <= hi2
    assert lo2 > lo1 and hi2 > hi1


def test_materialize_total_pinned():
    """Theorem 2.5's +2mT consume bill — pinned so the composed ledgers of
    every earlier PR keep their exact totals."""
    for T, m in ((1, 1), (2, 64), (5, 1000)):
        sched = CommSchedule.materialize(T, m)
        assert sched.total == 2 * m * T
        led = CommLedger()
        sched.record(led)
        assert led.by_tag()["materialize/S_down"] == m * T
        assert led.by_tag()["materialize/rows_up"] == m * T


def test_merge_schedule_is_both_children_consume_bill():
    """The named merge-and-reduce form: consuming BOTH children costs
    2*(m_left + m_right)*T — and only depends on the union size, so
    folding k coresets as (sum of first k-1, last) bills sum_i 2*m_i*T."""
    for T, ml, mr in ((1, 1, 1), (2, 64, 64), (3, 10, 500)):
        sched = CommSchedule.merge(T, ml, mr)
        assert sched.total == 2 * (ml + mr) * T
        # the merge of two equal coresets costs exactly two materializes
        assert CommSchedule.merge(T, ml, ml).total \
            == 2 * CommSchedule.materialize(T, ml).total
    assert CommSchedule.merge(2, 0, 7).total == CommSchedule.materialize(2, 7).total
    led = CommLedger()
    CommSchedule.merge(2, 3, 4).record(led)
    assert led.by_prefix("merge/") == led.total == 28
    assert led.by_tag()["merge/S_down"] == 14
    assert led.by_tag()["merge/rows_up"] == 14


def test_merge_schedule_rejects_negative():
    with pytest.raises(ValueError):
        CommSchedule.merge(2, -1, 4)
    with pytest.raises(ValueError):
        CommSchedule.merge(2, 4, -1)


# -- mark/rollback/since + by_prefix edge cases (integrity PR satellites) ----


def _led_with(entries):
    led = CommLedger()
    for tag, units in entries:
        led.party_to_server(tag, 0, units)
    return led


def test_mark_rollback_nesting():
    led = _led_with([("a/x", 1), ("a/y", 2)])
    outer = led.mark()
    led.party_to_server("b/x", 0, 4)
    inner = led.mark()
    led.party_to_server("b/y", 0, 8)
    assert led.total == 15 and led.since(outer) == 12 and led.since(inner) == 8
    led.rollback(inner)                      # unwind the inner bracket only
    assert led.total == 7 and led.by_tag().get("b/y") is None
    assert led.since(outer) == 4
    led.rollback(outer)                      # then the outer one
    assert led.total == 3 and led.by_prefix("b/") == 0
    assert led.by_tag() == {"a/x": 1, "a/y": 2}


def test_rollback_after_rollback_and_validation():
    led = _led_with([("t", 5)])
    mark = led.mark()
    led.party_to_server("t", 0, 7)
    led.rollback(mark)
    led.rollback(mark)                       # idempotent at the same mark
    assert led.total == 5
    led.party_to_server("u", 0, 1)
    with pytest.raises(ValueError, match="bad mark"):
        led.rollback(99)
    with pytest.raises(ValueError, match="bad mark"):
        led.rollback(-1)
    with pytest.raises(ValueError, match="bad mark"):
        led.since(99)
    # a stale mark BEYOND a rollback is invalid and says so
    deep = led.mark()
    led.rollback(mark)
    with pytest.raises(ValueError, match=f"bad mark {deep}"):
        led.rollback(deep)


def test_by_prefix_edge_cases():
    led = _led_with([("dis/round1/G_j", 3), ("dis/round2/S_up", 5),
                     ("retry/dis/round2/S_up", 5), ("disjoint", 11)])
    assert led.by_prefix("") == led.total == 24
    # prefixes are string prefixes, not path components: "dis" catches the
    # lookalike tag too; "dis/" does not
    assert led.by_prefix("dis") == 19
    assert led.by_prefix("dis/") == 8
    assert led.by_prefix("retry/") == 5
    assert led.by_prefix("retry/dis/round2/S_up") == 5
    assert led.by_prefix("nope/") == 0
    empty = CommLedger()
    assert empty.by_prefix("") == 0 and empty.since(empty.mark()) == 0
