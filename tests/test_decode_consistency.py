"""Decode-path correctness: sequential decode_step logits must match the
full forward pass at every position.  This validates the KV-cache ring
buffer, the MLA absorbed-attention decode, the chunked-WKV <-> serial-WKV
algebra, and the mamba chunked scan state carry."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import api as model_api
from repro.models import lm
from repro.models import encdec

# one representative per decode code path
ARCHS = ["llama3.2-1b",          # gqa ring cache
         "qwen3-14b",            # qk_norm
         "deepseek-v2-236b",     # MLA absorbed decode + MoE
         "rwkv6-3b",             # chunked vs serial WKV
         "hymba-1.5b",           # parallel attn+mamba states
         "whisper-medium"]       # enc-dec cross-attention cache


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    import dataclasses
    cfg = get_arch(arch).reduced()
    if cfg.is_moe:
        # capacity-based MoE dispatch is group-dependent: when capacity
        # binds, which tokens drop differs between a (B,S) prefill group and
        # a (B,1) decode group — that's inherent to Switch-style MoE, not a
        # cache bug.  Ample capacity makes dispatch lossless so this test
        # checks the routing/expert/cache ALGEBRA exactly.
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    key = jax.random.PRNGKey(3)
    params = model_api.init_params(key, cfg)
    B, S = 2, 8
    tokens = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0, cfg.vocab_size)

    if cfg.kind == "encdec":
        frames = jax.random.normal(jax.random.fold_in(key, 2),
                                   (B, cfg.num_prefix, cfg.d_model), jnp.float32)
        hidden, _ = encdec.forward(params, cfg, tokens, frames)
        logits_fwd = np.asarray(lm.mask_pad_logits(
            jnp.einsum("bsd,vd->bsv", hidden.astype(jnp.float32),
                       params["embed"].astype(jnp.float32)), cfg.vocab_size))
        cache = encdec.init_cache(cfg, B, 32)
        cache = encdec.prefill_cross(params, cfg, cache, frames)
    else:
        hidden, _ = lm.forward(params, cfg, tokens)
        logits_fwd = np.asarray(lm.logits_of(params, cfg, hidden))
        cache = lm.init_cache(cfg, B, 32)

    step = jax.jit(lambda p, c, t: model_api.decode_step(p, cfg, c, t))
    for t in range(S):
        logits_t, cache = step(params, cache, tokens[:, t : t + 1])
        np.testing.assert_allclose(
            np.asarray(logits_t[:, 0, : cfg.vocab_size]),
            logits_fwd[:, t, : cfg.vocab_size],
            rtol=2e-2, atol=2e-2,
            err_msg=f"{arch} diverges at position {t}",
        )


def test_sliding_window_decode_ring_buffer():
    """Ring overwrite: with window W the decode must match a forward pass
    restricted to the window."""
    import dataclasses
    cfg = dataclasses.replace(get_arch("llama3.2-1b").reduced(), sliding_window=4)
    key = jax.random.PRNGKey(4)
    params = model_api.init_params(key, cfg)
    B, S = 1, 10
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    hidden, _ = lm.forward(params, cfg, tokens)   # forward applies the window
    logits_fwd = np.asarray(lm.logits_of(params, cfg, hidden))
    cache = lm.init_cache(cfg, B, cache_len=64)   # ring is min(64, window)=4
    assert cache["layers"]["k"].shape[2] == 4
    step = jax.jit(lambda p, c, t: model_api.decode_step(p, cfg, c, t))
    for t in range(S):
        logits_t, cache = step(params, cache, tokens[:, t : t + 1])
        np.testing.assert_allclose(
            np.asarray(logits_t[:, 0, : cfg.vocab_size]),
            logits_fwd[:, t, : cfg.vocab_size], rtol=3e-2, atol=3e-2,
            err_msg=f"window decode diverges at t={t}")
