"""Party fault model: deterministic chaos plans, exact retry billing,
fault-free bit-identity pins for every engine + the tree, degraded builds,
checkpointed resume, crash-safe tree inserts, and the service's edge
validation.  (PR: fault-tolerant VFL rounds.)
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (
    CommLedger,
    Coreset,
    CoresetPipeline,
    CoresetSpec,
    FaultPlan,
    MaterializedCoreset,
    PartyUnavailable,
    PlanCache,
    StreamCheckpoint,
    Transport,
    VFLDataset,
    deliver_or_record,
)
from repro.core.comm import CommSchedule
from repro.serve import CoresetService, CoresetTree

BLOCK = 128


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_programs():
    yield
    jax.clear_caches()


def _ds(seed=0, n=600, dims=(3, 2, 2), labels=True):
    rng = np.random.default_rng(seed)
    parts = [rng.normal(size=(n, d)).astype(np.float32) for d in dims]
    y = None
    if labels:
        theta = np.linspace(1.0, -1.0, dims[0]).astype(np.float32)
        y = (parts[0] @ theta
             + 0.1 * rng.normal(size=n).astype(np.float32))
    return VFLDataset(parts, y)


def _spec(engine="materialized", policy="fail", task="vrlr", m=32, **kw):
    params = {"k": 3} if task == "vkmc" else {}
    params.update(kw.pop("params", {}))
    return CoresetSpec(task=task, budgets=m, engine=engine, backend="ref",
                       fault_policy=policy, params=params,
                       block_size=BLOCK, **kw)


def _same_draw(a: Coreset, b: Coreset) -> bool:
    return (np.array_equal(np.asarray(a.indices), np.asarray(b.indices))
            and np.array_equal(np.asarray(a.weights), np.asarray(b.weights)))


# -- FaultPlan: determinism + validation -------------------------------------


def test_fault_plan_decide_is_replayable():
    mk = lambda: FaultPlan(seed=3, drop=0.3, corrupt=0.1, delay=0.2)
    grid = [(f"dis/round{r}/x", j, a)
            for r in (1, 2, 3) for j in range(3) for a in range(4)]
    ev1 = [mk().decide(*g) for g in grid]
    ev2 = [mk().decide(*g) for g in grid]
    assert ev1 == ev2
    other = [FaultPlan(seed=4, drop=0.3, corrupt=0.1, delay=0.2).decide(*g)
             for g in grid]
    assert other != ev1  # the seed actually steers the draws
    statuses = {e.status for e in ev1}
    assert "ok" in statuses and statuses - {"ok"}  # some faults fired


def test_fault_plan_per_party_rates_and_null():
    plan = FaultPlan(seed=0, drop={1: 0.5})
    assert plan.rate("drop", 1) == 0.5
    assert plan.rate("drop", 0) == 0.0
    # party 0 has rate 0 -> always ok, no PRNG consulted
    assert all(plan.decide("t", 0, a).ok for a in range(8))
    assert FaultPlan.none().is_null
    assert not plan.is_null


@pytest.mark.parametrize("bad", [
    {"drop": 1.5}, {"corrupt": -0.1}, {"max_retries": -1},
    {"timeout_s": -1.0}, {"seed": "x"},
])
def test_fault_plan_validation(bad):
    with pytest.raises(ValueError):
        FaultPlan(**bad)


# -- Transport: billing exactness -------------------------------------------


def test_null_transport_bit_identical_to_record():
    sched = CommSchedule.dis(3, 16, counts=[10, 4, 2])
    led_rec, led_tr = CommLedger(), CommLedger()
    sched.record(led_rec)
    rep = Transport(FaultPlan.none()).deliver(sched, led_tr)
    assert [dataclasses.astuple(m) for m in led_tr.messages] == \
           [dataclasses.astuple(m) for m in led_rec.messages]
    assert rep.units == rep.units_base == sched.total
    assert rep.retries == 0 and not rep.failed


def test_deliver_or_record_without_transport_is_record():
    sched = CommSchedule.dis_round1(3)
    led = CommLedger()
    rep = deliver_or_record(sched, led, None)
    assert led.total == sched.total == rep.units
    assert rep.units_retried == 0


def test_retry_billing_base_tags_exact():
    sched = CommSchedule.dis(3, 16, counts=[16, 0, 0])
    plan = FaultPlan(seed=11, drop=0.35, max_retries=8)
    led = CommLedger()
    rep = Transport(plan).deliver(sched, led)
    retry_units = led.by_prefix("retry/")
    assert retry_units > 0  # chaos actually fired at this seed
    # base tags bill EXACTLY the fault-free schedule; retries are the rest
    assert led.total - retry_units == sched.total
    assert rep.units_base == sched.total
    assert rep.units_retried == retry_units
    assert rep.units == led.total


def test_exhaustion_raises_party_unavailable_with_attempt_count():
    sched = CommSchedule.dis_round1(3)
    plan = FaultPlan(seed=0, drop={1: 1.0}, max_retries=2)
    with pytest.raises(PartyUnavailable, match=r"party 1 unavailable: "
                                               r"3 attempt\(s\)") as ei:
        Transport(plan).deliver(sched, CommLedger())
    assert (ei.value.party, ei.value.attempts) == (1, 3)


def test_drop_on_exhaust_skips_the_partys_remaining_ops():
    sched = CommSchedule.dis(3, 12, counts=[4, 4, 4])
    plan = FaultPlan(seed=0, drop={1: 1.0}, max_retries=1)
    led = CommLedger()
    rep = Transport(plan).deliver(sched, led, drop_on_exhaust=True)
    assert set(rep.failed) == {1}
    assert rep.failed[1].attempts == 2
    # party 1 never lands a base-tag entry after its first exhaustion
    assert all("retry/" in m.tag for m in led.messages
               if "party:1" in (m.src, m.dst))


def test_transport_stats_accumulate_across_schedules():
    tr = Transport(FaultPlan(seed=2, drop=0.3, max_retries=6))
    for _ in range(3):
        tr.deliver(CommSchedule.dis_round1(4), CommLedger())
    s = tr.stats
    assert s.attempts == s.delivered + s.drops + s.corrupts + s.timeouts
    assert s.retries > 0 and s.sim_time_s > 0.0  # backoff accrued, not slept


# -- fault-free bit-identity: every engine + the tree ------------------------


@pytest.mark.parametrize("engine", ["materialized", "streamed", "pipelined"])
@pytest.mark.parametrize("task", ["vrlr", "vkmc"])
def test_fault_free_transport_pins_bit_identical(engine, task):
    ds = _ds(labels=task == "vrlr")
    key = jax.random.PRNGKey(5)
    led0 = CommLedger()
    cs0 = CoresetPipeline(ds).build(_spec(engine, task=task), key=key,
                                    ledger=led0)
    for policy, plan in [("fail", FaultPlan.none()),
                         ("retry", FaultPlan(seed=9)),  # null rates
                         ("degrade", FaultPlan.none())]:
        led = CommLedger()
        cs = CoresetPipeline(ds).build(
            _spec(engine, policy, task=task), key=key, ledger=led,
            transport=Transport(plan))
        assert _same_draw(cs, cs0)
        assert cs.comm_units == cs0.comm_units
        assert cs.degraded is None
        assert [dataclasses.astuple(m) for m in led.messages] == \
               [dataclasses.astuple(m) for m in led0.messages]


def test_fault_free_tree_insert_pins_bit_identical():
    chunks = [_ds(seed=s, n=300) for s in range(3)]
    kw = dict(key=jax.random.PRNGKey(1), backend="ref", block_size=BLOCK)
    t0 = CoresetTree("vrlr", 48, **kw)
    t1 = CoresetTree("vrlr", 48, transport=Transport(FaultPlan.none()),
                     fault_policy="retry", **kw)
    for c in chunks:
        t0.insert([np.asarray(p) for p in c.parts], np.asarray(c.y))
        t1.insert([np.asarray(p) for p in c.parts], np.asarray(c.y))
    q0, q1 = t0.query(), t1.query()
    assert np.array_equal(q0.indices, q1.indices)
    assert np.array_equal(q0.weights, q1.weights)
    assert t0.ledger.total == t1.ledger.total
    assert [dataclasses.astuple(m) for m in t0.ledger.messages] == \
           [dataclasses.astuple(m) for m in t1.ledger.messages]


# -- chaos determinism: replay + fixed-seed pin ------------------------------


def _chaos_build(seed=123, drop=0.3):
    ds = _ds()
    tr = Transport(FaultPlan(seed=seed, drop=drop, max_retries=6))
    led = CommLedger()
    cs = CoresetPipeline(ds).build(_spec(policy="retry"),
                                   key=jax.random.PRNGKey(7),
                                   ledger=led, transport=tr)
    return cs, led, tr


def test_chaos_replay_identical():
    (cs1, led1, tr1), (cs2, led2, tr2) = _chaos_build(), _chaos_build()
    assert _same_draw(cs1, cs2)
    assert led1.by_tag() == led2.by_tag()
    assert tr1.stats.as_dict() == tr2.stats.as_dict()


def test_chaos_fixed_seed_pin():
    # pinned off plan seed 123 / drop 0.3: threefry is platform-stable, so
    # these exact numbers must reproduce anywhere (fault-free base is 230 =
    # dis_total(T=3, m=32); 2 drops -> 64 retry units on m-sized messages)
    cs, led, tr = _chaos_build()
    assert led.total == 294
    assert led.by_prefix("retry/") == 64
    assert tr.stats.retries == 2 and tr.stats.drops == 2
    assert cs.comm_units == led.total
    assert np.asarray(cs.indices)[:6].tolist() == [140, 576, 86, 101, 422, 206]


def test_chaos_replay_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    sched = CommSchedule.dis(3, 8, counts=[8, 0, 0])

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**16), drop=st.floats(0.0, 0.4),
           retries=st.integers(2, 5))
    def prop(seed, drop, retries):
        def deliver():
            plan = FaultPlan(seed=seed, drop=drop, max_retries=retries)
            led = CommLedger()
            rep = Transport(plan).deliver(sched, led, drop_on_exhaust=True)
            return rep, led
        rep1, led1 = deliver()
        rep2, led2 = deliver()
        assert rep1 == rep2
        assert led1.by_tag() == led2.by_tag()
        # exactness holds under ANY fault pattern: every surviving party's
        # base-tag bill equals its fault-free share
        dead = set(rep1.failed)
        for op in sched.ops:
            if op.party not in dead:
                assert led1.by_tag().get(op.tag, 0) >= op.units
    prop()


# -- degraded builds ---------------------------------------------------------


def test_degrade_drops_party_and_issues_receipt():
    ds = _ds()
    tr = Transport(FaultPlan(seed=0, drop={0: 1.0}, max_retries=2))
    led = CommLedger()
    cs = CoresetPipeline(ds).build(_spec(policy="degrade"),
                                   key=jax.random.PRNGKey(3),
                                   ledger=led, transport=tr)
    d = cs.degraded
    assert d is not None
    assert d.surviving == (1, 2) and d.total_parties == 3
    assert d.dropped[0].party == 0
    assert d.bound_factor == pytest.approx(1.5)
    assert "2/3 parties survived" in d.describe()
    assert cs.comm_units == led.total
    assert np.asarray(cs.indices).max() < ds.n
    # the bill names only the parties that actually spoke in rounds 2-3
    assert all("party:0" not in (m.src, m.dst) for m in led.messages
               if m.tag.startswith("dis/round2"))


def test_degrade_label_party_loss_raises():
    ds = _ds()
    tr = Transport(FaultPlan(seed=0, drop={2: 1.0}, max_retries=1))
    with pytest.raises(PartyUnavailable):
        CoresetPipeline(ds).build(_spec(policy="degrade"),
                                  key=jax.random.PRNGKey(3), transport=tr)


def test_degrade_all_parties_lost_raises():
    ds = _ds(labels=False)
    tr = Transport(FaultPlan(seed=0, drop=1.0, max_retries=0))
    with pytest.raises(RuntimeError):
        CoresetPipeline(ds).build(_spec(policy="degrade", task="vkmc"),
                                  key=jax.random.PRNGKey(3), transport=tr)


def test_fail_and_retry_policies_raise_on_exhaustion():
    ds = _ds()
    for policy in ("fail", "retry"):
        tr = Transport(FaultPlan(seed=0, drop={1: 1.0}, max_retries=1))
        with pytest.raises(PartyUnavailable):
            CoresetPipeline(ds).build(_spec(policy=policy),
                                      key=jax.random.PRNGKey(3), transport=tr)


# -- spec / build validation -------------------------------------------------


def test_fault_policy_validation():
    with pytest.raises(ValueError, match="fault_policy must be one of"):
        _spec(policy="bogus")
    with pytest.raises(ValueError, match="batched engine bills its cells"):
        CoresetSpec(task="vrlr", budgets=(16,), engine="batched",
                    fault_policy="retry")
    assert "fault_policy=degrade" in CoresetPipeline(_ds()).plan(
        _spec(policy="degrade")).describe()


def test_build_rejects_incompatible_combinations():
    ds = _ds()
    pipe = CoresetPipeline(ds)
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="batched engine bills its cells"):
        pipe.build(CoresetSpec(task="vrlr", budgets=(16,), engine="batched",
                               backend="ref"),
                   key=key, transport=Transport())
    with pytest.raises(ValueError, match="checkpointed resume is a "
                                         "streamed/pipelined-engine"):
        pipe.build(_spec("materialized"), key=key,
                   checkpoint=StreamCheckpoint())
    with pytest.raises(ValueError, match="fused jit path"):
        pipe.build(_spec("materialized", jit=True), key=key,
                   transport=Transport())


# -- checkpointed resume -----------------------------------------------------


class _Bomb:
    def __init__(self, at):
        self.at, self.calls = at, 0

    def __call__(self):
        self.calls += 1
        if self.calls == self.at:
            raise RuntimeError("killed mid-scan")


@pytest.mark.parametrize("engine", ["streamed", "pipelined"])
@pytest.mark.parametrize("task", ["vrlr", "vkmc"])
def test_checkpoint_resume_draw_identical(engine, task):
    ds = _ds(n=700, labels=task == "vrlr")
    key = jax.random.PRNGKey(4)
    spec = _spec(engine, task=task, chunk_blocks=2)
    cs0 = CoresetPipeline(ds).build(spec, key=key)

    ck = StreamCheckpoint()
    with pytest.raises(RuntimeError, match="killed mid-scan"):
        CoresetPipeline(ds).build(spec, key=key, checkpoint=ck,
                                  probe=_Bomb(at=2))
    assert ck.saves > 0  # the crashed pass left resumable state behind
    cs1 = CoresetPipeline(ds).build(spec, key=key, checkpoint=ck)
    assert ck.resumes > 0
    assert _same_draw(cs1, cs0)
    assert cs1.comm_units == cs0.comm_units
    # a completed build clears its state: nothing stale for the next chunk
    assert ck.signature is None


def test_checkpoint_signature_mismatch_discards_stale_state():
    ds = _ds(n=700)
    spec = _spec("pipelined", chunk_blocks=2)
    ck = StreamCheckpoint()
    with pytest.raises(RuntimeError):
        CoresetPipeline(ds).build(spec, key=jax.random.PRNGKey(4),
                                  checkpoint=ck, probe=_Bomb(at=2))
    # resuming under a DIFFERENT key must not reuse key-4's accumulators
    other = jax.random.PRNGKey(8)
    cs = CoresetPipeline(ds).build(spec, key=other, checkpoint=ck)
    assert _same_draw(cs, CoresetPipeline(ds).build(spec, key=other))


# -- crash-safe tree inserts -------------------------------------------------


def _tree_chunks(num=4, rows=300):
    return [(_ds(seed=10 + s, n=rows).parts, _ds(seed=10 + s, n=rows).y)
            for s in range(num)]


def test_tree_crash_rolls_back_and_resumes_draw_identical():
    import repro.serve.tree as treemod

    chunks = [( [np.asarray(p) for p in parts], np.asarray(y) )
              for parts, y in _tree_chunks()]
    kw = dict(key=jax.random.PRNGKey(0), backend="ref",
              block_size=BLOCK, chunk_blocks=2)
    t_ref = CoresetTree("vrlr", 48, **kw)
    ck = StreamCheckpoint()
    t_cr = CoresetTree("vrlr", 48, checkpoint=ck, **kw)
    for i, (parts, y) in enumerate(chunks):
        t_ref.insert(parts, y)
        if i == 1:
            pre = (t_cr.ledger.total, t_cr.num_chunks, t_cr.n_total)
            orig = treemod.CoresetPipeline.build
            bomb = _Bomb(at=2)

            def crashing(self, *a, **kws):
                kws["probe"] = bomb
                return orig(self, *a, **kws)

            treemod.CoresetPipeline.build = crashing
            try:
                with pytest.raises(RuntimeError, match="killed mid-scan"):
                    t_cr.insert(parts, y)
            finally:
                treemod.CoresetPipeline.build = orig
            # the failed insert left NOTHING behind
            assert (t_cr.ledger.total, t_cr.num_chunks, t_cr.n_total) == pre
        t_cr.insert(parts, y)
    assert ck.resumes >= 1
    q_ref, q_cr = t_ref.query(), t_cr.query()
    assert np.array_equal(q_ref.indices, q_cr.indices)
    assert np.array_equal(q_ref.weights, q_cr.weights)
    assert t_ref.ledger.total == t_cr.ledger.total


# -- service edge validation + stats -----------------------------------------


def test_service_insert_validation():
    svc = CoresetService(backend="ref")
    svc.register("a", task="vrlr", budget=32, block_size=BLOCK)
    ds = _ds(n=200)
    with pytest.raises(ValueError, match="empty parts list"):
        svc.insert("a", [])
    with pytest.raises(ValueError, match="zero-row superchunk"):
        svc.insert("a", [np.zeros((0, 3)), np.zeros((0, 2))])
    with pytest.raises(ValueError, match="parties disagree on the chunk's "
                                         "row count"):
        svc.insert("a", [np.asarray(ds.parts[0]),
                         np.asarray(ds.parts[1])[:100]])
    # nothing above touched the tree
    assert svc.state("a").tree.num_chunks == 0


def test_service_chaos_tenant_streams_and_stats_expose_cache():
    svc = CoresetService(backend="ref",
                         plan_cache=PlanCache(max_entries=2))
    tr = Transport(FaultPlan(seed=6, drop=0.15, max_retries=6))
    svc.register("chaotic", task="vrlr", budget=32, block_size=BLOCK,
                 fault_policy="retry", transport=tr, checkpoint=True)
    for parts, y in _tree_chunks(num=2):
        svc.insert("chaotic", [np.asarray(p) for p in parts], np.asarray(y))
    rec = svc.query("chaotic")
    assert rec.m == 64  # two un-merged leaves of 32, concatenated
    assert rec.ledger_total == svc.state("chaotic").ledger.total
    s = svc.stats()
    for k in ("plan_cache_size", "plan_cache_max", "plan_hits",
              "plan_misses", "plan_evictions"):
        assert k in s
    assert s["plan_cache_max"] == 2


def test_vfl_dataset_rejects_degenerate_inputs():
    with pytest.raises(ValueError, match="at least one party"):
        VFLDataset([], None)
    with pytest.raises(ValueError, match=r"at least one row \(n=0\)"):
        VFLDataset([np.zeros((0, 3), np.float32)], None)


# -- PlanCache LRU bound -----------------------------------------------------


def test_plan_cache_lru_evicts_at_capacity():
    ds = _ds(n=200)
    pc = PlanCache(max_entries=2)
    specs = [_spec(m=8 + i) for i in range(3)]
    for sp in specs:
        pc.get(sp, ds)
    s = pc.stats()
    assert {k: s[k] for k in ("size", "max_entries", "hits", "misses",
                              "evictions")} == {
        "size": 2, "max_entries": 2, "hits": 0, "misses": 3, "evictions": 1}
    assert s["oldest_idle_s"] >= s["newest_idle_s"] >= 0.0
    pc.get(specs[2], ds)                  # newest entry: a hit
    assert pc.hits == 1
    pc.get(specs[0], ds)                  # evicted entry: a miss again
    assert pc.misses == 4
    with pytest.raises(ValueError, match="max_entries must be a positive"):
        PlanCache(max_entries=0)


# -- MaterializedCoreset edge cases ------------------------------------------


def _mat(seed, m=4, dims=(3, 2), offset=0, labels=True):
    ds = _ds(seed=seed, n=50, dims=dims, labels=labels)
    cs = Coreset(jax.numpy.arange(m), jax.numpy.ones(m), comm_units=7)
    return MaterializedCoreset.from_coreset(cs, ds, offset=offset)


def test_concat_edge_cases_pin_messages():
    with pytest.raises(ValueError, match="concat needs at least one coreset"):
        MaterializedCoreset.concat([])
    a, b = _mat(0), _mat(1, dims=(2, 3))
    with pytest.raises(ValueError, match=r"party widths differ across "
                                         r"coresets: coreset 0 has \(3, 2\), "
                                         r"coreset 1 has \(2, 3\)"):
        MaterializedCoreset.concat([a, b])
    with pytest.raises(ValueError, match="party counts differ"):
        MaterializedCoreset.concat([a, _mat(1, dims=(3, 2, 2))])
    with pytest.raises(ValueError, match="label presence differs"):
        MaterializedCoreset.concat([a, _mat(1, labels=False)])


def test_concat_with_empty_coreset_is_the_other_operand():
    full, empty = _mat(0, m=4), _mat(1, m=0)
    assert empty.m == 0
    u = MaterializedCoreset.concat([full, empty])
    assert u.m == 4
    assert np.array_equal(u.indices, full.indices)
    assert u.comm_units == full.comm_units + empty.comm_units


def test_from_coreset_offset_edges():
    ds = _ds(n=50, dims=(3, 2))
    cs = Coreset(jax.numpy.arange(4), jax.numpy.ones(4), comm_units=0)
    with pytest.raises(ValueError, match="offset must be >= 0, got -1"):
        MaterializedCoreset.from_coreset(cs, ds, offset=-1)
    with pytest.raises(OverflowError, match="global id overflow"):
        MaterializedCoreset.from_coreset(cs, ds,
                                         offset=np.iinfo(np.int64).max - 1)
    m = MaterializedCoreset.from_coreset(cs, ds, offset=100)
    assert m.indices.tolist() == [100, 101, 102, 103]
    # an empty coreset materializes to an m=0 node at any offset
    e = MaterializedCoreset.from_coreset(
        Coreset(jax.numpy.arange(0), jax.numpy.ones(0), comm_units=0),
        ds, offset=10)
    assert e.m == 0 and e.parts[0].shape == (0, 3)
