"""Per-kernel shape/dtype sweeps: Pallas (interpret mode on CPU) vs the
pure-jnp oracles in kernels/ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES_NK = [(17, 3, 5), (128, 8, 32), (300, 13, 90), (1000, 64, 7), (257, 10, 129)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("n,k,d", SHAPES_NK)
@pytest.mark.parametrize("dtype", DTYPES)
def test_kmeans_assign_sweep(n, k, d, dtype):
    kx, kc = jax.random.split(jax.random.PRNGKey(n * 31 + k))
    X = jax.random.normal(kx, (n, d), dtype)
    C = jax.random.normal(kc, (k, d), dtype)
    a_k, d_k = ops.kmeans_assign(X, C)
    a_r, d_r = ref.kmeans_assign(X, C)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=tol, atol=tol)
    # argmin may differ on exact ties under reordered float math: check the
    # CHOSEN distance is (near-)minimal instead of index equality
    d_all = np.asarray(ref.kmeans_assign(X, C)[1])
    chosen = np.asarray(
        jnp.sum((X.astype(jnp.float32) - C.astype(jnp.float32)[np.asarray(a_k)]) ** 2, axis=1))
    np.testing.assert_allclose(chosen, d_all, rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("n,d", [(16, 4), (200, 30), (513, 90), (64, 128), (1000, 18)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_leverage_sweep(n, d, dtype):
    kx, km = jax.random.split(jax.random.PRNGKey(n + d))
    X = jax.random.normal(kx, (n, d), dtype)
    A = jax.random.normal(km, (d, d), jnp.float32)
    M = A @ A.T / d
    out_k = ops.leverage(X, M)
    out_r = ref.leverage(X, M)
    tol = 1e-3 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=tol, atol=tol)


@pytest.mark.parametrize("n,d", [(16, 4), (300, 30), (700, 90), (128, 128)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_weighted_gram_sweep(n, d, dtype):
    kx, kw = jax.random.split(jax.random.PRNGKey(n * 7 + d))
    X = jax.random.normal(kx, (n, d), dtype)
    w = jax.random.uniform(kw, (n,))
    out_k = ops.weighted_gram(X, w)
    out_r = ref.weighted_gram(X, w)
    tol = 1e-3 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=tol, atol=tol * d)


def test_block_size_invariance():
    """Tiling must not change results (block boundary correctness)."""
    X = jax.random.normal(jax.random.PRNGKey(0), (517, 33))
    C = jax.random.normal(jax.random.PRNGKey(1), (9, 33))
    a1, d1 = ops.kmeans_assign(X, C, block_n=64)
    a2, d2 = ops.kmeans_assign(X, C, block_n=512)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-6)
