"""The overload-safe service layer: clock/deadline seam, admission control
(token bucket, queue bounds, in-flight cap), circuit breakers, the engine
failover ladder with the live-bytes watchdog, plan-cache aging, and the
ledger/receipt reconciliation invariant."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core import CommLedger, VFLDataset
from repro.core.comm import CommSchedule
from repro.core.api import CoresetPipeline, FailoverOutcome, build_coreset_streaming
from repro.core.faults import (
    Deadline,
    DeadlineExceeded,
    FaultPlan,
    PartyUnavailable,
    SimClock,
    Transport,
    WallClock,
)
from repro.core.plan import (
    FAILOVER_LADDER,
    CoresetSpec,
    MemoryBudgetExceeded,
    MemoryWatchdog,
    PlanCache,
    compile_plan,
    live_bytes,
)
from repro.serve import CoresetService, InsertReceipt, QueryReceipt, ShedReceipt
from repro.serve.resilience import CircuitBreaker, TokenBucket

BLOCK = 256


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_programs():
    yield
    jax.clear_caches()


def _chunk(rng, rows=300, dims=(3, 2), labels=True):
    parts = [rng.normal(size=(rows, d)).astype(np.float32) for d in dims]
    y = rng.normal(size=(rows,)).astype(np.float32) if labels else None
    return parts, y


def _ds(rng, n=512, dims=(3, 3)):
    parts = [rng.normal(size=(n, d)).astype(np.float32) for d in dims]
    y = rng.normal(size=(n,)).astype(np.float32)
    return VFLDataset(parts, y)


# --------------------------------------------------------------------------
# Clock / Deadline seam
# --------------------------------------------------------------------------

def test_sim_clock_ticks_and_advances():
    c = SimClock(start=5.0, tick=0.5)
    assert c.now() == 5.0
    assert c.now() == 5.5          # auto-tick per read
    c.advance(2.0)
    assert c.peek() == 8.0         # peek never consumes a tick
    assert c.peek() == 8.0
    with pytest.raises(ValueError):
        c.advance(-1.0)
    with pytest.raises(ValueError):
        SimClock(tick=-0.1)


def test_wall_clock_monotonic_and_advance_noop():
    c = WallClock()
    a = c.now()
    c.advance(1e6)                 # simulated delay never sleeps
    assert c.now() - a < 60.0


def test_deadline_expiry_uses_geq_semantics():
    """A deadline landing EXACTLY on a check boundary counts as missed."""
    c = SimClock(start=0.0, tick=1.0)
    dl = Deadline.after(c, 1.0)    # consumes t=0 -> at=1.0
    # next read is exactly t=1.0: expired, not "one more superchunk"
    assert dl.expired(c)
    with pytest.raises(DeadlineExceeded) as ei:
        dl.check(c, "op")
    assert ei.value.op == "op" and ei.value.at == 1.0
    with pytest.raises(ValueError):
        Deadline.after(c, -1.0)


def test_deadline_remaining_and_zero_budget():
    c = SimClock(tick=0.0)
    dl = Deadline.after(c, 2.5)
    assert dl.remaining(c) == 2.5
    z = Deadline.after(c, 0.0)
    assert z.expired(c)            # zero budget is born expired


def test_transport_advances_bound_clock():
    c = SimClock(tick=0.0)
    # every op delayed, but under timeout_s: pure latency, no retries
    tr = Transport(FaultPlan(seed=0, delay=1.0, delay_s=0.25, timeout_s=1.0,
                             max_retries=0), clock=c)
    tr.deliver(CommSchedule.dis_round1(4), CommLedger())
    assert c.peek() == pytest.approx(tr.stats.sim_time_s)
    assert c.peek() > 0.0


# --------------------------------------------------------------------------
# TokenBucket / CircuitBreaker
# --------------------------------------------------------------------------

def test_token_bucket_burst_refill_and_retry_hint():
    b = TokenBucket(rate_per_s=2.0, burst=2)
    ok1, _ = b.try_take(0.0)
    ok2, _ = b.try_take(0.0)
    ok3, retry = b.try_take(0.0)
    assert (ok1, ok2, ok3) == (True, True, False)
    assert retry == pytest.approx(0.5)     # 1 token / 2 per second
    ok4, _ = b.try_take(0.6)               # refilled 1.2 tokens
    assert ok4
    with pytest.raises(ValueError):
        TokenBucket(0.0, 2)
    with pytest.raises(ValueError):
        TokenBucket(1.0, 0.5)


def test_breaker_full_lifecycle():
    br = CircuitBreaker(threshold=2, cooldown_s=10.0)
    assert br.allow(0.0) == (True, 0.0)
    br.record_failure(0.0, "boom1")
    assert br.state == "closed"            # 1 of 2
    br.record_failure(1.0, "boom2")
    assert br.state == "open" and br.trips == 1
    ok, retry = br.allow(5.0)
    # opened at t=1.0 (the tripping failure), so 6s of cooldown remain
    assert not ok and retry == pytest.approx(6.0)
    ok, _ = br.allow(11.0)                 # cooldown elapsed -> probe
    assert ok and br.state == "half_open"
    ok2, _ = br.allow(11.0)                # only ONE probe in flight
    assert not ok2
    br.record_failure(11.0, "probe died")
    assert br.state == "open" and br.trips == 2
    ok, _ = br.allow(22.0)
    br.record_success()                    # probe succeeded
    assert br.state == "closed" and br.failures == 0


def test_breaker_success_resets_consecutive_count():
    br = CircuitBreaker(threshold=3, cooldown_s=1.0)
    br.record_failure(0.0, "x")
    br.record_failure(0.0, "x")
    br.record_success()                    # intermittent, not consecutive
    br.record_failure(0.0, "x")
    br.record_failure(0.0, "x")
    assert br.state == "closed" and br.trips == 0


def test_breaker_neutral_requeues_probe_without_trip():
    br = CircuitBreaker(threshold=1, cooldown_s=10.0)
    br.record_failure(0.0, "x")
    assert br.state == "open" and br.trips == 1
    ok, _ = br.allow(20.0)
    assert ok and br.state == "half_open"
    br.record_neutral(20.0)                # e.g. the probe hit a deadline
    assert br.state == "open" and br.trips == 1
    ok, _ = br.allow(31.0)                 # next probe still fires
    assert ok


# --------------------------------------------------------------------------
# MemoryWatchdog + the failover ladder
# --------------------------------------------------------------------------

def test_live_bytes_counts_device_arrays():
    before = live_bytes()
    keep = jax.device_put(np.zeros((256, 256), np.float32))
    assert live_bytes() >= before + keep.nbytes


def test_watchdog_raises_with_census():
    wd = MemoryWatchdog(1)
    keep = jax.device_put(np.zeros(64, np.float32))  # anything live trips it
    with pytest.raises(MemoryBudgetExceeded) as ei:
        wd.check()
    assert ei.value.budget == 1 and ei.value.observed >= keep.nbytes
    assert wd.checks == 1 and wd.peak >= keep.nbytes
    with pytest.raises(ValueError):
        MemoryWatchdog(0)


def test_fallback_chain_follows_ladder():
    rng = np.random.default_rng(0)
    ds = _ds(rng)
    chains = {}
    for engine in ("materialized", "pipelined", "streamed", "batched"):
        spec = CoresetSpec(task="vrlr", budgets=16, engine=engine,
                           block_size=64, chunk_blocks=4,
                           num_seeds=2 if engine == "batched" else 1)
        chains[engine] = compile_plan(spec, ds).fallback_chain
    assert chains["materialized"] == ("pipelined", "streamed")
    assert chains["pipelined"] == ("streamed",)
    assert chains["streamed"] == ()
    assert chains["batched"] == ()
    # jit pins the engine — no ladder
    jspec = CoresetSpec(task="vrlr", budgets=16, engine="materialized",
                        block_size=64, jit=True)
    assert compile_plan(jspec, ds).fallback_chain == ()
    assert FAILOVER_LADDER == ("materialized", "pipelined", "streamed")


def test_failover_draw_identity_and_ledger_bill():
    """THE acceptance pin: a pipelined build forced over its memory budget
    falls back to streamed bit-identically; the ledger equals the
    successful engine's bill plus a zero-unit fallback/ attribution."""
    rng = np.random.default_rng(1)
    ds = _ds(rng)
    pipe = CoresetPipeline(ds)
    key = jax.random.PRNGKey(3)
    spec = CoresetSpec(task="vrlr", budgets=24, engine="pipelined",
                       block_size=64, chunk_blocks=2)

    led = CommLedger()
    out = pipe.build_failover(spec, key=key, ledger=led,
                              memory_budget_bytes=1)
    assert isinstance(out, FailoverOutcome)
    assert out.fallback == "pipelined->streamed"
    assert out.attempts[0].engine == "pipelined"
    assert "MemoryBudgetExceeded" in out.attempts[0].error
    assert any("failover: pipelined -> streamed" in n
               for n in out.plan.notes)

    led_ref = CommLedger()
    ref = build_coreset_streaming(
        "vrlr", ds, 24, key=key, block_size=64, ledger=led_ref)
    np.testing.assert_array_equal(np.asarray(out.coreset.indices),
                                  np.asarray(ref.indices))
    np.testing.assert_array_equal(np.asarray(out.coreset.weights),
                                  np.asarray(ref.weights))
    assert led.total == led_ref.total
    fb = {t: u for t, u in led.by_tag().items() if t.startswith("fallback/")}
    assert fb == {"fallback/pipelined->streamed": 0}


def test_failover_noop_when_first_engine_succeeds():
    rng = np.random.default_rng(2)
    ds = _ds(rng)
    pipe = CoresetPipeline(ds)
    spec = CoresetSpec(task="vrlr", budgets=16, engine="pipelined",
                       block_size=64, chunk_blocks=2)
    led = CommLedger()
    out = pipe.build_failover(spec, key=jax.random.PRNGKey(0), ledger=led)
    assert out.fallback is None and out.attempts == ()
    assert led.by_prefix("fallback/") == 0
    assert not any("failover" in n for n in out.plan.notes)


def test_failover_passes_engine_independent_errors_through():
    """Deadline and spec errors must not burn ladder rungs."""
    rng = np.random.default_rng(3)
    ds = _ds(rng)
    pipe = CoresetPipeline(ds)
    spec = CoresetSpec(task="vrlr", budgets=16, engine="pipelined",
                       block_size=64, chunk_blocks=2)
    c = SimClock(tick=1.0)
    dl = Deadline.after(c, 0.5)
    led = CommLedger()
    with pytest.raises(DeadlineExceeded):
        pipe.build_failover(spec, key=jax.random.PRNGKey(0), ledger=led,
                            probe=lambda: dl.check(c, "leaf"))
    assert led.total == 0          # rolled back, no fallback entry


# --------------------------------------------------------------------------
# Service: deadlines (edge cases), admission, breakers, failover
# --------------------------------------------------------------------------

def _svc(clock=None, **kw):
    svc = CoresetService(clock=clock, **kw)
    svc.register("t", task="vrlr", budget=16, seed=0, block_size=BLOCK)
    return svc


def test_insert_deadline_expired_at_admission_sheds_with_zero_work():
    clock = SimClock(tick=0.0)
    svc = _svc(clock)
    rng = np.random.default_rng(0)
    parts, y = _chunk(rng)
    r = svc.insert("t", parts, y, deadline=Deadline.after(clock, 0.0))
    assert isinstance(r, ShedReceipt)
    assert r.reason == "deadline" and r.op == "insert"
    st = svc.state("t")
    assert st.tree.num_chunks == 0 and st.ledger.total == 0
    assert st.sheds == 1 and svc.stats()["sheds"] == 1


def test_insert_deadline_mid_build_rolls_back():
    clock = SimClock(tick=1.0)       # every clock read costs a full second
    svc = _svc(clock)
    rng = np.random.default_rng(0)
    parts, y = _chunk(rng)
    ok = svc.insert("t", parts, y)   # no deadline: lands
    assert isinstance(ok, InsertReceipt)
    led_before = svc.state("t").ledger.total
    # admission passes (first read), the leaf probe's read expires it
    r = svc.insert("t", parts, y, deadline=Deadline.after(clock, 1.5))
    assert isinstance(r, ShedReceipt) and r.reason == "deadline"
    st = svc.state("t")
    assert st.tree.num_chunks == 1 and st.ledger.total == led_before


def test_insert_deadline_exactly_at_boundary_sheds():
    """The >= semantics end to end: a deadline landing exactly on the
    superchunk-boundary check is a miss, not a keep-going."""
    clock = SimClock(tick=1.0)
    svc = _svc(clock)
    rng = np.random.default_rng(0)
    parts, y = _chunk(rng)
    # admission consumes t=0; the first probe reads exactly t=1.0 == at
    r = svc.insert("t", parts, y, deadline=Deadline.after(clock, 1.0))
    assert isinstance(r, ShedReceipt) and r.reason == "deadline"
    assert svc.state("t").tree.num_chunks == 0


def test_query_degrades_to_union_under_deadline_pressure():
    clock = SimClock(tick=0.6)
    svc = _svc(clock)
    rng = np.random.default_rng(0)
    for _ in range(2):
        parts, y = _chunk(rng)
        assert isinstance(svc.insert("t", parts, y), InsertReceipt)
    m_active = svc.state("t").tree.m_active
    led0 = svc.state("t").ledger.total
    # admission passes at t=0.6 < 1.0; the pre-reduce check lands past it
    q = svc.query("t", reduce_to=8, deadline=Deadline.after(clock, 1.0))
    assert isinstance(q, QueryReceipt)
    assert q.degraded and q.m == m_active and q.comm_delta == 0
    assert svc.state("t").ledger.total == led0      # union is free
    # an unpressed query still reduces
    q2 = svc.query("t", reduce_to=8)
    assert not q2.degraded and q2.m == 8 and q2.comm_delta > 0


def test_query_deadline_expired_at_admission_sheds():
    clock = SimClock(tick=0.0)
    svc = _svc(clock)
    rng = np.random.default_rng(0)
    parts, y = _chunk(rng)
    svc.insert("t", parts, y)
    r = svc.query("t", reduce_to=8, deadline=Deadline.after(clock, 0.0))
    assert isinstance(r, ShedReceipt) and r.reason == "deadline"


def test_rate_limited_tenant_sheds_and_recovers():
    clock = SimClock(tick=0.0)
    svc = CoresetService(clock=clock)
    svc.register("g", task="vrlr", budget=16, seed=0, block_size=BLOCK,
                 rate_limit=(1.0, 2))
    rng = np.random.default_rng(0)
    outs = [svc.insert("g", *_chunk(rng)) for _ in range(3)]
    assert [isinstance(o, InsertReceipt) for o in outs] == [True, True, False]
    assert outs[2].reason == "rate_limit" and outs[2].retry_after_s > 0
    clock.advance(2.0)                     # refill
    assert isinstance(svc.insert("g", *_chunk(rng)), InsertReceipt)


def test_global_inflight_cap_sheds_overloaded():
    svc = CoresetService(max_inflight=1)
    svc.register("t", task="vrlr", budget=16, seed=0, block_size=BLOCK)
    rng = np.random.default_rng(0)
    parts, y = _chunk(rng)
    svc._inflight = 1                      # a request is mid-flight
    r = svc.insert("t", parts, y)
    assert isinstance(r, ShedReceipt) and r.reason == "overloaded"
    svc._inflight = 0
    assert isinstance(svc.insert("t", parts, y), InsertReceipt)
    with pytest.raises(ValueError):
        CoresetService(max_inflight=0)


def test_submit_queue_bound_sheds_queue_full():
    rng = np.random.default_rng(0)
    svc = CoresetService()
    svc.register("t", task="vrlr", budget=16, seed=0, block_size=BLOCK,
                 max_pending=2)
    svc.attach_dataset("ref", _ds(rng))
    k = jax.random.PRNGKey(0)
    t1 = svc.submit("t", "ref", 8, key=k)
    t2 = svc.submit("t", "ref", 8, key=jax.random.fold_in(k, 1))
    assert isinstance(t1, int) and isinstance(t2, int)
    r = svc.submit("t", "ref", 8, key=jax.random.fold_in(k, 2))
    assert isinstance(r, ShedReceipt) and r.reason == "queue_full"
    svc.flush()                            # drains the queue
    assert isinstance(svc.submit("t", "ref", 8,
                                 key=jax.random.fold_in(k, 3)), int)


def test_breaker_trips_isolates_and_recovers_per_tenant():
    clock = SimClock(tick=0.5)
    svc = CoresetService(clock=clock)
    tr = Transport(FaultPlan(seed=3, drop=1.0, max_retries=1), clock=clock)
    svc.register("bad", task="vrlr", budget=16, seed=0, block_size=BLOCK,
                 fault_policy="retry", transport=tr,
                 breaker_threshold=2, breaker_cooldown_s=50.0)
    svc.register("good", task="vrlr", budget=16, seed=1, block_size=BLOCK)
    rng = np.random.default_rng(0)
    for _ in range(2):
        with pytest.raises(PartyUnavailable):
            svc.insert("bad", *_chunk(rng))
    br = svc.stats()["breakers"]["bad"]
    assert br["state"] == "open" and br["trips"] == 1
    assert "PartyUnavailable" in br["last_error"]
    shed = svc.insert("bad", *_chunk(rng))
    assert isinstance(shed, ShedReceipt) and shed.reason == "breaker_open"
    assert shed.retry_after_s > 0
    # the good tenant is untouched
    assert isinstance(svc.insert("good", *_chunk(rng)), InsertReceipt)
    assert svc.stats()["breakers"]["good"]["state"] == "closed"
    # cooldown passes; the transport still drops, so the probe reopens
    clock.advance(100.0)
    with pytest.raises(PartyUnavailable):
        svc.insert("bad", *_chunk(rng))
    assert svc.stats()["breakers"]["bad"]["trips"] == 2


def test_service_failover_receipt_and_draw_identity():
    rng = np.random.default_rng(0)
    chunks = [_chunk(np.random.default_rng(s)) for s in range(2)]

    def play(**extra):
        svc = CoresetService()
        svc.register("t", task="vrlr", budget=16, seed=5, block_size=BLOCK,
                     chunk_blocks=2, **extra)
        recs = [svc.insert("t", p, y) for p, y in chunks]
        return svc, recs, svc.query("t", reduce_to=16)

    svc_ok, recs_ok, q_ok = play()
    svc_fb, recs_fb, q_fb = play(failover=True, memory_budget_bytes=1)
    assert all(r.fallback == "pipelined->streamed" for r in recs_fb)
    assert all(r.stats.fallback == "pipelined->streamed" for r in recs_fb)
    assert all(r.fallback is None for r in recs_ok)
    np.testing.assert_array_equal(np.asarray(q_ok.result.indices),
                                  np.asarray(q_fb.result.indices))
    np.testing.assert_array_equal(np.asarray(q_ok.result.weights),
                                  np.asarray(q_fb.result.weights))
    assert svc_fb.state("t").ledger.total == svc_ok.state("t").ledger.total
    assert svc_fb.state("t").tree.fallbacks == 2
    assert svc_fb.state("t").tree.last_fallback == "pipelined->streamed"
    assert svc_fb.stats()["fallbacks"] == 2


def test_evict_drops_pending_submits():
    rng = np.random.default_rng(0)
    svc = CoresetService()
    svc.register("a", task="vrlr", budget=16, seed=0, block_size=BLOCK)
    svc.register("b", task="vrlr", budget=16, seed=1, block_size=BLOCK)
    svc.attach_dataset("ref", _ds(rng))
    k = jax.random.PRNGKey(0)
    svc.submit("a", "ref", 8, key=k)
    svc.submit("a", "ref", 8, key=jax.random.fold_in(k, 1))
    tb = svc.submit("b", "ref", 8, key=jax.random.fold_in(k, 2))
    ev = svc.evict("a")
    assert ev.dropped_pending == 2 and svc.pending == 1
    out = svc.flush()
    assert set(out) == {tb}                # a's tickets never execute


def test_flush_deadline_defers_unstarted_groups():
    rng = np.random.default_rng(0)
    clock = SimClock(tick=0.0)
    svc = CoresetService(clock=clock)
    svc.attach_dataset("ref", _ds(rng))
    k = jax.random.PRNGKey(0)
    t1 = svc.submit("x", "ref", 8, key=k)
    t2 = svc.submit("x", "ref", 12, key=jax.random.fold_in(k, 1))  # 2nd group
    out = svc.flush(deadline=Deadline.after(clock, 0.0))   # born expired
    assert out == {} and svc.pending == 2
    out = svc.flush()
    assert set(out) == {t1, t2}


# --------------------------------------------------------------------------
# PlanCache aging
# --------------------------------------------------------------------------

def test_plan_cache_prune_by_idle_age():
    t = [0.0]
    pc = PlanCache(time_fn=lambda: t[0])
    rng = np.random.default_rng(0)
    ds_a, ds_b = _ds(rng, n=256), _ds(rng, n=512)
    spec = CoresetSpec(task="vrlr", budgets=8, engine="streamed",
                       block_size=64)
    pc.get(spec, ds_a)
    t[0] = 10.0
    pc.get(spec, ds_b)
    t[0] = 15.0
    assert pc.prune(max_idle_s=8.0) == 1       # only ds_a is stale
    assert len(pc) == 1 and pc.evictions == 1
    s = pc.stats()
    assert s["oldest_idle_s"] == 5.0 and s["newest_idle_s"] == 5.0
    pc.get(spec, ds_b)                          # still cached
    assert pc.hits == 1
    pc.clear()
    assert len(pc) == 0 and pc.stats()["oldest_idle_s"] == 0.0
    with pytest.raises(ValueError):
        pc.prune(-1.0)


def test_service_exposes_plan_cache_maintenance():
    t = [0.0]
    svc = CoresetService(plan_cache=PlanCache(time_fn=lambda: t[0]))
    svc.register("t", task="vrlr", budget=16, seed=0, block_size=BLOCK)
    rng = np.random.default_rng(0)
    svc.insert("t", *_chunk(rng))
    assert svc.stats()["plan_cache_size"] == 1
    t[0] = 100.0
    assert svc.stats()["plan_oldest_idle_s"] == 100.0
    assert svc.prune_plans(50.0) == 1
    assert svc.stats()["plan_cache_size"] == 0
    svc.insert("t", *_chunk(rng))
    svc.clear_plans()
    assert svc.stats()["plan_cache_size"] == 0


# --------------------------------------------------------------------------
# Ledger/receipt reconciliation
# --------------------------------------------------------------------------

def _reconcile(seed, n_chunks, n_queries, budget=12):
    """One tenant's ledger total must equal the sum of comm units across
    its insert/query/flush receipts — no unattributed cost."""
    rng = np.random.default_rng(seed)
    svc = CoresetService()
    svc.register("t", task="vrlr", budget=budget, seed=seed, block_size=BLOCK)
    svc.attach_dataset("ref", _ds(rng))
    total = 0
    for i in range(n_chunks):
        r = svc.insert("t", *_chunk(rng, rows=200 + 50 * i))
        total += r.stats.comm_delta
        for _ in range(n_queries):
            q = svc.query("t", reduce_to=budget)
            total += q.comm_delta
    tk = svc.submit("t", "ref", 8, key=jax.random.PRNGKey(seed + 99))
    out = svc.flush()
    total += out[tk].comm_units
    assert svc.state("t").ledger.total == total
    return total


def test_ledger_receipt_reconciliation_fixed_seed():
    # deterministic pin: the composed bill for this exact workload
    total = _reconcile(0, n_chunks=3, n_queries=1)
    assert total == _reconcile(0, n_chunks=3, n_queries=1)
    assert total > 0


def test_ledger_receipt_reconciliation_property():
    # hypothesis sweep of the same invariant over (seed, workload shape):
    # whatever the mix of inserts/queries/submits, the tenant's ledger
    # total is exactly the sum of comm units across its receipts
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(seed=st.integers(min_value=0, max_value=2**16),
           n_chunks=st.integers(min_value=1, max_value=2),
           n_queries=st.integers(min_value=0, max_value=1))
    @settings(max_examples=6, deadline=None)
    def prop(seed, n_chunks, n_queries):
        _reconcile(seed, n_chunks=n_chunks, n_queries=n_queries)

    prop()
