"""Fused single-pass kernel + one-dispatch construction path.

Covers the perf_opt acceptance criteria:
  * ``kmeans_assign_update`` (Pallas, interpret on CPU) matches the
    assignment + segment_sum composition across shapes/dtypes/weights;
  * the fused Lloyd step is STRUCTURALLY one pass over X — exactly one
    pallas_call, zero scatter-add (segment_sum) in its jaxpr — while the
    seed data flow is three;
  * all three kernels (kmeans_assign, leverage, kmeans_assign_update) are
    batch-safe: leading batch dims / jax.vmap fold into the grid and match
    the per-slice results;
  * ``build_coresets_batched`` runs with ``backend="pallas"`` and matches
    the ``ref`` backend numerically;
  * ``build_coreset_jit`` (scoring + DIS in ONE jitted dispatch) reproduces
    the sequential ``build_coreset`` for the same key;
  * the stacked party view pads/masks correctly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.fused_lloyd import count_primitives, structural_passes
from repro.core import (
    VFLDataset,
    build_coreset,
    build_coreset_jit,
    build_coresets_batched,
)
from repro.core.vkmc import kmeans, lloyd
from repro.kernels import kmeans_assign_update as _kau
from repro.kernels import ops, ref

SHAPES_NKD = [(17, 3, 5), (128, 8, 32), (300, 13, 90), (257, 10, 129), (1000, 64, 7)]


def _data(n, k, d, dtype=jnp.float32, seed=0):
    kx, kc, kw = jax.random.split(jax.random.PRNGKey(seed + n * 31 + k), 3)
    X = jax.random.normal(kx, (n, d), dtype)
    C = jax.random.normal(kc, (k, d), dtype)
    w = jax.random.uniform(kw, (n,))
    return X, C, w


def _dataset(key, n=400, d=9, T=3):
    kx, kt, kn = jax.random.split(key, 3)
    X = jax.random.normal(kx, (n, d))
    y = X @ jax.random.normal(kt, (d,)) + 0.1 * jax.random.normal(kn, (n,))
    return VFLDataset.from_dense(X, y, T=T)


# --------------------------------------------------------------------------
# Fused kernel vs the assignment + segment_sum composition
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,d", SHAPES_NKD)
@pytest.mark.parametrize("weighted", [False, True])
def test_fused_matches_composition_sweep(n, k, d, weighted):
    X, C, w = _data(n, k, d)
    w = w if weighted else None
    a_f, d2_f, cs_f, ws_f, cc_f = ops.kmeans_assign_update(X, C, w)
    # composition oracle on the SAME assignment (ties are then irrelevant)
    a_r, d2_r, cs_r, ws_r, cc_r = ref.kmeans_assign_update(X, C, w)
    np.testing.assert_allclose(np.asarray(d2_f), np.asarray(d2_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(cs_f), np.asarray(cs_r),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(ws_f), np.asarray(ws_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cc_f), np.asarray(cc_r),
                               rtol=1e-4, atol=1e-3)
    # unweighted wsum is an exact integer count partition of n
    if not weighted:
        assert float(np.asarray(ws_f).sum()) == n


def test_fused_bf16_points():
    X, C, w = _data(300, 7, 33, dtype=jnp.bfloat16)
    _, d2_f, cs_f, ws_f, _ = ops.kmeans_assign_update(X, C, w)
    _, d2_r, cs_r, ws_r, _ = ref.kmeans_assign_update(X, C, w)
    np.testing.assert_allclose(np.asarray(d2_f), np.asarray(d2_r), rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(cs_f), np.asarray(cs_r), rtol=5e-2, atol=5e-1)


def test_fused_block_size_invariance():
    X, C, w = _data(517, 9, 33)
    outs = [ops.kmeans_assign_update(X, C, w, block_n=bn) for bn in (64, 512)]
    np.testing.assert_array_equal(np.asarray(outs[0][0]), np.asarray(outs[1][0]))
    for i in (1, 2, 3, 4):
        np.testing.assert_allclose(np.asarray(outs[0][i]), np.asarray(outs[1][i]),
                                   rtol=1e-5, atol=1e-5)


def test_fused_assignment_matches_assign_kernel():
    """The fused kernel's assignment is the SAME computation as
    kmeans_assign — bit-equal including tie behaviour."""
    X, C, _ = _data(513, 17, 40)
    a1, d1 = ops.kmeans_assign(X, C)
    a2, d2, *_ = ops.kmeans_assign_update(X, C)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


# --------------------------------------------------------------------------
# Structural single-pass criterion
# --------------------------------------------------------------------------

def test_fused_step_is_one_pass():
    X, C, w = _data(400, 6, 24)

    def fused_step(x, c, ww):
        return _kau.kmeans_assign_update(x, c, ww, interpret=True)

    # 1 pallas_call, no segment_sum, 1 X-sized pass total
    assert structural_passes(fused_step, X, C, w) == (1, 0, 1)


def test_seed_step_is_multi_pass():
    X, C, w = _data(400, 6, 24)

    def seed_step(x, c, ww):
        from repro.kernels import kmeans_assign as _ka
        a, _ = _ka.kmeans_assign(x, c, interpret=True)
        k = c.shape[0]
        wsum = jax.ops.segment_sum(ww, a, num_segments=k)
        csum = jax.ops.segment_sum(ww[:, None] * x, a, num_segments=k)
        return wsum, csum

    # 1 pallas_call + 2 scatter-adds; 2 X-sized passes (the csum scatter
    # streams X again, the wsum scatter only streams the (n,) weights)
    assert structural_passes(seed_step, X, C, w) == (1, 2, 2)


def test_lloyd_is_one_pallas_call_per_iteration_no_segment_sum():
    """The fused Lloyd body: exactly one pallas_call in the scanned
    iteration, no segment_sum anywhere in the solver's jaxpr."""
    X, C, _ = _data(400, 6, 24)
    jx = jax.make_jaxpr(lambda x, c: lloyd(x, c, iters=3, use_kernel=True))(X, C)
    assert count_primitives(jx.jaxpr, {"scatter-add"}) == 0
    # the single fused call sits inside the scan body, traced once
    assert count_primitives(jx.jaxpr, {"pallas_call"}) == 1


# --------------------------------------------------------------------------
# Batch safety: leading batch dims / vmap fold into the grid
# --------------------------------------------------------------------------

def test_kmeans_assign_vmap_over_centers():
    X, _, _ = _data(300, 5, 13)
    Cs = jax.random.normal(jax.random.PRNGKey(3), (4, 5, 13))
    a_v, d_v = jax.vmap(lambda c: ops.kmeans_assign(X, c))(Cs)
    for b in range(4):
        a_b, d_b = ops.kmeans_assign(X, Cs[b])
        np.testing.assert_array_equal(np.asarray(a_v[b]), np.asarray(a_b))
        np.testing.assert_allclose(np.asarray(d_v[b]), np.asarray(d_b), rtol=1e-6)
    # leading-batch-dim form takes the same path
    a_l, d_l = ops.kmeans_assign(X, Cs)
    np.testing.assert_array_equal(np.asarray(a_l), np.asarray(a_v))


def test_leverage_vmap_both_batched():
    Xs = jax.random.normal(jax.random.PRNGKey(4), (3, 200, 17))
    A = jax.random.normal(jax.random.PRNGKey(5), (3, 17, 17))
    Ms = jnp.einsum("bij,bkj->bik", A, A) / 17.0
    out_v = jax.vmap(ops.leverage)(Xs, Ms)
    for b in range(3):
        np.testing.assert_allclose(np.asarray(out_v[b]),
                                   np.asarray(ops.leverage(Xs[b], Ms[b])),
                                   rtol=1e-5, atol=1e-5)
    out_l = ops.leverage(Xs, Ms)
    np.testing.assert_allclose(np.asarray(out_l), np.asarray(out_v), rtol=1e-6)


def test_fused_vmap_over_centers_and_parties():
    # seeds axis: X shared, C batched; block_n=64 -> 5-step grid, so the
    # scratch init/flush logic is exercised across steps under the
    # prepended vmap grid axis
    X, _, w = _data(300, 5, 13)
    Cs = jax.random.normal(jax.random.PRNGKey(6), (4, 5, 13))
    out_v = jax.vmap(lambda c: ops.kmeans_assign_update(X, c, w, block_n=64))(Cs)
    for b in range(4):
        out_b = ops.kmeans_assign_update(X, Cs[b], w, block_n=64)
        for o_v, o_b in zip(out_v, out_b):
            np.testing.assert_allclose(np.asarray(o_v[b]), np.asarray(o_b),
                                       rtol=1e-5, atol=1e-5)
    # party axis: X and C both batched, unit weights
    Xs = jax.random.normal(jax.random.PRNGKey(7), (3, 300, 13))
    Cp = jax.random.normal(jax.random.PRNGKey(8), (3, 5, 13))
    out_p = ops.kmeans_assign_update(Xs, Cp, block_n=64)
    for b in range(3):
        out_b = ops.kmeans_assign_update(Xs[b], Cp[b], block_n=64)
        for o_p, o_b in zip(out_p, out_b):
            np.testing.assert_allclose(np.asarray(o_p[b]), np.asarray(o_b),
                                       rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# Stacked party view
# --------------------------------------------------------------------------

def test_stacked_view_pads_and_masks():
    ds = _dataset(jax.random.PRNGKey(9), n=50, d=8, T=3)   # dims (3, 3, 2)
    st = ds.stacked()
    assert st.blocks.shape == (3, 50, 3) and st.dims == (3, 3, 2)
    for j, p in enumerate(ds.parts):
        dj = p.shape[1]
        np.testing.assert_array_equal(np.asarray(st.blocks[j, :, :dj]), np.asarray(p))
        assert float(jnp.abs(st.blocks[j, :, dj:]).sum()) == 0.0
        np.testing.assert_array_equal(np.asarray(st.mask[j]),
                                      np.arange(3) < dj)


def test_stacked_view_appends_labels():
    ds = _dataset(jax.random.PRNGKey(10), n=40, d=6, T=3)  # dims (2, 2, 2)
    st = ds.stacked(with_labels=True)
    assert st.blocks.shape == (3, 40, 3) and st.dims == (2, 2, 3)
    np.testing.assert_array_equal(np.asarray(st.blocks[-1, :, 2]), np.asarray(ds.y))
    unlabeled = VFLDataset(ds.parts, None)
    with pytest.raises(ValueError):
        unlabeled.stacked(with_labels=True)


# --------------------------------------------------------------------------
# One-dispatch construction paths
# --------------------------------------------------------------------------

@pytest.mark.parametrize("task,kw", [
    ("vrlr", {}), ("vkmc", {"k": 3, "local_iters": 3}), ("uniform", {})])
def test_build_coreset_jit_matches_sequential(task, kw):
    ds = _dataset(jax.random.PRNGKey(11))
    for seed in (0, 1):
        key = jax.random.PRNGKey(20 + seed)
        seq = build_coreset(task, ds, 50, key=key, backend="ref", **kw)
        fast = build_coreset_jit(task, ds, 50, key=key, backend="ref", **kw)
        np.testing.assert_array_equal(np.asarray(seq.indices), np.asarray(fast.indices))
        np.testing.assert_allclose(np.asarray(seq.weights), np.asarray(fast.weights),
                                   rtol=1e-6)
        assert seq.comm_units == fast.comm_units


def test_build_coreset_jit_caches_compilation():
    from repro.core.api import _JIT_BUILDERS
    ds = _dataset(jax.random.PRNGKey(12))
    build_coreset_jit("vrlr", ds, 30, key=jax.random.PRNGKey(0), backend="ref")
    size0 = len(_JIT_BUILDERS)
    build_coreset_jit("vrlr", ds, 30, key=jax.random.PRNGKey(1), backend="ref")
    assert len(_JIT_BUILDERS) == size0          # same geometry -> cache hit
    build_coreset_jit("vrlr", ds, 31, key=jax.random.PRNGKey(2), backend="ref")
    assert len(_JIT_BUILDERS) == size0 + 1      # new budget -> new entry


@pytest.mark.parametrize("task,kw", [
    ("vrlr", {}), ("vkmc", {"k": 3, "local_iters": 2})])
def test_batched_pallas_matches_ref(task, kw):
    """Acceptance: the batched builder runs with backend="pallas"
    (interpret on CPU) and agrees with the ref backend."""
    ds = _dataset(jax.random.PRNGKey(13), n=200, d=6, T=2)
    keys = jax.random.split(jax.random.PRNGKey(14), 2)
    gp = build_coresets_batched(task, ds, [25], keys=keys, backend="pallas", **kw)
    gr = build_coresets_batched(task, ds, [25], keys=keys, backend="ref", **kw)
    np.testing.assert_array_equal(np.asarray(gp.indices), np.asarray(gr.indices))
    np.testing.assert_allclose(np.asarray(gp.weights), np.asarray(gr.weights),
                               rtol=1e-5)


def test_kmeans_plusplus_cached_norm_d2_nonnegative():
    """The expanded-form D^2 seeding keeps sane geometry: centers are data
    rows and the incremental min-distances stay >= 0 (fp clamp)."""
    X = jax.random.normal(jax.random.PRNGKey(15), (500, 12)) * 3.0
    from repro.core.vkmc import kmeans_plusplus
    C = kmeans_plusplus(jax.random.PRNGKey(16), X, 6)
    Xn = np.asarray(X)
    for c in np.asarray(C):
        assert np.min(np.sum((Xn - c) ** 2, axis=1)) < 1e-6   # c is a data row
    # distinct centers with overwhelming probability on random data
    assert len({tuple(np.round(c, 5)) for c in np.asarray(C)}) == 6
