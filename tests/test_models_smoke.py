"""REQUIRED per-arch smoke tests: a reduced variant of each assigned
architecture (2 layers, d_model<=256, <=4 experts) runs one train step and
one decode step on CPU — output shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_arch
from repro.core.selector import SelectorConfig
from repro.models import decode_step, init_cache, init_params, loss_fn
from repro.optim.schedules import constant
from repro.train import make_train_step, train_state_init

ARCHS = all_arch_names()


def _batch(cfg, key, B=2, S=16):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0, cfg.vocab_size),
    }
    if cfg.frontend != "none" or cfg.kind == "encdec":
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.num_prefix, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_arch(arch).reduced()
    assert cfg.num_layers <= 2 and cfg.d_model <= 512 and cfg.num_experts <= 4
    key = jax.random.PRNGKey(hash(arch) % 2 ** 31)
    state = train_state_init(key, cfg)
    batch = _batch(cfg, key)
    step = jax.jit(make_train_step(cfg, constant(1e-3)))
    state, metrics = step(state, batch, jax.random.fold_in(key, 3))
    assert np.isfinite(float(metrics["loss"])), arch
    # params stay finite after the update
    for leaf in jax.tree_util.tree_leaves(state["params"]):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = get_arch(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B = 2
    cache = init_cache(cfg, B, 32)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))(params, cache, tok)
    assert logits.shape == (B, 1, cfg.vocab_pad)
    assert bool(jnp.all(jnp.isfinite(logits[..., : cfg.vocab_size])))
    assert int(cache2["pos"]) == 1
    # padded vocab columns are masked out
    if cfg.vocab_pad > cfg.vocab_size:
        assert float(logits[..., cfg.vocab_size :].max()) < -1e29


@pytest.mark.parametrize("arch", ["llama3.2-1b", "granite-moe-3b-a800m", "rwkv6-3b"])
def test_reduced_coreset_train_step(arch):
    """The paper's batch selector runs on every family."""
    cfg = get_arch(arch).reduced()
    key = jax.random.PRNGKey(2)
    state = train_state_init(key, cfg)
    step = jax.jit(make_train_step(cfg, constant(1e-3),
                                   SelectorConfig(mode="coreset", fraction=0.5)))
    state, metrics = step(state, _batch(cfg, key, B=8), jax.random.fold_in(key, 5))
    assert np.isfinite(float(metrics["loss"]))
