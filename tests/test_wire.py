"""The compressed wire: codecs, bit billing, and the planner codec axis.

Two layers:

* plain pytest — seeded round-trip/bit-contract checks over a fixed shape
  grid, spec/plan validation, and the raw_fp32 bit-identity pins across
  all four engines (these always run);
* hypothesis properties (skipped where hypothesis is absent, the
  container default) — ``decode(encode(x))`` within the documented
  tolerance and ``wire_bits == 8 * len(encode(x))`` for arbitrary
  payloads, the contract ``benchmarks/compression.py`` reconciles
  against receipts.
"""

import jax
import numpy as np
import pytest

from repro.core import (
    CommLedger,
    CommSchedule,
    CoresetPipeline,
    CoresetSpec,
    FaultPlan,
    Transport,
    VFLDataset,
    build_coresets_batched,
    compile_plan,
)
from repro.core.plan import PLAN_KEY_FIELDS
from repro.core.wire import (
    CODEC_LADDER,
    SPEC_CODECS,
    UNIT_BITS,
    WIRE_CODECS,
    WirePayload,
    choose_codec,
    encode_payloads,
    fmt_bits,
    get_codec,
    predict_dis_bits,
    predict_uniform_bits,
)

#: shape grid covering the seams: empty, scalar-ish, one int8 block,
#: one-past-a-block, multi-d, and a long row
FLOAT_SHAPES = [(0,), (1,), (5,), (64,), (65,), (4, 7), (300,)]


def _dataset(key, n=400, d=10, T=3):
    X = jax.random.normal(key, (n, d))
    y = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    return VFLDataset.from_dense(X, y, T=T)


# -- codec contract (seeded grid; always runs) -------------------------------

@pytest.mark.parametrize("name", sorted(WIRE_CODECS))
def test_float_roundtrip_within_documented_tolerance(name):
    c = get_codec(name)
    rng = np.random.default_rng(7)
    for shape in FLOAT_SHAPES:
        x = (10.0 * rng.standard_normal(shape)).astype(np.float32)
        blob = c.encode(x)
        out = c.decode(blob, x.shape, x.dtype)
        assert out.shape == x.shape and out.dtype == np.float32
        if c.lossless:
            np.testing.assert_array_equal(out, x)
        else:
            tol = c.tolerance * (float(np.max(np.abs(x))) if x.size else 0.0)
            assert float(np.max(np.abs(out - x), initial=0.0)) <= tol
        # float payloads are shape-determined under every codec: the
        # contract the ledger bills by is EXACT, not a bound
        assert c.bits_exact(np.float32)
        assert 8 * len(blob) == c.wire_bits(shape, "float32")


@pytest.mark.parametrize("name", sorted(WIRE_CODECS))
def test_int_payloads_exact_under_every_codec(name):
    c = get_codec(name)
    rng = np.random.default_rng(11)
    for arr in (np.sort(rng.integers(0, 10**6, 200)).astype(np.int32),
                np.array([], np.int32),
                np.array([0, -5, 2**31 - 1, -2**31], np.int32)):
        blob = c.encode(arr)
        out = c.decode(blob, arr.shape, arr.dtype)
        np.testing.assert_array_equal(out, arr)
        assert c.exact_for(arr.dtype)
        if c.bits_exact(arr.dtype):
            assert 8 * len(blob) == c.wire_bits(arr.shape, "int32")
        else:  # varint: measured never exceeds the certified bound
            assert 8 * len(blob) <= c.wire_bits(arr.shape, "int32")


def test_quantization_errors_are_really_bounded_not_zero():
    # the lossy codecs must actually lose bits on a generic payload —
    # otherwise the tolerance contract is vacuous
    rng = np.random.default_rng(3)
    x = rng.standard_normal(257).astype(np.float32)
    for name in ("fp16", "int8_blockscale"):
        c = get_codec(name)
        out = c.decode(c.encode(x), x.shape, x.dtype)
        assert not np.array_equal(out, x)
        assert not c.lossless and not c.exact_for(np.float32)


def test_nonfinite_and_constant_blocks_survive_encoding():
    c = get_codec("int8_blockscale")
    x = np.zeros(130, np.float32)
    np.testing.assert_array_equal(c.decode(c.encode(x), x.shape, x.dtype), x)
    x[5] = np.inf
    out = c.decode(c.encode(x), x.shape, x.dtype)
    assert np.all(np.isfinite(out))


def test_get_codec_unknown_name():
    with pytest.raises(ValueError, match="unknown wire codec"):
        get_codec("gzip")


def test_fmt_bits_units():
    assert fmt_bits(100) == "100b"
    assert fmt_bits(8 * 2048) == "2.00KiB"
    assert fmt_bits(8 * 3 * (1 << 20)) == "3.00MiB"


def test_wire_payload_of_and_measured():
    p = WirePayload.of((100,), "float32", "fp16")
    assert p.bits == get_codec("fp16").wire_bits((100,), "float32")
    m = WirePayload.measured((100,), "int32", "delta_varint", 816)
    assert m.bits == 816
    with pytest.raises(ValueError, match="negative wire bits"):
        WirePayload((4,), "float32", "raw_fp32", -1)


def test_encode_payloads_bits_match_blobs():
    rng = np.random.default_rng(0)
    payloads = {j: np.sort(rng.integers(0, 5000, 50)).astype(np.int32)
                for j in range(3)}
    blobs, bits = encode_payloads("delta_varint", payloads)
    assert bits == {j: 8 * len(b) for j, b in blobs.items()}


# -- budget walk -------------------------------------------------------------

def test_choose_codec_walks_the_ladder_fidelity_first():
    bits = {"raw_fp32": 1000, "fp16": 600, "int8_blockscale": 300}
    assert choose_codec("auto", None, bits) == ("raw_fp32", False, "")
    name, exceeded, note = choose_codec("auto", 700, bits)
    assert (name, exceeded) == ("fp16", False) and "fp16" in note
    name, exceeded, note = choose_codec("auto", 100, bits)
    assert (name, exceeded) == ("int8_blockscale", True)
    assert "unmeetable" in note
    name, exceeded, note = choose_codec("fp16", 100, bits)
    assert (name, exceeded) == ("fp16", True) and "exceeds" in note


def test_predict_dis_bits_is_the_per_codec_wire_sum():
    T, m, cells = 3, 64, 1024
    for name in CODEC_LADDER:
        c = get_codec(name)
        want = (T * (c.wire_bits((cells,), "float32") + UNIT_BITS)
                + c.wire_bits((m,), "int32") + 2 * T * m * UNIT_BITS)
        assert predict_dis_bits(T, m, cells, name) == want
    assert predict_uniform_bits(T, m) == T * m * UNIT_BITS


# -- spec / plan axis --------------------------------------------------------

def test_spec_codec_validation():
    for bad in ("gzip", "delta_varint"):  # not a spec-selectable table format
        with pytest.raises(ValueError):
            CoresetSpec(task="vrlr", budgets=32, codec=bad)
    with pytest.raises(ValueError, match="jit"):
        CoresetSpec(task="vrlr", budgets=32, codec="fp16", jit=True)
    with pytest.raises(ValueError, match="batched"):
        CoresetSpec(task="vrlr", budgets=32, codec="int8_blockscale",
                    engine="batched")
    with pytest.raises(ValueError, match="comm_budget_bits"):
        CoresetSpec(task="vrlr", budgets=32, comm_budget_bits=0)
    assert "codec" in PLAN_KEY_FIELDS and "comm_budget_bits" in PLAN_KEY_FIELDS


def test_plan_predicts_bits_and_resolves_auto_codec():
    ds = _dataset(jax.random.PRNGKey(0), n=1024)
    spec = CoresetSpec(task="vrlr", budgets=64, engine="materialized",
                       backend="ref")
    plan = compile_plan(spec, ds)
    assert plan.codec == "raw_fp32"
    assert plan.predicted_wire_bits == predict_dis_bits(ds.T, 64, ds.n,
                                                        "raw_fp32")
    assert "on the wire" in plan.describe()

    tight = predict_dis_bits(ds.T, 64, ds.n, "fp16")
    spec2 = CoresetSpec(task="vrlr", budgets=64, engine="materialized",
                        backend="ref", codec="auto", comm_budget_bits=tight)
    plan2 = compile_plan(spec2, ds)
    assert plan2.codec == "fp16" and not plan2.comm_budget_exceeded
    assert plan2.predicted_wire_bits == tight
    assert "comm budget" in plan2.describe()

    spec3 = CoresetSpec(task="vrlr", budgets=64, engine="materialized",
                        backend="ref", codec="auto", comm_budget_bits=1)
    plan3 = compile_plan(spec3, ds)
    assert plan3.codec == "int8_blockscale" and plan3.comm_budget_exceeded


def test_ledger_bits_column_and_summary():
    led = CommLedger()
    led.party_to_server("x/table", 0, 4, 4096)
    led.party_to_server("x/scalar", 1, 1)       # defaults to UNIT_BITS
    assert led.total_bits == 4096 + UNIT_BITS
    assert led.by_tag(bits=True) == {"x/table": 4096, "x/scalar": UNIT_BITS}
    assert "on the wire" in led.summary()


def test_schedule_payload_bits():
    p = WirePayload.of((500,), "float32", "raw_fp32")
    sched = CommSchedule.dis(3, 16, counts=[16, 0, 0], round1_payload=p)
    # G_j ops bill the table row; every other op stays at UNIT_BITS/unit
    assert sched.total_bits == (3 * (p.bits + UNIT_BITS)
                                + UNIT_BITS * (16 + 2 * 3 * 16))


# -- raw_fp32 bit-identity pins across the engines ---------------------------

@pytest.mark.parametrize("engine", ["materialized", "streamed", "pipelined"])
def test_raw_bits_reconcile_across_engines(engine):
    ds = _dataset(jax.random.PRNGKey(2), n=600)
    spec = CoresetSpec(task="vrlr", budgets=48, engine=engine, backend="ref",
                       block_size=128)
    pipe = CoresetPipeline(ds)
    plan = pipe.plan(spec)
    led0, led1 = CommLedger(), CommLedger()
    key = jax.random.PRNGKey(3)
    cs0 = pipe.build(spec, key=key, ledger=led0)
    cs1 = pipe.build(spec, key=key, ledger=led1,
                     transport=Transport(FaultPlan.none()))
    np.testing.assert_array_equal(np.asarray(cs0.indices),
                                  np.asarray(cs1.indices))
    np.testing.assert_array_equal(np.asarray(cs0.weights),
                                  np.asarray(cs1.weights))
    assert led0.by_tag() == led1.by_tag()
    assert led0.by_tag(bits=True) == led1.by_tag(bits=True)
    for cs, led in ((cs0, led0), (cs1, led1)):
        assert cs.comm_bits == led.total_bits == plan.predicted_wire_bits
        assert cs.comm_units == led.total


def test_raw_bits_reconcile_batched():
    ds = _dataset(jax.random.PRNGKey(4), n=300)
    grid = build_coresets_batched("vrlr", ds, [32], key=jax.random.PRNGKey(5),
                                  backend="ref")
    led = CommLedger()
    cs = grid.coreset(0, 0, ledger=led)
    assert cs.comm_bits == led.total_bits
    assert led.by_tag(bits=True)["dis/round1/G_j"] == ds.T * 32 * ds.n


def test_lossy_codec_requires_a_transport():
    ds = _dataset(jax.random.PRNGKey(6), n=200)
    spec = CoresetSpec(task="vrlr", budgets=16, engine="materialized",
                       backend="ref", codec="fp16")
    with pytest.raises(ValueError, match="transport"):
        CoresetPipeline(ds).build(spec, key=jax.random.PRNGKey(7))
    cs = CoresetPipeline(ds).build(spec, key=jax.random.PRNGKey(7),
                                   ledger=(led := CommLedger()),
                                   transport=Transport(FaultPlan.none()))
    assert cs.comm_bits == led.total_bits
    assert (led.by_tag(bits=True)["dis/round1/G_j"]
            == ds.T * get_codec("fp16").wire_bits((ds.n,), "float32"))


def test_compressed_build_bills_fewer_bits_than_raw():
    ds = _dataset(jax.random.PRNGKey(8), n=2048)
    key = jax.random.PRNGKey(9)
    bills = {}
    for name in ("raw_fp32", "int8_blockscale"):
        spec = CoresetSpec(task="vrlr", budgets=64, engine="materialized",
                           backend="ref", codec=name)
        led = CommLedger()
        CoresetPipeline(ds).build(spec, key=key, ledger=led,
                                  transport=Transport(FaultPlan.none()))
        bills[name] = led.total_bits
    assert bills["int8_blockscale"] < bills["raw_fp32"]


# -- hypothesis properties (skipped without hypothesis) ----------------------

def test_property_roundtrip_and_packed_bits():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(name=st.sampled_from(sorted(WIRE_CODECS)),
           data=st.data(),
           shape=st.one_of(
               st.integers(0, 300).map(lambda n: (n,)),
               st.tuples(st.integers(1, 12), st.integers(1, 12))))
    def prop(name, data, shape):
        c = get_codec(name)
        size = int(np.prod(shape))
        vals = data.draw(st.lists(
            st.floats(-1e6, 1e6, width=32), min_size=size, max_size=size))
        x = np.asarray(vals, np.float32).reshape(shape)
        blob = c.encode(x)
        assert 8 * len(blob) == c.wire_bits(shape, "float32")
        out = c.decode(blob, shape, np.float32)
        if c.lossless:
            np.testing.assert_array_equal(out, x)
        else:
            tol = c.tolerance * (float(np.max(np.abs(x))) if size else 0.0)
            # documented bound plus fp dust from the scale multiply
            assert float(np.max(np.abs(out - x), initial=0.0)) <= tol * (1 + 1e-5)
    prop()


def test_property_varint_ints_exact_and_bounded():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(name=st.sampled_from(sorted(WIRE_CODECS)),
           vals=st.lists(st.integers(-2**31, 2**31 - 1), max_size=200))
    def prop(name, vals):
        c = get_codec(name)
        x = np.asarray(vals, np.int32)
        blob = c.encode(x)
        np.testing.assert_array_equal(c.decode(blob, x.shape, x.dtype), x)
        if c.bits_exact(x.dtype):
            assert 8 * len(blob) == c.wire_bits(x.shape, "int32")
        else:
            assert 8 * len(blob) <= c.wire_bits(x.shape, "int32")
    prop()
