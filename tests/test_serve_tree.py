"""Merge-and-reduce coreset tree: leaf draw-identity, insert census (no
full-data rescore), ledger composition + insert-order invariance, global
index integrity, query determinism, and graceful rel_error degradation of a
height-h tree vs the flat equal-budget build.
"""

import math

import jax
import numpy as np
import pytest

from repro.core import CommLedger, PlanCache, VFLDataset
from repro.core.api import build_coreset, build_coreset_streaming
from repro.core.comm import CommSchedule
from repro.core.solve import evaluate, fit_kmeans, fit_ridge, full_data_coreset
from repro.serve import CoresetTree, merge_reduce

BLOCK = 256


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_programs():
    # The tree tests compile many small per-shape programs; drop them when
    # the module finishes so the accumulated executables don't destabilize
    # XLA:CPU compiles in later test modules of the same process.
    yield
    jax.clear_caches()


def _chunks(seed, num, rows, dims=(3, 2), labels=True):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(num):
        parts = [rng.normal(size=(rows, d)).astype(np.float32) for d in dims]
        theta = np.linspace(1.0, -1.0, dims[0]).astype(np.float32)
        y = (parts[0] @ theta
             + 0.1 * rng.normal(size=rows).astype(np.float32)) if labels else None
        out.append((parts, y))
    return out


def _stream_ds(chunks):
    """The dense view of the whole stream (what the tree never re-reads)."""
    T = len(chunks[0][0])
    parts = [np.concatenate([c[0][j] for c in chunks]) for j in range(T)]
    y = None if chunks[0][1] is None else np.concatenate([c[1] for c in chunks])
    return VFLDataset(parts, y)


# -- leaves ------------------------------------------------------------------


@pytest.mark.parametrize("task,params", [("vrlr", {}), ("vkmc", {"k": 3})])
def test_leaf_draw_identical_to_direct_pipelined_build(task, params):
    labels = task == "vrlr"
    chunks = _chunks(0, 2, 400, labels=labels)
    tree = CoresetTree(task, 48, key=jax.random.PRNGKey(5),
                       block_size=BLOCK, params=params)
    for parts, y in chunks:
        tree.insert(parts, y)
    # replay each leaf directly through the streaming shim with leaf_key(i)
    # (leaves build at node_budget = headroom * budget)
    for i, (parts, y) in enumerate(chunks):
        ds = VFLDataset(parts, y)
        led = CommLedger()
        direct = build_coreset_streaming(task, ds, tree.node_budget,
                                         key=tree.leaf_key(i),
                                         block_size=BLOCK, ledger=led,
                                         **params)
        # leaf 1 was merged away, but leaf 0's materialization survives in
        # the level-1 union's FIRST half only after re-sampling; instead
        # rebuild the tree one chunk at a time and check the fresh leaf.
        t2 = CoresetTree(task, 48, key=jax.random.PRNGKey(5),
                         block_size=BLOCK, params=params)
        for parts2, y2 in chunks[: i + 1]:
            t2.insert(parts2, y2)
        if i % 2 == 0:          # even leaf index -> still at level 0
            leaf = t2.levels[0].cs
            offset = i * 400
            np.testing.assert_array_equal(
                np.asarray(direct.indices) + offset, leaf.indices)
            np.testing.assert_allclose(np.asarray(direct.weights),
                                       leaf.weights, rtol=1e-6)
            # leaf bill == the direct build's bill
            assert direct.comm_units == led.total


def test_leaf_rows_match_stream_rows():
    chunks = _chunks(1, 3, 300)
    stream = _stream_ds(chunks)
    tree = CoresetTree("vrlr", 32, key=jax.random.PRNGKey(0), block_size=BLOCK)
    for parts, y in chunks:
        tree.insert(parts, y)
    q = tree.query()
    for j in range(stream.T):
        np.testing.assert_array_equal(
            np.asarray(stream.parts[j])[q.indices], q.parts[j])
    np.testing.assert_array_equal(np.asarray(stream.y)[q.indices], q.y)
    assert (q.weights > 0).all()


# -- insert census: never a full-data rescore --------------------------------


def test_insert_census_o_log_n():
    m = 32
    tree = CoresetTree("vrlr", m, key=jax.random.PRNGKey(2), block_size=BLOCK)
    nb = tree.node_budget            # headroom * m rows per node
    assert nb == 2 * m
    total_rows = 0
    for i, (parts, y) in enumerate(_chunks(3, 9, 250)):
        stats = tree.insert(parts, y)
        total_rows += 250
        # binary-counter carry bound: #merges = #trailing ones of i
        carries = bin(i)[2:][::-1]
        expect = len(carries) - len(carries.lstrip("1"))
        assert stats.merges == expect
        assert stats.merges <= math.floor(math.log2(i + 1)) + 1
        assert stats.leaf_builds == 1
        # census: the chunk itself + one 2-node union per merge — NEVER n_total
        assert stats.rescored_rows == 250 + 2 * nb * stats.merges
        if i > 0:
            assert stats.rescored_rows < total_rows
        assert stats.height_after == tree.height
    assert tree.n_total == total_rows
    assert tree.num_chunks == 9
    # 9 = 0b1001 -> two occupied levels
    assert tree.num_nodes == 2 and tree.m_active == 2 * nb


def test_insert_comm_delta_is_exact():
    """Each insert's ledger delta = leaf DIS + per-merge (merge + DIS),
    all at node_budget = headroom * m."""
    m, T = 40, 2
    nb = 2 * m                       # default headroom
    leaf_bill = CommSchedule.dis_total(T, nb)
    merge_bill = CommSchedule.merge(T, nb, nb).total + leaf_bill
    tree = CoresetTree("vrlr", m, key=jax.random.PRNGKey(3), block_size=BLOCK)
    assert tree.node_budget == nb
    for parts, y in _chunks(4, 4, 200):
        stats = tree.insert(parts, y)
        assert stats.comm_delta == leaf_bill + stats.merges * merge_bill
    assert tree.ledger.total == 4 * leaf_bill + 3 * merge_bill
    # the root node's composed comm_units equals the whole ledger
    assert tree.query().comm_units == tree.ledger.total


# -- merge_reduce semantics --------------------------------------------------


def test_merge_reduce_folds_weights_and_composes_comm():
    chunks = _chunks(5, 2, 300)
    mats, led = [], CommLedger()
    for i, (parts, y) in enumerate(chunks):
        ds = VFLDataset(parts, y)
        cs = build_coreset("vrlr", ds, 30, key=jax.random.PRNGKey(i),
                           backend="ref")
        from repro.core.coreset import MaterializedCoreset
        mats.append(MaterializedCoreset.from_coreset(cs, ds, offset=300 * i))
    merged = merge_reduce("vrlr", mats, 30, key=jax.random.PRNGKey(9),
                          ledger=led, backend="ref")
    assert merged.m == 30 and merged.T == mats[0].T
    assert (merged.weights > 0).all()
    # global ids come from the union, rows gathered consistently
    stream = _stream_ds(chunks)
    for j in range(stream.T):
        np.testing.assert_array_equal(
            np.asarray(stream.parts[j])[merged.indices], merged.parts[j])
    # billing: Thm 2.5 consume for both children + the union re-sample DIS
    T = mats[0].T
    assert led.by_prefix("merge/") == 2 * (30 + 30) * T
    assert led.total == 2 * 60 * T + CommSchedule.dis_total(T, 30)
    assert merged.comm_units == mats[0].comm_units + mats[1].comm_units + led.total


def test_merge_reduce_uniform_task():
    chunks = _chunks(6, 2, 200, labels=False)
    from repro.core.coreset import MaterializedCoreset
    mats = []
    for i, (parts, _) in enumerate(chunks):
        ds = VFLDataset(parts)
        cs = build_coreset("uniform", ds, 25, key=jax.random.PRNGKey(i),
                           backend="ref")
        mats.append(MaterializedCoreset.from_coreset(cs, ds, offset=200 * i))
    merged = merge_reduce("uniform", mats, 25, key=jax.random.PRNGKey(1))
    assert merged.m == 25 and (merged.weights > 0).all()


def test_tree_rejects_bad_inputs():
    tree = CoresetTree("vrlr", 16, key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        tree.query()
    with pytest.raises(ValueError):
        CoresetTree("vrlr", 0, key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        CoresetTree("vrlr", 16, key=jax.random.PRNGKey(0), headroom=0)
    with pytest.raises(ValueError):
        tree.insert([np.zeros((0, 2), np.float32)])


# -- determinism -------------------------------------------------------------


def test_query_deterministic_until_next_insert():
    tree = CoresetTree("vrlr", 24, key=jax.random.PRNGKey(8), block_size=BLOCK)
    chunks = _chunks(7, 3, 220)
    for parts, y in chunks[:2]:
        tree.insert(parts, y)
    q1 = tree.query(reduce_to=24)
    q2 = tree.query(reduce_to=24)
    np.testing.assert_array_equal(q1.indices, q2.indices)
    np.testing.assert_allclose(q1.weights, q2.weights)
    tree.insert(*chunks[2])
    q3 = tree.query(reduce_to=24)
    assert not np.array_equal(q1.indices, q3.indices[: q1.m]) or \
        tree.num_chunks == 2  # key advanced with the insert count


def test_tree_replays_exactly():
    chunks = _chunks(9, 5, 180)
    def run():
        t = CoresetTree("vrlr", 20, key=jax.random.PRNGKey(4),
                        block_size=BLOCK, plan_cache=PlanCache())
        for parts, y in chunks:
            t.insert(parts, y)
        return t.query(reduce_to=20)
    a, b = run(), run()
    np.testing.assert_array_equal(a.indices, b.indices)
    np.testing.assert_allclose(a.weights, b.weights)
    assert a.comm_units == b.comm_units


# -- ledger: insert order never changes the composed total -------------------


def test_ledger_insert_order_invariance():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(st.lists(st.sampled_from([120, 180, 240]), min_size=1, max_size=5),
           st.randoms(use_true_random=False))
    @settings(max_examples=8, deadline=None)
    def prop(sizes, rnd):
        perm = list(sizes)
        rnd.shuffle(perm)
        rng = np.random.default_rng(0)
        def run(order):
            t = CoresetTree("vrlr", 16, key=jax.random.PRNGKey(1),
                            block_size=BLOCK)
            for r in order:
                parts = [rng.normal(size=(r, d)).astype(np.float32)
                         for d in (3, 2)]
                y = rng.normal(size=(r,)).astype(np.float32)
                t.insert(parts, y)
            return t.ledger.total
        # the composed bill depends only on (chunk count, budget, T) — the
        # leaf DIS bill is chunk-size-free and the carry chain is
        # count-determined — so any permutation of sizes bills identically
        assert run(sizes) == run(perm)

    prop()


def test_ledger_insert_order_invariance_fixed():
    """hypothesis-free version of the invariant (the container may lack
    hypothesis): three fixed permutations of mixed chunk sizes compose to
    the same ledger total."""
    rng = np.random.default_rng(0)
    def run(order):
        t = CoresetTree("vrlr", 16, key=jax.random.PRNGKey(1),
                        block_size=BLOCK)
        for r in order:
            parts = [rng.normal(size=(r, d)).astype(np.float32)
                     for d in (3, 2)]
            y = rng.normal(size=(r,)).astype(np.float32)
            t.insert(parts, y)
        return t.ledger.total
    sizes = [120, 240, 180, 120, 240]
    totals = {run(sizes), run(sizes[::-1]),
              run([240, 120, 120, 240, 180])}
    assert len(totals) == 1


# -- end-to-end: tree vs flat build ------------------------------------------


@pytest.mark.parametrize("task", ["vrlr", "vkmc"])
def test_tree_rel_error_degrades_gracefully(task):
    """A height-h tree's reduced query stays usable: its full-data rel_error
    is within a constant factor of the flat equal-budget batch build (the
    2x gate at n=1e5 lives in benchmarks/serve.py; this is the small-n
    smoke version with a looser factor for draw noise)."""
    labels = task == "vrlr"
    chunks = _chunks(11, 8, 1500, dims=(4, 3), labels=labels)
    stream = _stream_ds(chunks)
    m = 256
    params = {} if labels else {"k": 4}
    tree = CoresetTree(task, m, key=jax.random.PRNGKey(6),
                       block_size=1024, params=params)
    for parts, y in chunks:
        tree.insert(parts, y)
    q = tree.query(reduce_to=m)
    flat = build_coreset(task, stream, m, key=jax.random.PRNGKey(60),
                         backend="ref", **params)
    kev = jax.random.PRNGKey(7)
    if task == "vrlr":
        base = fit_ridge(stream, full_data_coreset(stream), 0.1).params
        r_tree = evaluate(stream, fit_ridge(stream, q.coreset(), 0.1),
                          baseline=base).rel_error
        r_flat = evaluate(stream, fit_ridge(stream, flat, 0.1),
                          baseline=base).rel_error
    else:
        base = fit_kmeans(stream, full_data_coreset(stream), 4, key=kev,
                          restarts=3, backend="ref").params
        r_tree = evaluate(stream, fit_kmeans(stream, q.coreset(), 4,
                                             key=jax.random.fold_in(kev, 1),
                                             restarts=3, backend="ref"),
                          baseline=base).rel_error
        r_flat = evaluate(stream, fit_kmeans(stream, flat, 4,
                                             key=jax.random.fold_in(kev, 2),
                                             restarts=3, backend="ref"),
                          baseline=base).rel_error
    # both small, and the tree within a constant factor of flat (fixed keys
    # make this deterministic; with default headroom=2 the measured tree
    # error sits well inside both gates — see benchmarks/serve.py for the
    # seed-averaged 2x gate)
    assert r_tree < 0.25
    assert r_tree <= max(8.0 * max(r_flat, 0.0), 0.05)
