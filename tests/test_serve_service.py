"""Multi-tenant CoresetService: tenant isolation + draw determinism, shared
plan cache across tenants, cross-tenant batched flush (one dispatch per
group, per-request draws unchanged), receipts and eviction."""

import jax
import numpy as np
import pytest

from repro.core import PlanCache, VFLDataset
from repro.core.api import build_coreset
from repro.serve import CoresetService, CoresetTree

BLOCK = 256


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_programs():
    # Drop this module's compiled programs on exit (see test_serve_tree).
    yield
    jax.clear_caches()


def _chunk(rng, rows=300, dims=(3, 2), labels=True):
    parts = [rng.normal(size=(rows, d)).astype(np.float32) for d in dims]
    y = rng.normal(size=(rows,)).astype(np.float32) if labels else None
    return parts, y


def test_register_insert_query_evict_lifecycle():
    svc = CoresetService()
    svc.register("a", task="vrlr", budget=24, seed=1, block_size=BLOCK)
    rng = np.random.default_rng(0)
    for i in range(3):
        parts, y = _chunk(rng)
        r = svc.insert("a", parts, y)
        assert r.tenant == "a" and r.chunk_idx == i
        assert r.stats.leaf_builds == 1 and r.latency_s > 0
        assert r.ledger_total == svc.state("a").ledger.total
    q = svc.query("a", reduce_to=24)
    assert q.m == 24 and (q.result.weights > 0).all()
    ev = svc.evict("a")
    assert ev.chunks == 3 and ev.rows == 900 and ev.ledger_total > 0
    with pytest.raises(KeyError):
        svc.query("a")
    with pytest.raises(ValueError):
        svc.register("b", budget=8)
        svc.register("b", budget=8)


def test_tenant_draws_isolated_and_deterministic():
    """A tenant's coresets depend only on its own (seed, insert sequence) —
    other tenants' traffic cannot perturb them."""
    chunks = [_chunk(np.random.default_rng(s)) for s in range(4)]

    def run(with_noise):
        svc = CoresetService()
        svc.register("t", task="vrlr", budget=20, seed=7, block_size=BLOCK)
        if with_noise:
            svc.register("noisy", task="vkmc", budget=16, seed=3,
                         block_size=BLOCK, k=3)
        for i, (parts, y) in enumerate(chunks):
            svc.insert("t", parts, y)
            if with_noise:
                np_parts, _ = _chunk(np.random.default_rng(100 + i),
                                     labels=False)
                svc.insert("noisy", np_parts)
                svc.query("noisy")
        return svc.query("t", reduce_to=20).result

    a, b = run(False), run(True)
    np.testing.assert_array_equal(a.indices, b.indices)
    np.testing.assert_allclose(a.weights, b.weights)


def test_service_tree_matches_standalone_tree():
    chunks = [_chunk(np.random.default_rng(s)) for s in range(3)]
    svc = CoresetService()
    svc.register("t", task="vrlr", budget=16, seed=5, block_size=BLOCK)
    tree = CoresetTree("vrlr", 16, key=jax.random.PRNGKey(5),
                       block_size=BLOCK)
    for parts, y in chunks:
        svc.insert("t", parts, y)
        tree.insert(parts, y)
    a = svc.query("t", reduce_to=16).result
    b = tree.query(reduce_to=16)
    np.testing.assert_array_equal(a.indices, b.indices)
    assert svc.state("t").ledger.total == tree.ledger.total


def test_plan_cache_shared_across_tenants():
    svc = CoresetService()
    for name, seed in (("a", 1), ("b", 2), ("c", 3)):
        svc.register(name, task="vrlr", budget=16, seed=seed,
                     block_size=BLOCK)
    rng = np.random.default_rng(0)
    receipts = []
    for name in ("a", "b", "c"):
        parts, y = _chunk(rng)        # same shapes for every tenant
        receipts.append(svc.insert(name, parts, y))
    # first insert compiles the plan, the rest hit the shared cache
    assert not receipts[0].plan_hit
    assert receipts[1].plan_hit and receipts[2].plan_hit
    s = svc.stats()
    assert s["plan_cache_size"] == 1 and s["plan_misses"] == 1
    assert s["plan_hits"] >= 2


def test_batched_flush_one_dispatch_per_group_draws_pinned():
    """R compatible requests flush as ONE batched build, and each request's
    draw equals the standalone build_coreset for its (key, m)."""
    rng = np.random.default_rng(4)
    parts, y = _chunk(rng, rows=800)
    ds = VFLDataset(parts, y)
    svc = CoresetService()
    svc.register("a", budget=8, seed=1, block_size=BLOCK)
    svc.register("b", budget=8, seed=2, block_size=BLOCK)
    svc.attach_dataset("ref", ds)
    with pytest.raises(ValueError):
        svc.attach_dataset("ref", ds)
    with pytest.raises(KeyError):
        svc.submit("a", "nope", 16, key=jax.random.PRNGKey(0))

    keys = [jax.random.PRNGKey(10 + i) for i in range(3)]
    t0 = svc.submit("a", "ref", 32, key=keys[0], task="vrlr")
    t1 = svc.submit("b", "ref", 48, key=keys[1], task="vrlr")
    t2 = svc.submit("a", "ref", 32, key=keys[2], task="vkmc", k=3)
    assert svc.pending == 3
    led_a0 = svc.state("a").ledger.total
    out = svc.flush()
    assert svc.pending == 0
    assert set(out) == {t0, t1, t2}
    # two groups: (ref, vrlr, {}) with 2 requests, (ref, vkmc, k=3) with 1
    assert svc.batched_flushes == 2 and svc.batched_cells == 3
    # draws pinned to the standalone builder (batched m==m_cap cells are
    # exactly the sequential result; smaller m is the iid prefix)
    solo = build_coreset("vrlr", ds, 48, key=keys[1], backend="ref")
    np.testing.assert_array_equal(np.asarray(out[t1].indices),
                                  np.asarray(solo.indices))
    np.testing.assert_allclose(np.asarray(out[t1].weights),
                               np.asarray(solo.weights), rtol=1e-6)
    assert out[t0].indices.shape == (32,) and out[t2].indices.shape == (32,)
    # each cell billed its exact schedule on the submitting tenant's ledger
    assert svc.state("a").ledger.total \
        == led_a0 + out[t0].comm_units + out[t2].comm_units


def test_flush_requires_resubmission_and_empty_flush_ok():
    svc = CoresetService()
    assert svc.flush() == {}
    rng = np.random.default_rng(9)
    parts, y = _chunk(rng, rows=400)
    svc.attach_dataset("d", VFLDataset(parts, y))
    t = svc.submit("ghost", "d", 16, key=jax.random.PRNGKey(0))
    out = svc.flush()                 # unknown tenants still get results,
    assert out[t].indices.shape == (16,)   # just no ledger to bill
    assert svc.flush() == {}


def test_shared_plan_cache_injection():
    cache = PlanCache()
    svc1 = CoresetService(plan_cache=cache)
    svc2 = CoresetService(plan_cache=cache)
    rng = np.random.default_rng(2)
    svc1.register("t", budget=16, seed=1, block_size=BLOCK)
    svc2.register("t", budget=16, seed=1, block_size=BLOCK)
    parts, y = _chunk(rng)
    assert not svc1.insert("t", parts, y).plan_hit
    assert svc2.insert("t", parts, y).plan_hit   # warmed by svc1
    assert len(cache) == 1
