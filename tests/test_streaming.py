"""Streaming block-scan scoring + hierarchical DIS.

The acceptance chain, tested link by link:

  1. ``dis_plan_blocked`` with ``block_size >= n`` is BIT-identical to
     ``dis_plan_full`` (the flat plan is the one-block degeneration);
  2. the hierarchical marginal telescopes exactly to the flat g_i/G
     (``dis_blocked_marginals``, computed without simplification);
  3. ``dis_plan_streamed`` is draw-identical to the in-memory
     ``dis_plan_blocked`` on the same scores (touched-block recomputation
     changes nothing);
  4. ``build_coreset_streaming`` therefore matches ``build_coreset`` bit for
     bit whenever the blockwise scores do (row-local ``norm`` backend), and
     statistically (empirical marginals, weight identity) always;
  5. the data-parallel mass table (``vrlr_block_masses_sharded``) agrees
     with the host block-scan.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CommLedger,
    VFLDataset,
    build_coreset,
    build_coreset_streaming,
    build_coresets_batched,
    resolve_backend,
    theoretical_dis_cost,
)
from repro.core.dis import (
    blocked_geometry,
    dis_blocked_marginals,
    dis_marginals,
    dis_plan_blocked,
    dis_plan_full,
)
from repro.core.sensitivity import norm_scores, vrlr_scores_stacked
from repro.core.streaming import (
    dis_plan_streamed,
    make_stream_scorer,
    vrlr_block_masses_sharded,
)


def _dataset(key, n=1200, d=12, T=3):
    kx, kt, kn = jax.random.split(key, 3)
    X = jax.random.normal(kx, (n, d))
    theta = jax.random.normal(kt, (d,))
    y = X @ theta + 0.1 * jax.random.normal(kn, (n,))
    return VFLDataset.from_dense(X, y, T=T)


def _scores(key, n, T):
    keys = jax.random.split(key, T)
    return jnp.stack([jax.random.uniform(k, (n,)) + 1e-3 for k in keys])


# --------------------------------------------------------------------------
# 1+2: the hierarchical DIS core
# --------------------------------------------------------------------------

def test_blocked_geometry():
    assert blocked_geometry(100, 30) == (4, 30)
    assert blocked_geometry(100, 100) == (1, 100)
    assert blocked_geometry(100, 1000) == (1, 100)   # bs clamps to n
    assert blocked_geometry(7, 1) == (7, 1)
    with pytest.raises(ValueError):
        blocked_geometry(10, 0)


def test_blocked_reduces_to_full_plan_bit_identical():
    """block_size >= n: same key chain, same cell masses, same draws —
    the flat plan IS the one-block hierarchical plan."""
    for trial in range(4):
        n, T, m = 200 + 31 * trial, trial % 3 + 1, 50 + trial
        scores = _scores(jax.random.PRNGKey(100 + trial), n, T)
        key = jax.random.PRNGKey(trial)
        pf = dis_plan_full(key, scores, m)
        for bsz in (n, n + 1, 10 * n):
            pb = dis_plan_blocked(key, scores, m, block_size=bsz)
            np.testing.assert_array_equal(np.asarray(pf.indices),
                                          np.asarray(pb.indices))
            np.testing.assert_array_equal(np.asarray(pf.weights),
                                          np.asarray(pb.weights))
            np.testing.assert_array_equal(np.asarray(pf.counts),
                                          np.asarray(pb.counts))
            np.testing.assert_array_equal(np.asarray(pf.totals),
                                          np.asarray(pb.totals))


@pytest.mark.parametrize("block_size", [1, 7, 64, 500, 2000])
def test_blocked_marginals_telescope_exactly(block_size):
    """P(i) = sum_cells P(cell) P(i|cell) collapses to g_i/G — computed
    unsimplified in float64, compared at float64 resolution."""
    scores = _scores(jax.random.PRNGKey(1), 500, 3)
    local = [scores[j] for j in range(3)]
    mb = dis_blocked_marginals(local, block_size)
    g64 = np.stack([np.asarray(x, np.float64) for x in local]).sum(axis=0)
    np.testing.assert_allclose(mb, g64 / g64.sum(), rtol=1e-12)
    # and against the float32 public helper at its own resolution
    np.testing.assert_allclose(mb, np.asarray(dis_marginals(local)), rtol=1e-5)


def test_blocked_plan_empirical_marginal():
    """Draws from the hierarchical sampler hit the flat marginal (5 sigma)."""
    n, T, m = 20, 3, 20000
    scores = _scores(jax.random.PRNGKey(3), n, T)
    probs = np.asarray(dis_marginals([scores[j] for j in range(T)]))
    plan = dis_plan_blocked(jax.random.PRNGKey(4), scores, m, block_size=7)
    emp = np.bincount(np.asarray(plan.indices), minlength=n) / m
    sigma = np.sqrt(probs * (1 - probs) / m)
    assert np.all(np.abs(emp - probs) < 5 * sigma + 1e-3)


def test_blocked_plan_weight_identity_and_counts():
    n, T, m = 333, 4, 80
    scores = _scores(jax.random.PRNGKey(5), n, T)
    plan = dis_plan_blocked(jax.random.PRNGKey(6), scores, m, block_size=50)
    assert int(plan.counts.sum()) == m
    assert bool(jnp.all((plan.indices >= 0) & (plan.indices < n)))
    g = np.asarray(scores.sum(axis=0))
    np.testing.assert_allclose(
        np.asarray(plan.weights) * m * g[np.asarray(plan.indices)],
        float(g.sum()), rtol=1e-4)


# --------------------------------------------------------------------------
# 3: streamed sampler == in-memory blocked plan on the same scores
# --------------------------------------------------------------------------

def test_streamed_plan_matches_blocked_plan():
    """The touched-block recomputation path produces the exact draws of the
    in-memory plan — norm scores are row-local, so the streamed scorer's
    blockwise values are bitwise the flat ones."""
    ds = _dataset(jax.random.PRNGKey(7), n=1100)
    key = jax.random.PRNGKey(8)
    st = ds.stacked(with_labels=True)
    sc = norm_scores(st.blocks) + 1.0 / ds.n
    for bsz in (128, 333, 2000):
        pb = dis_plan_blocked(key, sc, 90, block_size=bsz)
        scorer = make_stream_scorer("vrlr", key, ds, bsz, "norm")
        ps = dis_plan_streamed(scorer, 90)
        np.testing.assert_array_equal(np.asarray(pb.indices),
                                      np.asarray(ps.indices))
        np.testing.assert_array_equal(np.asarray(pb.weights),
                                      np.asarray(ps.weights))
        np.testing.assert_array_equal(np.asarray(pb.counts),
                                      np.asarray(ps.counts))


# --------------------------------------------------------------------------
# 4: the streaming entry point
# --------------------------------------------------------------------------

def test_streaming_build_bit_identical_to_flat_norm_backend():
    """block_size >= n + row-local scores => build_coreset_streaming ==
    build_coreset exactly, including the ledger bill."""
    ds = _dataset(jax.random.PRNGKey(9))
    key = jax.random.PRNGKey(10)
    led_f, led_s = CommLedger(), CommLedger()
    cs_f = build_coreset("vrlr", ds, 120, key=key, backend="norm", ledger=led_f)
    cs_s = build_coreset_streaming("vrlr", ds, 120, key=key, backend="norm",
                                   block_size=ds.n, ledger=led_s)
    np.testing.assert_array_equal(np.asarray(cs_f.indices),
                                  np.asarray(cs_s.indices))
    np.testing.assert_array_equal(np.asarray(cs_f.weights),
                                  np.asarray(cs_s.weights))
    assert led_f.total == led_s.total == cs_s.comm_units


@pytest.mark.parametrize("task,params", [("vrlr", {}), ("vkmc", {"k": 4})])
def test_streaming_build_ref_backend(task, params):
    ds = _dataset(jax.random.PRNGKey(11))
    led = CommLedger()
    cs = build_coreset_streaming(task, ds, 100, key=jax.random.PRNGKey(12),
                                 backend="ref", block_size=128, ledger=led,
                                 **params)
    assert cs.m == 100
    assert bool(jnp.all(cs.weights > 0))
    lo, hi = theoretical_dis_cost(100, ds.T)
    assert lo <= led.total <= hi


def test_streaming_marginals_match_flat_scores():
    """vrlr ref scores blockwise: the streamed empirical marginal tracks the
    materialized path's marginal (scores agree to fp, blocking is
    marginal-invariant)."""
    ds = _dataset(jax.random.PRNGKey(13), n=600)
    st = ds.stacked(with_labels=True)
    sc = np.asarray(vrlr_scores_stacked(st.blocks, use_kernel=False))
    g = sc.sum(axis=0)
    probs = g / g.sum()
    m = 20000
    scorer = make_stream_scorer("vrlr", jax.random.PRNGKey(14), ds, 97, "ref")
    plan = dis_plan_streamed(scorer, m)
    emp = np.bincount(np.asarray(plan.indices), minlength=ds.n) / m
    sigma = np.sqrt(probs * (1 - probs) / m)
    assert np.all(np.abs(emp - probs) < 5 * sigma + 1e-3)


def test_streaming_numpy_backed_dataset():
    """Host-resident (numpy) parts stream block by block; results match the
    jnp-backed dataset draw for draw (same scores, same keys)."""
    ds = _dataset(jax.random.PRNGKey(15), n=700)
    ds_np = VFLDataset([np.asarray(p) for p in ds.parts], np.asarray(ds.y))
    key = jax.random.PRNGKey(16)
    cs_j = build_coreset_streaming("vrlr", ds, 60, key=key, backend="ref",
                                   block_size=128)
    cs_n = build_coreset_streaming("vrlr", ds_np, 60, key=key, backend="ref",
                                   block_size=128)
    np.testing.assert_array_equal(np.asarray(cs_j.indices),
                                  np.asarray(cs_n.indices))
    np.testing.assert_allclose(np.asarray(cs_j.weights),
                               np.asarray(cs_n.weights), rtol=1e-6)


def test_streaming_uniform_and_label_validation():
    ds = _dataset(jax.random.PRNGKey(17), n=300)
    cs = build_coreset_streaming("uniform", ds, 30, key=jax.random.PRNGKey(0))
    assert cs.m == 30 and cs.comm_units == 30 * ds.T
    with pytest.raises(ValueError):
        build_coreset_streaming("vrlr", VFLDataset(ds.parts, None), 10,
                                key=jax.random.PRNGKey(0))
    with pytest.raises(KeyError):
        build_coreset_streaming("no-such-task", ds, 10,
                                key=jax.random.PRNGKey(0))
    # a registered task without a streaming scorer fails with a clear error
    from repro.core.api import CoresetTask
    task = CoresetTask(name="no-stream",
                       score_fn=lambda key, ds2, backend="ref": (None, key))
    with pytest.raises(ValueError, match="no streaming scorer"):
        build_coreset_streaming(task, ds, 10, key=jax.random.PRNGKey(0))


def test_block_view_matches_stacked():
    """VFLDataset.block(b) is exactly the corresponding slice of stacked()."""
    ds = _dataset(jax.random.PRNGKey(18), n=505)
    st = ds.stacked(with_labels=True)
    nb, bs = ds.block_geometry(100)
    assert (nb, bs) == (6, 100)
    for b in range(nb):
        blk, nvalid = ds.block(b, 100, with_labels=True)
        lo = b * bs
        want = np.asarray(st.blocks[:, lo:lo + nvalid, :])
        np.testing.assert_array_equal(np.asarray(blk[:, :nvalid]), want)
        assert float(jnp.abs(blk[:, nvalid:]).sum()) == 0.0
    assert nvalid == 505 - 5 * 100


# --------------------------------------------------------------------------
# 5: data-parallel mass table over the mesh
# --------------------------------------------------------------------------

def test_sharded_masses_match_block_scan():
    from repro.launch.mesh import make_debug_mesh

    ds = _dataset(jax.random.PRNGKey(19), n=800)
    mesh = make_debug_mesh(n_data=1, n_model=1)
    ms = vrlr_block_masses_sharded(mesh, ds, 100)
    scorer = make_stream_scorer("vrlr", jax.random.PRNGKey(0), ds, 100, "ref")
    assert ms.shape == (ds.T, 8)
    np.testing.assert_allclose(np.asarray(ms), np.asarray(scorer.masses),
                               rtol=1e-4, atol=1e-6)


def test_sharded_masses_rejects_misaligned_grid():
    from repro.launch.mesh import make_debug_mesh

    ds = _dataset(jax.random.PRNGKey(20), n=101)
    with pytest.raises(ValueError):
        vrlr_block_masses_sharded(make_debug_mesh(1, 1), ds, 100)


# --------------------------------------------------------------------------
# Satellites: backend="auto" and batched budget validation
# --------------------------------------------------------------------------

def test_backend_auto_resolution():
    assert resolve_backend("ref") == "ref"
    assert resolve_backend("norm") == "norm"
    resolved = resolve_backend("auto")
    if jax.default_backend() in ("tpu", "gpu"):
        assert resolved == "pallas"
    else:
        assert resolved == "ref"
    with pytest.raises(ValueError):
        resolve_backend("bogus")


def test_build_coreset_auto_default_matches_resolved():
    """The default backend="auto" build equals an explicit build with the
    resolved backend, draw for draw."""
    ds = _dataset(jax.random.PRNGKey(21), n=400)
    key = jax.random.PRNGKey(22)
    cs_auto = build_coreset("vrlr", ds, 50, key=key)
    cs_expl = build_coreset("vrlr", ds, 50, key=key,
                            backend=resolve_backend("auto"))
    np.testing.assert_array_equal(np.asarray(cs_auto.indices),
                                  np.asarray(cs_expl.indices))
    np.testing.assert_array_equal(np.asarray(cs_auto.weights),
                                  np.asarray(cs_expl.weights))


def test_batched_budget_grid_validation():
    ds = _dataset(jax.random.PRNGKey(23), n=200)
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="budgets"):
        build_coresets_batched("vrlr", ds, [0, 20], key=key)
    with pytest.raises(ValueError, match="budgets"):
        build_coresets_batched("vrlr", ds, [-3], key=key)
    with pytest.raises(ValueError, match="budgets"):
        build_coresets_batched("vrlr", ds, [10, 20], key=key, m_cap=15)
    with pytest.raises(ValueError):
        build_coresets_batched("vrlr", ds, [], key=key)
    # valid explicit m_cap > max(ms) still works (larger draw capacity)
    grid = build_coresets_batched("vrlr", ds, [10], key=key, m_cap=16)
    assert grid.indices.shape == (1, 1, 16)
    cs = grid.coreset(0, 0)
    assert cs.m == 10 and bool(jnp.all(cs.weights > 0))
