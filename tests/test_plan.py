"""CoresetSpec -> ExecutionPlan engine: spec validation, planner
boundaries, and forced-plan draw identity.

Covers the api_redesign acceptance criteria:
  * ALL knob validation is centralized in ``CoresetSpec.__post_init__``
    with uniform ValueError messages (block_size / chunk_blocks / budgets /
    engine / backend / memory_budget_bytes / m_cap);
  * the auto-planner flips materialized -> pipelined -> streamed EXACTLY at
    the memory-model thresholds, and records every decision (the
    ``chunk_blocks`` clamp is an explicit, described planner decision);
  * every forced plan is draw-identical to its legacy entry point (same key
    -> same indices, weights, and ledger totals) — the four legacy
    functions are thin shims over forced specs, and this pins it;
  * the predicted communication bill is EXACT (Algorithm 1's total is
    independent of the realised round-2 split);
  * the auto-plan smoke the CI step runs: two memory budgets -> two
    different engines, both draw-identical to their forced plans.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CommLedger,
    CoresetPipeline,
    CoresetSpec,
    VFLDataset,
    build_coreset,
    build_coreset_jit,
    build_coreset_streaming,
    build_coresets_batched,
    compile_plan,
)
from repro.core.plan import ENGINES, memory_model


def _dataset(key, n=1200, d=12, T=3, numpy_backed=False):
    kx, kt, kn = jax.random.split(key, 3)
    X = jax.random.normal(kx, (n, d))
    theta = jax.random.normal(kt, (d,))
    y = X @ theta + 0.1 * jax.random.normal(kn, (n,))
    ds = VFLDataset.from_dense(X, y, T=T)
    if numpy_backed:
        ds = VFLDataset([np.asarray(p) for p in ds.parts], np.asarray(ds.y))
    return ds


def _same_coreset(a, b):
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))
    np.testing.assert_array_equal(np.asarray(a.weights), np.asarray(b.weights))
    assert a.comm_units == b.comm_units


# --------------------------------------------------------------------------
# 1: centralized spec validation — uniform ValueError messages
# --------------------------------------------------------------------------

def test_spec_validates_budgets():
    for bad in (0, -1, (), (0, 20), (-3,), (1.5,), ("8",)):
        with pytest.raises(ValueError, match="budgets"):
            CoresetSpec(task="vrlr", budgets=bad)
    assert CoresetSpec(budgets=7).budgets == (7,)          # int normalizes
    assert CoresetSpec(budgets=[3, 9]).budgets == (3, 9)


def test_spec_validates_block_size_and_chunk_blocks():
    for bad in (0, -1, 2.5, "64", True):
        with pytest.raises(ValueError, match="block_size"):
            CoresetSpec(block_size=bad)
    for bad in (0, -3, 1.5, "4", True):
        with pytest.raises(ValueError, match="chunk_blocks"):
            CoresetSpec(chunk_blocks=bad)
    # None = planner default; ints pass through unclamped (the planner clamps)
    assert CoresetSpec(chunk_blocks=None).chunk_blocks is None
    assert CoresetSpec(chunk_blocks=10_000).chunk_blocks == 10_000


def test_spec_validates_enums_and_flags():
    with pytest.raises(ValueError, match="engine"):
        CoresetSpec(engine="warp")
    with pytest.raises(ValueError, match="backend"):
        CoresetSpec(backend="bogus")
    with pytest.raises(ValueError, match="num_seeds"):
        CoresetSpec(num_seeds=0)
    with pytest.raises(ValueError, match="prefetch"):
        CoresetSpec(prefetch=1)
    with pytest.raises(ValueError, match="memory_budget_bytes"):
        CoresetSpec(memory_budget_bytes=0)
    with pytest.raises(ValueError, match="jit"):
        CoresetSpec(jit=True, engine="streamed")
    with pytest.raises(ValueError, match="sharded_masses"):
        CoresetSpec(sharded_masses=True, engine="materialized")
    with pytest.raises(ValueError, match="task"):
        CoresetSpec(task=42)


def test_spec_validates_m_cap():
    with pytest.raises(ValueError, match="m_cap"):
        CoresetSpec(m_cap=0)
    with pytest.raises(ValueError, match="budgets"):
        CoresetSpec(budgets=(10, 20), m_cap=15)
    spec = CoresetSpec(budgets=(10,), m_cap=16)
    assert spec.m_cap == 16


def test_spec_budget_property_and_grid():
    assert CoresetSpec(budgets=9).budget == 9
    grid = CoresetSpec(budgets=(3, 9), num_seeds=2)
    assert grid.is_grid
    with pytest.raises(ValueError, match="grid"):
        _ = grid.budget


def test_legacy_streaming_validation_via_spec():
    """The legacy entry point's knob validation now COMES FROM CoresetSpec
    — same matches, same host-side failure before any work."""
    ds = _dataset(jax.random.PRNGKey(0), n=400)
    key = jax.random.PRNGKey(1)
    for bad in (0, -1, 2.5, "64"):
        with pytest.raises(ValueError, match="block_size"):
            build_coreset_streaming("vrlr", ds, 10, key=key, block_size=bad)
    for bad in (0, -3, 1.5):
        with pytest.raises(ValueError, match="chunk_blocks"):
            build_coreset_streaming("vrlr", ds, 10, key=key, block_size=64,
                                    chunk_blocks=bad)
    with pytest.raises(ValueError, match="budgets"):
        build_coresets_batched("vrlr", ds, [], key=key)


# --------------------------------------------------------------------------
# 2: planner boundaries — engine flips exactly at the memory-model thresholds
# --------------------------------------------------------------------------

def _plan(ds, **spec_kw):
    return CoresetPipeline(ds).plan(CoresetSpec(task="vrlr", budgets=64,
                                                **spec_kw))


def test_auto_planner_threshold_flips():
    """materialized at >= its predicted bytes, pipelined one byte below,
    streamed one byte below the pipelined peak — the exact model values."""
    ds = _dataset(jax.random.PRNGKey(2), n=4096)
    kw = dict(block_size=256, chunk_blocks=2)
    mm = _plan(ds, **kw).memory_model
    assert mm["streamed"] < mm["pipelined"] < mm["materialized"]

    at = lambda B: _plan(ds, memory_budget_bytes=B, **kw)
    assert at(mm["materialized"]).engine == "materialized"
    assert at(mm["materialized"] - 1).engine == "pipelined"
    assert at(mm["pipelined"]).engine == "pipelined"
    p = at(mm["pipelined"] - 1)
    assert p.engine == "streamed" and not p.budget_exceeded
    assert at(mm["streamed"]).engine == "streamed"
    # below even the streamed floor: still streamed, flagged
    tight = at(mm["streamed"] - 1)
    assert tight.engine == "streamed" and tight.budget_exceeded
    assert "EXCEEDS" in tight.describe()


def test_planner_no_budget_defaults_materialized():
    ds = _dataset(jax.random.PRNGKey(3), n=500)
    plan = _plan(ds)
    assert plan.engine == "materialized"
    assert plan.predicted_peak_bytes == plan.memory_model["materialized"]


def test_planner_grid_forces_batched():
    ds = _dataset(jax.random.PRNGKey(4), n=300)
    pipeline = CoresetPipeline(ds)
    plan = pipeline.plan(CoresetSpec(task="vrlr", budgets=(10, 20),
                                     num_seeds=3))
    assert plan.engine == "batched" and plan.grid == (3, 2)
    with pytest.raises(ValueError, match="grid"):
        pipeline.plan(CoresetSpec(task="vrlr", budgets=(10, 20),
                                  engine="materialized"))


def test_planner_clamp_is_explicit_and_described():
    """chunk_blocks above the block count is a PLANNER decision: clamped,
    recorded in notes, and printed by describe()."""
    ds = _dataset(jax.random.PRNGKey(5), n=400)
    plan = CoresetPipeline(ds).plan(
        CoresetSpec(task="vrlr", budgets=20, engine="pipelined",
                    block_size=64, chunk_blocks=10_000))
    nb = -(-400 // 64)
    assert plan.chunk_blocks == nb
    assert any("clamped" in n for n in plan.notes)
    assert "clamped" in plan.describe()
    # legacy entry point behaves identically (clamp -> one full-span chunk)
    key = jax.random.PRNGKey(6)
    cs_a = build_coreset_streaming("vrlr", ds, 20, key=key, block_size=64,
                                   chunk_blocks=10_000)
    cs_b = build_coreset_streaming("vrlr", ds, 20, key=key, block_size=64,
                                   chunk_blocks=nb)
    _same_coreset(cs_a, cs_b)


def test_planner_lowers_degenerate_pipelined_to_streamed():
    ds = _dataset(jax.random.PRNGKey(7), n=400)
    plan = CoresetPipeline(ds).plan(
        CoresetSpec(task="vrlr", budgets=20, engine="pipelined",
                    block_size=64, chunk_blocks=1, prefetch=False))
    assert plan.engine == "streamed"
    assert any("lowered" in n for n in plan.notes)


def test_plan_predicted_comm_is_exact():
    """The DIS bill is independent of the realised a_j split, so the plan's
    prediction equals the realised ledger total for every engine."""
    ds = _dataset(jax.random.PRNGKey(8), n=600)
    pipeline = CoresetPipeline(ds)
    m = 40
    for engine in ("materialized", "streamed", "pipelined"):
        spec = CoresetSpec(task="vrlr", budgets=m, engine=engine,
                           block_size=128)
        plan = pipeline.plan(spec)
        led = CommLedger()
        cs = pipeline.build(plan, key=jax.random.PRNGKey(9), ledger=led)
        assert led.total == plan.predicted_comm_units == cs.comm_units
    # uniform: broadcast-only bill
    uplan = pipeline.plan(CoresetSpec(task="uniform", budgets=m))
    assert uplan.predicted_comm_units == m * ds.T


def test_memory_model_uniform_is_tiny():
    ds = _dataset(jax.random.PRNGKey(10), n=5000)
    plan = CoresetPipeline(ds).plan(
        CoresetSpec(task="uniform", budgets=16,
                    memory_budget_bytes=10_000))
    assert plan.engine == "materialized"        # nothing to stream
    assert plan.predicted_peak_bytes < 10_000


def test_memory_model_function_matches_plan():
    ds = _dataset(jax.random.PRNGKey(11), n=2048)
    plan = _plan(ds, block_size=256, chunk_blocks=4)
    mm = memory_model(plan.T, plan.n, plan.stacked_width, plan.bs,
                      4, 1, 1, plan.m_cap)
    for e in ENGINES:
        assert mm[e] == plan.memory_model[e]


# --------------------------------------------------------------------------
# 3: forced plans are draw-identical to the legacy entry points
# --------------------------------------------------------------------------

def test_forced_materialized_matches_build_coreset():
    ds = _dataset(jax.random.PRNGKey(12))
    key = jax.random.PRNGKey(13)
    pipeline = CoresetPipeline(ds)
    for task, params in (("vrlr", {}), ("vkmc", {"k": 4}), ("uniform", {})):
        led_a, led_b = CommLedger(), CommLedger()
        legacy = build_coreset(task, ds, 50, key=key, ledger=led_a, **params)
        spec = CoresetSpec(task=task, budgets=50, engine="materialized",
                           params=params)
        forced = pipeline.build(spec, key=key, ledger=led_b)
        _same_coreset(legacy, forced)
        assert led_a.total == led_b.total
        assert led_a.by_tag() == led_b.by_tag()


def test_forced_jit_matches_build_coreset_jit():
    ds = _dataset(jax.random.PRNGKey(14), n=400)
    key = jax.random.PRNGKey(15)
    legacy = build_coreset_jit("vrlr", ds, 30, key=key)
    forced = CoresetPipeline(ds).build(
        CoresetSpec(task="vrlr", budgets=30, engine="materialized", jit=True),
        key=key)
    _same_coreset(legacy, forced)


def test_forced_streaming_matches_build_coreset_streaming():
    ds = _dataset(jax.random.PRNGKey(16), n=1100, numpy_backed=True)
    key = jax.random.PRNGKey(17)
    pipeline = CoresetPipeline(ds)
    # pipelined: chunked + prefetched
    led_a, led_b = CommLedger(), CommLedger()
    legacy = build_coreset_streaming("vrlr", ds, 60, key=key, block_size=128,
                                     chunk_blocks=4, prefetch=True,
                                     ledger=led_a)
    forced = pipeline.build(
        CoresetSpec(task="vrlr", budgets=60, engine="pipelined",
                    block_size=128, chunk_blocks=4, prefetch=True),
        key=key, ledger=led_b)
    _same_coreset(legacy, forced)
    assert led_a.by_tag() == led_b.by_tag()
    # streamed: the block-at-a-time engine
    legacy_s = build_coreset_streaming("vrlr", ds, 60, key=key,
                                       block_size=128, chunk_blocks=1,
                                       prefetch=False)
    forced_s = pipeline.build(
        CoresetSpec(task="vrlr", budgets=60, engine="streamed",
                    block_size=128),
        key=key)
    _same_coreset(legacy_s, forced_s)
    # and the two streaming engines draw identically (the PR 4 invariant)
    _same_coreset(legacy, legacy_s)


def test_forced_batched_matches_build_coresets_batched():
    ds = _dataset(jax.random.PRNGKey(18), n=500)
    keys = jax.random.split(jax.random.PRNGKey(19), 3)
    legacy = build_coresets_batched("vrlr", ds, [10, 25], keys=keys)
    forced = CoresetPipeline(ds).build(
        CoresetSpec(task="vrlr", budgets=(10, 25), num_seeds=3,
                    engine="batched", backend="ref"),
        keys=keys)
    np.testing.assert_array_equal(np.asarray(legacy.indices),
                                  np.asarray(forced.indices))
    np.testing.assert_array_equal(np.asarray(legacy.weights),
                                  np.asarray(forced.weights))
    np.testing.assert_array_equal(np.asarray(legacy.counts),
                                  np.asarray(forced.counts))
    for r in range(3):
        for mi in range(2):
            _same_coreset(legacy.coreset(r, mi), forced.coreset(r, mi))


def test_pipeline_requires_key():
    ds = _dataset(jax.random.PRNGKey(20), n=200)
    pipeline = CoresetPipeline(ds)
    with pytest.raises(ValueError, match="key"):
        pipeline.build(CoresetSpec(task="vrlr", budgets=10))
    with pytest.raises(ValueError, match="key"):
        pipeline.build(CoresetSpec(task="vrlr", budgets=10, num_seeds=2))


def test_plan_requires_labels_eagerly():
    ds = _dataset(jax.random.PRNGKey(21), n=100)
    unlabeled = VFLDataset(ds.parts, None)
    with pytest.raises(ValueError, match="labels"):
        compile_plan(CoresetSpec(task="vrlr", budgets=10), unlabeled)


# --------------------------------------------------------------------------
# 4: the CI auto-plan smoke — two budgets, two engines, draws match forced
# --------------------------------------------------------------------------

def test_auto_plan_smoke_two_budgets():
    """Build via auto-plan at a loose and a tight memory budget: the chosen
    engines DIFFER, and each build is draw-identical to its forced plan."""
    ds = _dataset(jax.random.PRNGKey(22), n=8192, numpy_backed=True)
    key = jax.random.PRNGKey(23)
    pipeline = CoresetPipeline(ds)
    base = dict(task="vrlr", budgets=64, block_size=512, chunk_blocks=2)

    engines, draws = [], {}
    mm = pipeline.plan(CoresetSpec(**base)).memory_model
    for budget in (mm["materialized"], mm["streamed"]):
        spec = CoresetSpec(memory_budget_bytes=int(budget), **base)
        plan = pipeline.plan(spec)
        cs = pipeline.build(plan, key=key)
        forced = pipeline.build(
            CoresetSpec(engine=plan.engine, **base), key=key)
        _same_coreset(cs, forced)
        engines.append(plan.engine)
        draws[plan.engine] = cs
    assert engines[0] != engines[1]
    assert engines[0] == "materialized" and engines[1] == "streamed"


def test_auto_plan_streaming_engines_draw_identical():
    """When the auto-planner flips between streamed and pipelined, the
    draws do NOT change — engine selection is pure throughput/memory
    policy."""
    ds = _dataset(jax.random.PRNGKey(24), n=4096, numpy_backed=True)
    key = jax.random.PRNGKey(25)
    pipeline = CoresetPipeline(ds)
    base = dict(task="vrlr", budgets=48, block_size=256, chunk_blocks=2)
    mm = pipeline.plan(CoresetSpec(**base)).memory_model
    plan_p = pipeline.plan(
        CoresetSpec(memory_budget_bytes=int(mm["pipelined"]), **base))
    plan_s = pipeline.plan(
        CoresetSpec(memory_budget_bytes=int(mm["pipelined"]) - 1, **base))
    assert (plan_p.engine, plan_s.engine) == ("pipelined", "streamed")
    _same_coreset(pipeline.build(plan_p, key=key),
                  pipeline.build(plan_s, key=key))


# --------------------------------------------------------------------------
# 5: the sharded_masses plan toggle
# --------------------------------------------------------------------------

def test_sharded_masses_toggle_builds():
    # n divisible by (devices * block_size): the shard-grid requirement
    ds = _dataset(jax.random.PRNGKey(26), n=800)
    pipeline = CoresetPipeline(ds)
    spec = CoresetSpec(task="vrlr", budgets=40, engine="streamed",
                       block_size=100, sharded_masses=True)
    assert "sharded_masses" in pipeline.plan(spec).describe()
    cs = pipeline.build(spec, key=jax.random.PRNGKey(27))
    assert cs.m == 40 and bool(jnp.all(cs.weights > 0))
    # the sharded table is the scorer's table up to fp order, so the draws
    # match the unsharded engine whenever the tables agree bitwise — not
    # guaranteed; what IS guaranteed is a valid 40-sample DIS plan + bill
    assert cs.comm_units == pipeline.plan(spec).predicted_comm_units


def test_sharded_masses_rejects_norm_backend():
    ds = _dataset(jax.random.PRNGKey(28), n=800)
    spec = CoresetSpec(task="vrlr", budgets=10, engine="streamed",
                       block_size=100, sharded_masses=True, backend="norm")
    with pytest.raises(ValueError, match="sharded_masses"):
        CoresetPipeline(ds).build(spec, key=jax.random.PRNGKey(0))


def test_sharded_masses_misaligned_grid_fails_at_plan_time():
    """The shard-grid divisibility requirement is surfaced by the PLANNER,
    not deep inside the executor."""
    ds = _dataset(jax.random.PRNGKey(29), n=801)        # 801 % 100 != 0
    spec = CoresetSpec(task="vrlr", budgets=10, engine="streamed",
                       block_size=100, sharded_masses=True)
    with pytest.raises(ValueError, match="sharded_masses"):
        CoresetPipeline(ds).plan(spec)


# --------------------------------------------------------------------------
# 6: spec flags never dropped silently; stale plans rejected
# --------------------------------------------------------------------------

def test_auto_planner_never_drops_jit_silently():
    """jit=True with engine='auto' must not be ignored when the memory
    model picks a streaming engine — same rejection as the forced combo."""
    ds = _dataset(jax.random.PRNGKey(30), n=4096)
    spec = CoresetSpec(task="vrlr", budgets=32, jit=True, block_size=256,
                       chunk_blocks=2, memory_budget_bytes=1)
    with pytest.raises(ValueError, match="jit"):
        CoresetPipeline(ds).plan(spec)
    # with a loose budget the fused materialized path plans fine
    loose = spec.replace(memory_budget_bytes=1 << 30)
    assert CoresetPipeline(ds).plan(loose).engine == "materialized"


def test_auto_planner_never_drops_sharded_masses_silently():
    ds = _dataset(jax.random.PRNGKey(31), n=800)
    spec = CoresetSpec(task="vrlr", budgets=10, block_size=100,
                       sharded_masses=True)        # auto -> materialized
    with pytest.raises(ValueError, match="sharded_masses"):
        CoresetPipeline(ds).plan(spec)


def test_build_rejects_plan_from_other_dataset():
    ds_a = _dataset(jax.random.PRNGKey(32), n=400)
    ds_b = _dataset(jax.random.PRNGKey(33), n=800)
    plan = CoresetPipeline(ds_a).plan(CoresetSpec(task="vrlr", budgets=10))
    with pytest.raises(ValueError, match="recompile"):
        CoresetPipeline(ds_b).build(plan, key=jax.random.PRNGKey(0))
    # same (n, T) but different feature widths: the memory model and engine
    # selection are stale — must also be rejected
    ds_c = _dataset(jax.random.PRNGKey(33), n=400, d=24)
    with pytest.raises(ValueError, match="recompile"):
        CoresetPipeline(ds_c).build(plan, key=jax.random.PRNGKey(0))


def test_forced_streamed_has_no_clamp_note():
    """A forced-streamed plan ignores chunk_blocks (chunk = 1), so no
    contradictory clamp note appears in describe()."""
    ds = _dataset(jax.random.PRNGKey(34), n=400)
    plan = CoresetPipeline(ds).plan(
        CoresetSpec(task="vrlr", budgets=10, engine="streamed",
                    block_size=64, chunk_blocks=10_000))
    assert plan.chunk_blocks == 1
    assert not any("clamped" in n for n in plan.notes)
