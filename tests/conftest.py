import jax
import pytest

# NOTE: no XLA_FLAGS / device-count override here — smoke tests and benches
# must see the 1 real CPU device (the 512-device mesh lives ONLY in
# repro.launch.dryrun, which sets the flag before importing jax).


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
