import os

import jax
import pytest

# NOTE: no XLA_FLAGS / device-count override here — smoke tests and benches
# must see the 1 real CPU device (the 512-device mesh lives ONLY in
# repro.launch.dryrun, which sets the flag before importing jax).

try:
    from hypothesis import settings

    # CI boxes jit-compile inside property bodies, so wall-clock per example
    # is noisy — pin deadline=None there (flaky DeadlineExceeded otherwise);
    # dev keeps the library defaults so genuinely slow examples still
    # surface locally.
    settings.register_profile("ci", deadline=None)
    settings.register_profile("dev")
    settings.load_profile("ci" if os.environ.get("CI") else "dev")
except ImportError:                       # hypothesis-free environments
    pass


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
