"""End-to-end coreset quality for VKMC (Algorithm 3) + DistDim baseline."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CommLedger,
    VFLDataset,
    build_uniform_coreset,
    build_vkmc_coreset,
    distdim,
    kmeans,
    kmeans_cost,
    vkmc_coreset_ratio,
)
from repro.data.synthetic import correlated_vfl_data


def _clustered(key, n=3000, d=12, T=3, k=5, rho=0.8):
    X = correlated_vfl_data(key, n, d, T, cross_correlation=rho, k_clusters=k)
    return VFLDataset.from_dense(X, None, T=T)


def test_vkmc_coreset_solution_quality():
    k = 5
    ds = _clustered(jax.random.PRNGKey(0), k=k)
    cs = build_vkmc_coreset(jax.random.PRNGKey(1), ds, k=k, m=500)
    XS, _, w = cs.materialize(ds)
    cent_full = kmeans(jax.random.PRNGKey(2), ds.full(), k)
    cent_cs = kmeans(jax.random.PRNGKey(2), XS, k, w)
    c_full = float(kmeans_cost(ds.full(), cent_full))
    c_cs = float(kmeans_cost(ds.full(), cent_cs))
    assert c_cs <= 1.15 * c_full, (c_cs, c_full)


def test_vkmc_coreset_epsilon_over_probe_centers():
    k = 4
    ds = _clustered(jax.random.PRNGKey(3), n=1500, k=k)
    cs = build_vkmc_coreset(jax.random.PRNGKey(4), ds, k=k, m=600)
    C_probe = jax.random.normal(jax.random.PRNGKey(5), (10, k, ds.d)) * 2.0
    eps = float(vkmc_coreset_ratio(ds, cs, C_probe))
    assert eps < 0.5, eps


def test_vkmc_coreset_beats_uniform():
    k = 6
    ds = _clustered(jax.random.PRNGKey(6), n=4000, k=k, rho=0.9)

    def cost_of(builder, seed, **kw):
        cs = builder(jax.random.PRNGKey(seed), ds, **kw)
        XS, _, w = cs.materialize(ds)
        cent = kmeans(jax.random.PRNGKey(7), XS, k, w)
        return float(kmeans_cost(ds.full(), cent))

    cs_c = np.mean([cost_of(build_vkmc_coreset, s, k=k, m=120) for s in range(6)])
    un_c = np.mean([cost_of(build_uniform_coreset, s + 50, m=120) for s in range(6)])
    assert cs_c <= un_c * 1.03, (cs_c, un_c)


def test_distdim_runs_and_costs_linear_comm():
    k = 4
    ds = _clustered(jax.random.PRNGKey(8), n=800, k=k)
    led = CommLedger()
    cent = distdim(jax.random.PRNGKey(9), ds, k, ledger=led)
    assert cent.shape == (k, ds.d)
    # Ding et al. cost: assignments n per party + local centers
    assert led.total >= ds.n * ds.T
    c = float(kmeans_cost(ds.full(), cent))
    c_central = float(kmeans_cost(ds.full(), kmeans(jax.random.PRNGKey(10), ds.full(), k)))
    assert c <= 3.0 * c_central       # constant-approx regime


def test_coreset_comm_much_smaller_than_distdim():
    k = 4
    ds = _clustered(jax.random.PRNGKey(11), n=5000, k=k)
    led_cs, led_dd = CommLedger(), CommLedger()
    build_vkmc_coreset(jax.random.PRNGKey(12), ds, k=k, m=200, ledger=led_cs)
    distdim(jax.random.PRNGKey(13), ds, k, ledger=led_dd)
    assert led_cs.total < led_dd.total / 5
