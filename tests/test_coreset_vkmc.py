"""End-to-end coreset quality for VKMC (Algorithm 3) + DistDim baseline."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CommLedger,
    VFLDataset,
    build_coresets_batched,
    build_uniform_coreset,
    build_vkmc_coreset,
    distdim,
    kmeans,
    kmeans_cost,
    vkmc_coreset_ratio,
)
from repro.data.synthetic import correlated_vfl_data


def _clustered(key, n=3000, d=12, T=3, k=5, rho=0.8):
    X = correlated_vfl_data(key, n, d, T, cross_correlation=rho, k_clusters=k)
    return VFLDataset.from_dense(X, None, T=T)


def test_vkmc_coreset_solution_quality():
    k = 5
    ds = _clustered(jax.random.PRNGKey(0), k=k)
    cs = build_vkmc_coreset(jax.random.PRNGKey(1), ds, k=k, m=500)
    XS, _, w = cs.materialize(ds)
    cent_full = kmeans(jax.random.PRNGKey(2), ds.full(), k)
    cent_cs = kmeans(jax.random.PRNGKey(2), XS, k, w)
    c_full = float(kmeans_cost(ds.full(), cent_full))
    c_cs = float(kmeans_cost(ds.full(), cent_cs))
    assert c_cs <= 1.15 * c_full, (c_cs, c_full)


def test_vkmc_coreset_epsilon_over_probe_centers():
    k = 4
    ds = _clustered(jax.random.PRNGKey(3), n=1500, k=k)
    cs = build_vkmc_coreset(jax.random.PRNGKey(4), ds, k=k, m=600)
    C_probe = jax.random.normal(jax.random.PRNGKey(5), (10, k, ds.d)) * 2.0
    eps = float(vkmc_coreset_ratio(ds, cs, C_probe))
    assert eps < 0.5, eps


def test_vkmc_coreset_beats_uniform():
    """C-KMEANS++ is no worse than U-KMEANS++ at matched budget (Table 1).

    The seed version of this test flaked: it averaged ONE downstream Lloyd
    solve per construction seed, and weighted Lloyd is local-optimum
    roulette with a heavy upper tail (~2-3x cost basins) — any single draw
    can land badly regardless of coreset fidelity, and a mean over 6 draws
    is dominated by that basin luck.  Theorem 5.1 bounds the coreset's COST
    RATIO, not which basin the downstream solver picks, so the statistic
    here is basin-robust: all construction seeds are built in one compiled
    ``build_coresets_batched`` call, each coreset is solved with best-of-3
    downstream restarts (standard k-means practice), and the MEDIAN over
    the fixed 12-seed batch is compared within a 3% margin.
    """
    k, m, R = 6, 120, 12
    ds = _clustered(jax.random.PRNGKey(6), n=4000, k=k, rho=0.9)
    Xf = ds.full()
    grid_c = build_coresets_batched("vkmc", ds, [m], key=jax.random.PRNGKey(100),
                                    num_seeds=R, backend="ref", k=k)
    grid_u = build_coresets_batched("uniform", ds, [m], key=jax.random.PRNGKey(200),
                                    num_seeds=R)

    def median_cost(grid):
        costs = []
        for r in range(R):
            cs = grid.coreset(r, 0)
            XS, w = Xf[cs.indices], cs.weights
            costs.append(min(
                float(kmeans_cost(Xf, kmeans(jax.random.PRNGKey(7 + t), XS, k, w,
                                             use_kernel=False),
                                  use_kernel=False))
                for t in range(3)))
        return float(np.median(costs))

    cs_c, un_c = median_cost(grid_c), median_cost(grid_u)
    assert cs_c <= un_c * 1.03, (cs_c, un_c)


def test_distdim_runs_and_costs_linear_comm():
    k = 4
    ds = _clustered(jax.random.PRNGKey(8), n=800, k=k)
    led = CommLedger()
    cent = distdim(jax.random.PRNGKey(9), ds, k, ledger=led)
    assert cent.shape == (k, ds.d)
    # Ding et al. cost: assignments n per party + local centers
    assert led.total >= ds.n * ds.T
    c = float(kmeans_cost(ds.full(), cent))
    c_central = float(kmeans_cost(ds.full(), kmeans(jax.random.PRNGKey(10), ds.full(), k)))
    assert c <= 3.0 * c_central       # constant-approx regime


def test_coreset_comm_much_smaller_than_distdim():
    k = 4
    ds = _clustered(jax.random.PRNGKey(11), n=5000, k=k)
    led_cs, led_dd = CommLedger(), CommLedger()
    build_vkmc_coreset(jax.random.PRNGKey(12), ds, k=k, m=200, ledger=led_cs)
    distdim(jax.random.PRNGKey(13), ds, k, ledger=led_dd)
    assert led_cs.total < led_dd.total / 5
