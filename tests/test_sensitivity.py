"""Algorithm 2/3 local scores: leverage properties + sensitivity bounds."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sensitivity import (
    kmeans_assignment,
    leverage_scores,
    total_sensitivity_bound_vkmc,
    total_sensitivity_bound_vrlr,
    vkmc_local_scores,
    vrlr_local_scores,
)
from repro.core.vkmc import kmeans


def test_leverage_in_unit_interval_and_sums_to_rank():
    X = jax.random.normal(jax.random.PRNGKey(0), (200, 7))
    lev = np.asarray(leverage_scores(X))
    assert np.all(lev >= 0) and np.all(lev <= 1 + 1e-6)
    np.testing.assert_allclose(lev.sum(), 7.0, rtol=1e-3)   # full column rank


def test_leverage_matches_qr():
    X = jax.random.normal(jax.random.PRNGKey(1), (80, 5))
    q, _ = jnp.linalg.qr(X)
    np.testing.assert_allclose(
        np.asarray(leverage_scores(X)), np.asarray(jnp.sum(q * q, axis=1)),
        rtol=1e-3, atol=1e-5)


def test_leverage_rank_deficient():
    X = jax.random.normal(jax.random.PRNGKey(2), (60, 4))
    X = jnp.concatenate([X, X[:, :2]], axis=1)              # rank 4, d=6
    lev = np.asarray(leverage_scores(X))
    np.testing.assert_allclose(lev.sum(), 4.0, rtol=1e-2)


def test_vrlr_scores_include_floor_and_bound():
    n = 150
    X = jax.random.normal(jax.random.PRNGKey(3), (n, 6))
    y = jax.random.normal(jax.random.PRNGKey(4), (n,))
    g = np.asarray(vrlr_local_scores(X, y))
    assert np.all(g >= 1.0 / n)
    # total <= d'_j + 1  (d'_j = rank([X, y]) = 7)
    assert g.sum() <= 7 + 1 + 1e-3
    assert g.sum() >= 6.0     # near-full rank data


def test_vkmc_total_sensitivity_exact():
    """Lemma F.2: sum_i g_i^(j) = 2(k+1) * alpha per party (exactly)."""
    k, alpha = 4, 2.0
    X = jax.random.normal(jax.random.PRNGKey(5), (300, 8))
    centers = kmeans(jax.random.PRNGKey(6), X, k, iters=5)
    g = np.asarray(vkmc_local_scores(X, centers, alpha))
    assert np.all(g > 0)
    np.testing.assert_allclose(g.sum(), 2 * (k + 1) * alpha, rtol=1e-4)
    assert abs(total_sensitivity_bound_vkmc(k, 1, alpha) - g.sum()) < 1e-3


def test_total_sensitivity_bounds_helpers():
    assert total_sensitivity_bound_vrlr((3, 3, 4), 3) == 13.0
    assert total_sensitivity_bound_vkmc(10, 3, 2.0) == 132.0


def test_kmeans_assignment_correct():
    X = jax.random.normal(jax.random.PRNGKey(7), (100, 5))
    C = jax.random.normal(jax.random.PRNGKey(8), (7, 5))
    a, d2 = kmeans_assignment(X, C)
    d_all = np.asarray(
        jnp.sum((X[:, None, :] - C[None, :, :]) ** 2, axis=-1))
    np.testing.assert_array_equal(np.asarray(a), d_all.argmin(1))
    np.testing.assert_allclose(np.asarray(d2), d_all.min(1), rtol=1e-4, atol=1e-5)
