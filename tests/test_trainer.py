"""Trainer integration: selector modes train, checkpoint roundtrip,
unbiasedness of the weighted loss."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.selector import SelectorConfig
from repro.data.lm import TokenStream
from repro.optim.schedules import constant, cosine_with_warmup
from repro.train import (
    load_checkpoint,
    make_train_step,
    save_checkpoint,
    train_state_init,
)


def _setup(mode, fraction=0.5, seed=0):
    cfg = get_arch("llama3.2-1b").reduced()
    key = jax.random.PRNGKey(seed)
    state = train_state_init(key, cfg)
    step = jax.jit(make_train_step(cfg, cosine_with_warmup(2e-3, 5, 50),
                                   SelectorConfig(mode=mode, fraction=fraction)))
    stream = TokenStream(vocab=cfg.vocab_size, seq_len=24, batch_size=8, seed=seed)
    return cfg, state, step, iter(stream), key


def test_training_reduces_loss_all_modes():
    for mode in ("none", "uniform", "coreset"):
        cfg, state, step, it, key = _setup(mode)
        losses = []
        for i in range(12):
            state, m = step(state, next(it), jax.random.fold_in(key, i))
            losses.append(float(m["ce"]))
        assert np.mean(losses[-3:]) < np.mean(losses[:3]), (mode, losses)


def test_schedule_values():
    sched = cosine_with_warmup(1.0, 10, 100, floor=0.1)
    assert float(sched(jnp.asarray(0))) == 0.0
    assert abs(float(sched(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(sched(jnp.asarray(100))) >= 0.099
    assert float(constant(0.5)(jnp.asarray(7))) == 0.5


def test_checkpoint_roundtrip(tmp_path):
    cfg, state, step, it, key = _setup("none")
    state, _ = step(state, next(it), key)
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, state, step=1)
    restored, step_no = load_checkpoint(path, state)
    assert step_no == 1
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_weighted_loss_unbiased_estimate():
    """Coreset gradient signal: the weighted subsample CE approximates the
    full-batch CE in expectation."""
    from repro.core.selector import local_scores, sample_coreset
    cfg, state, _, it, key = _setup("none")
    from repro.models import api as model_api
    batch = next(it)
    full, _ = model_api.loss_fn(state["params"], cfg, batch)
    ests = []
    for s in range(30):
        from repro.train.trainer import _score_features, _select_rows
        feats = _score_features(state["params"], cfg, batch)
        g = local_scores(feats, "leverage", 1e-4)
        idx, w = sample_coreset(jax.random.PRNGKey(s), g, 4)
        sub = _select_rows(batch, idx)
        est, _ = model_api.loss_fn(state["params"], cfg, sub, example_weights=w)
        ests.append(float(est))
    assert abs(np.mean(ests) - float(full)) / float(full) < 0.15
