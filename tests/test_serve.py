"""Serving engine integration tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import init_params
from repro.serve import ServeEngine


def test_generate_shapes_and_determinism():
    cfg = get_arch("llama3.2-1b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, cache_len=64)
    prompts = jnp.array([[1, 2, 3], [4, 5, 6]], jnp.int32)
    out1 = eng.generate(prompts, max_new_tokens=6)
    out2 = eng.generate(prompts, max_new_tokens=6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))  # greedy
    assert int(out1.max()) < cfg.vocab_size        # pad-mask respected


def test_generate_batched_vs_single_consistent():
    cfg = get_arch("starcoder2-3b").reduced()
    params = init_params(jax.random.PRNGKey(1), cfg)
    eng = ServeEngine(cfg, params, cache_len=64)
    prompts = jnp.array([[7, 8], [9, 10]], jnp.int32)
    both = np.asarray(eng.generate(prompts, max_new_tokens=4))
    one = np.asarray(eng.generate(prompts[:1], max_new_tokens=4))
    np.testing.assert_array_equal(both[:1], one)


def test_encdec_generate_with_frames():
    cfg = get_arch("whisper-medium").reduced()
    params = init_params(jax.random.PRNGKey(2), cfg)
    eng = ServeEngine(cfg, params, cache_len=64)
    frames = jax.random.normal(jax.random.PRNGKey(3), (2, cfg.num_prefix, cfg.d_model))
    out = eng.generate(jnp.zeros((2, 2), jnp.int32), max_new_tokens=3,
                       prefix_embeds=frames)
    assert out.shape == (2, 3)
