"""Validates the dry-run artifact table (benchmarks/artifacts/dryrun.jsonl).

The 512-device lower+compile itself runs via
``python -m repro.launch.dryrun --all [--multi-pod]`` (jax locks the device
count at first init, so it cannot run inside this pytest process).  This test
asserts the REQUIRED coverage over the artifact it produced: every
(arch x shape x mesh) either compiled ok or is an explicitly documented skip.
"""

import json
import os

import pytest

from repro.configs import INPUT_SHAPES, all_arch_names

ART = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "artifacts",
                   "dryrun.jsonl")

DOCUMENTED_SKIPS = {
    ("whisper-medium", "long_500k"),
}


def _load():
    if not os.path.exists(ART):
        pytest.skip("dry-run artifact not generated yet "
                    "(run: python -m repro.launch.dryrun --all --roofline; "
                    "then --all --multi-pod)")
    recs = {}
    with open(ART) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r   # last write wins
    return recs


def test_every_pair_covered_single_pod():
    recs = _load()
    missing, failed = [], []
    for arch in all_arch_names():
        for shape in INPUT_SHAPES:
            key = (arch, shape, "16x16")
            r = recs.get(key)
            if r is None:
                missing.append(key)
            elif r["status"] == "error":
                failed.append((key, r.get("error")))
            elif r["status"] == "skipped":
                assert (arch, shape) in DOCUMENTED_SKIPS, key
    assert not missing, f"missing single-pod dry-runs: {missing}"
    assert not failed, f"failed single-pod dry-runs: {failed}"


def test_every_pair_covered_multi_pod():
    recs = _load()
    if not any(m == "2x16x16" for (_, _, m) in recs):
        pytest.skip("multi-pod sweep not generated yet")
    missing, failed = [], []
    for arch in all_arch_names():
        for shape in INPUT_SHAPES:
            key = (arch, shape, "2x16x16")
            r = recs.get(key)
            if r is None:
                missing.append(key)
            elif r["status"] == "error":
                failed.append((key, r.get("error")))
    assert not missing, f"missing multi-pod dry-runs: {missing}"
    assert not failed, f"failed multi-pod dry-runs: {failed}"


def test_roofline_terms_present_and_positive():
    recs = _load()
    ok = [r for r in recs.values() if r["status"] == "ok" and r["mesh"] == "16x16"]
    assert ok
    for r in ok:
        if "t_compute_s" not in r:
            continue
        assert r["t_compute_s"] >= 0 and r["t_memory_s"] > 0
        assert r["bottleneck"] in ("compute", "memory", "collective")
        assert r["model_flops"] > 0
