"""Sharding-spec unit tests: every (arch x shape x mesh) spec tree is
divisibility-valid — the invariant pjit enforces on inputs."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, all_arch_names, get_arch
from repro.launch.inputs import cache_specs, state_specs
from repro.models import api as model_api
from repro.sharding.specs import (
    MESH_SIZES,
    batch_shardings,
    cache_shardings,
    param_shardings,
    sanitize,
)


def _axis_product(ax):
    if ax is None:
        return 1
    axes = ax if isinstance(ax, (tuple, list)) else (ax,)
    n = 1
    for a in axes:
        n *= MESH_SIZES[a]
    return n


def _check_tree(shape_tree, spec_tree):
    leaves_s = jax.tree_util.tree_leaves(shape_tree)
    leaves_p = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_s) == len(leaves_p)
    for sds, spec in zip(leaves_s, leaves_p):
        axes = tuple(spec) + (None,) * (len(sds.shape) - len(spec))
        for dim, ax in zip(sds.shape, axes):
            assert dim % _axis_product(ax) == 0, (sds.shape, spec)


@pytest.mark.parametrize("arch", all_arch_names())
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_shardings_divisible(arch, multi_pod):
    cfg = get_arch(arch)
    pshape = jax.eval_shape(lambda k: model_api.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    specs = param_shardings(pshape, cfg, multi_pod)
    _check_tree(pshape, specs)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-v2-236b", "rwkv6-3b",
                                  "hymba-1.5b", "whisper-medium"])
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_shardings_divisible(arch, shape_name):
    shape = INPUT_SHAPES[shape_name]
    cfg = get_arch(arch).for_shape(shape)
    if shape_name == "long_500k" and arch == "whisper-medium":
        pytest.skip("whisper long_500k skipped by design")
    cshape = cache_specs(cfg, shape)
    specs = cache_shardings(cshape, cfg, shape, multi_pod=False)
    _check_tree(cshape, specs)


def test_sanitize_drops_uneven():
    assert sanitize(P("model"), (40,)) == P(None)
    assert sanitize(P("model"), (64,)) == P("model")
    assert sanitize(P(("pod", "data")), (64,)) == P(("pod", "data"))
    assert sanitize(P(("pod", "data")), (48,)) == P(None)


def test_expert_sharding_policy():
    """deepseek (E=160) experts go expert-parallel; granite (E=40) falls back
    to ffn-dim sharding."""
    ds = get_arch("deepseek-v2-236b")
    gr = get_arch("granite-moe-3b-a800m")
    for cfg, expert_parallel in ((ds, True), (gr, False)):
        pshape = jax.eval_shape(lambda k: model_api.init_params(k, cfg),
                                jax.random.PRNGKey(0))
        specs = param_shardings(pshape, cfg, False)
        wg = specs["layers"]["moe"]["w_gate"]
        if expert_parallel:
            assert wg[1] == "model", wg
        else:
            assert wg[1] != "model" and "model" in tuple(wg), wg


def test_batch_shardings_all_shapes():
    for arch in ("llama3.2-1b", "internvl2-26b", "whisper-medium"):
        cfg = get_arch(arch)
        for shape in INPUT_SHAPES.values():
            for mp in (False, True):
                specs = batch_shardings(cfg, shape, mp)
                assert "tokens" in specs
