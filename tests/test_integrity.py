"""Integrity-verified wire: checksummed envelopes, silent-corruption
defense, value-level validators, poisoned-party quarantine, and the
numerical-health guardrails.  (PR: integrity-verified wire.)
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CommLedger,
    Coreset,
    CoresetPipeline,
    CoresetSpec,
    FaultPlan,
    HealthReport,
    IntegrityError,
    MaterializedCoreset,
    PartyUnavailable,
    PlanCache,
    Transport,
    VFLDataset,
    WireEnvelope,
    check_mass_table,
    check_merge_children,
    check_weights,
    health_from_masses,
    payload_digest,
    perturb_payload,
    require_valid_masses,
    split_uploads,
)
from repro.core.faults import SILENT_KINDS, _fault_draw
from repro.core.plan import PLAN_KEY_EXEMPT, PLAN_KEY_FIELDS, compile_plan
from repro.serve import CoresetService, CoresetTree
from repro.serve.tree import merge_reduce

BLOCK = 128


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_programs():
    yield
    jax.clear_caches()


def _ds(seed=0, n=600, dims=(3, 2, 2), labels=True):
    rng = np.random.default_rng(seed)
    parts = [rng.normal(size=(n, d)).astype(np.float32) for d in dims]
    y = None
    if labels:
        theta = np.linspace(1.0, -1.0, dims[0]).astype(np.float32)
        y = (parts[0] @ theta
             + 0.1 * rng.normal(size=n).astype(np.float32))
    return VFLDataset(parts, y)


def _spec(engine="materialized", policy="fail", task="vrlr", m=32, **kw):
    params = {"k": 3} if task == "vkmc" else {}
    params.update(kw.pop("params", {}))
    return CoresetSpec(task=task, budgets=m, engine=engine, backend="ref",
                       fault_policy=policy, params=params,
                       block_size=BLOCK, **kw)


def _same_draw(a: Coreset, b: Coreset) -> bool:
    return (np.array_equal(np.asarray(a.indices), np.asarray(b.indices))
            and np.array_equal(np.asarray(a.weights), np.asarray(b.weights)))


# -- WireEnvelope + payload digest -------------------------------------------


def test_envelope_roundtrip_and_digest_stability():
    x = np.arange(12, dtype=np.float32).reshape(3, 4)
    env = WireEnvelope.seal("dis/round1/G_j", 1, x)
    assert env.verify(x)
    assert env.verify(x.copy())                  # value equality, not identity
    assert payload_digest(x) == payload_digest(x.copy())
    # non-contiguous views digest by VALUE
    assert payload_digest(x[:, ::2]) == payload_digest(
        np.ascontiguousarray(x[:, ::2]))


@pytest.mark.parametrize("kind", SILENT_KINDS)
def test_envelope_detects_every_corruption_kind(kind):
    x = np.linspace(0.5, 4.0, 16, dtype=np.float32)
    env = WireEnvelope.seal("t", 0, x)
    bad = perturb_payload(x, kind, 0.37)
    assert not np.array_equal(bad, x)
    assert not env.verify(bad)
    assert env.mismatch(bad) == "payload digest mismatch"
    # the original is never touched — the honest sender can retransmit
    assert env.verify(x)


def test_envelope_names_shape_and_dtype_mismatches():
    env = WireEnvelope.seal("t", 0, np.ones((4,), np.float32))
    assert "shape" in env.mismatch(np.ones((5,), np.float32))
    assert "dtype" in env.mismatch(np.ones((4,), np.float64))


def test_perturb_payload_semantics():
    x = np.array([1.0, -2.0, 3.0], np.float32)
    assert np.array_equal(perturb_payload(x, "sign", 0.0), -x)
    scaled = perturb_payload(x, "scale", 0.5)
    np.testing.assert_allclose(scaled / x, (scaled / x)[0])    # uniform factor
    assert float(abs(scaled[0] / x[0])) >= 10.0
    poked = perturb_payload(x, "nan", 0.4)
    assert np.isnan(poked).sum() == 1
    # integer payloads: nan degrades to sign, scale stays integral
    idx = np.array([3, 7, 9], np.int64)
    assert np.array_equal(perturb_payload(idx, "nan", 0.1), -idx)
    assert perturb_payload(idx, "scale", 0.9).dtype == idx.dtype
    with pytest.raises(ValueError, match="unknown corruption kind"):
        perturb_payload(x, "bitrot", 0.1)


# -- FaultPlan silent-corruption fates ---------------------------------------


def test_silent_fate_deterministic_and_separately_namespaced():
    mk = lambda: FaultPlan(seed=5, silent_corrupt=0.6)
    grid = [("dis/round1/G_j", j, a) for j in range(3) for a in range(4)]
    f1 = [mk().silent_fate(*g) for g in grid]
    f2 = [mk().silent_fate(*g) for g in grid]
    assert f1 == f2
    assert any(f is not None for f in f1)
    assert any(f is None for f in f1)
    # enabling silent corruption never shifts the drop/corrupt/delay chain
    base = FaultPlan(seed=5, drop=0.3)
    noisy = FaultPlan(seed=5, drop=0.3, silent_corrupt=0.6)
    fates = [base.decide(*g) for g in grid]
    assert [noisy.decide(*g) for g in grid] == fates


def test_zero_silent_rate_consumes_no_draws():
    plan = FaultPlan(seed=999123, silent_corrupt={1: 0.5})
    _fault_draw.cache_clear()
    assert plan.silent_fate("some/tag", 0, 0) is None   # rate 0 for party 0
    assert _fault_draw.cache_info().misses == 0
    assert not FaultPlan(seed=0).is_null or True
    assert not FaultPlan(seed=0, silent_corrupt=0.1).is_null
    assert FaultPlan.none().is_null


def test_silent_kind_pins_flavor_and_validates():
    plan = FaultPlan(seed=1, silent_corrupt=1.0, silent_kind="nan")
    for a in range(4):
        kind, u = plan.silent_fate("t", 0, a)
        assert kind == "nan" and 0.0 <= u < 1.0
    with pytest.raises(ValueError, match="silent_kind"):
        FaultPlan(silent_kind="bitrot")
    with pytest.raises(ValueError, match="silent_corrupt"):
        FaultPlan(silent_corrupt=1.5)


# -- Transport.ship: the envelope seam ---------------------------------------


def test_ship_clean_path_returns_original_objects_and_bills_nothing():
    tr = Transport(FaultPlan.none())
    led = CommLedger()
    payloads = {j: np.arange(4, dtype=np.float32) + j for j in range(3)}
    delivered, failed = tr.ship("dis/round1/G_j", payloads, led)
    assert not failed and led.total == 0
    for j in range(3):
        assert delivered[j] is payloads[j]        # identity, not a copy
    assert tr.stats.silent_corrupts == tr.stats.silent_detected == 0


def test_ship_detects_retransmits_and_bills_exact_retry_units():
    # party 0 corrupts ~60% of attempts; a verifying transport catches every
    # one, retransmits, and delivers the ORIGINAL bytes
    plan = FaultPlan(seed=7, silent_corrupt={0: 0.6}, max_retries=16)
    tr = Transport(plan)
    led = CommLedger()
    payloads = {0: np.ones(5, np.float32), 1: np.ones(5, np.float32) * 2}
    units = {0: 5, 1: 5}
    delivered, failed = tr.ship("dis/round2/S_up", payloads, led, units=units)
    assert not failed
    assert delivered[0] is payloads[0] and delivered[1] is payloads[1]
    assert tr.stats.silent_corrupts == tr.stats.silent_detected > 0
    assert led.by_prefix("retry/dis/round2/S_up") == \
        5 * tr.stats.silent_detected
    assert led.total == led.by_prefix("retry/")   # ship never bills base tags


def test_ship_unverified_delivers_damaged_payloads():
    plan = FaultPlan(seed=7, silent_corrupt={0: 1.0}, silent_kind="sign")
    tr = Transport(plan, verify=False)
    payloads = {0: np.ones(4, np.float32), 1: np.ones(4, np.float32)}
    delivered, failed = tr.ship("t", payloads)
    assert not failed
    assert np.array_equal(delivered[0], -payloads[0])
    assert delivered[1] is payloads[1]
    assert tr.stats.silent_corrupts == 1 and tr.stats.silent_detected == 0


def test_ship_exhaustion_raises_or_drops():
    plan = FaultPlan(seed=0, silent_corrupt={0: 1.0}, max_retries=2)
    with pytest.raises(PartyUnavailable):
        Transport(plan).ship("t", {0: np.ones(3, np.float32)})
    tr = Transport(plan)
    delivered, failed = tr.ship("t", {0: np.ones(3, np.float32)},
                                drop_on_exhaust=True)
    assert 0 not in delivered and failed[0].party == 0
    assert failed[0].attempts == 3                # 1 + max_retries


# -- value-level validators ---------------------------------------------------


def test_check_mass_table_findings():
    clean = np.abs(np.random.default_rng(0).normal(size=(3, 8))) + 0.1
    assert check_mass_table(clean, clean.sum(axis=1)) == []
    nanned = clean.copy()
    nanned[1, 3] = np.nan
    f = check_mass_table(nanned)
    assert [x.party for x in f] == [1] and "non-finite" in f[0].reason
    neg = clean.copy()
    neg[2] *= -1.0
    f = check_mass_table(neg)
    assert [x.party for x in f] == [2] and "negative" in f[0].reason
    # row sum vs the independently communicated scalar total
    lied = clean.copy()
    lied[0] *= 100.0
    f = check_mass_table(lied, clean.sum(axis=1))
    assert [x.party for x in f] == [0] and "round-1 scalar" in f[0].reason
    # total-sensitivity bound, attributed to the largest contributor
    f = check_mass_table(lied, lied.sum(axis=1), bound=float(clean.sum()))
    assert [x.party for x in f] == [0] and "exceeds the task bound" in f[0].reason


def test_require_valid_masses_policies():
    bad = np.array([[1.0, np.nan], [1.0, 1.0]])
    assert require_valid_masses(bad, policy="quarantine") == (0,)
    with pytest.raises(IntegrityError, match="party 0.*non-finite"):
        require_valid_masses(bad, policy="fail")
    assert require_valid_masses(np.ones((2, 2)), np.full(2, 2.0)) == ()


def test_check_weights():
    assert check_weights(np.array([0.5, 2.0])) is None
    assert "empty" in check_weights(np.array([]))
    assert "non-finite" in check_weights(np.array([1.0, np.inf]))
    assert "<= 0" in check_weights(np.array([1.0, 0.0]))


def test_check_merge_children():
    a = np.array([0, 1, 1, 2])          # within-child repeats are legal
    b = np.array([5, 6, 7])
    check_merge_children([a, b], [np.ones(4), np.ones(3)])
    with pytest.raises(IntegrityError, match="share 1 global id"):
        check_merge_children([a, np.array([2, 9])],
                             [np.ones(4), np.ones(2)])
    with pytest.raises(IntegrityError, match="merge child 1"):
        check_merge_children([a, b], [np.ones(4), -np.ones(3)])


# -- HealthReport -------------------------------------------------------------


def test_health_from_masses():
    h = health_from_masses(np.ones((2, 4)))
    assert h.healthy and h.finite_fraction == 1.0 and h.mass_total == 8.0
    assert h.party_shares == (0.5, 0.5) and h.max_cell_share == 0.125
    sick = np.ones((2, 4))
    sick[0, 0] = np.nan
    h = health_from_masses(sick)
    assert not h.healthy and h.finite_fraction == 7 / 8
    assert any("non-finite" in n for n in h.notes)
    h = health_from_masses(np.zeros((2, 2)))
    assert not h.healthy and h.zero_mass_parties == (0, 1)
    assert any("zero total" in n for n in h.notes)
    h = health_from_masses(np.ones((2, 2)), gram_conds=[3.0, np.inf])
    assert not h.healthy and any("singular" in n for n in h.notes)
    assert "Gram condition" in h.describe()
    h = health_from_masses(np.ones((2, 2)), gram_conds=[3.0, 1e12])
    assert any("exceeds" in n for n in h.notes)


# -- builds: health attachment + clean-path bit-identity ----------------------


@pytest.mark.parametrize("engine", ["materialized", "streamed", "pipelined"])
def test_builds_attach_healthy_reports(engine):
    ds = _ds()
    cs = CoresetPipeline(ds).build(_spec(engine=engine),
                                   key=jax.random.PRNGKey(0))
    assert isinstance(cs.health, HealthReport)
    assert cs.health.healthy and cs.health.finite_fraction == 1.0
    assert len(cs.health.party_shares) == ds.T
    if engine != "materialized":                    # streaming vrlr: conds
        assert cs.health.gram_conds is not None
        assert all(np.isfinite(c) for c in cs.health.gram_conds)


def test_constant_feature_party_builds_with_health_note():
    ds = _ds()
    parts = [p.copy() for p in ds.parts]
    parts[1][:] = 1.0                               # rank-1 slice: singular Gram
    sick = VFLDataset(parts, ds.y)
    cs = CoresetPipeline(sick).build(_spec(engine="pipelined"),
                                     key=jax.random.PRNGKey(0))
    assert cs.m == 32                               # the build still completes
    assert cs.health.gram_conds is not None
    assert not np.isfinite(cs.health.gram_conds[1])
    assert not cs.health.healthy
    assert any("singular" in n or "condition" in n for n in cs.health.notes)


@pytest.mark.parametrize("engine", ["materialized", "streamed", "pipelined"])
@pytest.mark.parametrize("policy", ["fail", "retry", "degrade", "quarantine"])
def test_null_transport_bit_identical_under_every_policy(engine, policy):
    """Integrity on + no faults => draws AND ledger entries bit-identical
    to the transportless build, for every engine and policy."""
    ds = _ds()
    led0, led1 = CommLedger(), CommLedger()
    base = CoresetPipeline(ds).build(_spec(engine=engine),
                                     key=jax.random.PRNGKey(3), ledger=led0)
    tr = Transport(FaultPlan.none())
    got = CoresetPipeline(ds).build(_spec(engine=engine, policy=policy),
                                    key=jax.random.PRNGKey(3), ledger=led1,
                                    transport=tr)
    assert _same_draw(base, got)
    assert got.degraded is None
    assert [dataclasses.astuple(m) for m in led1.messages] == \
           [dataclasses.astuple(m) for m in led0.messages]
    assert tr.stats.silent_corrupts == 0


# -- quarantine end to end ----------------------------------------------------


def _poison(party, kind="sign"):
    """Party `party` silently corrupts every transmission; the wire does NOT
    verify, so the damage reaches the server's validators."""
    return Transport(FaultPlan(seed=11, silent_corrupt={party: 1.0},
                               silent_kind=kind), verify=False)


@pytest.mark.parametrize("engine", ["materialized", "pipelined"])
def test_quarantine_drops_poisoned_party_and_issues_receipt(engine):
    ds = _ds()
    led = CommLedger()
    cs = CoresetPipeline(ds).build(_spec(engine=engine, policy="quarantine"),
                                   key=jax.random.PRNGKey(3), ledger=led,
                                   transport=_poison(0))
    assert cs.degraded is not None
    assert cs.degraded.surviving == (1, 2)
    assert [d.party for d in cs.degraded.dropped] == [0]
    assert "quarantine" in cs.degraded.dropped[0].tag
    assert "quarantined for integrity violations" in cs.degraded.describe()
    assert cs.m == 32 and check_weights(cs.weights) is None
    # the survivors' draw matches a 2-party rebuild on the same key
    sub = ds.select_parties([1, 2])
    ref = CoresetPipeline(sub).build(_spec(engine=engine),
                                     key=jax.random.PRNGKey(3))
    assert _same_draw(ref, cs)


@pytest.mark.parametrize("engine", ["materialized", "pipelined"])
def test_fail_policy_raises_party_attributed_error(engine):
    ds = _ds()
    with pytest.raises(IntegrityError, match="party 0"):
        CoresetPipeline(ds).build(_spec(engine=engine, policy="fail"),
                                  key=jax.random.PRNGKey(3),
                                  transport=_poison(0))


def test_quarantining_the_label_party_is_unrecoverable():
    ds = _ds()
    with pytest.raises(IntegrityError, match="label party"):
        CoresetPipeline(ds).build(_spec(policy="quarantine"),
                                  key=jax.random.PRNGKey(3),
                                  transport=_poison(ds.T - 1))


def test_retry_policy_trusts_values_the_undefended_baseline():
    """Under `retry` with an unverifying wire the corrupted masses drive
    the draw — the exact blow-up the integrity benchmark measures."""
    ds = _ds()
    base = CoresetPipeline(ds).build(_spec(), key=jax.random.PRNGKey(3))
    got = CoresetPipeline(ds).build(_spec(policy="retry"),
                                    key=jax.random.PRNGKey(3),
                                    transport=_poison(0, kind="scale"))
    assert got.degraded is None
    assert not _same_draw(base, got)              # the corruption skewed it


# -- round-2 uploads + split_uploads ------------------------------------------


def test_split_uploads_roundtrip_and_validation():
    idx = np.arange(10)
    parts = split_uploads(idx, np.array([4, 0, 6]))
    assert [len(p) for p in parts] == [4, 0, 6]
    assert np.array_equal(np.concatenate(parts), idx)
    with pytest.raises(ValueError):
        split_uploads(idx, np.array([4, 4]))


def test_round2_corruption_detected_and_retried_with_exact_billing():
    """A verifying wire catches round-2 index corruption; the build lands
    draw-identical to fault-free, with the retries billed at a_j units."""
    ds = _ds()
    base = CoresetPipeline(ds).build(_spec(), key=jax.random.PRNGKey(3))
    led = CommLedger()
    plan = FaultPlan(seed=13, silent_corrupt=0.4, max_retries=16)
    tr = Transport(plan)
    got = CoresetPipeline(ds).build(_spec(policy="retry"),
                                    key=jax.random.PRNGKey(3), ledger=led,
                                    transport=tr)
    assert _same_draw(base, got)
    assert tr.stats.silent_detected == tr.stats.silent_corrupts > 0
    retry_units = led.by_prefix("retry/")
    assert retry_units == tr.stats.units_retried
    assert got.comm_units == base.comm_units + retry_units


# -- plan integration ---------------------------------------------------------


def test_plan_cache_key_audits_every_spec_field():
    """Every CoresetSpec field must be in the cache key (PLAN_KEY_FIELDS or
    the task/params pair) or explicitly exempted — a new field that silently
    misses the key would alias distinct plans."""
    fields = {f.name for f in dataclasses.fields(CoresetSpec)}
    covered = {"task", "params"} | set(PLAN_KEY_FIELDS) | set(PLAN_KEY_EXEMPT)
    assert fields == covered, (
        f"CoresetSpec fields {sorted(fields - covered)} missing from the "
        f"PlanCache key; add to PLAN_KEY_FIELDS or PLAN_KEY_EXEMPT"
    )
    ds = _ds(n=64)
    a = PlanCache.key(_spec(), ds)
    assert PlanCache.key(_spec(), ds) == a
    assert PlanCache.key(_spec(policy="quarantine"), ds) != a
    assert PlanCache.key(_spec(m=33), ds) != a


def test_plan_describe_surfaces_integrity_line():
    ds = _ds(n=64)
    d = compile_plan(_spec(policy="fail"), ds).describe()
    assert "integrity:" in d and "validators on" in d
    d = compile_plan(_spec(engine="streamed", policy="retry"), ds).describe()
    assert "validators off" in d and "(policy=retry)" in d


# -- dataset ingest validation (satellite) ------------------------------------


def test_vfl_dataset_nan_screen_names_party_and_column():
    rng = np.random.default_rng(0)
    parts = [rng.normal(size=(8, 3)).astype(np.float32) for _ in range(2)]
    parts[1][4, 2] = np.nan
    with pytest.raises(ValueError, match=r"NaN.*party 1 at row 4, column 2"):
        VFLDataset(parts)
    with pytest.raises(ValueError, match=r"Inf.*party 0"):
        bad = [p.copy() for p in parts]
        bad[1][4, 2] = 0.0
        bad[0][0, 0] = np.inf
        VFLDataset(bad)
    y = rng.normal(size=8).astype(np.float32)
    y[3] = np.nan
    parts[1][4, 2] = 0.0
    with pytest.raises(ValueError, match=r"labels \(party 1\) at row 3"):
        VFLDataset(parts, y)
    # the opt-out accepts the same data
    ds = VFLDataset(parts, y, validate=False)
    assert ds.n == 8


def test_vfl_dataset_structural_errors_unchanged():
    with pytest.raises(ValueError, match="parts is empty"):
        VFLDataset([])
    with pytest.raises(ValueError, match="n=0"):
        VFLDataset([np.zeros((0, 2), np.float32)])
    with pytest.raises(ValueError, match="party 1: bad shape"):
        VFLDataset([np.zeros((4, 2), np.float32),
                    np.zeros((3, 2), np.float32)])
    with pytest.raises(ValueError, match="label length mismatch"):
        VFLDataset([np.zeros((4, 2), np.float32)], np.zeros(3, np.float32))


# -- tree + service integration -----------------------------------------------


def _chunk(rng, n=200, dims=(3, 2, 2)):
    parts = [rng.normal(size=(n, d)).astype(np.float32) for d in dims]
    y = parts[0] @ np.linspace(1.0, -1.0, dims[0]).astype(np.float32)
    return parts, y.astype(np.float32)


def test_merge_reduce_rejects_cross_child_id_clash():
    rng = np.random.default_rng(0)
    parts = [rng.normal(size=(4, 2)).astype(np.float32)]
    mk = lambda ids: MaterializedCoreset(
        indices=np.asarray(ids, np.int64), weights=np.ones(len(ids)),
        parts=[parts[0][:len(ids)]], y=None)
    with pytest.raises(IntegrityError, match="disjoint stream segments"):
        merge_reduce("uniform", [mk([0, 1, 2]), mk([2, 8])], 2,
                     key=jax.random.PRNGKey(0))


def test_tree_tracks_leaf_health_and_describes_it():
    rng = np.random.default_rng(0)
    tree = CoresetTree("vrlr", 24, key=jax.random.PRNGKey(0), backend="ref",
                       block_size=BLOCK)
    for _ in range(3):
        tree.insert(*_chunk(rng))
    assert tree.health_checks == 3 and tree.health_warnings == 0
    assert tree.last_health is not None and tree.last_health.healthy
    assert "health: 3 checked, 0 warning(s), last=ok" in tree.describe()


def test_tree_rolls_back_health_census_on_failed_insert():
    rng = np.random.default_rng(0)
    tree = CoresetTree("vrlr", 24, key=jax.random.PRNGKey(0), backend="ref",
                       block_size=BLOCK)
    tree.insert(*_chunk(rng))
    snap = (tree.health_checks, tree.health_warnings, tree.last_health)
    parts, y = _chunk(rng)
    with pytest.raises(ValueError):
        tree.insert([p[:0] for p in parts], y[:0])   # zero-row chunk
    assert (tree.health_checks, tree.health_warnings,
            tree.last_health) == snap


def test_service_stats_aggregate_health():
    svc = CoresetService(backend="ref")
    svc.register("a", task="vrlr", budget=24, seed=1, block_size=BLOCK)
    rng = np.random.default_rng(1)
    svc.insert("a", *_chunk(rng))
    svc.insert("a", *_chunk(rng))
    s = svc.stats()
    assert s["health_checks"] == 2 and s["health_warnings"] == 0
