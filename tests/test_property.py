"""Hypothesis property tests on the system's invariants."""

import pytest

pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.core.comm import CommLedger, theoretical_dis_cost
from repro.core.dis import dis_sample
from repro.core.selector import SelectorConfig, local_scores, sample_coreset
from repro.core.vfl import split_columns
from repro.sharding.specs import MESH_SIZES, sanitize

SETTINGS = dict(max_examples=25, deadline=None)


@given(st.integers(1, 64), st.integers(1, 6))
@settings(**SETTINGS)
def test_split_columns_partition(d, T):
    if T > d:
        T = d
    slices = split_columns(d, T)
    cover = sorted(i for s in slices for i in range(s.start, s.stop))
    assert cover == list(range(d))
    assert len(slices) == T


@given(st.integers(2, 40), st.integers(1, 4), st.integers(1, 60),
       st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_dis_protocol_invariants(n, T, m, seed):
    key = jax.random.PRNGKey(seed)
    scores = [jax.random.uniform(jax.random.fold_in(key, j), (n,)) + 1e-3
              for j in range(T)]
    led = CommLedger()
    S, w = dis_sample(jax.random.fold_in(key, 99), scores, m, led)
    assert S.shape == (m,) and w.shape == (m,)
    assert bool(jnp.all((S >= 0) & (S < n)))
    assert bool(jnp.all(w > 0))
    lo, hi = theoretical_dis_cost(m, T)
    assert lo <= led.total <= hi
    # weight identity: w_i * m * g_i == G for every sample
    g = jnp.sum(jnp.stack(scores), 0)
    np.testing.assert_allclose(np.asarray(w * m * g[S]),
                               float(g.sum()), rtol=1e-4)


@given(st.lists(st.integers(1, 4096), min_size=1, max_size=4),
       st.integers(0, 2))
@settings(**SETTINGS)
def test_sanitize_always_divisible(dims, n_axes):
    axes = ["model", "data", ("pod", "data")][: n_axes + 1]
    spec = P(*(axes[i % len(axes)] for i in range(len(dims))))
    out = sanitize(spec, tuple(dims))
    for dim, ax in zip(dims, tuple(out) + (None,) * (len(dims) - len(out))):
        if ax is None:
            continue
        size = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            size *= MESH_SIZES[a]
        assert dim % size == 0


@given(st.integers(2, 32), st.integers(1, 16), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_selector_weights_unbiased_scale(B, d, seed):
    key = jax.random.PRNGKey(seed)
    feats = jax.random.normal(key, (B, d))
    g = local_scores(feats, "norm", 1e-4)
    m = max(1, B // 2)
    S, w = sample_coreset(jax.random.fold_in(key, 1), g, m)
    # E[sum w] = B; single-draw bound: every weight is positive and finite
    assert bool(jnp.all(w > 0)) and bool(jnp.all(jnp.isfinite(w)))
    assert S.shape == (m,)


@given(st.integers(1, 200), st.integers(1, 199))
@settings(**SETTINGS)
def test_selector_m_of(B, pct):
    cfg = SelectorConfig(fraction=pct / 100)
    m = cfg.m_of(B)
    assert 1 <= m <= 2 * B


@given(st.integers(4, 64), st.integers(2, 8), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_dis_estimator_positive_combination(n, T, seed):
    """Coreset cost estimates of a non-negative objective stay non-negative
    and finite for arbitrary scores."""
    key = jax.random.PRNGKey(seed)
    scores = [jax.random.uniform(jax.random.fold_in(key, j), (n,)) + 1e-6
              for j in range(T)]
    f = jax.random.uniform(jax.random.fold_in(key, 777), (n,))
    S, w = dis_sample(jax.random.fold_in(key, 1), scores, max(1, n // 2))
    est = jnp.sum(w * f[S])
    assert bool(est >= 0) and bool(jnp.isfinite(est))
