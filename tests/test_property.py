"""Hypothesis property tests on the system's invariants."""

import pytest

pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.core.comm import CommLedger, theoretical_dis_cost
from repro.core.dis import dis_sample
from repro.core.selector import SelectorConfig, local_scores, sample_coreset
from repro.core.vfl import split_columns
from repro.sharding.specs import MESH_SIZES, sanitize

SETTINGS = dict(max_examples=25, deadline=None)


@given(st.integers(1, 64), st.integers(1, 6))
@settings(**SETTINGS)
def test_split_columns_partition(d, T):
    if T > d:
        T = d
    slices = split_columns(d, T)
    cover = sorted(i for s in slices for i in range(s.start, s.stop))
    assert cover == list(range(d))
    assert len(slices) == T


@given(st.integers(2, 40), st.integers(1, 4), st.integers(1, 60),
       st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_dis_protocol_invariants(n, T, m, seed):
    key = jax.random.PRNGKey(seed)
    scores = [jax.random.uniform(jax.random.fold_in(key, j), (n,)) + 1e-3
              for j in range(T)]
    led = CommLedger()
    S, w = dis_sample(jax.random.fold_in(key, 99), scores, m, led)
    assert S.shape == (m,) and w.shape == (m,)
    assert bool(jnp.all((S >= 0) & (S < n)))
    assert bool(jnp.all(w > 0))
    lo, hi = theoretical_dis_cost(m, T)
    assert lo <= led.total <= hi
    # weight identity: w_i * m * g_i == G for every sample
    g = jnp.sum(jnp.stack(scores), 0)
    np.testing.assert_allclose(np.asarray(w * m * g[S]),
                               float(g.sum()), rtol=1e-4)


@given(st.lists(st.integers(1, 4096), min_size=1, max_size=4),
       st.integers(0, 2))
@settings(**SETTINGS)
def test_sanitize_always_divisible(dims, n_axes):
    axes = ["model", "data", ("pod", "data")][: n_axes + 1]
    spec = P(*(axes[i % len(axes)] for i in range(len(dims))))
    out = sanitize(spec, tuple(dims))
    for dim, ax in zip(dims, tuple(out) + (None,) * (len(dims) - len(out))):
        if ax is None:
            continue
        size = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            size *= MESH_SIZES[a]
        assert dim % size == 0


@given(st.integers(2, 32), st.integers(1, 16), st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_selector_weights_unbiased_scale(B, d, seed):
    key = jax.random.PRNGKey(seed)
    feats = jax.random.normal(key, (B, d))
    g = local_scores(feats, "norm", 1e-4)
    m = max(1, B // 2)
    S, w = sample_coreset(jax.random.fold_in(key, 1), g, m)
    # E[sum w] = B; single-draw bound: every weight is positive and finite
    assert bool(jnp.all(w > 0)) and bool(jnp.all(jnp.isfinite(w)))
    assert S.shape == (m,)


@given(st.integers(1, 200), st.integers(1, 199))
@settings(**SETTINGS)
def test_selector_m_of(B, pct):
    cfg = SelectorConfig(fraction=pct / 100)
    m = cfg.m_of(B)
    assert 1 <= m <= 2 * B


@given(st.integers(5, 200), st.integers(1, 8), st.integers(1, 32),
       st.booleans(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_fused_kernel_matches_composition(n, k, d, weighted, seed):
    """The fused single-pass kmeans_assign_update equals the seed data flow
    (kmeans_assign + segment_sum composition) across shapes and weights."""
    from repro.kernels import kmeans_assign_update as _kau
    from repro.kernels import ops

    kx, kc, kw = jax.random.split(jax.random.PRNGKey(seed), 3)
    X = jax.random.normal(kx, (n, d))
    C = jax.random.normal(kc, (k, d))
    w = jax.random.uniform(kw, (n,)) + 0.1 if weighted else None
    a_f, d2_f, cs_f, ws_f, cc_f = _kau.kmeans_assign_update(
        X, C, w, interpret=True)
    # compose from the SAME (pallas) assignment so ties cannot diverge
    a_c, d2_c = ops.kmeans_assign(X, C)
    ww = jnp.ones((n,)) if w is None else w
    ws_c = jax.ops.segment_sum(ww, a_c, num_segments=k)
    cs_c = jax.ops.segment_sum(ww[:, None] * X, a_c, num_segments=k)
    cc_c = jax.ops.segment_sum(ww * d2_c, a_c, num_segments=k)
    np.testing.assert_array_equal(np.asarray(a_f), np.asarray(a_c))
    np.testing.assert_allclose(np.asarray(d2_f), np.asarray(d2_c), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ws_f), np.asarray(ws_c), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(cs_f), np.asarray(cs_c), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(cc_f), np.asarray(cc_c), rtol=1e-4, atol=1e-3)


@given(st.integers(2, 4), st.integers(5, 60), st.integers(1, 5),
       st.integers(1, 16), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=8, deadline=None)
def test_kernels_vmap_safe_in_interpret_mode(B, n, k, d, seed):
    """vmap folds a leading batch dim into the kernel grid for all three
    kernels; every batch slice equals its standalone call."""
    from repro.kernels import kmeans_assign as _ka
    from repro.kernels import kmeans_assign_update as _kau
    from repro.kernels import leverage as _lev

    key = jax.random.PRNGKey(seed)
    X = jax.random.normal(jax.random.fold_in(key, 0), (n, d))
    Cs = jax.random.normal(jax.random.fold_in(key, 1), (B, k, d))
    A = jax.random.normal(jax.random.fold_in(key, 2), (B, d, d))
    Ms = jnp.einsum("bij,bkj->bik", A, A) / d

    # block_n=16 forces multi-step grids for n > 16 — the vmapped scratch
    # init/flush across grid steps is the load-bearing part of the claim
    a_v, d_v = jax.vmap(
        lambda c: _ka.kmeans_assign(X, c, block_n=16, interpret=True))(Cs)
    lev_v = jax.vmap(
        lambda m: _lev.leverage(X, m, block_n=16, interpret=True))(Ms)
    f_v = jax.vmap(
        lambda c: _kau.kmeans_assign_update(X, c, block_n=16, interpret=True))(Cs)
    for b in range(B):
        a_b, d_b = _ka.kmeans_assign(X, Cs[b], block_n=16, interpret=True)
        np.testing.assert_array_equal(np.asarray(a_v[b]), np.asarray(a_b))
        np.testing.assert_allclose(np.asarray(d_v[b]), np.asarray(d_b),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(lev_v[b]),
            np.asarray(_lev.leverage(X, Ms[b], block_n=16, interpret=True)),
            rtol=1e-5, atol=1e-5)
        f_b = _kau.kmeans_assign_update(X, Cs[b], block_n=16, interpret=True)
        for o_v, o_b in zip(f_v, f_b):
            np.testing.assert_allclose(np.asarray(o_v[b]), np.asarray(o_b),
                                       rtol=1e-5, atol=1e-5)


@given(st.integers(2, 300), st.integers(1, 4), st.integers(1, 350),
       st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_blocked_marginals_match_flat_for_random_partitions(n, T, block_size, seed):
    """Hierarchical DIS correctness: for ANY block partition the induced
    marginal of dis_plan_blocked telescopes to exactly the flat dis_marginals
    (float64, unsimplified cell-sum vs the direct g/G)."""
    from repro.core.dis import dis_blocked_marginals

    key = jax.random.PRNGKey(seed)
    scores = [jax.random.uniform(jax.random.fold_in(key, j), (n,)) + 1e-3
              for j in range(T)]
    mb = dis_blocked_marginals(scores, block_size)
    g64 = np.stack([np.asarray(g, np.float64) for g in scores]).sum(axis=0)
    np.testing.assert_allclose(mb, g64 / g64.sum(), rtol=1e-12)
    np.testing.assert_allclose(mb, np.asarray(dis_marginals(scores)), rtol=1e-5)


@given(st.integers(10, 200), st.integers(1, 3), st.integers(1, 40),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_blocked_plan_reduces_to_full_and_keeps_invariants(n, T, m, seed):
    """block_size >= n is bit-identical to the flat plan; a random smaller
    block size keeps the protocol invariants (index range, positive weights,
    counts summing to m, weight identity w*m*g = G)."""
    from repro.core.dis import dis_plan_blocked, dis_plan_full

    key = jax.random.PRNGKey(seed)
    scores = jnp.stack(
        [jax.random.uniform(jax.random.fold_in(key, j), (n,)) + 1e-3
         for j in range(T)])
    pkey = jax.random.fold_in(key, 99)
    pf = dis_plan_full(pkey, scores, m)
    pb = dis_plan_blocked(pkey, scores, m, block_size=n)
    np.testing.assert_array_equal(np.asarray(pf.indices), np.asarray(pb.indices))
    np.testing.assert_array_equal(np.asarray(pf.weights), np.asarray(pb.weights))
    np.testing.assert_array_equal(np.asarray(pf.counts), np.asarray(pb.counts))

    bsz = max(1, n // max(1, (seed % 7) + 1) - 3)
    ps = dis_plan_blocked(pkey, scores, m, block_size=bsz)
    assert bool(jnp.all((ps.indices >= 0) & (ps.indices < n)))
    assert bool(jnp.all(ps.weights > 0))
    assert int(ps.counts.sum()) == m
    g = np.asarray(scores.sum(axis=0))
    np.testing.assert_allclose(
        np.asarray(ps.weights) * m * g[np.asarray(ps.indices)],
        float(np.asarray(scores).sum()), rtol=1e-3)


@given(st.integers(4, 64), st.integers(2, 8), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_dis_estimator_positive_combination(n, T, seed):
    """Coreset cost estimates of a non-negative objective stay non-negative
    and finite for arbitrary scores."""
    key = jax.random.PRNGKey(seed)
    scores = [jax.random.uniform(jax.random.fold_in(key, j), (n,)) + 1e-6
              for j in range(T)]
    f = jax.random.uniform(jax.random.fold_in(key, 777), (n,))
    S, w = dis_sample(jax.random.fold_in(key, 1), scores, max(1, n // 2))
    est = jnp.sum(w * f[S])
    assert bool(est >= 0) and bool(jnp.isfinite(est))
