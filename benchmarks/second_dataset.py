"""Paper Appendix A.4 (Figures 10/11): KC-House-profile dataset, T=2 parties
(9 features each), plain linear regression + k-means."""

from __future__ import annotations

from benchmarks.common import (
    make_vkmc_data,
    make_vrlr_data,
    run_vkmc_method,
    run_vrlr_method,
    sweep,
    write_rows,
)

BENCH = "kchouse"
SIZES_SMALL = [100, 200, 500, 1000, 2000]


def run(fast: bool = True):
    repeats = 3 if fast else 20
    rows = []
    train, test = make_vrlr_data(fast, T=2, dataset="kchouse")
    base = run_vrlr_method("central", None, 0, train, test, seed=0, reg_kind="linear")
    rows.append({"bench": BENCH, "method": "CENTRAL", "size": train.n,
                 "cost_mean": base["cost"], "cost_std": 0.0,
                 "comm": base["comm"], "wall_s": base["wall_s"]})
    for sampling, tag in (("coreset", "C"), ("uniform", "U")):
        for row in sweep(lambda m, r: run_vrlr_method(
                "central", sampling, m, train, test, seed=17 * r + m,
                reg_kind="linear"), SIZES_SMALL, repeats):
            rows.append({"bench": BENCH, "method": f"{tag}-CENTRAL", **row})

    ds = make_vkmc_data(fast, T=2, dataset="kchouse")
    base = run_vkmc_method("kmeanspp", None, 0, ds, 10, seed=0)
    rows.append({"bench": BENCH, "method": "KMEANS++", "size": ds.n,
                 "cost_mean": base["cost"], "cost_std": 0.0,
                 "comm": base["comm"], "wall_s": base["wall_s"]})
    for sampling, tag in (("coreset", "C"), ("uniform", "U")):
        for row in sweep(lambda m, r: run_vkmc_method(
                "kmeanspp", sampling, m, ds, 10, seed=19 * r + m),
                SIZES_SMALL, repeats):
            rows.append({"bench": BENCH, "method": f"{tag}-KMEANS++", **row})
    write_rows(BENCH, rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
