"""Shared harness for the paper-reproduction benchmarks.

Each benchmark module exposes ``run(fast: bool) -> list[dict]`` with rows
{"bench", "method", "size", "cost_mean", "cost_std", "comm", "wall_s"} and
appends them to benchmarks/artifacts/<bench>.csv.  ``benchmarks.run``
aggregates everything and prints the harness-level
``name,us_per_call,derived`` CSV.

Offline-data note: YearPredictionMSD / KC-House are replaced by matched
generators (see repro.data.synthetic); sizes default to ~10x smaller than
the paper's so the full suite finishes on one CPU core — pass --full for
paper-scale n.
"""

from __future__ import annotations

import csv
import os
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CommLedger,
    VFLDataset,
    build_coreset,
    central_comm_cost,
    ridge_closed_form,
    ridge_cost,
    standardize,
)
from repro.core.vkmc import kmeans, kmeans_central_comm_cost, kmeans_cost, distdim
from repro.core import vrlr as vrlr_mod
from repro.data.synthetic import kc_house_like, year_prediction_like

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts")

# Machine-readable kernel-perf trajectory, tracked from PR 2 onward.  Lives
# at the repo root (next to the CSV artifacts dir) so CI uploads it and
# successive PRs can diff the entries.
BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                          "BENCH_kernels.json")

SIZES = [1000, 2000, 3000, 4000, 5000, 6000]


def write_bench_json(section: str, entries: List[Dict]) -> None:
    """Merge ``entries`` under ``section`` into BENCH_kernels.json.

    Sections are replaced wholesale per run (each benchmark module owns one
    section); other sections are preserved so kernel_micro and fused_lloyd
    can update the same artifact independently.
    """
    import json

    doc = {"schema": 1, "backend": jax.default_backend(), "sections": {}}
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                doc = json.load(f)
        except (json.JSONDecodeError, OSError):
            pass
    doc.setdefault("sections", {})
    doc["schema"] = 1
    doc["backend"] = jax.default_backend()
    doc["sections"][section] = entries
    with open(BENCH_JSON, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def time_us(fn: Callable, *args, iters: int = 5) -> float:
    """Mean wall microseconds per call: one blocked warmup (compile/trace),
    then ``iters`` timed calls blocked at the end.  Shared by the kernel
    microbenchmarks so their numbers stay comparable."""
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    out = None
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def write_rows(bench: str, rows: List[Dict]) -> None:
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"{bench}.csv")
    if not rows:
        return
    keys = list(rows[0])
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        w.writerows(rows)


def make_vrlr_data(fast: bool, T: int = 3, dataset: str = "yearpred"):
    """(train VFLDataset, test VFLDataset)."""
    key = jax.random.PRNGKey(7)
    if dataset == "yearpred":
        n = 51534 if fast else 515345
        X, y = year_prediction_like(key, n=n)
    else:
        X, y = kc_house_like(key)
    n = X.shape[0]
    n_test = n // 10
    y = y - y[:-n_test].mean()     # center targets (paper's ~90 testing loss
    #                                implies mean-removed years; with raw
    #                                labels ridge lam=0.1n collapses to E[y^2])
    ds = VFLDataset.from_dense(X, y, T=T)
    train = VFLDataset([p[:-n_test] for p in ds.parts], ds.y[:-n_test])
    test = VFLDataset([p[-n_test:] for p in ds.parts], ds.y[-n_test:])
    return train, test


def make_vkmc_data(fast: bool, T: int = 3, dataset: str = "yearpred"):
    key = jax.random.PRNGKey(11)
    if dataset == "yearpred":
        n = 51534 if fast else 515345
        X, _ = year_prediction_like(key, n=n)
    else:
        X, _ = kc_house_like(key)
    return standardize(VFLDataset.from_dense(X, None, T=T))


# --------------------------------------------------------------------------
# VRLR method runners (the paper's C-/U- x {CENTRAL, SAGA} grid)
# --------------------------------------------------------------------------

def vrlr_eval(train: VFLDataset, test: VFLDataset, theta, reg_kind: str,
              lam: float, lam1: float, lam2: float, on_train: bool) -> float:
    """Ridge/linear report the paper's 'testing loss' = plain test MSE (the
    regulariser is a train-time device; including lam*|th|^2 in the eval
    rewards under-converged low-norm solutions).  Lasso/elastic report the
    training objective, as in appendix A.2."""
    ds = train if on_train else test
    X, y = ds.full(), ds.y
    if reg_kind == "lasso":
        return float(vrlr_mod.lasso_cost(X, y, theta, lam1) / ds.n)
    if reg_kind == "elastic":
        return float(vrlr_mod.elastic_cost(X, y, theta, lam1, lam2) / ds.n)
    return float(vrlr_mod.sq_loss(X, y, theta) / ds.n)


def run_vrlr_method(
    method: str,                      # central | saga
    sampling: Optional[str],          # None | coreset | uniform
    m: int,
    train: VFLDataset,
    test: VFLDataset,
    seed: int,
    reg_kind: str = "ridge",
    saga_steps: int = 20000,
) -> Dict:
    """One (method, sampling, m) cell -> {cost, comm, wall}."""
    n = train.n
    lam = 0.1 * n if reg_kind == "ridge" else 0.0
    lam1 = 2.0 * n if reg_kind in ("lasso", "elastic") else 0.0
    lam2 = 1.0 * n if reg_kind == "elastic" else 0.0
    key = jax.random.PRNGKey(seed)
    led = CommLedger()
    t0 = time.time()

    if sampling is None:
        X, y, w = train.full(), train.y, None
        central_comm_cost(n, train.dims, led)
        eff_lam, eff_l1, eff_l2 = lam, lam1, lam2
    else:
        task = "vrlr" if sampling == "coreset" else "uniform"
        cs = build_coreset(task, train, m, key=key, ledger=led)
        X, y, w = cs.materialize(train)
        for j in range(train.T):            # ship the m selected rows
            led.party_to_server("materialize/rows", j, m * train.dims[j])
        led.party_to_server("materialize/labels", train.T - 1, m)
        eff_lam, eff_l1, eff_l2 = lam, lam1, lam2

    key2 = jax.random.fold_in(key, 1)
    if method == "central":
        if reg_kind == "ridge":
            theta = ridge_closed_form(X, y, eff_lam, w)
        elif reg_kind == "linear":
            theta = ridge_closed_form(X, y, 1e-6, w)
        else:
            theta = vrlr_mod.fista(X, y, eff_l1, eff_l2, w)
    else:  # saga (VFL fashion; comm accounted inside; auto step size)
        theta = vrlr_mod.saga_ridge(key2, X, y, eff_lam, w, steps=saga_steps,
                                    dims=train.dims, ledger=led)
    wall = time.time() - t0
    on_train = reg_kind != "ridge"
    cost = vrlr_eval(train, test, theta, reg_kind, lam, lam1, lam2, on_train)
    return {"cost": cost, "comm": led.total, "wall_s": round(wall, 2)}


# --------------------------------------------------------------------------
# VKMC method runners (C-/U- x {KMEANS++, DISTDIM})
# --------------------------------------------------------------------------

def run_vkmc_method(
    method: str,                      # kmeanspp | distdim
    sampling: Optional[str],
    m: int,
    ds: VFLDataset,
    k: int,
    seed: int,
) -> Dict:
    key = jax.random.PRNGKey(seed)
    led = CommLedger()
    t0 = time.time()
    if sampling is None:
        sub, w = ds, None
        if method == "kmeanspp":
            kmeans_central_comm_cost(ds.n, ds.dims, led)
            centers = kmeans(key, ds.full(), k)
        else:
            centers = distdim(key, ds, k, ledger=led)
    else:
        if sampling == "coreset":
            cs = build_coreset("vkmc", ds, m, key=key, k=k, ledger=led)
        else:
            cs = build_coreset("uniform", ds, m, key=key, ledger=led)
        XS, _, w = cs.materialize(ds)
        for j in range(ds.T):
            led.party_to_server("materialize/rows", j, m * ds.dims[j])
        sub = VFLDataset.from_dense(XS, None, T=ds.T, sizes=list(ds.dims))
        key2 = jax.random.fold_in(key, 2)
        if method == "kmeanspp":
            centers = kmeans(key2, XS, k, w)
        else:
            centers = distdim(key2, sub, k, w, ledger=CommLedger())  # solver on coreset
    wall = time.time() - t0
    cost = float(kmeans_cost(ds.full(), centers)) / ds.n
    return {"cost": cost, "comm": led.total, "wall_s": round(wall, 2)}


def sweep(cell_fn: Callable[[int, int], Dict], sizes: List[int], repeats: int) -> List[Dict]:
    rows = []
    for m in sizes:
        costs, comms, walls = [], [], []
        for r in range(repeats):
            out = cell_fn(m, r)
            costs.append(out["cost"])
            comms.append(out["comm"])
            walls.append(out["wall_s"])
        rows.append({
            "size": m,
            "cost_mean": float(np.mean(costs)),
            "cost_std": float(np.std(costs)),
            "comm": int(np.mean(comms)),
            "wall_s": float(np.mean(walls)),
        })
    return rows
