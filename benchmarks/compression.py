"""Compression benchmark: the bit-billed wire under codec compression.

Three experiments, recorded under the ``compression`` section of
BENCH_kernels.json:

* ``raw-identity`` — ``codec="raw_fp32"`` (the default) is a no-op in
  every observable: for materialized and pipelined builds the draw,
  the per-tag unit receipts AND the per-tag bit receipts are identical
  transport-vs-transportless, and the plan's ``predicted_wire_bits``
  equals both the coreset's ``comm_bits`` and the ledger's
  ``total_bits`` to the bit.
* ``detect-int8`` — the envelope's CRC covers the COMPRESSED payload:
  under silent corruption every perturbed int8 table is caught at the
  wire, every delivered table equals the quantized round-trip
  ``decode(encode(x))`` within the codec's documented tolerance, and
  every retransmission bills ``retry/<tag>`` exactly
  ``wire_bits``-per-detection.  An end-to-end int8 build through a
  corrupting verified wire lands draw-identical to the clean int8
  build, paying only the measured retry bits.
* ``tradeoff`` — the acceptance gate at n=2e4 for BOTH tasks (vrlr and
  vkmc): ``int8_blockscale`` shrinks the round-1 mass tables >= 3x
  versus ``raw_fp32`` while the downstream rel_error (via
  :func:`evaluate`, never a proxy) stays within max(2x the raw
  baseline, 0.02); every build's bits reconcile against the ledger
  receipts to the bit, and lossy builds never exceed the plan's
  certified ``predicted_wire_bits`` bound.

  PYTHONPATH=src python -m benchmarks.compression --fast
  PYTHONPATH=src python -m benchmarks.run --sections compression --strict
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import write_bench_json, write_rows
from benchmarks.serve import _chunk_stream, _stream_ds
from repro.core import (
    CODEC_LADDER,
    CommLedger,
    CoresetPipeline,
    CoresetSpec,
    FaultPlan,
    Transport,
    evaluate,
    fit_kmeans,
    fit_ridge,
    fmt_bits,
    full_data_coreset,
    get_codec,
    standardize,
)

BENCH = "compression"
SECTION = "compression"

DETECT_RATE = 0.4            # per-message corruption odds at the wire
DETECT_RETRIES = 16          # 0.4^17 ~ 2e-7 exhaustion odds per message
SWEEP_N = 20_000             # the acceptance criterion's n (both modes)
TABLE_RATIO_GATE = 3.0       # int8 round-1 tables >= 3x smaller than raw
REL_FACTOR = 2.0             # compressed rel_error within 2x raw's...
REL_FLOOR = 0.02             # ...with an absolute floor for the tiny regime

R1_TABLE_TAG = "dis/round1/G_j"


def _vrlr_stream(seed, n, d=12, T=3, num_chunks=4):
    chunks = _chunk_stream(seed, num_chunks, n // num_chunks, d, T, True)
    return chunks, _stream_ds(chunks)


# --------------------------------------------------------------------------
# Experiment 1: raw_fp32 is pinned identical to the pre-codec wire
# --------------------------------------------------------------------------

def run_raw_identity(fast: bool):
    n = 8192 if fast else 32768
    m, d, T = 256, 12, 3
    _, ds = _vrlr_stream(21, n, d, T)
    key = jax.random.PRNGKey(17)
    entries, rows = [], []
    for engine in ("materialized", "pipelined"):
        spec = CoresetSpec(task="vrlr", budgets=m, engine=engine,
                           backend="ref", block_size=512)
        pipe = CoresetPipeline(ds)
        plan = pipe.plan(spec)
        if plan.codec != "raw_fp32":
            raise AssertionError(
                f"{engine}: default spec resolved codec {plan.codec!r}, "
                f"expected raw_fp32")
        t0 = time.time()
        led0 = CommLedger()
        cs0 = pipe.build(spec, key=key, ledger=led0)
        led1 = CommLedger()
        cs1 = pipe.build(spec, key=key, ledger=led1,
                         transport=Transport(FaultPlan.none()))
        wall = time.time() - t0
        if not (np.array_equal(np.asarray(cs0.indices), np.asarray(cs1.indices))
                and np.array_equal(np.asarray(cs0.weights),
                                   np.asarray(cs1.weights))):
            raise AssertionError(
                f"{engine}: raw wire drifted from the transportless draw")
        if led0.by_tag() != led1.by_tag():
            raise AssertionError(
                f"{engine}: per-tag UNIT receipts differ transport-vs-none: "
                f"{led0.by_tag()} vs {led1.by_tag()}")
        if led0.by_tag(bits=True) != led1.by_tag(bits=True):
            raise AssertionError(
                f"{engine}: per-tag BIT receipts differ transport-vs-none: "
                f"{led0.by_tag(bits=True)} vs {led1.by_tag(bits=True)}")
        for label, cs, led in (("bare", cs0, led0), ("wire", cs1, led1)):
            if not (plan.predicted_wire_bits == cs.comm_bits
                    == led.total_bits):
                raise AssertionError(
                    f"{engine}/{label}: predicted {plan.predicted_wire_bits} "
                    f"!= coreset {cs.comm_bits} != ledger {led.total_bits} "
                    f"bits")
            if cs.comm_units != led.total:
                raise AssertionError(
                    f"{engine}/{label}: coreset units {cs.comm_units} != "
                    f"ledger {led.total}")
        entries.append({
            "kind": "raw-identity", "engine": engine, "n": n, "m": m,
            "wire_bits": led1.total_bits, "units": led1.total,
            "draw_identical": True, "receipts_identical": True,
        })
        rows.append({
            "bench": BENCH, "method": f"raw-identity-{engine}", "size": n,
            "cost_mean": 1.0, "cost_std": 0.0, "comm": led1.total,
            "wall_s": round(wall, 3),
        })
    return entries, rows


# --------------------------------------------------------------------------
# Experiment 2: CRC over the compressed payload + exact retry-bit billing
# --------------------------------------------------------------------------

def run_detect_int8(fast: bool):
    rounds = 80 if fast else 320
    T, cells = 3, 4096
    c = get_codec("int8_blockscale")
    row_bits = c.wire_bits((cells,), "float32")
    rng = np.random.default_rng(0)
    payloads = {j: rng.random(cells).astype(np.float32) + 0.1
                for j in range(T)}
    quantized = {j: c.decode(c.encode(p), p.shape, p.dtype)
                 for j, p in payloads.items()}
    for j, p in payloads.items():
        if 8 * len(c.encode(p)) != row_bits:
            raise AssertionError(
                f"party {j}: packed length != wire_bits({cells},) — the "
                f"shape-determined contract is broken")

    tr = Transport(FaultPlan(seed=31, silent_corrupt=DETECT_RATE,
                             silent_kind="scale",
                             max_retries=DETECT_RETRIES))
    led = CommLedger()
    t0 = time.time()
    for i in range(rounds):
        delivered, failed = tr.ship(f"detect/int8/r{i}", payloads, led,
                                    units={j: cells for j in range(T)},
                                    codec="int8_blockscale")
        if failed:
            raise AssertionError(f"exhaustion at round {i} despite "
                                 f"{DETECT_RETRIES} retries")
        for j, arr in delivered.items():
            if not np.array_equal(np.asarray(arr), quantized[j]):
                raise AssertionError(
                    f"party {j} delivered != decode(encode(x)) through a "
                    f"VERIFYING wire at round {i}")
            err = float(np.max(np.abs(np.asarray(arr) - payloads[j])))
            tol = c.tolerance * float(np.max(np.abs(payloads[j])))
            if err > tol:
                raise AssertionError(
                    f"party {j}: round-trip error {err:.3g} exceeds the "
                    f"documented tolerance {tol:.3g}")
    wall = time.time() - t0
    st = tr.stats
    if st.silent_corrupts == 0:
        raise AssertionError(f"the plan never corrupted anything across "
                             f"{rounds} rounds")
    if st.silent_detected != st.silent_corrupts:
        raise AssertionError(
            f"{st.silent_corrupts} corruptions but only "
            f"{st.silent_detected} detected — the CRC over the compressed "
            f"payload missed some")
    retry_bits = led.by_prefix("retry/", bits=True)
    if retry_bits != st.bits_retried or retry_bits != row_bits * st.silent_detected:
        raise AssertionError(
            f"retry bill {retry_bits} bits != {row_bits} x "
            f"{st.silent_detected} detections (stats say {st.bits_retried})")
    entries = [{
        "kind": "detect-int8", "rounds": rounds, "cells": cells,
        "messages": rounds * T, "corrupts": st.silent_corrupts,
        "detected": st.silent_detected, "detection_rate": 1.0,
        "retry_bits": retry_bits, "row_bits": row_bits,
    }]
    rows = [{
        "bench": BENCH, "method": "detect-int8", "size": rounds * T,
        "cost_mean": 1.0, "cost_std": 0.0, "comm": led.total,
        "wall_s": round(wall, 3),
    }]

    # end-to-end: an int8 build through a corrupting verified wire is
    # draw-identical to the clean int8 build and pays exactly the
    # measured retry bits on top
    _, ds = _vrlr_stream(21, 8192 if fast else 16384)
    key = jax.random.PRNGKey(17)
    spec = CoresetSpec(task="vrlr", budgets=256, engine="materialized",
                       backend="ref", codec="int8_blockscale",
                       fault_policy="retry")
    led_c = CommLedger()
    cs_c = CoresetPipeline(ds).build(spec, key=key, ledger=led_c,
                                     transport=Transport(FaultPlan.none()))
    tr2 = Transport(FaultPlan(seed=47, silent_corrupt=0.3,
                              silent_kind="sign",
                              max_retries=DETECT_RETRIES))
    led_x = CommLedger()
    cs_x = CoresetPipeline(ds).build(spec, key=key, ledger=led_x,
                                     transport=tr2)
    if not (np.array_equal(np.asarray(cs_x.indices), np.asarray(cs_c.indices))
            and np.array_equal(np.asarray(cs_x.weights),
                               np.asarray(cs_c.weights))):
        raise AssertionError("corrupted int8 wire drifted from the clean "
                             "int8 build's draw")
    if led_x.total_bits != led_c.total_bits + tr2.stats.bits_retried:
        raise AssertionError(
            f"corrupted-wire bill {led_x.total_bits} bits != clean "
            f"{led_c.total_bits} + retried {tr2.stats.bits_retried}")
    if cs_x.comm_bits != cs_c.comm_bits + tr2.stats.bits_retried:
        raise AssertionError(
            f"coreset comm_bits {cs_x.comm_bits} != clean {cs_c.comm_bits} "
            f"+ retried {tr2.stats.bits_retried}")
    entries.append({
        "kind": "detect-int8-e2e", "n": ds.n, "m": 256,
        "corrupts": tr2.stats.silent_corrupts,
        "detected": tr2.stats.silent_detected, "draw_identical": True,
        "bill_bits": led_x.total_bits, "clean_bits": led_c.total_bits,
        "retry_bits": tr2.stats.bits_retried,
    })
    return entries, rows


# --------------------------------------------------------------------------
# Experiment 3: bits vs rel_error at the acceptance n, both tasks
# --------------------------------------------------------------------------

def _sweep_one(task, ds, m, rel_of, entries, rows):
    """One codec ladder sweep on one task; returns per-codec results and
    enforces the reconcile-to-the-bit receipts."""
    T = ds.T
    pipe = CoresetPipeline(ds)
    key = jax.random.PRNGKey(100)
    results = {}
    for name in CODEC_LADDER:
        spec = CoresetSpec(task=task, budgets=m, engine="materialized",
                           backend="ref", codec=name,
                           params={"k": 5} if task == "vkmc" else {})
        plan = pipe.plan(spec)
        if plan.codec != name:
            raise AssertionError(f"{task}: plan resolved {plan.codec!r} "
                                 f"for explicit codec {name!r}")
        c = get_codec(name)
        led = CommLedger()
        t0 = time.time()
        cs = pipe.build(spec, key=key, ledger=led,
                        transport=Transport(FaultPlan.none()))
        rel = rel_of(cs)
        wall = time.time() - t0
        table_bits = led.by_prefix(R1_TABLE_TAG, bits=True)
        if table_bits != T * c.wire_bits((ds.n,), "float32"):
            raise AssertionError(
                f"{task}/{name}: round-1 table receipts {table_bits} bits "
                f"!= {T} x wire_bits(({ds.n},)) = "
                f"{T * c.wire_bits((ds.n,), 'float32')}")
        if cs.comm_bits != led.total_bits:
            raise AssertionError(
                f"{task}/{name}: coreset comm_bits {cs.comm_bits} != "
                f"ledger {led.total_bits}")
        if c.lossless:
            if cs.comm_bits != plan.predicted_wire_bits:
                raise AssertionError(
                    f"{task}/{name}: lossless bill {cs.comm_bits} != "
                    f"predicted {plan.predicted_wire_bits}")
        elif cs.comm_bits > plan.predicted_wire_bits:
            raise AssertionError(
                f"{task}/{name}: bill {cs.comm_bits} exceeds the certified "
                f"bound {plan.predicted_wire_bits}")
        results[name] = {"table_bits": table_bits,
                         "total_bits": led.total_bits, "rel": rel}
        entries.append({
            "kind": "tradeoff", "task": task, "codec": name, "n": ds.n,
            "m": m, "table_bits": table_bits, "total_bits": led.total_bits,
            "total_fmt": fmt_bits(led.total_bits),
            "rel_error": round(rel, 6),
        })
        rows.append({
            "bench": BENCH, "method": f"tradeoff-{task}-{name}", "size": ds.n,
            "cost_mean": round(rel, 6), "cost_std": 0.0,
            "comm": led.total, "wall_s": round(wall, 3),
        })
    return results


def run_tradeoff(fast: bool):
    n, m, T = SWEEP_N, 512, 3
    entries, rows = [], []

    # vrlr: ridge rel_error via evaluate() against the full-data solve
    _, ds = _vrlr_stream(3, n, 30, T)
    lam = 0.1 * n
    baseline = fit_ridge(ds, full_data_coreset(ds), lam).params

    def rel_vrlr(cs):
        rep = evaluate(ds, fit_ridge(ds, cs, lam), baseline=baseline)
        return max(float(rep.rel_error), 0.0)

    res_r = _sweep_one("vrlr", ds, m, rel_vrlr, entries, rows)

    # vkmc: k-means rel_error via evaluate() against the full-data solve
    chunks = _chunk_stream(5, 4, n // 4, 16, T, False)
    ds2 = standardize(_stream_ds(chunks))
    key_k = jax.random.PRNGKey(200)
    baseline2 = fit_kmeans(ds2, full_data_coreset(ds2), 5,
                           key=key_k, backend="ref").params

    def rel_vkmc(cs):
        fit = fit_kmeans(ds2, cs, 5, key=jax.random.fold_in(key_k, 1),
                         backend="ref")
        rep = evaluate(ds2, fit, baseline=baseline2, backend="ref")
        return max(float(rep.rel_error), 0.0)

    res_k = _sweep_one("vkmc", ds2, m, rel_vkmc, entries, rows)

    for task, res in (("vrlr", res_r), ("vkmc", res_k)):
        ratio = res["raw_fp32"]["table_bits"] / res["int8_blockscale"]["table_bits"]
        if ratio < TABLE_RATIO_GATE:
            raise AssertionError(
                f"{task}: int8 round-1 tables only {ratio:.2f}x smaller "
                f"than raw (gate {TABLE_RATIO_GATE}x)")
        gate = max(REL_FACTOR * res["raw_fp32"]["rel"], REL_FLOOR)
        for name in ("fp16", "int8_blockscale"):
            if res[name]["rel"] > gate:
                raise AssertionError(
                    f"{task}/{name}: rel_error {res[name]['rel']:.4f} "
                    f"exceeds max({REL_FACTOR}x raw "
                    f"{res['raw_fp32']['rel']:.4f}, {REL_FLOOR}) at n={n}")
        entries.append({
            "kind": "tradeoff-gate", "task": task, "n": n, "m": m,
            "table_ratio_int8": round(ratio, 3),
            "rel_gate": round(gate, 6),
            "rel_raw": round(res["raw_fp32"]["rel"], 6),
            "rel_fp16": round(res["fp16"]["rel"], 6),
            "rel_int8": round(res["int8_blockscale"]["rel"], 6),
        })
    return entries, rows


def run(fast: bool = True):
    entries, rows = [], []
    for fn in (run_raw_identity, run_detect_int8, run_tradeoff):
        e, r = fn(fast)
        entries.extend(e)
        rows.extend(r)
    write_rows(BENCH, rows)
    write_bench_json(SECTION, entries)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true", default=True)
    ap.add_argument("--full", dest="fast", action="store_false")
    args = ap.parse_args()
    for r in run(fast=args.fast):
        print(r)
