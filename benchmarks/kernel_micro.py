"""Kernel microbenchmarks: us/call for each Pallas hot-spot vs its jnp
reference.

On the TPU target the Pallas rows time the compiled kernels; on any other
backend the kernels would only run under ``interpret=True`` — interpreter
overhead, not kernel performance — so those rows are SKIPPED by default
(pass ``--interpret`` to time them anyway; they are then explicitly
labeled ``pallas-interp`` and carry ``"interpret": true`` in
BENCH_kernels.json so the artifact never headlines interpreter wall time
as kernel speed).  The off-TPU interpret rule mirrors
``repro.kernels.ops._interpret`` — how the library itself executes the
kernels.  The jnp reference rows are XLA-compiled and meaningful on every
backend.
"""

from __future__ import annotations

import argparse
import functools
import sys

import jax
import jax.numpy as jnp

from repro.kernels import kmeans_assign as _ka
from repro.kernels import kmeans_assign_update as _kau
from repro.kernels import leverage as _lev
from repro.kernels import ref
from repro.kernels import weighted_gram as _wg
from benchmarks.common import time_us, write_bench_json, write_rows

BENCH = "kernel_micro"


def run(fast: bool = True, interpret: bool = False):
    n, d, k = (20000, 90, 10) if fast else (200000, 90, 10)
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (n, d))
    C = jax.random.normal(jax.random.fold_in(key, 1), (k, d))
    M = jnp.eye(d) * 0.5
    w = jax.random.uniform(jax.random.fold_in(key, 2), (n,))

    jit_ref_ka = jax.jit(ref.kmeans_assign)
    jit_ref_kau = jax.jit(ref.kmeans_assign_update)
    jit_ref_lev = jax.jit(ref.leverage)
    jit_ref_wg = jax.jit(ref.weighted_gram)

    # pallas rows are timed by default only where the kernels run COMPILED
    # (interpret=False — the same off-TPU interpret rule as
    # repro.kernels.ops._interpret); everywhere else they would be
    # interpreter overhead, so they need the explicit --interpret opt-in
    interp = jax.default_backend() != "tpu"
    include_pallas = (not interp) or interpret
    if not include_pallas:
        print(f"# {BENCH}: backend={jax.default_backend()} runs pallas in "
              "interpret mode (repro.kernels.ops._interpret); skipping "
              "those timings (pass --interpret to include them)",
              file=sys.stderr)
    pl_ka = functools.partial(_ka.kmeans_assign, interpret=interp)
    pl_kau = functools.partial(_kau.kmeans_assign_update, interpret=interp)
    pl_lev = functools.partial(_lev.leverage, interpret=interp)
    pl_wg = functools.partial(_wg.weighted_gram, interpret=interp)
    suffix = "pallas-interp" if interp else "pallas"
    cases = []
    if include_pallas:
        cases += [
            (f"kmeans_assign/{suffix}", pl_ka, (X, C)),
            (f"kmeans_assign_update/{suffix}", pl_kau, (X, C, w)),
            (f"leverage/{suffix}", pl_lev, (X, M)),
            (f"weighted_gram/{suffix}", pl_wg, (X, w)),
        ]
    cases += [
        ("kmeans_assign/jnp-ref", jit_ref_ka, (X, C)),
        ("kmeans_assign_update/jnp-ref", jit_ref_kau, (X, C, w)),
        ("leverage/jnp-ref", jit_ref_lev, (X, M)),
        ("weighted_gram/jnp-ref", jit_ref_wg, (X, w)),
    ]
    rows, json_entries = [], []
    for name, fn, args in cases:
        us = time_us(fn, *args)
        rows.append({"bench": BENCH, "method": name, "size": n,
                     "cost_mean": round(us, 1), "cost_std": 0.0,
                     "comm": 0, "wall_s": round(us / 1e6, 4)})
        entry = {"method": name, "n": n, "us_per_call": round(us, 1)}
        if "pallas" in name and interp:
            entry["interpret"] = True    # interpreter wall, NOT kernel perf
        json_entries.append(entry)
    write_rows(BENCH, rows)
    write_bench_json(BENCH, json_entries)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true", default=True)
    ap.add_argument("--full", dest="fast", action="store_false")
    ap.add_argument("--interpret", action="store_true",
                    help="time interpret-mode pallas rows even on CPU")
    args = ap.parse_args()
    for r in run(fast=args.fast, interpret=args.interpret):
        print(r)
