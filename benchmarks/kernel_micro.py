"""Kernel microbenchmarks: us/call for each Pallas hot-spot vs its jnp
reference (CPU interpret mode here — wall numbers are for relative tracking
only; the BlockSpec analysis in EXPERIMENTS.md covers the TPU target)."""

from __future__ import annotations

import argparse

import functools

import jax
import jax.numpy as jnp

from repro.kernels import kmeans_assign as _ka
from repro.kernels import kmeans_assign_update as _kau
from repro.kernels import leverage as _lev
from repro.kernels import ref
from repro.kernels import weighted_gram as _wg
from benchmarks.common import time_us, write_bench_json, write_rows

BENCH = "kernel_micro"


def run(fast: bool = True):
    n, d, k = (20000, 90, 10) if fast else (200000, 90, 10)
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (n, d))
    C = jax.random.normal(jax.random.fold_in(key, 1), (k, d))
    M = jnp.eye(d) * 0.5
    w = jax.random.uniform(jax.random.fold_in(key, 2), (n,))

    jit_ref_ka = jax.jit(ref.kmeans_assign)
    jit_ref_kau = jax.jit(ref.kmeans_assign_update)
    jit_ref_lev = jax.jit(ref.leverage)
    jit_ref_wg = jax.jit(ref.weighted_gram)

    interp = jax.default_backend() != "tpu"
    pl_ka = functools.partial(_ka.kmeans_assign, interpret=interp)
    pl_kau = functools.partial(_kau.kmeans_assign_update, interpret=interp)
    pl_lev = functools.partial(_lev.leverage, interpret=interp)
    pl_wg = functools.partial(_wg.weighted_gram, interpret=interp)
    suffix = "pallas-interp" if interp else "pallas"
    rows, json_entries = [], []
    for name, fn, args in [
        (f"kmeans_assign/{suffix}", pl_ka, (X, C)),
        ("kmeans_assign/jnp-ref", jit_ref_ka, (X, C)),
        (f"kmeans_assign_update/{suffix}", pl_kau, (X, C, w)),
        ("kmeans_assign_update/jnp-ref", jit_ref_kau, (X, C, w)),
        (f"leverage/{suffix}", pl_lev, (X, M)),
        ("leverage/jnp-ref", jit_ref_lev, (X, M)),
        (f"weighted_gram/{suffix}", pl_wg, (X, w)),
        ("weighted_gram/jnp-ref", jit_ref_wg, (X, w)),
    ]:
        us = time_us(fn, *args)
        rows.append({"bench": BENCH, "method": name, "size": n,
                     "cost_mean": round(us, 1), "cost_std": 0.0,
                     "comm": 0, "wall_s": round(us / 1e6, 4)})
        json_entries.append({"method": name, "n": n,
                             "us_per_call": round(us, 1)})
    write_rows(BENCH, rows)
    write_bench_json(BENCH, json_entries)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true", default=True)
    ap.add_argument("--full", dest="fast", action="store_false")
    args = ap.parse_args()
    for r in run(fast=args.fast):
        print(r)
