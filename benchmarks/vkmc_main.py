"""Paper Table 1 (right) / Figure 3: VKMC with k=10 on the standardized
YearPrediction-profile dataset, T=3 parties.

Grid: KMEANS++, DISTDIM (full data) vs C-/U-{KMEANS++, DISTDIM} over coreset
sizes 1000..6000, reporting training cost + communication complexity.
"""

from __future__ import annotations

from benchmarks.common import SIZES, make_vkmc_data, run_vkmc_method, sweep, write_rows

BENCH = "vkmc_main"


def run(fast: bool = True, k: int = 10, T: int = 3, dataset: str = "yearpred",
        bench: str = BENCH):
    repeats = 3 if fast else 20
    ds = make_vkmc_data(fast, T=T, dataset=dataset)
    rows = []
    for method in ("kmeanspp", "distdim"):
        base = run_vkmc_method(method, None, 0, ds, k, seed=0)
        rows.append({"bench": bench, "method": method.upper(), "size": ds.n,
                     "cost_mean": base["cost"], "cost_std": 0.0,
                     "comm": base["comm"], "wall_s": base["wall_s"]})
        for sampling, tag in (("coreset", "C"), ("uniform", "U")):
            sw = sweep(lambda m, r: run_vkmc_method(
                method, sampling, m, ds, k, seed=2000 * r + m),
                SIZES, repeats)
            for row in sw:
                rows.append({"bench": bench, "method": f"{tag}-{method.upper()}",
                             **row})
    write_rows(bench, rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
