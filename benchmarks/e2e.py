"""End-to-end spec-build + downstream-solve benchmark: the full paper loop.

One declarative ``CoresetSpec`` per task, built by ``CoresetPipeline`` and
closed by the ``fit_ridge``/``fit_kmeans`` + ``evaluate`` layer
(:mod:`repro.core.solve`): build wall time, fit wall time, and the paper's
FULL-DATA relative error per task, recorded under the ``e2e_solve`` section
of BENCH_kernels.json — {task, n, m, engine, build_s, fit_s, rel_error,
comm_units}.

The relative error doubles as a correctness gate: an m = 1024 leverage /
sensitivity coreset must land within REL_ERROR_BOUND of the full-data
solve, so a broken score path (or a broken solver) fails the benchmark
instead of silently recording garbage.  CI runs ``--fast`` as its
end-to-end solve smoke.

  PYTHONPATH=src python -m benchmarks.e2e --fast
  PYTHONPATH=src python -m benchmarks.run --sections e2e
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import write_bench_json, write_rows
from repro.core import CommLedger, CoresetPipeline, CoresetSpec, VFLDataset
from repro.core.solve import evaluate, fit_kmeans, fit_ridge

BENCH = "e2e"
SECTION = "e2e_solve"

# generous gates — the measured values sit far below (rel_error ~1e-2 for
# ridge at m=1024); tripping one means the score path or solver broke
REL_ERROR_BOUND = {"vrlr": 0.5, "vkmc": 0.5}


def _dataset(n: int, d: int = 30, T: int = 3, k_clusters: int = 8):
    rng = np.random.default_rng(3)
    centers = 2.0 * rng.standard_normal((k_clusters, d)).astype(np.float32)
    X = (centers[rng.integers(0, k_clusters, n)]
         + rng.standard_normal((n, d)).astype(np.float32))
    theta = rng.standard_normal(d).astype(np.float32)
    y = X @ theta + 0.1 * rng.standard_normal(n).astype(np.float32)
    return VFLDataset.from_dense(X, y, T=T)


def run(fast: bool = True):
    n = 20_000 if fast else 100_000
    m, k = 1024, 8
    ds = _dataset(n)
    lam = 0.1 * n
    pipeline = CoresetPipeline(ds)
    key = jax.random.PRNGKey(0)

    rows, entries = [], []
    for task in ("vrlr", "vkmc"):
        spec = CoresetSpec(task=task, budgets=m,
                           params={"k": k} if task == "vkmc" else {})
        plan = pipeline.plan(spec)
        led = CommLedger()
        t0 = time.time()
        cs = pipeline.build(plan, key=jax.random.fold_in(key, 1), ledger=led)
        jax.block_until_ready(cs.weights)
        build_s = time.time() - t0

        t0 = time.time()
        if task == "vrlr":
            fit = fit_ridge(ds, cs, lam)
            rep = evaluate(ds, fit)
        else:
            # Both Lloyd solves are heuristic, so the raw ratio can swing
            # NEGATIVE when the full-data solve lands in a worse basin than
            # the coreset solve (basin roulette — the test_vkmc fix of PR 2
            # documents it).  Benchmark against the BEST KNOWN centers
            # instead: rel_error >= 0 always, a broken score path still
            # blows past the gate, and the recorded number means "distance
            # from the best solution either solve found".
            fit = fit_kmeans(ds, cs, k, key=jax.random.fold_in(key, 2),
                             restarts=5)
            from repro.core.solve import full_data_coreset
            full_fit = fit_kmeans(ds, full_data_coreset(ds), k,
                                  key=jax.random.fold_in(key, 2), restarts=5)
            rep_full = evaluate(ds, fit, baseline=full_fit.params)
            best = (full_fit.params if rep_full.rel_error >= 0
                    else fit.params)
            rep = evaluate(ds, fit, baseline=best)
        jax.block_until_ready(fit.params)
        fit_s = time.time() - t0

        bound = REL_ERROR_BOUND[task]
        if not rep.rel_error < bound:
            raise AssertionError(
                f"{task}: end-to-end relative error {rep.rel_error:.4f} "
                f"exceeds the {bound} gate (m={m}, n={n})"
            )
        entries.append({
            "task": task, "n": n, "m": m, "engine": plan.engine,
            "build_s": round(build_s, 4), "fit_s": round(fit_s, 4),
            "rel_error": round(rep.rel_error, 6),
            "comm_units": int(cs.comm_units),
        })
        rows.append({"bench": BENCH, "method": f"{task}-{plan.engine}",
                     "size": n, "cost_mean": round(rep.rel_error, 6),
                     "cost_std": 0.0, "comm": int(led.total),
                     "wall_s": round(build_s + fit_s, 4)})

    write_rows(BENCH, rows)
    write_bench_json(SECTION, entries)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true", default=True)
    ap.add_argument("--full", dest="fast", action="store_false")
    args = ap.parse_args()
    for r in run(fast=args.fast):
        print(r)
