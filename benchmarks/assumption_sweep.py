"""Beyond the paper's experiments: data-assumption sweep (Assumptions 4.1 /
5.1 and the robust-coreset regime of Remarks 4.3/5.3).

Sweeps the cross-party correlation rho of the generator:
  * rho -> 0: independent blocks — gamma (Assumption 4.1) large, VRLR
    coresets strong; but tau (Assumption 5.1) unbounded, VKMC falls back to
    the robust guarantee;
  * rho -> 1: shared geometry — tau -> 1 (VKMC strong), gamma -> 0 (VRLR
    falls back to robust).

Reported: empirical coreset epsilon (max relative cost error over probe
parameters) for coreset vs uniform at fixed m — showing the graceful
degradation the robust theorems predict rather than a cliff.

Construction uses ``build_coresets_batched``: all `repeats` seeds of a
(task, rho) cell are built in ONE jit-compiled vmap over the pure DIS core
(the seed version re-traced a Python protocol loop per repeat).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import write_rows
from repro.core import (
    VFLDataset,
    build_coresets_batched,
    vkmc_coreset_ratio,
    vrlr_coreset_ratio,
)
from repro.data.synthetic import correlated_vfl_data

BENCH = "assumption_sweep"
RHOS = [0.0, 0.3, 0.6, 0.9, 0.99]


def run(fast: bool = True):
    n, d, T, k, m = (6000, 18, 3, 5, 600) if fast else (40000, 30, 3, 10, 2000)
    repeats = 3 if fast else 10
    rows = []
    for rho in RHOS:
        key = jax.random.PRNGKey(int(rho * 100))
        X = correlated_vfl_data(key, n, d, T, cross_correlation=rho, k_clusters=k)
        theta = jax.random.normal(jax.random.fold_in(key, 1), (d,))
        y = X @ theta + 0.5 * jax.random.normal(jax.random.fold_in(key, 2), (n,))
        ds = VFLDataset.from_dense(X, y, T=T)
        lam = 0.1 * n
        thetas = jax.random.normal(jax.random.fold_in(key, 3), (16, d))
        centers = 2.0 * jax.random.normal(jax.random.fold_in(key, 4), (8, k, d))

        # the seed grid: per repeat r, key kk for the VRLR build and
        # fold_in(kk, 1) for the VKMC build (uniform reuses the same keys)
        keys_r = jnp.stack([jax.random.fold_in(key, 10 + r) for r in range(repeats)])
        keys_c = jnp.stack([jax.random.fold_in(kk, 1) for kk in keys_r])

        for kind in ("coreset", "uniform"):
            if kind == "coreset":
                bc_r = build_coresets_batched("vrlr", ds, [m], keys=keys_r)
                bc_c = build_coresets_batched("vkmc", ds, [m], keys=keys_c, k=k)
            else:
                bc_r = build_coresets_batched("uniform", ds, [m], keys=keys_r)
                bc_c = build_coresets_batched("uniform", ds, [m], keys=keys_c)
            eps_r = [float(vrlr_coreset_ratio(ds, bc_r.coreset(r), thetas, lam))
                     for r in range(repeats)]
            eps_c = [float(vkmc_coreset_ratio(ds, bc_c.coreset(r), centers))
                     for r in range(repeats)]
            rows.append({"bench": BENCH, "method": f"{kind}-vrlr-eps",
                         "size": int(rho * 100), "cost_mean": float(np.mean(eps_r)),
                         "cost_std": float(np.std(eps_r)), "comm": m,
                         "wall_s": 0.0})
            rows.append({"bench": BENCH, "method": f"{kind}-vkmc-eps",
                         "size": int(rho * 100), "cost_mean": float(np.mean(eps_c)),
                         "cost_std": float(np.std(eps_c)), "comm": m,
                         "wall_s": 0.0})
    write_rows(BENCH, rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
