"""Beyond the paper's experiments: data-assumption sweep (Assumptions 4.1 /
5.1 and the robust-coreset regime of Remarks 4.3/5.3).

Sweeps the cross-party correlation rho of the generator:
  * rho -> 0: independent blocks — gamma (Assumption 4.1) large, VRLR
    coresets strong; but tau (Assumption 5.1) unbounded, VKMC falls back to
    the robust guarantee;
  * rho -> 1: shared geometry — tau -> 1 (VKMC strong), gamma -> 0 (VRLR
    falls back to robust).

Reported: empirical coreset epsilon (max relative cost error over probe
parameters) for coreset vs uniform at fixed m — showing the graceful
degradation the robust theorems predict rather than a cliff.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import write_rows
from repro.core import (
    VFLDataset,
    build_uniform_coreset,
    build_vkmc_coreset,
    build_vrlr_coreset,
    vkmc_coreset_ratio,
    vrlr_coreset_ratio,
)
from repro.data.synthetic import correlated_vfl_data

BENCH = "assumption_sweep"
RHOS = [0.0, 0.3, 0.6, 0.9, 0.99]


def run(fast: bool = True):
    n, d, T, k, m = (6000, 18, 3, 5, 600) if fast else (40000, 30, 3, 10, 2000)
    repeats = 3 if fast else 10
    rows = []
    for rho in RHOS:
        key = jax.random.PRNGKey(int(rho * 100))
        X = correlated_vfl_data(key, n, d, T, cross_correlation=rho, k_clusters=k)
        theta = jax.random.normal(jax.random.fold_in(key, 1), (d,))
        y = X @ theta + 0.5 * jax.random.normal(jax.random.fold_in(key, 2), (n,))
        ds = VFLDataset.from_dense(X, y, T=T)
        lam = 0.1 * n
        thetas = jax.random.normal(jax.random.fold_in(key, 3), (16, d))
        centers = 2.0 * jax.random.normal(jax.random.fold_in(key, 4), (8, k, d))

        for kind, builder in (("coreset", None), ("uniform", None)):
            eps_r, eps_c = [], []
            for r in range(repeats):
                kk = jax.random.fold_in(key, 10 + r)
                if kind == "coreset":
                    cs_r = build_vrlr_coreset(kk, ds, m)
                    cs_c = build_vkmc_coreset(jax.random.fold_in(kk, 1), ds, k=k, m=m)
                else:
                    cs_r = build_uniform_coreset(kk, ds, m)
                    cs_c = build_uniform_coreset(jax.random.fold_in(kk, 1), ds, m)
                eps_r.append(float(vrlr_coreset_ratio(ds, cs_r, thetas, lam)))
                eps_c.append(float(vkmc_coreset_ratio(ds, cs_c, centers)))
            rows.append({"bench": BENCH, "method": f"{kind}-vrlr-eps",
                         "size": int(rho * 100), "cost_mean": float(np.mean(eps_r)),
                         "cost_std": float(np.std(eps_r)), "comm": m,
                         "wall_s": 0.0})
            rows.append({"bench": BENCH, "method": f"{kind}-vkmc-eps",
                         "size": int(rho * 100), "cost_mean": float(np.mean(eps_c)),
                         "cost_std": float(np.std(eps_c)), "comm": m,
                         "wall_s": 0.0})
    write_rows(BENCH, rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
