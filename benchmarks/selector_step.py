"""Beyond-paper benchmark: coreset batch selection inside LLM training.

Measures, on a reduced llama config (CPU): step wall time and end-loss for
dense vs uniform vs coreset selection at fraction 0.25 — the paper's
Theorem 2.5 composition with the train step as the downstream scheme.  The
production-mesh collective savings are quantified separately in
EXPERIMENTS.md §Perf from the dry-run HLO.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import write_rows
from repro.configs import get_arch
from repro.core.selector import SelectorConfig
from repro.data.lm import TokenStream
from repro.optim.schedules import cosine_with_warmup
from repro.train import make_train_step, train_state_init

BENCH = "selector_step"


def run(fast: bool = True):
    steps = 30 if fast else 200
    cfg = get_arch("llama3.2-1b").reduced()
    key = jax.random.PRNGKey(0)
    rows = []
    for mode, frac in (("none", 1.0), ("uniform", 0.25), ("coreset", 0.25)):
        state = train_state_init(key, cfg)
        step = jax.jit(make_train_step(
            cfg, cosine_with_warmup(2e-3, 10, steps),
            SelectorConfig(mode=mode, fraction=frac)))
        stream = iter(TokenStream(vocab=cfg.vocab_size, seq_len=32,
                                  batch_size=16, seed=0))
        # warmup/compile
        state, _ = step(state, next(stream), key)
        t0 = time.time()
        losses = []
        for i in range(steps):
            state, m = step(state, next(stream), jax.random.fold_in(key, i))
            losses.append(float(m["ce"]))
        wall = (time.time() - t0) / steps
        rows.append({"bench": BENCH, "method": f"{mode}@{frac}", "size": steps,
                     "cost_mean": float(np.mean(losses[-5:])),
                     "cost_std": float(np.std(losses[-5:])),
                     "comm": 0, "wall_s": round(wall, 4)})
    write_rows(BENCH, rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
