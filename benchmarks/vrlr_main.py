"""Paper Table 1 (left) / Figure 2: VRLR (ridge, lambda=0.1n) on the
YearPrediction-profile dataset, T=3 parties.

Grid: CENTRAL, SAGA (full data) vs C-/U-{CENTRAL, SAGA} over coreset sizes
1000..6000, reporting testing loss + communication complexity.
"""

from __future__ import annotations

from benchmarks.common import (
    SIZES,
    make_vrlr_data,
    run_vrlr_method,
    sweep,
    write_rows,
)

BENCH = "vrlr_main"


def run(fast: bool = True):
    repeats = 3 if fast else 20
    train, test = make_vrlr_data(fast)
    rows = []

    for method in ("central", "saga"):
        # full-data baseline (1 repeat — deterministic / expensive)
        base = run_vrlr_method(method, None, 0, train, test, seed=0,
                               saga_steps=20000 if fast else 100000)
        rows.append({"bench": BENCH, "method": method.upper(), "size": train.n,
                     "cost_mean": base["cost"], "cost_std": 0.0,
                     "comm": base["comm"], "wall_s": base["wall_s"]})
        for sampling, tag in (("coreset", "C"), ("uniform", "U")):
            sw = sweep(lambda m, r: run_vrlr_method(
                method, sampling, m, train, test, seed=1000 * r + m),
                SIZES, repeats)
            for row in sw:
                rows.append({"bench": BENCH, "method": f"{tag}-{method.upper()}",
                             **row})
    write_rows(BENCH, rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
