"""Paper Appendix A.2 (Figures 6-8): plain linear regression, Lasso
(R = 2n|th|_1) and elastic net (R = 2n|th|_1 + n|th|_2^2) — C-/U-CENTRAL
vs CENTRAL (no SAGA for the prox problems, as in the paper)."""

from __future__ import annotations

from benchmarks.common import SIZES, make_vrlr_data, run_vrlr_method, sweep, write_rows

BENCH = "regularizers"


def run(fast: bool = True):
    repeats = 3 if fast else 20
    train, test = make_vrlr_data(fast)
    rows = []
    for reg in ("linear", "lasso", "elastic"):
        base = run_vrlr_method("central", None, 0, train, test, seed=0, reg_kind=reg)
        rows.append({"bench": BENCH, "method": f"CENTRAL[{reg}]", "size": train.n,
                     "cost_mean": base["cost"], "cost_std": 0.0,
                     "comm": base["comm"], "wall_s": base["wall_s"]})
        for sampling, tag in (("coreset", "C"), ("uniform", "U")):
            for row in sweep(lambda m, r: run_vrlr_method(
                    "central", sampling, m, train, test,
                    seed=13 * r + m, reg_kind=reg), SIZES[:4], repeats):
                rows.append({"bench": BENCH, "method": f"{tag}-CENTRAL[{reg}]", **row})
    write_rows(BENCH, rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
