"""Fused vs seed Lloyd-iteration microbenchmark: passes-over-X and us/step.

One seed-path Lloyd iteration is 3 separate data passes — 2 of them
X-sized (assign kernel + the coordinate-sum segment_sum) plus the n-sized
weight-sum scatter; the fused ``kmeans_assign_update`` kernel is 1 X-sized
pass total.  This module measures both data flows in both execution modes:

  * ``pallas-interp`` (``pallas`` on TPU) — the kernel paths;
  * ``jnp-ref``       — XLA-compiled jnp: seed = assign + segment_sums,
    fused = assign + one-hot matmul fold (the scatter-free data flow the
    kernel implements, expressed as a matmul XLA can fuse).

Pass counts are derived STRUCTURALLY from the lowered jaxpr (number of
pallas_call + scatter ops touching X-sized operands), not asserted by
hand, and land in BENCH_kernels.json for the perf trajectory.

Off the TPU target the kernel paths only run under ``interpret=True``, so
their WALL TIME is interpreter overhead, not kernel performance — those
timings are skipped by default (the structural pass census, which needs
only the jaxpr, is still recorded as ``*/pallas-structural`` rows); pass
``--interpret`` to time them anyway, explicitly labeled with
``"interpret": true``.  The off-TPU interpret rule mirrors
``repro.kernels.ops._interpret`` — how the library itself executes the
kernels.
"""

from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp

from benchmarks.common import time_us, write_bench_json, write_rows
from repro.kernels import kmeans_assign as _ka
from repro.kernels import kmeans_assign_update as _kau
from repro.kernels import ref

BENCH = "fused_lloyd"


def _subjaxprs(v):
    import jax.core as jax_core
    if isinstance(v, jax_core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jax_core.Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _subjaxprs(x)


def count_primitives(jaxpr, names, pred=None) -> int:
    """Recursive primitive census over a jaxpr (descends into pjit/scan/
    pallas_call sub-jaxprs).  ``names``: exact primitive names to count;
    ``pred``: optional extra filter on the matching eqn."""
    cnt = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in names and (pred is None or pred(eqn)):
            cnt += 1
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                cnt += count_primitives(sub, names, pred)
    return cnt


def _is_matrix_scatter(eqn):
    # scatter-add invars are (operand, indices, updates): X-sized iff the
    # scattered UPDATES are (n, d)-shaped (csum's segment_sum); the wsum
    # segment_sum only scatters the (n,) weight vector
    return getattr(eqn.invars[-1].aval, "ndim", 0) >= 2


def structural_passes(fn, *args):
    """(pallas_call count, scatter-add count, X-sized passes) for ``fn`` —
    the structural census of the single-pass acceptance check.

    X-sized passes = pallas_call count (each kernel reads its X block
    stream once) + scatter-adds whose scattered operand is (n, d)-sized
    (csum's segment_sum; the wsum segment_sum only streams the (n,)
    weights and is NOT an X-sized pass).  Zero-padding ``scatter`` copies
    are layout moves shared by both paths and also not counted.  Seed
    Lloyd step: 1 pallas_call + 2 scatter-adds, of which 1 is X-sized ->
    2 X-sized passes (+1 n-sized); fused: 1 pallas_call, 0 scatter-adds
    -> 1 pass.
    """
    jx = jax.make_jaxpr(fn)(*args).jaxpr
    n_pallas = count_primitives(jx, {"pallas_call"})
    n_scatter = count_primitives(jx, {"scatter-add"})
    n_xsized = count_primitives(jx, {"scatter-add"}, _is_matrix_scatter)
    return n_pallas, n_scatter, n_pallas + n_xsized


def _new_centers(csum, wsum, C):
    return jnp.where(wsum[:, None] > 0,
                     csum / jnp.maximum(wsum, 1e-30)[:, None], C)


def make_steps(interp: bool):
    """One Lloyd iteration, four ways: (name, fn) pairs."""
    suffix = "pallas-interp" if interp else "pallas"

    def seed_pallas(X, C, w):
        assign, _ = _ka.kmeans_assign(X, C, interpret=interp)       # pass 1
        k = C.shape[0]
        wsum = jax.ops.segment_sum(w, assign, num_segments=k)       # pass 2
        csum = jax.ops.segment_sum(w[:, None] * X, assign, num_segments=k)  # 3
        return _new_centers(csum, wsum, C)

    def fused_pallas(X, C, w):
        _, _, csum, wsum, _ = _kau.kmeans_assign_update(X, C, w, interpret=interp)
        return _new_centers(csum, wsum, C)

    def seed_jnp(X, C, w):
        assign, _ = ref.kmeans_assign(X, C)
        k = C.shape[0]
        wsum = jax.ops.segment_sum(w, assign, num_segments=k)
        csum = jax.ops.segment_sum(w[:, None] * X, assign, num_segments=k)
        return _new_centers(csum, wsum, C)

    def fused_jnp(X, C, w):
        # the kernel's data flow in pure jnp: scatter-free one-hot fold
        assign, _ = ref.kmeans_assign(X, C)
        k = C.shape[0]
        onehot = (assign[:, None] == jnp.arange(k)[None, :]).astype(jnp.float32)
        wh = onehot * w[:, None]                                    # (n, k)
        wsum = jnp.sum(wh, axis=0)
        csum = wh.T @ X.astype(jnp.float32)
        return _new_centers(csum, wsum, C)

    return [
        (f"seed-3pass/{suffix}", seed_pallas),
        (f"fused-1pass/{suffix}", fused_pallas),
        ("seed-3pass/jnp-ref", jax.jit(seed_jnp)),
        ("fused-1pass/jnp-ref", jax.jit(fused_jnp)),
    ]


def run(fast: bool = True, interpret: bool = False):
    n, d, k = (20000, 90, 10) if fast else (200000, 90, 10)
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (n, d))
    C = jax.random.normal(jax.random.fold_in(key, 1), (k, d))
    w = jax.random.uniform(jax.random.fold_in(key, 2), (n,))

    # pallas rows are timed by default only where the kernels run COMPILED
    # (interpret=False — the same off-TPU interpret rule as
    # repro.kernels.ops._interpret); same gate as kernel_micro
    interp = jax.default_backend() != "tpu"
    time_pallas = (not interp) or interpret
    if not time_pallas:
        print(f"# {BENCH}: backend={jax.default_backend()} runs pallas in "
              "interpret mode (repro.kernels.ops._interpret); pallas rows "
              "keep the structural census only (pass --interpret to time "
              "them)", file=sys.stderr)
    rows, json_entries = [], []
    for name, fn in make_steps(interp):
        is_pallas_path = "pallas" in name
        n_pallas, n_scatter, n_passes = structural_passes(fn, X, C, w)
        entry = {
            "method": name, "n": n, "d": d, "k": k,
            "pallas_calls": n_pallas,
            "segment_sum_scatters": n_scatter,
        }
        if n_pallas:       # the census is about the kernel data flow; the
            entry["x_sized_passes"] = n_passes  # jnp rows are wall-time refs
        if is_pallas_path and not time_pallas:
            # structural-only row: the pass census comes from the jaxpr and
            # costs nothing; interpreter wall time would mislead
            entry["method"] = name.split("/")[0] + "/pallas-structural"
            json_entries.append(entry)
            continue
        us = time_us(fn, X, C, w)
        rows.append({"bench": BENCH, "method": name, "size": n,
                     "cost_mean": round(us, 1), "cost_std": 0.0,
                     "comm": 0, "wall_s": round(us / 1e6, 4)})
        entry["us_per_step"] = round(us, 1)
        if is_pallas_path and interp:
            entry["interpret"] = True    # interpreter wall, NOT kernel perf
        json_entries.append(entry)
    write_rows(BENCH, rows)
    write_bench_json(BENCH, json_entries)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true", default=True)
    ap.add_argument("--full", dest="fast", action="store_false")
    ap.add_argument("--interpret", action="store_true",
                    help="time interpret-mode pallas rows even on CPU")
    args = ap.parse_args()
    for r in run(fast=args.fast, interpret=args.interpret):
        print(r)
