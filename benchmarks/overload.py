"""Overload benchmark: the service under a hostile tenant mix.

Three experiments under the ``overload`` section of BENCH_kernels.json,
each with an ASSERTED gate (run with ``--strict`` in CI — a regression
fails the build instead of recording a bad number):

* ``hostile_mix`` — normal, greedy (rate-limited), slow (deadline-pressed)
  and faulty (all parties dropping) tenants share one service.  Gates:
  **shed-not-stall** — every issued request yields a success receipt, a
  shed receipt, or a billed party-failure (zero requests lost without an
  artifact); normal tenants are never shed and their p99 insert latency
  stays bounded; the greedy tenant IS shed (rate_limit) and the slow
  tenant's deadline aborts are rolled back (tree state unaffected).
* ``breaker_isolation`` — the faulty tenant trips its circuit breaker
  (consecutive retry exhaustions), post-trip requests shed fast with
  ``breaker_open`` receipts, and — the isolation pin — a normal tenant
  sharing the service produces a final query BIT-IDENTICAL to the same
  tenant running alone on a fresh service.
* ``failover_identity`` — the acceptance pin: a tenant whose pipelined
  leaf builds are forced over ``memory_budget_bytes=1`` falls back to the
  streamed engine, yielding indices/weights bit-identical to an unforced
  twin tenant and a ledger equal to the twin's bill plus zero-unit
  ``fallback/`` attributions.

All admission state machines run on a shared
:class:`~repro.core.faults.SimClock` (tick-per-read), so the shed pattern
is deterministic; latencies are wall-clock.

  PYTHONPATH=src python -m benchmarks.overload --fast
  PYTHONPATH=src python -m benchmarks.run --sections overload
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import write_bench_json, write_rows
from repro.core.faults import Deadline, FaultPlan, PartyUnavailable, SimClock, Transport
from repro.serve import CoresetService, InsertReceipt, QueryReceipt, ShedReceipt

BENCH = "overload"
SECTION = "overload"

P99_GATE_S = 10.0       # absolute bound on normal-tenant insert p99 (CI-safe)


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q))


def _chunk_stream(seed, num, rows, d, T):
    rng = np.random.default_rng(seed)
    theta = rng.standard_normal(d).astype(np.float32)
    base, rem = divmod(d, T)
    widths = [base + (1 if j < rem else 0) for j in range(T)]
    chunks = []
    for _ in range(num):
        X = rng.standard_normal((rows, d)).astype(np.float32)
        y = X @ theta + 0.1 * rng.standard_normal(rows).astype(np.float32)
        parts, start = [], 0
        for w in widths:
            parts.append(X[:, start:start + w])
            start += w
        chunks.append((parts, y))
    return chunks


# --------------------------------------------------------------------------
# Experiment 1: hostile mix — shed, don't stall
# --------------------------------------------------------------------------

def run_hostile_mix(fast: bool):
    num_chunks = 4 if fast else 8
    rows = 1024 if fast else 8192
    m, d, T = 64, 6, 3
    greedy_burst = 6 if fast else 12     # requests the greedy tenant fires per round

    clock = SimClock(tick=0.01)
    svc = CoresetService(clock=clock)
    tr_faulty = Transport(FaultPlan(seed=11, drop=1.0, max_retries=1),
                          clock=clock)

    svc.register("normal0", task="vrlr", budget=m, seed=0, block_size=256)
    svc.register("normal1", task="vrlr", budget=m, seed=1, block_size=256)
    svc.register("greedy", task="vrlr", budget=m, seed=2, block_size=256,
                 rate_limit=(0.5, 2))
    svc.register("slow", task="vrlr", budget=m, seed=3, block_size=256)
    svc.register("faulty", task="vrlr", budget=m, seed=4, block_size=256,
                 fault_policy="retry", transport=tr_faulty,
                 breaker_threshold=2, breaker_cooldown_s=60.0)

    streams = {name: _chunk_stream(100 + i, max(num_chunks, greedy_burst),
                                   rows, d, T)
               for i, name in enumerate(svc.tenants())}

    issued = succeeded = shed = party_failures = 0
    lat = {name: [] for name in svc.tenants()}
    sheds_by = {name: 0 for name in svc.tenants()}
    t_start = time.time()
    for r in range(num_chunks):
        for name in ("normal0", "normal1"):
            issued += 1
            rec = svc.insert(name, *streams[name][r])
            assert isinstance(rec, InsertReceipt), rec
            succeeded += 1
            lat[name].append(rec.latency_s)
        # greedy: a burst per round against a 0.5 req/s budget
        for b in range(greedy_burst):
            issued += 1
            rec = svc.insert("greedy", *streams["greedy"][b])
            if isinstance(rec, ShedReceipt):
                shed += 1
                sheds_by["greedy"] += 1
                assert rec.reason == "rate_limit", rec
            else:
                succeeded += 1
        # slow: a deadline too tight for even one superchunk boundary
        issued += 1
        before = svc.state("slow").tree.num_chunks
        rec = svc.insert("slow", *streams["slow"][r],
                         deadline=Deadline.after(clock, 0.005))
        if isinstance(rec, ShedReceipt):
            shed += 1
            sheds_by["slow"] += 1
            assert rec.reason == "deadline", rec
            assert svc.state("slow").tree.num_chunks == before, \
                "deadline shed must roll the tree back"
        else:
            succeeded += 1
        # faulty: every party drops; pre-trip this raises (billed failure),
        # post-trip it sheds instantly
        issued += 1
        try:
            rec = svc.insert("faulty", *streams["faulty"][r])
            if isinstance(rec, ShedReceipt):
                shed += 1
                sheds_by["faulty"] += 1
                assert rec.reason == "breaker_open", rec
            else:
                succeeded += 1
        except PartyUnavailable:
            party_failures += 1
    wall = time.time() - t_start

    stats = svc.stats()
    lost = issued - (succeeded + shed + party_failures)
    if lost != 0:
        raise AssertionError(
            f"shed-not-stall violated: {lost} of {issued} requests vanished "
            f"without a receipt or billed failure")
    normal_sheds = sheds_by["normal0"] + sheds_by["normal1"]
    if normal_sheds != 0:
        raise AssertionError(
            f"normal tenants were shed {normal_sheds} time(s) — hostile "
            f"tenants must not starve the rest")
    if sheds_by["greedy"] == 0:
        raise AssertionError("the greedy tenant was never rate-limited")
    if sheds_by["slow"] == 0:
        raise AssertionError("the slow tenant's deadline never fired")
    p99_normal = _pct(lat["normal0"] + lat["normal1"], 99)
    if not p99_normal < P99_GATE_S:
        raise AssertionError(
            f"normal-tenant insert p99 {p99_normal:.2f}s breaches the "
            f"{P99_GATE_S}s bound under the hostile mix")

    entry = {
        "kind": "hostile_mix", "tenants": len(svc.tenants()),
        "chunks": num_chunks, "chunk_rows": rows, "m": m,
        "issued": issued, "succeeded": succeeded, "shed": shed,
        "party_failures": party_failures,
        "sheds_by": sheds_by,
        "normal_p50_ms": round(_pct(lat["normal0"] + lat["normal1"], 50)
                               * 1e3, 3),
        "normal_p99_ms": round(p99_normal * 1e3, 3),
        "requests_per_s": round(issued / wall, 2),
        "breaker_faulty": stats["breakers"]["faulty"]["state"],
    }
    row = {"bench": BENCH, "method": "hostile-mix", "size": issued,
           "cost_mean": round(p99_normal * 1e3, 3),
           "cost_std": float(shed), "comm": sum(
               svc.state(t).ledger.total for t in svc.tenants()),
           "wall_s": round(wall, 2)}
    return entry, row


# --------------------------------------------------------------------------
# Experiment 2: breaker isolation — faulty tenant cannot perturb a neighbor
# --------------------------------------------------------------------------

def _run_normal(svc, stream, m, rounds):
    for r in range(rounds):
        rec = svc.insert("victim", *stream[r])
        assert isinstance(rec, InsertReceipt), rec
    q = svc.query("victim", reduce_to=m)
    assert isinstance(q, QueryReceipt)
    return q


def run_breaker_isolation(fast: bool):
    rounds = 3 if fast else 6
    rows = 1024 if fast else 8192
    m, d, T = 64, 6, 3
    stream = _chunk_stream(7, rounds, rows, d, T)
    faulty_stream = _chunk_stream(8, rounds, rows, d, T)

    # solo: the victim alone on a fresh service
    solo = CoresetService(clock=SimClock(tick=0.01))
    solo.register("victim", task="vrlr", budget=m, seed=0, block_size=256)
    t0 = time.time()
    q_solo = _run_normal(solo, stream, m, rounds)

    # shared: same victim + a breaker-tripping faulty tenant interleaved
    clock = SimClock(tick=0.01)
    shared = CoresetService(clock=clock)
    shared.register("victim", task="vrlr", budget=m, seed=0, block_size=256)
    tr = Transport(FaultPlan(seed=13, drop=1.0, max_retries=1), clock=clock)
    shared.register("chaos", task="vrlr", budget=m, seed=9, block_size=256,
                    fault_policy="retry", transport=tr,
                    breaker_threshold=2, breaker_cooldown_s=1e6)
    breaker_sheds = 0
    for r in range(rounds):
        try:
            rec = shared.insert("chaos", *faulty_stream[r])
            if isinstance(rec, ShedReceipt):
                assert rec.reason == "breaker_open", rec
                breaker_sheds += 1
        except PartyUnavailable:
            pass
        rec = shared.insert("victim", *stream[r])
        assert isinstance(rec, InsertReceipt), rec
    q_shared = shared.query("victim", reduce_to=m)
    wall = time.time() - t0

    br = shared.stats()["breakers"]["chaos"]
    if br["trips"] < 1:
        raise AssertionError(
            f"the faulty tenant never tripped its breaker: {br}")
    if breaker_sheds == 0:
        raise AssertionError(
            "post-trip requests were not shed with breaker_open receipts")
    if not (np.array_equal(np.asarray(q_solo.result.indices),
                           np.asarray(q_shared.result.indices))
            and np.array_equal(np.asarray(q_solo.result.weights),
                               np.asarray(q_shared.result.weights))):
        raise AssertionError(
            "breaker isolation violated: the victim's query draw changed "
            "because a faulty tenant shared the service")
    if q_solo.ledger_total != q_shared.ledger_total:
        raise AssertionError(
            f"victim's bill changed under contention: solo "
            f"{q_solo.ledger_total} vs shared {q_shared.ledger_total}")

    entry = {
        "kind": "breaker_isolation", "rounds": rounds, "chunk_rows": rows,
        "m": m, "breaker": br, "breaker_sheds": breaker_sheds,
        "victim_bill": q_solo.ledger_total, "draw_identical": True,
    }
    row = {"bench": BENCH, "method": "breaker-isolation",
           "size": rounds * rows, "cost_mean": float(br["trips"]),
           "cost_std": float(breaker_sheds),
           "comm": q_shared.ledger_total, "wall_s": round(wall, 2)}
    return entry, row


# --------------------------------------------------------------------------
# Experiment 3: failover draw-identity (the acceptance pin)
# --------------------------------------------------------------------------

def run_failover_identity(fast: bool):
    rounds = 2 if fast else 4
    rows = 1024 if fast else 8192
    m, d, T = 64, 6, 3
    stream = _chunk_stream(21, rounds, rows, d, T)

    def play(**extra):
        svc = CoresetService(clock=SimClock(tick=0.01))
        svc.register("t", task="vrlr", budget=m, seed=5, block_size=256,
                     chunk_blocks=2, **extra)
        recs = [svc.insert("t", *c) for c in stream]
        q = svc.query("t", reduce_to=m)
        return svc, recs, q

    t0 = time.time()
    svc_ok, recs_ok, q_ok = play()
    # memory_budget_bytes=1 is unsatisfiable: every pipelined leaf build
    # breaches at its first superchunk probe and falls back to streamed
    svc_fb, recs_fb, q_fb = play(failover=True, memory_budget_bytes=1)
    wall = time.time() - t0

    fallbacks = [r.fallback for r in recs_fb]
    if not all(f == "pipelined->streamed" for f in fallbacks):
        raise AssertionError(
            f"expected every leaf build to fall back pipelined->streamed, "
            f"got {fallbacks}")
    if any(r.fallback is not None for r in recs_ok):
        raise AssertionError("the unforced twin must never fall back")
    if not (np.array_equal(np.asarray(q_ok.result.indices),
                           np.asarray(q_fb.result.indices))
            and np.array_equal(np.asarray(q_ok.result.weights),
                               np.asarray(q_fb.result.weights))):
        raise AssertionError(
            "failover draw-identity violated: pipelined->streamed fallback "
            "changed the query draw")
    led_ok = svc_ok.state("t").ledger
    led_fb = svc_fb.state("t").ledger
    if led_fb.total != led_ok.total:
        raise AssertionError(
            f"fallback bill {led_fb.total} != successful-engine bill "
            f"{led_ok.total} (fallback entries must cost 0 units)")
    fb_tags = {t: u for t, u in led_fb.by_tag().items()
               if t.startswith("fallback/")}
    if len(fb_tags) == 0 or any(u != 0 for u in fb_tags.values()):
        raise AssertionError(
            f"expected zero-unit fallback/ attributions, got {fb_tags}")

    entry = {
        "kind": "failover_identity", "rounds": rounds, "chunk_rows": rows,
        "m": m, "fallbacks": svc_fb.state("t").tree.fallbacks,
        "last_fallback": svc_fb.state("t").tree.last_fallback,
        "bill": led_fb.total, "fallback_tags": sorted(fb_tags),
        "draw_identical": True,
    }
    row = {"bench": BENCH, "method": "failover-identity",
           "size": rounds * rows,
           "cost_mean": float(svc_fb.state("t").tree.fallbacks),
           "cost_std": 0.0, "comm": led_fb.total, "wall_s": round(wall, 2)}
    return entry, row


def run(fast: bool = True):
    entries, rows = [], []
    for fn in (run_hostile_mix, run_breaker_isolation, run_failover_identity):
        e, r = fn(fast)
        entries.append(e)
        rows.append(r)
    write_rows(BENCH, rows)
    write_bench_json(SECTION, entries)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true", default=True)
    ap.add_argument("--full", dest="fast", action="store_false")
    args = ap.parse_args()
    for r in run(fast=args.fast):
        print(r)
